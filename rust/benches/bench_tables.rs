//! End-to-end round-throughput benchmarks, one section per paper table
//! (run with `cargo bench`). These measure the *system* cost of a
//! communication round for each technique at each table's workload shape,
//! on the native engine so the numbers isolate coordinator + compression
//! + transport (the PJRT model step is benchmarked by the experiment
//! harness itself and recorded in EXPERIMENTS.md).
//!
//!   table3 shape: 20 clients × P=77 850 (resnet8), rate 0.1
//!   table4 shape: 100 clients × P=25 920 (charlstm), rate 0.1
//!
//! Also includes the fig5/fig6 ablation axis: round cost vs compression
//! rate, demonstrating where the wire dense-fallback crossover sits.

use fedgmf::compress::{CompressConfig, Compressor, CompressorKind, TauSchedule};
use fedgmf::coordinator::server::{BroadcastPolicy, FlServer, IngestOpts, UploadSource};
use fedgmf::coordinator::traffic::{TrafficMeter, TrafficPolicy};
use fedgmf::sparse::codec::{CodecParams, IndexCoding, ValueCoding};
use fedgmf::sparse::wire;
use fedgmf::util::rng::Rng;
use std::time::Instant;

/// One synthetic FL round over pre-generated gradients: compress on every
/// client, ship (through `codec`), aggregate, broadcast. No model step —
/// pure system cost. Returns (ms/round, total bytes, v1-equivalent bytes).
fn round_cost_with(
    kind: CompressorKind,
    clients: usize,
    p: usize,
    rate: f64,
    rounds: usize,
    codec: CodecParams,
) -> (f64, usize, usize) {
    let cfg = CompressConfig { tau: TauSchedule::Constant(0.4), ..Default::default() };
    let mut comps: Vec<_> = (0..clients).map(|_| fedgmf::compress::build(kind, &cfg, p)).collect();
    let policy = if kind.server_momentum() {
        BroadcastPolicy::ServerMomentum { beta: 0.9 }
    } else {
        BroadcastPolicy::Aggregate
    };
    let mut server = FlServer::new(p, policy);
    let mut meter = TrafficMeter::new(TrafficPolicy::default());
    let k = ((rate * p as f64) as usize).max(1);
    let mut rng = Rng::new(99);
    let grads: Vec<Vec<f32>> =
        (0..clients).map(|_| (0..p).map(|_| rng.normal()).collect()).collect();

    let t0 = Instant::now();
    let mut payload = fedgmf::sparse::vector::SparseVec::empty(p);
    let mut buf = Vec::new();
    for round in 0..rounds {
        meter.begin_round();
        for (c, comp) in comps.iter_mut().enumerate() {
            comp.observe_broadcast(&payload);
            let out = comp.compress(&grads[c], k, round);
            wire::encode_with(&out.gradient, &mut buf, codec);
            meter.record_uplink(c, buf.len(), wire::encoded_bytes(&out.gradient));
            server.ingest(
                UploadSource::Sparse(&wire::decode(&buf).unwrap()),
                IngestOpts::new(),
            );
        }
        let (pl, _ghat) = server.finish_round(clients);
        wire::encode_with(&pl, &mut buf, codec);
        meter.record_broadcast(buf.len(), wire::encoded_bytes(&pl), clients);
        payload = pl;
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / rounds as f64;
    (ms, meter.total(), meter.total_precodec)
}

fn round_cost(
    kind: CompressorKind,
    clients: usize,
    p: usize,
    rate: f64,
    rounds: usize,
) -> (f64, usize) {
    let (ms, bytes, _) = round_cost_with(kind, clients, p, rate, rounds, CodecParams::V1);
    (ms, bytes)
}

fn main() {
    println!("== fedgmf per-round system cost (coordinator+compression+wire, no model step) ==");
    println!("   kernel dispatch: {}\n", fedgmf::sparse::simd::describe());

    println!("-- table3 shape: 20 clients, P=77850 (resnet8), rate 0.1 --");
    for kind in CompressorKind::ALL {
        let (ms, bytes) = round_cost(kind, 20, 77_850, 0.1, 8);
        println!(
            "{:<10} {:>9.2} ms/round   {:>10.2} KB/round",
            kind.name(),
            ms,
            bytes as f64 / 8.0 / 1e3
        );
    }

    println!("\n-- table4 shape: 100 clients, P=25920 (charlstm), rate 0.1 --");
    for kind in CompressorKind::ALL {
        let (ms, bytes) = round_cost(kind, 100, 25_920, 0.1, 5);
        println!(
            "{:<10} {:>9.2} ms/round   {:>10.2} KB/round",
            kind.name(),
            ms,
            bytes as f64 / 5.0 / 1e3
        );
    }

    println!("\n-- fig5/fig6 axis: DGCwGMF round cost vs rate (P=77850, 20 clients) --");
    for rate in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let (ms, bytes) = round_cost(CompressorKind::DgcWgmf, 20, 77_850, rate, 6);
        println!(
            "rate {rate:<4} {:>9.2} ms/round   {:>10.2} KB/round",
            ms,
            bytes as f64 / 6.0 / 1e3
        );
    }

    println!("\n-- codec v2: DGCwGMF bytes/round per wire mode (table3 shape, rate 0.1) --");
    let modes: [(&str, CodecParams); 4] = [
        ("raw-f32(v1)", CodecParams::V1),
        ("varint-f32", CodecParams { index: IndexCoding::Varint, value: ValueCoding::F32 }),
        ("varint-f16", CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 }),
        ("varint-q8", CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 }),
    ];
    for (name, codec) in modes {
        let (ms, bytes, precodec) =
            round_cost_with(CompressorKind::DgcWgmf, 20, 77_850, 0.1, 6, codec);
        println!(
            "{:<12} {:>9.2} ms/round   {:>10.2} KB/round   ratio {:>5.2}x",
            name,
            ms,
            bytes as f64 / 6.0 / 1e3,
            precodec as f64 / bytes as f64
        );
    }

    println!("\n-- ablation: exact vs sampled top-k inside DGCwGMF (P=1M, 8 clients) --");
    for (label, exact) in [("exact", true), ("sampled", false)] {
        let cfg = CompressConfig {
            tau: TauSchedule::Constant(0.4),
            exact_topk: exact,
            ..Default::default()
        };
        let mut comp = fedgmf::compress::DgcGmf::new(&cfg, 1_000_000);
        let mut rng = Rng::new(5);
        let grad: Vec<f32> = (0..1_000_000).map(|_| rng.normal()).collect();
        let t0 = Instant::now();
        for round in 0..6 {
            std::hint::black_box(comp.compress(&grad, 100_000, round));
        }
        println!("topk={label:<8} {:>9.2} ms/compress", t0.elapsed().as_secs_f64() * 1e3 / 6.0);
    }
}
