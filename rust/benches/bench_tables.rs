//! End-to-end round-throughput benchmarks, one section per paper table
//! (run with `cargo bench`). These measure the *system* cost of a
//! communication round for each technique at each table's workload shape,
//! on the native engine so the numbers isolate coordinator + compression
//! + transport (the PJRT model step is benchmarked by the experiment
//! harness itself and recorded in EXPERIMENTS.md).
//!
//!   table3 shape: 20 clients × P=77 850 (resnet8), rate 0.1
//!   table4 shape: 100 clients × P=25 920 (charlstm), rate 0.1
//!
//! Also includes the fig5/fig6 ablation axis: round cost vs compression
//! rate, demonstrating where the wire dense-fallback crossover sits.

use fedgmf::compress::{CompressConfig, Compressor, CompressorKind, TauSchedule};
use fedgmf::coordinator::server::{BroadcastPolicy, FlServer};
use fedgmf::coordinator::traffic::{TrafficMeter, TrafficPolicy};
use fedgmf::sparse::wire;
use fedgmf::util::rng::Rng;
use std::time::Instant;

/// One synthetic FL round over pre-generated gradients: compress on every
/// client, ship, aggregate, broadcast. No model step — pure system cost.
fn round_cost(
    kind: CompressorKind,
    clients: usize,
    p: usize,
    rate: f64,
    rounds: usize,
) -> (f64, usize) {
    let cfg = CompressConfig { tau: TauSchedule::Constant(0.4), ..Default::default() };
    let mut comps: Vec<_> = (0..clients).map(|_| fedgmf::compress::build(kind, &cfg, p)).collect();
    let policy = if kind.server_momentum() {
        BroadcastPolicy::ServerMomentum { beta: 0.9 }
    } else {
        BroadcastPolicy::Aggregate
    };
    let mut server = FlServer::new(p, policy);
    let mut meter = TrafficMeter::new(TrafficPolicy::default());
    let k = ((rate * p as f64) as usize).max(1);
    let mut rng = Rng::new(99);
    let grads: Vec<Vec<f32>> =
        (0..clients).map(|_| (0..p).map(|_| rng.normal()).collect()).collect();

    let t0 = Instant::now();
    let mut payload = fedgmf::sparse::vector::SparseVec::empty(p);
    for round in 0..rounds {
        meter.begin_round();
        for (c, comp) in comps.iter_mut().enumerate() {
            comp.observe_broadcast(&payload);
            let out = comp.compress(&grads[c], k, round);
            let buf = wire::encode(&out.gradient);
            meter.record_uplink(c, buf.len());
            server.receive(&wire::decode(&buf).unwrap());
        }
        let (pl, _ghat) = server.finish_round(clients);
        let buf = wire::encode(&pl);
        meter.record_broadcast(buf.len(), clients);
        payload = pl;
    }
    (t0.elapsed().as_secs_f64() * 1e3 / rounds as f64, meter.total())
}

fn main() {
    println!("== fedgmf per-round system cost (coordinator+compression+wire, no model step) ==\n");

    println!("-- table3 shape: 20 clients, P=77850 (resnet8), rate 0.1 --");
    for kind in CompressorKind::ALL {
        let (ms, bytes) = round_cost(kind, 20, 77_850, 0.1, 8);
        println!(
            "{:<10} {:>9.2} ms/round   {:>10.2} KB/round",
            kind.name(),
            ms,
            bytes as f64 / 8.0 / 1e3
        );
    }

    println!("\n-- table4 shape: 100 clients, P=25920 (charlstm), rate 0.1 --");
    for kind in CompressorKind::ALL {
        let (ms, bytes) = round_cost(kind, 100, 25_920, 0.1, 5);
        println!(
            "{:<10} {:>9.2} ms/round   {:>10.2} KB/round",
            kind.name(),
            ms,
            bytes as f64 / 5.0 / 1e3
        );
    }

    println!("\n-- fig5/fig6 axis: DGCwGMF round cost vs rate (P=77850, 20 clients) --");
    for rate in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let (ms, bytes) = round_cost(CompressorKind::DgcWgmf, 20, 77_850, rate, 6);
        println!(
            "rate {rate:<4} {:>9.2} ms/round   {:>10.2} KB/round",
            ms,
            bytes as f64 / 6.0 / 1e3
        );
    }

    println!("\n-- ablation: exact vs sampled top-k inside DGCwGMF (P=1M, 8 clients) --");
    for (label, exact) in [("exact", true), ("sampled", false)] {
        let cfg = CompressConfig {
            tau: TauSchedule::Constant(0.4),
            exact_topk: exact,
            ..Default::default()
        };
        let mut comp = fedgmf::compress::DgcGmf::new(&cfg, 1_000_000);
        let mut rng = Rng::new(5);
        let grad: Vec<f32> = (0..1_000_000).map(|_| rng.normal()).collect();
        let t0 = Instant::now();
        for round in 0..6 {
            std::hint::black_box(comp.compress(&grad, 100_000, round));
        }
        println!("topk={label:<8} {:>9.2} ms/compress", t0.elapsed().as_secs_f64() * 1e3 / 6.0);
    }
}
