//! Micro- and round-level benchmarks of the L3 hot path (`cargo bench`).
//!
//! The offline vendored crate set has no criterion, so this is a small
//! self-contained harness: warmup + N timed iterations, reporting
//! median/mean/p90 per op. Sizes match the real models (P = 77 850 for
//! resnet8, 25 920 for charlstm) plus a 1M-parameter stress size.
//!
//! Covered (one section per hot-path stage):
//!   topk/exact, topk/sampled      — selection (dominant cost)
//!   score/abs, score/gmf          — selection-score construction
//!   compress/dgc, compress/gmf    — full client compression step
//!   aggregate/20clients           — server-side sparse mean
//!   wire/encode+decode            — serialisation (v1, incl. dense path)
//!   codec/<mode>                  — codec v2 encode/decode per mode, with
//!                                   bytes-per-upload + reduction ratio
//!   kernel/<name>                 — dispatched hot kernels vs their scalar
//!                                   twins on identical inputs (topk
//!                                   threshold, varint, q8, f16, and the
//!                                   full varint+q8 decode), with speedups
//!   ingest/<mode>                 — server fold per upload: materialized
//!                                   decode+add vs the streamed pull-decoder
//!   momentum/accumulate           — client M update
//!   fleet/<n>                     — VirtualStore resident bytes/client at
//!                                   10k/100k/1M clients with a 1k cohort
//!   round/e2e                     — full FlRun::step_round, 20 clients ×
//!                                   P≈1M, sequential vs parallel workers
//!
//! Results are also written machine-readable to `BENCH_hotpath.json` at the
//! repo root so the perf trajectory is tracked across PRs.

use fedgmf::compress::{primitives, CompressConfig, Compressor, CompressorKind, TauSchedule};
use fedgmf::coordinator::round::{FlConfig, FlRun, LrSchedule};
use fedgmf::data::dataset::Dataset;
use fedgmf::runtime::native::{BlobDataset, NativeEngine};
use fedgmf::runtime::TrainEngine;
use fedgmf::sim::network::Network;
use fedgmf::sparse::codec::{q8_block_scale, CodecParams, IndexCoding, ValueCoding, Q8_BLOCK};
use fedgmf::sparse::merge::Aggregator;
use fedgmf::sparse::simd::{self, KernelMode};
use fedgmf::sparse::topk;
use fedgmf::sparse::vector::SparseVec;
use fedgmf::sparse::wire;
use fedgmf::util::json::Json;
use fedgmf::util::rng::Rng;
use std::time::Instant;

#[derive(Clone, Copy)]
struct Stats {
    median_ms: f64,
    mean_ms: f64,
    p90_ms: f64,
}

fn bench<F: FnMut()>(results: &mut Vec<(String, Stats)>, name: &str, iters: usize, mut f: F) {
    for _ in 0..3 {
        f(); // warmup
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
    let stats = Stats {
        median_ms: samples[samples.len() / 2],
        mean_ms: mean,
        p90_ms: samples[samples.len() * 9 / 10],
    };
    println!(
        "{name:<42} median {:>9.3} ms  mean {:>9.3} ms  p90 {:>9.3} ms",
        stats.median_ms, stats.mean_ms, stats.p90_ms
    );
    results.push((name.to_string(), stats));
}

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal()).collect()
}

/// Full communication rounds through `FlRun::step_round` on the native
/// engine: N clients × P params at rate 0.1. Returns mean ms/round over
/// `rounds` steady-state rounds (one warmup round excluded).
fn round_e2e(
    clients: usize,
    input_dim: usize,
    hidden: usize,
    classes: usize,
    workers: usize,
    rounds: usize,
) -> (f64, usize) {
    let engine = NativeEngine::new(input_dim, hidden, classes, 1);
    let p = engine.param_count();
    let shards: Vec<Box<dyn Dataset + Send>> = (0..clients)
        .map(|c| {
            Box::new(BlobDataset::generate_split(32, input_dim, classes, 0.4, 9, 10 + c as u64))
                as Box<dyn Dataset + Send>
        })
        .collect();
    let net = Network::uniform(clients, Default::default());
    let mut cfg = FlConfig::new(CompressorKind::Dgc, 0.1, rounds + 1);
    cfg.lr = LrSchedule::constant(0.05);
    cfg.batch_size = 8;
    cfg.eval_every = 0;
    cfg.warmup.warmup_rounds = 0; // steady-state k from round 0
    cfg.workers = workers;
    let mut run = FlRun::new(&engine, shards, Vec::new(), net, cfg);
    let mut engine = engine;
    run.step_round(&mut engine, 0).unwrap(); // warm the buffers
    let t0 = Instant::now();
    for r in 1..=rounds {
        run.step_round(&mut engine, r).unwrap();
    }
    (t0.elapsed().as_secs_f64() * 1e3 / rounds as f64, p)
}

fn main() {
    // BENCH_QUICK=1: CI smoke mode — small sizes, few iterations, same JSON
    // shape (validated by the workflow); timings are not representative.
    let quick = std::env::var("BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let it = |n: usize| if quick { 3 } else { n };
    let mut results: Vec<(String, Stats)> = Vec::new();
    println!("== fedgmf hot-path micro-benchmarks{} ==", if quick { " (quick mode)" } else { "" });
    let sizes: &[usize] = if quick { &[77_850] } else { &[77_850, 1_000_000] };
    for &p in sizes {
        let label = if p == 77_850 { "P=77850(resnet8)" } else { "P=1M" };
        let k = p / 10;
        let scores: Vec<f32> = randvec(p, 1).iter().map(|x| x.abs()).collect();
        let mut scratch = Vec::new();

        bench(&mut results, &format!("topk/exact        {label}"), it(20), || {
            std::hint::black_box(topk::threshold_exact(&scores, k, &mut scratch));
        });
        bench(&mut results, &format!("topk/sampled      {label}"), it(20), || {
            std::hint::black_box(topk::threshold_sampled(&scores, k, 7, &mut scratch));
        });

        let v = randvec(p, 2);
        let m = randvec(p, 3);
        let mut z = vec![0.0f32; p];
        bench(&mut results, &format!("score/abs         {label}"), it(30), || {
            primitives::abs_score(&mut z, &v);
            std::hint::black_box(&z);
        });
        bench(&mut results, &format!("score/gmf         {label}"), it(30), || {
            primitives::gmf_score(&mut z, &v, &m, 0.4);
            std::hint::black_box(&z);
        });

        let grad = randvec(p, 4);
        let mut dgc = fedgmf::compress::Dgc::new(&CompressConfig::default(), p);
        bench(&mut results, &format!("compress/dgc      {label}"), it(15), || {
            std::hint::black_box(dgc.compress(&grad, k, 1));
        });
        let cfg = CompressConfig { tau: TauSchedule::Constant(0.4), ..Default::default() };
        let mut gmf = fedgmf::compress::DgcGmf::new(&cfg, p);
        gmf.observe_broadcast(&SparseVec::from_dense(&randvec(p, 5)));
        bench(&mut results, &format!("compress/gmf      {label}"), it(15), || {
            std::hint::black_box(gmf.compress(&grad, k, 1));
        });

        let cfg2 = CompressConfig { exact_topk: false, ..cfg.clone() };
        let mut gmf2 = fedgmf::compress::DgcGmf::new(&cfg2, p);
        gmf2.observe_broadcast(&SparseVec::from_dense(&randvec(p, 5)));
        bench(&mut results, &format!("compress/gmf-sampled {label}"), it(15), || {
            std::hint::black_box(gmf2.compress(&grad, k, 1));
        });

        // server-side aggregate of 20 client gradients at rate 0.1
        let grads: Vec<SparseVec> = (0..20u64)
            .map(|c| {
                let raw = randvec(p, 100 + c);
                let abs: Vec<f32> = raw.iter().map(|x| x.abs()).collect();
                let ids = topk::select_topk(&abs, k);
                let vals: Vec<f32> = ids.iter().map(|&i| raw[i as usize]).collect();
                SparseVec::from_sorted(p, ids, vals)
            })
            .collect();
        let refs: Vec<&SparseVec> = grads.iter().collect();
        let mut agg = Aggregator::new(p);
        let mut out_sv = SparseVec::empty(p);
        bench(&mut results, &format!("aggregate/20c     {label}"), it(15), || {
            for g in &grads {
                agg.add(&[g], 1.0, 1);
            }
            agg.finish_into(20, &mut out_sv, 1);
            std::hint::black_box(&out_sv);
        });
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        bench(&mut results, &format!("aggregate/20c-sharded {label}"), it(15), || {
            agg.add(&refs, 1.0, cores);
            agg.finish_into(20, &mut out_sv, cores);
            std::hint::black_box(&out_sv);
        });

        let buf = wire::encode(&grads[0]);
        let mut enc_buf = Vec::new();
        bench(&mut results, &format!("wire/encode       {label}"), it(30), || {
            wire::encode_into(&grads[0], &mut enc_buf);
            std::hint::black_box(&enc_buf);
        });
        let mut dec_sv = SparseVec::empty(0);
        bench(&mut results, &format!("wire/decode       {label}"), it(30), || {
            wire::decode_into(&buf, &mut dec_sv).unwrap();
            std::hint::black_box(&dec_sv);
        });
        // the v1 dense fallback (bulk zero-run writes) — the downlink shape
        // once server-side momentum densifies the aggregate
        let dense_sv = {
            let raw = randvec(p, 7);
            let ids: Vec<u32> = (0..p as u32).filter(|i| i % 5 != 0).collect();
            let vals: Vec<f32> = ids.iter().map(|&i| raw[i as usize]).collect();
            SparseVec::from_sorted(p, ids, vals)
        };
        bench(&mut results, &format!("wire/encode-dense {label}"), it(15), || {
            wire::encode_into(&dense_sv, &mut enc_buf);
            std::hint::black_box(&enc_buf);
        });

        let mut mom = randvec(p, 6);
        bench(&mut results, &format!("momentum/accum    {label}"), it(30), || {
            primitives::momentum_accumulate(&mut mom, 0.9, &grads[0]);
            std::hint::black_box(&mom);
        });
        println!();
    }

    // ---- codec v2 micro-benchmarks: encode/decode per mode at the table3
    // uplink shape (P = 77 850, rate 0.1), plus a mid-density bitmap shape.
    // Throughput is reported against the v1-equivalent payload bytes, so
    // modes are comparable on one axis; bytes-per-upload + ratio land in
    // the JSON for the byte-reduction trajectory.
    println!("== codec v2 (per-upload encode/decode, P=77850 rate 0.1) ==");
    let codec_rows = {
        let p = 77_850usize;
        let k = p / 10;
        let raw = randvec(p, 40);
        let abs: Vec<f32> = raw.iter().map(|x| x.abs()).collect();
        let ids = topk::select_topk(&abs, k);
        let vals: Vec<f32> = ids.iter().map(|&i| raw[i as usize]).collect();
        let topk_sv = SparseVec::from_sorted(p, ids, vals);
        let mid_sv = {
            let ids: Vec<u32> = (0..p as u32).filter(|i| i % 3 == 0).collect();
            let vals: Vec<f32> = ids.iter().map(|&i| raw[i as usize]).collect();
            SparseVec::from_sorted(p, ids, vals)
        };
        let modes: Vec<(String, &SparseVec, CodecParams)> = vec![
            ("raw-f32(v1)".into(), &topk_sv, CodecParams::V1),
            (
                "varint-f32".into(),
                &topk_sv,
                CodecParams { index: IndexCoding::Varint, value: ValueCoding::F32 },
            ),
            (
                "varint-f16".into(),
                &topk_sv,
                CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 },
            ),
            (
                "varint-q8".into(),
                &topk_sv,
                CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 },
            ),
            (
                "bitmap-f16(d=0.33)".into(),
                &mid_sv,
                CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 },
            ),
        ];
        let mut rows: Vec<Json> = Vec::new();
        let mut enc_buf = Vec::new();
        let mut dec_sv = SparseVec::empty(0);
        for (name, sv, params) in &modes {
            let v1_bytes = wire::encoded_bytes(sv);
            let mut enc_stats = Vec::new();
            bench(&mut enc_stats, &format!("codec/encode {name}"), it(20), || {
                wire::encode_with(sv, &mut enc_buf, *params);
                std::hint::black_box(&enc_buf);
            });
            let bytes = enc_buf.len();
            let mut dec_stats = Vec::new();
            bench(&mut dec_stats, &format!("codec/decode {name}"), it(20), || {
                wire::decode_into(&enc_buf, &mut dec_sv).unwrap();
                std::hint::black_box(&dec_sv);
            });
            let enc = enc_stats[0].1;
            let dec = dec_stats[0].1;
            let gbps = |ms: f64| v1_bytes as f64 / 1e9 / (ms / 1e3).max(1e-12);
            let ratio = v1_bytes as f64 / bytes as f64;
            println!(
                "codec/{name:<20} {bytes:>8} B/upload  ratio {ratio:>5.2}x  \
                 enc {:>7.2} GB/s  dec {:>7.2} GB/s",
                gbps(enc.median_ms),
                gbps(dec.median_ms)
            );
            rows.push(Json::obj(vec![
                ("name", Json::str(name.as_str())),
                ("bytes_per_upload", Json::num(bytes as f64)),
                ("v1_bytes_per_upload", Json::num(v1_bytes as f64)),
                ("ratio", Json::num(ratio)),
                ("encode_ms", Json::num(enc.median_ms)),
                ("decode_ms", Json::num(dec.median_ms)),
                ("encode_gbps_v1eq", Json::num(gbps(enc.median_ms))),
                ("decode_gbps_v1eq", Json::num(gbps(dec.median_ms))),
            ]));
            results.push((format!("codec/encode {name}"), enc));
            results.push((format!("codec/decode {name}"), dec));
        }
        println!();
        rows
    };

    // ---- kernel dispatch: each rewritten hot kernel timed under its scalar
    // twin and the dispatched implementation on identical inputs at the
    // table3 uplink shape (P = 77 850, rate 0.1). The two headline rows
    // (topk/threshold, decode/varint+q8) carry the acceptance bar: with AVX2
    // dispatched they must run >= 2x their scalar baselines, asserted here so
    // `cargo bench` itself fails on regression (the CI gate re-checks the
    // JSON). The full-buffer decode rows flip the global dispatch mode per
    // call; bench main is single-threaded, so this cannot race.
    println!("== kernel dispatch (scalar vs {}) ==", simd::describe());
    let kernel_rows = {
        fn pair(
            results: &mut Vec<(String, Stats)>,
            rows: &mut Vec<Json>,
            name: &str,
            iters: usize,
            scalar: impl FnMut(),
            dispatched: impl FnMut(),
        ) -> f64 {
            let mut s_stats = Vec::new();
            bench(&mut s_stats, &format!("kernel/{name} scalar"), iters, scalar);
            let mut d_stats = Vec::new();
            bench(&mut d_stats, &format!("kernel/{name} dispatched"), iters, dispatched);
            let (s, d) = (s_stats[0].1, d_stats[0].1);
            let speedup = s.median_ms / d.median_ms.max(1e-9);
            println!("kernel/{name:<25} speedup {speedup:>6.2}x");
            rows.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("scalar_ms", Json::num(s.median_ms)),
                ("dispatched_ms", Json::num(d.median_ms)),
                ("speedup", Json::num(speedup)),
            ]));
            results.push((format!("kernel/{name} scalar"), s));
            results.push((format!("kernel/{name} dispatched"), d));
            speedup
        }
        let p = 77_850usize;
        let k = p / 10;
        let raw = randvec(p, 60);
        let scores: Vec<f32> = raw.iter().map(|x| x.abs()).collect();
        let ids = topk::select_topk(&scores, k);
        let vals: Vec<f32> = ids.iter().map(|&i| raw[i as usize]).collect();
        let nnz = ids.len();
        let mut rows: Vec<Json> = Vec::new();

        let (mut sc1, mut sc2) = (Vec::new(), Vec::new());
        let topk_speedup = pair(
            &mut results,
            &mut rows,
            "topk/threshold",
            it(20),
            || {
                std::hint::black_box(topk::threshold_exact_quickselect(&scores, k, &mut sc1));
            },
            || {
                std::hint::black_box(topk::threshold_exact(&scores, k, &mut sc2));
            },
        );

        let (mut vb1, mut vb2) = (Vec::new(), Vec::new());
        pair(
            &mut results,
            &mut rows,
            "varint/encode",
            it(30),
            || {
                vb1.clear();
                simd::varint_encode_gaps_scalar(&ids, &mut vb1);
                std::hint::black_box(&vb1);
            },
            || {
                vb2.clear();
                simd::varint_encode_gaps(&ids, &mut vb2);
                std::hint::black_box(&vb2);
            },
        );
        let venc = {
            let mut b = Vec::new();
            simd::varint_encode_gaps(&ids, &mut b);
            b
        };
        let (mut g1, mut g2) = (vec![0u32; nnz], vec![0u32; nnz]);
        pair(
            &mut results,
            &mut rows,
            "varint/decode",
            it(30),
            || {
                let mut pos = 0;
                std::hint::black_box(simd::varint_decode_gaps_scalar(&venc, &mut pos, &mut g1));
            },
            || {
                let mut pos = 0;
                std::hint::black_box(simd::varint_decode_gaps(&venc, &mut pos, &mut g2));
            },
        );

        let (mut q1, mut q2) = (Vec::new(), Vec::new());
        pair(
            &mut results,
            &mut rows,
            "q8/quantize",
            it(30),
            || {
                q1.clear();
                for block in vals.chunks(Q8_BLOCK) {
                    simd::q8_quantize_scalar(block, simd::maxabs_scalar(block), &mut q1);
                }
                std::hint::black_box(&q1);
            },
            || {
                q2.clear();
                for block in vals.chunks(Q8_BLOCK) {
                    simd::q8_quantize(block, simd::maxabs(block), &mut q2);
                }
                std::hint::black_box(&q2);
            },
        );
        // q2 holds the concatenated quantized blocks (no scale prefixes), so
        // byte offsets line up with value offsets block for block
        let qblocks: Vec<(f32, usize, usize)> = vals
            .chunks(Q8_BLOCK)
            .scan(0usize, |off, block| {
                let o = *off;
                *off += block.len();
                Some((q8_block_scale(block), o, block.len()))
            })
            .collect();
        let (mut d1, mut d2) = (vec![0.0f32; nnz], vec![0.0f32; nnz]);
        pair(
            &mut results,
            &mut rows,
            "q8/dequantize",
            it(30),
            || {
                for &(s, o, n) in &qblocks {
                    simd::q8_dequantize_scalar(&q2[o..o + n], s, &mut d1[o..o + n]);
                }
                std::hint::black_box(&d1);
            },
            || {
                for &(s, o, n) in &qblocks {
                    simd::q8_dequantize(&q2[o..o + n], s, &mut d2[o..o + n]);
                }
                std::hint::black_box(&d2);
            },
        );

        let (mut h1, mut h2) = (Vec::new(), Vec::new());
        pair(
            &mut results,
            &mut rows,
            "f16/encode",
            it(30),
            || {
                h1.clear();
                simd::f16_encode_scalar(&vals, &mut h1);
                std::hint::black_box(&h1);
            },
            || {
                h2.clear();
                simd::f16_encode(&vals, &mut h2);
                std::hint::black_box(&h2);
            },
        );
        let (mut fd1, mut fd2) = (vec![0.0f32; nnz], vec![0.0f32; nnz]);
        pair(
            &mut results,
            &mut rows,
            "f16/decode",
            it(30),
            || {
                simd::f16_decode_scalar(&h2, &mut fd1);
                std::hint::black_box(&fd1);
            },
            || {
                simd::f16_decode(&h2, &mut fd2);
                std::hint::black_box(&fd2);
            },
        );

        let sv = SparseVec::from_sorted(p, ids.clone(), vals.clone());
        let q8wire = {
            let mut b = Vec::new();
            wire::encode_with(
                &sv,
                &mut b,
                CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 },
            );
            b
        };
        let (mut w1, mut w2) = (SparseVec::empty(0), SparseVec::empty(0));
        let decode_speedup = pair(
            &mut results,
            &mut rows,
            "decode/varint+q8",
            it(20),
            || {
                simd::set_mode(KernelMode::Scalar);
                wire::decode_into(&q8wire, &mut w1).unwrap();
                std::hint::black_box(&w1);
            },
            || {
                simd::set_mode(KernelMode::Auto);
                wire::decode_into(&q8wire, &mut w2).unwrap();
                std::hint::black_box(&w2);
            },
        );
        simd::set_mode(KernelMode::Auto);

        // the acceptance bar is only meaningful when AVX2 actually
        // dispatched (a FEDGMF_KERNELS=scalar leg measures ~1x, honestly)
        if simd::active().avx2 {
            assert!(
                topk_speedup >= 2.0,
                "topk/threshold bucketed speedup {topk_speedup:.2}x below the 2x bar"
            );
            assert!(
                decode_speedup >= 2.0,
                "decode/varint+q8 speedup {decode_speedup:.2}x below the 2x bar"
            );
        }
        println!();
        rows
    };

    // ---- streamed-ingest throughput: fold one upload into the server
    // aggregate, materialized (decode_into + add) vs streamed (Runs
    // pull-decoder + fold_stream), with the resident ingest scratch each
    // path holds per upload — the streamed path's is a pointer-sized view
    // regardless of model dimension.
    println!("== ingest throughput (server fold per upload) ==");
    let ingest_rows = {
        use fedgmf::sparse::stream::Runs;
        let dims: &[usize] = if quick { &[77_850] } else { &[77_850, 1_000_000] };
        let modes: &[(&str, CodecParams)] = &[
            ("raw-f32(v1)", CodecParams::V1),
            ("varint-f16", CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 }),
        ];
        let mut rows: Vec<Json> = Vec::new();
        for &p in dims {
            let k = p / 10;
            let raw = randvec(p, 55);
            let abs: Vec<f32> = raw.iter().map(|x| x.abs()).collect();
            let ids = topk::select_topk(&abs, k);
            let vals: Vec<f32> = ids.iter().map(|&i| raw[i as usize]).collect();
            let sv = SparseVec::from_sorted(p, ids, vals);
            for &(name, params) in modes {
                let mut buf = Vec::new();
                wire::encode_with(&sv, &mut buf, params);
                let wire_bytes = buf.len();
                let mut agg = Aggregator::new(p);
                let mut echo = SparseVec::empty(p);
                let mut m_stats = Vec::new();
                bench(&mut m_stats, &format!("ingest/materialized {name} P={p}"), it(20), || {
                    wire::decode_into(&buf, &mut echo).unwrap();
                    agg.add(&[&echo], 1.0, 1);
                    std::hint::black_box(&agg);
                });
                let mut s_stats = Vec::new();
                bench(&mut s_stats, &format!("ingest/streamed     {name} P={p}"), it(20), || {
                    let runs = Runs::validate(&buf).unwrap();
                    agg.fold_stream(&runs, 1.0);
                    std::hint::black_box(&agg);
                });
                let m = m_stats[0].1;
                let s = s_stats[0].1;
                let mbps = |ms: f64| wire_bytes as f64 / 1e6 / (ms / 1e3).max(1e-12);
                let mat_scratch =
                    (echo.indices.capacity() + echo.values.capacity()) * 4;
                let stream_scratch = std::mem::size_of::<Runs<'static>>();
                println!(
                    "ingest/{name:<14} P={p:>8} {wire_bytes:>8} B  materialized \
                     {:>8.1} MB/s ({mat_scratch} B scratch)  streamed {:>8.1} MB/s \
                     ({stream_scratch} B scratch)",
                    mbps(m.median_ms),
                    mbps(s.median_ms)
                );
                rows.push(Json::obj(vec![
                    ("name", Json::str(name)),
                    ("dim", Json::num(p as f64)),
                    ("nnz", Json::num(sv.nnz() as f64)),
                    ("wire_bytes", Json::num(wire_bytes as f64)),
                    ("materialized_ms", Json::num(m.median_ms)),
                    ("streamed_ms", Json::num(s.median_ms)),
                    ("materialized_mbps", Json::num(mbps(m.median_ms))),
                    ("streamed_mbps", Json::num(mbps(s.median_ms))),
                    ("materialized_scratch_bytes", Json::num(mat_scratch as f64)),
                    ("streamed_scratch_bytes", Json::num(stream_scratch as f64)),
                ]));
                results.push((format!("ingest/materialized {name} P={p}"), m));
                results.push((format!("ingest/streamed {name} P={p}"), s));
            }
        }
        println!();
        rows
    };

    // ---- fleet memory: the virtualized-store acceptance bar. Build
    // longtail fleets at 10k/100k/1M clients, checkout + compress + checkin
    // one 1k cohort, and report resident client-state bytes per client
    // against the dense-equivalent footprint. Shards are zero-sized stubs:
    // `resident_state_bytes` deliberately excludes data payloads, so the
    // numbers isolate the per-client state planes.
    println!("== fleet memory (VirtualStore, 1k cohort, dim 4096) ==");
    let fleet_rows = {
        use fedgmf::coordinator::store::{ClientStore, DenseStore, VirtualStore};
        use fedgmf::data::dataset::Batch;
        struct StubShard;
        impl Dataset for StubShard {
            fn len(&self) -> usize {
                0
            }
            fn label_histogram(&self) -> Vec<usize> {
                Vec::new()
            }
            fn sample_batch(&self, _batch: usize, _rng: &mut Rng) -> Batch {
                unreachable!("fleet-memory bench never trains")
            }
            fn eval_batches(&self, _batch: usize) -> Vec<Batch> {
                Vec::new()
            }
        }
        let dim = 4096usize;
        let k = dim / 10;
        let cohort_n = 1000usize;
        let ccfg = CompressConfig::default();
        let root = Rng::new(77);
        let codec = CodecParams::default();
        let stub_shards = |n: usize| -> Vec<Box<dyn Dataset + Send>> {
            (0..n).map(|_| Box::new(StubShard) as Box<dyn Dataset + Send>).collect()
        };
        // dense-equivalent bytes per client, measured on a small fleet of
        // the same scheme and dim (a dense 1M-client fleet would not fit —
        // that is the point)
        let mut probe =
            DenseStore::new(stub_shards(8), &root, dim, CompressorKind::DgcWgmf, &ccfg, codec);
        let dense_per_client = probe.resident_state_bytes() / probe.fleet_len();
        let fleets: &[usize] = &[10_000, 100_000, 1_000_000];
        let grad = randvec(dim, 88);
        let mut rows: Vec<Json> = Vec::new();
        let mut measured: Vec<(usize, usize)> = Vec::new();
        for &fleet in fleets {
            let t0 = Instant::now();
            let mut store = VirtualStore::new(
                stub_shards(fleet),
                &root,
                dim,
                CompressorKind::DgcWgmf,
                &ccfg,
                codec,
            );
            // an evenly-strided sorted cohort — a longtail spread, not the
            // first 1k ids
            let stride = fleet / cohort_n;
            let cohort: Vec<usize> = (0..cohort_n).map(|i| i * stride).collect();
            store.checkout(&cohort);
            for c in store.cohort_mut() {
                // one real compression step so eviction gathers live
                // residual planes, not all-zero ones
                c.compressor.compress_into(&grad, k, 0, &mut c.upload);
            }
            store.checkin();
            let resident = store.resident_state_bytes();
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let per_client = resident as f64 / fleet as f64;
            let ratio = dense_per_client as f64 / per_client;
            println!(
                "fleet/{fleet:>9} clients  resident {:>8.1} MB  {per_client:>8.1} B/client  \
                 dense-equiv {dense_per_client} B/client ({ratio:>6.1}x)  [{ms:.0} ms]",
                resident as f64 / 1e6
            );
            rows.push(Json::obj(vec![
                ("fleet", Json::num(fleet as f64)),
                ("cohort", Json::num(cohort_n as f64)),
                ("dim", Json::num(dim as f64)),
                ("resident_bytes", Json::num(resident as f64)),
                ("bytes_per_client", Json::num(per_client)),
                ("dense_equiv_bytes_per_client", Json::num(dense_per_client as f64)),
                ("virtualization_ratio", Json::num(ratio)),
                ("build_round_ms", Json::num(ms)),
            ]));
            measured.push((fleet, resident));
        }
        // the acceptance bar, asserted here so `cargo bench` itself fails
        // if virtualization regresses (the CI gate re-checks the JSON):
        // growing the fleet past the cohort must cost only the at-rest
        // record, and the 1M-client fleet must sit far below dense
        let (f_hi, r_hi) = measured[measured.len() - 1];
        let (f_lo, r_lo) = measured[measured.len() - 2];
        let marginal = (r_hi - r_lo) as f64 / (f_hi - f_lo) as f64;
        assert!(
            marginal <= 512.0,
            "per-client marginal cost {marginal:.0} B exceeds the at-rest record bound"
        );
        let per_client_hi = r_hi as f64 / f_hi as f64;
        assert!(
            per_client_hi * 20.0 <= dense_per_client as f64,
            "1M-client fleet must stay far below dense: {per_client_hi:.0} B/client \
             vs dense-equiv {dense_per_client} B/client"
        );
        println!();
        rows
    };

    // ---- round-level end-to-end: 20 clients × P≈1M, sequential vs parallel
    // (quick mode shrinks the model and client count to keep CI fast)
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (e2e_clients, e2e_in, e2e_hidden, e2e_classes, e2e_rounds) =
        if quick { (8, 256, 120, 8, 2) } else { (20, 1024, 976, 16, 4) };
    println!("== round end-to-end (FlRun::step_round, {e2e_clients} clients, rate 0.1) ==");
    let (seq_ms, p) = round_e2e(e2e_clients, e2e_in, e2e_hidden, e2e_classes, 1, e2e_rounds);
    println!("round/e2e sequential (P={p})            {seq_ms:>9.1} ms/round");
    let (par_ms, _) = round_e2e(e2e_clients, e2e_in, e2e_hidden, e2e_classes, 0, e2e_rounds);
    let speedup = seq_ms / par_ms;
    println!("round/e2e parallel   ({cores} cores)          {par_ms:>9.1} ms/round");
    println!("round/e2e speedup                          {speedup:>9.2}x");

    // ---- machine-readable trajectory file at the repo root
    let sections: Vec<Json> = results
        .iter()
        .map(|(name, s)| {
            Json::obj(vec![
                ("name", Json::str(name.trim())),
                ("median_ms", Json::num(s.median_ms)),
                ("mean_ms", Json::num(s.mean_ms)),
                ("p90_ms", Json::num(s.p90_ms)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::num(3.0)),
        ("generated", Json::Bool(true)),
        ("quick", Json::Bool(quick)),
        ("host_cores", Json::num(cores as f64)),
        ("kernel_dispatch", Json::str(simd::describe())),
        ("codec", Json::Arr(codec_rows)),
        ("kernels", Json::Arr(kernel_rows)),
        ("ingest_throughput", Json::Arr(ingest_rows)),
        ("fleet_memory", Json::Arr(fleet_rows)),
        (
            "round_e2e",
            Json::obj(vec![
                ("clients", Json::num(e2e_clients as f64)),
                ("param_count", Json::num(p as f64)),
                ("rate", Json::num(0.1)),
                ("sequential_ms_per_round", Json::num(seq_ms)),
                ("parallel_ms_per_round", Json::num(par_ms)),
                ("parallel_workers", Json::num(cores as f64)),
                ("speedup", Json::num(speedup)),
            ]),
        ),
        ("micro", Json::Arr(sections)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match std::fs::write(path, doc.to_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
