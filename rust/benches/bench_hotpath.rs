//! Micro-benchmarks of the L3 hot path (run with `cargo bench`).
//!
//! The offline vendored crate set has no criterion, so this is a small
//! self-contained harness: warmup + N timed iterations, reporting
//! median/mean/p90 per op. Sizes match the real models (P = 77 850 for
//! resnet8, 25 920 for charlstm) plus a 1M-parameter stress size.
//!
//! Covered (one section per hot-path stage):
//!   topk/exact, topk/sampled      — selection (dominant cost)
//!   score/abs, score/gmf          — selection-score construction
//!   compress/dgc, compress/gmf    — full client compression step
//!   aggregate/20clients           — server-side sparse mean
//!   wire/encode+decode            — serialisation
//!   momentum/accumulate           — client M update

use fedgmf::compress::{primitives, CompressConfig, Compressor, TauSchedule};
use fedgmf::sparse::merge::Aggregator;
use fedgmf::sparse::topk;
use fedgmf::sparse::vector::SparseVec;
use fedgmf::sparse::wire;
use fedgmf::util::rng::Rng;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    for _ in 0..3 {
        f(); // warmup
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
    let median = samples[samples.len() / 2];
    let p90 = samples[samples.len() * 9 / 10];
    println!("{name:<42} median {median:>9.3} ms  mean {mean:>9.3} ms  p90 {p90:>9.3} ms");
}

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal()).collect()
}

fn main() {
    println!("== fedgmf hot-path micro-benchmarks ==");
    for &p in &[77_850usize, 1_000_000] {
        let label = if p == 77_850 { "P=77850(resnet8)" } else { "P=1M" };
        let k = p / 10;
        let scores: Vec<f32> = randvec(p, 1).iter().map(|x| x.abs()).collect();
        let mut scratch = Vec::new();

        bench(&format!("topk/exact        {label}"), 20, || {
            std::hint::black_box(topk::threshold_exact(&scores, k, &mut scratch));
        });
        bench(&format!("topk/sampled      {label}"), 20, || {
            std::hint::black_box(topk::threshold_sampled(&scores, k, 7, &mut scratch));
        });

        let v = randvec(p, 2);
        let m = randvec(p, 3);
        let mut z = vec![0.0f32; p];
        bench(&format!("score/abs         {label}"), 30, || {
            primitives::abs_score(&mut z, &v);
            std::hint::black_box(&z);
        });
        bench(&format!("score/gmf         {label}"), 30, || {
            primitives::gmf_score(&mut z, &v, &m, 0.4);
            std::hint::black_box(&z);
        });

        let grad = randvec(p, 4);
        let mut dgc = fedgmf::compress::Dgc::new(&CompressConfig::default(), p);
        bench(&format!("compress/dgc      {label}"), 15, || {
            std::hint::black_box(dgc.compress(&grad, k, 1));
        });
        let cfg = CompressConfig { tau: TauSchedule::Constant(0.4), ..Default::default() };
        let mut gmf = fedgmf::compress::DgcGmf::new(&cfg, p);
        gmf.observe_broadcast(&SparseVec::from_dense(&randvec(p, 5)));
        bench(&format!("compress/gmf      {label}"), 15, || {
            std::hint::black_box(gmf.compress(&grad, k, 1));
        });

        let cfg2 = CompressConfig { exact_topk: false, ..cfg.clone() };
        let mut gmf2 = fedgmf::compress::DgcGmf::new(&cfg2, p);
        gmf2.observe_broadcast(&SparseVec::from_dense(&randvec(p, 5)));
        bench(&format!("compress/gmf-sampled {label}"), 15, || {
            std::hint::black_box(gmf2.compress(&grad, k, 1));
        });

        // server-side aggregate of 20 client gradients at rate 0.1
        let grads: Vec<SparseVec> = (0..20u64)
            .map(|c| {
                let raw = randvec(p, 100 + c);
                let abs: Vec<f32> = raw.iter().map(|x| x.abs()).collect();
                let ids = topk::select_topk(&abs, k);
                let vals: Vec<f32> = ids.iter().map(|&i| raw[i as usize]).collect();
                SparseVec::from_sorted(p, ids, vals)
            })
            .collect();
        let mut agg = Aggregator::new(p);
        bench(&format!("aggregate/20c     {label}"), 15, || {
            for g in &grads {
                agg.add(g);
            }
            std::hint::black_box(agg.finish_mean(20));
        });

        let buf = wire::encode(&grads[0]);
        bench(&format!("wire/encode       {label}"), 30, || {
            std::hint::black_box(wire::encode(&grads[0]));
        });
        bench(&format!("wire/decode       {label}"), 30, || {
            std::hint::black_box(wire::decode(&buf).unwrap());
        });

        let mut mom = randvec(p, 6);
        bench(&format!("momentum/accum    {label}"), 30, || {
            primitives::momentum_accumulate(&mut mom, 0.9, &grads[0]);
            std::hint::black_box(&mom);
        });
        println!();
    }
}
