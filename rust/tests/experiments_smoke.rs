//! Smoke tests of the experiment harness: every registered experiment id
//! runs at quick scale on the native engine (table1/table2 are metadata
//! renders; the heavy grids are restricted to one level and two techniques
//! so this completes in seconds without artifacts).

use fedgmf::compress::CompressorKind;
use fedgmf::config::{EngineKind, Scale};
use fedgmf::experiments::{list, run, ExpArgs};
use std::path::PathBuf;

fn args(tmp: &str) -> ExpArgs {
    let out = std::env::temp_dir().join(format!("fedgmf-exp-{}-{tmp}", std::process::id()));
    let mut a = ExpArgs::new(PathBuf::from("artifacts"), out);
    a.scale = Scale::Quick;
    a.engine = Some(EngineKind::Native);
    a.techniques = vec![CompressorKind::Dgc, CompressorKind::DgcWgmf];
    a.levels = vec![0.99];
    a
}

#[test]
fn list_contains_every_id() {
    let l = list();
    for (id, _) in fedgmf::experiments::registry::EXPERIMENTS {
        assert!(l.contains(id));
    }
}

#[test]
fn table1_and_table2_render() {
    let a = args("t12");
    let t1 = run("table1", &a).unwrap();
    assert!(t1.contains("# of clients"));
    let t2 = run("table2", &a).unwrap();
    assert!(t2.contains("DGCwGMF") && t2.contains("compression process"));
}

#[test]
fn table3_quick_native() {
    let a = args("t3");
    let report = run("table3", &a).unwrap();
    assert!(report.contains("Cifar10-0"));
    assert!(report.contains("DGC"));
    assert!(report.contains("DGCwGMF"));
    // evidence files written
    assert!(a.out_dir.join("table3").join("summary.json").exists());
}

#[test]
fn fig4_quick_native_writes_curves() {
    let a = args("f4");
    let report = run("fig4", &a).unwrap();
    assert!(report.contains("DGC"));
    assert!(a.out_dir.join("fig4").join("DGC.csv").exists());
    assert!(a.out_dir.join("fig4").join("DGCwGMF.csv").exists());
}

#[test]
fn fig5_quick_native_sweeps() {
    let mut a = args("f5");
    a.levels = vec![0.2, 0.8]; // rates for the sweep
    let report = run("fig5", &a).unwrap();
    assert!(report.contains("0.2") && report.contains("0.8"));
    let csv = std::fs::read_to_string(a.out_dir.join("fig5").join("sweep.csv")).unwrap();
    assert!(csv.lines().count() >= 5); // header + 2 rates × 2 techniques
}

#[test]
fn time_to_accuracy_quick_native() {
    let mut a = args("tta");
    a.levels = vec![0.6, 2.0]; // simulated-seconds budgets
    let report = run("time_to_accuracy", &a).unwrap();
    assert!(report.contains("Time-to-accuracy"));
    assert!(report.contains("DGCwGMF"));
    assert!(report.contains("acc@budget"));
    let csv =
        std::fs::read_to_string(a.out_dir.join("time_to_accuracy").join("budgets.csv")).unwrap();
    assert_eq!(csv.lines().count(), 5, "header + 2 techniques × 2 budgets");
    // per-round curves carry the scheduler columns
    let curve =
        std::fs::read_to_string(a.out_dir.join("time_to_accuracy").join("DGC.csv")).unwrap();
    assert!(curve.lines().next().unwrap().contains("dropped_deadline"));
}

#[test]
fn staleness_sweep_quick_native() {
    let mut a = args("ss");
    a.techniques = vec![CompressorKind::DgcWgmf];
    a.levels = vec![0.5]; // carry_discounted alpha
    let report = run("staleness_sweep", &a).unwrap();
    assert!(report.contains("Staleness sweep"));
    assert!(report.contains("drop"));
    assert!(report.contains("carry"));
    assert!(report.contains("carry_disc"));
    assert!(report.contains("carry+feas"));
    let csv =
        std::fs::read_to_string(a.out_dir.join("staleness_sweep").join("sweep.csv")).unwrap();
    assert_eq!(csv.lines().count(), 5, "header + 4 policy variants");
    // per-policy curves carry the semi-sync recorder columns
    let curve = std::fs::read_to_string(
        a.out_dir.join("staleness_sweep").join("DGCwGMF_carry.csv"),
    )
    .unwrap();
    let header = curve.lines().next().unwrap();
    assert!(header.contains("carried_in") && header.contains("traffic_gini"));
}

#[test]
fn unknown_id_lists_options() {
    let a = args("bad");
    let err = run("table99", &a).unwrap_err().to_string();
    assert!(err.contains("table3"));
}
