//! Codec v2 integration properties: round-trips through reused buffers for
//! every (index, value, container) mode combination, strict-prefix and
//! corruption rejection, v1 ↔ v2 cross-version decoding, default-config
//! byte identity with v1, and the rate-0.1 bytes-per-round bars the issue
//! pins (varint never exceeds v1 sparse bytes; ≥ 1.5× reduction).
//!
//! Same in-tree property-harness conventions as `proptests.rs`: `CASES`
//! deterministic seeds, replayable via `PROP_SEED=<n>`.

use fedgmf::sparse::codec::{
    self, CodecParams, IndexCoding, ValueCoding, CONTAINER_BITMAP, CONTAINER_DENSE,
    CONTAINER_SPARSE, KIND_V2,
};
use fedgmf::sparse::vector::SparseVec;
use fedgmf::sparse::wire;
use fedgmf::util::rng::Rng;

const CASES: u64 = 40;

fn seeds() -> impl Iterator<Item = u64> {
    let base: u64 = std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC0DE);
    (0..CASES).map(move |i| base.wrapping_add(i))
}

fn all_params() -> Vec<CodecParams> {
    let mut out = Vec::new();
    for index in [IndexCoding::Raw, IndexCoding::Varint] {
        for value in [ValueCoding::F32, ValueCoding::F16, ValueCoding::Q8] {
            out.push(CodecParams { index, value });
        }
    }
    out
}

fn rand_support(rng: &mut Rng, dim: usize, nnz: usize) -> SparseVec {
    let mut ids: Vec<u32> = (0..dim as u32).collect();
    rng.shuffle(&mut ids);
    ids.truncate(nnz);
    ids.sort_unstable();
    let values: Vec<f32> = ids.iter().map(|_| rng.normal() * 3.0).collect();
    SparseVec::from_sorted(dim, ids, values)
}

/// The value each coding is contractually allowed to deliver: exact for
/// f32, the f16 round-trip for f16. (q8 is block-dependent; its error
/// bound is asserted separately.)
fn expected_value(coding: ValueCoding, v: f32) -> f32 {
    match coding {
        ValueCoding::F32 => v,
        ValueCoding::F16 => codec::f16_bits_to_f32(codec::f32_to_f16_bits(v)),
        ValueCoding::Q8 => unreachable!("q8 asserted via error bound"),
    }
}

// ------------------------------------------------------------- round-trips

#[test]
fn prop_roundtrip_reused_buffers_every_mode_and_container() {
    let mut buf = Vec::new();
    let mut back = SparseVec::empty(0);
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let dim = 16 + rng.below(600);
        // sweep densities so every container gets picked across the run
        for frac in [0.02f64, 0.15, 0.35, 0.7, 0.98] {
            let nnz = ((dim as f64 * frac).ceil() as usize).min(dim);
            let sv = rand_support(&mut rng, dim, nnz);
            for p in all_params() {
                wire::encode_with(&sv, &mut buf, p);
                assert_eq!(buf.len(), wire::encoded_bytes_with(&sv, p), "seed {seed} {p:?}");
                wire::decode_into(&buf, &mut back).unwrap();
                assert_eq!(back.dim, sv.dim, "seed {seed} {p:?}");
                match p.value {
                    ValueCoding::F32 => {
                        assert_eq!(back.to_dense(), sv.to_dense(), "seed {seed} {p:?}");
                    }
                    ValueCoding::F16 => {
                        // dense containers drop entries that quantise to 0;
                        // compare coordinate-wise against the f16 round-trip
                        let dense = back.to_dense();
                        let mut want = vec![0.0f32; sv.dim];
                        for (&i, &v) in sv.indices.iter().zip(&sv.values) {
                            want[i as usize] = expected_value(p.value, v);
                        }
                        assert_eq!(dense, want, "seed {seed} {p:?}");
                    }
                    ValueCoding::Q8 => {
                        let dense = back.to_dense();
                        let orig = sv.to_dense();
                        // block scale ≤ global maxabs / 127; half-step
                        // rounding error ≤ scale/2 (+ f32 noise)
                        let maxabs = sv.values.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                        let tol = maxabs / 127.0 * 0.5 + maxabs * 1e-6 + 1e-7;
                        for i in 0..sv.dim {
                            let err = (dense[i] - orig[i]).abs();
                            assert!(err <= tol, "seed {seed} {p:?} i {i}: {err} > {tol}");
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_all_containers_appear_and_roundtrip() {
    // force each container explicitly and count them, so a selection bug
    // cannot silently reduce coverage to one container
    let mut rng = Rng::new(99);
    let mut counts = [0usize; 3];
    let p = CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 };
    let mut buf = Vec::new();
    for round in 0..20 {
        let dim = 512 + 37 * round;
        // densities placed safely on each side of the two crossovers:
        // 2 % → sparse, 30 % → bitmap, 97 % → dense (f16 values)
        for (frac, want) in
            [(0.02f64, CONTAINER_SPARSE), (0.3, CONTAINER_BITMAP), (0.97, CONTAINER_DENSE)]
        {
            let nnz = ((dim as f64 * frac).round() as usize).clamp(1, dim);
            let sv = rand_support(&mut rng, dim, nnz);
            wire::encode_with(&sv, &mut buf, p);
            assert_eq!(buf[4], KIND_V2);
            assert_eq!(buf[5], want, "dim {dim} nnz {nnz}");
            match buf[5] {
                CONTAINER_SPARSE => counts[0] += 1,
                CONTAINER_BITMAP => counts[1] += 1,
                CONTAINER_DENSE => counts[2] += 1,
                c => panic!("unknown container byte {c}"),
            }
            let back = wire::decode(&buf).unwrap();
            assert_eq!(back.indices, sv.indices, "support must survive every container");
        }
    }
    assert!(
        counts.iter().all(|&c| c > 0),
        "density sweep must exercise sparse, bitmap and dense: {counts:?}"
    );
}

#[test]
fn default_codec_is_byte_identical_to_v1() {
    let mut rng = Rng::new(5);
    let mut buf = Vec::new();
    for _ in 0..40 {
        let dim = 1 + rng.below(400);
        let nnz = rng.below(dim + 1);
        let sv = rand_support(&mut rng, dim, nnz);
        wire::encode_with(&sv, &mut buf, CodecParams::default());
        assert_eq!(buf, wire::encode(&sv), "default codec must emit v1 bytes");
        assert_eq!(wire::encoded_bytes_with(&sv, CodecParams::default()), buf.len());
    }
}

#[test]
fn cross_version_decode_v1_and_v2_through_one_decoder() {
    // a v1 buffer and every v2 mode of the same vector must decode to the
    // same support through the same reused output vector, with no codec
    // configuration on the decode side
    let mut rng = Rng::new(6);
    let sv = rand_support(&mut rng, 300, 30);
    let mut out = SparseVec::empty(0);
    let v1 = wire::encode(&sv);
    wire::decode_into(&v1, &mut out).unwrap();
    assert_eq!(out, sv);
    let mut buf = Vec::new();
    for p in all_params() {
        wire::encode_with(&sv, &mut buf, p);
        wire::decode_into(&buf, &mut out).unwrap();
        assert_eq!(out.indices, sv.indices, "{p:?}");
        // and back to v1 through the same buffers — version interleaving
        // must leave no stale state behind
        wire::decode_into(&v1, &mut out).unwrap();
        assert_eq!(out, sv, "{p:?}");
    }
}

// ------------------------------------------------- prefixes and corruption

#[test]
fn prop_every_strict_prefix_rejected_every_mode() {
    let mut out = SparseVec::empty(0);
    for seed in seeds().take(8) {
        let mut rng = Rng::new(seed);
        let dim = 16 + rng.below(80);
        let nnz = rng.below(dim + 1);
        let sv = rand_support(&mut rng, dim, nnz);
        for p in all_params() {
            let mut buf = Vec::new();
            wire::encode_with(&sv, &mut buf, p);
            for cut in 0..buf.len() {
                assert!(
                    wire::decode_into(&buf[..cut], &mut out).is_err(),
                    "seed {seed} {p:?}: prefix of {cut}/{} bytes must be rejected",
                    buf.len()
                );
            }
            wire::decode_into(&buf, &mut out).unwrap();
            assert_eq!(out.indices, sv.indices, "seed {seed} {p:?}");
        }
    }
}

#[test]
fn corrupt_varint_stream_rejected_without_panic() {
    // a sparse varint buffer with every index byte forced to a dangling
    // continuation marker must error (varint overflow / truncation), and a
    // zero gap (duplicate index) must read as unsorted
    let sv = SparseVec::new(1000, vec![(3, 1.0), (700, -2.0), (980, 0.5)]);
    let p = CodecParams { index: IndexCoding::Varint, value: ValueCoding::F32 };
    let mut buf = Vec::new();
    wire::encode_with(&sv, &mut buf, p);
    assert_eq!((buf[4], buf[5], buf[6]), (KIND_V2, CONTAINER_SPARSE, 1));
    let idx_off = codec::V2_HEADER_BYTES + 4;
    let mut out = SparseVec::empty(0);
    // continuation bit on every byte of the stream → overflow or truncation
    let mut bad = buf.clone();
    for b in &mut bad[idx_off..] {
        *b |= 0x80;
    }
    assert!(wire::decode_into(&bad, &mut out).is_err());
    // zero gap after the first index decodes as a duplicate → Unsorted
    let mut dup = buf.clone();
    dup[idx_off + 1] = 0; // second gap (700-3 = 697 is 2 bytes, overwrite low)
    let verdict = wire::decode_into(&dup, &mut out);
    assert!(verdict.is_err(), "zero/garbled gap must not decode silently");
    // gap overrunning dim → IndexOutOfBounds
    let mut far = buf.clone();
    far[idx_off] = 0x7F; // first index 127, later gaps unchanged → may pass
    let _ = wire::decode_into(&far, &mut out); // must simply not panic
}

#[test]
fn corrupt_bitmap_and_headers_rejected_without_panic() {
    let mut rng = Rng::new(21);
    // mid density forces the bitmap container at f16
    let sv = rand_support(&mut rng, 257, 90);
    let p = CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 };
    let mut buf = Vec::new();
    wire::encode_with(&sv, &mut buf, p);
    assert_eq!(buf[5], CONTAINER_BITMAP);
    let mut out = SparseVec::empty(0);
    // a bit beyond dim (dim 257 → last byte may only use bit 0)
    let bm_last = codec::V2_HEADER_BYTES + 257usize.div_ceil(8) - 1;
    let mut bad = buf.clone();
    bad[bm_last] |= 0x80;
    assert!(
        matches!(wire::decode_into(&bad, &mut out), Err(wire::WireError::BadBitmap)),
        "bit at position >= dim must be rejected"
    );
    // setting an extra in-range bit grows nnz past the value stream → Err
    let mut extra = buf.clone();
    let first_bm = codec::V2_HEADER_BYTES;
    extra[first_bm] = 0xFF;
    if extra[first_bm] != buf[first_bm] {
        assert!(wire::decode_into(&extra, &mut out).is_err());
    }
    // bad container / coding bytes
    for (off, err_is) in [(5usize, "container"), (6, "coding"), (7, "coding")] {
        let mut bad = buf.clone();
        bad[off] = 0x7E;
        let verdict = wire::decode_into(&bad, &mut out);
        assert!(verdict.is_err(), "corrupt {err_is} byte at {off} must be rejected");
    }
}

#[test]
fn prop_garbage_never_panics_and_buffers_stay_usable() {
    let reference = SparseVec::new(50, vec![(7, 1.5), (31, -0.25)]);
    let p = CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 };
    let mut ref_buf = Vec::new();
    wire::encode_with(&reference, &mut ref_buf, p);
    let mut out = SparseVec::empty(0);
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let len = rng.below(96);
        let mut garbage: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let _ = wire::decode_into(&garbage, &mut out);
        if garbage.len() >= codec::V2_HEADER_BYTES {
            garbage[0..4].copy_from_slice(&wire::MAGIC.to_le_bytes());
            garbage[4] = KIND_V2;
            garbage[5] = (seed % 4) as u8; // container, sometimes valid
            garbage[6] = (seed % 3) as u8; // index coding, sometimes valid
            garbage[7] = (seed % 4) as u8; // value coding, sometimes valid
            let _ = wire::decode_into(&garbage, &mut out);
        }
        // the reused buffer must survive whatever the failed decode left
        wire::decode_into(&ref_buf, &mut out).unwrap();
        assert_eq!(out.indices, reference.indices, "seed {seed}");
    }
}

// --------------------------------------------------- rate-0.1 byte budgets

/// Build a realistic top-k upload: the k largest of P gaussian scores.
fn topk_upload(p: usize, k: usize, seed: u64) -> SparseVec {
    let mut rng = Rng::new(seed);
    let raw: Vec<f32> = (0..p).map(|_| rng.normal()).collect();
    let abs: Vec<f32> = raw.iter().map(|x| x.abs()).collect();
    let ids = fedgmf::sparse::topk::select_topk(&abs, k);
    let vals: Vec<f32> = ids.iter().map(|&i| raw[i as usize]).collect();
    SparseVec::from_sorted(p, ids, vals)
}

#[test]
fn varint_never_exceeds_v1_sparse_bytes_per_round_at_rate_01() {
    // the quick-mode CI bar: one simulated round of 20 clients at the
    // table3 shape (P = 77 850, rate 0.1). Varint coding must never exceed
    // the v1 sparse bytes, and must beat them by ≥ 1.5×.
    let p_dim = 77_850;
    let k = p_dim / 10;
    let varint = CodecParams { index: IndexCoding::Varint, value: ValueCoding::F32 };
    let mut buf = Vec::new();
    let (mut v1_total, mut v2_total) = (0usize, 0usize);
    for client in 0..20u64 {
        let sv = topk_upload(p_dim, k, 1000 + client);
        assert_eq!(sv.nnz(), k);
        let v1 = wire::encoded_bytes(&sv);
        wire::encode_with(&sv, &mut buf, varint);
        assert!(
            buf.len() <= v1,
            "client {client}: varint {} exceeds v1 sparse {v1}",
            buf.len()
        );
        v1_total += v1;
        v2_total += buf.len();
    }
    let ratio = v1_total as f64 / v2_total as f64;
    assert!(ratio >= 1.5, "rate-0.1 uplink reduction {ratio:.3}x below the 1.5x bar");
}

#[test]
fn prop_varint_f32_never_exceeds_v1_plus_constant_header_gap() {
    // buffer-level guarantee behind the round-level bar: min(varint, raw)
    // index coding and min-byte container selection keep every v2 f32
    // buffer within the 3-byte header gap of v1
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let dim = 1 + rng.below(3000);
        let nnz = rng.below(dim + 1);
        let sv = rand_support(&mut rng, dim, nnz);
        let p = CodecParams { index: IndexCoding::Varint, value: ValueCoding::F32 };
        let v2 = wire::encoded_bytes_with(&sv, p);
        let v1 = wire::encoded_bytes(&sv);
        assert!(v2 <= v1 + 3, "seed {seed} dim {dim} nnz {nnz}: v2 {v2} v1 {v1}");
    }
}

#[test]
fn f16_and_q8_compound_the_reduction() {
    let p_dim = 77_850;
    let k = p_dim / 10;
    let sv = topk_upload(p_dim, k, 7);
    let v1 = wire::encoded_bytes(&sv) as f64;
    let f16 = CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 };
    let q8 = CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 };
    let f16_bytes = wire::encoded_bytes_with(&sv, f16) as f64;
    let q8_bytes = wire::encoded_bytes_with(&sv, q8) as f64;
    assert!(v1 / f16_bytes >= 2.4, "varint+f16 ratio {:.2}", v1 / f16_bytes);
    assert!(v1 / q8_bytes >= 3.5, "varint+q8 ratio {:.2}", v1 / q8_bytes);
}
