//! End-to-end transport conformance for service mode.
//!
//! The acceptance bar of the service-mode work: a loopback multi-process
//! topology (here: multi-thread over real sockets — the same wire path as
//! `fedgmf serve` / `fedgmf client`) must reproduce the in-process
//! simulator's trajectory digest **bit-identically**, with and without a
//! chaos plan, and every fault kind must leave the mass and traffic
//! ledgers clean. Retransmits and duplicates may only move counters that
//! the digest deliberately excludes (retries / timeouts / stale_frames /
//! dup_frames).

use fedgmf::coordinator::round::FlRun;
use fedgmf::coordinator::service::{
    build_service_client, build_service_handlers, build_service_run, service_config, ServiceRun,
};
use fedgmf::experiments::workload::{verify_fixture, VerifyFixture};
use fedgmf::testkit::digest::trajectory_digest;
use fedgmf::testkit::invariants::{check_traffic, MassLedger};
use fedgmf::transport::fault::{FaultKind, FaultPlan};
use fedgmf::transport::inproc::InProcTransport;
use fedgmf::transport::socket::{run_client, SocketTransport};
use fedgmf::transport::TransportConfig;

const CLIENTS: usize = 5;
const ROUNDS: usize = 4;
const SEED: u64 = 42;
const ROUND_DEADLINE_MS: u64 = 30_000;

/// Reference trajectory: the plain in-process simulator with the same
/// fault plan replayed through `FlConfig::fault`.
fn sim_digest(fault: Option<FaultPlan>) -> u64 {
    let VerifyFixture { shards, network, mut engine } = verify_fixture(CLIENTS, SEED);
    let cfg = service_config(CLIENTS, ROUNDS, SEED, fault);
    let mut run = FlRun::new(&engine, shards, Vec::new(), network, cfg);
    let summary = run.run(&mut engine).unwrap();
    let bits: Vec<u32> = run.params.iter().map(|p| p.to_bits()).collect();
    trajectory_digest(&bits, &summary.recorder.rounds)
}

/// Drive a `ServiceRun` over an already-bound socket transport with one
/// client thread per handler; returns (digest, run) for counter checks.
fn socket_service_run(fault: Option<FaultPlan>, addr: &str) -> (u64, ServiceRun) {
    let run = build_service_run(CLIENTS, ROUNDS, SEED, fault);
    let dim = run.params.len();
    let mut tcfg = TransportConfig::default();
    tcfg.addr = addr.to_string();
    tcfg.fault = fault;
    let mut transport = SocketTransport::bind(tcfg.clone(), CLIENTS, dim, ROUNDS).unwrap();
    let connect = transport.local_addr().to_string();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|id| {
            let mut ccfg = tcfg.clone();
            ccfg.addr = connect.clone();
            std::thread::spawn(move || {
                let mut handler = build_service_client(CLIENTS, id, ROUNDS, SEED, fault);
                run_client(&ccfg, &mut handler).unwrap();
            })
        })
        .collect();
    let mut service = ServiceRun::new(run, ROUND_DEADLINE_MS);
    let summary = service.run(&mut transport).unwrap();
    for h in handles {
        h.join().unwrap();
    }
    let bits: Vec<u32> = service.run.params.iter().map(|p| p.to_bits()).collect();
    (trajectory_digest(&bits, &summary.recorder.rounds), service)
}

fn socket_digest(fault: Option<FaultPlan>) -> u64 {
    socket_service_run(fault, "127.0.0.1:0").0
}

#[test]
fn socket_loopback_matches_simulator_digest_without_faults() {
    assert_eq!(
        socket_digest(None),
        sim_digest(None),
        "clean loopback run must be bit-identical to the simulator"
    );
}

#[test]
fn socket_loopback_matches_simulator_digest_under_drop_plan() {
    let plan = Some(FaultPlan::new(FaultKind::Drop, 0.35, 7));
    assert_eq!(
        socket_digest(plan),
        sim_digest(plan),
        "drop-chaos loopback run must be bit-identical to the simulator"
    );
}

#[test]
fn socket_retransmit_faults_preserve_digest_and_book_retries() {
    // truncate-mid-frame and disconnect-mid-upload both force the client
    // through reconnect + resend: the trajectory must not move (retransmit
    // bytes are not metered, the payload is identical), but the transport
    // retry counters must record the churn
    for kind in [FaultKind::Truncate, FaultKind::Disconnect] {
        let plan = Some(FaultPlan::new(kind, 0.5, 11));
        let (digest, service) = socket_service_run(plan, "127.0.0.1:0");
        assert_eq!(
            digest,
            sim_digest(plan),
            "{kind:?}: retransmitted uploads must land bit-identically"
        );
        let retries: usize = service.run.recorder.rounds.iter().map(|r| r.retries).sum();
        assert!(retries > 0, "{kind:?}: reconnects must surface in the retry counter");
    }
}

#[cfg(unix)]
#[test]
fn unix_domain_loopback_matches_simulator_digest() {
    let path = std::env::temp_dir().join(format!("fedgmf-uds-{}.sock", std::process::id()));
    let addr = format!("unix:{}", path.display());
    let plan = Some(FaultPlan::new(FaultKind::Duplicate, 0.4, 3));
    assert_eq!(socket_service_run(plan, &addr).0, sim_digest(plan));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn every_fault_kind_completes_with_clean_ledgers() {
    // the full chaos sweep runs over the in-process transport (the socket
    // paths above cover the wire-specific kinds); the mass ledger and the
    // traffic ledger must stay clean under every plan
    for kind in FaultKind::ALL {
        let plan = Some(FaultPlan::new(kind, 0.3, 11));
        let cfg = service_config(CLIENTS, ROUNDS, SEED, plan);
        let staleness = cfg.sim.staleness;
        let v1 = cfg.codec.is_v1();
        let mut run = build_service_run(CLIENTS, ROUNDS, SEED, plan);
        let dim = run.params.len();
        run.ledger = Some(Box::new(MassLedger::new(dim, staleness)));
        let mut tcfg = TransportConfig::default();
        tcfg.fault = plan;
        let handlers = build_service_handlers(CLIENTS, ROUNDS, SEED, plan);
        let mut transport = InProcTransport::new(handlers, tcfg);
        let mut service = ServiceRun::new(run, ROUND_DEADLINE_MS);
        let summary = service.run(&mut transport).unwrap();
        let ledger = service
            .run
            .ledger
            .take()
            .expect("ledger installed above")
            .into_any()
            .downcast::<MassLedger>()
            .expect("mass ledger type");
        let mut violations = ledger.check(&service.run.stale_queue);
        violations.extend(check_traffic(&service.run.meter, &summary.recorder, CLIENTS, v1));
        assert!(violations.is_empty(), "{kind:?}: {violations:?}");
    }
}
