//! Cross-module integration tests on the native engine (no artifacts
//! needed): full FL runs, scheme-level behavioural properties from the
//! paper's problem formulation, config plumbing, persistence.

use fedgmf::compress::CompressorKind;
use fedgmf::config::{EngineKind, RunConfig, Task};
use fedgmf::coordinator::round::{FlConfig, FlRun, LrSchedule};
use fedgmf::coordinator::sampler::Sampler;
use fedgmf::data::dataset::Dataset;
use fedgmf::experiments::runner::execute;
use fedgmf::experiments::workload::build_workload;
use fedgmf::runtime::native::{BlobDataset, NativeEngine};
use fedgmf::sim::network::Network;
use std::path::Path;

fn native_cifar_cfg(kind: CompressorKind) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.engine = EngineKind::Native;
    cfg.clients = 10;
    cfg.rounds = 25;
    cfg.samples_per_client = 60;
    cfg.test_size = 160;
    cfg.technique = kind;
    cfg.lr = 0.15; // stable for the momentum-corrected schemes on the MLP
    cfg.eval_every = 5;
    cfg
}

#[test]
fn native_cifar_all_schemes_learn() {
    // the synthetic CIFAR classes are separable; the DGC-family schemes
    // must beat chance (0.1) by a wide margin even at rate 0.1 under mild
    // non-IID. GMC is exempt from the accuracy bar: its global-momentum
    // compensation is amplification-unstable at this lr — the same
    // fragility the paper reports ("GMC fails to converge", Table 4) — so
    // for GMC we only require the run to complete with finite metrics.
    for kind in CompressorKind::ALL {
        let mut cfg = native_cifar_cfg(kind);
        cfg.emd = 0.48;
        // per-technique lr, as the paper tunes per scheme: momentum-bearing
        // schemes multiply the effective step (≈1/(1-β)) and need smaller lr
        cfg.lr = match kind {
            CompressorKind::Dgc => 0.3,
            CompressorKind::Gmc => 0.15,
            CompressorKind::DgcWgm => 0.05,
            CompressorKind::DgcWgmf => 0.1,
        };
        let (summary, _) = execute(&cfg, Path::new("artifacts"), &mut None).unwrap();
        assert!(summary.total_traffic_gb > 0.0);
        assert!(summary.final_loss.is_finite(), "{}: loss diverged to NaN", kind.name());
        if kind != CompressorKind::Gmc {
            assert!(
                summary.final_accuracy > 0.3, // chance = 0.1
                "{}: accuracy {}",
                kind.name(),
                summary.final_accuracy
            );
        }
    }
}

#[test]
fn dgcwgm_costs_more_downlink_and_gmf_not_more() {
    // paper Table 3 ordering on the downlink: DGCwGMF <= DGC < DGCwGM
    let run = |kind: CompressorKind| {
        let mut cfg = native_cifar_cfg(kind);
        cfg.emd = 1.35;
        cfg.rounds = 30;
        let (s, _) = execute(&cfg, Path::new("artifacts"), &mut None).unwrap();
        s
    };
    let dgc = run(CompressorKind::Dgc);
    let gm = run(CompressorKind::DgcWgm);
    let gmf = run(CompressorKind::DgcWgmf);
    assert!(
        gm.downlink_gb > dgc.downlink_gb,
        "DGCwGM downlink {} must exceed DGC {}",
        gm.downlink_gb,
        dgc.downlink_gb
    );
    assert!(
        gmf.total_traffic_gb <= dgc.total_traffic_gb * 1.02,
        "DGCwGMF traffic {} must not exceed DGC {}",
        gmf.total_traffic_gb,
        dgc.total_traffic_gb
    );
    assert!(
        gmf.mean_mask_overlap > dgc.mean_mask_overlap,
        "GMF raises mask overlap: {} vs {}",
        gmf.mean_mask_overlap,
        dgc.mean_mask_overlap
    );
}

#[test]
fn traffic_accounting_is_consistent() {
    let mut cfg = native_cifar_cfg(CompressorKind::DgcWgmf);
    cfg.rounds = 8;
    let (summary, _) = execute(&cfg, Path::new("artifacts"), &mut None).unwrap();
    let up: usize = summary.recorder.rounds.iter().map(|r| r.uplink_bytes).sum();
    let down: usize = summary.recorder.rounds.iter().map(|r| r.downlink_bytes).sum();
    assert!((summary.uplink_gb - up as f64 / 1e9).abs() < 1e-12);
    assert!((summary.downlink_gb - down as f64 / 1e9).abs() < 1e-12);
    assert!((summary.total_traffic_gb - (up + down) as f64 / 1e9).abs() < 1e-12);
    for r in &summary.recorder.rounds {
        assert!(r.uplink_bytes > 0 && r.downlink_bytes > 0 && r.sim_seconds > 0.0);
    }
}

#[test]
fn partial_participation_reduces_uplink() {
    let engine = NativeEngine::new(16, 12, 4, 1);
    let make_run = |sampler: Sampler| {
        let shards: Vec<Box<dyn Dataset + Send>> = (0..8)
            .map(|c| {
                Box::new(BlobDataset::generate_split(60, 16, 4, 0.4, 7, 8 + c as u64))
                    as Box<dyn Dataset + Send>
            })
            .collect();
        let test = BlobDataset::generate_split(64, 16, 4, 0.4, 7, 0xE).eval_batches(32);
        let mut fc = FlConfig::new(CompressorKind::Dgc, 0.1, 10);
        fc.sampler = sampler;
        fc.lr = LrSchedule::constant(0.3);
        FlRun::new(&engine, shards, test, Network::uniform(8, Default::default()), fc)
    };
    let mut e1 = engine.clone();
    let full = make_run(Sampler::Full).run(&mut e1).unwrap();
    let mut e2 = engine.clone();
    let half = make_run(Sampler::Fraction(0.5)).run(&mut e2).unwrap();
    assert!(half.uplink_gb < full.uplink_gb * 0.6, "{} vs {}", half.uplink_gb, full.uplink_gb);
}

#[test]
fn rate_sweep_orders_uplink() {
    // uplink bytes must scale with the keep-rate below the wire layer's
    // dense-fallback crossover (nnz = dim/2; above it all rates cost the
    // dense payload — that plateau is itself asserted in the wire tests)
    let mut totals = Vec::new();
    for rate in [0.05, 0.2, 0.4] {
        let mut cfg = native_cifar_cfg(CompressorKind::Dgc);
        cfg.rate = rate;
        cfg.rounds = 6;
        cfg.warmup_rounds = 0;
        let (s, _) = execute(&cfg, Path::new("artifacts"), &mut None).unwrap();
        totals.push(s.uplink_gb);
    }
    assert!(totals[0] < totals[1] && totals[1] < totals[2], "{totals:?}");
}

#[test]
fn emd_partition_quality_via_workload() {
    for emd in [0.0, 0.76, 1.35] {
        let mut cfg = RunConfig::default();
        cfg.engine = EngineKind::Native;
        cfg.clients = 20;
        cfg.samples_per_client = 100;
        cfg.emd = emd;
        let w = build_workload(&cfg).unwrap();
        assert!(
            (w.achieved_emd - emd).abs() < 0.08,
            "target {emd} achieved {}",
            w.achieved_emd
        );
    }
}

#[test]
fn shakespeare_workload_is_naturally_noniid() {
    let mut cfg = RunConfig::shakespeare();
    cfg.clients = 40;
    cfg.samples_per_client = 1500;
    let w = build_workload(&cfg).unwrap();
    assert!(w.achieved_emd > 0.05, "char EMD {}", w.achieved_emd);
    assert_eq!(w.shards.len(), 40);
}

#[test]
fn run_is_deterministic_given_seed() {
    let run = || {
        let mut cfg = native_cifar_cfg(CompressorKind::DgcWgmf);
        cfg.rounds = 6;
        cfg.seed = 1234;
        let (s, _) = execute(&cfg, Path::new("artifacts"), &mut None).unwrap();
        (
            s.final_accuracy,
            s.total_traffic_gb,
            s.recorder.rounds.last().unwrap().train_loss,
        )
    };
    assert_eq!(run(), run());
}

#[test]
fn recorder_csv_and_json_consistent() {
    let mut cfg = native_cifar_cfg(CompressorKind::Gmc);
    cfg.rounds = 4;
    let (summary, _) = execute(&cfg, Path::new("artifacts"), &mut None).unwrap();
    let csv = summary.recorder.to_csv();
    let lines: Vec<&str> = csv.trim().lines().collect();
    assert_eq!(lines.len(), 5); // header + 4 rounds
    let header_cols = lines[0].split(',').count();
    for line in &lines[1..] {
        assert_eq!(line.split(',').count(), header_cols);
    }
    let j = fedgmf::util::json::Json::parse(&summary.recorder.summary_json().to_pretty()).unwrap();
    assert_eq!(j.get("rounds").unwrap().as_usize(), Some(4));
}

#[test]
fn config_pipeline_from_toml_to_run() {
    let cfg = RunConfig::from_toml_str(
        r#"
[run]
task = "cifar"
engine = "native"
technique = "dgcwgmf"
rounds = 5
[data]
clients = 6
samples_per_client = 40
test_size = 64
emd = 0.87
[compress]
rate = 0.2
[train]
lr = 0.3
eval_every = 5
"#,
        &[],
    )
    .unwrap();
    assert_eq!(cfg.task, Task::Cifar);
    let (summary, emd) = execute(&cfg, Path::new("artifacts"), &mut None).unwrap();
    assert!(emd > 0.5);
    assert_eq!(summary.recorder.rounds.len(), 5);
}

#[test]
fn gmc_masks_dominated_by_global_term_under_noniid() {
    // §2.2 at system level: GMC's compensation folds β·Ĝ into every
    // client's V, so the selection is pulled toward the shared global
    // direction and client masks overlap far more than DGC's on the same
    // non-IID workload — the same signal that makes GMC's transmissions
    // carry less client-specific information (its over-fitting mechanism).
    let overlap_after = |kind: CompressorKind| -> f64 {
        let mut cfg = native_cifar_cfg(kind);
        cfg.emd = 1.35;
        cfg.rounds = 20;
        let w = build_workload(&cfg).unwrap();
        let mut engine = NativeEngine::new(3072, 24, 10, cfg.seed);
        let mut run = FlRun::new(
            &engine,
            w.shards,
            w.test,
            Network::uniform(cfg.clients, Default::default()),
            cfg.fl_config(),
        );
        let mut last = 0.0;
        for round in 0..20 {
            last = run.step_round(&mut engine, round).unwrap().mask_overlap;
        }
        last
    };
    let gmc = overlap_after(CompressorKind::Gmc);
    let dgc = overlap_after(CompressorKind::Dgc);
    assert!(
        gmc > dgc * 1.2,
        "GMC mask overlap {gmc} must clearly exceed DGC's {dgc}"
    );
}

#[test]
fn warmup_rounds_send_more_early() {
    let mut cfg = native_cifar_cfg(CompressorKind::Dgc);
    cfg.rounds = 10;
    cfg.warmup_rounds = 5;
    let (summary, _) = execute(&cfg, Path::new("artifacts"), &mut None).unwrap();
    let first = summary.recorder.rounds[0].uplink_bytes;
    let last = summary.recorder.rounds[9].uplink_bytes;
    assert!(first > last, "warmup round 0 uplink {first} must exceed steady {last}");
}
