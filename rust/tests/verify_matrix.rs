//! In-process run of the `fedgmf verify` scenario-matrix conformance
//! harness: the full technique × codec × staleness × selection × preset ×
//! chaos cross-product at both worker counts, with the invariant ledgers
//! armed.
//!
//! This makes `cargo test` itself a matrix gate: mass conservation,
//! traffic-ledger consistency and cross-worker digest equality must hold
//! for every scenario. The golden-digest comparison additionally arms
//! itself once `tests/golden/verify_matrix.json` is blessed (see
//! docs/testing.md), so an accidental trajectory change in any axis
//! combination fails here before it reaches CI.

use fedgmf::config::Scale;
use fedgmf::testkit::scenario::{Scenario, WORKERS};
use fedgmf::testkit::{run_verify, VerifyOptions};
use std::path::PathBuf;

fn committed_golden() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/verify_matrix.json")
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fedgmf-verify-{}-{name}.json", std::process::id()))
}

#[test]
fn quick_matrix_passes_invariants_and_golden_gate() {
    let report_path = tmp("report");
    let opts = VerifyOptions {
        scale: Scale::Quick,
        bless: false,
        golden_path: committed_golden(),
        report_path: Some(report_path.clone()),
    };
    let report = run_verify(&opts).unwrap();
    // the acceptance bar: the matrix is the full cross-product and at
    // least 200 scenario runs deep
    assert_eq!(report.scenarios.len(), Scenario::all().len());
    // every scenario runs at each worker count plus one streamed-ingest
    // run and one two-tier topology run (folded into the cross-run digest
    // gate), plus one adaptive rate-control run (invariant ledgers only)
    assert_eq!(report.runs, Scenario::all().len() * (WORKERS.len() + 3));
    assert_eq!(report.streamed_runs, Scenario::all().len());
    assert_eq!(report.tiered_runs, Scenario::all().len());
    assert_eq!(report.rate_control_runs, Scenario::all().len());
    assert!(report.runs >= 200, "matrix shrank below the 200-run floor: {}", report.runs);
    // every invariant ledger must be clean in every scenario
    for s in &report.scenarios {
        assert!(s.violations.is_empty(), "{}: {:?}", s.key, s.violations);
    }
    assert!(report.codec_selfcheck.is_empty(), "{:?}", report.codec_selfcheck);
    assert!(report.kernel_selfcheck.is_empty(), "{:?}", report.kernel_selfcheck);
    assert!(!report.kernel_dispatch.is_empty(), "report must record the active dispatch");
    // digest gate: clean when armed; self-arming notice when not
    assert!(
        report.digest_mismatches.is_empty(),
        "golden digest mismatches: {:?}",
        report.digest_mismatches
    );
    assert!(report.passed());
    // the report artifact round-trips as JSON with the headline fields
    let j = fedgmf::util::json::Json::parse(&std::fs::read_to_string(&report_path).unwrap())
        .unwrap();
    assert_eq!(j.get("runs").unwrap().as_usize(), Some(report.runs));
    assert_eq!(j.get("streamed_runs").unwrap().as_usize(), Some(report.streamed_runs));
    assert_eq!(j.get("tiered_runs").unwrap().as_usize(), Some(report.tiered_runs));
    assert_eq!(j.get("invariant_failures").unwrap().as_usize(), Some(0));
    assert_eq!(
        j.get("digests").unwrap().as_obj().unwrap().len(),
        report.scenarios.len(),
        "report must carry the full would-be registry"
    );
    // the chaos axis is a first-class report dimension: listed explicitly
    // and present in every scenario key's trailing segment
    let chaos = j.get("chaos_axis").unwrap().as_arr().unwrap();
    assert_eq!(chaos.len(), 7, "chaos axis must enumerate all fault kinds plus none");
    let names: Vec<&str> = chaos.iter().filter_map(|v| v.as_str()).collect();
    assert_eq!(names, ["none", "drop", "delay", "dup", "reorder", "truncate", "disconnect"]);
    for s in &report.scenarios {
        let tail = s.key.rsplit('/').next().unwrap();
        assert!(names.contains(&tail), "{}: key must end in a chaos axis value", s.key);
    }
    // the rate-control axis is runner-level (not part of the scenario key):
    // the report names both legs and counts the adaptive runs
    let rc = j.get("rate_control_axis").unwrap().as_arr().unwrap();
    let rc_names: Vec<&str> = rc.iter().filter_map(|v| v.as_str()).collect();
    assert_eq!(rc_names, ["off", "adaptive"]);
    assert_eq!(
        j.get("rate_control_runs").unwrap().as_usize(),
        Some(report.rate_control_runs)
    );
    let _ = std::fs::remove_file(&report_path);
}

#[test]
fn streamed_ingest_matches_materialized_digest_under_chaos_with_mass_ledger() {
    // satellite check for the streamed-ingest path where it is hardest:
    // chaos-axis scenarios with the MassLedger armed. run_scenario_with
    // installs the ledger either way, so a clean violation list here means
    // the conservation audit held with uploads folded straight from wire
    // bytes — and the digest must equal the materialized run's bit-for-bit.
    use fedgmf::testkit::run_scenario_with;
    let mut covered = 0;
    for s in Scenario::all() {
        let tail = s.key().rsplit('/').next().unwrap().to_string();
        if !matches!(tail.as_str(), "dup" | "drop" | "truncate") || covered >= 3 {
            continue;
        }
        covered += 1;
        let (dm, vm) = run_scenario_with(&s, 1, 2, false).unwrap();
        let (ds, vs) = run_scenario_with(&s, 1, 2, true).unwrap();
        assert!(vm.is_empty(), "{} materialized: {:?}", s.key(), vm);
        assert!(vs.is_empty(), "{} streamed: {:?}", s.key(), vs);
        assert_eq!(dm, ds, "{}: streamed digest diverged", s.key());
    }
    assert_eq!(covered, 3, "chaos-axis scenarios must be enumerable");
}

#[test]
fn two_tier_matches_flat_digest_under_chaos_with_mass_ledger() {
    // the tiers-axis satellite check where it is hardest: chaos-axis
    // scenarios with the MassLedger armed. A two-tier run re-routes every
    // accepted upload through an edge merge before the hub — the digest
    // must still equal the flat run's bit-for-bit, and the mass and
    // traffic ledgers (now including the per-tier columns) must stay clean.
    use fedgmf::testkit::run_scenario_tiered;
    let mut covered = 0;
    for s in Scenario::all() {
        let tail = s.key().rsplit('/').next().unwrap().to_string();
        if !matches!(tail.as_str(), "dup" | "reorder" | "disconnect") || covered >= 3 {
            continue;
        }
        covered += 1;
        let (df, vf) = run_scenario_tiered(&s, 1, 2, false, 1).unwrap();
        let (dt, vt) = run_scenario_tiered(&s, 1, 2, false, 2).unwrap();
        assert!(vf.is_empty(), "{} flat: {:?}", s.key(), vf);
        assert!(vt.is_empty(), "{} two-tier: {:?}", s.key(), vt);
        assert_eq!(df, dt, "{}: two-tier digest diverged from flat", s.key());
    }
    assert_eq!(covered, 3, "chaos-axis scenarios must be enumerable");
}

#[test]
fn bless_arms_the_gate_and_is_byte_identical_on_rewrite() {
    // one bless run (matrix sweep 1), then a gated run against it (sweep
    // 2): the gate only passes if every scenario digest reproduces across
    // independent run_verify invocations — the "byte-identical on
    // re-bless" acceptance reduces to that digest stability plus the
    // deterministic registry serialisation, which reload → re-save proves
    // without a third full sweep
    let a = tmp("bless");
    let _ = std::fs::remove_file(&a);
    let opts = VerifyOptions {
        scale: Scale::Quick,
        bless: true,
        golden_path: a.clone(),
        report_path: None,
    };
    let report = run_verify(&opts).unwrap();
    assert!(report.blessed_now, "a clean tree must bless");
    assert!(report.passed());
    // reload → re-save is byte-identical (deterministic serialisation)
    let first = std::fs::read(&a).unwrap();
    let reg = fedgmf::testkit::golden::GoldenRegistry::load(&a).unwrap();
    assert!(reg.blessed);
    reg.save(&a).unwrap();
    assert_eq!(std::fs::read(&a).unwrap(), first, "re-save must be byte-identical");
    // a blessed registry arms the gate, and a fresh matrix run matches it
    // digest-for-digest (run-to-run digest determinism, end to end)
    let opts = VerifyOptions {
        scale: Scale::Quick,
        bless: false,
        golden_path: a.clone(),
        report_path: None,
    };
    let report = run_verify(&opts).unwrap();
    assert!(report.digest_gate_armed);
    assert!(report.digest_mismatches.is_empty(), "{:?}", report.digest_mismatches);
    assert!(report.passed());
    let _ = std::fs::remove_file(&a);
}
