//! Round-level properties of the semi-synchronous aggregation subsystem.
//!
//! Two contracts from the staleness-policy design, checked over randomized
//! runs (in-tree property harness, same conventions as `proptests.rs`:
//! deterministic seed stream, `PROP_SEED=<n>` replays a failure):
//!
//! 1. `carry_discounted(α = 0)` is **byte-identical** to `drop` — a zero
//!    discount must take the drop code path bit-for-bit, not merely
//!    approximate it.
//! 2. every staleness policy **conserves gradient mass** across straggler
//!    rounds: per coordinate, transmitted upload mass equals
//!    Σ(contributors · aggregate) plus what was restored into client
//!    residuals plus α · (still-buffered stale uploads) — checked by the
//!    testkit's `MassLedger`, the same invariant `fedgmf verify` asserts
//!    over the full scenario matrix.
//!
//! The straggler regime is constructed, not sampled: every second client
//! is 8× slower (compute 0.08 s + 25 ms latency > the 0.06 s deadline)
//! while fast clients finish in ~0.035 s — so every round deterministically
//! has both accepted and late uploads.

use fedgmf::compress::CompressorKind;
use fedgmf::coordinator::round::{FlConfig, FlRun, LrSchedule, RunSummary};
use fedgmf::data::dataset::Dataset;
use fedgmf::runtime::native::{BlobDataset, NativeEngine};
use fedgmf::sim::network::Network;
use fedgmf::sim::scheduler::{ProfilePreset, SimConfig, StalenessPolicy};

const CASES: u64 = 8; // full FL runs per property — heavier than unit props
const CLIENTS: usize = 5;
const DIM: usize = 12;
const CLASSES: usize = 4;
const ROUNDS: usize = 10;

fn seeds() -> impl Iterator<Item = u64> {
    let base: u64 =
        std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5E31);
    (0..CASES).map(move |i| base.wrapping_add(i * 7))
}

fn build_run(seed: u64, staleness: StalenessPolicy) -> (NativeEngine, FlRun) {
    let engine = NativeEngine::new(DIM, 10, CLASSES, seed ^ 0xA5);
    let shards: Vec<Box<dyn Dataset + Send>> = (0..CLIENTS)
        .map(|c| {
            Box::new(BlobDataset::generate_split(40, DIM, CLASSES, 0.4, seed, seed + 1 + c as u64))
                as Box<dyn Dataset + Send>
        })
        .collect();
    let mut cfg = FlConfig::new(CompressorKind::DgcWgmf, 0.2, ROUNDS);
    cfg.lr = LrSchedule::constant(0.3);
    cfg.eval_every = 0; // no eval: params move only through broadcasts
    cfg.seed = seed;
    cfg.workers = 1;
    cfg.sim = SimConfig {
        preset: ProfilePreset::Heterogeneous { slow_every: 2, slow_factor: 8.0 },
        deadline_s: 0.06,
        compute_s: 0.01,
        staleness,
        ..Default::default()
    };
    let run = FlRun::new(
        &engine,
        shards,
        Vec::new(),
        Network::uniform(CLIENTS, Default::default()),
        cfg,
    );
    (engine, run)
}

fn record_fingerprint(s: &RunSummary) -> Vec<(usize, usize, usize, u64, usize, usize, usize)> {
    s.recorder
        .rounds
        .iter()
        .map(|r| {
            (
                r.uplink_bytes,
                r.downlink_bytes,
                r.aggregate_nnz,
                r.train_loss.to_bits(),
                r.dropped_deadline,
                r.carried_in,
                r.wasted_uplink_bytes,
            )
        })
        .collect()
}

#[test]
fn prop_carry_discounted_zero_is_byte_identical_to_drop() {
    for seed in seeds() {
        let (mut e_drop, mut r_drop) = build_run(seed, StalenessPolicy::Drop);
        let (mut e_zero, mut r_zero) = build_run(seed, StalenessPolicy::CarryDiscounted(0.0));
        let s_drop = r_drop.run(&mut e_drop).unwrap();
        let s_zero = r_zero.run(&mut e_zero).unwrap();
        let bits_drop: Vec<u32> = r_drop.params.iter().map(|p| p.to_bits()).collect();
        let bits_zero: Vec<u32> = r_zero.params.iter().map(|p| p.to_bits()).collect();
        assert_eq!(bits_drop, bits_zero, "seed {seed}: params must be byte-identical");
        assert_eq!(
            record_fingerprint(&s_drop),
            record_fingerprint(&s_zero),
            "seed {seed}: per-round records must be byte-identical"
        );
        assert!(s_drop.dropped_deadline > 0, "seed {seed}: regime must produce stragglers");
        assert_eq!(s_zero.carried_total, 0, "seed {seed}: a zero discount must carry nothing");
        assert_eq!(r_zero.stale_queue.pending(), 0, "seed {seed}");
        // and both policies wasted the same (nonzero) straggler bytes
        assert!(s_drop.wasted_uplink_gb > 0.0, "seed {seed}");
        assert_eq!(s_drop.wasted_uplink_gb, s_zero.wasted_uplink_gb, "seed {seed}");
    }
}

#[test]
fn prop_staleness_policies_conserve_gradient_mass_across_straggler_rounds() {
    // the per-coordinate f64 mass ledger is the testkit's (the same
    // implementation `fedgmf verify` installs across the whole scenario
    // matrix): per coordinate, transmitted echo mass = contributors ×
    // aggregate + residual restores + α × still-pending stale uploads
    use fedgmf::testkit::invariants::MassLedger;
    for policy in [
        StalenessPolicy::Carry,
        StalenessPolicy::Drop,
        StalenessPolicy::CarryDiscounted(0.4),
    ] {
        for seed in seeds() {
            let (mut engine, mut run) = build_run(seed, policy);
            let dim = run.params.len();
            run.ledger = Some(Box::new(MassLedger::new(dim, policy)));
            let mut stragglers_seen = 0usize;
            for round in 0..ROUNDS {
                let rec = run.step_round(&mut engine, round).unwrap();
                stragglers_seen += rec.dropped_deadline;
                if policy == StalenessPolicy::Carry {
                    assert_eq!(rec.wasted_uplink_bytes, 0, "seed {seed} round {round}");
                }
            }
            assert!(stragglers_seen > 0, "seed {seed}: regime must produce stragglers");
            if policy == StalenessPolicy::Carry {
                assert!(
                    run.stale_queue.pending() > 0,
                    "seed {seed}: last round's stragglers remain buffered"
                );
            }
            let ledger = run
                .ledger
                .take()
                .unwrap()
                .into_any()
                .downcast::<MassLedger>()
                .unwrap();
            assert_eq!(ledger.stragglers_seen, stragglers_seen, "seed {seed} {policy:?}");
            let violations = ledger.check(&run.stale_queue);
            assert!(violations.is_empty(), "seed {seed} {policy:?}: {violations:?}");
        }
    }
}

#[test]
fn prop_adaptive_rate_control_conserves_mass_across_carry_rounds() {
    // the restore_upload_scaled audit: with the rate controller on, a
    // carried straggler's upload is compressed under that round's
    // per-client (k, coding) plan — slow clients land on the Q8 floor while
    // fast clients' k drifts round to round with their hit history. The
    // same-round restore (1 − α, under the codec the payload was encoded
    // with) plus the α·copy the server folds in next round must still
    // conserve per-coordinate mass exactly: no residual double-count, no
    // mass minted when the plan changes between the compress round and the
    // carry-apply round.
    use fedgmf::compress::RateControlMode;
    use fedgmf::testkit::invariants::MassLedger;
    for policy in [StalenessPolicy::Carry, StalenessPolicy::CarryDiscounted(0.4)] {
        for seed in seeds() {
            let (mut engine, mut run) = build_run(seed, policy);
            run.cfg.rate_control.mode = RateControlMode::Adaptive;
            // let the hit-history term actually move k between rounds
            run.cfg.rate_control.max_rate_boost = 2.0;
            let dim = run.params.len();
            run.ledger = Some(Box::new(MassLedger::new(dim, policy)));
            let mut stragglers_seen = 0usize;
            let mut carried = 0usize;
            let mut downshifts = 0usize;
            let mut spread = false;
            let mut means: Vec<u64> = Vec::new();
            for round in 0..ROUNDS {
                let rec = run.step_round(&mut engine, round).unwrap();
                stragglers_seen += rec.dropped_deadline;
                carried += rec.carried_in;
                downshifts += rec.coding_downshifts;
                spread |= rec.rate_max - rec.rate_min > 1e-9;
                means.push(rec.rate_mean.to_bits());
            }
            // the regime must genuinely exercise what it claims to audit
            assert!(stragglers_seen > 0, "seed {seed} {policy:?}: no stragglers");
            assert!(carried > 0, "seed {seed} {policy:?}: nothing carried");
            assert!(spread, "seed {seed} {policy:?}: plans never diverged");
            assert!(downshifts > 0, "seed {seed} {policy:?}: no codec downshift");
            means.dedup();
            assert!(means.len() > 1, "seed {seed} {policy:?}: k never moved across rounds");
            let ledger =
                run.ledger.take().unwrap().into_any().downcast::<MassLedger>().unwrap();
            let violations = ledger.check(&run.stale_queue);
            assert!(violations.is_empty(), "seed {seed} {policy:?}: {violations:?}");
        }
    }
}

#[test]
fn carry_and_discounted_alpha_one_are_byte_identical() {
    // α = 1 restores nothing and applies everything — exactly `carry`
    let (mut e_carry, mut r_carry) = build_run(11, StalenessPolicy::Carry);
    let (mut e_one, mut r_one) = build_run(11, StalenessPolicy::CarryDiscounted(1.0));
    let s_carry = r_carry.run(&mut e_carry).unwrap();
    let s_one = r_one.run(&mut e_one).unwrap();
    assert_eq!(
        r_carry.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
        r_one.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>()
    );
    assert_eq!(record_fingerprint(&s_carry), record_fingerprint(&s_one));
    assert!(s_carry.carried_total > 0, "regime must exercise the carry path");
}
