//! Round-level properties of the semi-synchronous aggregation subsystem.
//!
//! Two contracts from the staleness-policy design, checked over randomized
//! runs (in-tree property harness, same conventions as `proptests.rs`:
//! deterministic seed stream, `PROP_SEED=<n>` replays a failure):
//!
//! 1. `carry_discounted(α = 0)` is **byte-identical** to `drop` — a zero
//!    discount must take the drop code path bit-for-bit, not merely
//!    approximate it.
//! 2. `carry(α = 1)` **conserves gradient mass** across straggler rounds:
//!    every transmitted upload enters exactly one aggregate at full
//!    weight, so per coordinate, Σ(contributors · aggregate) over the run
//!    plus whatever the stale queue still holds equals Σ(uploads).
//!
//! The straggler regime is constructed, not sampled: every second client
//! is 8× slower (compute 0.08 s + 25 ms latency > the 0.06 s deadline)
//! while fast clients finish in ~0.035 s — so every round deterministically
//! has both accepted and late uploads.

use fedgmf::compress::CompressorKind;
use fedgmf::coordinator::round::{FlConfig, FlRun, LrSchedule, RunSummary};
use fedgmf::data::dataset::Dataset;
use fedgmf::runtime::native::{BlobDataset, NativeEngine};
use fedgmf::sim::network::Network;
use fedgmf::sim::scheduler::{ProfilePreset, SimConfig, StalenessPolicy};

const CASES: u64 = 8; // full FL runs per property — heavier than unit props
const CLIENTS: usize = 5;
const DIM: usize = 12;
const CLASSES: usize = 4;
const ROUNDS: usize = 10;

fn seeds() -> impl Iterator<Item = u64> {
    let base: u64 =
        std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5E31);
    (0..CASES).map(move |i| base.wrapping_add(i * 7))
}

fn build_run(seed: u64, staleness: StalenessPolicy) -> (NativeEngine, FlRun) {
    let engine = NativeEngine::new(DIM, 10, CLASSES, seed ^ 0xA5);
    let shards: Vec<Box<dyn Dataset + Send>> = (0..CLIENTS)
        .map(|c| {
            Box::new(BlobDataset::generate_split(40, DIM, CLASSES, 0.4, seed, seed + 1 + c as u64))
                as Box<dyn Dataset + Send>
        })
        .collect();
    let mut cfg = FlConfig::new(CompressorKind::DgcWgmf, 0.2, ROUNDS);
    cfg.lr = LrSchedule::constant(0.3);
    cfg.eval_every = 0; // no eval: params move only through broadcasts
    cfg.seed = seed;
    cfg.workers = 1;
    cfg.sim = SimConfig {
        preset: ProfilePreset::Heterogeneous { slow_every: 2, slow_factor: 8.0 },
        deadline_s: 0.06,
        compute_s: 0.01,
        staleness,
        ..Default::default()
    };
    let run = FlRun::new(
        &engine,
        shards,
        Vec::new(),
        Network::uniform(CLIENTS, Default::default()),
        cfg,
    );
    (engine, run)
}

fn record_fingerprint(s: &RunSummary) -> Vec<(usize, usize, usize, u64, usize, usize, usize)> {
    s.recorder
        .rounds
        .iter()
        .map(|r| {
            (
                r.uplink_bytes,
                r.downlink_bytes,
                r.aggregate_nnz,
                r.train_loss.to_bits(),
                r.dropped_deadline,
                r.carried_in,
                r.wasted_uplink_bytes,
            )
        })
        .collect()
}

#[test]
fn prop_carry_discounted_zero_is_byte_identical_to_drop() {
    for seed in seeds() {
        let (mut e_drop, mut r_drop) = build_run(seed, StalenessPolicy::Drop);
        let (mut e_zero, mut r_zero) = build_run(seed, StalenessPolicy::CarryDiscounted(0.0));
        let s_drop = r_drop.run(&mut e_drop).unwrap();
        let s_zero = r_zero.run(&mut e_zero).unwrap();
        let bits_drop: Vec<u32> = r_drop.params.iter().map(|p| p.to_bits()).collect();
        let bits_zero: Vec<u32> = r_zero.params.iter().map(|p| p.to_bits()).collect();
        assert_eq!(bits_drop, bits_zero, "seed {seed}: params must be byte-identical");
        assert_eq!(
            record_fingerprint(&s_drop),
            record_fingerprint(&s_zero),
            "seed {seed}: per-round records must be byte-identical"
        );
        assert!(s_drop.dropped_deadline > 0, "seed {seed}: regime must produce stragglers");
        assert_eq!(s_zero.carried_total, 0, "seed {seed}: a zero discount must carry nothing");
        assert_eq!(r_zero.stale_queue.pending(), 0, "seed {seed}");
        // and both policies wasted the same (nonzero) straggler bytes
        assert!(s_drop.wasted_uplink_gb > 0.0, "seed {seed}");
        assert_eq!(s_drop.wasted_uplink_gb, s_zero.wasted_uplink_gb, "seed {seed}");
    }
}

#[test]
fn prop_carry_conserves_gradient_mass_across_straggler_rounds() {
    for seed in seeds() {
        let (mut engine, mut run) = build_run(seed, StalenessPolicy::Carry);
        // per-coordinate f64 ledgers (immune to cross-coordinate cancellation)
        let dim = run.params.len();
        let mut uploaded = vec![0.0f64; dim];
        let mut delivered = vec![0.0f64; dim];
        let mut stragglers_seen = 0usize;
        for round in 0..ROUNDS {
            let rec = run.step_round(&mut engine, round).unwrap();
            // full participation + zero dropout: every client transmitted,
            // so every echo is an upload that crossed the wire this round
            for c in &run.clients {
                for (&i, &v) in c.echo.indices.iter().zip(&c.echo.values) {
                    uploaded[i as usize] += v as f64;
                }
            }
            let accepted = rec.selected - rec.dropped_deadline - rec.dropped_offline;
            let contributors = (accepted + rec.carried_in) as f64;
            for (&i, &v) in run.last_payload.indices.iter().zip(&run.last_payload.values) {
                delivered[i as usize] += contributors * v as f64;
            }
            stragglers_seen += rec.dropped_deadline;
            assert_eq!(rec.wasted_uplink_bytes, 0, "seed {seed} round {round}");
        }
        assert!(stragglers_seen > 0, "seed {seed}: regime must produce stragglers");
        // whatever the run ended holding never reached an aggregate
        let mut leftover = vec![0.0f64; dim];
        for e in run.stale_queue.pending_entries() {
            for (&i, &v) in e.grad.indices.iter().zip(&e.grad.values) {
                leftover[i as usize] += v as f64;
            }
        }
        assert!(run.stale_queue.pending() > 0, "seed {seed}: last round's stragglers remain");
        for i in 0..dim {
            let got = delivered[i] + leftover[i];
            let want = uploaded[i];
            let tol = 1e-3 * want.abs().max(1.0);
            assert!(
                (got - want).abs() <= tol,
                "seed {seed} coord {i}: delivered+leftover {got} != uploaded {want}"
            );
        }
    }
}

#[test]
fn carry_and_discounted_alpha_one_are_byte_identical() {
    // α = 1 restores nothing and applies everything — exactly `carry`
    let (mut e_carry, mut r_carry) = build_run(11, StalenessPolicy::Carry);
    let (mut e_one, mut r_one) = build_run(11, StalenessPolicy::CarryDiscounted(1.0));
    let s_carry = r_carry.run(&mut e_carry).unwrap();
    let s_one = r_one.run(&mut e_one).unwrap();
    assert_eq!(
        r_carry.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
        r_one.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>()
    );
    assert_eq!(record_fingerprint(&s_carry), record_fingerprint(&s_one));
    assert!(s_carry.carried_total > 0, "regime must exercise the carry path");
}
