//! Property-based tests over randomized inputs.
//!
//! The offline vendored crate set has no `proptest`, so this file carries a
//! small in-tree property harness: each property runs `CASES` randomized
//! cases from a deterministic seed stream; failures print the case seed so
//! they can be replayed exactly (`PROP_SEED=<n>`).

use fedgmf::compress::{
    primitives, CompressConfig, Compressor, CompressorKind, SparsityWarmup, TauSchedule,
};
use fedgmf::data::partition::{emd_of_partition, partition_by_emd};
use fedgmf::sparse::codec;
use fedgmf::sparse::merge::Aggregator;
use fedgmf::sparse::simd;
use fedgmf::sparse::stream;
use fedgmf::sparse::topk;
use fedgmf::sparse::vector::SparseVec;
use fedgmf::sparse::wire;
use fedgmf::transport::framing;
use fedgmf::util::json::Json;
use fedgmf::util::rng::Rng;

const CASES: u64 = 60;

fn seeds() -> impl Iterator<Item = u64> {
    let base: u64 = std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xF00D);
    (0..CASES).map(move |i| base.wrapping_add(i))
}

fn rand_sparse(rng: &mut Rng, max_dim: usize) -> SparseVec {
    let dim = 1 + rng.below(max_dim);
    let nnz = rng.below(dim + 1);
    let mut ids: Vec<u32> = (0..dim as u32).collect();
    rng.shuffle(&mut ids);
    ids.truncate(nnz);
    ids.sort_unstable();
    let values: Vec<f32> = ids.iter().map(|_| rng.normal() * 10.0).collect();
    SparseVec::from_sorted(dim, ids, values)
}

/// Sequentially fold one gradient into `agg` (the consolidated `add` API).
fn agg_add(agg: &mut Aggregator, g: &SparseVec) {
    agg.add(&[g], 1.0, 1);
}

/// Sequentially emit the `count`-mean of `agg` into a fresh vector.
fn agg_finish(agg: &mut Aggregator, count: usize) -> SparseVec {
    let mut out = SparseVec::empty(0);
    agg.finish_into(count, &mut out, 1);
    out
}

// -------------------------------------------------------------------- wire

#[test]
fn prop_wire_roundtrip_preserves_vector() {
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 400);
        let buf = wire::encode(&sv);
        assert_eq!(buf.len(), wire::encoded_bytes(&sv), "seed {seed}");
        let back = wire::decode(&buf).unwrap();
        assert_eq!(back.to_dense(), sv.to_dense(), "seed {seed}");
    }
}

#[test]
fn prop_wire_never_larger_than_dense_plus_header() {
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 300);
        let dense_bytes = 9 + 4 * sv.dim;
        assert!(wire::encoded_bytes(&sv) <= dense_bytes, "seed {seed}");
    }
}

#[test]
fn prop_wire_decode_rejects_truncations() {
    for seed in seeds().take(20) {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 100);
        let buf = wire::encode(&sv);
        for cut in [1usize, buf.len() / 2, buf.len().saturating_sub(1)] {
            if cut < buf.len() {
                assert!(wire::decode(&buf[..cut]).is_err(), "seed {seed} cut {cut}");
            }
        }
    }
}

#[test]
fn prop_wire_into_roundtrip_through_reused_buffers() {
    // the hot-path pair (`encode_into`/`decode_into`) must round-trip every
    // vector exactly through the same reused buffers, matching the
    // allocating wrappers byte for byte
    let mut buf = Vec::new();
    let mut back = SparseVec::empty(0);
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 400);
        wire::encode_into(&sv, &mut buf);
        assert_eq!(buf, wire::encode(&sv), "seed {seed}: encode_into != encode");
        assert_eq!(buf.len(), wire::encoded_bytes(&sv), "seed {seed}");
        wire::decode_into(&buf, &mut back).unwrap();
        assert_eq!(back, sv, "seed {seed}: decode_into mismatch");
    }
}

#[test]
fn prop_wire_decode_into_rejects_every_strict_prefix() {
    // every encoding's length is implied by its header, so *any* strict
    // prefix — including odd-length slices — must return Err, never panic,
    // through the reusable-buffer path
    let mut out = SparseVec::empty(0);
    for seed in seeds().take(12) {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 60);
        let buf = wire::encode(&sv);
        for cut in 0..buf.len() {
            assert!(
                wire::decode_into(&buf[..cut], &mut out).is_err(),
                "seed {seed}: prefix of {cut} bytes must be rejected"
            );
        }
        // and the full buffer still decodes after all the failed attempts
        wire::decode_into(&buf, &mut out).unwrap();
        assert_eq!(out, sv, "seed {seed}");
    }
}

#[test]
fn prop_wire_decode_rejects_corrupt_indices_without_panic() {
    let mut out = SparseVec::empty(0);
    for seed in seeds().take(20) {
        let mut rng = Rng::new(seed);
        // non-empty sparse vector, sparse encoding guaranteed (nnz small)
        let dim = 50 + rng.below(100);
        let nnz = 1 + rng.below(5);
        let pairs: Vec<(u32, f32)> = (0..nnz as u32).map(|i| (i * 7, 1.0 + i as f32)).collect();
        let sv = SparseVec::new(dim, pairs);
        let buf = wire::encode(&sv);
        assert_eq!(buf[4], 0, "seed {seed}: must be sparse-encoded");

        // out-of-range index (>= dim) → Err, never panic
        let mut bad = buf.clone();
        let idx_off = 9 + 4; // header + nnz field
        bad[idx_off..idx_off + 4].copy_from_slice(&(dim as u32).to_le_bytes());
        let verdict = wire::decode_into(&bad, &mut out);
        assert!(
            matches!(verdict, Err(wire::WireError::IndexOutOfBounds { .. })),
            "seed {seed}"
        );

        // duplicated/unsorted index → Err
        if nnz >= 2 {
            let mut dup = buf.clone();
            let second = idx_off + 4;
            let first: [u8; 4] = dup[idx_off..idx_off + 4].try_into().unwrap();
            dup[second..second + 4].copy_from_slice(&first);
            assert!(
                matches!(wire::decode_into(&dup, &mut out), Err(wire::WireError::Unsorted)),
                "seed {seed}"
            );
        }

        // unknown kind byte → Err
        let mut kindless = buf.clone();
        kindless[4] = 2 + (seed % 250) as u8;
        assert!(wire::decode_into(&kindless, &mut out).is_err(), "seed {seed}");
    }
}

#[test]
fn prop_wire_decode_never_panics_on_garbage() {
    // random byte strings — with and without a valid magic prefix — must
    // decode to Ok or Err, never panic, and leave the reused output vector
    // usable for the next decode
    let mut out = SparseVec::empty(0);
    let reference = SparseVec::new(20, vec![(3, 1.0), (9, -2.0)]);
    let ref_buf = wire::encode(&reference);
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let len = rng.below(64);
        let mut garbage: Vec<u8> = (0..len).map(|_| (rng.below(256)) as u8).collect();
        let _ = wire::decode_into(&garbage, &mut out);
        if garbage.len() >= 9 {
            garbage[0..4].copy_from_slice(&wire::MAGIC.to_le_bytes());
            garbage[4] = (seed % 3) as u8; // sometimes a valid kind byte
            let _ = wire::decode_into(&garbage, &mut out);
        }
        // the buffer survives whatever state the failed decode left behind
        wire::decode_into(&ref_buf, &mut out).unwrap();
        assert_eq!(out, reference, "seed {seed}");
    }
}

// ------------------------------------------------------------------- top-k

#[test]
fn prop_topk_threshold_selects_exactly_k_distinct() {
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let n = 10 + rng.below(5000);
        // distinct scores: add index-scaled epsilon
        let scores: Vec<f32> = (0..n).map(|i| rng.f32() + i as f32 * 1e-6).collect();
        let k = 1 + rng.below(n);
        let mut scratch = Vec::new();
        let t = topk::threshold_exact(&scores, k, &mut scratch);
        let count = scores.iter().filter(|&&s| s >= t).count();
        assert_eq!(count, k, "seed {seed} n {n} k {k}");
        let ts = topk::threshold_sampled(&scores, k, seed, &mut scratch);
        assert_eq!(ts, t, "sampled != exact, seed {seed}");
    }
}

#[test]
fn prop_select_at_threshold_sorted_and_capped() {
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let n = 5 + rng.below(1000);
        let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let k = 1 + rng.below(n);
        let sel = topk::select_topk(&scores, k);
        assert!(sel.len() <= k, "seed {seed}");
        assert!(sel.windows(2).all(|w| w[0] < w[1]), "seed {seed}");
    }
}

// --------------------------------------------------------- kernel dispatch

#[test]
fn prop_bucketed_threshold_equals_quickselect_under_ties_and_denormals() {
    // the two selection kernels behind `threshold_exact` must return the
    // same k-th value on tie-heavy mixtures (a small magnitude pool reused
    // across the vector), exact zeros, denormals and full-range normals —
    // and the support selected at that threshold must be identical
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let n = 5 + rng.below(4000);
        let pool: Vec<f32> = (0..1 + rng.below(6))
            .map(|_| rng.normal() * 10f32.powi(rng.below(9) as i32 - 4))
            .collect();
        let scores: Vec<f32> = (0..n)
            .map(|_| match rng.below(8) {
                0 => 0.0,
                1 => f32::from_bits(1 + rng.below(100) as u32), // denormal
                2 => rng.f32(),
                _ => pool[rng.below(pool.len())].abs(),
            })
            .collect();
        let k = 1 + rng.below(n);
        let (mut s1, mut s2) = (Vec::new(), Vec::new());
        let q = topk::threshold_exact_quickselect(&scores, k, &mut s1);
        let b = topk::threshold_exact_bucketed(&scores, k, &mut s2);
        assert_eq!(q, b, "seed {seed} n {n} k {k}");
        assert_eq!(
            topk::select_at_threshold(&scores, q, k),
            topk::select_at_threshold(&scores, b, k),
            "seed {seed}: selected support diverged"
        );
    }
}

#[test]
fn prop_simd_varint_kernels_byte_identical_to_scalar() {
    // encode, size and decode must agree between the dispatched varint
    // kernels and their scalar twins on random gap mixes covering every
    // width class (1-byte runs through 5-byte extremes), and truncated
    // tails must fail with the same error at the same position
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let n = rng.below(600);
        let mut ids: Vec<u32> = Vec::with_capacity(n);
        let mut acc = 0u64;
        for _ in 0..n {
            let width = 1usize << (3 + rng.below(25));
            acc += 1 + rng.below(width) as u64;
            if acc > u32::MAX as u64 {
                break;
            }
            ids.push(acc as u32);
        }
        let (mut a, mut b) = (Vec::new(), Vec::new());
        simd::varint_encode_gaps_scalar(&ids, &mut a);
        simd::varint_encode_gaps(&ids, &mut b);
        assert_eq!(a, b, "seed {seed}: encode bytes diverged");
        assert_eq!(simd::varint_gaps_bytes(&ids), a.len(), "seed {seed}");
        assert_eq!(simd::varint_gaps_bytes_scalar(&ids), a.len(), "seed {seed}");
        let (mut g1, mut g2) = (vec![0u32; ids.len()], vec![0u32; ids.len()]);
        let (mut p1, mut p2) = (0usize, 0usize);
        let r1 = simd::varint_decode_gaps_scalar(&a, &mut p1, &mut g1);
        let r2 = simd::varint_decode_gaps(&a, &mut p2, &mut g2);
        assert_eq!(r1.0, r2.0, "seed {seed}: decoded counts diverged");
        assert_eq!(format!("{:?}", r1.1), format!("{:?}", r2.1), "seed {seed}");
        assert_eq!(p1, p2, "seed {seed}: cursor positions diverged");
        assert_eq!(g1, g2, "seed {seed}: decoded gaps diverged");
        if !a.is_empty() {
            let cut = rng.below(a.len());
            let (mut q1, mut q2) = (0usize, 0usize);
            let t1 = simd::varint_decode_gaps_scalar(&a[..cut], &mut q1, &mut g1);
            let t2 = simd::varint_decode_gaps(&a[..cut], &mut q2, &mut g2);
            assert_eq!(t1.0, t2.0, "seed {seed} cut {cut}");
            assert_eq!(format!("{:?}", t1.1), format!("{:?}", t2.1), "seed {seed} cut {cut}");
            assert_eq!(q1, q2, "seed {seed} cut {cut}");
            assert_eq!(g1[..t1.0], g2[..t2.0], "seed {seed} cut {cut}");
        }
    }
}

#[test]
fn prop_simd_q8_and_f16_kernels_byte_identical_to_scalar() {
    // value-coding kernels: every byte the dispatched q8/f16 paths emit,
    // and every f32 bit they decode back, must match the scalar twins — on
    // random blocks and on the adversarial edges (the round-half trap just
    // below 0.5, f16 overflow saturation, subnormals, signed zeros, and
    // the all-zero block whose scale is exactly 0)
    let half_trap = f32::from_bits(0.5f32.to_bits() - 1);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(1000);
        let mut vals: Vec<f32> = (0..n)
            .map(|_| rng.normal() * 10f32.powi(rng.below(11) as i32 - 5))
            .collect();
        for slot in 0..vals.len() {
            match rng.below(12) {
                0 => vals[slot] = 0.0,
                1 => vals[slot] = -0.0,
                2 => vals[slot] = half_trap * vals[slot].signum(),
                3 => vals[slot] = 65520.0 * vals[slot].signum(), // f16 overflow
                4 => vals[slot] = f32::from_bits(1 + rng.below(50) as u32),
                _ => {}
            }
        }
        // an all-zero leading block exercises the scale = 0 edge
        if rng.below(3) == 0 {
            for v in vals.iter_mut().take(codec::Q8_BLOCK.min(n)) {
                *v = 0.0;
            }
        }
        let (mut h1, mut h2) = (Vec::new(), Vec::new());
        simd::f16_encode_scalar(&vals, &mut h1);
        simd::f16_encode(&vals, &mut h2);
        assert_eq!(h1, h2, "seed {seed}: f16 encode bytes diverged");
        let (mut f1, mut f2) = (vec![0.0f32; n], vec![0.0f32; n]);
        simd::f16_decode_scalar(&h1, &mut f1);
        simd::f16_decode(&h1, &mut f2);
        assert_eq!(bits(&f1), bits(&f2), "seed {seed}: f16 decode bits diverged");
        for block in vals.chunks(codec::Q8_BLOCK) {
            let (ma, mb) = (simd::maxabs_scalar(block), simd::maxabs(block));
            assert_eq!(ma.to_bits(), mb.to_bits(), "seed {seed}: maxabs diverged");
            let (mut d1, mut d2) = (vec![0.0f32; block.len()], vec![0.0f32; block.len()]);
            if ma > 0.0 {
                let (mut q1, mut q2) = (Vec::new(), Vec::new());
                simd::q8_quantize_scalar(block, ma, &mut q1);
                simd::q8_quantize(block, ma, &mut q2);
                assert_eq!(q1, q2, "seed {seed}: q8 bytes diverged");
                let scale = codec::q8_block_scale(block);
                simd::q8_dequantize_scalar(&q1, scale, &mut d1);
                simd::q8_dequantize(&q1, scale, &mut d2);
            } else {
                // the wire format stores zero bytes and a zero scale for an
                // all-zero block; both decoders must emit exact +0.0
                let zeros = vec![0u8; block.len()];
                simd::q8_dequantize_scalar(&zeros, 0.0, &mut d1);
                simd::q8_dequantize(&zeros, 0.0, &mut d2);
                assert!(d1.iter().all(|v| v.to_bits() == 0), "seed {seed}");
            }
            assert_eq!(bits(&d1), bits(&d2), "seed {seed}: q8 decode bits diverged");
        }
    }
}

// -------------------------------------------------------------- aggregation

#[test]
fn prop_aggregator_equals_dense_mean() {
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let dim = 10 + rng.below(200);
        let kcount = 1 + rng.below(8);
        let mut agg = Aggregator::new(dim);
        let mut dense_sum = vec![0.0f64; dim];
        for _ in 0..kcount {
            let mut ids: Vec<u32> = (0..dim as u32).collect();
            rng.shuffle(&mut ids);
            ids.truncate(rng.below(dim + 1));
            ids.sort_unstable();
            let vals: Vec<f32> = ids.iter().map(|_| rng.normal()).collect();
            let sv = SparseVec::from_sorted(dim, ids, vals);
            for (&i, &v) in sv.indices.iter().zip(&sv.values) {
                dense_sum[i as usize] += v as f64;
            }
            agg_add(&mut agg, &sv);
        }
        let mean = agg_finish(&mut agg, kcount);
        let dense = mean.to_dense();
        for i in 0..dim {
            let want = dense_sum[i] / kcount as f64;
            assert!((dense[i] as f64 - want).abs() < 1e-5, "seed {seed} i {i}");
        }
    }
}

// ------------------------------------------------------------ mask overlap

#[test]
fn prop_jaccard_estimate_tracks_exact() {
    // the O(nnz) estimator from PR 1 vs the exact O(n²·nnz) statistic:
    // exact on any two masks and on identical masks; on random equal-size
    // masks it's a Jensen lower bound within a small deviation
    use fedgmf::sparse::merge::{mean_jaccard_estimate, mean_pairwise_jaccard};
    let mut scratch = Vec::new();
    for seed in seeds() {
        let mut rng = Rng::new(seed);

        // n = 2: estimator reduces to intersection/union — exact
        let a = rand_sparse(&mut rng, 200);
        let mut b = rand_sparse(&mut rng, 200);
        b.dim = a.dim.max(b.dim);
        let a2 = SparseVec::from_sorted(b.dim, a.indices.clone(), a.values.clone());
        let exact2 = mean_pairwise_jaccard(&[&a2, &b]);
        let est2 = mean_jaccard_estimate(&[&a2, &b], &mut scratch);
        assert!((est2 - exact2).abs() < 1e-12, "seed {seed}: n=2 must be exact");

        // identical masks: both statistics are exactly 1
        let copies: Vec<&SparseVec> = std::iter::repeat(&a2).take(2 + seed as usize % 4).collect();
        assert_eq!(mean_jaccard_estimate(&copies, &mut scratch), 1.0, "seed {seed}");
        assert_eq!(mean_pairwise_jaccard(&copies), 1.0, "seed {seed}");

        // random equal-k masks: bounded deviation, and never above the exact
        // statistic (Jensen: x/(2k−x) is convex in the intersection x)
        let dim = 300;
        let k = 30;
        let n = 3 + rng.below(5);
        let masks: Vec<SparseVec> = (0..n)
            .map(|_| {
                let mut ids: Vec<u32> = (0..dim as u32).collect();
                rng.shuffle(&mut ids);
                ids.truncate(k);
                ids.sort_unstable();
                let vals = vec![1.0f32; k];
                SparseVec::from_sorted(dim, ids, vals)
            })
            .collect();
        let refs: Vec<&SparseVec> = masks.iter().collect();
        let exact = mean_pairwise_jaccard(&refs);
        let est = mean_jaccard_estimate(&refs, &mut scratch);
        assert!(
            est <= exact + 1e-9,
            "seed {seed}: estimate {est} must lower-bound exact {exact} at equal k"
        );
        assert!(
            (exact - est).abs() < 0.05,
            "seed {seed}: |{exact} - {est}| out of tolerance"
        );
    }
}

// ------------------------------------------------------------- compression

#[test]
fn prop_compress_partitions_v_and_respects_k() {
    // For every scheme: nnz(G) <= k, and for DGC-family the transmitted
    // values + residual exactly reconstruct the pre-extraction V.
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let dim = 50 + rng.below(500);
        let k = 1 + rng.below(dim / 2 + 1);
        for kind in CompressorKind::ALL {
            let mut comp = fedgmf::compress::build(kind, &CompressConfig::default(), dim);
            let ghat = rand_sparse(&mut rng, dim);
            // pad ghat to the right dim (rand_sparse picks its own)
            let ghat = SparseVec::new(
                dim,
                ghat.indices
                    .iter()
                    .zip(&ghat.values)
                    .filter(|(&i, _)| (i as usize) < dim)
                    .map(|(&i, &v)| (i, v))
                    .collect(),
            );
            comp.observe_broadcast(&ghat);
            let grad: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            let out = comp.compress(&grad, k, 0);
            assert!(out.gradient.nnz() <= k, "{} seed {seed}", kind.name());
            assert_eq!(out.gradient.dim, dim);
            out.gradient.debug_ok();
        }
    }
}

#[test]
fn prop_gmf_tau_zero_is_dgc_for_any_input() {
    for seed in seeds().take(30) {
        let mut rng = Rng::new(seed);
        let dim = 20 + rng.below(300);
        let k = 1 + rng.below(dim / 3 + 1);
        let cfg0 = CompressConfig { tau: TauSchedule::Constant(0.0), ..Default::default() };
        let mut gmf = fedgmf::compress::DgcGmf::new(&cfg0, dim);
        let mut dgc = fedgmf::compress::Dgc::new(&CompressConfig::default(), dim);
        for round in 0..4 {
            let ghat = rand_sparse(&mut rng, dim);
            let ghat = SparseVec::new(
                dim,
                ghat.indices
                    .iter()
                    .zip(&ghat.values)
                    .filter(|(&i, _)| (i as usize) < dim)
                    .map(|(&i, &v)| (i, v))
                    .collect(),
            );
            gmf.observe_broadcast(&ghat);
            dgc.observe_broadcast(&ghat);
            let grad: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
            let a = gmf.compress(&grad, k, round);
            let b = dgc.compress(&grad, k, round);
            assert_eq!(a.gradient, b.gradient, "seed {seed} round {round}");
        }
    }
}

#[test]
fn prop_gmf_score_invariants() {
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let n = 2 + rng.below(2000);
        let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let m: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let tau = rng.f32();
        let mut z = vec![0.0f32; n];
        primitives::gmf_score(&mut z, &v, &m, tau);
        // non-negative, finite, bounded by |N(v)| + |N(m)| <= 2
        assert!(z.iter().all(|&x| x >= 0.0 && x.is_finite() && x <= 2.0), "seed {seed}");
    }
}

// -------------------------------------------------------------- partition

#[test]
fn prop_partition_covers_all_samples_once() {
    for seed in seeds().take(25) {
        let mut rng = Rng::new(seed);
        let classes = 2 + rng.below(9);
        let per_class = 20 + rng.below(80);
        let clients = classes + rng.below(3 * classes);
        let labels: Vec<i32> = (0..classes)
            .flat_map(|c| std::iter::repeat(c as i32).take(per_class))
            .collect();
        let max_emd = 2.0 * (classes as f64 - 1.0) / classes as f64;
        let target = rng.f64() * max_emd;
        let (shards, achieved) =
            partition_by_emd(&labels, classes, clients, target, seed).unwrap();
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.sample_ids.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..labels.len()).collect::<Vec<_>>(), "seed {seed}");
        assert!((0.0..=max_emd + 1e-9).contains(&achieved), "seed {seed}");
    }
}

#[test]
fn prop_emd_bounds() {
    for seed in seeds().take(30) {
        let mut rng = Rng::new(seed);
        let classes = 2 + rng.below(8);
        let clients = 1 + rng.below(12);
        let hists: Vec<Vec<usize>> = (0..clients)
            .map(|_| (0..classes).map(|_| rng.below(50)).collect())
            .collect();
        let emd = emd_of_partition(&hists);
        let max = 2.0;
        assert!((0.0..=max).contains(&emd), "seed {seed} emd {emd}");
    }
}

// -------------------------------------------------------------------- json

#[test]
fn prop_json_roundtrip() {
    for seed in seeds().take(40) {
        let mut rng = Rng::new(seed);
        let j = rand_json(&mut rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back, "seed {seed}: {text}");
        let pretty = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, pretty, "seed {seed}");
    }
}

fn rand_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.normal() * 100.0).round() as f64 / 4.0),
        3 => {
            let len = rng.below(8);
            Json::Str((0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
        }
        4 => Json::Arr((0..rng.below(4)).map(|_| rand_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), rand_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

// -------------------------------------------------- schedules (boundaries)

#[test]
fn prop_schedule_boundaries_hold_for_random_shapes() {
    // randomized (rate, warmup, dim, total_rounds, steps) shapes: k_at is
    // always in [1, dim] for dim > 0 (0 at dim 0), warmup keep-rates decay
    // monotonically to the target, and tau ramps monotonically into a
    // clamped end value at and past total_rounds
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let rate = 10f64.powf(-(rng.below(9) as f64)).max(1e-9);
        let warmup = rng.below(12);
        let w = SparsityWarmup { rate, warmup_rounds: warmup };
        let dim = rng.below(2000);
        for round in [0usize, 1, warmup.saturating_sub(1), warmup, warmup + 1, 10_000] {
            let k = w.k_at(dim, round);
            if dim == 0 {
                assert_eq!(k, 0, "seed {seed} round {round}");
            } else {
                assert!((1..=dim).contains(&k), "seed {seed} dim {dim} round {round}: k {k}");
            }
            let keep = w.at(round);
            assert!(keep >= rate - 1e-15 && keep <= 1.0, "seed {seed}: keep {keep}");
            if round >= warmup {
                assert_eq!(keep, rate, "seed {seed}: past warmup the rate is flat");
            }
        }
        let total = 1 + rng.below(300);
        let steps = 1 + rng.below(20);
        let end = rng.f32();
        let s = TauSchedule::Stepped { end, steps, total_rounds: total };
        let mut last = -1.0f32;
        for round in 0..total {
            let tau = s.at(round);
            assert!(tau >= last, "seed {seed} round {round}: tau must not decrease");
            assert!((0.0..=end.max(0.0)).contains(&tau), "seed {seed}: tau {tau}");
            last = tau;
        }
        // end·steps/steps can differ from end by an ulp — compare loosely
        let done = s.at(total);
        assert!((done - end).abs() <= end.abs() * 1e-6, "seed {seed}: {done} vs {end}");
        assert_eq!(s.at(total + rng.below(10_000)).to_bits(), done.to_bits(), "seed {seed}");
        assert_eq!(s.at(usize::MAX).to_bits(), done.to_bits(), "seed {seed}: no overflow");
    }
}

// ----------------------------------------------------- q8 value coding

#[test]
fn prop_q8_roundtrip_error_bounded_and_zeros_exact() {
    // the blockwise-int8 contract, checked by the exact invariant
    // `fedgmf verify` uses (testkit::invariants::check_q8_roundtrip):
    // support preserved, exact zeros exact, per-coordinate error within
    // half a block quantisation step. Low density keeps the sparse
    // container selected so explicit zero entries survive the trip.
    use fedgmf::sparse::codec::{CodecParams, IndexCoding, ValueCoding};
    use fedgmf::testkit::invariants::check_q8_roundtrip;
    let mut buf = Vec::new();
    let mut back = SparseVec::empty(0);
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let dim = 600 + rng.below(8000);
        let nnz = 1 + rng.below(dim / 20 + 1); // sparse container territory
        let mut ids: Vec<u32> = (0..dim as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(nnz);
        ids.sort_unstable();
        let mut values: Vec<f32> = ids
            .iter()
            .map(|_| rng.normal() * 10f32.powi(rng.below(5) as i32 - 2))
            .collect();
        // sprinkle exact zeros (an all-zero block is a valid edge too)
        for slot in 0..values.len() {
            if rng.below(5) == 0 {
                values[slot] = 0.0;
            }
        }
        let sv = SparseVec::from_sorted(dim, ids, values);
        for index in [IndexCoding::Raw, IndexCoding::Varint] {
            let p = CodecParams { index, value: ValueCoding::Q8 };
            wire::encode_with(&sv, &mut buf, p);
            wire::decode_into(&buf, &mut back).unwrap();
            let violations = check_q8_roundtrip(&sv, &back);
            assert!(violations.is_empty(), "seed {seed} {p:?}: {violations:?}");
        }
    }
}

// ------------------------------------------- adversarial v2 wire buffers

/// Hand-rolled v2 sparse-container header (magic | kind 2 | container |
/// index | value | dim | nnz) for adversarial buffer construction.
fn v2_sparse_header(dim: u32, nnz: u32, index: u8, value: u8) -> Vec<u8> {
    let mut b = Vec::with_capacity(16);
    b.extend_from_slice(&wire::MAGIC.to_le_bytes());
    b.push(codec::KIND_V2);
    b.push(codec::CONTAINER_SPARSE);
    b.push(index);
    b.push(value);
    b.extend_from_slice(&dim.to_le_bytes());
    b.extend_from_slice(&nnz.to_le_bytes());
    b
}

#[test]
fn prop_wire_v2_varint_gap_overflow_is_error_not_panic() {
    let mut out = SparseVec::empty(0);

    // gaps that accumulate past dim → IndexOutOfBounds, never a bad vector
    let mut past_dim = v2_sparse_header(100, 2, 1, 0);
    past_dim.push(70); // first index 70
    past_dim.extend_from_slice(&[0xC8, 0x01]); // gap 200 → index 270 ≥ dim
    past_dim.extend_from_slice(&[0u8; 8]); // two f32 value slots
    assert!(matches!(
        wire::decode_into(&past_dim, &mut out),
        Err(wire::WireError::IndexOutOfBounds { .. })
    ));

    // a varint whose 5th byte carries bits above u32 → BadVarint
    let mut wide = v2_sparse_header(100, 2, 1, 0);
    wide.extend_from_slice(&[0xFF, 0xFF, 0xFF, 0xFF, 0x1F]);
    wide.extend_from_slice(&[0u8; 16]); // enough bytes to pass the pre-check
    assert!(matches!(wire::decode_into(&wide, &mut out), Err(wire::WireError::BadVarint(_))));

    // unbounded continuation bytes → BadVarint (shift guard), not a hang
    let mut endless = v2_sparse_header(100, 2, 1, 0);
    endless.extend_from_slice(&[0x80; 10]);
    endless.extend_from_slice(&[0u8; 16]);
    assert!(matches!(wire::decode_into(&endless, &mut out), Err(wire::WireError::BadVarint(_))));

    // a zero gap after the first index → Unsorted (duplicate index)
    let mut dup = v2_sparse_header(100, 2, 1, 0);
    dup.push(5);
    dup.push(0);
    dup.extend_from_slice(&[0u8; 8]);
    assert!(matches!(wire::decode_into(&dup, &mut out), Err(wire::WireError::Unsorted)));

    // randomized: corrupt one gap byte of a valid varint buffer — decode
    // must return Ok or Err, never panic, and the buffer stays reusable
    let mut buf = Vec::new();
    for seed in seeds().take(25) {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 300);
        let p = codec::CodecParams { index: codec::IndexCoding::Varint, value: codec::ValueCoding::F32 };
        wire::encode_with(&sv, &mut buf, p);
        if buf.len() <= 17 {
            continue; // header-only (empty vector) — nothing to corrupt
        }
        let at = 16 + rng.below(buf.len() - 16);
        let mut bad = buf.clone();
        bad[at] = bad[at].wrapping_add(1 + rng.below(255) as u8);
        let _ = wire::decode_into(&bad, &mut out);
        wire::decode_into(&buf, &mut out).unwrap();
        assert_eq!(out, sv, "seed {seed}: pristine buffer must still decode");
    }
}

#[test]
fn prop_wire_v2_nnz_lies_rejected_without_overallocation() {
    // a header claiming u32::MAX q8 entries against a tiny buffer must be
    // rejected by the availability pre-check BEFORE any reserve — the
    // output vector's capacity proves no allocation happened
    for (index, value) in [(0u8, 2u8), (1, 2), (0, 0), (1, 1)] {
        let mut lie = v2_sparse_header(1000, u32::MAX, index, value);
        lie.extend_from_slice(&[0u8; 32]);
        let mut fresh = SparseVec::empty(0);
        assert!(matches!(
            wire::decode_into(&lie, &mut fresh),
            Err(wire::WireError::Truncated(_))
        ));
        assert_eq!(fresh.indices.capacity(), 0, "oversized nnz must not allocate");
        assert_eq!(fresh.values.capacity(), 0, "oversized nnz must not allocate");
    }

    // q8 block-length lies: claim more entries than the value stream holds
    // (the nnz field implies scale-prefixed block lengths) → Truncated
    let mut out = SparseVec::empty(0);
    let mut buf = Vec::new();
    for seed in seeds().take(25) {
        let mut rng = Rng::new(seed);
        let dim = 600 + rng.below(2000);
        let nnz = 1 + rng.below(dim / 20 + 1); // sparse container territory
        let mut ids: Vec<u32> = (0..dim as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(nnz);
        ids.sort_unstable();
        let values: Vec<f32> = ids.iter().map(|_| rng.normal()).collect();
        let sv = SparseVec::from_sorted(dim, ids, values);
        let p = codec::CodecParams { index: codec::IndexCoding::Raw, value: codec::ValueCoding::Q8 };
        wire::encode_with(&sv, &mut buf, p);
        assert_eq!(buf[5], codec::CONTAINER_SPARSE, "seed {seed}");
        let mut bloated = buf.clone();
        let claim = (nnz as u32).saturating_add(1 + rng.below(1000) as u32);
        bloated[12..16].copy_from_slice(&claim.to_le_bytes());
        assert!(
            wire::decode_into(&bloated, &mut out).is_err(),
            "seed {seed}: inflated nnz {claim} over {nnz} real entries must fail"
        );
        // and every strict prefix of the honest buffer is rejected too
        for cut in (0..buf.len()).step_by(1 + buf.len() / 40) {
            assert!(
                wire::decode_into(&buf[..cut], &mut out).is_err(),
                "seed {seed}: q8 prefix of {cut} bytes must be rejected"
            );
        }
        wire::decode_into(&buf, &mut out).unwrap();
        assert_eq!(out.indices, sv.indices, "seed {seed}");
    }
}

#[test]
fn prop_wire_v2_bitmap_dim_mismatch_rejected() {
    let mut out = SparseVec::empty(0);

    // hand-rolled: dim 10 needs 2 bitmap bytes; a presence bit at
    // position ≥ dim contradicts the header → BadBitmap
    let mut bad = Vec::new();
    bad.extend_from_slice(&wire::MAGIC.to_le_bytes());
    bad.push(codec::KIND_V2);
    bad.push(codec::CONTAINER_BITMAP);
    bad.push(0); // index coding (unused by bitmap)
    bad.push(0); // f32 values
    bad.extend_from_slice(&10u32.to_le_bytes());
    bad.push(0b0000_1000); // bit 3 — legal
    bad.push(0b0001_0000); // bit 12 — beyond dim 10
    assert!(matches!(wire::decode_into(&bad, &mut out), Err(wire::WireError::BadBitmap)));

    // randomized: take honestly-encoded bitmap buffers at non-multiple-of-8
    // dims and set the top bit of the last bitmap byte
    let mut buf = Vec::new();
    for seed in seeds().take(25) {
        let mut rng = Rng::new(seed);
        let dim = 8 * (64 + rng.below(64)) + 1 + rng.below(7); // dim % 8 != 0
        let nnz = dim * 3 / 10; // mid density → bitmap container
        let mut ids: Vec<u32> = (0..dim as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(nnz);
        ids.sort_unstable();
        let values: Vec<f32> = ids.iter().map(|_| rng.normal()).collect();
        let sv = SparseVec::from_sorted(dim, ids, values);
        let p = codec::CodecParams { index: codec::IndexCoding::Varint, value: codec::ValueCoding::F16 };
        wire::encode_with(&sv, &mut buf, p);
        if buf[5] != codec::CONTAINER_BITMAP {
            continue; // density heuristics picked another container
        }
        let last_bm = codec::V2_HEADER_BYTES + dim.div_ceil(8) - 1;
        let mut lifted = buf.clone();
        lifted[last_bm] |= 0x80; // bit 7 of the last byte is ≥ dim here
        assert!(
            matches!(wire::decode_into(&lifted, &mut out), Err(wire::WireError::BadBitmap)),
            "seed {seed} dim {dim}"
        );
        // truncating the value stream behind an honest bitmap → Truncated
        let cut = buf.len() - 1;
        assert!(
            matches!(wire::decode_into(&buf[..cut], &mut out), Err(wire::WireError::Truncated(_))),
            "seed {seed}"
        );
        wire::decode_into(&buf, &mut out).unwrap();
        assert_eq!(out.indices, sv.indices, "seed {seed}");
    }
}

#[test]
fn prop_wire_v2_mutation_fuzz_never_panics() {
    // arbitrary single-byte corruption anywhere in a valid v2 buffer must
    // produce Ok or Err — never a panic — and leave the reused output
    // vector decodable next call
    let mut out = SparseVec::empty(0);
    let mut buf = Vec::new();
    let combos = [
        (codec::IndexCoding::Varint, codec::ValueCoding::F32),
        (codec::IndexCoding::Varint, codec::ValueCoding::F16),
        (codec::IndexCoding::Raw, codec::ValueCoding::Q8),
        (codec::IndexCoding::Varint, codec::ValueCoding::Q8),
    ];
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 500);
        let (index, value) = combos[rng.below(combos.len())];
        wire::encode_with(&sv, &mut buf, codec::CodecParams { index, value });
        let mut bad = buf.clone();
        for _ in 0..1 + rng.below(3) {
            let at = rng.below(bad.len());
            bad[at] ^= 1 << rng.below(8);
        }
        let _ = wire::decode_into(&bad, &mut out);
        wire::decode_into(&buf, &mut out).unwrap();
        assert_eq!(out.dim, sv.dim, "seed {seed}");
        assert_eq!(out.indices, sv.indices, "seed {seed}");
    }
}

// ------------------------------------------------------- service framing

/// A reader that yields at most one byte per `read` call — worst-case
/// stream fragmentation for the framing layer.
struct OneByteReader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl std::io::Read for OneByteReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if buf.is_empty() || self.pos >= self.data.len() {
            return Ok(0);
        }
        buf[0] = self.data[self.pos];
        self.pos += 1;
        Ok(1)
    }
}

fn rand_msg(rng: &mut Rng) -> framing::Msg {
    let payload: Vec<u8> = (0..rng.below(200)).map(|_| rng.below(256) as u8).collect();
    let fates =
        [framing::FATE_NONE, framing::FATE_ACCEPTED, framing::FATE_STRAGGLER, framing::FATE_OFFLINE];
    match rng.below(5) {
        0 => framing::Msg::Hello { client: rng.below(1 << 20) as u32 },
        1 => framing::Msg::Welcome { dim: rng.below(1 << 20) as u32, rounds: rng.below(500) as u32 },
        2 => framing::Msg::Round {
            round: rng.below(500) as u32,
            participate: rng.below(2) == 0,
            fate: fates[rng.below(4)],
            payload,
        },
        3 => framing::Msg::Upload {
            round: rng.below(500) as u32,
            client: rng.below(1 << 20) as u32,
            loss: rng.normal() as f64,
            precodec: rng.below(1 << 30) as u64,
            payload,
        },
        _ => framing::Msg::Done { fate: fates[rng.below(4)] },
    }
}

#[test]
fn prop_framing_roundtrip_over_fragmenting_reader() {
    // a stream of random frames must reassemble exactly through both read
    // paths when the transport delivers one byte at a time
    for seed in seeds().take(30) {
        let mut rng = Rng::new(seed);
        let msgs: Vec<framing::Msg> = (0..1 + rng.below(8)).map(|_| rand_msg(&mut rng)).collect();
        let mut wire_bytes = Vec::new();
        for m in &msgs {
            m.encode(&mut wire_bytes);
        }

        // read_msg over the fragmenting reader (read_exact loops)
        let mut r = OneByteReader { data: &wire_bytes, pos: 0 };
        for m in &msgs {
            assert_eq!(&framing::read_msg(&mut r).unwrap(), m, "seed {seed}");
        }

        // read_msg_buffered + FrameBuffer (the timeout-safe path)
        let mut r = OneByteReader { data: &wire_bytes, pos: 0 };
        let mut fb = framing::FrameBuffer::new();
        for m in &msgs {
            assert_eq!(&framing::read_msg_buffered(&mut r, &mut fb).unwrap(), m, "seed {seed}");
        }
        assert!(fb.next_msg().unwrap().is_none(), "seed {seed}: buffer must drain");
    }
}

#[test]
fn prop_framing_truncation_at_every_boundary_rejected() {
    // a stream that ends at ANY byte inside a frame must surface
    // UnexpectedEof from both read paths — never a partial message, never
    // a panic; the FrameBuffer path additionally must keep reporting
    // "incomplete" (Ok(None)) rather than fabricating a frame
    for seed in seeds().take(12) {
        let mut rng = Rng::new(seed);
        let msg = rand_msg(&mut rng);
        let mut wire_bytes = Vec::new();
        msg.encode(&mut wire_bytes);
        for cut in 0..wire_bytes.len() {
            let err = framing::read_msg(&mut &wire_bytes[..cut]).unwrap_err();
            assert_eq!(
                err.kind(),
                std::io::ErrorKind::UnexpectedEof,
                "seed {seed} cut {cut}"
            );
            let mut r = OneByteReader { data: &wire_bytes[..cut], pos: 0 };
            let mut fb = framing::FrameBuffer::new();
            let err = framing::read_msg_buffered(&mut r, &mut fb).unwrap_err();
            assert_eq!(
                err.kind(),
                std::io::ErrorKind::UnexpectedEof,
                "seed {seed} cut {cut}"
            );
            assert!(
                fb.next_msg().unwrap().is_none(),
                "seed {seed} cut {cut}: a partial frame must never parse"
            );
        }
        // the full frame still parses after all the rejected prefixes
        assert_eq!(framing::read_msg(&mut &wire_bytes[..]).unwrap(), msg, "seed {seed}");
    }
}

// ----------------------------------------------- streamed ingest (Runs)

/// Every index × value coding the v2 codec can emit, plus the Raw/F32 pair
/// that doubles as the v1-identical shape.
fn all_codings() -> [(codec::IndexCoding, codec::ValueCoding); 6] {
    use codec::{IndexCoding::*, ValueCoding::*};
    [(Raw, F32), (Raw, F16), (Raw, Q8), (Varint, F32), (Varint, F16), (Varint, Q8)]
}

#[test]
fn prop_fold_stream_is_bit_identical_to_decode_then_add() {
    // the tentpole contract: folding a validated wire buffer straight into
    // the aggregator must match decode-then-add bit for bit, for any valid
    // vector under every index/value coding (the encoder picks the
    // container, so sparse, bitmap and dense layouts are all exercised as
    // density varies)
    let combos = all_codings();
    let mut buf = Vec::new();
    let mut echo = SparseVec::empty(0);
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 500);
        let (index, value) = combos[rng.below(combos.len())];
        wire::encode_with(&sv, &mut buf, codec::CodecParams { index, value });

        wire::decode_into(&buf, &mut echo).unwrap();
        let mut decoded = Aggregator::new(sv.dim);
        agg_add(&mut decoded, &echo);

        let runs = stream::Runs::validate(&buf).unwrap();
        let mut streamed = Aggregator::new(sv.dim);
        let folded = streamed.fold_stream(&runs, 1.0);
        assert_eq!(folded, echo.nnz(), "seed {seed}: fold must emit every decoded run");

        let (a, b) = (agg_finish(&mut decoded, 1), agg_finish(&mut streamed, 1));
        assert_eq!(a.indices, b.indices, "seed {seed} {index:?}/{value:?}");
        let bits = |v: &SparseVec| v.values.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&a), bits(&b), "seed {seed}: folded values must be bit-identical");
    }
}

#[test]
fn prop_runs_validate_verdict_agrees_with_decode_on_corrupt_buffers() {
    // pull-decoder validation must accept exactly the buffers decode_into
    // accepts: flip a few random bits in a valid buffer and demand the two
    // paths reach the same verdict — and when the mutant survives, that the
    // fold still emits exactly the decoded run count
    let combos = all_codings();
    let mut buf = Vec::new();
    let mut out = SparseVec::empty(0);
    for seed in seeds() {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 400);
        let (index, value) = combos[rng.below(combos.len())];
        wire::encode_with(&sv, &mut buf, codec::CodecParams { index, value });
        let mut bad = buf.clone();
        for _ in 0..1 + rng.below(3) {
            let at = rng.below(bad.len());
            bad[at] ^= 1 << rng.below(8);
        }
        let decode_ok = wire::decode_into(&bad, &mut out).is_ok();
        match stream::Runs::validate(&bad) {
            Ok(runs) => {
                assert!(decode_ok, "seed {seed}: validate accepted a buffer decode rejects");
                let mut agg = Aggregator::new(runs.dim());
                let folded = agg.fold_stream(&runs, 1.0);
                assert_eq!(folded, out.nnz(), "seed {seed}: accepted mutant must fold fully");
            }
            Err(_) => {
                assert!(!decode_ok, "seed {seed}: validate rejected a buffer decode accepts");
            }
        }
    }
}

#[test]
fn prop_fold_stream_truncation_rejected_without_partial_fold() {
    // partial-fold atomicity: a buffer cut at ANY byte boundary must fail
    // validation, so no run is ever emitted from it — the aggregator that
    // sat through every rejected prefix then folds the intact buffer to the
    // exact decode-then-add result, proving nothing leaked in
    let combos = all_codings();
    let mut echo = SparseVec::empty(0);
    for seed in seeds().take(12) {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 120);
        let (index, value) = combos[rng.below(combos.len())];
        let mut buf = Vec::new();
        wire::encode_with(&sv, &mut buf, codec::CodecParams { index, value });

        let mut agg = Aggregator::new(sv.dim);
        for cut in 0..buf.len() {
            assert!(
                stream::Runs::validate(&buf[..cut]).is_err(),
                "seed {seed} {index:?}/{value:?} cut {cut}: strict prefix must be rejected"
            );
        }
        let runs = stream::Runs::validate(&buf).unwrap();
        agg.fold_stream(&runs, 1.0);

        wire::decode_into(&buf, &mut echo).unwrap();
        let mut fresh = Aggregator::new(sv.dim);
        agg_add(&mut fresh, &echo);
        let (a, b) = (agg_finish(&mut agg, 1), agg_finish(&mut fresh, 1));
        assert_eq!(a.indices, b.indices, "seed {seed}");
        assert_eq!(
            a.values.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
            b.values.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
            "seed {seed}: prefix rejections must leave no trace in the accumulator"
        );
    }
}

#[test]
fn prop_read_payload_one_byte_fragmentation_then_fold_matches_direct() {
    // chunked Reader source: a payload delivered one byte per read() call
    // must reassemble byte-exactly, validate, and fold to the same result
    // as the buffer handed over whole
    let combos = all_codings();
    let mut buf = Vec::new();
    let mut scratch = Vec::new();
    let mut echo = SparseVec::empty(0);
    for seed in seeds().take(20) {
        let mut rng = Rng::new(seed);
        let sv = rand_sparse(&mut rng, 200);
        let (index, value) = combos[rng.below(combos.len())];
        wire::encode_with(&sv, &mut buf, codec::CodecParams { index, value });

        let mut r = OneByteReader { data: &buf, pos: 0 };
        let n = stream::read_payload(&mut r, &mut scratch).unwrap();
        assert_eq!(n, buf.len(), "seed {seed}");
        assert_eq!(scratch, buf, "seed {seed}: chunked reassembly must be byte-exact");

        let runs = stream::Runs::validate(&scratch).unwrap();
        let mut streamed = Aggregator::new(sv.dim);
        streamed.fold_stream(&runs, 1.0);
        wire::decode_into(&buf, &mut echo).unwrap();
        let mut direct = Aggregator::new(sv.dim);
        agg_add(&mut direct, &echo);
        assert_eq!(agg_finish(&mut streamed, 1), agg_finish(&mut direct, 1), "seed {seed}");
    }
}

// ----------------------------------------------------- fleet-state residency

/// Digest of one verify-fixture run at the given residency, codec and
/// topology knobs (everything else pinned to a sampled-cohort regime that
/// forces the virtual store through materialize → train → fold-back →
/// evict every round).
fn fixture_run_digest(
    kind: CompressorKind,
    params: codec::CodecParams,
    store: fedgmf::coordinator::StoreMode,
    tiers: usize,
    cohorts_per_edge: usize,
) -> (u64, fedgmf::coordinator::round::RunSummary) {
    fixture_run_digest_with(kind, params, store, tiers, cohorts_per_edge, |_| {})
}

/// Same fixture run with a final config tweak applied before the run is
/// built (rate-control knobs, sim deadlines, ...).
fn fixture_run_digest_with(
    kind: CompressorKind,
    params: codec::CodecParams,
    store: fedgmf::coordinator::StoreMode,
    tiers: usize,
    cohorts_per_edge: usize,
    tweak: impl FnOnce(&mut fedgmf::coordinator::round::FlConfig),
) -> (u64, fedgmf::coordinator::round::RunSummary) {
    use fedgmf::coordinator::round::{FlConfig, FlRun};
    use fedgmf::coordinator::sampler::Sampler;
    use fedgmf::experiments::workload::verify_fixture;
    use fedgmf::testkit::digest::trajectory_digest;

    let fx = verify_fixture(8, 0xBEEF);
    let mut engine = fx.engine;
    let mut cfg = FlConfig::new(kind, 0.25, 5);
    cfg.sampler = Sampler::Count(4);
    cfg.eval_every = 0;
    cfg.seed = 7;
    cfg.store = store;
    cfg.codec = codec::WireCodec { uplink: params, downlink: params };
    cfg.hierarchy.tiers = tiers;
    cfg.hierarchy.cohorts_per_edge = cohorts_per_edge;
    tweak(&mut cfg);
    let mut run = FlRun::new(&engine, fx.shards, Vec::new(), fx.network, cfg);
    let summary = run.run(&mut engine).unwrap();
    let bits: Vec<u32> = run.params.iter().map(|p| p.to_bits()).collect();
    (trajectory_digest(&bits, &summary.recorder.rounds), summary)
}

#[test]
fn prop_virtual_store_bit_identical_to_dense_across_techniques_and_codings() {
    // the ClientStore contract: sparse-at-rest records materialized into
    // pooled scratch for the sampled cohort, trained, folded back and
    // evicted must reproduce the always-dense fleet bit for bit — for
    // every compression technique and under every codec value coding
    // (which changes the broadcast bytes the virtual store replays)
    use fedgmf::coordinator::StoreMode;
    let codings = [
        codec::CodecParams { index: codec::IndexCoding::Raw, value: codec::ValueCoding::F32 },
        codec::CodecParams { index: codec::IndexCoding::Varint, value: codec::ValueCoding::F16 },
        codec::CodecParams { index: codec::IndexCoding::Varint, value: codec::ValueCoding::Q8 },
    ];
    for &kind in CompressorKind::ALL.iter() {
        for &params in &codings {
            let (dense, _) = fixture_run_digest(kind, params, StoreMode::Dense, 1, 32);
            let (virt, _) = fixture_run_digest(kind, params, StoreMode::Virtual, 1, 32);
            assert_eq!(
                dense, virt,
                "{kind:?}/{params:?}: virtual store trajectory diverged from dense"
            );
        }
    }
}

#[test]
fn prop_two_tier_digest_matches_flat_for_any_edge_fanin() {
    // the hierarchy contract, swept over edge fan-ins from degenerate
    // (every member its own edge) to larger-than-cohort (one edge): the
    // trajectory digest never moves, while the tier-1 ledger fills in
    // whenever the topology is actually two-tier
    use fedgmf::coordinator::StoreMode;
    let params =
        codec::CodecParams { index: codec::IndexCoding::Varint, value: codec::ValueCoding::Q8 };
    let kind = CompressorKind::DgcWgmf;
    let (flat, flat_summary) = fixture_run_digest(kind, params, StoreMode::Auto, 1, 32);
    assert!(
        flat_summary.recorder.rounds.iter().all(|r| r.edge_count == 0),
        "flat run must not record edges"
    );
    for per_edge in [1usize, 2, 3, 64] {
        let (tiered, summary) = fixture_run_digest(kind, params, StoreMode::Auto, 2, per_edge);
        assert_eq!(flat, tiered, "per_edge {per_edge}: two-tier digest diverged from flat");
        let edgy = summary.recorder.rounds.iter().filter(|r| r.edge_count > 0).count();
        assert!(edgy > 0, "per_edge {per_edge}: no round recorded edge traffic");
        for r in &summary.recorder.rounds {
            assert!(
                r.consistency_violations().is_empty(),
                "per_edge {per_edge} round {}: {:?}",
                r.round,
                r.consistency_violations()
            );
        }
    }
}

// ----------------------------------------------------- adaptive rate control

#[test]
fn prop_rate_control_off_is_inert_for_every_technique() {
    // `[rate_control] mode = "off"` — even with every other knob moved off
    // its default — must be byte-identical to a config that never mentions
    // the section. The mode gates all planning, so pre-controller
    // trajectories are reproduced digest-exact for every technique.
    use fedgmf::compress::{RateControlConfig, RateControlMode};
    use fedgmf::coordinator::StoreMode;
    let params =
        codec::CodecParams { index: codec::IndexCoding::Varint, value: codec::ValueCoding::F16 };
    let off = RateControlConfig {
        mode: RateControlMode::Off,
        min_rate_frac: 0.5,
        max_rate_boost: 4.0,
        deadline_margin: 0.5,
        adapt_coding: false,
    };
    for &kind in CompressorKind::ALL.iter() {
        let (base, _) = fixture_run_digest(kind, params, StoreMode::Auto, 1, 32);
        let (gated, summary) =
            fixture_run_digest_with(kind, params, StoreMode::Auto, 1, 32, |cfg| {
                cfg.rate_control = off;
            });
        assert_eq!(base, gated, "{kind:?}: rate_control=off moved the trajectory digest");
        for r in &summary.recorder.rounds {
            assert_eq!(r.coding_downshifts, 0, "{kind:?} round {}: off downshifted", r.round);
            assert!(
                (r.rate_max - r.rate_min).abs() < 1e-12,
                "{kind:?} round {}: off must record one shared rate",
                r.round
            );
        }
    }
}

#[test]
fn prop_adaptive_digest_invariant_across_store_and_topology() {
    // adaptive planning is a pure function of per-client scheduler profiles
    // and selection history — state that is identical across fleet-state
    // residency and aggregation topology — so turning the controller on
    // must not break the Dense ≡ Virtual and flat ≡ two-tier digest
    // contracts, even while per-client (k, coding) genuinely diverge
    use fedgmf::coordinator::StoreMode;
    let params =
        codec::CodecParams { index: codec::IndexCoding::Varint, value: codec::ValueCoding::F16 };
    // deadline regime sized so the 1 200 B/s fixture tier is hopeless
    // (k floor + Q8) while the 24 000 B/s tier keeps its full budget
    fn adaptive(cfg: &mut fedgmf::coordinator::round::FlConfig) {
        use fedgmf::compress::RateControlMode;
        cfg.rate_control.mode = RateControlMode::Adaptive;
        cfg.sim.deadline_s = 0.03;
        cfg.sim.compute_s = 0.004;
    }
    for &kind in CompressorKind::ALL.iter() {
        let (dense, summary) =
            fixture_run_digest_with(kind, params, StoreMode::Dense, 1, 32, adaptive);
        let (virt, _) = fixture_run_digest_with(kind, params, StoreMode::Virtual, 1, 32, adaptive);
        assert_eq!(dense, virt, "{kind:?}: adaptive virtual-store trajectory diverged from dense");
        let (tiered, tiered_summary) =
            fixture_run_digest_with(kind, params, StoreMode::Auto, 2, 2, adaptive);
        assert_eq!(dense, tiered, "{kind:?}: adaptive two-tier digest diverged from flat");
        // the regime must genuinely plan per client, not degenerate to off
        assert!(
            summary.recorder.rounds.iter().any(|r| r.rate_max - r.rate_min > 1e-9),
            "{kind:?}: adaptive plans never diverged across clients"
        );
        assert!(
            summary.recorder.rounds.iter().map(|r| r.coding_downshifts).sum::<usize>() > 0,
            "{kind:?}: hopeless tier never downshifted its value coding"
        );
        for r in &tiered_summary.recorder.rounds {
            assert!(
                r.consistency_violations().is_empty(),
                "{kind:?} round {}: {:?}",
                r.round,
                r.consistency_violations()
            );
        }
    }
}

#[test]
fn prop_adaptive_rate_control_mass_clean_under_chaos_and_staleness() {
    // the verify-matrix claim at property scale: with per-client (k, coding)
    // moving round to round, frame-level chaos (offline drops, delayed
    // uploads) composed with every staleness policy must leave the
    // per-coordinate mass ledger clean — no residual double-count, no mass
    // minted when a replayed or carried upload meets a different plan
    use fedgmf::compress::RateControlMode;
    use fedgmf::coordinator::round::{FlConfig, FlRun};
    use fedgmf::coordinator::sampler::Sampler;
    use fedgmf::experiments::workload::verify_fixture;
    use fedgmf::sim::scheduler::StalenessPolicy;
    use fedgmf::testkit::invariants::MassLedger;
    use fedgmf::transport::fault::{FaultKind, FaultPlan};
    const ROUNDS: usize = 6;
    let params =
        codec::CodecParams { index: codec::IndexCoding::Varint, value: codec::ValueCoding::F16 };
    for policy in [
        StalenessPolicy::Drop,
        StalenessPolicy::Carry,
        StalenessPolicy::CarryDiscounted(0.4),
    ] {
        for (fkind, frate) in [(FaultKind::Drop, 0.2), (FaultKind::Delay, 0.25)] {
            let fx = verify_fixture(8, 0xBEEF);
            let mut engine = fx.engine;
            let mut cfg = FlConfig::new(CompressorKind::DgcWgmf, 0.25, ROUNDS);
            cfg.sampler = Sampler::Count(4);
            cfg.eval_every = 0;
            cfg.seed = 7;
            cfg.codec = codec::WireCodec { uplink: params, downlink: params };
            cfg.sim.deadline_s = 0.03;
            cfg.sim.compute_s = 0.004;
            cfg.sim.staleness = policy;
            cfg.fault = Some(FaultPlan::new(fkind, frate, 0xC4A05));
            cfg.rate_control.mode = RateControlMode::Adaptive;
            cfg.rate_control.max_rate_boost = 2.0;
            let mut run = FlRun::new(&engine, fx.shards, Vec::new(), fx.network, cfg);
            let dim = run.params.len();
            run.ledger = Some(Box::new(MassLedger::new(dim, policy)));
            let mut planned = false;
            for round in 0..ROUNDS {
                let rec = run.step_round(&mut engine, round).unwrap();
                planned |= rec.rate_max - rec.rate_min > 1e-9;
                assert!(
                    rec.consistency_violations().is_empty(),
                    "{policy:?}/{fkind:?} round {round}: {:?}",
                    rec.consistency_violations()
                );
            }
            assert!(planned, "{policy:?}/{fkind:?}: plans never diverged across clients");
            let ledger =
                run.ledger.take().unwrap().into_any().downcast::<MassLedger>().unwrap();
            let violations = ledger.check(&run.stale_queue);
            assert!(violations.is_empty(), "{policy:?}/{fkind:?}: {violations:?}");
        }
    }
}

// ------------------------------------------------------------ trait helper

trait DebugOk {
    fn debug_ok(&self);
}

impl DebugOk for SparseVec {
    fn debug_ok(&self) {
        assert_eq!(self.indices.len(), self.values.len());
        assert!(self.indices.windows(2).all(|w| w[0] < w[1]));
        if let Some(&last) = self.indices.last() {
            assert!((last as usize) < self.dim);
        }
    }
}
