//! Integration tests across the AOT boundary: artifacts built by
//! `python/compile/aot.py` (L2 JAX models + L1 Pallas kernels) loaded and
//! executed by the Rust PJRT runtime, checked against the Rust-native
//! implementations of the same math.
//!
//! These tests skip (with a notice) when `artifacts/` is missing — run
//! `make artifacts` first; `make test` does this automatically.

use fedgmf::compress::primitives;
use fedgmf::data::dataset::Batch;
use fedgmf::runtime::manifest::Manifest;
use fedgmf::runtime::pjrt::{KernelExecutor, PjrtContext};
use fedgmf::runtime::{evaluate, TrainEngine};
use fedgmf::util::rng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal()).collect()
}

#[test]
fn manifest_loads_and_lists_models() {
    let Some(dir) = artifacts_dir() else { return };
    let man = Manifest::load(&dir).unwrap();
    assert!(man.names().contains(&"resnet8"));
    assert!(man.names().contains(&"charlstm"));
    assert_eq!(man.model("resnet8").unwrap().param_count, 77850);
    assert_eq!(man.model("charlstm").unwrap().param_count, 25920);
}

#[test]
fn pallas_gmf_score_matches_rust_primitives() {
    let Some(dir) = artifacts_dir() else { return };
    let man = Manifest::load(&dir).unwrap();
    let ctx = PjrtContext::cpu().unwrap();
    let entry = man.model("charlstm").unwrap();
    let kx = KernelExecutor::new(&ctx, entry).unwrap();
    let p = entry.param_count;

    for (seed, tau) in [(1u64, 0.0f32), (2, 0.3), (3, 0.6), (4, 1.0)] {
        let v = randvec(p, seed);
        let m = randvec(p, seed + 100);
        let z_pallas = kx.gmf_score(&v, &m, tau).unwrap();
        let mut z_rust = vec![0.0f32; p];
        primitives::gmf_score(&mut z_rust, &v, &m, tau);
        let mut max_err = 0.0f32;
        for (a, b) in z_pallas.iter().zip(&z_rust) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-5, "tau={tau}: max |pallas - rust| = {max_err}");
    }
}

#[test]
fn pallas_dgc_update_matches_rust_primitives() {
    let Some(dir) = artifacts_dir() else { return };
    let man = Manifest::load(&dir).unwrap();
    let ctx = PjrtContext::cpu().unwrap();
    let entry = man.model("charlstm").unwrap();
    let kx = KernelExecutor::new(&ctx, entry).unwrap();
    let p = entry.param_count;

    let u0 = randvec(p, 10);
    let v0 = randvec(p, 11);
    let g = randvec(p, 12);
    let (u_pallas, v_pallas) = kx.dgc_update(&u0, &v0, &g, 0.9).unwrap();

    let mut u_rust = u0.clone();
    let mut v_rust = v0.clone();
    primitives::dgc_update(&mut u_rust, &mut v_rust, &g, 0.9);

    for i in 0..p {
        assert!((u_pallas[i] - u_rust[i]).abs() < 1e-5, "u[{i}]");
        assert!((v_pallas[i] - v_rust[i]).abs() < 1e-5, "v[{i}]");
    }
}

#[test]
fn lstm_train_step_runs_and_learns() {
    let Some(dir) = artifacts_dir() else { return };
    let man = Manifest::load(&dir).unwrap();
    let ctx = PjrtContext::cpu().unwrap();
    let entry = man.model("charlstm").unwrap();
    let mut engine = fedgmf::runtime::pjrt::PjrtEngine::new(ctx, entry).unwrap();

    let b = entry.batch;
    let s = entry.seq.unwrap();
    let vocab = entry.vocab.unwrap();
    let mut rng = Rng::new(7);
    // a learnable fixed batch: y = x (predict the same char class)
    let x: Vec<i32> = (0..b * s).map(|_| rng.below(vocab) as i32).collect();
    let y: Vec<i32> = x.clone();
    let batch = Batch::Tokens { x, y, n: b, seq: s };

    let mut params = engine.initial_params();
    let first = engine.train_step(&params, &batch).unwrap();
    assert!(first.loss.is_finite() && first.loss > 0.0);
    assert_eq!(first.grads.len(), entry.param_count);
    let gnorm: f64 = first.grads.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>().sqrt();
    assert!(gnorm > 0.0, "gradient must be nonzero");

    let mut last = first.loss;
    for _ in 0..15 {
        let out = engine.train_step(&params, &batch).unwrap();
        for (p, g) in params.iter_mut().zip(&out.grads) {
            *p -= 1.0 * g;
        }
        last = out.loss;
    }
    assert!(last < first.loss - 0.05, "loss {} -> {last}", first.loss);

    // eval agrees with train metrics at the same params
    let (eloss, eacc) = evaluate(&mut engine, &params, &[batch]).unwrap();
    assert!(eloss.is_finite());
    assert!((0.0..=1.0).contains(&eacc));
}

#[test]
fn resnet_train_step_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let man = Manifest::load(&dir).unwrap();
    let ctx = PjrtContext::cpu().unwrap();
    let entry = man.model("resnet8").unwrap();
    let mut engine = fedgmf::runtime::pjrt::PjrtEngine::new(ctx, entry).unwrap();

    use fedgmf::data::dataset::Dataset;
    use fedgmf::data::synth_cifar::CifarLike;
    let ds = CifarLike::balanced(8, 0.15, 5); // 80 samples
    let mut rng = Rng::new(3);
    let batch = ds.sample_batch(entry.batch, &mut rng);

    let params = engine.initial_params();
    let t0 = std::time::Instant::now();
    let out = engine.train_step(&params, &batch).unwrap();
    let dt = t0.elapsed();
    eprintln!("resnet8 train_step: {:.1} ms", dt.as_secs_f64() * 1e3);
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert_eq!(out.grads.len(), entry.param_count);
    assert!(out.ncorrect <= entry.batch);

    let (eloss, enc) = engine.eval_step(&params, &batch).unwrap();
    assert!((eloss - out.loss).abs() < 1e-4, "eval {eloss} vs train {}", out.loss);
    assert_eq!(enc, out.ncorrect);
}
