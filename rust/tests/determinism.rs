//! Parallel ⇔ sequential equivalence: a round executed over worker threads
//! must be **bit-identical** to the sequential path — same model parameters
//! (f32 bit patterns), same traffic bytes, same round records. This is the
//! contract that makes `run.workers` a pure performance knob.
//!
//! PR 2 extends the contract to the time-domain scheduler: with the inert
//! default `SimConfig` a run equals one with every sim knob spelled out at
//! its disabled value (the scheduler adds nothing), and with scheduling
//! *active* (deadline + dropout + over-selection + compute model) runs stay
//! bit-identical across worker counts — dropout draws come from the run
//! RNG in participant order, never from thread timing.

use fedgmf::compress::CompressorKind;
use fedgmf::coordinator::round::{FlConfig, FlRun, LrSchedule, RunSummary};
use fedgmf::coordinator::sampler::Sampler;
use fedgmf::data::dataset::Dataset;
use fedgmf::runtime::native::{BlobDataset, NativeEngine};
use fedgmf::sim::network::Network;
use fedgmf::sim::scheduler::{ProfilePreset, SimConfig};

const DIM: usize = 16;
const CLASSES: usize = 4;
const CLIENTS: usize = 8;

fn engine() -> NativeEngine {
    NativeEngine::new(DIM, 12, CLASSES, 7)
}

fn run_with_sim(
    kind: CompressorKind,
    sampler: Sampler,
    workers: usize,
    sim: SimConfig,
) -> (Vec<u32>, RunSummary) {
    let mut engine = engine();
    let shards: Vec<Box<dyn Dataset + Send>> = (0..CLIENTS)
        .map(|c| {
            Box::new(BlobDataset::generate_split(60, DIM, CLASSES, 0.4, 11, 12 + c as u64))
                as Box<dyn Dataset + Send>
        })
        .collect();
    let test = BlobDataset::generate_split(64, DIM, CLASSES, 0.4, 11, 0xE).eval_batches(32);
    let mut cfg = FlConfig::new(kind, 0.1, 12);
    cfg.lr = LrSchedule::constant(0.2);
    cfg.eval_every = 4;
    cfg.sampler = sampler;
    cfg.workers = workers;
    cfg.sim = sim;
    let mut run =
        FlRun::new(&engine, shards, test, Network::uniform(CLIENTS, Default::default()), cfg);
    let summary = run.run(&mut engine).unwrap();
    let param_bits: Vec<u32> = run.params.iter().map(|p| p.to_bits()).collect();
    (param_bits, summary)
}

fn run_with(kind: CompressorKind, sampler: Sampler, workers: usize) -> (Vec<u32>, RunSummary) {
    run_with_sim(kind, sampler, workers, SimConfig::default())
}

fn assert_rounds_identical(kind: CompressorKind, sum_seq: &RunSummary, sum_par: &RunSummary) {
    assert_eq!(sum_seq.recorder.rounds.len(), sum_par.recorder.rounds.len());
    for (a, b) in sum_seq.recorder.rounds.iter().zip(&sum_par.recorder.rounds) {
        assert_eq!(a.uplink_bytes, b.uplink_bytes, "{} round {}", kind.name(), a.round);
        assert_eq!(a.downlink_bytes, b.downlink_bytes, "{} round {}", kind.name(), a.round);
        assert_eq!(a.aggregate_nnz, b.aggregate_nnz, "{} round {}", kind.name(), a.round);
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "{} round {}: train loss must be bit-identical",
            kind.name(),
            a.round
        );
        assert_eq!(
            a.mask_overlap.to_bits(),
            b.mask_overlap.to_bits(),
            "{} round {}",
            kind.name(),
            a.round
        );
        assert_eq!(a.selected, b.selected, "{} round {}", kind.name(), a.round);
        assert_eq!(a.dropped_deadline, b.dropped_deadline, "{} round {}", kind.name(), a.round);
        assert_eq!(a.dropped_offline, b.dropped_offline, "{} round {}", kind.name(), a.round);
        assert_eq!(
            a.sim_seconds.to_bits(),
            b.sim_seconds.to_bits(),
            "{} round {}: simulated time must be bit-identical",
            kind.name(),
            a.round
        );
        assert_eq!(
            a.sim_clock.to_bits(),
            b.sim_clock.to_bits(),
            "{} round {}",
            kind.name(),
            a.round
        );
        assert_eq!(
            a.test_accuracy.to_bits(),
            b.test_accuracy.to_bits(),
            "{} round {}: parallel eval must be bit-identical",
            kind.name(),
            a.round
        );
    }
    assert_eq!(sum_seq.final_accuracy, sum_par.final_accuracy, "{}", kind.name());
}

fn assert_identical(kind: CompressorKind, sampler: Sampler) {
    let (params_seq, sum_seq) = run_with(kind, sampler, 1);
    for workers in [2usize, 4] {
        let (params_par, sum_par) = run_with(kind, sampler, workers);
        assert_eq!(
            params_seq, params_par,
            "{}: params must be bit-identical at workers={workers}",
            kind.name()
        );
        assert_rounds_identical(kind, &sum_seq, &sum_par);
    }
}

#[test]
fn all_schemes_bit_identical_under_parallelism() {
    for kind in CompressorKind::ALL {
        assert_identical(kind, Sampler::Full);
    }
}

#[test]
fn partial_participation_bit_identical_under_parallelism() {
    assert_identical(CompressorKind::DgcWgmf, Sampler::Fraction(0.5));
    assert_identical(CompressorKind::DgcWgm, Sampler::Count(3));
}

#[test]
fn scheduler_off_equals_explicitly_inert_scheduler() {
    // the scheduler must add nothing when every knob sits at its disabled
    // value — guards against "active by default" regressions of the PR 1
    // behaviour, at both worker counts
    let inert = SimConfig {
        preset: ProfilePreset::Uniform,
        deadline_s: 0.0,
        dropout: 0.0,
        overselect: 1.0,
        compute_s: 0.0,
    };
    for workers in [1usize, 4] {
        let (pa, sa) = run_with(CompressorKind::DgcWgmf, Sampler::Full, workers);
        let (pb, sb) =
            run_with_sim(CompressorKind::DgcWgmf, Sampler::Full, workers, inert);
        assert_eq!(pa, pb, "workers={workers}");
        assert_rounds_identical(CompressorKind::DgcWgmf, &sa, &sb);
        assert_eq!(sa.dropped_deadline, 0);
        assert_eq!(sa.dropped_offline, 0);
    }
}

#[test]
fn scheduler_on_bit_identical_across_worker_counts() {
    // full straggler regime: heterogeneous profiles, compute model, tight
    // deadline, dropouts, over-selection — still a pure performance knob
    let sim = SimConfig {
        preset: ProfilePreset::Heterogeneous { slow_every: 3, slow_factor: 6.0 },
        deadline_s: 0.08,
        dropout: 0.15,
        overselect: 1.5,
        compute_s: 0.01,
    };
    for (kind, sampler) in [
        (CompressorKind::DgcWgmf, Sampler::Fraction(0.5)),
        (CompressorKind::Dgc, Sampler::Full),
        (CompressorKind::DgcWgm, Sampler::Count(4)),
    ] {
        let (params_seq, sum_seq) = run_with_sim(kind, sampler, 1, sim);
        for workers in [2usize, 4] {
            let (params_par, sum_par) = run_with_sim(kind, sampler, workers, sim);
            assert_eq!(
                params_seq, params_par,
                "{}: scheduled run must be bit-identical at workers={workers}",
                kind.name()
            );
            assert_rounds_identical(kind, &sum_seq, &sum_par);
        }
        // the regime actually drops something, otherwise this test is vacuous
        assert!(
            sum_seq.dropped_deadline + sum_seq.dropped_offline > 0,
            "{}: straggler regime must produce drops",
            kind.name()
        );
    }
}

#[test]
fn longtail_profiles_and_budget_runs_deterministic() {
    let sim = SimConfig {
        preset: ProfilePreset::LongTail { sigma: 0.8 },
        deadline_s: 0.1,
        dropout: 0.05,
        overselect: 1.25,
        compute_s: 0.02,
    };
    let (pa, sa) = run_with_sim(CompressorKind::Gmc, Sampler::Fraction(0.6), 1, sim);
    let (pb, sb) = run_with_sim(CompressorKind::Gmc, Sampler::Fraction(0.6), 4, sim);
    assert_eq!(pa, pb);
    assert_rounds_identical(CompressorKind::Gmc, &sa, &sb);
}

#[test]
fn large_model_crosses_parallel_thresholds_bit_identical() {
    // The small cases above stay under the work gates and take the
    // sequential fallbacks inside the parallel machinery. This model is
    // sized so both gated paths actually execute at workers > 1:
    //   observe fan-out:  P × clients = 8828 × 8 = 70 624 ≥ 2^15
    //   sharded merge:    round nnz   = 4414 × 8 = 35 312 ≥ 2^15
    // DGCwGMF so observe_broadcast does real O(P) momentum work.
    let run = |workers: usize| {
        let mut engine = NativeEngine::new(96, 84, 8, 3); // P = 8828
        let shards: Vec<Box<dyn Dataset + Send>> = (0..CLIENTS)
            .map(|c| {
                Box::new(BlobDataset::generate_split(48, 96, 8, 0.4, 21, 22 + c as u64))
                    as Box<dyn Dataset + Send>
            })
            .collect();
        let mut cfg = FlConfig::new(CompressorKind::DgcWgmf, 0.5, 3);
        cfg.lr = LrSchedule::constant(0.1);
        cfg.batch_size = 16;
        cfg.workers = workers;
        let mut run = FlRun::new(
            &engine,
            shards,
            Vec::new(),
            Network::uniform(CLIENTS, Default::default()),
            cfg,
        );
        let summary = run.run(&mut engine).unwrap();
        let bits: Vec<u32> = run.params.iter().map(|p| p.to_bits()).collect();
        (bits, summary)
    };
    let (params_seq, sum_seq) = run(1);
    let (params_par, sum_par) = run(4);
    assert_eq!(params_seq, params_par, "params must be bit-identical across the sharded paths");
    for (a, b) in sum_seq.recorder.rounds.iter().zip(&sum_par.recorder.rounds) {
        assert_eq!(a.uplink_bytes, b.uplink_bytes, "round {}", a.round);
        assert_eq!(a.downlink_bytes, b.downlink_bytes, "round {}", a.round);
        assert_eq!(a.aggregate_nnz, b.aggregate_nnz, "round {}", a.round);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {}", a.round);
        assert_eq!(a.mask_overlap.to_bits(), b.mask_overlap.to_bits(), "round {}", a.round);
    }
}
