//! Parallel ⇔ sequential equivalence: a round executed over worker threads
//! must be **bit-identical** to the sequential path — same model parameters
//! (f32 bit patterns), same traffic bytes, same round records. This is the
//! contract that makes `run.workers` a pure performance knob.
//!
//! PR 2 extends the contract to the time-domain scheduler: with the inert
//! default `SimConfig` a run equals one with every sim knob spelled out at
//! its disabled value (the scheduler adds nothing), and with scheduling
//! *active* (deadline + dropout + over-selection + compute model) runs stay
//! bit-identical across worker counts — dropout draws come from the run
//! RNG in participant order, never from thread timing.

use fedgmf::compress::CompressorKind;
use fedgmf::coordinator::round::{FlConfig, FlRun, LrSchedule, RunSummary};
use fedgmf::coordinator::sampler::Sampler;
use fedgmf::data::dataset::Dataset;
use fedgmf::runtime::native::{BlobDataset, NativeEngine};
use fedgmf::sim::network::Network;
use fedgmf::sim::scheduler::{ProfilePreset, SelectionPolicy, SimConfig, StalenessPolicy};
use fedgmf::sparse::codec::{CodecParams, IndexCoding, ValueCoding, WireCodec};

const DIM: usize = 16;
const CLASSES: usize = 4;
const CLIENTS: usize = 8;

fn engine() -> NativeEngine {
    NativeEngine::new(DIM, 12, CLASSES, 7)
}

fn run_with_codec(
    kind: CompressorKind,
    sampler: Sampler,
    workers: usize,
    sim: SimConfig,
    codec: WireCodec,
) -> (Vec<u32>, RunSummary) {
    run_with_codec_rc(kind, sampler, workers, sim, codec, false)
}

fn run_with_codec_rc(
    kind: CompressorKind,
    sampler: Sampler,
    workers: usize,
    sim: SimConfig,
    codec: WireCodec,
    adaptive: bool,
) -> (Vec<u32>, RunSummary) {
    let mut engine = engine();
    let shards: Vec<Box<dyn Dataset + Send>> = (0..CLIENTS)
        .map(|c| {
            Box::new(BlobDataset::generate_split(60, DIM, CLASSES, 0.4, 11, 12 + c as u64))
                as Box<dyn Dataset + Send>
        })
        .collect();
    let test = BlobDataset::generate_split(64, DIM, CLASSES, 0.4, 11, 0xE).eval_batches(32);
    let mut cfg = FlConfig::new(kind, 0.1, 12);
    cfg.lr = LrSchedule::constant(0.2);
    cfg.eval_every = 4;
    cfg.sampler = sampler;
    cfg.workers = workers;
    cfg.sim = sim;
    cfg.codec = codec;
    if adaptive {
        cfg.rate_control.mode = fedgmf::compress::RateControlMode::Adaptive;
        cfg.rate_control.max_rate_boost = 2.0;
    }
    let mut run =
        FlRun::new(&engine, shards, test, Network::uniform(CLIENTS, Default::default()), cfg);
    let summary = run.run(&mut engine).unwrap();
    let param_bits: Vec<u32> = run.params.iter().map(|p| p.to_bits()).collect();
    (param_bits, summary)
}

fn run_with_sim(
    kind: CompressorKind,
    sampler: Sampler,
    workers: usize,
    sim: SimConfig,
) -> (Vec<u32>, RunSummary) {
    run_with_codec(kind, sampler, workers, sim, WireCodec::default())
}

fn run_with(kind: CompressorKind, sampler: Sampler, workers: usize) -> (Vec<u32>, RunSummary) {
    run_with_sim(kind, sampler, workers, SimConfig::default())
}

/// The varint+f16 matrix configuration (both directions).
fn varint_f16() -> WireCodec {
    let p = CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 };
    WireCodec { uplink: p, downlink: p }
}

fn assert_rounds_identical(kind: CompressorKind, sum_seq: &RunSummary, sum_par: &RunSummary) {
    assert_eq!(sum_seq.recorder.rounds.len(), sum_par.recorder.rounds.len());
    for (a, b) in sum_seq.recorder.rounds.iter().zip(&sum_par.recorder.rounds) {
        assert_eq!(a.uplink_bytes, b.uplink_bytes, "{} round {}", kind.name(), a.round);
        assert_eq!(a.downlink_bytes, b.downlink_bytes, "{} round {}", kind.name(), a.round);
        assert_eq!(a.aggregate_nnz, b.aggregate_nnz, "{} round {}", kind.name(), a.round);
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "{} round {}: train loss must be bit-identical",
            kind.name(),
            a.round
        );
        assert_eq!(
            a.mask_overlap.to_bits(),
            b.mask_overlap.to_bits(),
            "{} round {}",
            kind.name(),
            a.round
        );
        assert_eq!(a.selected, b.selected, "{} round {}", kind.name(), a.round);
        assert_eq!(a.dropped_deadline, b.dropped_deadline, "{} round {}", kind.name(), a.round);
        assert_eq!(a.dropped_offline, b.dropped_offline, "{} round {}", kind.name(), a.round);
        assert_eq!(a.carried_in, b.carried_in, "{} round {}", kind.name(), a.round);
        assert_eq!(a.carried_bytes, b.carried_bytes, "{} round {}", kind.name(), a.round);
        assert_eq!(
            a.wasted_uplink_bytes,
            b.wasted_uplink_bytes,
            "{} round {}",
            kind.name(),
            a.round
        );
        assert_eq!(a.precodec_bytes, b.precodec_bytes, "{} round {}", kind.name(), a.round);
        assert_eq!(
            a.codec_ratio.to_bits(),
            b.codec_ratio.to_bits(),
            "{} round {}",
            kind.name(),
            a.round
        );
        assert_eq!(
            a.traffic_gini.to_bits(),
            b.traffic_gini.to_bits(),
            "{} round {}",
            kind.name(),
            a.round
        );
        assert_eq!(
            a.sim_seconds.to_bits(),
            b.sim_seconds.to_bits(),
            "{} round {}: simulated time must be bit-identical",
            kind.name(),
            a.round
        );
        assert_eq!(
            a.sim_clock.to_bits(),
            b.sim_clock.to_bits(),
            "{} round {}",
            kind.name(),
            a.round
        );
        assert_eq!(
            a.test_accuracy.to_bits(),
            b.test_accuracy.to_bits(),
            "{} round {}: parallel eval must be bit-identical",
            kind.name(),
            a.round
        );
    }
    assert_eq!(sum_seq.final_accuracy, sum_par.final_accuracy, "{}", kind.name());
}

fn assert_identical(kind: CompressorKind, sampler: Sampler) {
    let (params_seq, sum_seq) = run_with(kind, sampler, 1);
    for workers in [2usize, 4] {
        let (params_par, sum_par) = run_with(kind, sampler, workers);
        assert_eq!(
            params_seq, params_par,
            "{}: params must be bit-identical at workers={workers}",
            kind.name()
        );
        assert_rounds_identical(kind, &sum_seq, &sum_par);
    }
}

#[test]
fn all_schemes_bit_identical_under_parallelism() {
    for kind in CompressorKind::ALL {
        assert_identical(kind, Sampler::Full);
    }
}

#[test]
fn partial_participation_bit_identical_under_parallelism() {
    assert_identical(CompressorKind::DgcWgmf, Sampler::Fraction(0.5));
    assert_identical(CompressorKind::DgcWgm, Sampler::Count(3));
}

#[test]
fn scheduler_off_equals_explicitly_inert_scheduler() {
    // the scheduler must add nothing when every knob sits at its disabled
    // value — guards against "active by default" regressions of the PR 1
    // behaviour, at both worker counts
    let inert = SimConfig {
        preset: ProfilePreset::Uniform,
        deadline_s: 0.0,
        dropout: 0.0,
        overselect: 1.0,
        compute_s: 0.0,
        staleness: StalenessPolicy::Drop,
        selection: SelectionPolicy::Uniform,
    };
    for workers in [1usize, 4] {
        let (pa, sa) = run_with(CompressorKind::DgcWgmf, Sampler::Full, workers);
        let (pb, sb) =
            run_with_sim(CompressorKind::DgcWgmf, Sampler::Full, workers, inert);
        assert_eq!(pa, pb, "workers={workers}");
        assert_rounds_identical(CompressorKind::DgcWgmf, &sa, &sb);
        assert_eq!(sa.dropped_deadline, 0);
        assert_eq!(sa.dropped_offline, 0);
    }
}

#[test]
fn scheduler_on_bit_identical_across_worker_counts() {
    // full straggler regime: heterogeneous profiles, compute model, tight
    // deadline, dropouts, over-selection — still a pure performance knob
    let sim = SimConfig {
        preset: ProfilePreset::Heterogeneous { slow_every: 3, slow_factor: 6.0 },
        deadline_s: 0.08,
        dropout: 0.15,
        overselect: 1.5,
        compute_s: 0.01,
        ..Default::default()
    };
    for (kind, sampler) in [
        (CompressorKind::DgcWgmf, Sampler::Fraction(0.5)),
        (CompressorKind::Dgc, Sampler::Full),
        (CompressorKind::DgcWgm, Sampler::Count(4)),
    ] {
        let (params_seq, sum_seq) = run_with_sim(kind, sampler, 1, sim);
        for workers in [2usize, 4] {
            let (params_par, sum_par) = run_with_sim(kind, sampler, workers, sim);
            assert_eq!(
                params_seq, params_par,
                "{}: scheduled run must be bit-identical at workers={workers}",
                kind.name()
            );
            assert_rounds_identical(kind, &sum_seq, &sum_par);
        }
        // the regime actually drops something, otherwise this test is vacuous
        assert!(
            sum_seq.dropped_deadline + sum_seq.dropped_offline > 0,
            "{}: straggler regime must produce drops",
            kind.name()
        );
    }
}

#[test]
fn carry_policies_bit_identical_across_worker_counts() {
    // a deadline below the link latency: every upload is late every round,
    // so the carry path is exercised on every round after the first —
    // carried counts are guaranteed nonzero, not regime-dependent
    for staleness in [StalenessPolicy::Carry, StalenessPolicy::CarryDiscounted(0.4)] {
        let sim = SimConfig {
            preset: ProfilePreset::Uniform,
            deadline_s: 1e-6,
            dropout: 0.1,
            overselect: 1.0,
            compute_s: 0.0,
            staleness,
            selection: SelectionPolicy::Uniform,
        };
        let (params_seq, sum_seq) =
            run_with_sim(CompressorKind::DgcWgmf, Sampler::Fraction(0.5), 1, sim);
        assert!(sum_seq.carried_total > 0, "{staleness:?}: regime must carry uploads");
        assert!(sum_seq.dropped_deadline > 0);
        assert_eq!(
            sum_seq.wasted_uplink_gb, 0.0,
            "{staleness:?}: carry must leave no wasted straggler bytes"
        );
        for workers in [2usize, 4] {
            let (params_par, sum_par) =
                run_with_sim(CompressorKind::DgcWgmf, Sampler::Fraction(0.5), workers, sim);
            assert_eq!(
                params_seq, params_par,
                "{staleness:?}: carried run must be bit-identical at workers={workers}"
            );
            assert_rounds_identical(CompressorKind::DgcWgmf, &sum_seq, &sum_par);
        }
    }
    // a mixed regime (some hit, some miss) through the same contract
    let mixed = SimConfig {
        preset: ProfilePreset::Heterogeneous { slow_every: 3, slow_factor: 6.0 },
        deadline_s: 0.08,
        dropout: 0.1,
        overselect: 1.5,
        compute_s: 0.01,
        staleness: StalenessPolicy::CarryDiscounted(0.7),
        selection: SelectionPolicy::Uniform,
    };
    let (ps, ss) = run_with_sim(CompressorKind::Gmc, Sampler::Count(4), 1, mixed);
    let (pp, sp) = run_with_sim(CompressorKind::Gmc, Sampler::Count(4), 4, mixed);
    assert_eq!(ps, pp);
    assert_rounds_identical(CompressorKind::Gmc, &ss, &sp);
}

#[test]
fn feasibility_selection_bit_identical_across_worker_counts() {
    let sim = SimConfig {
        preset: ProfilePreset::Heterogeneous { slow_every: 3, slow_factor: 6.0 },
        deadline_s: 0.08,
        dropout: 0.1,
        overselect: 1.25,
        compute_s: 0.01,
        staleness: StalenessPolicy::Carry,
        selection: SelectionPolicy::Feasibility { beta: 0.7 },
    };
    let (params_seq, sum_seq) =
        run_with_sim(CompressorKind::DgcWgmf, Sampler::Fraction(0.5), 1, sim);
    for workers in [2usize, 4] {
        let (params_par, sum_par) =
            run_with_sim(CompressorKind::DgcWgmf, Sampler::Fraction(0.5), workers, sim);
        assert_eq!(
            params_seq, params_par,
            "feasibility-selected run must be bit-identical at workers={workers}"
        );
        assert_rounds_identical(CompressorKind::DgcWgmf, &sum_seq, &sum_par);
    }
}

/// Digest of the run's observable outputs — the shared
/// `testkit::digest::trajectory_digest` (final parameter bits plus every
/// per-round record field the round loop promises to keep deterministic),
/// so the CI matrix and `fedgmf verify` fingerprint runs identically.
fn run_digest(workers: usize, staleness: StalenessPolicy, codec: WireCodec, adaptive: bool) -> u64 {
    let sim = SimConfig {
        preset: ProfilePreset::Heterogeneous { slow_every: 3, slow_factor: 6.0 },
        deadline_s: 0.08,
        dropout: 0.15,
        overselect: 1.5,
        compute_s: 0.01,
        staleness,
        selection: SelectionPolicy::Uniform,
    };
    let (params, sum) = run_with_codec_rc(
        CompressorKind::DgcWgmf,
        Sampler::Fraction(0.5),
        workers,
        sim,
        codec,
        adaptive,
    );
    fedgmf::testkit::digest::trajectory_digest(&params, &sum.recorder.rounds)
}

/// The CI determinism matrix entrypoint: each matrix job pins one
/// (workers, staleness, codec, rate_control) combination via
/// `FED_DET_WORKERS` / `FED_DET_STALENESS` / `FED_DET_CODEC` /
/// `FED_DET_RATE_CONTROL` and this test asserts its digest equals the
/// sequential digest for the same (staleness, codec, rate_control) triple.
/// Without the env vars (local runs) it sweeps the full matrix in-process.
#[test]
fn ci_matrix_digest() {
    let policies: Vec<(&str, StalenessPolicy)> =
        match std::env::var("FED_DET_STALENESS").ok().as_deref() {
            Some("drop") => vec![("drop", StalenessPolicy::Drop)],
            Some("carry") => vec![("carry", StalenessPolicy::Carry)],
            Some(other) => panic!("FED_DET_STALENESS must be drop|carry, got `{other}`"),
            None => vec![("drop", StalenessPolicy::Drop), ("carry", StalenessPolicy::Carry)],
        };
    let codecs: Vec<(&str, WireCodec)> = match std::env::var("FED_DET_CODEC").ok().as_deref() {
        Some("v1") => vec![("v1", WireCodec::default())],
        Some("varint_f16") => vec![("varint_f16", varint_f16())],
        Some(other) => panic!("FED_DET_CODEC must be v1|varint_f16, got `{other}`"),
        None => vec![("v1", WireCodec::default()), ("varint_f16", varint_f16())],
    };
    let rate_controls: Vec<(&str, bool)> =
        match std::env::var("FED_DET_RATE_CONTROL").ok().as_deref() {
            Some("off") => vec![("off", false)],
            Some("adaptive") => vec![("adaptive", true)],
            Some(other) => panic!("FED_DET_RATE_CONTROL must be off|adaptive, got `{other}`"),
            None => vec![("off", false), ("adaptive", true)],
        };
    let workers: Vec<usize> = match std::env::var("FED_DET_WORKERS").ok() {
        Some(w) => vec![w.parse().expect("FED_DET_WORKERS must be a worker count")],
        None => vec![1, 2, 0], // 0 = one worker per core
    };
    for (sname, policy) in &policies {
        for (cname, codec) in &codecs {
            for (rname, adaptive) in &rate_controls {
                let reference = run_digest(1, *policy, *codec, *adaptive);
                eprintln!(
                    "determinism digest[staleness={sname}, codec={cname}, \
                     rate_control={rname}, workers=1] = {reference:016x}"
                );
                // workers=1 IS the reference — re-running it would only
                // assert same-process repeatability at double the job cost
                for &w in workers.iter().filter(|&&w| w != 1) {
                    let d = run_digest(w, *policy, *codec, *adaptive);
                    eprintln!(
                        "determinism digest[staleness={sname}, codec={cname}, \
                         rate_control={rname}, workers={w}] = {d:016x}"
                    );
                    assert_eq!(
                        d, reference,
                        "digest diverged: staleness={sname} codec={cname} \
                         rate_control={rname} workers={w}"
                    );
                }
            }
        }
    }
}

#[test]
fn varint_f16_codec_bit_identical_across_worker_counts() {
    // quantised uplink + downlink: the codec's error feedback runs on
    // every client, and the run must still be a pure function of the seed
    // at any worker count
    let (params_seq, sum_seq) = run_with_codec(
        CompressorKind::DgcWgmf,
        Sampler::Full,
        1,
        SimConfig::default(),
        varint_f16(),
    );
    assert!(
        sum_seq.recorder.rounds.iter().all(|r| r.codec_ratio > 1.0),
        "the quantised run must actually shrink the wire"
    );
    for workers in [2usize, 4] {
        let (params_par, sum_par) = run_with_codec(
            CompressorKind::DgcWgmf,
            Sampler::Full,
            workers,
            SimConfig::default(),
            varint_f16(),
        );
        assert_eq!(
            params_seq, params_par,
            "varint+f16 run must be bit-identical at workers={workers}"
        );
        assert_rounds_identical(CompressorKind::DgcWgmf, &sum_seq, &sum_par);
    }
}

#[test]
fn longtail_profiles_and_budget_runs_deterministic() {
    let sim = SimConfig {
        preset: ProfilePreset::LongTail { sigma: 0.8 },
        deadline_s: 0.1,
        dropout: 0.05,
        overselect: 1.25,
        compute_s: 0.02,
        ..Default::default()
    };
    let (pa, sa) = run_with_sim(CompressorKind::Gmc, Sampler::Fraction(0.6), 1, sim);
    let (pb, sb) = run_with_sim(CompressorKind::Gmc, Sampler::Fraction(0.6), 4, sim);
    assert_eq!(pa, pb);
    assert_rounds_identical(CompressorKind::Gmc, &sa, &sb);
}

#[test]
fn large_model_crosses_parallel_thresholds_bit_identical() {
    // The small cases above stay under the work gates and take the
    // sequential fallbacks inside the parallel machinery. This model is
    // sized so both gated paths actually execute at workers > 1:
    //   observe fan-out:  P × clients = 8828 × 8 = 70 624 ≥ 2^15
    //   sharded merge:    round nnz   = 4414 × 8 = 35 312 ≥ 2^15
    // DGCwGMF so observe_broadcast does real O(P) momentum work.
    let run = |workers: usize| {
        let mut engine = NativeEngine::new(96, 84, 8, 3); // P = 8828
        let shards: Vec<Box<dyn Dataset + Send>> = (0..CLIENTS)
            .map(|c| {
                Box::new(BlobDataset::generate_split(48, 96, 8, 0.4, 21, 22 + c as u64))
                    as Box<dyn Dataset + Send>
            })
            .collect();
        let mut cfg = FlConfig::new(CompressorKind::DgcWgmf, 0.5, 3);
        cfg.lr = LrSchedule::constant(0.1);
        cfg.batch_size = 16;
        cfg.workers = workers;
        let mut run = FlRun::new(
            &engine,
            shards,
            Vec::new(),
            Network::uniform(CLIENTS, Default::default()),
            cfg,
        );
        let summary = run.run(&mut engine).unwrap();
        let bits: Vec<u32> = run.params.iter().map(|p| p.to_bits()).collect();
        (bits, summary)
    };
    let (params_seq, sum_seq) = run(1);
    let (params_par, sum_par) = run(4);
    assert_eq!(params_seq, params_par, "params must be bit-identical across the sharded paths");
    for (a, b) in sum_seq.recorder.rounds.iter().zip(&sum_par.recorder.rounds) {
        assert_eq!(a.uplink_bytes, b.uplink_bytes, "round {}", a.round);
        assert_eq!(a.downlink_bytes, b.downlink_bytes, "round {}", a.round);
        assert_eq!(a.aggregate_nnz, b.aggregate_nnz, "round {}", a.round);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {}", a.round);
        assert_eq!(a.mask_overlap.to_bits(), b.mask_overlap.to_bits(), "round {}", a.round);
    }
}
