//! Parallel ⇔ sequential equivalence: a round executed over worker threads
//! must be **bit-identical** to the sequential path — same model parameters
//! (f32 bit patterns), same traffic bytes, same round records. This is the
//! contract that makes `run.workers` a pure performance knob.

use fedgmf::compress::CompressorKind;
use fedgmf::coordinator::round::{FlConfig, FlRun, LrSchedule, RunSummary};
use fedgmf::coordinator::sampler::Sampler;
use fedgmf::data::dataset::Dataset;
use fedgmf::runtime::native::{BlobDataset, NativeEngine};
use fedgmf::sim::network::Network;

const DIM: usize = 16;
const CLASSES: usize = 4;
const CLIENTS: usize = 8;

fn engine() -> NativeEngine {
    NativeEngine::new(DIM, 12, CLASSES, 7)
}

fn run_with(kind: CompressorKind, sampler: Sampler, workers: usize) -> (Vec<u32>, RunSummary) {
    let mut engine = engine();
    let shards: Vec<Box<dyn Dataset + Send>> = (0..CLIENTS)
        .map(|c| {
            Box::new(BlobDataset::generate_split(60, DIM, CLASSES, 0.4, 11, 12 + c as u64))
                as Box<dyn Dataset + Send>
        })
        .collect();
    let test = BlobDataset::generate_split(64, DIM, CLASSES, 0.4, 11, 0xE).eval_batches(32);
    let mut cfg = FlConfig::new(kind, 0.1, 12);
    cfg.lr = LrSchedule::constant(0.2);
    cfg.eval_every = 4;
    cfg.sampler = sampler;
    cfg.workers = workers;
    let mut run =
        FlRun::new(&engine, shards, test, Network::uniform(CLIENTS, Default::default()), cfg);
    let summary = run.run(&mut engine).unwrap();
    let param_bits: Vec<u32> = run.params.iter().map(|p| p.to_bits()).collect();
    (param_bits, summary)
}

fn assert_identical(kind: CompressorKind, sampler: Sampler) {
    let (params_seq, sum_seq) = run_with(kind, sampler, 1);
    for workers in [2usize, 4] {
        let (params_par, sum_par) = run_with(kind, sampler, workers);
        assert_eq!(
            params_seq, params_par,
            "{}: params must be bit-identical at workers={workers}",
            kind.name()
        );
        assert_eq!(sum_seq.recorder.rounds.len(), sum_par.recorder.rounds.len());
        for (a, b) in sum_seq.recorder.rounds.iter().zip(&sum_par.recorder.rounds) {
            assert_eq!(a.uplink_bytes, b.uplink_bytes, "{} round {}", kind.name(), a.round);
            assert_eq!(a.downlink_bytes, b.downlink_bytes, "{} round {}", kind.name(), a.round);
            assert_eq!(a.aggregate_nnz, b.aggregate_nnz, "{} round {}", kind.name(), a.round);
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{} round {}: train loss must be bit-identical",
                kind.name(),
                a.round
            );
            assert_eq!(
                a.mask_overlap.to_bits(),
                b.mask_overlap.to_bits(),
                "{} round {}",
                kind.name(),
                a.round
            );
        }
        assert_eq!(sum_seq.final_accuracy, sum_par.final_accuracy, "{}", kind.name());
    }
}

#[test]
fn all_schemes_bit_identical_under_parallelism() {
    for kind in CompressorKind::ALL {
        assert_identical(kind, Sampler::Full);
    }
}

#[test]
fn partial_participation_bit_identical_under_parallelism() {
    assert_identical(CompressorKind::DgcWgmf, Sampler::Fraction(0.5));
    assert_identical(CompressorKind::DgcWgm, Sampler::Count(3));
}

#[test]
fn large_model_crosses_parallel_thresholds_bit_identical() {
    // The small cases above stay under the work gates and take the
    // sequential fallbacks inside the parallel machinery. This model is
    // sized so both gated paths actually execute at workers > 1:
    //   observe fan-out:  P × clients = 8828 × 8 = 70 624 ≥ 2^15
    //   sharded merge:    round nnz   = 4414 × 8 = 35 312 ≥ 2^15
    // DGCwGMF so observe_broadcast does real O(P) momentum work.
    let run = |workers: usize| {
        let mut engine = NativeEngine::new(96, 84, 8, 3); // P = 8828
        let shards: Vec<Box<dyn Dataset + Send>> = (0..CLIENTS)
            .map(|c| {
                Box::new(BlobDataset::generate_split(48, 96, 8, 0.4, 21, 22 + c as u64))
                    as Box<dyn Dataset + Send>
            })
            .collect();
        let mut cfg = FlConfig::new(CompressorKind::DgcWgmf, 0.5, 3);
        cfg.lr = LrSchedule::constant(0.1);
        cfg.batch_size = 16;
        cfg.workers = workers;
        let mut run = FlRun::new(
            &engine,
            shards,
            Vec::new(),
            Network::uniform(CLIENTS, Default::default()),
            cfg,
        );
        let summary = run.run(&mut engine).unwrap();
        let bits: Vec<u32> = run.params.iter().map(|p| p.to_bits()).collect();
        (bits, summary)
    };
    let (params_seq, sum_seq) = run(1);
    let (params_par, sum_par) = run(4);
    assert_eq!(params_seq, params_par, "params must be bit-identical across the sharded paths");
    for (a, b) in sum_seq.recorder.rounds.iter().zip(&sum_par.recorder.rounds) {
        assert_eq!(a.uplink_bytes, b.uplink_bytes, "round {}", a.round);
        assert_eq!(a.downlink_bytes, b.downlink_bytes, "round {}", a.round);
        assert_eq!(a.aggregate_nnz, b.aggregate_nnz, "round {}", a.round);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {}", a.round);
        assert_eq!(a.mask_overlap.to_bits(), b.mask_overlap.to_bits(), "round {}", a.round);
    }
}
