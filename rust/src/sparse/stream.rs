//! Streaming pull-decoder over wire buffers — (index, value) runs without
//! an intermediate `SparseVec`.
//!
//! The server-side ingest path historically decoded every upload into a
//! per-client `SparseVec` (O(nnz) per client, O(rate · dim) at the
//! steady-state top-k shape) before folding it into the [`Aggregator`].
//! [`Runs`] removes that materialization: it validates a complete wire
//! buffer (v1 *and* v2, every container and coding) up front, then emits
//! the (index, value) pairs directly to a fold callback. Ingest memory per
//! upload is a fixed few dozen bytes of cursor state, independent of model
//! dimension.
//!
//! ## Contract
//!
//! * **Validation is exhaustive and up-front.** [`Runs::validate`] performs
//!   exactly the checks `wire::decode_into` performs, in the same order,
//!   returning the same [`WireError`] for any malformed buffer (the
//!   proptests in `tests/proptests.rs` assert decode/validate verdict
//!   agreement on adversarially corrupted buffers). Only a fully vetted
//!   buffer yields a `Runs` value.
//! * **Partial-fold atomicity.** Because every structural check (lengths,
//!   index bounds, sortedness, varint shape, bitmap tail bits) happens
//!   before the first run is emitted, a truncated or corrupt buffer can
//!   never leave a consumer half-folded: `Aggregator::fold_stream` over a
//!   `Runs` cannot fail, and a buffer that would fail mid-stream never
//!   becomes a `Runs` at all.
//! * **Bit-identical emit order.** [`Runs::for_each`] emits exactly the
//!   (index, value) pairs `decode_into` would have produced, in the same
//!   order, computed by the same expressions — so folding runs is
//!   bit-identical to decoding and folding the vector (sparse/bitmap
//!   containers keep explicit zero-valued entries; dense containers drop
//!   exact zeros, like the decoders).
//! * **Blocked emission.** [`Runs::for_each_block`] emits the same runs in
//!   batches of up to [`EMIT_BLOCK`] coordinates, decoding whole index and
//!   value blocks through the dispatched kernels in `sparse::simd`. The
//!   per-element values are bit-identical to [`Runs::for_each`] in the same
//!   order; `EMIT_BLOCK == Q8_BLOCK`, so a q8 value block never straddles a
//!   scale prefix.
//!
//! ## Chunked `Reader` source
//!
//! Wire buffers arrive from the transport as length-prefixed frames;
//! [`read_payload`] drains an `io::Read` (however fragmented — the
//! proptests deliver one byte per read call) into a reusable scratch
//! buffer in fixed-size chunks, after which [`Runs::validate`] takes over.
//! The fold itself never allocates a decoded vector.
//!
//! [`Aggregator`]: super::merge::Aggregator

use super::codec::{
    self, IndexCoding, ValueCoding, CONTAINER_BITMAP, CONTAINER_DENSE, CONTAINER_SPARSE, KIND_V2,
    Q8_BLOCK, V2_HEADER_BYTES,
};
use super::simd;
use super::wire::{WireError, HEADER_BYTES, MAGIC};

/// Emission block size for [`Runs::for_each_block`] — kept equal to
/// [`Q8_BLOCK`] so a blocked value decode never straddles a q8 scale
/// prefix.
pub const EMIT_BLOCK: usize = Q8_BLOCK;

/// Internal layout descriptor recorded by validation: where each stream
/// lives and how it is coded, so the emit pass is a straight walk.
#[derive(Clone, Copy)]
enum Layout {
    /// v1 kind 0: raw u32 indices at 13, f32 values at `13 + 4·nnz`.
    V1Sparse { nnz: usize },
    /// v1 kind 1: `dim` f32 values at 9; zeros dropped on emit.
    V1Dense,
    /// v2 sparse container: index stream at 16, value stream at `val_off`.
    V2Sparse { nnz: usize, index: IndexCoding, value: ValueCoding, val_off: usize },
    /// v2 bitmap container: `ceil(dim/8)` presence bytes at 12, then values.
    V2Bitmap { value: ValueCoding },
    /// v2 dense container: `dim` coded values at 12; zeros dropped on emit.
    V2Dense { value: ValueCoding },
}

/// A fully validated wire buffer, ready to emit its (index, value) runs.
/// Construction is only through [`Runs::validate`]; see the module docs for
/// the contract.
#[derive(Clone, Copy)]
pub struct Runs<'a> {
    buf: &'a [u8],
    dim: u32,
    layout: Layout,
}

impl<'a> Runs<'a> {
    /// Validate a complete wire buffer (either version, any container or
    /// coding) without allocating or emitting anything. Returns the same
    /// [`WireError`] `wire::decode_into` would return for the same buffer.
    pub fn validate(buf: &'a [u8]) -> Result<Runs<'a>, WireError> {
        if buf.len() < HEADER_BYTES {
            return Err(WireError::Truncated(buf.len()));
        }
        let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        let kind = buf[4];
        if kind == KIND_V2 {
            return Self::validate_v2(buf);
        }
        let dim = u32::from_le_bytes(buf[5..9].try_into().unwrap());
        match kind {
            1 => {
                let body_len = 4 * dim as usize;
                if buf.get(HEADER_BYTES..HEADER_BYTES + body_len).is_none() {
                    return Err(WireError::Truncated(buf.len()));
                }
                Ok(Runs { buf, dim, layout: Layout::V1Dense })
            }
            0 => {
                let Some(nnz_bytes) = buf.get(HEADER_BYTES..HEADER_BYTES + 4) else {
                    return Err(WireError::Truncated(buf.len()));
                };
                let nnz = u32::from_le_bytes(nnz_bytes.try_into().unwrap()) as usize;
                let idx_off = HEADER_BYTES + 4;
                let val_off = idx_off + 4 * nnz;
                if buf.len() < val_off + 4 * nnz {
                    return Err(WireError::Truncated(buf.len()));
                }
                let mut last: i64 = -1;
                for c in buf[idx_off..val_off].chunks_exact(4) {
                    let i = u32::from_le_bytes(c.try_into().unwrap());
                    if i >= dim {
                        return Err(WireError::IndexOutOfBounds { idx: i, dim });
                    }
                    if (i as i64) <= last {
                        return Err(WireError::Unsorted);
                    }
                    last = i as i64;
                }
                Ok(Runs { buf, dim, layout: Layout::V1Sparse { nnz } })
            }
            k => Err(WireError::BadKind(k)),
        }
    }

    fn validate_v2(buf: &'a [u8]) -> Result<Runs<'a>, WireError> {
        if buf.len() < V2_HEADER_BYTES {
            return Err(WireError::Truncated(buf.len()));
        }
        let container = buf[5];
        let index = IndexCoding::from_byte(buf[6])?;
        let value = ValueCoding::from_byte(buf[7])?;
        let dim = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        let mut pos = V2_HEADER_BYTES;
        match container {
            CONTAINER_SPARSE => {
                let Some(nnz_bytes) = buf.get(pos..pos + 4) else {
                    return Err(WireError::Truncated(buf.len()));
                };
                let nnz = u32::from_le_bytes(nnz_bytes.try_into().unwrap()) as usize;
                pos += 4;
                let idx_min = match index {
                    IndexCoding::Raw => 4 * nnz,
                    IndexCoding::Varint => nnz,
                };
                let vb = codec::value_stream_bytes(value, nnz);
                if buf.len() < pos + idx_min + vb {
                    return Err(WireError::Truncated(buf.len()));
                }
                match index {
                    IndexCoding::Raw => {
                        let end = pos + 4 * nnz;
                        let mut last: i64 = -1;
                        for c in buf[pos..end].chunks_exact(4) {
                            let i = u32::from_le_bytes(c.try_into().unwrap());
                            if i >= dim {
                                return Err(WireError::IndexOutOfBounds { idx: i, dim });
                            }
                            if (i as i64) <= last {
                                return Err(WireError::Unsorted);
                            }
                            last = i as i64;
                        }
                        pos = end;
                    }
                    IndexCoding::Varint => {
                        codec::walk_varint_indices(buf, &mut pos, nnz, dim, |_| {})?;
                        if buf.len() < pos + vb {
                            return Err(WireError::Truncated(buf.len()));
                        }
                    }
                }
                let layout = Layout::V2Sparse { nnz, index, value, val_off: pos };
                Ok(Runs { buf, dim, layout })
            }
            CONTAINER_BITMAP => {
                let bm_len = (dim as usize).div_ceil(8);
                let Some(bm) = buf.get(pos..pos + bm_len) else {
                    return Err(WireError::Truncated(buf.len()));
                };
                if dim % 8 != 0 {
                    let mask = 0xFFu8 << (dim % 8); // bits at positions ≥ dim
                    if bm[bm_len - 1] & mask != 0 {
                        return Err(WireError::BadBitmap);
                    }
                }
                let nnz: usize = bm.iter().map(|b| b.count_ones() as usize).sum();
                let vb = codec::value_stream_bytes(value, nnz);
                if buf.len() < pos + bm_len + vb {
                    return Err(WireError::Truncated(buf.len()));
                }
                Ok(Runs { buf, dim, layout: Layout::V2Bitmap { value } })
            }
            CONTAINER_DENSE => {
                let need = codec::value_stream_bytes(value, dim as usize);
                if buf.get(pos..pos + need).is_none() {
                    return Err(WireError::Truncated(buf.len()));
                }
                Ok(Runs { buf, dim, layout: Layout::V2Dense { value } })
            }
            c => Err(WireError::BadContainer(c)),
        }
    }

    /// Model dimension declared by the buffer's header.
    pub fn dim(&self) -> usize {
        self.dim as usize
    }

    /// Emit every (index, value) run in ascending-coordinate order —
    /// exactly the pairs `wire::decode_into` would have produced, computed
    /// by the same expressions. Infallible: validation already vetted the
    /// whole buffer.
    pub fn for_each(&self, mut f: impl FnMut(u32, f32)) {
        match self.layout {
            Layout::V1Sparse { nnz } => {
                let idx_off = HEADER_BYTES + 4;
                let val_off = idx_off + 4 * nnz;
                let idx = buf_u32s(&self.buf[idx_off..val_off]);
                let val = &self.buf[val_off..val_off + 4 * nnz];
                for (i, c) in idx.zip(val.chunks_exact(4)) {
                    f(i, f32::from_le_bytes(c.try_into().unwrap()));
                }
            }
            Layout::V1Dense => {
                let body = &self.buf[HEADER_BYTES..HEADER_BYTES + 4 * self.dim as usize];
                for (i, c) in body.chunks_exact(4).enumerate() {
                    let v = f32::from_le_bytes(c.try_into().unwrap());
                    if v != 0.0 {
                        f(i as u32, v);
                    }
                }
            }
            Layout::V2Sparse { nnz, index, value, val_off } => {
                let mut vals = ValueCursor::new(&self.buf[val_off..], value);
                match index {
                    IndexCoding::Raw => {
                        let idx_off = V2_HEADER_BYTES + 4;
                        for i in buf_u32s(&self.buf[idx_off..idx_off + 4 * nnz]) {
                            f(i, vals.next());
                        }
                    }
                    IndexCoding::Varint => {
                        let mut pos = V2_HEADER_BYTES + 4;
                        let mut acc = 0u64;
                        for slot in 0..nnz {
                            // the index stream was fully validated; a
                            // malformed varint here is unreachable
                            let gap = codec::read_varint(self.buf, &mut pos)
                                .expect("validated varint stream") as u64;
                            if slot == 0 {
                                acc = gap;
                            } else {
                                acc += gap;
                            }
                            f(acc as u32, vals.next());
                        }
                    }
                }
            }
            Layout::V2Bitmap { value } => {
                let bm_len = (self.dim as usize).div_ceil(8);
                let bm = &self.buf[V2_HEADER_BYTES..V2_HEADER_BYTES + bm_len];
                let mut vals = ValueCursor::new(&self.buf[V2_HEADER_BYTES + bm_len..], value);
                for (byte_i, &b) in bm.iter().enumerate() {
                    let mut bits = b;
                    while bits != 0 {
                        let bit = bits.trailing_zeros() as usize;
                        f((byte_i * 8 + bit) as u32, vals.next());
                        bits &= bits - 1;
                    }
                }
            }
            Layout::V2Dense { value } => {
                let n = self.dim as usize;
                let body = &self.buf[V2_HEADER_BYTES..];
                match value {
                    ValueCoding::F32 => {
                        for (i, c) in body.chunks_exact(4).take(n).enumerate() {
                            let v = f32::from_le_bytes(c.try_into().unwrap());
                            if v != 0.0 {
                                f(i as u32, v);
                            }
                        }
                    }
                    ValueCoding::F16 => {
                        for (i, c) in body.chunks_exact(2).take(n).enumerate() {
                            let v =
                                codec::f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap()));
                            if v != 0.0 {
                                f(i as u32, v);
                            }
                        }
                    }
                    ValueCoding::Q8 => {
                        // mirror the decoder exactly: the keep test is on
                        // the quantised byte and the block scale, not the
                        // product (an adversarial NaN scale must behave
                        // identically on both paths)
                        let mut off = 0usize;
                        let mut idx = 0usize;
                        while idx < n {
                            let take = (n - idx).min(Q8_BLOCK);
                            let scale =
                                f32::from_le_bytes(body[off..off + 4].try_into().unwrap());
                            off += 4;
                            for (j, &b) in body[off..off + take].iter().enumerate() {
                                let q = b as i8;
                                if q != 0 && scale != 0.0 {
                                    f((idx + j) as u32, q as f32 * scale);
                                }
                            }
                            off += take;
                            idx += take;
                        }
                    }
                }
            }
        }
    }

    /// Emit the same runs as [`for_each`](Runs::for_each), but in blocks of
    /// up to [`EMIT_BLOCK`] coordinates: `f(indices, values)` with both
    /// slices the same length, concatenating to exactly the scalar emit
    /// stream. Sparse containers decode whole index and value blocks
    /// through the dispatched kernels (`sparse::simd`); values are
    /// bit-identical to the scalar cursor's, element for element.
    pub fn for_each_block(&self, mut f: impl FnMut(&[u32], &[f32])) {
        let mut ids = [0u32; EMIT_BLOCK];
        let mut vals = [0f32; EMIT_BLOCK];
        match self.layout {
            Layout::V1Sparse { nnz } => {
                let idx_off = HEADER_BYTES + 4;
                let val_off = idx_off + 4 * nnz;
                let mut done = 0usize;
                while done < nnz {
                    let take = (nnz - done).min(EMIT_BLOCK);
                    let ib = idx_off + 4 * done;
                    for (slot, c) in ids.iter_mut().zip(self.buf[ib..ib + 4 * take].chunks_exact(4))
                    {
                        *slot = u32::from_le_bytes(c.try_into().unwrap());
                    }
                    let vb = val_off + 4 * done;
                    decode_f32_block(&self.buf[vb..vb + 4 * take], &mut vals[..take]);
                    f(&ids[..take], &vals[..take]);
                    done += take;
                }
            }
            Layout::V2Sparse { nnz, index, value, val_off } => {
                let mut pos = V2_HEADER_BYTES + 4; // index-stream cursor
                let mut vpos = val_off;
                let mut done = 0usize;
                let mut acc = 0u32;
                while done < nnz {
                    let take = (nnz - done).min(EMIT_BLOCK);
                    match index {
                        IndexCoding::Raw => {
                            for (slot, c) in
                                ids.iter_mut().zip(self.buf[pos..pos + 4 * take].chunks_exact(4))
                            {
                                *slot = u32::from_le_bytes(c.try_into().unwrap());
                            }
                            pos += 4 * take;
                        }
                        IndexCoding::Varint => {
                            // the index stream was fully validated; a short
                            // or malformed decode here is unreachable
                            let (got, err) =
                                simd::varint_decode_gaps(self.buf, &mut pos, &mut ids[..take]);
                            debug_assert_eq!(got, take, "validated varint stream");
                            debug_assert!(err.is_none(), "validated varint stream");
                            // in-place gap → absolute index prefix sum
                            for (t, slot) in ids[..take].iter_mut().enumerate() {
                                if done + t == 0 {
                                    acc = *slot;
                                } else {
                                    acc += *slot;
                                }
                                *slot = acc;
                            }
                        }
                    }
                    vpos = decode_value_block(self.buf, vpos, value, &mut vals[..take]);
                    f(&ids[..take], &vals[..take]);
                    done += take;
                }
            }
            // dense and bitmap layouts gain nothing from block decode (runs
            // are filtered / bit-scattered) — batch the scalar walk instead
            _ => {
                let mut n = 0usize;
                self.for_each(|i, v| {
                    ids[n] = i;
                    vals[n] = v;
                    n += 1;
                    if n == EMIT_BLOCK {
                        f(&ids, &vals);
                        n = 0;
                    }
                });
                if n > 0 {
                    f(&ids[..n], &vals[..n]);
                }
            }
        }
    }
}

/// Decode one value block (`out.len() ≤ EMIT_BLOCK` values) starting at
/// byte `pos`, returning the position just past the consumed bytes. Q8
/// reads the block's scale prefix first — callers step in `EMIT_BLOCK`
/// units, so the prefix is always aligned with the encoder's blocks.
fn decode_value_block(buf: &[u8], pos: usize, coding: ValueCoding, out: &mut [f32]) -> usize {
    let n = out.len();
    match coding {
        ValueCoding::F32 => {
            decode_f32_block(&buf[pos..pos + 4 * n], out);
            pos + 4 * n
        }
        ValueCoding::F16 => {
            simd::f16_decode(&buf[pos..pos + 2 * n], out);
            pos + 2 * n
        }
        ValueCoding::Q8 => {
            let scale = f32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
            simd::q8_dequantize(&buf[pos + 4..pos + 4 + n], scale, out);
            pos + 4 + n
        }
    }
}

/// Little-endian f32 block load (exact — byte reinterpretation only, so the
/// scalar loop is already the bit-identical fast path).
fn decode_f32_block(bytes: &[u8], out: &mut [f32]) {
    for (c, slot) in bytes.chunks_exact(4).zip(out.iter_mut()) {
        *slot = f32::from_le_bytes(c.try_into().unwrap());
    }
}

/// Little-endian u32 iterator over a validated 4-byte-aligned slice.
fn buf_u32s(body: &[u8]) -> impl Iterator<Item = u32> + '_ {
    body.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap()))
}

/// Sequential reader over a validated value stream — one `next()` per
/// emitted run, computing exactly the decoder's value expressions
/// (`f32::from_le_bytes`, `f16_bits_to_f32`, `(b as i8) as f32 * scale`).
struct ValueCursor<'a> {
    body: &'a [u8],
    pos: usize,
    coding: ValueCoding,
    /// q8: values left in the current block before the next scale prefix
    block_left: usize,
    scale: f32,
}

impl<'a> ValueCursor<'a> {
    fn new(body: &'a [u8], coding: ValueCoding) -> ValueCursor<'a> {
        ValueCursor { body, pos: 0, coding, block_left: 0, scale: 0.0 }
    }

    #[inline]
    fn next(&mut self) -> f32 {
        match self.coding {
            ValueCoding::F32 => {
                let v = f32::from_le_bytes(self.body[self.pos..self.pos + 4].try_into().unwrap());
                self.pos += 4;
                v
            }
            ValueCoding::F16 => {
                let h = u16::from_le_bytes(self.body[self.pos..self.pos + 2].try_into().unwrap());
                self.pos += 2;
                codec::f16_bits_to_f32(h)
            }
            ValueCoding::Q8 => {
                if self.block_left == 0 {
                    self.scale = f32::from_le_bytes(
                        self.body[self.pos..self.pos + 4].try_into().unwrap(),
                    );
                    self.pos += 4;
                    self.block_left = Q8_BLOCK;
                }
                let b = self.body[self.pos];
                self.pos += 1;
                self.block_left -= 1;
                (b as i8) as f32 * self.scale
            }
        }
    }
}

/// Chunked `Reader` source: drain `r` to end-of-stream into `scratch`
/// (cleared, capacity kept across calls) reading fixed-size chunks, so an
/// upload payload delivered incrementally — one frame, one fragment, or one
/// byte at a time — lands in a single reusable buffer ready for
/// [`Runs::validate`]. Returns the payload length.
pub fn read_payload<R: std::io::Read>(r: &mut R, scratch: &mut Vec<u8>) -> std::io::Result<usize> {
    scratch.clear();
    let mut chunk = [0u8; 4096];
    loop {
        match r.read(&mut chunk) {
            Ok(0) => return Ok(scratch.len()),
            Ok(n) => scratch.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::codec::CodecParams;
    use crate::sparse::vector::SparseVec;
    use crate::sparse::wire;
    use crate::util::rng::Rng;

    fn collect(runs: &Runs<'_>) -> SparseVec {
        let mut out = SparseVec::empty(runs.dim());
        runs.for_each(|i, v| {
            out.indices.push(i);
            out.values.push(v);
        });
        out
    }

    fn rand_support(rng: &mut Rng, dim: usize, nnz: usize) -> SparseVec {
        let mut ids: Vec<u32> = (0..dim as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(nnz);
        ids.sort_unstable();
        let values: Vec<f32> = ids.iter().map(|_| rng.normal()).collect();
        SparseVec::from_sorted(dim, ids, values)
    }

    #[test]
    fn runs_match_decode_across_every_mode_and_density() {
        let mut rng = Rng::new(23);
        let mut buf = Vec::new();
        let mut back = SparseVec::empty(0);
        for &dim in &[1usize, 8, 100, 1000, 4096] {
            for &frac in &[0.0f64, 0.05, 0.3, 0.8, 1.0] {
                let nnz = ((dim as f64 * frac) as usize).min(dim);
                let sv = rand_support(&mut rng, dim, nnz);
                for index in [IndexCoding::Raw, IndexCoding::Varint] {
                    for value in [ValueCoding::F32, ValueCoding::F16, ValueCoding::Q8] {
                        let p = CodecParams { index, value };
                        wire::encode_with(&sv, &mut buf, p);
                        wire::decode_into(&buf, &mut back).unwrap();
                        let runs = Runs::validate(&buf).unwrap();
                        let got = collect(&runs);
                        assert_eq!(got.dim, back.dim, "{p:?} dim {dim} frac {frac}");
                        assert_eq!(got.indices, back.indices, "{p:?} dim {dim} frac {frac}");
                        let a: Vec<u32> = got.values.iter().map(|v| v.to_bits()).collect();
                        let b: Vec<u32> = back.values.iter().map(|v| v.to_bits()).collect();
                        assert_eq!(a, b, "{p:?} dim {dim} frac {frac}: values must be bit-equal");
                    }
                }
            }
        }
    }

    #[test]
    fn for_each_block_concatenates_to_for_each() {
        let mut rng = Rng::new(31);
        let mut buf = Vec::new();
        // densities straddling the container crossovers, plus block-edge
        // nnz (…, EMIT_BLOCK − 1, EMIT_BLOCK, EMIT_BLOCK + 1, …)
        for &dim in &[1usize, 8, 255, 256, 257, 1000, 4096] {
            for &frac in &[0.0f64, 0.05, 0.3, 0.8, 1.0] {
                let nnz = ((dim as f64 * frac) as usize).min(dim);
                let sv = rand_support(&mut rng, dim, nnz);
                for index in [IndexCoding::Raw, IndexCoding::Varint] {
                    for value in [ValueCoding::F32, ValueCoding::F16, ValueCoding::Q8] {
                        let p = CodecParams { index, value };
                        wire::encode_with(&sv, &mut buf, p);
                        let runs = Runs::validate(&buf).unwrap();
                        let mut scalar_ids = Vec::new();
                        let mut scalar_vals = Vec::new();
                        runs.for_each(|i, v| {
                            scalar_ids.push(i);
                            scalar_vals.push(v.to_bits());
                        });
                        let mut block_ids = Vec::new();
                        let mut block_vals = Vec::new();
                        runs.for_each_block(|ids, vals| {
                            assert_eq!(ids.len(), vals.len());
                            assert!(ids.len() <= EMIT_BLOCK);
                            block_ids.extend_from_slice(ids);
                            block_vals.extend(vals.iter().map(|v| v.to_bits()));
                        });
                        assert_eq!(block_ids, scalar_ids, "{p:?} dim {dim} frac {frac}");
                        assert_eq!(block_vals, scalar_vals, "{p:?} dim {dim} frac {frac}");
                    }
                }
            }
        }
    }

    #[test]
    fn for_each_block_handles_all_zero_q8_blocks() {
        // an all-zero q8 block ships scale = 0 and zero bytes; the blocked
        // decode must reproduce the explicit zero entries (support kept).
        // dim far above the bitmap crossover so the sparse container wins.
        let dim = 64 * Q8_BLOCK;
        let nnz = 2 * Q8_BLOCK + 7;
        let ids: Vec<u32> = (0..nnz as u32).collect();
        let mut values = vec![0.0f32; nnz];
        // second block non-zero, first and third all-zero
        for (v, slot) in values[Q8_BLOCK..2 * Q8_BLOCK].iter_mut().enumerate() {
            *slot = (v as f32) - 100.0;
        }
        let sv = SparseVec::from_sorted(dim, ids, values);
        let mut buf = Vec::new();
        let p = CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 };
        wire::encode_with(&sv, &mut buf, p);
        assert_eq!(buf[5], CONTAINER_SPARSE, "test must exercise the sparse blocked path");
        let runs = Runs::validate(&buf).unwrap();
        let mut got = Vec::new();
        runs.for_each_block(|ids, vals| {
            got.extend(ids.iter().zip(vals).map(|(&i, &v)| (i, v.to_bits())));
        });
        let mut want = Vec::new();
        runs.for_each(|i, v| want.push((i, v.to_bits())));
        assert_eq!(got, want);
        assert_eq!(got.len(), nnz, "explicit zero entries keep the support");
    }

    #[test]
    fn validate_rejects_every_strict_prefix() {
        let mut rng = Rng::new(29);
        let sv = rand_support(&mut rng, 200, 40);
        for (index, value) in [
            (IndexCoding::Raw, ValueCoding::F32),
            (IndexCoding::Varint, ValueCoding::F16),
            (IndexCoding::Varint, ValueCoding::Q8),
        ] {
            let mut buf = Vec::new();
            wire::encode_with(&sv, &mut buf, CodecParams { index, value });
            for cut in 0..buf.len() {
                assert!(Runs::validate(&buf[..cut]).is_err(), "{index:?} {value:?} cut {cut}");
            }
            assert!(Runs::validate(&buf).is_ok());
        }
    }

    #[test]
    fn reader_source_survives_one_byte_fragmentation() {
        let sv = SparseVec::new(64, vec![(3, 1.5), (40, -2.0), (63, 0.25)]);
        let buf = wire::encode(&sv);
        struct OneByte<'a>(&'a [u8], usize);
        impl std::io::Read for OneByte<'_> {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if out.is_empty() || self.1 >= self.0.len() {
                    return Ok(0);
                }
                out[0] = self.0[self.1];
                self.1 += 1;
                Ok(1)
            }
        }
        let mut scratch = Vec::new();
        let n = read_payload(&mut OneByte(&buf, 0), &mut scratch).unwrap();
        assert_eq!(n, buf.len());
        assert_eq!(scratch, buf);
        let runs = Runs::validate(&scratch).unwrap();
        assert_eq!(collect(&runs), sv);
    }
}
