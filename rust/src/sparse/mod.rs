//! Sparse gradient substrate: COO vectors, top-k selection, aggregation,
//! wire formats (v1 + codec v2) with exact byte accounting.
pub mod codec;
pub mod merge;
pub mod simd;
pub mod stream;
pub mod topk;
pub mod vector;
pub mod wire;

pub use codec::{CodecParams, IndexCoding, ValueCoding, WireCodec};
pub use merge::Aggregator;
pub use simd::KernelMode;
pub use vector::SparseVec;
