//! Sparse gradient substrate: COO vectors, top-k selection, aggregation,
//! wire format with exact byte accounting.
pub mod merge;
pub mod topk;
pub mod vector;
pub mod wire;

pub use merge::Aggregator;
pub use vector::SparseVec;
