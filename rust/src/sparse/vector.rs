//! Sparse gradient vector (COO, sorted unique indices).
//!
//! The unit of communication in the whole framework: clients upload sparse
//! compressed gradients, the server broadcasts a sparse (or dense-fallback)
//! aggregate. Invariants, enforced in debug builds and by proptests:
//!   * indices strictly increasing (sorted, unique)
//!   * indices < dim
//!   * values.len() == indices.len()

/// COO sparse vector over a dense space of `dim` f32 coordinates.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseVec {
    pub dim: usize,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseVec {
    pub fn empty(dim: usize) -> Self {
        SparseVec { dim, indices: Vec::new(), values: Vec::new() }
    }

    /// Build from parallel index/value arrays. Sorts by index and asserts
    /// uniqueness; use [`SparseVec::from_sorted`] on pre-sorted input.
    pub fn new(dim: usize, mut pairs: Vec<(u32, f32)>) -> Self {
        pairs.sort_unstable_by_key(|&(i, _)| i);
        let indices: Vec<u32> = pairs.iter().map(|&(i, _)| i).collect();
        let values: Vec<f32> = pairs.iter().map(|&(_, v)| v).collect();
        let sv = SparseVec { dim, indices, values };
        sv.debug_check();
        sv
    }

    /// Build from already-sorted unique indices (hot path, no sort).
    pub fn from_sorted(dim: usize, indices: Vec<u32>, values: Vec<f32>) -> Self {
        let sv = SparseVec { dim, indices, values };
        sv.debug_check();
        sv
    }

    /// Extract nonzeros of a dense vector.
    pub fn from_dense(dense: &[f32]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v);
            }
        }
        SparseVec { dim: dense.len(), indices, values }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn density(&self) -> f64 {
        if self.dim == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.dim as f64
        }
    }

    /// Materialise as a dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] = v;
        }
        out
    }

    /// Add into an existing dense accumulator: `acc += scale * self`.
    pub fn add_into(&self, acc: &mut [f32], scale: f32) {
        debug_assert_eq!(acc.len(), self.dim);
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            acc[i as usize] += scale * v;
        }
    }

    /// Coordinate-wise difference into a reusable vector: `out = self −
    /// other` over the union support, with exact-zero differences dropped
    /// (`out` is cleared and refilled, capacity kept). Both inputs must
    /// share `dim`. The wire codec's quantisation error feedback uses this
    /// to compute `upload − decode(encode(upload))`, where `other`'s
    /// support is a subset of `self`'s by construction.
    pub fn diff_into(&self, other: &SparseVec, out: &mut SparseVec) {
        debug_assert_eq!(self.dim, other.dim);
        out.dim = self.dim;
        out.indices.clear();
        out.values.clear();
        let (na, nb) = (self.indices.len(), other.indices.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < na && j < nb {
            let (ia, ib) = (self.indices[i], other.indices[j]);
            if ia == ib {
                let v = self.values[i] - other.values[j];
                if v != 0.0 {
                    out.indices.push(ia);
                    out.values.push(v);
                }
                i += 1;
                j += 1;
            } else if ia < ib {
                if self.values[i] != 0.0 {
                    out.indices.push(ia);
                    out.values.push(self.values[i]);
                }
                i += 1;
            } else {
                if other.values[j] != 0.0 {
                    out.indices.push(ib);
                    out.values.push(-other.values[j]);
                }
                j += 1;
            }
        }
        while i < na {
            if self.values[i] != 0.0 {
                out.indices.push(self.indices[i]);
                out.values.push(self.values[i]);
            }
            i += 1;
        }
        while j < nb {
            if other.values[j] != 0.0 {
                out.indices.push(other.indices[j]);
                out.values.push(-other.values[j]);
            }
            j += 1;
        }
        out.debug_check();
    }

    /// Scale all values in place.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.values {
            *v *= s;
        }
    }

    pub fn l2_norm(&self) -> f32 {
        self.values.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    pub(crate) fn debug_check(&self) {
        debug_assert_eq!(self.indices.len(), self.values.len());
        debug_assert!(self.indices.windows(2).all(|w| w[0] < w[1]), "indices not sorted-unique");
        if let Some(&last) = self.indices.last() {
            debug_assert!((last as usize) < self.dim, "index out of bounds");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let sv = SparseVec::from_dense(&dense);
        assert_eq!(sv.nnz(), 2);
        assert_eq!(sv.to_dense(), dense);
    }

    #[test]
    fn new_sorts_pairs() {
        let sv = SparseVec::new(10, vec![(5, 1.0), (2, 2.0), (7, 3.0)]);
        assert_eq!(sv.indices, vec![2, 5, 7]);
        assert_eq!(sv.values, vec![2.0, 1.0, 3.0]);
    }

    #[test]
    fn add_into_accumulates() {
        let sv = SparseVec::new(4, vec![(1, 2.0), (3, -1.0)]);
        let mut acc = vec![1.0; 4];
        sv.add_into(&mut acc, 0.5);
        assert_eq!(acc, vec![1.0, 2.0, 1.0, 0.5]);
    }

    #[test]
    fn density_and_norm() {
        let sv = SparseVec::new(8, vec![(0, 3.0), (4, 4.0)]);
        assert!((sv.density() - 0.25).abs() < 1e-12);
        assert!((sv.l2_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn empty_vector() {
        let sv = SparseVec::empty(16);
        assert_eq!(sv.nnz(), 0);
        assert_eq!(sv.to_dense(), vec![0.0; 16]);
    }

    #[test]
    fn diff_into_matches_dense_subtraction() {
        let a = SparseVec::new(10, vec![(1, 2.0), (3, -1.0), (7, 4.0)]);
        let b = SparseVec::new(10, vec![(1, 2.0), (4, 0.5), (7, 1.0)]);
        let mut out = SparseVec::empty(0);
        a.diff_into(&b, &mut out);
        let want: Vec<f32> =
            a.to_dense().iter().zip(&b.to_dense()).map(|(x, y)| x - y).collect();
        assert_eq!(out.to_dense(), want);
        // identical entries cancel entirely (index 1 vanishes)
        assert_eq!(out.indices, vec![3, 4, 7]);
        // warm reuse: a second diff through the same buffers
        let ptr = out.indices.as_ptr();
        a.diff_into(&b, &mut out);
        assert_eq!(out.indices.as_ptr(), ptr, "warm diff must not reallocate");
        // empty edges
        let empty = SparseVec::empty(10);
        a.diff_into(&empty, &mut out);
        assert_eq!(out.to_dense(), a.to_dense());
        empty.diff_into(&a, &mut out);
        let neg: Vec<f32> = a.to_dense().iter().map(|x| -x).collect();
        assert_eq!(out.to_dense(), neg);
    }
}
