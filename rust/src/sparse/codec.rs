//! Wire codec v2 — delta-varint indices, bitmap containers, quantised
//! payloads. Full byte-level specification in `docs/wire.md`.
//!
//! The v1 format (`wire.rs`) spends 8 bytes per sparse coordinate (raw u32
//! index + f32 value). For the sorted top-k supports and momentum-corrected
//! gradients this system actually ships, that leaves a 2–4× byte reduction
//! on the table; codec v2 takes it along three independent axes:
//!
//! * **Index coding** — [`IndexCoding::Varint`] stores the *gaps* of the
//!   sorted-unique index stream as LEB128 varints (first gap = first index,
//!   later gaps = difference to the previous index, always ≥ 1). At keep
//!   rate 0.1 the mean gap is ~10, so almost every index costs 1 byte
//!   instead of 4. When a pathological gap distribution would make the
//!   varint stream larger than raw u32s, the encoder falls back to raw for
//!   that buffer — the header records which coding actually shipped.
//! * **Container selection** — the encoder picks the smallest of three
//!   self-describing containers: *sparse* (index stream + values), *bitmap*
//!   (`ceil(dim/8)`-byte presence bitmap + packed values — wins at mid
//!   density, where indices dominate sparse but zeros dominate dense) and
//!   *dense* (all `dim` values). Ties break sparse ≺ bitmap ≺ dense.
//! * **Value coding** — [`ValueCoding::F32`] (exact), [`ValueCoding::F16`]
//!   (IEEE 754 half, round-to-nearest-even, overflow saturates to ±65504),
//!   or [`ValueCoding::Q8`] (blocks of [`Q8_BLOCK`] values, one f32 scale =
//!   maxabs/127 per block + one int8 per value). Lossy codings rely on the
//!   caller restoring `upload − decode(encode(upload))` into the client
//!   residual (`Compressor::restore_upload`), so DGC/GMC/GMF error feedback
//!   absorbs the quantisation error — see `coordinator::client`.
//!
//! The default [`CodecParams`] (raw + f32) never reaches this module:
//! `wire::encode_with` routes it to the v1 encoder, keeping default-config
//! buffers byte-identical to v1. Decoding is always self-describing — a
//! receiver needs no configuration to decode either version.
//!
//! Values are encoded in support order; sparse and bitmap containers keep
//! explicit entries whose value quantises to exactly 0 (support is
//! preserved), while the dense container drops zeros on decode like v1.

use super::simd;
use super::vector::SparseVec;
use super::wire::{WireError, MAGIC};

/// Kind byte marking a v2-coded buffer (v1 uses 0 = sparse, 1 = dense).
pub const KIND_V2: u8 = 2;

/// v2 header: magic u32, kind u8, container u8, index u8, value u8, dim u32.
pub const V2_HEADER_BYTES: usize = 12;

/// Values per q8 block — one f32 scale each, ~1.6 % overhead.
pub const Q8_BLOCK: usize = 256;

/// Container byte values (buffer offset 5).
pub const CONTAINER_SPARSE: u8 = 0;
pub const CONTAINER_BITMAP: u8 = 1;
pub const CONTAINER_DENSE: u8 = 2;

/// How the sparse container's index stream is coded.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum IndexCoding {
    /// Raw little-endian u32 per index (v1-compatible cost: 4 bytes each).
    #[default]
    Raw,
    /// LEB128 varints over the gaps of the sorted-unique index stream.
    Varint,
}

impl IndexCoding {
    pub fn parse(s: &str) -> Option<IndexCoding> {
        match s.to_ascii_lowercase().as_str() {
            "raw" | "u32" => Some(IndexCoding::Raw),
            "varint" | "delta-varint" | "delta_varint" | "leb128" => Some(IndexCoding::Varint),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IndexCoding::Raw => "raw",
            IndexCoding::Varint => "varint",
        }
    }

    fn byte(self) -> u8 {
        match self {
            IndexCoding::Raw => 0,
            IndexCoding::Varint => 1,
        }
    }

    pub(crate) fn from_byte(b: u8) -> Result<IndexCoding, WireError> {
        match b {
            0 => Ok(IndexCoding::Raw),
            1 => Ok(IndexCoding::Varint),
            b => Err(WireError::BadCoding(b)),
        }
    }
}

/// How the value stream is coded.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ValueCoding {
    /// Exact little-endian f32 (v1-compatible cost: 4 bytes each).
    #[default]
    F32,
    /// IEEE 754 binary16, round-to-nearest-even, saturating at ±65504.
    F16,
    /// Blockwise int8: per [`Q8_BLOCK`] values one f32 scale (maxabs/127)
    /// followed by one signed byte per value.
    Q8,
}

impl ValueCoding {
    pub fn parse(s: &str) -> Option<ValueCoding> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "float" => Some(ValueCoding::F32),
            "f16" | "half" => Some(ValueCoding::F16),
            "q8" | "int8" => Some(ValueCoding::Q8),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ValueCoding::F32 => "f32",
            ValueCoding::F16 => "f16",
            ValueCoding::Q8 => "q8",
        }
    }

    fn byte(self) -> u8 {
        match self {
            ValueCoding::F32 => 0,
            ValueCoding::F16 => 1,
            ValueCoding::Q8 => 2,
        }
    }

    pub(crate) fn from_byte(b: u8) -> Result<ValueCoding, WireError> {
        match b {
            0 => Ok(ValueCoding::F32),
            1 => Ok(ValueCoding::F16),
            2 => Ok(ValueCoding::Q8),
            b => Err(WireError::BadCoding(b)),
        }
    }
}

/// Codec selection for one direction (one buffer family).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct CodecParams {
    pub index: IndexCoding,
    pub value: ValueCoding,
}

impl CodecParams {
    /// The v1-compatible default: raw u32 indices, f32 values.
    pub const V1: CodecParams = CodecParams { index: IndexCoding::Raw, value: ValueCoding::F32 };

    /// Whether these params emit the v1 byte layout (the default config).
    pub fn is_v1(&self) -> bool {
        *self == CodecParams::V1
    }

    /// Whether the value coding loses precision (quantisation error must be
    /// fed back into the client residual).
    pub fn lossy(&self) -> bool {
        self.value != ValueCoding::F32
    }

    pub fn describe(&self) -> String {
        format!("{}+{}", self.index.name(), self.value.name())
    }
}

/// Per-direction codec configuration for a run (TOML `[codec]`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct WireCodec {
    pub uplink: CodecParams,
    pub downlink: CodecParams,
}

impl WireCodec {
    pub fn is_v1(&self) -> bool {
        self.uplink.is_v1() && self.downlink.is_v1()
    }
}

// ---------------------------------------------------------------- f16 / q8

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even. Out-of-range
/// magnitudes saturate to ±65504 (the largest finite half) and NaN maps to
/// 0 — gradient payloads are finite by contract, and saturation keeps the
/// error-feedback residual finite even if one slips through.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // NaN → 0 (finite-payload contract), ±Inf saturates
        return if man != 0 { 0 } else { sign | 0x7BFF };
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        return sign | 0x7BFF; // saturate to ±65504
    }
    if e <= 0 {
        // subnormal half (or underflow to zero)
        if e < -10 {
            return sign;
        }
        let man = man | 0x0080_0000; // implicit leading bit
        let shift = (14 - e) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round_up = rem > halfway || (rem == halfway && (half & 1) == 1);
        // a round-up carry lands exactly on the smallest normal (0x0400)
        return sign | (half + round_up as u32) as u16;
    }
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1FFF;
    let round_up = rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1);
    let rounded = half + round_up as u32;
    if rounded >= 0x7C00 {
        return sign | 0x7BFF; // carry overflowed the exponent: saturate
    }
    sign | rounded as u16
}

/// IEEE 754 binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // subnormal half: man · 2⁻²⁴ — normalise into f32
            let p = 31 - man.leading_zeros(); // msb position, 0..=9
            let r = man & !(1u32 << p);
            sign | ((103 + p) << 23) | (r << (23 - p))
        }
    } else if exp == 31 {
        sign | 0x7F80_0000 | (man << 13) // ±Inf / NaN (never encoded)
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// ----------------------------------------------------------------- varints

#[inline]
pub(crate) fn varint_len(mut x: u32) -> usize {
    let mut n = 1;
    while x >= 0x80 {
        x >>= 7;
        n += 1;
    }
    n
}

#[inline]
pub(crate) fn push_varint(out: &mut Vec<u8>, mut x: u32) {
    while x >= 0x80 {
        out.push((x as u8 & 0x7F) | 0x80);
        x >>= 7;
    }
    out.push(x as u8);
}

#[inline]
pub(crate) fn read_varint(buf: &[u8], pos: &mut usize) -> Result<u32, WireError> {
    let mut x: u32 = 0;
    let mut shift = 0u32;
    loop {
        let Some(&b) = buf.get(*pos) else {
            return Err(WireError::Truncated(buf.len()));
        };
        *pos += 1;
        let low = (b & 0x7F) as u32;
        if shift == 28 && low > 0x0F {
            return Err(WireError::BadVarint(*pos - 1));
        }
        x |= low << shift;
        if b & 0x80 == 0 {
            return Ok(x);
        }
        shift += 7;
        if shift > 28 {
            return Err(WireError::BadVarint(*pos - 1));
        }
    }
}

/// Exact bytes of the delta-varint coding of a sorted-unique index stream
/// (dispatched: SIMD gap batching when active, scalar fold otherwise).
fn varint_index_bytes(indices: &[u32]) -> usize {
    simd::varint_gaps_bytes(indices)
}

/// Walk a delta-varint index stream, calling `sink` for each decoded
/// absolute index, with the exact validation the scalar decoder performs:
/// zero gaps after the first slot are `Unsorted`, accumulated indices at or
/// past `dim` are `IndexOutOfBounds`, malformed or truncated varints
/// surface from the varint reader. Decoding is batched through the SIMD
/// kernels; validation runs over each decoded prefix *before* any batch
/// decode error surfaces, so the first error observed is identical to the
/// sequential scalar loop's.
pub(crate) fn walk_varint_indices(
    buf: &[u8],
    pos: &mut usize,
    nnz: usize,
    dim: u32,
    mut sink: impl FnMut(u32),
) -> Result<(), WireError> {
    let mut gaps = [0u32; 64];
    let mut done = 0usize;
    let mut acc = 0u64;
    while done < nnz {
        let want = (nnz - done).min(gaps.len());
        let (got, err) = simd::varint_decode_gaps(buf, pos, &mut gaps[..want]);
        for (t, &gap) in gaps[..got].iter().enumerate() {
            if done + t == 0 {
                acc = gap as u64;
            } else {
                if gap == 0 {
                    return Err(WireError::Unsorted);
                }
                acc += gap as u64;
            }
            if acc >= dim as u64 {
                let idx = acc.min(u32::MAX as u64) as u32;
                return Err(WireError::IndexOutOfBounds { idx, dim });
            }
            sink(acc as u32);
        }
        done += got;
        if let Some(e) = err {
            return Err(e);
        }
    }
    Ok(())
}

// ------------------------------------------------------------ value stream

/// Exact byte size of the value stream for `n` values under `coding`.
pub fn value_stream_bytes(coding: ValueCoding, n: usize) -> usize {
    match coding {
        ValueCoding::F32 => 4 * n,
        ValueCoding::F16 => 2 * n,
        ValueCoding::Q8 => n + 4 * n.div_ceil(Q8_BLOCK),
    }
}

/// Per-block q8 scale: `maxabs / 127`, or 0 for an all-zero block. One
/// implementation shared by both encoder paths and by the testkit's
/// round-trip invariant (`testkit::invariants::check_q8_roundtrip`), so
/// the checked bound is the shipped bound by construction.
pub fn q8_block_scale(block: &[f32]) -> f32 {
    q8_scale_from_maxabs(block.iter().fold(0.0f32, |a, &v| a.max(v.abs())))
}

/// Scale from an already-computed block maxabs (the encoders fold the
/// block once for both the scale and the `127/maxabs` quantiser).
fn q8_scale_from_maxabs(maxabs: f32) -> f32 {
    if maxabs > 0.0 {
        maxabs / 127.0
    } else {
        0.0
    }
}

fn push_values(out: &mut Vec<u8>, coding: ValueCoding, values: &[f32]) {
    match coding {
        ValueCoding::F32 => {
            for &v in values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        ValueCoding::F16 => simd::f16_encode(values, out),
        ValueCoding::Q8 => {
            for block in values.chunks(Q8_BLOCK) {
                let maxabs = simd::maxabs(block);
                let scale = q8_scale_from_maxabs(maxabs);
                out.extend_from_slice(&scale.to_le_bytes());
                if scale > 0.0 {
                    simd::q8_quantize(block, maxabs, out);
                } else {
                    out.resize(out.len() + block.len(), 0);
                }
            }
        }
    }
}

fn read_values(
    buf: &[u8],
    pos: &mut usize,
    coding: ValueCoding,
    n: usize,
    out: &mut Vec<f32>,
) -> Result<(), WireError> {
    let need = value_stream_bytes(coding, n);
    let Some(body) = buf.get(*pos..*pos + need) else {
        return Err(WireError::Truncated(buf.len()));
    };
    *pos += need;
    match coding {
        ValueCoding::F32 => {
            for c in body.chunks_exact(4) {
                out.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
        }
        ValueCoding::F16 => {
            let base = out.len();
            out.resize(base + n, 0.0);
            simd::f16_decode(body, &mut out[base..]);
        }
        ValueCoding::Q8 => {
            let base = out.len();
            out.resize(base + n, 0.0);
            let mut off = 0usize;
            let mut done = 0usize;
            while done < n {
                let take = (n - done).min(Q8_BLOCK);
                let scale = f32::from_le_bytes(body[off..off + 4].try_into().unwrap());
                off += 4;
                simd::q8_dequantize(
                    &body[off..off + take],
                    scale,
                    &mut out[base + done..base + done + take],
                );
                off += take;
                done += take;
            }
        }
    }
    Ok(())
}

// ----------------------------------------------------------------- encoder

struct Plan {
    container: u8,
    index: IndexCoding,
    exact: usize,
    /// `reserve()` bound that is stable across rounds at fixed nnz/dim —
    /// varint sizes wobble a few bytes round to round, and reserving the
    /// raw-index worst case keeps warm buffers from ever reallocating.
    bound: usize,
}

fn plan(sv: &SparseVec, params: CodecParams) -> Plan {
    let n = sv.nnz();
    let vb = value_stream_bytes(params.value, n);
    let raw_idx = 4 * n;
    // per-buffer fallback: varint never ships when it loses to raw u32s
    let (index, idx_bytes) = match params.index {
        IndexCoding::Raw => (IndexCoding::Raw, raw_idx),
        IndexCoding::Varint => {
            let var = varint_index_bytes(&sv.indices);
            if var <= raw_idx {
                (IndexCoding::Varint, var)
            } else {
                (IndexCoding::Raw, raw_idx)
            }
        }
    };
    let sparse_exact = V2_HEADER_BYTES + 4 + idx_bytes + vb;
    let sparse_bound = V2_HEADER_BYTES + 4 + raw_idx + vb;
    let bitmap_exact = V2_HEADER_BYTES + sv.dim.div_ceil(8) + vb;
    let dense_exact = V2_HEADER_BYTES + value_stream_bytes(params.value, sv.dim);
    if sparse_exact <= bitmap_exact && sparse_exact <= dense_exact {
        Plan { container: CONTAINER_SPARSE, index, exact: sparse_exact, bound: sparse_bound }
    } else if bitmap_exact <= dense_exact {
        let (exact, bound) = (bitmap_exact, bitmap_exact);
        Plan { container: CONTAINER_BITMAP, index: IndexCoding::Raw, exact, bound }
    } else {
        let (exact, bound) = (dense_exact, dense_exact);
        Plan { container: CONTAINER_DENSE, index: IndexCoding::Raw, exact, bound }
    }
}

/// Exact number of bytes [`encode_v2`] will produce.
pub fn encoded_bytes_v2(sv: &SparseVec, params: CodecParams) -> usize {
    plan(sv, params).exact
}

/// Serialise in the v2 layout into a reusable buffer (cleared and refilled,
/// capacity kept — no steady-state allocation once warm). The container is
/// chosen per buffer by exact byte count; the header records every choice,
/// so decoding needs no configuration.
pub fn encode_v2(sv: &SparseVec, out: &mut Vec<u8>, params: CodecParams) {
    let plan = plan(sv, params);
    out.clear();
    out.reserve(plan.bound);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(KIND_V2);
    out.push(plan.container);
    out.push(plan.index.byte());
    out.push(params.value.byte());
    out.extend_from_slice(&(sv.dim as u32).to_le_bytes());
    match plan.container {
        CONTAINER_SPARSE => {
            out.extend_from_slice(&(sv.nnz() as u32).to_le_bytes());
            match plan.index {
                IndexCoding::Raw => {
                    for &i in &sv.indices {
                        out.extend_from_slice(&i.to_le_bytes());
                    }
                }
                IndexCoding::Varint => simd::varint_encode_gaps(&sv.indices, out),
            }
            push_values(out, params.value, &sv.values);
        }
        CONTAINER_BITMAP => {
            let base = out.len();
            out.resize(base + sv.dim.div_ceil(8), 0);
            for &i in &sv.indices {
                out[base + (i as usize >> 3)] |= 1u8 << (i % 8);
            }
            push_values(out, params.value, &sv.values);
        }
        _ => push_dense_values(out, params.value, sv),
    }
    debug_assert_eq!(out.len(), plan.exact);
}

/// Dense value stream straight from the sparse representation — zero runs
/// are bulk-written (`resize`), never materialised as a dense f32 copy.
fn push_dense_values(out: &mut Vec<u8>, coding: ValueCoding, sv: &SparseVec) {
    match coding {
        // same writer as the v1 dense body — byte-identical by contract
        ValueCoding::F32 => super::wire::push_dense_f32(out, sv),
        ValueCoding::F16 => {
            let mut next = 0usize;
            for (&i, &v) in sv.indices.iter().zip(&sv.values) {
                let run = i as usize - next;
                if run > 0 {
                    out.resize(out.len() + 2 * run, 0);
                }
                out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
                next = i as usize + 1;
            }
            out.resize(out.len() + 2 * (sv.dim - next), 0);
        }
        ValueCoding::Q8 => {
            // q8 blocks span the dense coordinate stream: per block, find
            // the entries inside it (cursor walk), scale by the block's
            // maxabs, bulk-zero the rest
            let mut e = 0usize;
            let mut block_start = 0usize;
            while block_start < sv.dim {
                let block_end = (block_start + Q8_BLOCK).min(sv.dim);
                let e0 = e;
                while e < sv.indices.len() && (sv.indices[e] as usize) < block_end {
                    e += 1;
                }
                let mut maxabs = 0.0f32;
                for &v in &sv.values[e0..e] {
                    maxabs = maxabs.max(v.abs());
                }
                let scale = q8_scale_from_maxabs(maxabs);
                out.extend_from_slice(&scale.to_le_bytes());
                let base = out.len();
                out.resize(base + (block_end - block_start), 0);
                if maxabs > 0.0 {
                    let inv = 127.0 / maxabs;
                    for (&ix, &v) in sv.indices[e0..e].iter().zip(&sv.values[e0..e]) {
                        let off = ix as usize - block_start;
                        out[base + off] = (v * inv).round().clamp(-127.0, 127.0) as i8 as u8;
                    }
                }
                block_start = block_end;
            }
        }
    }
}

// ----------------------------------------------------------------- decoder

/// Decode a v2 buffer (kind byte 2; magic + kind already verified by
/// `wire::decode_into`) into a reusable vector. Self-describing: the header
/// carries the container and both codings. On error `out` is left in an
/// unspecified (but valid) state, like the v1 decoder.
pub(crate) fn decode_v2(buf: &[u8], out: &mut SparseVec) -> Result<(), WireError> {
    if buf.len() < V2_HEADER_BYTES {
        return Err(WireError::Truncated(buf.len()));
    }
    let container = buf[5];
    let index = IndexCoding::from_byte(buf[6])?;
    let value = ValueCoding::from_byte(buf[7])?;
    let dim = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    out.dim = dim as usize;
    out.indices.clear();
    out.values.clear();
    let mut pos = V2_HEADER_BYTES;
    match container {
        CONTAINER_SPARSE => {
            let Some(nnz_bytes) = buf.get(pos..pos + 4) else {
                return Err(WireError::Truncated(buf.len()));
            };
            let nnz = u32::from_le_bytes(nnz_bytes.try_into().unwrap()) as usize;
            pos += 4;
            // lower-bound availability check before reserving anything:
            // each index costs ≥ 1 byte (varint) / exactly 4 (raw)
            let idx_min = match index {
                IndexCoding::Raw => 4 * nnz,
                IndexCoding::Varint => nnz,
            };
            let vb = value_stream_bytes(value, nnz);
            if buf.len() < pos + idx_min + vb {
                return Err(WireError::Truncated(buf.len()));
            }
            out.indices.reserve(nnz);
            out.values.reserve(nnz);
            match index {
                IndexCoding::Raw => {
                    let end = pos + 4 * nnz;
                    let mut last: i64 = -1;
                    for c in buf[pos..end].chunks_exact(4) {
                        let i = u32::from_le_bytes(c.try_into().unwrap());
                        if i >= dim {
                            return Err(WireError::IndexOutOfBounds { idx: i, dim });
                        }
                        if (i as i64) <= last {
                            return Err(WireError::Unsorted);
                        }
                        last = i as i64;
                        out.indices.push(i);
                    }
                    pos = end;
                }
                IndexCoding::Varint => {
                    let indices = &mut out.indices;
                    walk_varint_indices(buf, &mut pos, nnz, dim, |i| indices.push(i))?;
                    // the varint stream was wider than the 1-byte lower
                    // bound: re-check the value bytes at the real offset
                    if buf.len() < pos + vb {
                        return Err(WireError::Truncated(buf.len()));
                    }
                }
            }
            read_values(buf, &mut pos, value, nnz, &mut out.values)?;
            out.debug_check();
            Ok(())
        }
        CONTAINER_BITMAP => {
            let bm_len = (dim as usize).div_ceil(8);
            let Some(bm) = buf.get(pos..pos + bm_len) else {
                return Err(WireError::Truncated(buf.len()));
            };
            if dim % 8 != 0 {
                let mask = 0xFFu8 << (dim % 8); // bits at positions ≥ dim
                if bm[bm_len - 1] & mask != 0 {
                    return Err(WireError::BadBitmap);
                }
            }
            let nnz: usize = bm.iter().map(|b| b.count_ones() as usize).sum();
            let vb = value_stream_bytes(value, nnz);
            if buf.len() < pos + bm_len + vb {
                return Err(WireError::Truncated(buf.len()));
            }
            out.indices.reserve(nnz);
            out.values.reserve(nnz);
            for (byte_i, &b) in bm.iter().enumerate() {
                let mut bits = b;
                while bits != 0 {
                    let bit = bits.trailing_zeros() as usize;
                    out.indices.push((byte_i * 8 + bit) as u32);
                    bits &= bits - 1;
                }
            }
            pos += bm_len;
            read_values(buf, &mut pos, value, nnz, &mut out.values)?;
            out.debug_check();
            Ok(())
        }
        CONTAINER_DENSE => {
            let n = dim as usize;
            let need = value_stream_bytes(value, n);
            let Some(body) = buf.get(pos..pos + need) else {
                return Err(WireError::Truncated(buf.len()));
            };
            match value {
                ValueCoding::F32 => {
                    for (i, c) in body.chunks_exact(4).enumerate() {
                        let v = f32::from_le_bytes(c.try_into().unwrap());
                        if v != 0.0 {
                            out.indices.push(i as u32);
                            out.values.push(v);
                        }
                    }
                }
                ValueCoding::F16 => {
                    for (i, c) in body.chunks_exact(2).enumerate() {
                        let v = f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap()));
                        if v != 0.0 {
                            out.indices.push(i as u32);
                            out.values.push(v);
                        }
                    }
                }
                ValueCoding::Q8 => {
                    let mut off = 0usize;
                    let mut idx = 0usize;
                    while idx < n {
                        let take = (n - idx).min(Q8_BLOCK);
                        let scale = f32::from_le_bytes(body[off..off + 4].try_into().unwrap());
                        off += 4;
                        for (j, &b) in body[off..off + take].iter().enumerate() {
                            let q = b as i8;
                            if q != 0 && scale != 0.0 {
                                out.indices.push((idx + j) as u32);
                                out.values.push(q as f32 * scale);
                            }
                        }
                        off += take;
                        idx += take;
                    }
                }
            }
            out.debug_check();
            Ok(())
        }
        c => Err(WireError::BadContainer(c)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::wire;
    use crate::util::rng::Rng;

    fn params(index: IndexCoding, value: ValueCoding) -> CodecParams {
        CodecParams { index, value }
    }

    #[test]
    fn f16_known_values() {
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3C00),
            (-1.0, 0xBC00),
            (0.5, 0x3800),
            (65504.0, 0x7BFF),
            (6.103_515_6e-5, 0x0400), // smallest normal half
            (5.960_464_5e-8, 0x0001), // smallest subnormal half
        ] {
            assert_eq!(f32_to_f16_bits(x), bits, "{x}");
            assert_eq!(f16_bits_to_f32(bits), x, "{bits:#06x}");
        }
        // saturation + NaN policy
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), 65504.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e9)), -65504.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), 65504.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)), 0.0);
        // negative zero keeps its sign bit
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
    }

    #[test]
    fn f16_roundtrip_relative_error_bounded() {
        let mut rng = Rng::new(1);
        for _ in 0..5000 {
            let x = rng.normal() * 10f32.powi(rng.below(9) as i32 - 4);
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            if x.abs() >= 6.2e-5 && x.abs() <= 65504.0 {
                assert!((x - y).abs() <= x.abs() / 1024.0, "{x} -> {y}");
            }
            // idempotence: a decoded half re-encodes to the same bits
            assert_eq!(f32_to_f16_bits(y), f32_to_f16_bits(x), "{x}");
        }
    }

    #[test]
    fn varint_roundtrip_and_lengths() {
        let mut buf = Vec::new();
        for x in [0u32, 1, 127, 128, 300, 16_383, 16_384, 1 << 21, u32::MAX] {
            buf.clear();
            push_varint(&mut buf, x);
            assert_eq!(buf.len(), varint_len(x), "{x}");
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), x, "{x}");
            assert_eq!(pos, buf.len(), "{x}");
        }
        assert_eq!(varint_len(127), 1);
        assert_eq!(varint_len(128), 2);
        assert_eq!(varint_len(u32::MAX), 5);
    }

    #[test]
    fn varint_rejects_overflow_and_truncation() {
        // 5 continuation bytes → shift past 32 bits
        let over = [0x80u8, 0x80, 0x80, 0x80, 0x80, 0x01];
        let mut pos = 0;
        assert!(matches!(read_varint(&over, &mut pos), Err(WireError::BadVarint(_))));
        // 5th byte carrying more than the top 4 bits of a u32
        let wide = [0xFFu8, 0xFF, 0xFF, 0xFF, 0x1F];
        pos = 0;
        assert!(matches!(read_varint(&wide, &mut pos), Err(WireError::BadVarint(_))));
        // dangling continuation bit
        let cut = [0x80u8];
        pos = 0;
        assert!(matches!(read_varint(&cut, &mut pos), Err(WireError::Truncated(_))));
    }

    fn rand_support(rng: &mut Rng, dim: usize, nnz: usize) -> SparseVec {
        let mut ids: Vec<u32> = (0..dim as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(nnz);
        ids.sort_unstable();
        let values: Vec<f32> = ids.iter().map(|_| rng.normal()).collect();
        SparseVec::from_sorted(dim, ids, values)
    }

    #[test]
    fn v2_f32_roundtrip_exact_across_densities() {
        let mut rng = Rng::new(7);
        let mut buf = Vec::new();
        let mut back = SparseVec::empty(0);
        for &dim in &[1usize, 8, 100, 1000] {
            for &frac in &[0.0f64, 0.05, 0.3, 0.8, 1.0] {
                let nnz = ((dim as f64 * frac) as usize).min(dim);
                let sv = rand_support(&mut rng, dim, nnz);
                for index in [IndexCoding::Raw, IndexCoding::Varint] {
                    let p = params(index, ValueCoding::F32);
                    if p.is_v1() {
                        continue; // routed to v1 by encode_with
                    }
                    encode_v2(&sv, &mut buf, p);
                    assert_eq!(buf.len(), encoded_bytes_v2(&sv, p), "dim {dim} frac {frac}");
                    wire::decode_into(&buf, &mut back).unwrap();
                    assert_eq!(back.to_dense(), sv.to_dense(), "dim {dim} frac {frac}");
                }
            }
        }
    }

    #[test]
    fn container_selection_tracks_density() {
        let mut rng = Rng::new(9);
        let dim = 4096;
        let p = params(IndexCoding::Varint, ValueCoding::F16);
        let mut buf = Vec::new();
        // low density → sparse
        encode_v2(&rand_support(&mut rng, dim, dim / 50), &mut buf, p);
        assert_eq!(buf[5], CONTAINER_SPARSE);
        // mid density → bitmap (indices dominate sparse, zeros dominate dense)
        encode_v2(&rand_support(&mut rng, dim, dim * 3 / 10), &mut buf, p);
        assert_eq!(buf[5], CONTAINER_BITMAP);
        // near-full → dense
        encode_v2(&rand_support(&mut rng, dim, dim * 95 / 100), &mut buf, p);
        assert_eq!(buf[5], CONTAINER_DENSE);
    }

    #[test]
    fn v2_never_larger_than_v1_plus_header_slack() {
        // sparse container: v2 header (16 incl. nnz) vs v1 (13), and the
        // index stream is min(varint, raw) — so v2 ≤ v1 + 3 always
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let dim = 1 + rng.below(2000);
            let nnz = rng.below(dim + 1);
            let sv = rand_support(&mut rng, dim, nnz);
            let p = params(IndexCoding::Varint, ValueCoding::F32);
            let v2 = encoded_bytes_v2(&sv, p);
            let v1 = wire::encoded_bytes(&sv);
            assert!(v2 <= v1 + 3, "dim {dim} nnz {nnz}: v2 {v2} v1 {v1}");
        }
    }

    #[test]
    fn varint_fallback_on_adversarial_gaps() {
        // gaps ≥ 2^28 need 5-byte varints — three of them cost 15 bytes
        // against 12 raw, so the encoder must ship raw u32s and record
        // that in the header
        let dim = (1usize << 31) + 7;
        let ids = vec![1u32 << 29, 1 << 30, (1 << 30) + (1 << 29)];
        let sv = SparseVec::from_sorted(dim, ids, vec![1.0, 2.0, 3.0]);
        let p = params(IndexCoding::Varint, ValueCoding::F32);
        let mut buf = Vec::new();
        encode_v2(&sv, &mut buf, p);
        assert_eq!(buf[5], CONTAINER_SPARSE);
        assert_eq!(buf[6], 0, "adversarial gaps must fall back to raw indices");
        let back = wire::decode(&buf).unwrap();
        assert_eq!(back, sv);
    }

    #[test]
    fn q8_block_scale_definition() {
        assert_eq!(q8_block_scale(&[]), 0.0);
        assert_eq!(q8_block_scale(&[0.0, 0.0]), 0.0, "all-zero block has no scale");
        assert_eq!(q8_block_scale(&[1.0, -127.0, 3.5]), 1.0);
        assert_eq!(q8_block_scale(&[-0.254]), 0.254 / 127.0);
        // the encoder ships exactly this scale in the block header
        let values: Vec<f32> = (0..Q8_BLOCK).map(|i| (i as f32) - 100.0).collect();
        // dim far above the bitmap crossover so the sparse container wins
        let sv = SparseVec::from_sorted(
            Q8_BLOCK * 64,
            (0..Q8_BLOCK as u32).collect(),
            values.clone(),
        );
        let mut buf = Vec::new();
        encode_v2(&sv, &mut buf, params(IndexCoding::Varint, ValueCoding::Q8));
        assert_eq!(buf[5], CONTAINER_SPARSE);
        let nnz_off = V2_HEADER_BYTES;
        let nnz = u32::from_le_bytes(buf[nnz_off..nnz_off + 4].try_into().unwrap()) as usize;
        assert_eq!(nnz, Q8_BLOCK);
        // value stream starts after nnz + varint index stream; recover its
        // offset from the known total layout (values are the tail)
        let tail = value_stream_bytes(ValueCoding::Q8, nnz);
        let val_off = buf.len() - tail;
        let shipped = f32::from_le_bytes(buf[val_off..val_off + 4].try_into().unwrap());
        assert_eq!(shipped, q8_block_scale(&values));
    }

    #[test]
    fn q8_error_bounded_by_block_scale() {
        let mut rng = Rng::new(13);
        let dim = 2000;
        let sv = rand_support(&mut rng, dim, 700);
        let p = params(IndexCoding::Varint, ValueCoding::Q8);
        let mut buf = Vec::new();
        encode_v2(&sv, &mut buf, p);
        let back = wire::decode(&buf).unwrap();
        assert_eq!(back.indices, sv.indices, "q8 preserves the support");
        for block in 0..sv.nnz().div_ceil(Q8_BLOCK) {
            let lo = block * Q8_BLOCK;
            let hi = (lo + Q8_BLOCK).min(sv.nnz());
            let maxabs = sv.values[lo..hi].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            // half a quantisation step plus f32 rounding noise (the scale
            // and its reciprocal are rounded independently)
            let tol = maxabs / 127.0 * 0.5 + maxabs * 1e-6 + 1e-7;
            for i in lo..hi {
                let err = (sv.values[i] - back.values[i]).abs();
                assert!(err <= tol, "i {i}: err {err} tol {tol}");
            }
        }
    }

    #[test]
    fn empty_and_tiny_vectors_roundtrip_every_mode() {
        let mut buf = Vec::new();
        let mut back = SparseVec::empty(0);
        for value in [ValueCoding::F32, ValueCoding::F16, ValueCoding::Q8] {
            for index in [IndexCoding::Raw, IndexCoding::Varint] {
                let p = params(index, value);
                if p.is_v1() {
                    continue;
                }
                for sv in [
                    SparseVec::empty(0),
                    SparseVec::empty(17),
                    SparseVec::from_sorted(1, vec![0], vec![1.0]),
                    SparseVec::from_sorted(9, vec![8], vec![-2.0]),
                ] {
                    encode_v2(&sv, &mut buf, p);
                    assert_eq!(buf.len(), encoded_bytes_v2(&sv, p));
                    wire::decode_into(&buf, &mut back).unwrap();
                    assert_eq!(back.dim, sv.dim, "{p:?}");
                    assert_eq!(back.indices, sv.indices, "{p:?}");
                }
            }
        }
    }

    #[test]
    fn warm_encode_reuses_buffer_across_varying_gaps() {
        // round-over-round varint sizes wobble; the stable reserve bound
        // must keep the warm buffer from reallocating
        let mut rng = Rng::new(17);
        let dim = 5000;
        let p = params(IndexCoding::Varint, ValueCoding::F16);
        let mut buf = Vec::new();
        encode_v2(&rand_support(&mut rng, dim, 500), &mut buf, p);
        let (cap, ptr) = (buf.capacity(), buf.as_ptr());
        for _ in 0..20 {
            encode_v2(&rand_support(&mut rng, dim, 500), &mut buf, p);
            assert_eq!(buf.capacity(), cap);
            assert_eq!(buf.as_ptr(), ptr, "warm v2 encode must not reallocate");
        }
    }
}
