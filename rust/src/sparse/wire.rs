//! Wire format + byte accounting for gradient exchange.
//!
//! Every uplink/downlink in the system is *actually serialised* through this
//! format (not just size-estimated), so the communication-overhead numbers in
//! the experiment tables are byte-exact for the implementation.
//!
//! Layout (little-endian):
//! ```text
//!   magic   u32   0x46474D46 ("FGMF")
//!   kind    u8    0 = sparse, 1 = dense
//!   dim     u32
//!   sparse: nnz u32, then nnz * (idx u32), then nnz * (val f32)
//!   dense:  dim * (val f32)
//! ```
//! The encoder auto-selects dense when `8·nnz >= 4·dim` (sparse would be
//! larger) — this is exactly the "aggregated gradient becomes nearly full
//! size" effect of server-side global momentum the paper's §2.1 measures.
//!
//! This module is the **v1** layout (and the version-dispatching decoder).
//! The v2 layout — delta-varint indices, bitmap containers, f16/q8 value
//! coding, kind byte 2 — lives in [`super::codec`]; [`encode_with`] routes
//! between the two (the default [`CodecParams`] emits v1 byte-identically)
//! and [`decode_into`] transparently accepts both versions.

use super::codec::{self, CodecParams};
use super::vector::SparseVec;

pub const MAGIC: u32 = 0x4647_4D46;
pub(crate) const HEADER_BYTES: usize = 4 + 1 + 4;

#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("buffer too short ({0} bytes)")]
    Truncated(usize),
    #[error("bad magic {0:#x}")]
    BadMagic(u32),
    #[error("bad kind byte {0}")]
    BadKind(u8),
    #[error("index {idx} out of bounds for dim {dim}")]
    IndexOutOfBounds { idx: u32, dim: u32 },
    #[error("indices not sorted-unique")]
    Unsorted,
    #[error("bad v2 container byte {0}")]
    BadContainer(u8),
    #[error("bad v2 coding byte {0}")]
    BadCoding(u8),
    #[error("malformed varint at byte {0}")]
    BadVarint(usize),
    #[error("bitmap has bits set at positions >= dim")]
    BadBitmap,
}

/// Exact number of bytes [`encode`] will produce — the **v1** (raw u32 +
/// f32) size. The traffic meter also uses this as the pre-codec byte count
/// a v2-coded upload is compared against.
pub fn encoded_bytes(sv: &SparseVec) -> usize {
    if use_dense(sv) {
        HEADER_BYTES + 4 * sv.dim
    } else {
        HEADER_BYTES + 4 + 8 * sv.nnz()
    }
}

/// Exact number of bytes [`encode_with`] will produce under `params`.
pub fn encoded_bytes_with(sv: &SparseVec, params: CodecParams) -> usize {
    if params.is_v1() {
        encoded_bytes(sv)
    } else {
        codec::encoded_bytes_v2(sv, params)
    }
}

fn use_dense(sv: &SparseVec) -> bool {
    8 * sv.nnz() >= 4 * sv.dim
}

/// Serialise into a reusable buffer: `out` is cleared and refilled, keeping
/// its capacity across calls — the round hot path encodes every uplink and
/// the broadcast through per-client persistent buffers with zero steady-state
/// allocation. The dense fallback streams zeros directly instead of
/// materialising a dense copy.
pub fn encode_into(sv: &SparseVec, out: &mut Vec<u8>) {
    out.clear();
    out.reserve(encoded_bytes(sv));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    if use_dense(sv) {
        out.push(1);
        out.extend_from_slice(&(sv.dim as u32).to_le_bytes());
        push_dense_f32(out, sv);
    } else {
        out.push(0);
        out.extend_from_slice(&(sv.dim as u32).to_le_bytes());
        out.extend_from_slice(&(sv.nnz() as u32).to_le_bytes());
        for &i in &sv.indices {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for &v in &sv.values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    debug_assert_eq!(out.len(), encoded_bytes(sv));
}

/// Dense f32 value stream (all `dim` coordinates): zero runs are
/// bulk-written (`resize` → memset), not streamed one 4-byte slice at a
/// time — this is the downlink broadcast hot path once server-side global
/// momentum densifies the aggregate. Shared by the v1 dense body and the
/// v2 dense container's f32 mode, which are byte-identical by contract.
pub(crate) fn push_dense_f32(out: &mut Vec<u8>, sv: &SparseVec) {
    let mut next = 0usize;
    for (&i, &v) in sv.indices.iter().zip(&sv.values) {
        let run = i as usize - next;
        if run > 0 {
            out.resize(out.len() + 4 * run, 0);
        }
        out.extend_from_slice(&v.to_le_bytes());
        next = i as usize + 1;
    }
    out.resize(out.len() + 4 * (sv.dim - next), 0);
}

/// Allocating convenience wrapper over [`encode_into`].
pub fn encode(sv: &SparseVec) -> Vec<u8> {
    let mut out = Vec::with_capacity(encoded_bytes(sv));
    encode_into(sv, &mut out);
    out
}

/// Serialise through the configured codec: the default (raw u32 + f32)
/// params emit the v1 byte layout exactly — byte-identical to
/// [`encode_into`] — while anything else emits the self-describing v2
/// layout (see `docs/wire.md`). Either way `out` is cleared and refilled
/// with its capacity kept, and [`decode_into`] accepts the result without
/// being told which codec produced it.
pub fn encode_with(sv: &SparseVec, out: &mut Vec<u8>, params: CodecParams) {
    if params.is_v1() {
        encode_into(sv, out);
    } else {
        codec::encode_v2(sv, out, params);
    }
}

/// Deserialise into a reusable vector: `out.indices` / `out.values` are
/// cleared and refilled (capacity kept), `out.dim` is overwritten. Index and
/// value arrays are read in bulk via `chunks_exact` rather than per-element
/// cursor reads. On error `out` is left in an unspecified (but valid) state.
pub fn decode_into(buf: &[u8], out: &mut SparseVec) -> Result<(), WireError> {
    if buf.len() < HEADER_BYTES {
        return Err(WireError::Truncated(buf.len()));
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let kind = buf[4];
    if kind == codec::KIND_V2 {
        return codec::decode_v2(buf, out);
    }
    let dim = u32::from_le_bytes(buf[5..9].try_into().unwrap());
    out.dim = dim as usize;
    out.indices.clear();
    out.values.clear();
    match kind {
        1 => {
            let body_len = 4 * dim as usize;
            let Some(body) = buf.get(HEADER_BYTES..HEADER_BYTES + body_len) else {
                return Err(WireError::Truncated(buf.len()));
            };
            for (i, c) in body.chunks_exact(4).enumerate() {
                let v = f32::from_le_bytes(c.try_into().unwrap());
                if v != 0.0 {
                    out.indices.push(i as u32);
                    out.values.push(v);
                }
            }
            Ok(())
        }
        0 => {
            let Some(nnz_bytes) = buf.get(HEADER_BYTES..HEADER_BYTES + 4) else {
                return Err(WireError::Truncated(buf.len()));
            };
            let nnz = u32::from_le_bytes(nnz_bytes.try_into().unwrap()) as usize;
            let idx_off = HEADER_BYTES + 4;
            let val_off = idx_off + 4 * nnz;
            if buf.len() < val_off + 4 * nnz {
                return Err(WireError::Truncated(buf.len()));
            }
            out.indices.reserve(nnz);
            out.values.reserve(nnz);
            let mut last: i64 = -1;
            for c in buf[idx_off..val_off].chunks_exact(4) {
                let i = u32::from_le_bytes(c.try_into().unwrap());
                if i >= dim {
                    return Err(WireError::IndexOutOfBounds { idx: i, dim });
                }
                if (i as i64) <= last {
                    return Err(WireError::Unsorted);
                }
                last = i as i64;
                out.indices.push(i);
            }
            for c in buf[val_off..val_off + 4 * nnz].chunks_exact(4) {
                out.values.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            out.debug_check();
            Ok(())
        }
        k => Err(WireError::BadKind(k)),
    }
}

/// Allocating convenience wrapper over [`decode_into`].
pub fn decode(buf: &[u8]) -> Result<SparseVec, WireError> {
    let mut out = SparseVec::empty(0);
    decode_into(buf, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_roundtrip() {
        let sv = SparseVec::new(100, vec![(3, 1.5), (50, -2.0), (99, 0.25)]);
        let buf = encode(&sv);
        assert_eq!(buf.len(), encoded_bytes(&sv));
        assert_eq!(decode(&buf).unwrap(), sv);
    }

    #[test]
    fn dense_fallback_when_over_half() {
        // nnz/dim >= 0.5 → dense encoding is smaller
        let pairs: Vec<(u32, f32)> = (0..60).map(|i| (i, i as f32 + 1.0)).collect();
        let sv = SparseVec::new(100, pairs);
        let buf = encode(&sv);
        assert_eq!(buf.len(), HEADER_BYTES + 400);
        let back = decode(&buf).unwrap();
        assert_eq!(back.to_dense(), sv.to_dense());
    }

    #[test]
    fn crossover_is_exact() {
        // sparse bytes = 13 + 8nnz, dense bytes = 9 + 4dim
        let dim = 100usize;
        for nnz in [49usize, 50, 51] {
            let pairs: Vec<(u32, f32)> = (0..nnz as u32).map(|i| (i, 1.0)).collect();
            let sv = SparseVec::new(dim, pairs);
            let expect_dense = 8 * nnz >= 4 * dim;
            assert_eq!(encode(&sv)[4] == 1, expect_dense, "nnz={nnz}");
        }
    }

    #[test]
    fn rejects_corrupt_input() {
        let sv = SparseVec::new(10, vec![(1, 1.0)]);
        let mut buf = encode(&sv);
        assert!(matches!(decode(&buf[..3]), Err(WireError::Truncated(_))));
        buf[0] ^= 0xFF;
        assert!(matches!(decode(&buf), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn rejects_out_of_bounds_index() {
        let sv = SparseVec::new(10, vec![(1, 1.0)]);
        let mut buf = encode(&sv);
        // index field starts at HEADER+4
        buf[HEADER_BYTES + 4..HEADER_BYTES + 8].copy_from_slice(&100u32.to_le_bytes());
        assert!(matches!(decode(&buf), Err(WireError::IndexOutOfBounds { .. })));
    }

    #[test]
    fn empty_vec_roundtrip() {
        let sv = SparseVec::empty(42);
        assert_eq!(decode(&encode(&sv)).unwrap(), sv);
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let a = SparseVec::new(100, vec![(3, 1.5), (50, -2.0), (99, 0.25)]);
        let b = SparseVec::new(100, vec![(7, 4.0)]);
        let mut buf = Vec::new();
        let mut back = SparseVec::empty(0);
        encode_into(&a, &mut buf);
        decode_into(&buf, &mut back).unwrap();
        assert_eq!(back, a);
        let (buf_cap, buf_ptr) = (buf.capacity(), buf.as_ptr());
        let idx_ptr = back.indices.as_ptr();
        // smaller payload through the same buffers: no reallocation
        encode_into(&b, &mut buf);
        decode_into(&buf, &mut back).unwrap();
        assert_eq!(back, b);
        assert_eq!(buf.capacity(), buf_cap);
        assert_eq!(buf.as_ptr(), buf_ptr, "warm encode must not reallocate");
        assert_eq!(back.indices.as_ptr(), idx_ptr, "warm decode must not reallocate");
    }

    #[test]
    fn dense_streaming_encode_matches_dense_materialise() {
        // the dense fallback streams zeros; bytes must equal encoding the
        // materialised dense vector
        let pairs: Vec<(u32, f32)> = (0..60).map(|i| (i * 3 % 100, i as f32 - 7.5)).collect();
        let sv = SparseVec::new(100, pairs.into_iter().collect());
        let buf = encode(&sv);
        assert_eq!(buf[4], 1, "must take the dense path");
        let dense = sv.to_dense();
        for (i, c) in buf[HEADER_BYTES..].chunks_exact(4).enumerate() {
            assert_eq!(f32::from_le_bytes(c.try_into().unwrap()), dense[i], "coord {i}");
        }
    }
}
