//! Wire format + byte accounting for gradient exchange.
//!
//! Every uplink/downlink in the system is *actually serialised* through this
//! format (not just size-estimated), so the communication-overhead numbers in
//! the experiment tables are byte-exact for the implementation.
//!
//! Layout (little-endian):
//! ```text
//!   magic   u32   0x46474D46 ("FGMF")
//!   kind    u8    0 = sparse, 1 = dense
//!   dim     u32
//!   sparse: nnz u32, then nnz * (idx u32), then nnz * (val f32)
//!   dense:  dim * (val f32)
//! ```
//! The encoder auto-selects dense when `8·nnz >= 4·dim` (sparse would be
//! larger) — this is exactly the "aggregated gradient becomes nearly full
//! size" effect of server-side global momentum the paper's §2.1 measures.

use super::vector::SparseVec;

pub const MAGIC: u32 = 0x4647_4D46;
const HEADER_BYTES: usize = 4 + 1 + 4;

#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("buffer too short ({0} bytes)")]
    Truncated(usize),
    #[error("bad magic {0:#x}")]
    BadMagic(u32),
    #[error("bad kind byte {0}")]
    BadKind(u8),
    #[error("index {idx} out of bounds for dim {dim}")]
    IndexOutOfBounds { idx: u32, dim: u32 },
    #[error("indices not sorted-unique")]
    Unsorted,
}

/// Exact number of bytes [`encode`] will produce.
pub fn encoded_bytes(sv: &SparseVec) -> usize {
    if use_dense(sv) {
        HEADER_BYTES + 4 * sv.dim
    } else {
        HEADER_BYTES + 4 + 8 * sv.nnz()
    }
}

fn use_dense(sv: &SparseVec) -> bool {
    8 * sv.nnz() >= 4 * sv.dim
}

pub fn encode(sv: &SparseVec) -> Vec<u8> {
    let mut out = Vec::with_capacity(encoded_bytes(sv));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    if use_dense(sv) {
        out.push(1);
        out.extend_from_slice(&(sv.dim as u32).to_le_bytes());
        let dense = sv.to_dense();
        for v in dense {
            out.extend_from_slice(&v.to_le_bytes());
        }
    } else {
        out.push(0);
        out.extend_from_slice(&(sv.dim as u32).to_le_bytes());
        out.extend_from_slice(&(sv.nnz() as u32).to_le_bytes());
        for &i in &sv.indices {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for &v in &sv.values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    debug_assert_eq!(out.len(), encoded_bytes(sv));
    out
}

pub fn decode(buf: &[u8]) -> Result<SparseVec, WireError> {
    let mut cur = Cursor { buf, pos: 0 };
    let magic = cur.u32()?;
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let kind = cur.u8()?;
    let dim = cur.u32()?;
    match kind {
        1 => {
            let mut dense = Vec::with_capacity(dim as usize);
            for _ in 0..dim {
                dense.push(cur.f32()?);
            }
            Ok(SparseVec::from_dense(&dense))
        }
        0 => {
            let nnz = cur.u32()?;
            let mut indices = Vec::with_capacity(nnz as usize);
            for _ in 0..nnz {
                let i = cur.u32()?;
                if i >= dim {
                    return Err(WireError::IndexOutOfBounds { idx: i, dim });
                }
                indices.push(i);
            }
            if !indices.windows(2).all(|w| w[0] < w[1]) {
                return Err(WireError::Unsorted);
            }
            let mut values = Vec::with_capacity(nnz as usize);
            for _ in 0..nnz {
                values.push(cur.f32()?);
            }
            Ok(SparseVec::from_sorted(dim as usize, indices, values))
        }
        k => Err(WireError::BadKind(k)),
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated(self.buf.len()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_roundtrip() {
        let sv = SparseVec::new(100, vec![(3, 1.5), (50, -2.0), (99, 0.25)]);
        let buf = encode(&sv);
        assert_eq!(buf.len(), encoded_bytes(&sv));
        assert_eq!(decode(&buf).unwrap(), sv);
    }

    #[test]
    fn dense_fallback_when_over_half() {
        // nnz/dim >= 0.5 → dense encoding is smaller
        let pairs: Vec<(u32, f32)> = (0..60).map(|i| (i, i as f32 + 1.0)).collect();
        let sv = SparseVec::new(100, pairs);
        let buf = encode(&sv);
        assert_eq!(buf.len(), HEADER_BYTES + 400);
        let back = decode(&buf).unwrap();
        assert_eq!(back.to_dense(), sv.to_dense());
    }

    #[test]
    fn crossover_is_exact() {
        // sparse bytes = 13 + 8nnz, dense bytes = 9 + 4dim
        let dim = 100usize;
        for nnz in [49usize, 50, 51] {
            let pairs: Vec<(u32, f32)> = (0..nnz as u32).map(|i| (i, 1.0)).collect();
            let sv = SparseVec::new(dim, pairs);
            let expect_dense = 8 * nnz >= 4 * dim;
            assert_eq!(encode(&sv)[4] == 1, expect_dense, "nnz={nnz}");
        }
    }

    #[test]
    fn rejects_corrupt_input() {
        let sv = SparseVec::new(10, vec![(1, 1.0)]);
        let mut buf = encode(&sv);
        assert!(matches!(decode(&buf[..3]), Err(WireError::Truncated(_))));
        buf[0] ^= 0xFF;
        assert!(matches!(decode(&buf), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn rejects_out_of_bounds_index() {
        let sv = SparseVec::new(10, vec![(1, 1.0)]);
        let mut buf = encode(&sv);
        // index field starts at HEADER+4
        buf[HEADER_BYTES + 4..HEADER_BYTES + 8].copy_from_slice(&100u32.to_le_bytes());
        assert!(matches!(decode(&buf), Err(WireError::IndexOutOfBounds { .. })));
    }

    #[test]
    fn empty_vec_roundtrip() {
        let sv = SparseVec::empty(42);
        assert_eq!(decode(&encode(&sv)).unwrap(), sv);
    }
}
