//! Sparse aggregation: the server-side combine of client gradients.
//!
//! `Ĝ_t = (1/K) Σ_k G_{k,t}` where each `G_k` is sparse. The support of the
//! result is the **union** of client supports — the quantity the paper's
//! downlink overhead measures (GMF's whole point is shrinking this union by
//! correlating client masks through the shared global momentum).

use super::stream::Runs;
use super::vector::SparseVec;

/// Below this many total incoming nonzeros the sharded merge is not worth
/// the per-round thread-spawn overhead.
const PARALLEL_MERGE_MIN_NNZ: usize = 1 << 15;

/// Dense-buffer sparse accumulator, reused across rounds (no allocation in
/// the round loop once warm).
pub struct Aggregator {
    acc: Vec<f32>,
    touched: Vec<u32>,
    dirty: Vec<bool>,
    /// per-shard touched lists for the parallel merge (reused across rounds)
    shard_touched: Vec<Vec<u32>>,
    /// per-shard output staging for the parallel finish (reused across rounds)
    shard_out: Vec<SparseVec>,
}

impl Aggregator {
    pub fn new(dim: usize) -> Self {
        Aggregator {
            acc: vec![0.0; dim],
            touched: Vec::new(),
            dirty: vec![false; dim],
            shard_touched: Vec::new(),
            shard_out: Vec::new(),
        }
    }

    pub fn dim(&self) -> usize {
        self.acc.len()
    }

    /// Add one contribution scaled by `scale` (`acc += scale · v`) — the
    /// sequential unit [`Aggregator::add`] is built from.
    fn add_one(&mut self, g: &SparseVec, scale: f32) {
        assert_eq!(g.dim, self.acc.len(), "dimension mismatch");
        for (&i, &v) in g.indices.iter().zip(&g.values) {
            let iu = i as usize;
            if !self.dirty[iu] {
                self.dirty[iu] = true;
                self.touched.push(i);
            }
            self.acc[iu] += scale * v;
        }
    }

    /// Fold a validated pull-decoder's (index, value) runs straight into
    /// the accumulator — the streamed-ingest equivalent of decoding the
    /// buffer and calling [`Aggregator::add`], without the
    /// intermediate `SparseVec`. Bit-identical to that pair: the runs
    /// arrive in the decoder's emit order and the per-coordinate update is
    /// the same `acc += scale · v` expression. Consumption is through
    /// [`Runs::for_each_block`], so sparse uploads decode whole index and
    /// value blocks through the dispatched SIMD kernels; the blocked emit
    /// concatenates to exactly the scalar run stream, so the fold order and
    /// every f32 operation are unchanged.
    ///
    /// Partial-fold atomicity: [`Runs::validate`] has already vetted the
    /// entire buffer, so this emit pass cannot fail — a truncated or
    /// corrupt buffer is rejected *before* the first accumulator mutation
    /// (see docs/wire.md). Returns the number of runs folded.
    pub fn fold_stream(&mut self, runs: &Runs<'_>, scale: f32) -> usize {
        assert_eq!(runs.dim(), self.acc.len(), "dimension mismatch");
        let acc = &mut self.acc;
        let dirty = &mut self.dirty;
        let touched = &mut self.touched;
        let mut n = 0usize;
        runs.for_each_block(|ids, vals| {
            for (&i, &v) in ids.iter().zip(vals) {
                let iu = i as usize;
                if !dirty[iu] {
                    dirty[iu] = true;
                    touched.push(i);
                }
                acc[iu] += scale * v;
            }
            n += ids.len();
        });
        n
    }

    /// Add contributions scaled by `scale` (`acc += scale · g` per
    /// gradient, `scale = 1` bit-identical to unscaled addition — IEEE-754
    /// guarantees `1.0 · v == v`), sharding the coordinate space over up to
    /// `workers` threads when the volume justifies it. A `scale ≠ 1` is the
    /// staleness-discount path for carried-over late uploads.
    ///
    /// Bit-identical to sequential single-gradient adds in `grads` order at
    /// any worker count: shards partition the coordinate space, so within
    /// every coordinate the f32 additions still happen in client order.
    pub fn add(&mut self, grads: &[&SparseVec], scale: f32, workers: usize) {
        let total_nnz: usize = grads.iter().map(|g| g.nnz()).sum();
        if workers <= 1 || total_nnz < PARALLEL_MERGE_MIN_NNZ || self.acc.is_empty() {
            for g in grads {
                self.add_one(g, scale);
            }
            return;
        }
        for g in grads {
            assert_eq!(g.dim, self.acc.len(), "dimension mismatch");
        }
        let shards = workers.min(self.acc.len());
        let shard_len = self.acc.len().div_ceil(shards);
        if self.shard_touched.len() < shards {
            self.shard_touched.resize_with(shards, Vec::new);
        }
        let shard_touched = &mut self.shard_touched[..shards];
        let acc = &mut self.acc[..];
        let dirty = &mut self.dirty[..];
        std::thread::scope(|s| {
            let mut acc_rest: &mut [f32] = acc;
            let mut dirty_rest: &mut [bool] = dirty;
            let mut base = 0usize;
            for touched in shard_touched.iter_mut() {
                let len = shard_len.min(acc_rest.len());
                let (acc_chunk, ar) = acc_rest.split_at_mut(len);
                let (dirty_chunk, dr) = dirty_rest.split_at_mut(len);
                acc_rest = ar;
                dirty_rest = dr;
                let lo = base;
                base += len;
                s.spawn(move || {
                    touched.clear();
                    for g in grads {
                        let start = g.indices.partition_point(|&i| (i as usize) < lo);
                        let end = g.indices.partition_point(|&i| (i as usize) < lo + len);
                        for (&i, &v) in g.indices[start..end].iter().zip(&g.values[start..end]) {
                            let off = i as usize - lo;
                            if !dirty_chunk[off] {
                                dirty_chunk[off] = true;
                                touched.push(i);
                            }
                            acc_chunk[off] += scale * v;
                        }
                    }
                });
            }
        });
        for t in shard_touched.iter() {
            self.touched.extend_from_slice(t);
        }
    }

    /// Finish the round allocation-free: divide by `count`, emit the
    /// union-support mean into `out` (cleared, capacity kept), and reset
    /// for the next round, with the emit phase sharded over up to
    /// `workers` threads when the touched set justifies it.
    ///
    /// Instead of sorting the touched list, each worker scans its disjoint
    /// slice of the dirty bitmap in ascending coordinate order, emitting and
    /// resetting locally; concatenating the per-shard outputs in shard order
    /// is globally sorted. Values are the same `acc[i] * scale` products in
    /// the same order, so the result is **bit-identical** to the sequential
    /// sort + scan at any worker count.
    pub fn finish_into(&mut self, count: usize, out: &mut SparseVec, workers: usize) {
        let scale = if count == 0 { 0.0 } else { 1.0 / count as f32 };
        out.dim = self.acc.len();
        out.indices.clear();
        out.values.clear();
        if workers <= 1 || self.touched.len() < PARALLEL_MERGE_MIN_NNZ || self.acc.is_empty() {
            // sequential path: sort the touched list and scan it
            self.touched.sort_unstable();
            out.indices.reserve(self.touched.len());
            out.values.reserve(self.touched.len());
            for &i in &self.touched {
                let iu = i as usize;
                let v = self.acc[iu] * scale;
                if v != 0.0 {
                    out.indices.push(i);
                    out.values.push(v);
                }
                self.acc[iu] = 0.0;
                self.dirty[iu] = false;
            }
            self.touched.clear();
            out.debug_check();
            return;
        }
        let dim = self.acc.len();
        let shards = workers.min(dim);
        let shard_len = dim.div_ceil(shards);
        if self.shard_out.len() < shards {
            self.shard_out.resize_with(shards, || SparseVec::empty(0));
        }
        let shard_out = &mut self.shard_out[..shards];
        std::thread::scope(|s| {
            let mut acc_rest: &mut [f32] = &mut self.acc[..];
            let mut dirty_rest: &mut [bool] = &mut self.dirty[..];
            let mut base = 0usize;
            for so in shard_out.iter_mut() {
                let len = shard_len.min(acc_rest.len());
                let (acc_chunk, ar) = acc_rest.split_at_mut(len);
                let (dirty_chunk, dr) = dirty_rest.split_at_mut(len);
                acc_rest = ar;
                dirty_rest = dr;
                let lo = base;
                base += len;
                s.spawn(move || {
                    so.indices.clear();
                    so.values.clear();
                    let chunk = acc_chunk.iter_mut().zip(dirty_chunk.iter_mut());
                    for (off, (a, d)) in chunk.enumerate() {
                        if *d {
                            let v = *a * scale;
                            if v != 0.0 {
                                so.indices.push((lo + off) as u32);
                                so.values.push(v);
                            }
                            *a = 0.0;
                            *d = false;
                        }
                    }
                });
            }
        });
        let total: usize = shard_out.iter().map(|so| so.indices.len()).sum();
        out.indices.reserve(total);
        out.values.reserve(total);
        for so in shard_out.iter() {
            out.indices.extend_from_slice(&so.indices);
            out.values.extend_from_slice(&so.values);
        }
        self.touched.clear();
        out.debug_check();
    }
}

/// Union of supports without values (used by broadcast-size analysis).
pub fn support_union(vs: &[&SparseVec]) -> Vec<u32> {
    let mut all: Vec<u32> = vs.iter().flat_map(|v| v.indices.iter().copied()).collect();
    all.sort_unstable();
    all.dedup();
    all
}

/// Count-based O(total-nnz·log) estimate of the mean pairwise Jaccard
/// overlap, replacing the O(clients²·nnz) exact diagnostic on the round hot
/// path (at 100 clients the exact version dominates the round cost).
///
/// The mean pairwise *intersection* is computed exactly from coordinate
/// multiplicities (Σ_i C(c_i, 2) over C(n, 2) pairs); per-pair union sizes
/// are approximated by the mean mask size. The estimate is exact for n = 2
/// and for identical masks, and shares the exact statistic's ordering: it is
/// a strictly increasing function of the mean intersection whenever mask
/// sizes are equal (the steady-state exact-top-k case).
///
/// `scratch` is a reusable index buffer (no allocation when warm).
pub fn mean_jaccard_estimate(vs: &[&SparseVec], scratch: &mut Vec<u32>) -> f64 {
    let n = vs.len();
    if n < 2 {
        return 1.0;
    }
    let total: usize = vs.iter().map(|v| v.nnz()).sum();
    scratch.clear();
    scratch.reserve(total);
    for v in vs {
        scratch.extend_from_slice(&v.indices);
    }
    jaccard_estimate_finish(n, scratch)
}

/// Finishing half of [`mean_jaccard_estimate`] over an already-collected
/// index multiset: `scratch` holds the concatenated support indices of all
/// `n` masks (any order; sorted in place here). Exposed so the streamed
/// ingest path can collect indices *while folding* uploads and still
/// compute the identical statistic — same sort, same f64 expressions, so
/// the result is bit-identical to the materialized path.
pub fn jaccard_estimate_finish(n: usize, scratch: &mut Vec<u32>) -> f64 {
    if n < 2 {
        return 1.0;
    }
    let total = scratch.len();
    if total == 0 {
        return 1.0;
    }
    scratch.sort_unstable();
    let mut inter_pairs = 0u64;
    let mut run = 1u64;
    for w in 1..scratch.len() {
        if scratch[w] == scratch[w - 1] {
            run += 1;
        } else {
            inter_pairs += run * (run - 1) / 2;
            run = 1;
        }
    }
    inter_pairs += run * (run - 1) / 2;
    let pairs = (n * (n - 1) / 2) as f64;
    let mean_inter = inter_pairs as f64 / pairs;
    let mean_nnz = total as f64 / n as f64;
    let denom = 2.0 * mean_nnz - mean_inter;
    if denom <= 0.0 {
        return 1.0;
    }
    (mean_inter / denom).clamp(0.0, 1.0)
}

/// Mean Jaccard overlap between consecutive client masks — the mask
/// similarity statistic GMF is designed to raise (higher overlap → smaller
/// union → cheaper downlink). Exact but O(clients²·nnz); the round loop uses
/// [`mean_jaccard_estimate`] unless configured otherwise.
pub fn mean_pairwise_jaccard(vs: &[&SparseVec]) -> f64 {
    if vs.len() < 2 {
        return 1.0;
    }
    let mut total = 0.0;
    let mut pairs = 0usize;
    for i in 0..vs.len() {
        for j in (i + 1)..vs.len() {
            total += jaccard(&vs[i].indices, &vs[j].indices);
            pairs += 1;
        }
    }
    total / pairs as f64
}

fn jaccard(a: &[u32], b: &[u32]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let (mut i, mut j, mut inter) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    let union = a.len() + b.len() - inter;
    inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Allocating convenience over [`Aggregator::finish_into`].
    fn finish(agg: &mut Aggregator, count: usize) -> SparseVec {
        let mut out = SparseVec::empty(0);
        agg.finish_into(count, &mut out, 1);
        out
    }

    #[test]
    fn mean_of_two() {
        let mut agg = Aggregator::new(6);
        agg.add(&[&SparseVec::new(6, vec![(0, 2.0), (3, 4.0)])], 1.0, 1);
        agg.add(&[&SparseVec::new(6, vec![(3, 2.0), (5, 6.0)])], 1.0, 1);
        let out = finish(&mut agg, 2);
        assert_eq!(out.indices, vec![0, 3, 5]);
        assert_eq!(out.values, vec![1.0, 3.0, 3.0]);
    }

    #[test]
    fn scaled_add_discounts_values() {
        let mut agg = Aggregator::new(6);
        agg.add(&[&SparseVec::new(6, vec![(1, 4.0)])], 1.0, 1);
        agg.add(&[&SparseVec::new(6, vec![(1, 4.0), (3, 8.0)])], 0.5, 1);
        let out = finish(&mut agg, 2);
        assert_eq!(out.indices, vec![1, 3]);
        assert_eq!(out.values, vec![3.0, 2.0]); // (4 + 2)/2, (0 + 4)/2
    }

    #[test]
    fn scale_one_is_bit_identical_to_plain_add() {
        let g = rand_sparse(512, 200, 99);
        let mut a = Aggregator::new(512);
        a.add_one(&g, 1.0);
        let mut b = Aggregator::new(512);
        b.add(&[&g], 1.0, 1);
        let (oa, ob) = (finish(&mut a, 1), finish(&mut b, 1));
        assert_eq!(oa.indices, ob.indices);
        let bits_a: Vec<u32> = oa.values.iter().map(|v| v.to_bits()).collect();
        let bits_b: Vec<u32> = ob.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits_a, bits_b);
    }

    #[test]
    fn fold_stream_is_bit_identical_to_decode_then_add() {
        use crate::sparse::codec::{CodecParams, IndexCoding, ValueCoding};
        use crate::sparse::{stream, wire};
        let dim = 2048;
        let grads: Vec<SparseVec> = (0..5).map(|c| rand_sparse(dim, 150, 700 + c)).collect();
        let params = [
            CodecParams { index: IndexCoding::Raw, value: ValueCoding::F32 },
            CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 },
            CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 },
        ];
        for p in params {
            let mut via_decode = Aggregator::new(dim);
            let mut via_stream = Aggregator::new(dim);
            let mut buf = Vec::new();
            let mut echo = SparseVec::empty(0);
            for g in &grads {
                wire::encode_with(g, &mut buf, p);
                wire::decode_into(&buf, &mut echo).unwrap();
                via_decode.add(&[&echo], 1.0, 1);
                let runs = stream::Runs::validate(&buf).unwrap();
                let folded = via_stream.fold_stream(&runs, 1.0);
                assert_eq!(folded, echo.nnz(), "{p:?}");
            }
            let a = finish(&mut via_decode, grads.len());
            let b = finish(&mut via_stream, grads.len());
            assert_eq!(a.indices, b.indices, "{p:?}");
            let bits_a: Vec<u32> = a.values.iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = b.values.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "{p:?}: values must be bit-identical");
        }
    }

    #[test]
    fn jaccard_finish_matches_estimate_on_collected_indices() {
        let a = SparseVec::new(40, vec![(1, 1.0), (2, 1.0), (9, 1.0)]);
        let b = SparseVec::new(40, vec![(2, 1.0), (3, 1.0)]);
        let mut scratch = Vec::new();
        let want = mean_jaccard_estimate(&[&a, &b], &mut scratch);
        let mut collected: Vec<u32> = Vec::new();
        collected.extend_from_slice(&a.indices);
        collected.extend_from_slice(&b.indices);
        let got = jaccard_estimate_finish(2, &mut collected);
        assert_eq!(want.to_bits(), got.to_bits(), "finish must be bit-identical");
        let mut empty: Vec<u32> = Vec::new();
        assert_eq!(jaccard_estimate_finish(2, &mut empty), 1.0);
        assert_eq!(jaccard_estimate_finish(1, &mut empty), 1.0);
    }

    #[test]
    fn sharded_scaled_merge_is_bit_identical_to_sequential() {
        let dim = 50_000;
        let grads: Vec<SparseVec> = (0..8).map(|c| rand_sparse(dim, 8_000, 300 + c)).collect();
        let refs: Vec<&SparseVec> = grads.iter().collect();
        assert!(refs.iter().map(|g| g.nnz()).sum::<usize>() >= super::PARALLEL_MERGE_MIN_NNZ);

        let mut seq = Aggregator::new(dim);
        for g in &refs {
            seq.add_one(g, 0.375); // exactly representable discount
        }
        let a = finish(&mut seq, 8);

        for workers in [2usize, 5, 64] {
            let mut par = Aggregator::new(dim);
            par.add(&refs, 0.375, workers);
            let b = finish(&mut par, 8);
            assert_eq!(a.indices, b.indices, "workers={workers}");
            let bits_a: Vec<u32> = a.values.iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = b.values.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "workers={workers}");
        }
    }

    #[test]
    fn aggregator_resets_between_rounds() {
        let mut agg = Aggregator::new(4);
        agg.add(&[&SparseVec::new(4, vec![(1, 1.0)])], 1.0, 1);
        let _ = finish(&mut agg, 1);
        agg.add(&[&SparseVec::new(4, vec![(2, 5.0)])], 1.0, 1);
        let out = finish(&mut agg, 1);
        assert_eq!(out.indices, vec![2]);
        assert_eq!(out.values, vec![5.0]);
    }

    #[test]
    fn cancellation_drops_zero_entries() {
        let mut agg = Aggregator::new(4);
        agg.add(&[&SparseVec::new(4, vec![(1, 1.0)])], 1.0, 1);
        agg.add(&[&SparseVec::new(4, vec![(1, -1.0)])], 1.0, 1);
        let out = finish(&mut agg, 2);
        assert_eq!(out.nnz(), 0);
    }

    #[test]
    fn union_support() {
        let a = SparseVec::new(10, vec![(1, 1.0), (5, 1.0)]);
        let b = SparseVec::new(10, vec![(5, 1.0), (7, 1.0)]);
        assert_eq!(support_union(&[&a, &b]), vec![1, 5, 7]);
    }

    #[test]
    fn jaccard_values() {
        let a = SparseVec::new(10, vec![(1, 1.0), (2, 1.0)]);
        let b = SparseVec::new(10, vec![(2, 1.0), (3, 1.0)]);
        let c = SparseVec::new(10, vec![(1, 1.0), (2, 1.0)]);
        assert!((mean_pairwise_jaccard(&[&a, &b]) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean_pairwise_jaccard(&[&a, &c]), 1.0);
        assert_eq!(mean_pairwise_jaccard(&[&a]), 1.0);
    }

    #[test]
    fn empty_mean() {
        let mut agg = Aggregator::new(8);
        let out = finish(&mut agg, 0);
        assert_eq!(out.nnz(), 0);
    }

    fn rand_sparse(dim: usize, nnz: usize, seed: u64) -> SparseVec {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut ids: Vec<u32> = (0..dim as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(nnz);
        ids.sort_unstable();
        let vals: Vec<f32> = ids.iter().map(|_| rng.normal()).collect();
        SparseVec::from_sorted(dim, ids, vals)
    }

    #[test]
    fn sharded_merge_is_bit_identical_to_sequential() {
        // total nnz must clear PARALLEL_MERGE_MIN_NNZ so the sharded path runs
        let dim = 50_000;
        let grads: Vec<SparseVec> = (0..8).map(|c| rand_sparse(dim, 8_000, 100 + c)).collect();
        let refs: Vec<&SparseVec> = grads.iter().collect();
        assert!(refs.iter().map(|g| g.nnz()).sum::<usize>() >= super::PARALLEL_MERGE_MIN_NNZ);

        let mut seq = Aggregator::new(dim);
        for g in &refs {
            seq.add_one(g, 1.0);
        }
        let a = finish(&mut seq, 8);

        for workers in [2usize, 3, 5, 64] {
            let mut par = Aggregator::new(dim);
            par.add(&refs, 1.0, workers);
            let b = finish(&mut par, 8);
            assert_eq!(a.indices, b.indices, "workers={workers}");
            let bits_a: Vec<u32> = a.values.iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = b.values.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "workers={workers}: values must be bit-identical");
        }
    }

    #[test]
    fn parallel_finish_is_bit_identical_to_sequential() {
        // touched must clear PARALLEL_MERGE_MIN_NNZ so the sharded emit runs
        let dim = 60_000;
        let grads: Vec<SparseVec> = (0..6).map(|c| rand_sparse(dim, 9_000, 500 + c)).collect();
        let refs: Vec<&SparseVec> = grads.iter().collect();

        let mut seq = Aggregator::new(dim);
        for g in &refs {
            seq.add_one(g, 1.0);
        }
        let mut a = SparseVec::empty(0);
        seq.finish_into(6, &mut a, 1);
        assert!(a.nnz() >= super::PARALLEL_MERGE_MIN_NNZ, "test must exercise the parallel gate");

        for workers in [2usize, 3, 7, 64] {
            let mut par = Aggregator::new(dim);
            for g in &refs {
                par.add_one(g, 1.0);
            }
            let mut b = SparseVec::empty(0);
            par.finish_into(6, &mut b, workers);
            assert_eq!(a.indices, b.indices, "workers={workers}");
            let bits_a: Vec<u32> = a.values.iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = b.values.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "workers={workers}: values must be bit-identical");
            // aggregator must be fully reset afterwards
            let mut empty = SparseVec::empty(0);
            par.finish_into(1, &mut empty, workers);
            assert_eq!(empty.nnz(), 0, "workers={workers}: dirty state must be cleared");
        }
    }

    #[test]
    fn finish_into_reuses_buffers() {
        let mut agg = Aggregator::new(16);
        let mut out = SparseVec::empty(0);
        agg.add(&[&SparseVec::new(16, vec![(1, 2.0), (9, 4.0)])], 1.0, 1);
        agg.finish_into(1, &mut out, 1);
        assert_eq!(out.indices, vec![1, 9]);
        assert_eq!(out.dim, 16);
        let ptr = out.indices.as_ptr();
        agg.add(&[&SparseVec::new(16, vec![(3, 1.0)])], 1.0, 1);
        agg.finish_into(1, &mut out, 1);
        assert_eq!(out.indices, vec![3]);
        assert_eq!(out.indices.as_ptr(), ptr, "warm finish must not reallocate");
    }

    #[test]
    fn jaccard_estimate_exact_for_two_masks_and_identical_masks() {
        let a = SparseVec::new(10, vec![(1, 1.0), (2, 1.0)]);
        let b = SparseVec::new(10, vec![(2, 1.0), (3, 1.0)]);
        let mut scratch = Vec::new();
        let est = mean_jaccard_estimate(&[&a, &b], &mut scratch);
        assert!((est - mean_pairwise_jaccard(&[&a, &b])).abs() < 1e-12);
        let est_same = mean_jaccard_estimate(&[&a, &a, &a], &mut scratch);
        assert_eq!(est_same, 1.0);
        assert_eq!(mean_jaccard_estimate(&[&a], &mut scratch), 1.0);
        let e = SparseVec::empty(10);
        assert_eq!(mean_jaccard_estimate(&[&e, &e], &mut scratch), 1.0);
    }

    #[test]
    fn jaccard_estimate_orders_like_exact_at_equal_k() {
        // three cohorts with increasing true overlap; the estimate must rank
        // them the same way the exact statistic does
        let mk = |shift: u32| -> Vec<SparseVec> {
            (0..6u32)
                .map(|c| {
                    let ids: Vec<u32> = (0..20).map(|j| j * 7 + c * shift).collect();
                    SparseVec::new(1000, ids.into_iter().map(|i| (i, 1.0)).collect())
                })
                .collect()
        };
        let mut scratch = Vec::new();
        let mut last_est = -1.0f64;
        let mut last_exact = -1.0f64;
        for shift in [21u32, 7, 0] {
            let cohort = mk(shift);
            let refs: Vec<&SparseVec> = cohort.iter().collect();
            let est = mean_jaccard_estimate(&refs, &mut scratch);
            let exact = mean_pairwise_jaccard(&refs);
            assert!(est >= last_est, "shift {shift}: est {est} < {last_est}");
            assert!(exact >= last_exact);
            last_est = est;
            last_exact = exact;
        }
        assert_eq!(last_est, 1.0); // shift 0: identical masks
    }
}
