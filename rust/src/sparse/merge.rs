//! Sparse aggregation: the server-side combine of client gradients.
//!
//! `Ĝ_t = (1/K) Σ_k G_{k,t}` where each `G_k` is sparse. The support of the
//! result is the **union** of client supports — the quantity the paper's
//! downlink overhead measures (GMF's whole point is shrinking this union by
//! correlating client masks through the shared global momentum).

use super::vector::SparseVec;

/// Dense-buffer sparse accumulator, reused across rounds (no allocation in
/// the round loop once warm).
pub struct Aggregator {
    acc: Vec<f32>,
    touched: Vec<u32>,
    dirty: Vec<bool>,
}

impl Aggregator {
    pub fn new(dim: usize) -> Self {
        Aggregator { acc: vec![0.0; dim], touched: Vec::new(), dirty: vec![false; dim] }
    }

    pub fn dim(&self) -> usize {
        self.acc.len()
    }

    /// Add one client contribution.
    pub fn add(&mut self, g: &SparseVec) {
        assert_eq!(g.dim, self.acc.len(), "dimension mismatch");
        for (&i, &v) in g.indices.iter().zip(&g.values) {
            let iu = i as usize;
            if !self.dirty[iu] {
                self.dirty[iu] = true;
                self.touched.push(i);
            }
            self.acc[iu] += v;
        }
    }

    /// Finish the round: divide by `count`, emit the union-support sparse
    /// aggregate, and reset for the next round.
    pub fn finish_mean(&mut self, count: usize) -> SparseVec {
        let scale = if count == 0 { 0.0 } else { 1.0 / count as f32 };
        self.touched.sort_unstable();
        let mut indices = Vec::with_capacity(self.touched.len());
        let mut values = Vec::with_capacity(self.touched.len());
        for &i in &self.touched {
            let iu = i as usize;
            let v = self.acc[iu] * scale;
            if v != 0.0 {
                indices.push(i);
                values.push(v);
            }
            self.acc[iu] = 0.0;
            self.dirty[iu] = false;
        }
        self.touched.clear();
        SparseVec::from_sorted(self.dim(), indices, values)
    }
}

/// Union of supports without values (used by broadcast-size analysis).
pub fn support_union(vs: &[&SparseVec]) -> Vec<u32> {
    let mut all: Vec<u32> = vs.iter().flat_map(|v| v.indices.iter().copied()).collect();
    all.sort_unstable();
    all.dedup();
    all
}

/// Mean Jaccard overlap between consecutive client masks — the mask
/// similarity statistic GMF is designed to raise (higher overlap → smaller
/// union → cheaper downlink).
pub fn mean_pairwise_jaccard(vs: &[&SparseVec]) -> f64 {
    if vs.len() < 2 {
        return 1.0;
    }
    let mut total = 0.0;
    let mut pairs = 0usize;
    for i in 0..vs.len() {
        for j in (i + 1)..vs.len() {
            total += jaccard(&vs[i].indices, &vs[j].indices);
            pairs += 1;
        }
    }
    total / pairs as f64
}

fn jaccard(a: &[u32], b: &[u32]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let (mut i, mut j, mut inter) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    let union = a.len() + b.len() - inter;
    inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_of_two() {
        let mut agg = Aggregator::new(6);
        agg.add(&SparseVec::new(6, vec![(0, 2.0), (3, 4.0)]));
        agg.add(&SparseVec::new(6, vec![(3, 2.0), (5, 6.0)]));
        let out = agg.finish_mean(2);
        assert_eq!(out.indices, vec![0, 3, 5]);
        assert_eq!(out.values, vec![1.0, 3.0, 3.0]);
    }

    #[test]
    fn aggregator_resets_between_rounds() {
        let mut agg = Aggregator::new(4);
        agg.add(&SparseVec::new(4, vec![(1, 1.0)]));
        let _ = agg.finish_mean(1);
        agg.add(&SparseVec::new(4, vec![(2, 5.0)]));
        let out = agg.finish_mean(1);
        assert_eq!(out.indices, vec![2]);
        assert_eq!(out.values, vec![5.0]);
    }

    #[test]
    fn cancellation_drops_zero_entries() {
        let mut agg = Aggregator::new(4);
        agg.add(&SparseVec::new(4, vec![(1, 1.0)]));
        agg.add(&SparseVec::new(4, vec![(1, -1.0)]));
        let out = agg.finish_mean(2);
        assert_eq!(out.nnz(), 0);
    }

    #[test]
    fn union_support() {
        let a = SparseVec::new(10, vec![(1, 1.0), (5, 1.0)]);
        let b = SparseVec::new(10, vec![(5, 1.0), (7, 1.0)]);
        assert_eq!(support_union(&[&a, &b]), vec![1, 5, 7]);
    }

    #[test]
    fn jaccard_values() {
        let a = SparseVec::new(10, vec![(1, 1.0), (2, 1.0)]);
        let b = SparseVec::new(10, vec![(2, 1.0), (3, 1.0)]);
        let c = SparseVec::new(10, vec![(1, 1.0), (2, 1.0)]);
        assert!((mean_pairwise_jaccard(&[&a, &b]) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean_pairwise_jaccard(&[&a, &c]), 1.0);
        assert_eq!(mean_pairwise_jaccard(&[&a]), 1.0);
    }

    #[test]
    fn empty_mean() {
        let mut agg = Aggregator::new(8);
        let out = agg.finish_mean(0);
        assert_eq!(out.nnz(), 0);
    }
}
