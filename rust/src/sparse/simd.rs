//! Runtime-dispatched hot-path kernels — SIMD (AVX2/F16C) with bit-identical
//! scalar twins.
//!
//! Every kernel in this module exists in (at least) two forms:
//!
//! * a `_scalar` twin — the reference implementation, always compiled on
//!   every architecture, and exactly the per-element expressions the codec
//!   and decoders historically used;
//! * an accelerated form — explicit AVX2/F16C intrinsics behind
//!   `is_x86_feature_detected!`, or an arch-independent batched loop where
//!   the win is batching itself (varint decode).
//!
//! The un-suffixed entry points dispatch at runtime. **Dispatch never
//! changes bytes**: every accelerated kernel is proven bit-identical to its
//! scalar twin (unit tests here, proptests in `tests/proptests.rs`, and the
//! verify matrix runs under both dispatch modes in CI), so trajectory
//! digests are independent of the selected mode. The non-obvious fixups
//! that buy that identity:
//!
//! * **q8 rounding** — scalar uses `f32::round()` (half away from zero);
//!   SSE rounding is nearest-even. We emulate with `trunc(t)` plus a
//!   `|frac| >= 0.5` step: `t - trunc(t)` is exact (Sterbenz), so the
//!   emulation is exact for all `|t| < 2^24` and clamps identically beyond.
//!   `trunc(t + copysign(0.5, t))` would *not* work: at `t = 0.5 - 2^-25`
//!   the add rounds up to 1.0 before the truncation.
//! * **NaN lanes** — scalar `as i8` saturating casts map NaN to 0 and
//!   `f32::max` ignores NaN operands; vector compares propagate instead,
//!   so NaN lanes are zeroed through an ordered-compare mask first.
//! * **f16 encode** — `_mm256_cvtps_ph` rounds to nearest-even like the
//!   scalar converter, but overflows to ±Inf and quiets NaNs; exponent
//!   all-ones lanes are rewritten to the scalar policy (saturate to
//!   `sign|0x7BFF`, NaN source lanes to 0).
//! * **f16 decode** — `_mm256_cvtph_ps` quiets signalling-NaN wire bytes;
//!   exponent all-ones halves are rebuilt by the scalar bit expression so
//!   adversarial buffers decode identically.
//!
//! ## Mode selection
//!
//! Precedence: the `FEDGMF_KERNELS` environment variable (read once per
//! process) overrides [`set_mode`], which overrides the `Auto` default.
//! `set_mode` is only called from the CLI entry points (`main.rs`) after
//! config parsing — library code never mutates the global, so parallel unit
//! tests all run under one stable mode and compare explicit variants
//! instead. `Scalar` forces every twin; `Simd`/`Auto` both use whatever the
//! CPU supports (the bucketed/batched algorithm layer stays on even without
//! AVX2 — it is arch-independent). See `docs/perf.md`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::codec;
use super::wire::WireError;

/// Kernel dispatch mode (config knob `run.kernels`, env `FEDGMF_KERNELS`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelMode {
    /// Use accelerated kernels where the CPU supports them (default).
    #[default]
    Auto,
    /// Force the scalar twins everywhere (CI determinism legs).
    Scalar,
    /// Request accelerated kernels explicitly (same selection as `Auto`;
    /// spelled out so configs can be self-documenting).
    Simd,
}

impl KernelMode {
    pub fn parse(s: &str) -> Option<KernelMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(KernelMode::Auto),
            "scalar" => Some(KernelMode::Scalar),
            "simd" | "accel" | "avx2" => Some(KernelMode::Simd),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelMode::Auto => "auto",
            KernelMode::Scalar => "scalar",
            KernelMode::Simd => "simd",
        }
    }
}

static MODE: AtomicU8 = AtomicU8::new(0);

fn env_mode() -> Option<KernelMode> {
    static ENV: OnceLock<Option<KernelMode>> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("FEDGMF_KERNELS").ok().as_deref().and_then(KernelMode::parse)
    })
}

/// Install the process-wide dispatch mode. Called from the CLI entry points
/// only; the `FEDGMF_KERNELS` environment variable still wins if set.
pub fn set_mode(mode: KernelMode) {
    let b = match mode {
        KernelMode::Auto => 0,
        KernelMode::Scalar => 1,
        KernelMode::Simd => 2,
    };
    MODE.store(b, Ordering::Relaxed);
}

/// The effective dispatch mode (env override > [`set_mode`] > `Auto`).
pub fn mode() -> KernelMode {
    if let Some(m) = env_mode() {
        return m;
    }
    match MODE.load(Ordering::Relaxed) {
        1 => KernelMode::Scalar,
        2 => KernelMode::Simd,
        _ => KernelMode::Auto,
    }
}

#[derive(Clone, Copy)]
struct Features {
    avx2: bool,
    f16c: bool,
}

fn features() -> Features {
    static F: OnceLock<Features> = OnceLock::new();
    *F.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            Features {
                avx2: is_x86_feature_detected!("avx2"),
                f16c: is_x86_feature_detected!("avx2") && is_x86_feature_detected!("f16c"),
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            Features { avx2: false, f16c: false }
        }
    })
}

/// What the current mode actually enables on this CPU.
#[derive(Clone, Copy, Debug)]
pub struct Active {
    /// Arch-independent accelerated algorithms (bucketed top-k, batched
    /// varint decode). Off only under [`KernelMode::Scalar`].
    pub accel: bool,
    /// AVX2 integer/float kernels (detected and enabled).
    pub avx2: bool,
    /// F16C half-precision conversion kernels (detected and enabled).
    pub f16c: bool,
}

/// Resolve the dispatch decision for this call site.
pub fn active() -> Active {
    let accel = mode() != KernelMode::Scalar;
    let f = features();
    Active { accel, avx2: accel && f.avx2, f16c: accel && f.f16c }
}

/// Human-readable dispatch summary (bench/report provenance): `"scalar"`,
/// `"accel"`, `"accel+avx2"` or `"accel+avx2+f16c"`.
pub fn describe() -> String {
    let a = active();
    if !a.accel {
        return "scalar".into();
    }
    let mut s = String::from("accel");
    if a.avx2 {
        s.push_str("+avx2");
    }
    if a.f16c {
        s.push_str("+f16c");
    }
    s
}

// -------------------------------------------------------------- f16 kernels

/// Append the IEEE binary16 encoding of `values` (2 bytes each, LE).
pub fn f16_encode(values: &[f32], out: &mut Vec<u8>) {
    #[cfg(target_arch = "x86_64")]
    if active().f16c {
        // SAFETY: `f16c` is only set when AVX2+F16C were detected.
        unsafe { f16_encode_f16c(values, out) };
        return;
    }
    f16_encode_scalar(values, out);
}

/// Scalar twin of [`f16_encode`].
pub fn f16_encode_scalar(values: &[f32], out: &mut Vec<u8>) {
    for &v in values {
        out.extend_from_slice(&codec::f32_to_f16_bits(v).to_le_bytes());
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,f16c")]
unsafe fn f16_encode_f16c(values: &[f32], out: &mut Vec<u8>) {
    use core::arch::x86_64::*;
    let expmask = _mm_set1_epi16(0x7C00);
    let signmask = _mm_set1_epi16(0x8000u16 as i16);
    let satval = _mm_set1_epi16(0x7BFF);
    let mut chunks = values.chunks_exact(8);
    for c in chunks.by_ref() {
        let x = _mm256_loadu_ps(c.as_ptr());
        // NaN -> 0.0 first: the scalar converter maps NaN to half 0
        let x = _mm256_and_ps(x, _mm256_cmp_ps(x, x, _CMP_ORD_Q));
        let h = _mm256_cvtps_ph(x, _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC);
        // hardware overflows to +-Inf (exponent all-ones); the scalar
        // converter saturates those lanes to sign|0x7BFF (+-65504)
        let isinf = _mm_cmpeq_epi16(_mm_and_si128(h, expmask), expmask);
        let sat = _mm_or_si128(_mm_and_si128(h, signmask), satval);
        let h = _mm_blendv_epi8(h, sat, isinf);
        let mut bytes = [0u8; 16];
        _mm_storeu_si128(bytes.as_mut_ptr() as *mut __m128i, h);
        out.extend_from_slice(&bytes);
    }
    f16_encode_scalar(chunks.remainder(), out);
}

/// Decode `out.len()` halves from `bytes` (`bytes.len() == 2 * out.len()`).
pub fn f16_decode(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), 2 * out.len());
    #[cfg(target_arch = "x86_64")]
    if active().f16c {
        // SAFETY: `f16c` is only set when AVX2+F16C were detected.
        unsafe { f16_decode_f16c(bytes, out) };
        return;
    }
    f16_decode_scalar(bytes, out);
}

/// Scalar twin of [`f16_decode`].
pub fn f16_decode_scalar(bytes: &[u8], out: &mut [f32]) {
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(2)) {
        *o = codec::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,f16c")]
unsafe fn f16_decode_f16c(bytes: &[u8], out: &mut [f32]) {
    use core::arch::x86_64::*;
    let n = out.len();
    let mut i = 0usize;
    while i + 8 <= n {
        let h = _mm_loadu_si128(bytes.as_ptr().add(2 * i) as *const __m128i);
        let f = _mm256_cvtph_ps(h);
        // exponent all-ones halves (inf/NaN wire bytes) must decode by the
        // exact scalar expression sign|0x7F800000|(man<<13): the hardware
        // conversion quiets signalling-NaN payloads, the scalar one doesn't
        let w = _mm256_cvtepu16_epi32(h);
        let exp = _mm256_and_si256(w, _mm256_set1_epi32(0x7C00));
        let special = _mm256_cmpeq_epi32(exp, _mm256_set1_epi32(0x7C00));
        let sign = _mm256_slli_epi32(_mm256_and_si256(w, _mm256_set1_epi32(0x8000)), 16);
        let man = _mm256_slli_epi32(_mm256_and_si256(w, _mm256_set1_epi32(0x03FF)), 13);
        let manual = _mm256_or_si256(sign, _mm256_or_si256(_mm256_set1_epi32(0x7F80_0000), man));
        let bits = _mm256_blendv_epi8(_mm256_castps_si256(f), manual, special);
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, bits);
        i += 8;
    }
    f16_decode_scalar(&bytes[2 * i..], &mut out[i..]);
}

// --------------------------------------------------------------- q8 kernels

/// Max |v| over `values` with `f32::max` NaN-ignoring semantics (the q8
/// block scale numerator).
pub fn maxabs(values: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if active().avx2 && values.len() >= 8 {
        // SAFETY: `avx2` is only set when AVX2 was detected.
        return unsafe { maxabs_avx2(values) };
    }
    maxabs_scalar(values)
}

/// Scalar twin of [`maxabs`].
pub fn maxabs_scalar(values: &[f32]) -> f32 {
    values.iter().fold(0.0f32, |a, &v| a.max(v.abs()))
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn maxabs_avx2(values: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
    let mut acc = _mm256_setzero_ps();
    let mut chunks = values.chunks_exact(8);
    for c in chunks.by_ref() {
        let x = _mm256_loadu_ps(c.as_ptr());
        // f32::max ignores NaN operands; maxps would propagate its second
        // operand, so zero NaN lanes first (max with 0 is the identity on
        // the non-negative accumulator)
        let x = _mm256_and_ps(x, _mm256_cmp_ps(x, x, _CMP_ORD_Q));
        acc = _mm256_max_ps(acc, _mm256_and_ps(x, absmask));
    }
    let mut lanes = [0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut m = lanes.iter().fold(0.0f32, |a, &v| a.max(v));
    for &v in chunks.remainder() {
        m = m.max(v.abs());
    }
    m
}

/// Append the q8 codes of one block: `(v * 127/maxabs).round()` clamped to
/// [-127, 127], cast `as i8 as u8` (NaN -> 0). Caller writes the scale
/// prefix and handles the all-zero-block (`maxabs == 0`) case.
pub fn q8_quantize(block: &[f32], maxabs: f32, out: &mut Vec<u8>) {
    debug_assert!(maxabs > 0.0);
    #[cfg(target_arch = "x86_64")]
    if active().avx2 && block.len() >= 8 {
        // SAFETY: `avx2` is only set when AVX2 was detected.
        unsafe { q8_quantize_avx2(block, maxabs, out) };
        return;
    }
    q8_quantize_scalar(block, maxabs, out);
}

/// Scalar twin of [`q8_quantize`].
pub fn q8_quantize_scalar(block: &[f32], maxabs: f32, out: &mut Vec<u8>) {
    let inv = 127.0 / maxabs;
    for &v in block {
        // saturating float->int cast: NaN -> 0, out-of-range clamps —
        // quantised code stays in [-127, 127]
        out.push((v * inv).round().clamp(-127.0, 127.0) as i8 as u8);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn q8_quantize_avx2(block: &[f32], maxabs: f32, out: &mut Vec<u8>) {
    use core::arch::x86_64::*;
    let inv = _mm256_set1_ps(127.0 / maxabs);
    let signmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x8000_0000u32 as i32));
    let half = _mm256_set1_ps(0.5);
    let one = _mm256_set1_ps(1.0);
    let hi = _mm256_set1_ps(127.0);
    let lo = _mm256_set1_ps(-127.0);
    let mut chunks = block.chunks_exact(8);
    for c in chunks.by_ref() {
        let x = _mm256_loadu_ps(c.as_ptr());
        // NaN -> 0 (the scalar saturating cast maps NaN to 0)
        let x = _mm256_and_ps(x, _mm256_cmp_ps(x, x, _CMP_ORD_Q));
        let t = _mm256_mul_ps(x, inv);
        // round half away from zero: trunc(t) + copysign(1, t)·[|t-trunc(t)| >= 0.5]
        // — the fraction is exact (Sterbenz), so this matches f32::round for
        // every |t| < 2^24 and both paths clamp identically beyond
        let r = _mm256_round_ps(t, _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC);
        let frac = _mm256_sub_ps(t, r);
        let fabs = _mm256_andnot_ps(signmask, frac);
        let ge = _mm256_cmp_ps(fabs, half, _CMP_GE_OQ);
        let step = _mm256_or_ps(_mm256_and_ps(ge, one), _mm256_and_ps(t, signmask));
        let r = _mm256_add_ps(r, step);
        let r = _mm256_max_ps(_mm256_min_ps(r, hi), lo);
        let q = _mm256_cvttps_epi32(r);
        let p16 = _mm_packs_epi32(_mm256_castsi256_si128(q), _mm256_extracti128_si256(q, 1));
        let p8 = _mm_packs_epi16(p16, p16);
        let mut bytes = [0u8; 16];
        _mm_storeu_si128(bytes.as_mut_ptr() as *mut __m128i, p8);
        out.extend_from_slice(&bytes[..8]);
    }
    q8_quantize_scalar(chunks.remainder(), maxabs, out);
}

/// Dequantize one q8 block: `(b as i8) as f32 * scale` per byte. `scale`
/// comes straight off the wire (0, Inf or NaN behave like the scalar
/// decoder by construction — same multiply, same operand order).
pub fn q8_dequantize(bytes: &[u8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    if active().avx2 && bytes.len() >= 8 {
        // SAFETY: `avx2` is only set when AVX2 was detected.
        unsafe { q8_dequantize_avx2(bytes, scale, out) };
        return;
    }
    q8_dequantize_scalar(bytes, scale, out);
}

/// Scalar twin of [`q8_dequantize`].
pub fn q8_dequantize_scalar(bytes: &[u8], scale: f32, out: &mut [f32]) {
    for (o, &b) in out.iter_mut().zip(bytes) {
        *o = (b as i8) as f32 * scale;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn q8_dequantize_avx2(bytes: &[u8], scale: f32, out: &mut [f32]) {
    use core::arch::x86_64::*;
    let s = _mm256_set1_ps(scale);
    let n = out.len();
    let mut i = 0usize;
    while i + 8 <= n {
        let b = _mm_loadl_epi64(bytes.as_ptr().add(i) as *const __m128i);
        let w = _mm256_cvtepi8_epi32(b);
        let v = _mm256_mul_ps(_mm256_cvtepi32_ps(w), s);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), v);
        i += 8;
    }
    q8_dequantize_scalar(&bytes[i..], scale, &mut out[i..]);
}

// ----------------------------------------------------------- varint kernels

/// Append the delta-varint coding of a sorted-unique index stream (first
/// gap = first index, later gaps = difference to the previous index).
pub fn varint_encode_gaps(indices: &[u32], out: &mut Vec<u8>) {
    #[cfg(target_arch = "x86_64")]
    if active().avx2 {
        // SAFETY: `avx2` is only set when AVX2 was detected.
        unsafe { varint_encode_gaps_avx2(indices, out) };
        return;
    }
    varint_encode_gaps_scalar(indices, out);
}

/// Scalar twin of [`varint_encode_gaps`].
pub fn varint_encode_gaps_scalar(indices: &[u32], out: &mut Vec<u8>) {
    let mut prev = 0u32;
    for &i in indices {
        codec::push_varint(out, i - prev);
        prev = i;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn varint_encode_gaps_avx2(indices: &[u32], out: &mut Vec<u8>) {
    use core::arch::x86_64::*;
    let n = indices.len();
    if n == 0 {
        return;
    }
    codec::push_varint(out, indices[0]);
    // bits above the low 7 — unsigned-safe single-byte test (gaps >= 2^31
    // must not slip through a signed compare)
    let big = _mm256_set1_epi32(!0x7Fi32);
    let mut j = 1usize;
    while j + 8 <= n {
        let cur = _mm256_loadu_si256(indices.as_ptr().add(j) as *const __m256i);
        let prv = _mm256_loadu_si256(indices.as_ptr().add(j - 1) as *const __m256i);
        let g = _mm256_sub_epi32(cur, prv);
        if _mm256_testz_si256(g, big) != 0 {
            // eight single-byte varints at once
            let p16 = _mm_packus_epi32(_mm256_castsi256_si128(g), _mm256_extracti128_si256(g, 1));
            let p8 = _mm_packus_epi16(p16, p16);
            let mut bytes = [0u8; 16];
            _mm_storeu_si128(bytes.as_mut_ptr() as *mut __m128i, p8);
            out.extend_from_slice(&bytes[..8]);
        } else {
            let mut gs = [0u32; 8];
            _mm256_storeu_si256(gs.as_mut_ptr() as *mut __m256i, g);
            for &gap in &gs {
                codec::push_varint(out, gap);
            }
        }
        j += 8;
    }
    let mut prev = indices[j - 1];
    for &i in &indices[j..] {
        codec::push_varint(out, i - prev);
        prev = i;
    }
}

/// Exact byte length [`varint_encode_gaps`] will append.
pub fn varint_gaps_bytes(indices: &[u32]) -> usize {
    #[cfg(target_arch = "x86_64")]
    if active().avx2 {
        // SAFETY: `avx2` is only set when AVX2 was detected.
        return unsafe { varint_gaps_bytes_avx2(indices) };
    }
    varint_gaps_bytes_scalar(indices)
}

/// Scalar twin of [`varint_gaps_bytes`].
pub fn varint_gaps_bytes_scalar(indices: &[u32]) -> usize {
    let mut total = 0;
    let mut prev = 0u32;
    for &i in indices {
        total += codec::varint_len(i - prev);
        prev = i;
    }
    total
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn varint_gaps_bytes_avx2(indices: &[u32]) -> usize {
    use core::arch::x86_64::*;
    let n = indices.len();
    if n == 0 {
        return 0;
    }
    let mut total = codec::varint_len(indices[0]);
    let big = _mm256_set1_epi32(!0x7Fi32);
    let mut j = 1usize;
    while j + 8 <= n {
        let cur = _mm256_loadu_si256(indices.as_ptr().add(j) as *const __m256i);
        let prv = _mm256_loadu_si256(indices.as_ptr().add(j - 1) as *const __m256i);
        let g = _mm256_sub_epi32(cur, prv);
        if _mm256_testz_si256(g, big) != 0 {
            total += 8;
        } else {
            let mut gs = [0u32; 8];
            _mm256_storeu_si256(gs.as_mut_ptr() as *mut __m256i, g);
            for &gap in &gs {
                total += codec::varint_len(gap);
            }
        }
        j += 8;
    }
    let mut prev = indices[j - 1];
    for &i in &indices[j..] {
        total += codec::varint_len(i - prev);
        prev = i;
    }
    total
}

/// Decode up to `gaps.len()` LEB128 varints starting at `*pos`, batching
/// runs of single-byte varints eight at a time. Returns the count decoded
/// and, if the stream stopped early, the same [`WireError`] the scalar
/// `read_varint` loop would have produced at the same position — callers
/// preserving error order must check the decoded prefix before surfacing
/// the error (see `codec::walk_varint_indices`).
pub fn varint_decode_gaps(
    buf: &[u8],
    pos: &mut usize,
    gaps: &mut [u32],
) -> (usize, Option<WireError>) {
    if !active().accel {
        return varint_decode_gaps_scalar(buf, pos, gaps);
    }
    #[cfg(target_arch = "x86_64")]
    if active().avx2 {
        // SAFETY: `avx2` is only set when AVX2 was detected.
        return unsafe { varint_decode_gaps_avx2(buf, pos, gaps) };
    }
    varint_decode_gaps_swar(buf, pos, gaps)
}

/// Scalar twin of [`varint_decode_gaps`]: one `read_varint` per slot.
pub fn varint_decode_gaps_scalar(
    buf: &[u8],
    pos: &mut usize,
    gaps: &mut [u32],
) -> (usize, Option<WireError>) {
    for (t, g) in gaps.iter_mut().enumerate() {
        match codec::read_varint(buf, pos) {
            Ok(x) => *g = x,
            Err(e) => return (t, Some(e)),
        }
    }
    (gaps.len(), None)
}

/// High-bit test mask: a u64 window of eight bytes is eight complete
/// single-byte varints iff no byte has its continuation bit set.
const CONT_BITS: u64 = 0x8080_8080_8080_8080;

fn varint_decode_gaps_swar(
    buf: &[u8],
    pos: &mut usize,
    gaps: &mut [u32],
) -> (usize, Option<WireError>) {
    let n = gaps.len();
    let mut t = 0usize;
    while t + 8 <= n && *pos + 8 <= buf.len() {
        let word = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
        if word & CONT_BITS == 0 {
            for (k, g) in gaps[t..t + 8].iter_mut().enumerate() {
                *g = buf[*pos + k] as u32;
            }
            *pos += 8;
            t += 8;
        } else {
            match codec::read_varint(buf, pos) {
                Ok(x) => {
                    gaps[t] = x;
                    t += 1;
                }
                Err(e) => return (t, Some(e)),
            }
        }
    }
    while t < n {
        match codec::read_varint(buf, pos) {
            Ok(x) => {
                gaps[t] = x;
                t += 1;
            }
            Err(e) => return (t, Some(e)),
        }
    }
    (n, None)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn varint_decode_gaps_avx2(
    buf: &[u8],
    pos: &mut usize,
    gaps: &mut [u32],
) -> (usize, Option<WireError>) {
    use core::arch::x86_64::*;
    let n = gaps.len();
    let mut t = 0usize;
    while t + 8 <= n && *pos + 8 <= buf.len() {
        let word = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
        if word & CONT_BITS == 0 {
            let b = _mm_loadl_epi64(buf.as_ptr().add(*pos) as *const __m128i);
            let w = _mm256_cvtepu8_epi32(b);
            _mm256_storeu_si256(gaps.as_mut_ptr().add(t) as *mut __m256i, w);
            *pos += 8;
            t += 8;
        } else {
            match codec::read_varint(buf, pos) {
                Ok(x) => {
                    gaps[t] = x;
                    t += 1;
                }
                Err(e) => return (t, Some(e)),
            }
        }
    }
    while t < n {
        match codec::read_varint(buf, pos) {
            Ok(x) => {
                gaps[t] = x;
                t += 1;
            }
            Err(e) => return (t, Some(e)),
        }
    }
    (n, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // Every test here compares the *dispatched* kernel against its scalar
    // twin: under FEDGMF_KERNELS=scalar the comparison is trivially true,
    // under auto/simd it proves the accelerated path bit-identical on this
    // CPU. No test mutates the global mode (parallel tests share it).

    fn adversarial_values() -> Vec<f32> {
        let mut v = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.5,
            -0.5,
            f32::from_bits(0.5f32.to_bits() - 1), // just below 0.5: the
            // trunc(t + 0.5) emulation would round this up
            -f32::from_bits(0.5f32.to_bits() - 1),
            65504.0,
            65520.0,
            -65520.0,
            1e9,
            -1e9,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            -f32::NAN,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            f32::from_bits(1),    // smallest subnormal
            f32::from_bits(0x42), // subnormal
            6.1e-5,
            5.9e-8,
            126.5,
            -126.5,
            127.49,
            -127.49,
        ];
        let mut rng = Rng::new(0xA11CE);
        for _ in 0..500 {
            v.push(rng.normal() * 10f32.powi(rng.below(12) as i32 - 6));
        }
        v
    }

    #[test]
    fn mode_parse_and_names() {
        for m in [KernelMode::Auto, KernelMode::Scalar, KernelMode::Simd] {
            assert_eq!(KernelMode::parse(m.name()), Some(m));
        }
        assert_eq!(KernelMode::parse("accel"), Some(KernelMode::Simd));
        assert_eq!(KernelMode::parse("bogus"), None);
        // describe() names the scalar twin exactly when accel is off
        assert_eq!(describe() == "scalar", !active().accel);
    }

    #[test]
    fn f16_encode_matches_scalar() {
        let vals = adversarial_values();
        // sweep offsets so chunk remainders of every length get exercised
        for off in 0..9 {
            let v = &vals[off..];
            let mut a = Vec::new();
            let mut b = Vec::new();
            f16_encode(v, &mut a);
            f16_encode_scalar(v, &mut b);
            assert_eq!(a, b, "offset {off}");
        }
    }

    #[test]
    fn f16_decode_matches_scalar_on_arbitrary_halves() {
        // include inf/NaN half patterns — wire bytes are adversarial
        let mut bytes = Vec::new();
        for h in [0x0000u16, 0x8000, 0x3C00, 0x7BFF, 0x7C00, 0xFC00, 0x7C01, 0xFE00, 0x03FF] {
            bytes.extend_from_slice(&h.to_le_bytes());
        }
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..1000 {
            bytes.extend_from_slice(&(rng.next_u64() as u16).to_le_bytes());
        }
        for off in 0..9 {
            let body = &bytes[2 * off..];
            let n = body.len() / 2;
            let mut a = vec![0f32; n];
            let mut b = vec![0f32; n];
            f16_decode(body, &mut a);
            f16_decode_scalar(body, &mut b);
            let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb, "offset {off}: decode must be bit-identical");
        }
    }

    #[test]
    fn maxabs_matches_scalar() {
        let vals = adversarial_values();
        for off in 0..9 {
            let v = &vals[off..];
            assert_eq!(maxabs(v).to_bits(), maxabs_scalar(v).to_bits(), "offset {off}");
        }
        assert_eq!(maxabs(&[]), 0.0);
        assert_eq!(maxabs(&[f32::NAN; 32]), 0.0, "all-NaN folds to the 0 identity");
    }

    #[test]
    fn q8_quantize_matches_scalar() {
        // blocks built so t = v * 127/maxabs hits exact .5 boundaries and
        // the just-below-.5 rounding trap
        let mut block = vec![127.0f32, -127.0];
        for k in 0..60 {
            block.push(k as f32 + 0.5);
            block.push(-(k as f32) - 0.5);
            block.push(k as f32 + 0.5 - f32::EPSILON * 32.0);
        }
        block.push(f32::from_bits(0.5f32.to_bits() - 1));
        block.push(126.5);
        block.push(127.49);
        block.push(f32::NAN);
        let mut rng = Rng::new(0xC0DE);
        for _ in 0..300 {
            block.push(rng.normal() * 40.0);
        }
        for off in 0..9 {
            let b = &block[off..];
            let m = maxabs_scalar(b);
            let mut qa = Vec::new();
            let mut qb = Vec::new();
            q8_quantize(b, m, &mut qa);
            q8_quantize_scalar(b, m, &mut qb);
            assert_eq!(qa, qb, "offset {off} maxabs {m}");
        }
    }

    #[test]
    fn q8_dequantize_matches_scalar() {
        let mut rng = Rng::new(0xD0D0);
        let bytes: Vec<u8> = (0..800).map(|_| rng.next_u64() as u8).collect();
        for scale in [0.017f32, 1.0, 0.0, -3.5, f32::INFINITY, f32::NAN] {
            for off in 0..9 {
                let b = &bytes[off..];
                let mut a = vec![0f32; b.len()];
                let mut c = vec![0f32; b.len()];
                q8_dequantize(b, scale, &mut a);
                q8_dequantize_scalar(b, scale, &mut c);
                let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let cb: Vec<u32> = c.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ab, cb, "scale {scale} offset {off}");
            }
        }
    }

    fn adversarial_indices() -> Vec<Vec<u32>> {
        let mut rng = Rng::new(0x1D);
        let mut sets = vec![
            vec![],
            vec![0],
            vec![u32::MAX - 1],
            (0..1000u32).collect(),
            // gap >= 2^31: breaks signed single-byte tests
            vec![5, 10, 11, 12, 13, 14, 15, 16, 17, (1u32 << 31) + 9],
            vec![0x7FFF_FFFF, 0xFFFF_FFFE],
            (0..64u32).map(|i| i * 127).collect(),
            (0..64u32).map(|i| i * 128).collect(),
        ];
        for _ in 0..20 {
            let n = 1 + rng.below(300);
            let mut ids = Vec::with_capacity(n);
            let mut acc = 0u64;
            for _ in 0..n {
                // mixed small/large gaps, crossing every varint width
                acc += 1 + rng.next_u64() % (1u64 << (1 + rng.below(20)));
                if acc > u32::MAX as u64 {
                    break;
                }
                ids.push(acc as u32);
            }
            sets.push(ids);
        }
        sets
    }

    #[test]
    fn varint_encode_and_size_match_scalar() {
        for ids in adversarial_indices() {
            let mut a = Vec::new();
            let mut b = Vec::new();
            varint_encode_gaps(&ids, &mut a);
            varint_encode_gaps_scalar(&ids, &mut b);
            assert_eq!(a, b, "n={}", ids.len());
            assert_eq!(varint_gaps_bytes(&ids), a.len());
            assert_eq!(varint_gaps_bytes_scalar(&ids), a.len());
        }
    }

    #[test]
    fn varint_decode_matches_scalar_and_roundtrips() {
        for ids in adversarial_indices() {
            let mut buf = Vec::new();
            varint_encode_gaps_scalar(&ids, &mut buf);
            let n = ids.len();
            let mut ga = vec![0u32; n];
            let mut gb = vec![0u32; n];
            let (mut pa, mut pb) = (0usize, 0usize);
            let (ca, ea) = varint_decode_gaps(&buf, &mut pa, &mut ga);
            let (cb, eb) = varint_decode_gaps_scalar(&buf, &mut pb, &mut gb);
            assert_eq!((ca, pa), (cb, pb), "n={n}");
            assert!(ea.is_none() && eb.is_none());
            assert_eq!(ga, gb, "n={n}");
            // gaps reconstruct the original indices
            let mut acc = 0u64;
            let back: Vec<u32> = ga
                .iter()
                .enumerate()
                .map(|(i, &g)| {
                    acc = if i == 0 { g as u64 } else { acc + g as u64 };
                    acc as u32
                })
                .collect();
            assert_eq!(back, ids);
        }
    }

    #[test]
    fn varint_decode_errors_match_scalar() {
        // truncations and malformed tails at every cut of a mixed stream
        let ids: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8, 200, 300, 70000, 70001, (1 << 30) + 5];
        let mut buf = Vec::new();
        varint_encode_gaps_scalar(&ids, &mut buf);
        for cut in 0..buf.len() {
            let short = &buf[..cut];
            let mut ga = vec![0u32; ids.len()];
            let mut gb = vec![0u32; ids.len()];
            let (mut pa, mut pb) = (0usize, 0usize);
            let (ca, ea) = varint_decode_gaps(short, &mut pa, &mut ga);
            let (cb, eb) = varint_decode_gaps_scalar(short, &mut pb, &mut gb);
            assert_eq!((ca, pa), (cb, pb), "cut {cut}");
            assert_eq!(format!("{ea:?}"), format!("{eb:?}"), "cut {cut}");
            assert_eq!(ga[..ca], gb[..cb], "cut {cut}");
        }
        // overlong varint mid-stream
        let mut bad = buf.clone();
        bad.splice(4..4, [0xFFu8, 0xFF, 0xFF, 0xFF, 0x7F]);
        let mut ga = vec![0u32; ids.len()];
        let mut gb = vec![0u32; ids.len()];
        let (mut pa, mut pb) = (0usize, 0usize);
        let (ca, ea) = varint_decode_gaps(&bad, &mut pa, &mut ga);
        let (cb, eb) = varint_decode_gaps_scalar(&bad, &mut pb, &mut gb);
        assert_eq!((ca, pa), (cb, pb));
        assert_eq!(format!("{ea:?}"), format!("{eb:?}"));
    }
}
