//! Top-k selection — the L3 hot path of every compression scheme.
//!
//! Each client, each round, selects the k largest-score coordinates out of P
//! (P ≈ 10^5..10^6, k = rate·P). We provide:
//!
//! * [`threshold_exact`] — exact k-th largest score. Dispatches between two
//!   value-identical kernels (`sparse::simd::active()`):
//!   [`threshold_exact_bucketed`] — a 256-bucket histogram over the f32
//!   sort-key's top byte (sign+exponent) walked from the top, quickselecting
//!   only inside the boundary bucket, so the full-copy quickselect shrinks
//!   to one counting pass plus a small gather — and
//!   [`threshold_exact_quickselect`] — the scalar fallback (full copy,
//!   iterative quickselect, median-of-three pivots, O(P) expected).
//! * [`threshold_sampled`] — DGC's trick: estimate the threshold from a
//!   deterministic sample, then correct by counting; falls back to exact
//!   refinement only on the (rare) underflow. Used by the perf-tuned path.
//!   Its two internal selections dispatch the same way.
//! * [`select_topk`] — mask extraction at a threshold with an exact-k tie
//!   policy (first-index-wins, matching `jax.lax.top_k` determinism closely
//!   enough for the equivalence tests, which compare sets at distinct scores).
//!
//! Both threshold kernels return the same *value* for the same input (the
//! k-th largest element of a multiset does not depend on the algorithm;
//! ties across the ±0.0 bucket boundary compare equal under `>=`, which is
//! all downstream selection uses), so dispatch never changes a trajectory.
//! NaN scores are outside the contract of every function here, exactly as
//! they were for the quickselect-only implementation.

use super::simd;
use crate::util::rng::splitmix64;

/// Exact value of the k-th largest element (1-based: k=1 → max).
/// Returns `f32::INFINITY` for k == 0 (a threshold no score can clear, so
/// nothing is selected) and the minimum for k >= len.
pub fn threshold_exact(scores: &[f32], k: usize, scratch: &mut Vec<f32>) -> f32 {
    if simd::active().accel {
        threshold_exact_bucketed(scores, k, scratch)
    } else {
        threshold_exact_quickselect(scores, k, scratch)
    }
}

/// Scalar twin of [`threshold_exact`]: full copy into `scratch`, iterative
/// quickselect.
pub fn threshold_exact_quickselect(scores: &[f32], k: usize, scratch: &mut Vec<f32>) -> f32 {
    if k == 0 {
        return f32::INFINITY;
    }
    if k >= scores.len() {
        return scores.iter().cloned().fold(f32::INFINITY, f32::min);
    }
    scratch.clear();
    scratch.extend_from_slice(scores);
    let kth_from_start = scores.len() - k; // k-th largest == (n-k)-th smallest (0-based)
    *order_stat(scratch, kth_from_start)
}

/// Bucketed/histogram k-th largest: bin every score by the top byte of its
/// total-order sort key (sign bit + exponent, 256 buckets), walk buckets
/// from the top until the k-th element's bucket is found, then gather only
/// that boundary bucket into `scratch` and quickselect inside it. One
/// branch-free counting pass over `scores` replaces the full copy, and the
/// quickselect runs on the boundary bucket only (tiny for the exponent
/// spread of real gradient scores; the degenerate single-exponent case
/// degrades gracefully to the old cost).
pub fn threshold_exact_bucketed(scores: &[f32], k: usize, scratch: &mut Vec<f32>) -> f32 {
    if k == 0 {
        return f32::INFINITY;
    }
    if k >= scores.len() {
        return scores.iter().cloned().fold(f32::INFINITY, f32::min);
    }
    let mut counts = [0u32; 256];
    for &s in scores {
        counts[bucket(s)] += 1;
    }
    let (b, remaining) = boundary_bucket(&counts, k);
    scratch.clear();
    scratch.extend(scores.iter().copied().filter(|&s| bucket(s) == b));
    let idx = scratch.len() - remaining;
    *order_stat(scratch, idx)
}

/// Monotone u32 sort key: `key(a) < key(b)` iff `a < b` as floats (negative
/// range flipped, positive range offset). ±0.0 get distinct keys (buckets
/// 0x7F and 0x80) — harmless, since the boundary value is only ever used
/// through `>=` comparisons where -0.0 == +0.0.
#[inline]
fn sort_key(s: f32) -> u32 {
    let b = s.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    }
}

#[inline]
fn bucket(s: f32) -> usize {
    (sort_key(s) >> 24) as usize
}

/// Walk buckets top-down; returns the bucket holding the k-th largest
/// element and how many of the k largest live in it (1-based from the
/// bucket's top). `counts` must sum to ≥ k.
fn boundary_bucket(counts: &[u32; 256], k: usize) -> (usize, usize) {
    let mut remaining = k;
    let mut b = 255usize;
    loop {
        let c = counts[b] as usize;
        if c >= remaining {
            return (b, remaining);
        }
        remaining -= c;
        b -= 1;
    }
}

/// k-th largest (1 ≤ k ≤ len) of `buf`, consuming its contents: histogram,
/// then compact the boundary bucket to the front (`retain`) and quickselect
/// inside it. The in-scratch selections of [`threshold_sampled`] use this
/// under accel dispatch instead of a full quickselect.
fn kth_largest_inplace(buf: &mut Vec<f32>, k: usize) -> f32 {
    debug_assert!(k >= 1 && k <= buf.len());
    let mut counts = [0u32; 256];
    for &s in buf.iter() {
        counts[bucket(s)] += 1;
    }
    let (b, remaining) = boundary_bucket(&counts, k);
    buf.retain(|&s| bucket(s) == b);
    let idx = buf.len() - remaining;
    *order_stat(buf, idx)
}

/// Iterative quickselect for the idx-th smallest (0-based) element.
fn order_stat(buf: &mut [f32], idx: usize) -> &f32 {
    let (mut lo, mut hi) = (0usize, buf.len());
    loop {
        debug_assert!(lo <= idx && idx < hi);
        if hi - lo <= 8 {
            buf[lo..hi].sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            return &buf[idx];
        }
        // median-of-three pivot
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (buf[lo], buf[mid], buf[hi - 1]);
        let pivot = median3(a, b, c);

        // three-way partition (Dutch flag) to be robust against duplicates
        let (mut lt, mut i, mut gt) = (lo, lo, hi);
        while i < gt {
            if buf[i] < pivot {
                buf.swap(lt, i);
                lt += 1;
                i += 1;
            } else if buf[i] > pivot {
                gt -= 1;
                buf.swap(i, gt);
            } else {
                i += 1;
            }
        }
        if idx < lt {
            hi = lt;
        } else if idx >= gt {
            lo = gt;
        } else {
            return &buf[idx]; // inside the == pivot run
        }
    }
}

fn median3(a: f32, b: f32, c: f32) -> f32 {
    a.max(b).min(a.min(b).max(c))
}

/// DGC-style sampled threshold estimation — *exact* result, sampled speed.
///
/// Samples `max(1024, P/100)` scores deterministically and picks a
/// deliberately *low* candidate threshold (targeting ~2k survivors), so that
/// the survivor set almost surely contains the true top-k; the exact k-th
/// largest is then selected among the survivors only (≈2k ≪ P elements).
///
/// **Determinism contract of `seed`:** the returned threshold always equals
/// [`threshold_exact`]'s for the same `scores`/`k`, *for every seed* — the
/// seed only decorrelates which elements feed the candidate estimate
/// (callers pass the round number), so it is purely a performance knob: a
/// resonant sampling pattern can only cost a slower refinement pass, never
/// a different result. Sampling is strided with a per-slot jittered offset
/// (sequential memory order, one `splitmix64` per slot) rather than a
/// random gather, which keeps the pass prefetch-friendly and avoids the
/// per-call PRNG construction the previous implementation paid.
///
/// On undershoot (the candidate overshot the true threshold — heavy ties
/// or an adversarial distribution) the survivor set is topped up with the
/// remaining scores in place, so the fallback costs one extra filter pass
/// over `scores` instead of a second full clear-and-copy.
pub fn threshold_sampled(scores: &[f32], k: usize, seed: u64, scratch: &mut Vec<f32>) -> f32 {
    let n = scores.len();
    if k == 0 {
        return f32::INFINITY;
    }
    if k >= n {
        return scores.iter().cloned().fold(f32::INFINITY, f32::min);
    }
    let accel = simd::active().accel;
    let sample_n = (n / 100).max(1024).min(n);
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    scratch.clear();
    // only the sample lives here until the survivor pass (~2k elements)
    // replaces it — that pass and the rare top-up grow the buffer on demand
    scratch.reserve(sample_n);
    for s in 0..sample_n {
        // one jittered pick per stratum [s·n/N, (s+1)·n/N): sequential
        // memory order, full-range coverage, no per-call PRNG state
        let lo = s * n / sample_n;
        let hi = ((s + 1) * n / sample_n).max(lo + 1);
        let jitter = (splitmix64(&mut h) % (hi - lo) as u64) as usize;
        scratch.push(scores[lo + jitter]);
    }
    // target 2k survivors (safety margin against sampling noise)
    let k_sample = ((2.0 * k as f64) * (sample_n as f64) / (n as f64)).ceil() as usize;
    let k_sample = k_sample.clamp(1, sample_n);
    let candidate = if accel {
        kth_largest_inplace(scratch, k_sample)
    } else {
        *order_stat(scratch, sample_n - k_sample)
    };

    scratch.clear();
    scratch.extend(scores.iter().cloned().filter(|&s| s >= candidate));
    if scratch.len() < k {
        // undershoot: top up with the non-survivors — scratch then holds a
        // permutation of all of `scores` and the select below is the full
        // exact one
        scratch.extend(scores.iter().cloned().filter(|&s| s < candidate));
        if scratch.len() < n {
            // non-finite scores defeated the two-way partition; preserve
            // the legacy exact-fallback behaviour
            return threshold_exact(scores, k, scratch);
        }
    }
    if accel {
        kth_largest_inplace(scratch, k)
    } else {
        let idx = scratch.len() - k;
        *order_stat(scratch, idx)
    }
}

/// Collect the indices whose score clears `threshold` into a reusable
/// buffer, capped at `k` (first-index-wins on ties). Indices come out
/// sorted; `out` keeps its capacity across calls (no allocation when warm).
pub fn select_at_threshold_into(scores: &[f32], threshold: f32, k: usize, out: &mut Vec<u32>) {
    out.clear();
    if k == 0 {
        // k == 0 must select nothing even for scores that clear an infinite
        // threshold (s == +INF satisfies s >= f32::INFINITY)
        return;
    }
    for (i, &s) in scores.iter().enumerate() {
        if s >= threshold {
            out.push(i as u32);
            if out.len() == k {
                break;
            }
        }
    }
}

/// Allocating convenience wrapper over [`select_at_threshold_into`].
pub fn select_at_threshold(scores: &[f32], threshold: f32, k: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(k.min(scores.len()));
    select_at_threshold_into(scores, threshold, k, &mut out);
    out
}

/// Convenience: exact top-k indices of `scores` (sorted ascending by index).
pub fn select_topk(scores: &[f32], k: usize) -> Vec<u32> {
    let mut scratch = Vec::new();
    let t = threshold_exact(scores, k, &mut scratch);
    select_at_threshold(scores, t, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn brute_topk(scores: &[f32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut top: Vec<u32> = idx.into_iter().take(k).collect();
        top.sort_unstable();
        top
    }

    #[test]
    fn exact_threshold_matches_sort() {
        let mut rng = Rng::new(1);
        for n in [1usize, 2, 7, 100, 1000] {
            let scores: Vec<f32> = (0..n).map(|_| rng.normal().abs()).collect();
            let mut sorted = scores.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut scratch = Vec::new();
            for k in [1usize, n / 2, n] {
                if k == 0 || k > n {
                    continue;
                }
                let t = threshold_exact(&scores, k, &mut scratch);
                assert_eq!(t, sorted[k - 1], "n={n} k={k}");
            }
        }
    }

    #[test]
    fn exact_with_duplicates() {
        let scores = vec![1.0f32; 100];
        let mut scratch = Vec::new();
        assert_eq!(threshold_exact(&scores, 10, &mut scratch), 1.0);
        let sel = select_at_threshold(&scores, 1.0, 10);
        assert_eq!(sel, (0..10u32).collect::<Vec<_>>());
    }

    #[test]
    fn select_topk_matches_brute_force() {
        let mut rng = Rng::new(2);
        for n in [10usize, 257, 4096] {
            // distinct scores so set comparison is well-defined
            let scores: Vec<f32> = (0..n).map(|i| rng.f32() + i as f32 * 1e-7).collect();
            for k in [1usize, 3, n / 10 + 1, n / 2] {
                assert_eq!(select_topk(&scores, k), brute_topk(&scores, k), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn sampled_selects_exactly_k() {
        let mut rng = Rng::new(3);
        let n = 100_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.normal().abs()).collect();
        let k = 10_000;
        let mut scratch = Vec::new();
        let t = threshold_sampled(&scores, k, 42, &mut scratch);
        let survivors = scores.iter().filter(|&&s| s >= t).count();
        assert_eq!(survivors, k, "distinct scores: survivors must equal k");
    }

    #[test]
    fn sampled_equals_exact() {
        let mut rng = Rng::new(4);
        let n = 50_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let mut scratch = Vec::new();
        for k in [1usize, 100, 5000, 25_000, 49_999] {
            let te = threshold_exact(&scores, k, &mut scratch);
            let ts = threshold_sampled(&scores, k, 7, &mut scratch);
            assert_eq!(ts, te, "k={k}");
        }
    }

    #[test]
    fn sampled_handles_constant_scores() {
        let scores = vec![2.5f32; 10_000];
        let mut scratch = Vec::new();
        assert_eq!(threshold_sampled(&scores, 100, 1, &mut scratch), 2.5);
    }

    #[test]
    fn sampled_result_is_seed_independent() {
        // the documented contract: the seed picks the sampling pattern,
        // never the result — every seed returns the exact threshold
        let mut rng = Rng::new(8);
        let n = 30_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.normal().abs()).collect();
        let mut scratch = Vec::new();
        for k in [1usize, 500, 3000, 29_999] {
            let exact = threshold_exact(&scores, k, &mut scratch);
            for seed in [0u64, 1, 7, 0xDEAD_BEEF, u64::MAX] {
                assert_eq!(
                    threshold_sampled(&scores, k, seed, &mut scratch),
                    exact,
                    "k={k} seed={seed}"
                );
            }
        }
    }

    #[test]
    fn sampled_undershoot_topup_matches_exact() {
        // heavy ties around the threshold — the regime where the candidate
        // estimate can overshoot and the top-up backstop has to produce the
        // exact answer anyway
        let mut scores = vec![1.0f32; 5000];
        for (i, s) in scores.iter_mut().enumerate().take(200) {
            *s = 2.0 + i as f32 * 1e-3;
        }
        let mut scratch = Vec::new();
        for k in [300usize, 1000, 4999] {
            let exact = threshold_exact(&scores, k, &mut scratch);
            assert_eq!(threshold_sampled(&scores, k, 3, &mut scratch), exact, "k={k}");
        }
    }

    #[test]
    fn k_zero_threshold_is_plus_infinity_and_selects_nothing() {
        // doc contract: k == 0 yields +∞ (an unclearable threshold), NOT
        // NEG_INFINITY (which would select everything)
        let scores = vec![1.0f32, 5.0, 3.0];
        let mut scratch = Vec::new();
        let t = threshold_exact(&scores, 0, &mut scratch);
        assert_eq!(t, f32::INFINITY);
        assert!(select_at_threshold(&scores, t, 0).is_empty());
        assert_eq!(threshold_sampled(&scores, 0, 1, &mut scratch), f32::INFINITY);
        // +INF scores clear an infinite threshold; k == 0 must still win
        let inf_scores = vec![1.0f32, f32::INFINITY, 3.0];
        assert!(select_at_threshold(&inf_scores, f32::INFINITY, 0).is_empty());
    }

    #[test]
    fn select_into_reuses_buffer() {
        let scores = vec![0.9f32, 0.1, 0.8, 0.2, 0.7];
        let mut out = Vec::new();
        select_at_threshold_into(&scores, 0.5, 3, &mut out);
        assert_eq!(out, vec![0, 2, 4]);
        let cap = out.capacity();
        let ptr = out.as_ptr();
        select_at_threshold_into(&scores, 0.5, 2, &mut out);
        assert_eq!(out, vec![0, 2]);
        assert_eq!(out.capacity(), cap);
        assert_eq!(out.as_ptr(), ptr, "warm select must not reallocate");
    }

    #[test]
    fn k_edge_cases() {
        let scores = vec![0.5f32, 0.1, 0.9];
        let mut scratch = Vec::new();
        assert_eq!(threshold_exact(&scores, 0, &mut scratch), f32::INFINITY);
        assert_eq!(threshold_exact(&scores, 3, &mut scratch), 0.1);
        assert_eq!(threshold_exact(&scores, 99, &mut scratch), 0.1);
        assert!(select_topk(&scores, 0).is_empty());
    }

    #[test]
    fn adversarial_patterns() {
        let mut scratch = Vec::new();
        // already sorted ascending / descending / sawtooth
        let asc: Vec<f32> = (0..2000).map(|i| i as f32).collect();
        let desc: Vec<f32> = (0..2000).rev().map(|i| i as f32).collect();
        let saw: Vec<f32> = (0..2000).map(|i| (i % 7) as f32).collect();
        assert_eq!(threshold_exact(&asc, 100, &mut scratch), 1900.0);
        assert_eq!(threshold_exact(&desc, 100, &mut scratch), 1900.0);
        let t = threshold_exact(&saw, 100, &mut scratch);
        assert_eq!(t, 6.0);
    }

    /// Score vectors that stress the bucket boundaries: heavy ties, one
    /// shared exponent (worst case: everything lands in one bucket),
    /// denormals, signed values straddling the ±0.0 bucket split.
    fn bucket_stress_vectors() -> Vec<Vec<f32>> {
        let mut rng = Rng::new(0xB0CC);
        let mut vs = vec![
            vec![1.0; 777],
            (0..1000).map(|i| if i % 3 == 0 { 0.5 } else { 0.25 }).collect(),
            // single binade: every score in bucket 0x7E..  (exponent tie)
            (0..2000).map(|_| 1.0 + rng.f32()).collect(),
            // denormals mixed with zeros and tiny normals
            (0..500)
                .map(|i| f32::from_bits((i % 17) as u32) * if i % 2 == 0 { 1.0 } else { -1.0 })
                .collect(),
            // signed, straddling ±0.0
            (0..800).map(|i| (i as f32 - 400.0) * 0.125).collect(),
            vec![-0.0, 0.0, -0.0, 0.0, 1.0, -1.0],
            (0..300).map(|_| rng.normal()).collect(),
            (0..5000).map(|_| rng.normal().abs()).collect(),
        ];
        // full-range magnitudes across many exponents
        vs.push((0..3000).map(|_| rng.normal() * 10f32.powi(rng.below(20) as i32 - 10)).collect());
        vs
    }

    #[test]
    fn bucketed_matches_quickselect() {
        let mut s1 = Vec::new();
        let mut s2 = Vec::new();
        for scores in bucket_stress_vectors() {
            let n = scores.len();
            for k in [1usize, 2, n / 7 + 1, n / 2, n - 1, n] {
                if k == 0 || k > n {
                    continue;
                }
                let a = threshold_exact_bucketed(&scores, k, &mut s1);
                let b = threshold_exact_quickselect(&scores, k, &mut s2);
                // == (not bit) equality: a ±0.0 boundary may differ in sign
                assert_eq!(a, b, "n={n} k={k}");
                // and the selected sets are identical
                assert_eq!(
                    select_at_threshold(&scores, a, k),
                    select_at_threshold(&scores, b, k),
                    "n={n} k={k}"
                );
            }
        }
    }

    #[test]
    fn kth_largest_inplace_matches_sort() {
        let mut rng = Rng::new(0x5EED);
        for scores in bucket_stress_vectors() {
            let n = scores.len();
            let mut sorted = scores.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            for _ in 0..4 {
                let k = 1 + rng.below(n);
                let mut buf = scores.clone();
                assert_eq!(kth_largest_inplace(&mut buf, k), sorted[k - 1], "n={n} k={k}");
            }
        }
    }

    #[test]
    fn bucketed_handles_edge_ks() {
        let scores = vec![3.0f32, 1.0, 2.0];
        let mut scratch = Vec::new();
        assert_eq!(threshold_exact_bucketed(&scores, 0, &mut scratch), f32::INFINITY);
        assert_eq!(threshold_exact_bucketed(&scores, 3, &mut scratch), 1.0);
        assert_eq!(threshold_exact_bucketed(&scores, 99, &mut scratch), 1.0);
        assert_eq!(threshold_exact_bucketed(&scores, 1, &mut scratch), 3.0);
    }

    #[test]
    fn sampled_equals_exact_under_both_selection_kernels() {
        // threshold_sampled dispatches internally; the contract is that its
        // result equals threshold_exact under every mode. Compare against
        // both explicit exact kernels to pin the value regardless of the
        // ambient dispatch mode.
        let mut rng = Rng::new(0xAB);
        let n = 40_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.normal().abs()).collect();
        let mut scratch = Vec::new();
        for k in [1usize, 37, 4000, 39_999] {
            let b = threshold_exact_bucketed(&scores, k, &mut scratch);
            let q = threshold_exact_quickselect(&scores, k, &mut scratch);
            let s = threshold_sampled(&scores, k, 9, &mut scratch);
            assert_eq!(b, q, "k={k}");
            assert_eq!(s, b, "k={k}");
        }
    }
}
