//! Deterministic pseudo-random number generation.
//!
//! The framework never uses OS randomness: every experiment is seeded from
//! the config, so runs are bit-reproducible across machines (the paper's
//! tables are regenerated, not re-rolled). The generator is xoshiro256**
//! seeded via SplitMix64, the standard pairing recommended by the xoshiro
//! authors.

/// SplitMix64 — used to expand a 64-bit seed into xoshiro state and for
/// cheap stateless hashing (e.g. per-(client, round) derived seeds).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Not cryptographic; excellent statistical quality and
/// extremely fast, which matters in the data generators (millions of draws).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a labelled sub-task (client id,
    /// round number, ...). Streams from distinct labels are decorrelated.
    pub fn derive(&self, label: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ label.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full float precision
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (bias < 2^-32 for all realistic n).
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; the generators are not FLOP-bound).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Sample an index from an (unnormalised) non-negative weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn derive_streams_decorrelated() {
        let root = Rng::new(7);
        let mut a = root.derive(0);
        let mut b = root.derive(1);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
