//! Shared utilities: deterministic RNG, JSON, numeric helpers.
pub mod json;
pub mod math;
pub mod rng;
