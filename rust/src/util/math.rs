//! Small numeric helpers shared across modules.

/// L2 norm of a dense vector.
#[inline]
pub fn l2_norm(xs: &[f32]) -> f32 {
    // accumulate in f64: P ~ 1e5..1e6 elements, f32 accumulation loses
    // ~3 digits and the normalisation in the GMF score is tolerance-checked
    // against the jax oracle at 1e-4.
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

/// Dot product (f64 accumulator).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Mean of an f64 iterator; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Softmax in place over a logits slice (numerically stable).
pub fn softmax_inplace(logits: &mut [f32]) {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in logits.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in logits.iter_mut() {
        *x /= sum;
    }
}

/// argmax index; first max wins.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Total L1 distance between two probability vectors.
pub fn l1_distance(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_norm_basic() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert_eq!(l2_norm(&[]), 0.0);
    }

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0f32, 2.0, 3.0, 1000.0];
        softmax_inplace(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(xs[3] > 0.99);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }

    #[test]
    fn l1_distance_symmetric() {
        let a = [0.5, 0.5];
        let b = [0.9, 0.1];
        assert!((l1_distance(&a, &b) - 0.8).abs() < 1e-12);
        assert_eq!(l1_distance(&a, &b), l1_distance(&b, &a));
    }
}
