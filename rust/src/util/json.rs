//! Minimal JSON parser + writer.
//!
//! The build environment has no network access to crates.io, so the usual
//! serde stack is unavailable; this module implements the subset of JSON the
//! framework needs (the artifact manifest, metric sinks, experiment reports)
//! from scratch. It is a strict parser for the JSON grammar with the usual
//! escapes; numbers are held as f64 (the manifest's integers are < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so serialisation is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["models", "resnet8", "param_count"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialise compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialise with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let numeric = |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if numeric(c)) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"models":{"resnet8":{"param_count":77850}},"version":2}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.to_string(), src);
        let j2 = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""éx""#).unwrap();
        assert_eq!(j.as_str(), Some("éx"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn string_escaping_roundtrip() {
        let s = "quote\" backslash\\ newline\n tab\t ctrl\u{1}";
        let j = Json::Str(s.to_string());
        assert_eq!(Json::parse(&j.to_string()).unwrap().as_str(), Some(s));
    }

    #[test]
    fn integers_serialise_without_fraction() {
        assert_eq!(Json::num(77850.0).to_string(), "77850");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }
}
