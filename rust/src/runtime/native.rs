//! Native mock engine: a pure-Rust one-hidden-layer MLP classifier with
//! hand-written backprop.
//!
//! Exists so that the FL coordinator, the compression schemes and all the
//! experiment machinery can be exercised (tests, proptests, benches, quick
//! CI runs) without the AOT artifacts or the PJRT runtime, and fast enough
//! to run hundreds of FL rounds in milliseconds. Accepts `Features` batches
//! directly and `Image` batches by treating pixels as a flat feature vector.
//!
//! Architecture: x[D] → tanh(W1ᵀx + b1)[H] → softmax(W2ᵀh + b2)[C].
//! Flat packing order: W1 (D·H), b1 (H), W2 (H·C), b2 (C).

use super::{StepOutput, TrainEngine};
use crate::data::dataset::Batch;
use crate::util::math::{argmax, softmax_inplace};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

#[derive(Clone, Debug)]
pub struct NativeEngine {
    pub input_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    seed: u64,
    // scratch buffers (reused across steps; no allocation when warm)
    h_buf: Vec<f32>,
    logit_buf: Vec<f32>,
    dh_buf: Vec<f32>,
}

impl NativeEngine {
    pub fn new(input_dim: usize, hidden: usize, classes: usize, seed: u64) -> Self {
        NativeEngine {
            input_dim,
            hidden,
            classes,
            seed,
            h_buf: vec![0.0; hidden],
            logit_buf: vec![0.0; classes],
            dh_buf: vec![0.0; hidden],
        }
    }

    /// Offsets into the flat parameter vector.
    fn offsets(&self) -> (usize, usize, usize, usize) {
        let w1 = 0;
        let b1 = w1 + self.input_dim * self.hidden;
        let w2 = b1 + self.hidden;
        let b2 = w2 + self.hidden * self.classes;
        (w1, b1, w2, b2)
    }

    fn batch_views<'a>(&self, batch: &'a Batch) -> Result<(&'a [f32], &'a [i32], usize)> {
        match batch {
            Batch::Features { x, y, n, dim } => {
                if *dim != self.input_dim {
                    return Err(anyhow!("feature dim {} != engine input {}", dim, self.input_dim));
                }
                Ok((x, y, *n))
            }
            Batch::Image { x, y, n } => {
                if x.len() != n * self.input_dim {
                    return Err(anyhow!(
                        "image batch pixels {} != n*input_dim {}",
                        x.len(),
                        n * self.input_dim
                    ));
                }
                Ok((x, y, *n))
            }
            Batch::Tokens { .. } => Err(anyhow!("native engine does not model token batches")),
        }
    }

    /// Forward one sample; fills h_buf and logit_buf (softmax-ed in place by
    /// the caller when needed).
    fn forward(&mut self, params: &[f32], x: &[f32]) {
        let (w1, b1, w2, b2) = self.offsets();
        for j in 0..self.hidden {
            let mut acc = params[b1 + j];
            let col = w1 + j; // W1 stored row-major [D, H]: element (i, j) at i*H + j
            for i in 0..self.input_dim {
                acc += x[i] * params[col + i * self.hidden];
            }
            self.h_buf[j] = acc.tanh();
        }
        for c in 0..self.classes {
            let mut acc = params[b2 + c];
            for j in 0..self.hidden {
                acc += self.h_buf[j] * params[w2 + j * self.classes + c];
            }
            self.logit_buf[c] = acc;
        }
    }
}

impl TrainEngine for NativeEngine {
    fn param_count(&self) -> usize {
        self.input_dim * self.hidden + self.hidden + self.hidden * self.classes + self.classes
    }

    fn initial_params(&self) -> Vec<f32> {
        let mut rng = Rng::new(self.seed ^ 0xAB1E);
        let mut p = vec![0.0f32; self.param_count()];
        let (w1, b1, w2, b2) = self.offsets();
        let s1 = (2.0 / self.input_dim as f32).sqrt();
        let s2 = (2.0 / self.hidden as f32).sqrt();
        for i in w1..b1 {
            p[i] = rng.normal() * s1;
        }
        for i in w2..b2 {
            p[i] = rng.normal() * s2;
        }
        p
    }

    fn train_step(&mut self, params: &[f32], batch: &Batch) -> Result<StepOutput> {
        if params.len() != self.param_count() {
            return Err(anyhow!("param len {} != {}", params.len(), self.param_count()));
        }
        let (xs, ys, n) = self.batch_views(batch)?;
        let (xs, ys) = (xs.to_vec(), ys.to_vec()); // detach borrows from self
        let (w1, b1, w2, b2) = self.offsets();
        let mut grads = vec![0.0f32; self.param_count()];
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        let inv_n = 1.0 / n as f32;

        for s in 0..n {
            let x = &xs[s * self.input_dim..(s + 1) * self.input_dim];
            let label = ys[s] as usize;
            self.forward(params, x);
            if argmax(&self.logit_buf) == label {
                correct += 1;
            }
            softmax_inplace(&mut self.logit_buf);
            loss_sum += -(self.logit_buf[label].max(1e-12).ln() as f64);

            // dL/dlogits = softmax - onehot (scaled by 1/n)
            self.logit_buf[label] -= 1.0;
            for v in self.logit_buf.iter_mut() {
                *v *= inv_n;
            }
            // backprop into W2, b2, h
            self.dh_buf.iter_mut().for_each(|d| *d = 0.0);
            for j in 0..self.hidden {
                let hj = self.h_buf[j];
                let row = w2 + j * self.classes;
                let mut dh = 0.0f32;
                for c in 0..self.classes {
                    let dl = self.logit_buf[c];
                    grads[row + c] += hj * dl;
                    dh += params[row + c] * dl;
                }
                self.dh_buf[j] = dh * (1.0 - hj * hj); // tanh'
            }
            for c in 0..self.classes {
                grads[b2 + c] += self.logit_buf[c];
            }
            // backprop into W1, b1
            for i in 0..self.input_dim {
                let xi = x[i];
                if xi == 0.0 {
                    continue;
                }
                let row = w1 + i * self.hidden;
                for j in 0..self.hidden {
                    grads[row + j] += xi * self.dh_buf[j];
                }
            }
            for j in 0..self.hidden {
                grads[b1 + j] += self.dh_buf[j];
            }
        }
        Ok(StepOutput { loss: loss_sum / n as f64, grads, ncorrect: correct })
    }

    fn eval_step(&mut self, params: &[f32], batch: &Batch) -> Result<(f64, usize)> {
        let (xs, ys, n) = self.batch_views(batch)?;
        let (xs, ys) = (xs.to_vec(), ys.to_vec());
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        for s in 0..n {
            let x = &xs[s * self.input_dim..(s + 1) * self.input_dim];
            let label = ys[s] as usize;
            self.forward(params, x);
            if argmax(&self.logit_buf) == label {
                correct += 1;
            }
            softmax_inplace(&mut self.logit_buf);
            loss_sum += -(self.logit_buf[label].max(1e-12).ln() as f64);
        }
        Ok((loss_sum / n as f64, correct))
    }

    fn spawn_worker(&self) -> Option<Box<dyn TrainEngine>> {
        // the engine is stateless apart from scratch buffers, so a clone is
        // a fully independent, numerically identical worker instance
        Some(Box::new(self.clone()))
    }
}

/// Synthetic Gaussian-blob feature dataset for native-engine tests: class c
/// lives around a deterministic center; labels learnable by the MLP.
pub struct BlobDataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub dim: usize,
    pub classes: usize,
}

impl BlobDataset {
    pub fn generate(n: usize, dim: usize, classes: usize, spread: f32, seed: u64) -> Self {
        Self::generate_split(n, dim, classes, spread, seed, seed)
    }

    /// Same class centers for every `centers_seed`, independent noise draws
    /// per `noise_seed` — lets FL tests shard one distribution across
    /// clients (shared centers) with disjoint sample noise.
    pub fn generate_split(
        n: usize,
        dim: usize,
        classes: usize,
        spread: f32,
        centers_seed: u64,
        noise_seed: u64,
    ) -> Self {
        let mut rng = Rng::new(noise_seed ^ 0xB10B);
        // deterministic well-separated centers
        let mut centers = vec![0.0f32; classes * dim];
        let mut crng = Rng::new(centers_seed ^ 0xCE17E5);
        for v in centers.iter_mut() {
            *v = crng.normal() * 2.0;
        }
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % classes;
            for d in 0..dim {
                x.push(centers[c * dim + d] + spread * rng.normal());
            }
            y.push(c as i32);
        }
        BlobDataset { x, y, dim, classes }
    }

    pub fn batch(&self, ids: &[usize]) -> Batch {
        let mut x = Vec::with_capacity(ids.len() * self.dim);
        let mut y = Vec::with_capacity(ids.len());
        for &i in ids {
            x.extend_from_slice(&self.x[i * self.dim..(i + 1) * self.dim]);
            y.push(self.y[i]);
        }
        Batch::Features { x, y, n: ids.len(), dim: self.dim }
    }
}

impl crate::data::dataset::Dataset for BlobDataset {
    fn len(&self) -> usize {
        self.y.len()
    }

    fn label_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &l in &self.y {
            h[l as usize] += 1;
        }
        h
    }

    fn sample_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        let ids: Vec<usize> = (0..batch).map(|_| rng.below(self.len())).collect();
        self.batch(&ids)
    }

    fn eval_batches(&self, batch: usize) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut i = 0;
        while i + batch <= self.len() {
            let ids: Vec<usize> = (i..i + batch).collect();
            out.push(self.batch(&ids));
            i += batch;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_formula() {
        let e = NativeEngine::new(10, 8, 3, 0);
        assert_eq!(e.param_count(), 10 * 8 + 8 + 8 * 3 + 3);
        assert_eq!(e.initial_params().len(), e.param_count());
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut e = NativeEngine::new(6, 5, 3, 1);
        let params = e.initial_params();
        let ds = BlobDataset::generate(9, 6, 3, 0.5, 2);
        let batch = ds.batch(&[0, 1, 2, 3, 4, 5]);
        let out = e.train_step(&params, &batch).unwrap();

        let eps = 1e-3f32;
        let mut checked = 0;
        for idx in (0..params.len()).step_by(3) {
            let mut pp = params.clone();
            pp[idx] += eps;
            let (lp, _) = loss_of(&mut e, &pp, &batch);
            let mut pm = params.clone();
            pm[idx] -= eps;
            let (lm, _) = loss_of(&mut e, &pm, &batch);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = out.grads[idx] as f64;
            assert!(
                (fd - an).abs() < 2e-3 + 0.05 * fd.abs().max(an.abs()),
                "idx {idx}: fd={fd} analytic={an}"
            );
            checked += 1;
        }
        assert!(checked > 10);
    }

    fn loss_of(e: &mut NativeEngine, params: &[f32], batch: &Batch) -> (f64, usize) {
        e.eval_step(params, batch).unwrap()
    }

    #[test]
    fn sgd_learns_blobs() {
        let mut e = NativeEngine::new(8, 16, 4, 3);
        let ds = BlobDataset::generate(200, 8, 4, 0.3, 4);
        let mut params = e.initial_params();
        let mut rng = Rng::new(5);
        use crate::data::dataset::Dataset;
        let mut first_loss = None;
        for _ in 0..60 {
            let batch = ds.sample_batch(32, &mut rng);
            let out = e.train_step(&params, &batch).unwrap();
            if first_loss.is_none() {
                first_loss = Some(out.loss);
            }
            for (p, g) in params.iter_mut().zip(&out.grads) {
                *p -= 0.5 * g;
            }
        }
        let batches = ds.eval_batches(50);
        let (loss, acc) = {
            let mut correct = 0;
            let mut ls = 0.0;
            for b in &batches {
                let (l, c) = e.eval_step(&params, b).unwrap();
                ls += l;
                correct += c;
            }
            (ls / batches.len() as f64, correct as f64 / 200.0)
        };
        assert!(loss < first_loss.unwrap(), "loss {loss} vs {first_loss:?}");
        assert!(acc > 0.9, "accuracy {acc}");
    }

    #[test]
    fn eval_matches_train_metrics() {
        let mut e = NativeEngine::new(5, 4, 2, 7);
        let params = e.initial_params();
        let ds = BlobDataset::generate(20, 5, 2, 0.4, 8);
        let batch = ds.batch(&(0..20).collect::<Vec<_>>());
        let t = e.train_step(&params, &batch).unwrap();
        let (l, c) = e.eval_step(&params, &batch).unwrap();
        assert!((t.loss - l).abs() < 1e-9);
        assert_eq!(t.ncorrect, c);
    }

    #[test]
    fn rejects_wrong_dims() {
        let mut e = NativeEngine::new(5, 4, 2, 7);
        let params = e.initial_params();
        let bad = Batch::Features { x: vec![0.0; 12], y: vec![0; 3], n: 3, dim: 4 };
        assert!(e.train_step(&params, &bad).is_err());
        let good = Batch::Features { x: vec![0.0; 10], y: vec![0; 2], n: 2, dim: 5 };
        assert!(e.train_step(&params[..3], &good).is_err());
    }
}
