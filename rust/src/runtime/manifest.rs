//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed with the in-tree JSON module.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One lowered model variant's artifact set.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String, // "cnn" | "lstm"
    pub param_count: usize,
    pub batch: usize,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub x_dtype: String,
    pub train_file: PathBuf,
    pub eval_file: PathBuf,
    pub init_file: PathBuf,
    pub gmf_score_file: PathBuf,
    pub dgc_update_file: PathBuf,
    pub vocab: Option<usize>,
    pub seq: Option<usize>,
    pub num_classes: Option<usize>,
}

/// The whole `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub version: usize,
    pub block: usize,
    pub models: Vec<ModelEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version < 2 {
            return Err(anyhow!("manifest version {version} too old; re-run `make artifacts`"));
        }
        let block = j.get("block").and_then(Json::as_usize).unwrap_or(1024);
        let models_obj = j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?;

        let mut models = Vec::new();
        for (name, entry) in models_obj {
            let file = |part: &str| -> Result<PathBuf> {
                let f = entry
                    .at(&[part, "file"])
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model {name}: missing {part}.file"))?;
                Ok(dir.join(f))
            };
            let shape = |which: &str| -> Vec<usize> {
                entry
                    .at(&["inputs", which, "shape"])
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default()
            };
            models.push(ModelEntry {
                name: name.clone(),
                kind: entry.get("kind").and_then(Json::as_str).unwrap_or("").to_string(),
                param_count: entry
                    .get("param_count")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("model {name}: missing param_count"))?,
                batch: entry.get("batch").and_then(Json::as_usize).unwrap_or(0),
                x_shape: shape("x"),
                y_shape: shape("y"),
                x_dtype: entry
                    .at(&["inputs", "x", "dtype"])
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string(),
                train_file: file("train")?,
                eval_file: file("eval")?,
                init_file: file("init")?,
                gmf_score_file: file("gmf_score")?,
                dgc_update_file: file("dgc_update")?,
                vocab: entry.get("vocab").and_then(Json::as_usize),
                seq: entry.get("seq").and_then(Json::as_usize),
                num_classes: entry.get("num_classes").and_then(Json::as_usize),
            });
        }
        models.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Manifest { dir: dir.to_path_buf(), version, block, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.iter().map(|m| m.name.as_str()).collect()
    }
}

/// Read a raw little-endian f32 file (the exported W_init).
pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("{}: length {} not a multiple of 4", path.display(), bytes.len()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("fedgmf-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let man = r#"{
          "version": 2, "block": 1024, "jax": "0.8.2",
          "models": {
            "tiny": {
              "kind": "cnn", "param_count": 10, "batch": 4,
              "inputs": {"x": {"shape": [4, 2], "dtype": "float32"},
                          "y": {"shape": [4], "dtype": "int32"}},
              "train": {"file": "tiny_train.hlo.txt", "bytes": 1, "sha256_16": "x"},
              "eval": {"file": "tiny_eval.hlo.txt", "bytes": 1, "sha256_16": "x"},
              "init": {"file": "tiny_init.f32", "bytes": 40, "sha256_16": "x"},
              "gmf_score": {"file": "t_g.hlo.txt", "bytes": 1, "sha256_16": "x"},
              "dgc_update": {"file": "t_d.hlo.txt", "bytes": 1, "sha256_16": "x"}
            }
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), man).unwrap();
        dir
    }

    #[test]
    fn loads_and_resolves_paths() {
        let dir = fake_manifest_dir();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.version, 2);
        let e = m.model("tiny").unwrap();
        assert_eq!(e.param_count, 10);
        assert_eq!(e.x_shape, vec![4, 2]);
        assert!(e.train_file.ends_with("tiny_train.hlo.txt"));
        assert!(m.model("missing").is_err());
    }

    #[test]
    fn read_f32_roundtrip() {
        let dir = fake_manifest_dir();
        let path = dir.join("vals.f32");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(read_f32_file(&path).unwrap(), vals);
    }

    #[test]
    fn rejects_old_version() {
        let dir = std::env::temp_dir().join(format!("fedgmf-manifest-old-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version": 1, "models": {}}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
