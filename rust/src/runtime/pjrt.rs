//! PJRT execution engine: loads AOT HLO-text artifacts and runs them.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialised protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see DESIGN.md and aot.py).
//!
//! One `PjrtEngine` per model variant; executables are compiled once at
//! construction and reused for every client every round.

use super::manifest::{read_f32_file, ModelEntry};
use super::{StepOutput, TrainEngine};
use crate::data::dataset::Batch;
use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::rc::Rc;

/// Shared PJRT client (one per process is plenty; executables are cheap).
pub struct PjrtContext {
    pub client: xla::PjRtClient,
}

impl PjrtContext {
    pub fn cpu() -> Result<Rc<PjrtContext>> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(Rc::new(PjrtContext { client }))
    }

    /// Load + compile an HLO text artifact.
    pub fn load(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
    }
}

/// Engine for one model variant backed by the AOT artifacts.
pub struct PjrtEngine {
    /// keeps the client alive for the executables' lifetime
    _ctx: Rc<PjrtContext>,
    entry: ModelEntry,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    init: Vec<f32>,
}

// The PJRT CPU client is used from one coordinator thread at a time; the
// raw pointers inside the xla wrappers prevent an auto-impl.
unsafe impl Send for PjrtEngine {}

impl PjrtEngine {
    pub fn new(ctx: Rc<PjrtContext>, entry: &ModelEntry) -> Result<PjrtEngine> {
        let train_exe = ctx.load(&entry.train_file).context("train artifact")?;
        let eval_exe = ctx.load(&entry.eval_file).context("eval artifact")?;
        let init = read_f32_file(&entry.init_file).context("init artifact")?;
        if init.len() != entry.param_count {
            return Err(anyhow!(
                "init vector length {} != param_count {}",
                init.len(),
                entry.param_count
            ));
        }
        Ok(PjrtEngine { _ctx: ctx.clone(), entry: entry.clone(), train_exe, eval_exe, init })
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    /// Batch → (x, y) literals matching the lowered input specs.
    fn batch_literals(&self, batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
        let dims_x: Vec<i64> = self.entry.x_shape.iter().map(|&d| d as i64).collect();
        let dims_y: Vec<i64> = self.entry.y_shape.iter().map(|&d| d as i64).collect();
        match batch {
            Batch::Image { x, y, .. } => {
                let expect: usize = self.entry.x_shape.iter().product();
                if x.len() != expect {
                    return Err(anyhow!(
                        "image batch has {} pixels, artifact expects {expect}",
                        x.len()
                    ));
                }
                let lx = xla::Literal::vec1(x.as_slice())
                    .reshape(&dims_x)
                    .map_err(|e| anyhow!("reshape x: {e}"))?;
                let ly = xla::Literal::vec1(y.as_slice());
                Ok((lx, ly))
            }
            Batch::Tokens { x, y, .. } => {
                let expect: usize = self.entry.x_shape.iter().product();
                if x.len() != expect {
                    return Err(anyhow!(
                        "token batch has {} ids, artifact expects {expect}",
                        x.len()
                    ));
                }
                let lx = xla::Literal::vec1(x.as_slice())
                    .reshape(&dims_x)
                    .map_err(|e| anyhow!("reshape x: {e}"))?;
                let ly = xla::Literal::vec1(y.as_slice())
                    .reshape(&dims_y)
                    .map_err(|e| anyhow!("reshape y: {e}"))?;
                Ok((lx, ly))
            }
            Batch::Features { .. } => {
                Err(anyhow!("PJRT engine has no artifact for feature batches"))
            }
        }
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e}"))?;
        result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))
    }
}

impl TrainEngine for PjrtEngine {
    fn param_count(&self) -> usize {
        self.entry.param_count
    }

    fn initial_params(&self) -> Vec<f32> {
        self.init.clone()
    }

    fn train_step(&mut self, params: &[f32], batch: &Batch) -> Result<StepOutput> {
        debug_assert_eq!(params.len(), self.entry.param_count);
        let lp = xla::Literal::vec1(params);
        let (lx, ly) = self.batch_literals(batch)?;
        let out = Self::run(&self.train_exe, &[lp, lx, ly])?;
        // lowered with return_tuple=True: (loss, grads, ncorrect)
        let (loss, grads, ncorrect) = out
            .to_tuple3()
            .map_err(|e| anyhow!("train output tuple: {e}"))?;
        Ok(StepOutput {
            loss: loss.get_first_element::<f32>().map_err(|e| anyhow!("loss: {e}"))? as f64,
            grads: grads.to_vec::<f32>().map_err(|e| anyhow!("grads: {e}"))?,
            ncorrect: ncorrect.get_first_element::<i32>().map_err(|e| anyhow!("ncorrect: {e}"))?
                as usize,
        })
    }

    fn eval_step(&mut self, params: &[f32], batch: &Batch) -> Result<(f64, usize)> {
        let lp = xla::Literal::vec1(params);
        let (lx, ly) = self.batch_literals(batch)?;
        let out = Self::run(&self.eval_exe, &[lp, lx, ly])?;
        let (loss, ncorrect) = out.to_tuple2().map_err(|e| anyhow!("eval output tuple: {e}"))?;
        Ok((
            loss.get_first_element::<f32>().map_err(|e| anyhow!("loss: {e}"))? as f64,
            ncorrect.get_first_element::<i32>().map_err(|e| anyhow!("ncorrect: {e}"))? as usize,
        ))
    }
}

/// Standalone wrapper for the L1 kernel artifacts (`gmf_score`,
/// `dgc_update`) — used by the Rust-vs-Pallas equivalence tests and the
/// optional fused-score engine.
pub struct KernelExecutor {
    gmf_score: xla::PjRtLoadedExecutable,
    dgc_update: xla::PjRtLoadedExecutable,
    pub param_count: usize,
}

unsafe impl Send for KernelExecutor {}

impl KernelExecutor {
    pub fn new(ctx: &PjrtContext, entry: &ModelEntry) -> Result<KernelExecutor> {
        Ok(KernelExecutor {
            gmf_score: ctx.load(&entry.gmf_score_file)?,
            dgc_update: ctx.load(&entry.dgc_update_file)?,
            param_count: entry.param_count,
        })
    }

    /// Z = |(1−τ)N(V) + τN(M)| via the AOT Pallas kernel.
    pub fn gmf_score(&self, v: &[f32], m: &[f32], tau: f32) -> Result<Vec<f32>> {
        let out = PjrtEngine::run(
            &self.gmf_score,
            &[xla::Literal::vec1(v), xla::Literal::vec1(m), xla::Literal::scalar(tau)],
        )?;
        let z = out.to_tuple1().map_err(|e| anyhow!("gmf_score tuple: {e}"))?;
        z.to_vec::<f32>().map_err(|e| anyhow!("gmf_score out: {e}"))
    }

    /// (U', V') = momentum correction via the AOT Pallas kernel.
    pub fn dgc_update(
        &self,
        u: &[f32],
        v: &[f32],
        g: &[f32],
        alpha: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = PjrtEngine::run(
            &self.dgc_update,
            &[
                xla::Literal::vec1(u),
                xla::Literal::vec1(v),
                xla::Literal::vec1(g),
                xla::Literal::scalar(alpha),
            ],
        )?;
        let (u2, v2) = out.to_tuple2().map_err(|e| anyhow!("dgc_update tuple: {e}"))?;
        Ok((
            u2.to_vec::<f32>().map_err(|e| anyhow!("u out: {e}"))?,
            v2.to_vec::<f32>().map_err(|e| anyhow!("v out: {e}"))?,
        ))
    }
}
