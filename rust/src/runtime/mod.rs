//! Execution engines: how a client turns a minibatch into a gradient.
//!
//! * [`pjrt::PjrtEngine`] — the production path: loads the AOT-compiled HLO
//!   artifacts (L2 JAX models + L1 Pallas kernels, see `python/compile/`)
//!   and runs them on the PJRT CPU client. Python is never on this path.
//!   Compiled only with the `pjrt` cargo feature (needs the xla bindings);
//!   the default build substitutes an API-compatible stub whose constructors
//!   error at runtime, so the rest of the crate works without libxla.
//! * [`native::NativeEngine`] — a self-contained pure-Rust model (MLP with
//!   hand-written backprop) used by unit/integration tests and benches that
//!   must run without artifacts, and as a cross-check for the FL dynamics.
//!
//! Both implement [`TrainEngine`]; the coordinator is engine-agnostic. The
//! parallel round loop asks an engine for per-worker instances through
//! [`TrainEngine::spawn_worker`]; engines that cannot be replicated return
//! `None` and the coordinator falls back to sequential execution.

pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use manifest::{Manifest, ModelEntry};

use crate::data::dataset::Batch;

/// Result of one local training step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub loss: f64,
    pub grads: Vec<f32>,
    pub ncorrect: usize,
}

/// A model execution engine with the flat-parameter ABI (DESIGN.md §2).
pub trait TrainEngine: Send {
    /// Length P of the flat parameter vector.
    fn param_count(&self) -> usize;
    /// Initial parameters (the W_init the server shares, Alg. 1 line 2).
    fn initial_params(&self) -> Vec<f32>;
    /// Loss + flat gradient + #correct on one batch.
    fn train_step(&mut self, params: &[f32], batch: &Batch) -> anyhow::Result<StepOutput>;
    /// Loss + #correct on one batch (no gradient).
    fn eval_step(&mut self, params: &[f32], batch: &Batch) -> anyhow::Result<(f64, usize)>;
    /// Spawn an independent engine instance for one parallel worker thread
    /// of the round loop. Engines wrapping a runtime handle that cannot be
    /// shared or replicated (e.g. the PJRT client) keep the default `None`,
    /// which makes the coordinator run its sequential path instead.
    fn spawn_worker(&self) -> Option<Box<dyn TrainEngine>> {
        None
    }
}

/// Evaluate over a list of batches; returns (mean loss, accuracy).
pub fn evaluate(
    engine: &mut dyn TrainEngine,
    params: &[f32],
    batches: &[Batch],
) -> anyhow::Result<(f64, f64)> {
    evaluate_with_pool(engine, &mut [], params, batches)
}

/// [`evaluate`] fanned out over the caller's worker-engine pool.
///
/// Per-batch results are independent of which engine instance computes them
/// (eval is a pure forward pass over `params`), and the loss/accuracy
/// reduction runs in batch order over the gathered per-batch results — the
/// same additions in the same order as the sequential loop — so the result
/// is **bit-identical** at any pool size.
pub fn evaluate_with_pool(
    engine: &mut dyn TrainEngine,
    extra: &mut [Box<dyn TrainEngine>],
    params: &[f32],
    batches: &[Batch],
) -> anyhow::Result<(f64, f64)> {
    let mut results: Vec<(f64, usize)> = vec![(0.0, 0); batches.len()];
    if extra.is_empty() || batches.len() < 2 {
        for (b, r) in batches.iter().zip(results.iter_mut()) {
            *r = engine.eval_step(params, b)?;
        }
    } else {
        let threads = (extra.len() + 1).min(batches.len());
        let chunk = batches.len().div_ceil(threads);
        let mut first_err: anyhow::Result<()> = Ok(());
        std::thread::scope(|s| {
            let mut batch_chunks = batches.chunks(chunk);
            let mut res_chunks = results.chunks_mut(chunk);
            let head_batches = batch_chunks.next();
            let head_results = res_chunks.next();
            let mut handles = Vec::with_capacity(threads - 1);
            for ((bc, rc), eng) in batch_chunks.zip(res_chunks).zip(extra.iter_mut()) {
                handles.push(s.spawn(move || -> anyhow::Result<()> {
                    for (b, r) in bc.iter().zip(rc.iter_mut()) {
                        *r = eng.eval_step(params, b)?;
                    }
                    Ok(())
                }));
            }
            // the caller's engine drives the first chunk on this thread
            if let (Some(bc), Some(rc)) = (head_batches, head_results) {
                for (b, r) in bc.iter().zip(rc.iter_mut()) {
                    match engine.eval_step(params, b) {
                        Ok(x) => *r = x,
                        Err(e) => {
                            first_err = Err(e);
                            break;
                        }
                    }
                }
            }
            for h in handles {
                let r = h.join().expect("eval worker thread panicked");
                if first_err.is_ok() {
                    first_err = r;
                }
            }
        });
        first_err?;
    }
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut preds = 0usize;
    for (b, &(loss, nc)) in batches.iter().zip(&results) {
        loss_sum += loss * b.len() as f64;
        correct += nc;
        preds += b.prediction_count();
    }
    let n: usize = batches.iter().map(|b| b.len()).sum();
    if n == 0 {
        return Ok((0.0, 0.0));
    }
    Ok((loss_sum / n as f64, correct as f64 / preds as f64))
}
