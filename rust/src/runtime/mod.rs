//! Execution engines: how a client turns a minibatch into a gradient.
//!
//! * [`pjrt::PjrtEngine`] — the production path: loads the AOT-compiled HLO
//!   artifacts (L2 JAX models + L1 Pallas kernels, see `python/compile/`)
//!   and runs them on the PJRT CPU client. Python is never on this path.
//!   Compiled only with the `pjrt` cargo feature (needs the xla bindings);
//!   the default build substitutes an API-compatible stub whose constructors
//!   error at runtime, so the rest of the crate works without libxla.
//! * [`native::NativeEngine`] — a self-contained pure-Rust model (MLP with
//!   hand-written backprop) used by unit/integration tests and benches that
//!   must run without artifacts, and as a cross-check for the FL dynamics.
//!
//! Both implement [`TrainEngine`]; the coordinator is engine-agnostic. The
//! parallel round loop asks an engine for per-worker instances through
//! [`TrainEngine::spawn_worker`]; engines that cannot be replicated return
//! `None` and the coordinator falls back to sequential execution.

pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use manifest::{Manifest, ModelEntry};

use crate::data::dataset::Batch;

/// Result of one local training step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub loss: f64,
    pub grads: Vec<f32>,
    pub ncorrect: usize,
}

/// A model execution engine with the flat-parameter ABI (DESIGN.md §2).
pub trait TrainEngine: Send {
    /// Length P of the flat parameter vector.
    fn param_count(&self) -> usize;
    /// Initial parameters (the W_init the server shares, Alg. 1 line 2).
    fn initial_params(&self) -> Vec<f32>;
    /// Loss + flat gradient + #correct on one batch.
    fn train_step(&mut self, params: &[f32], batch: &Batch) -> anyhow::Result<StepOutput>;
    /// Loss + #correct on one batch (no gradient).
    fn eval_step(&mut self, params: &[f32], batch: &Batch) -> anyhow::Result<(f64, usize)>;
    /// Spawn an independent engine instance for one parallel worker thread
    /// of the round loop. Engines wrapping a runtime handle that cannot be
    /// shared or replicated (e.g. the PJRT client) keep the default `None`,
    /// which makes the coordinator run its sequential path instead.
    fn spawn_worker(&self) -> Option<Box<dyn TrainEngine>> {
        None
    }
}

/// Evaluate over a list of batches; returns (mean loss, accuracy).
pub fn evaluate(
    engine: &mut dyn TrainEngine,
    params: &[f32],
    batches: &[Batch],
) -> anyhow::Result<(f64, f64)> {
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut preds = 0usize;
    for b in batches {
        let (loss, nc) = engine.eval_step(params, b)?;
        loss_sum += loss * b.len() as f64;
        correct += nc;
        preds += b.prediction_count();
    }
    let n: usize = batches.iter().map(|b| b.len()).sum();
    if n == 0 {
        return Ok((0.0, 0.0));
    }
    Ok((loss_sum / n as f64, correct as f64 / preds as f64))
}
