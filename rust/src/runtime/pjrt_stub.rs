//! API-compatible stub for the PJRT engine, compiled when the `pjrt`
//! feature is disabled (the default: the offline build has no xla bindings
//! or libxla).
//!
//! Every constructor returns an error, so the pjrt-requiring code paths
//! (`--engine pjrt`, `artifacts-check`, the AOT equivalence tests) fail
//! gracefully at runtime with an actionable message, while the rest of the
//! crate — native engine, coordinator, compression, experiments — builds
//! and runs unchanged. The engine/executor types are uninhabited enums:
//! they can only ever exist behind the real implementation.

use super::manifest::ModelEntry;
use super::{StepOutput, TrainEngine};
use crate::data::dataset::Batch;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::rc::Rc;

fn disabled() -> anyhow::Error {
    anyhow!(
        "this build has no PJRT runtime (compiled without the `pjrt` feature); \
         vendor the xla bindings and rebuild with `--features pjrt`, or use `--engine native`"
    )
}

/// Placeholder for the PJRT client handle.
pub struct StubClient;

impl StubClient {
    pub fn platform_name(&self) -> String {
        "pjrt-disabled".to_string()
    }
}

/// Stub of the shared PJRT client context; [`PjrtContext::cpu`] always errs.
pub struct PjrtContext {
    pub client: StubClient,
}

impl PjrtContext {
    pub fn cpu() -> Result<Rc<PjrtContext>> {
        Err(disabled())
    }

    /// Load + compile an HLO text artifact (stub: always errs).
    pub fn load(&self, _path: &Path) -> Result<()> {
        Err(disabled())
    }
}

/// Uninhabited stand-in for the artifact-backed engine.
pub enum PjrtEngine {}

impl PjrtEngine {
    pub fn new(_ctx: Rc<PjrtContext>, _entry: &ModelEntry) -> Result<PjrtEngine> {
        Err(disabled())
    }

    pub fn entry(&self) -> &ModelEntry {
        match *self {}
    }
}

impl TrainEngine for PjrtEngine {
    fn param_count(&self) -> usize {
        match *self {}
    }

    fn initial_params(&self) -> Vec<f32> {
        match *self {}
    }

    fn train_step(&mut self, _params: &[f32], _batch: &Batch) -> Result<StepOutput> {
        match *self {}
    }

    fn eval_step(&mut self, _params: &[f32], _batch: &Batch) -> Result<(f64, usize)> {
        match *self {}
    }
}

/// Uninhabited stand-in for the L1 kernel executor.
pub enum KernelExecutor {}

impl KernelExecutor {
    pub fn new(_ctx: &PjrtContext, _entry: &ModelEntry) -> Result<KernelExecutor> {
        Err(disabled())
    }

    pub fn gmf_score(&self, _v: &[f32], _m: &[f32], _tau: f32) -> Result<Vec<f32>> {
        match *self {}
    }

    pub fn dgc_update(
        &self,
        _u: &[f32],
        _v: &[f32],
        _g: &[f32],
        _alpha: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        match *self {}
    }
}
