//! Non-IID partitioning with EMD targeting (paper §4.1, "Mod-Cifar10").
//!
//! The paper follows Zhao et al. [9] and quantifies non-IID-ness as the
//! earth-mover distance between each client's label distribution and the
//! population distribution, weighted by client size:
//!
//! ```text
//!   EMD = Σ_k (n_k / n) · ‖ p_k − p ‖₁
//! ```
//!
//! The partitioner mixes, per client, a fraction γ of a client-specific
//! dominant class with (1−γ) of the global distribution:
//! `p_k = γ·e_{c_k} + (1−γ)·p`. For a balanced C-class dataset this gives a
//! closed form `EMD(γ) = γ · 2(C−1)/C`, which we invert to hit the paper's
//! seven targets {0, 0.48, 0.76, 0.87, 0.99, 1.18, 1.35} exactly (max
//! representable: 1.8 at γ=1 for C=10).

use super::dataset::Shard;
use crate::util::math::l1_distance;
use crate::util::rng::Rng;

/// Mixing coefficient γ that achieves `target_emd` for `classes` balanced
/// classes. Errors if the target exceeds the γ=1 maximum.
pub fn gamma_for_emd(target_emd: f64, classes: usize) -> Result<f64, String> {
    let max = 2.0 * (classes as f64 - 1.0) / classes as f64;
    if !(0.0..=max).contains(&target_emd) {
        return Err(format!("EMD {target_emd} out of range [0, {max}] for {classes} classes"));
    }
    Ok(target_emd / max)
}

/// Weighted-average EMD of realized shard label histograms.
pub fn emd_of_partition(shard_hists: &[Vec<usize>]) -> f64 {
    let classes = shard_hists.first().map(|h| h.len()).unwrap_or(0);
    let mut global = vec![0usize; classes];
    let mut total = 0usize;
    for h in shard_hists {
        for (g, &c) in global.iter_mut().zip(h) {
            *g += c;
        }
        total += h.iter().sum::<usize>();
    }
    if total == 0 {
        return 0.0;
    }
    let p: Vec<f64> = global.iter().map(|&g| g as f64 / total as f64).collect();
    let mut emd = 0.0;
    for h in shard_hists {
        let nk: usize = h.iter().sum();
        if nk == 0 {
            continue;
        }
        let pk: Vec<f64> = h.iter().map(|&c| c as f64 / nk as f64).collect();
        emd += (nk as f64 / total as f64) * l1_distance(&pk, &p);
    }
    emd
}

/// Partition `labels` into `clients` shards targeting `target_emd`.
///
/// Deterministic given `seed`. Returns the shards (every sample assigned
/// exactly once) plus the achieved EMD (reported in experiment logs; differs
/// from the target only by integer-rounding noise).
pub fn partition_by_emd(
    labels: &[i32],
    classes: usize,
    clients: usize,
    target_emd: f64,
    seed: u64,
) -> Result<(Vec<Shard>, f64), String> {
    assert!(clients > 0 && classes > 0);
    let gamma = gamma_for_emd(target_emd, classes)?;
    let n = labels.len();

    // per-class pools of sample ids, shuffled for tie-breaking diversity
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); classes];
    for (i, &l) in labels.iter().enumerate() {
        pools[l as usize].push(i);
    }
    let mut rng = Rng::new(seed ^ 0xEAD);
    for pool in &mut pools {
        rng.shuffle(pool);
    }

    // global distribution of the actual labels (robust to unbalanced input)
    let p: Vec<f64> = pools.iter().map(|pool| pool.len() as f64 / n as f64).collect();

    // desired per-client class counts via largest-remainder rounding
    let base = n / clients;
    let mut desired: Vec<Vec<usize>> = Vec::with_capacity(clients);
    for k in 0..clients {
        let dominant = k % classes; // spread dominants evenly across clients
        let nk = base + usize::from(k < n % clients);
        let mut want: Vec<f64> = (0..classes)
            .map(|c| {
                let mix = if c == dominant {
                    gamma + (1.0 - gamma) * p[c]
                } else {
                    (1.0 - gamma) * p[c]
                };
                mix * nk as f64
            })
            .collect();
        // largest-remainder rounding to integers summing to nk
        let mut counts: Vec<usize> = want.iter().map(|w| w.floor() as usize).collect();
        let mut short = nk - counts.iter().sum::<usize>();
        let mut rema: Vec<(usize, f64)> =
            want.iter_mut().enumerate().map(|(c, w)| (c, *w - w.floor())).collect();
        rema.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for (c, _) in rema {
            if short == 0 {
                break;
            }
            counts[c] += 1;
            short -= 1;
        }
        desired.push(counts);
    }

    // draw ids: greedy with fallback when a class pool is exhausted
    let mut shards = vec![Shard::default(); clients];
    for (k, counts) in desired.iter().enumerate() {
        for (c, &cnt) in counts.iter().enumerate() {
            for _ in 0..cnt {
                if let Some(id) = pools[c].pop() {
                    shards[k].sample_ids.push(id);
                } else if let Some(id) = pools
                    .iter_mut()
                    .max_by_key(|p| p.len())
                    .and_then(|p| p.pop())
                {
                    shards[k].sample_ids.push(id);
                }
            }
        }
    }
    // leftovers (rounding) round-robin
    let mut k = 0;
    for pool in &mut pools {
        while let Some(id) = pool.pop() {
            shards[k % clients].sample_ids.push(id);
            k += 1;
        }
    }

    // achieved EMD from realized histograms
    let hists: Vec<Vec<usize>> = shards
        .iter()
        .map(|s| {
            let mut h = vec![0usize; classes];
            for &id in &s.sample_ids {
                h[labels[id] as usize] += 1;
            }
            h
        })
        .collect();
    Ok((shards, emd_of_partition(&hists)))
}

/// The paper's seven Mod-Cifar10 EMD levels (Table 3 row groups).
pub const PAPER_EMD_LEVELS: [f64; 7] = [0.0, 0.48, 0.76, 0.87, 0.99, 1.18, 1.35];

#[cfg(test)]
mod tests {
    use super::*;

    fn balanced_labels(per_class: usize, classes: usize) -> Vec<i32> {
        (0..classes)
            .flat_map(|c| std::iter::repeat(c as i32).take(per_class))
            .collect()
    }

    #[test]
    fn gamma_inversion() {
        assert_eq!(gamma_for_emd(0.0, 10).unwrap(), 0.0);
        assert!((gamma_for_emd(1.8, 10).unwrap() - 1.0).abs() < 1e-12);
        assert!((gamma_for_emd(0.9, 10).unwrap() - 0.5).abs() < 1e-12);
        assert!(gamma_for_emd(2.0, 10).is_err());
        assert!(gamma_for_emd(-0.1, 10).is_err());
    }

    #[test]
    fn every_sample_assigned_exactly_once() {
        let labels = balanced_labels(100, 10);
        let (shards, _) = partition_by_emd(&labels, 10, 20, 0.99, 1).unwrap();
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.sample_ids.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn achieves_paper_emd_targets() {
        // sizes divisible by clients*classes so integer rounding cannot
        // inflate the EMD floor (2000 samples → 100/client → 10/class)
        let labels = balanced_labels(200, 10);
        for &target in &PAPER_EMD_LEVELS {
            let (_, achieved) = partition_by_emd(&labels, 10, 20, target, 2).unwrap();
            assert!(
                (achieved - target).abs() < 0.06,
                "target {target} achieved {achieved}"
            );
        }
    }

    #[test]
    fn emd_zero_is_iid() {
        let labels = balanced_labels(100, 10);
        let (shards, achieved) = partition_by_emd(&labels, 10, 10, 0.0, 3).unwrap();
        assert!(achieved < 0.01, "achieved {achieved}");
        for s in &shards {
            assert_eq!(s.len(), 100);
        }
    }

    #[test]
    fn max_emd_makes_single_class_clients() {
        let labels = balanced_labels(100, 10);
        let (shards, achieved) = partition_by_emd(&labels, 10, 10, 1.8, 4).unwrap();
        assert!(achieved > 1.75, "achieved {achieved}");
        for (k, s) in shards.iter().enumerate() {
            let mut h = vec![0usize; 10];
            for &id in &s.sample_ids {
                h[labels[id] as usize] += 1;
            }
            // dominant class holds (nearly) everything
            assert!(h[k % 10] >= 95, "client {k}: {h:?}");
        }
    }

    #[test]
    fn emd_of_partition_hand_example() {
        // two clients, two classes, fully skewed: p=(.5,.5), each ‖p_k−p‖₁=1
        let hists = vec![vec![10, 0], vec![0, 10]];
        assert!((emd_of_partition(&hists) - 1.0).abs() < 1e-12);
        // identical halves: EMD = 0
        let hists = vec![vec![5, 5], vec![5, 5]];
        assert_eq!(emd_of_partition(&hists), 0.0);
    }

    #[test]
    fn deterministic_partition() {
        let labels = balanced_labels(50, 10);
        let (a, _) = partition_by_emd(&labels, 10, 5, 0.76, 9).unwrap();
        let (b, _) = partition_by_emd(&labels, 10, 5, 0.76, 9).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.sample_ids, y.sample_ids);
        }
    }
}
