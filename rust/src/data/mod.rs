//! Data substrate: synthetic datasets + non-IID partitioning with EMD
//! targeting (paper §4.1).
pub mod dataset;
pub mod partition;
pub mod shakespeare;
pub mod synth_cifar;

pub use dataset::{Batch, Dataset, Shard};
