//! Dataset abstractions shared by the data generators and the FL runtime.
//!
//! Samples stay in flat contiguous buffers (image pixels as f32, token
//! streams as i32) so batches can be copied straight into PJRT literals with
//! zero per-sample allocation.

use crate::util::rng::Rng;

/// One minibatch in the engine ABI (matches the lowered HLO input specs).
#[derive(Clone, Debug)]
pub enum Batch {
    /// Images NHWC f32 + one label per image.
    Image { x: Vec<f32>, y: Vec<i32>, n: usize },
    /// Token sequences [B, S] + next-token targets [B, S].
    Tokens { x: Vec<i32>, y: Vec<i32>, n: usize, seq: usize },
    /// Plain feature rows [B, D] + labels (native mock engine / tests).
    Features { x: Vec<f32>, y: Vec<i32>, n: usize, dim: usize },
}

impl Batch {
    pub fn len(&self) -> usize {
        match self {
            Batch::Image { n, .. } | Batch::Tokens { n, .. } | Batch::Features { n, .. } => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of predictions this batch scores (for accuracy accounting):
    /// images/features count 1 per sample, token batches 1 per position.
    pub fn prediction_count(&self) -> usize {
        match self {
            Batch::Image { n, .. } | Batch::Features { n, .. } => *n,
            Batch::Tokens { n, seq, .. } => n * seq,
        }
    }
}

/// An in-memory labelled dataset from which fixed-size batches are drawn.
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Class/label histogram (used for EMD computation).
    fn label_histogram(&self) -> Vec<usize>;
    /// Assemble a batch of exactly `batch` samples drawn by `rng` (with
    /// replacement if the shard is smaller than the batch).
    fn sample_batch(&self, batch: usize, rng: &mut Rng) -> Batch;
    /// Deterministic sequential batches covering the dataset (for eval).
    fn eval_batches(&self, batch: usize) -> Vec<Batch>;
}

/// A shard = subset of a dataset assigned to one client (by index).
#[derive(Clone, Debug, Default)]
pub struct Shard {
    pub sample_ids: Vec<usize>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.sample_ids.len()
    }
    pub fn is_empty(&self) -> bool {
        self.sample_ids.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_count_by_variant() {
        let b = Batch::Image { x: vec![], y: vec![], n: 8 };
        assert_eq!(b.prediction_count(), 8);
        let t = Batch::Tokens { x: vec![], y: vec![], n: 4, seq: 20 };
        assert_eq!(t.prediction_count(), 80);
    }
}
