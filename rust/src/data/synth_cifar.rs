//! Synthetic CIFAR10-like image generator.
//!
//! Substitution for the real CIFAR10 (no dataset downloads in the build
//! environment — see DESIGN.md §Substitutions): 32×32×3 images whose class
//! signal is a class-specific 2-D sinusoidal pattern (frequency, orientation
//! and colour phase all depend on the label) superimposed with per-sample
//! Gaussian texture noise and a random global intensity shift. A small CNN
//! reaches high accuracy given enough rounds, and — the property that
//! matters for this paper — per-class gradient structure differs enough that
//! non-IID label skew produces diverging client gradients.

use super::dataset::{Batch, Dataset};
use crate::util::rng::Rng;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const PIXELS: usize = IMG * IMG * CHANNELS;
pub const NUM_CLASSES: usize = 10;

/// Owned image dataset; pixels are f32 in [0, 1], NHWC.
pub struct CifarLike {
    pub pixels: Vec<f32>, // len = n * PIXELS
    pub labels: Vec<i32>,
    pub noise: f32,
}

/// Class pattern parameters, deterministic per label.
fn class_params(label: usize) -> (f32, f32, [f32; 3]) {
    let fx = 1.0 + (label % 5) as f32; // spatial frequency 1..5
    let theta = (label as f32) * std::f32::consts::PI / NUM_CLASSES as f32;
    let phase = [
        (label as f32) * 0.7,
        (label as f32) * 1.3 + 1.0,
        (label as f32) * 2.1 + 2.0,
    ];
    (fx, theta, phase)
}

/// Render one clean class pattern pixel (before noise), in [-1, 1].
fn pattern(label: usize, row: usize, col: usize, ch: usize) -> f32 {
    let (freq, theta, phase) = class_params(label);
    let (sin_t, cos_t) = theta.sin_cos();
    let u = (row as f32 / IMG as f32) * cos_t + (col as f32 / IMG as f32) * sin_t;
    let v = -(row as f32 / IMG as f32) * sin_t + (col as f32 / IMG as f32) * cos_t;
    let s = (2.0 * std::f32::consts::PI * freq * u + phase[ch]).sin();
    let c = (2.0 * std::f32::consts::PI * (freq * 0.5 + 0.5) * v).cos();
    0.5 * s + 0.5 * c
}

impl CifarLike {
    /// Generate `n` samples with the given label sequence (labels.len() == n).
    pub fn from_labels(labels: Vec<i32>, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let n = labels.len();
        let mut pixels = vec![0.0f32; n * PIXELS];
        for (s, &label) in labels.iter().enumerate() {
            let shift = (rng.f32() - 0.5) * 0.2; // per-sample brightness
            let base = s * PIXELS;
            for row in 0..IMG {
                for col in 0..IMG {
                    for ch in 0..CHANNELS {
                        let clean = pattern(label as usize, row, col, ch);
                        let noisy = 0.5 + 0.35 * clean + noise * rng.normal() + shift;
                        pixels[base + (row * IMG + col) * CHANNELS + ch] = noisy.clamp(0.0, 1.0);
                    }
                }
            }
        }
        CifarLike { pixels, labels, noise }
    }

    /// Balanced dataset: `per_class` samples of each of the 10 classes,
    /// shuffled deterministically.
    pub fn balanced(per_class: usize, noise: f32, seed: u64) -> Self {
        let mut labels: Vec<i32> = (0..NUM_CLASSES)
            .flat_map(|c| std::iter::repeat(c as i32).take(per_class))
            .collect();
        let mut rng = Rng::new(seed ^ 0xC1FA);
        rng.shuffle(&mut labels);
        Self::from_labels(labels, noise, seed)
    }

    pub fn image(&self, idx: usize) -> &[f32] {
        &self.pixels[idx * PIXELS..(idx + 1) * PIXELS]
    }
}

impl Dataset for CifarLike {
    fn len(&self) -> usize {
        self.labels.len()
    }

    fn label_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; NUM_CLASSES];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }

    fn sample_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        let mut x = Vec::with_capacity(batch * PIXELS);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let idx = rng.below(self.len());
            x.extend_from_slice(self.image(idx));
            y.push(self.labels[idx]);
        }
        Batch::Image { x, y, n: batch }
    }

    fn eval_batches(&self, batch: usize) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut idx = 0;
        while idx + batch <= self.len() {
            let mut x = Vec::with_capacity(batch * PIXELS);
            let mut y = Vec::with_capacity(batch);
            for i in idx..idx + batch {
                x.extend_from_slice(self.image(i));
                y.push(self.labels[i]);
            }
            out.push(Batch::Image { x, y, n: batch });
            idx += batch;
        }
        out
    }
}

/// Owned client shard: shares the parent dataset via `Arc` so shards can be
/// boxed as `'static` Datasets for the coordinator.
pub struct OwnedCifarShard {
    pub parent: std::sync::Arc<CifarLike>,
    pub ids: Vec<usize>,
}

impl Dataset for OwnedCifarShard {
    fn len(&self) -> usize {
        self.ids.len()
    }

    fn label_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; NUM_CLASSES];
        for &id in &self.ids {
            h[self.parent.labels[id] as usize] += 1;
        }
        h
    }

    fn sample_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        let mut x = Vec::with_capacity(batch * PIXELS);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let id = self.ids[rng.below(self.ids.len())];
            x.extend_from_slice(self.parent.image(id));
            y.push(self.parent.labels[id]);
        }
        Batch::Image { x, y, n: batch }
    }

    fn eval_batches(&self, batch: usize) -> Vec<Batch> {
        CifarShard { parent: &self.parent, ids: self.ids.clone() }.eval_batches(batch)
    }
}

/// View of a client shard as a Dataset (samples by id into the parent).
pub struct CifarShard<'a> {
    pub parent: &'a CifarLike,
    pub ids: Vec<usize>,
}

impl<'a> Dataset for CifarShard<'a> {
    fn len(&self) -> usize {
        self.ids.len()
    }

    fn label_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; NUM_CLASSES];
        for &id in &self.ids {
            h[self.parent.labels[id] as usize] += 1;
        }
        h
    }

    fn sample_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        let mut x = Vec::with_capacity(batch * PIXELS);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let id = self.ids[rng.below(self.ids.len())];
            x.extend_from_slice(self.parent.image(id));
            y.push(self.parent.labels[id]);
        }
        Batch::Image { x, y, n: batch }
    }

    fn eval_batches(&self, batch: usize) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut idx = 0;
        while idx + batch <= self.ids.len() {
            let mut x = Vec::with_capacity(batch * PIXELS);
            let mut y = Vec::with_capacity(batch);
            for i in idx..idx + batch {
                let id = self.ids[i];
                x.extend_from_slice(self.parent.image(id));
                y.push(self.parent.labels[id]);
            }
            out.push(Batch::Image { x, y, n: batch });
            idx += batch;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_histogram() {
        let ds = CifarLike::balanced(5, 0.1, 1);
        assert_eq!(ds.len(), 50);
        assert_eq!(ds.label_histogram(), vec![5; 10]);
    }

    #[test]
    fn pixels_in_unit_range() {
        let ds = CifarLike::balanced(2, 0.3, 2);
        assert!(ds.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = CifarLike::balanced(2, 0.1, 7);
        let b = CifarLike::balanced(2, 0.1, 7);
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn classes_are_separable_by_template_correlation() {
        // nearest-template classification on clean correlations must beat
        // chance by a wide margin — the class signal is real.
        let ds = CifarLike::balanced(10, 0.15, 3);
        let mut correct = 0;
        for s in 0..ds.len() {
            let img = ds.image(s);
            let mut best = (f32::NEG_INFINITY, 0usize);
            for c in 0..NUM_CLASSES {
                let mut corr = 0.0f32;
                for row in 0..IMG {
                    for col in 0..IMG {
                        for ch in 0..CHANNELS {
                            corr += pattern(c, row, col, ch)
                                * img[(row * IMG + col) * CHANNELS + ch];
                        }
                    }
                }
                if corr > best.0 {
                    best = (corr, c);
                }
            }
            if best.1 == ds.labels[s] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.len() as f64;
        assert!(acc > 0.8, "template accuracy {acc}");
    }

    #[test]
    fn batches_have_requested_shape() {
        let ds = CifarLike::balanced(4, 0.1, 4);
        let mut rng = Rng::new(0);
        match ds.sample_batch(8, &mut rng) {
            Batch::Image { x, y, n } => {
                assert_eq!(n, 8);
                assert_eq!(x.len(), 8 * PIXELS);
                assert_eq!(y.len(), 8);
            }
            _ => panic!("wrong batch kind"),
        }
    }

    #[test]
    fn eval_batches_cover_dataset() {
        let ds = CifarLike::balanced(8, 0.1, 5); // 80 samples
        let batches = ds.eval_batches(32);
        assert_eq!(batches.len(), 2); // 64 covered, 16 tail dropped
        assert!(batches.iter().all(|b| b.len() == 32));
    }

    #[test]
    fn shard_histogram_subsets_parent() {
        let ds = CifarLike::balanced(4, 0.1, 6);
        let shard = CifarShard { parent: &ds, ids: (0..10).collect() };
        let h = shard.label_histogram();
        assert_eq!(h.iter().sum::<usize>(), 10);
    }
}
