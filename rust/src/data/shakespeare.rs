//! Synthetic Shakespeare-like corpus: naturally non-IID next-character
//! prediction across speakers (paper §4.1/§4.3).
//!
//! Substitution for LEAF's Shakespeare split (see DESIGN.md): each of the
//! `speakers` clients is a "role" whose lines are generated from a shared
//! phrase pool with a speaker-biased mixture — speakers prefer different
//! phrase families, so per-client character distributions shift relative to
//! the population, exactly the "naturally non-IID" property the paper
//! exploits. The bias strength is tuned so the measured character-level EMD
//! of a 100-speaker corpus lands near the paper's 0.1157.
//!
//! Tokenisation: chars mapped into a fixed 64-symbol vocabulary
//! (`a-z`, space, punctuation, digits reserved); sequences of length `seq`
//! with next-char targets, matching the lowered charlstm ABI.

use super::dataset::{Batch, Dataset};
use crate::util::rng::Rng;

pub const VOCAB: usize = 64;

/// Fixed char → token mapping (id 0 is <unk>/padding).
pub fn char_to_token(c: char) -> i32 {
    match c {
        'a'..='z' => 1 + (c as u8 - b'a') as i32, // 1..=26
        ' ' => 27,
        '.' => 28,
        ',' => 29,
        '!' => 30,
        '?' => 31,
        '\'' => 32,
        ';' => 33,
        ':' => 34,
        '-' => 35,
        '\n' => 36,
        _ => 0,
    }
}

/// Phrase families: shared Shakespeare-flavoured fragments. Speakers mix
/// these with different weights. (Short public-domain-style fragments.)
const PHRASES: [&[&str]; 6] = [
    &[
        "to be or not to be that is the question",
        "whether tis nobler in the mind to suffer",
        "the slings and arrows of outrageous fortune",
        "to sleep perchance to dream",
    ],
    &[
        "now is the winter of our discontent",
        "made glorious summer by this sun of york",
        "a horse! a horse! my kingdom for a horse!",
        "was ever woman in this humour wooed?",
    ],
    &[
        "shall i compare thee to a summers day?",
        "thou art more lovely and more temperate",
        "rough winds do shake the darling buds of may",
        "so long lives this, and this gives life to thee",
    ],
    &[
        "friends, romans, countrymen, lend me your ears;",
        "i come to bury caesar, not to praise him.",
        "the evil that men do lives after them;",
        "ambition should be made of sterner stuff",
    ],
    &[
        "double, double toil and trouble;",
        "fire burn and cauldron bubble.",
        "by the pricking of my thumbs,",
        "something wicked this way comes.",
    ],
    &[
        "all the worlds a stage,",
        "and all the men and women merely players;",
        "they have their exits and their entrances,",
        "and one man in his time plays many parts.",
    ],
];

/// One speaker's text stream, tokenised.
pub struct SpeakerText {
    pub tokens: Vec<i32>,
}

/// The whole corpus: one stream per speaker (= per FL client).
pub struct Shakespeare {
    pub speakers: Vec<SpeakerText>,
    pub seq: usize,
}

impl Shakespeare {
    /// Generate a corpus of `speakers` roles with ~`chars_per_speaker`
    /// characters each. `bias` in [0,1] sets how concentrated a speaker's
    /// phrase-family mixture is (0 = uniform = IID, 1 = single family).
    pub fn generate(
        speakers: usize,
        chars_per_speaker: usize,
        seq: usize,
        bias: f64,
        seed: u64,
    ) -> Self {
        let mut out = Vec::with_capacity(speakers);
        let root = Rng::new(seed ^ 0x5AE5);
        for s in 0..speakers {
            let mut rng = root.derive(s as u64);
            // speaker mixture over phrase families
            let fam = s % PHRASES.len();
            let weights: Vec<f64> = (0..PHRASES.len())
                .map(|f| {
                    let uniform = (1.0 - bias) / PHRASES.len() as f64;
                    if f == fam {
                        bias + uniform
                    } else {
                        uniform
                    }
                })
                .collect();
            let mut text = String::new();
            while text.len() < chars_per_speaker {
                let f = rng.categorical(&weights);
                let phrase = PHRASES[f][rng.below(PHRASES[f].len())];
                text.push_str(phrase);
                text.push(' ');
            }
            let tokens: Vec<i32> = text.chars().map(char_to_token).collect();
            out.push(SpeakerText { tokens });
        }
        Shakespeare { speakers: out, seq }
    }

    /// Default bias calibrated so 100 speakers measure EMD ≈ 0.1157 over
    /// character distributions (paper §4.1).
    pub const PAPER_BIAS: f64 = 0.42;

    /// Character-distribution EMD across speakers (same definition as the
    /// label-EMD in `partition.rs`, over the VOCAB-dim char histogram).
    pub fn char_emd(&self) -> f64 {
        let hists: Vec<Vec<usize>> = self
            .speakers
            .iter()
            .map(|sp| {
                let mut h = vec![0usize; VOCAB];
                for &t in &sp.tokens {
                    h[t as usize] += 1;
                }
                h
            })
            .collect();
        super::partition::emd_of_partition(&hists)
    }

    /// Train/test split per speaker: last `test_frac` of each stream is
    /// held out (temporal split, like LEAF).
    pub fn split(&self, test_frac: f64) -> (Vec<ClientStream<'_>>, Vec<ClientStream<'_>>) {
        let mut train = Vec::new();
        let mut test = Vec::new();
        for sp in &self.speakers {
            let cut = ((sp.tokens.len() as f64) * (1.0 - test_frac)) as usize;
            train.push(ClientStream { tokens: &sp.tokens[..cut], seq: self.seq });
            test.push(ClientStream { tokens: &sp.tokens[cut..], seq: self.seq });
        }
        (train, test)
    }
}

/// Owned per-client stream (for `'static` boxing into the coordinator).
pub struct OwnedStream {
    pub tokens: Vec<i32>,
    pub seq: usize,
}

impl Dataset for OwnedStream {
    fn len(&self) -> usize {
        ClientStream { tokens: &self.tokens, seq: self.seq }.len()
    }
    fn label_histogram(&self) -> Vec<usize> {
        ClientStream { tokens: &self.tokens, seq: self.seq }.label_histogram()
    }
    fn sample_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        ClientStream { tokens: &self.tokens, seq: self.seq }.sample_batch(batch, rng)
    }
    fn eval_batches(&self, batch: usize) -> Vec<Batch> {
        ClientStream { tokens: &self.tokens, seq: self.seq }.eval_batches(batch)
    }
}

impl Shakespeare {
    /// Owned train/test split (temporal, per speaker).
    pub fn split_owned(&self, test_frac: f64) -> (Vec<OwnedStream>, Vec<OwnedStream>) {
        let (train, test) = self.split(test_frac);
        (
            train
                .into_iter()
                .map(|s| OwnedStream { tokens: s.tokens.to_vec(), seq: s.seq })
                .collect(),
            test.into_iter()
                .map(|s| OwnedStream { tokens: s.tokens.to_vec(), seq: s.seq })
                .collect(),
        )
    }
}

/// A token stream viewed as a next-char dataset: sample windows of length
/// seq+1; x = first seq chars, y = shifted by one.
pub struct ClientStream<'a> {
    pub tokens: &'a [i32],
    pub seq: usize,
}

impl<'a> ClientStream<'a> {
    fn window_count(&self) -> usize {
        self.tokens.len().saturating_sub(self.seq)
    }

    fn window(&self, start: usize, x: &mut Vec<i32>, y: &mut Vec<i32>) {
        x.extend_from_slice(&self.tokens[start..start + self.seq]);
        y.extend_from_slice(&self.tokens[start + 1..start + self.seq + 1]);
    }
}

impl<'a> Dataset for ClientStream<'a> {
    fn len(&self) -> usize {
        self.window_count()
    }

    fn label_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; VOCAB];
        for &t in self.tokens {
            h[t as usize] += 1;
        }
        h
    }

    fn sample_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        let mut x = Vec::with_capacity(batch * self.seq);
        let mut y = Vec::with_capacity(batch * self.seq);
        let windows = self.window_count().max(1);
        for _ in 0..batch {
            let start = rng.below(windows);
            let start = start.min(self.tokens.len().saturating_sub(self.seq + 1));
            self.window(start, &mut x, &mut y);
        }
        Batch::Tokens { x, y, n: batch, seq: self.seq }
    }

    fn eval_batches(&self, batch: usize) -> Vec<Batch> {
        let mut out = Vec::new();
        let stride = self.seq; // non-overlapping eval windows
        let mut starts = Vec::new();
        let mut s = 0;
        while s + self.seq + 1 <= self.tokens.len() {
            starts.push(s);
            s += stride;
        }
        let mut idx = 0;
        while idx + batch <= starts.len() {
            let mut x = Vec::with_capacity(batch * self.seq);
            let mut y = Vec::with_capacity(batch * self.seq);
            for &st in &starts[idx..idx + batch] {
                self.window(st, &mut x, &mut y);
            }
            out.push(Batch::Tokens { x, y, n: batch, seq: self.seq });
            idx += batch;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let c = Shakespeare::generate(5, 500, 20, 0.4, 1);
        for sp in &c.speakers {
            assert!(sp.tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
        }
    }

    #[test]
    fn speaker_count_and_length() {
        let c = Shakespeare::generate(7, 300, 20, 0.4, 2);
        assert_eq!(c.speakers.len(), 7);
        for sp in &c.speakers {
            assert!(sp.tokens.len() >= 300);
        }
    }

    #[test]
    fn bias_zero_is_near_iid() {
        let c0 = Shakespeare::generate(20, 2000, 20, 0.0, 3);
        let c9 = Shakespeare::generate(20, 2000, 20, 0.9, 3);
        assert!(c0.char_emd() < c9.char_emd(), "{} vs {}", c0.char_emd(), c9.char_emd());
    }

    #[test]
    fn paper_bias_hits_target_emd() {
        let c = Shakespeare::generate(100, 4000, 20, Shakespeare::PAPER_BIAS, 4);
        let emd = c.char_emd();
        assert!((emd - 0.1157).abs() < 0.05, "char EMD {emd}");
    }

    #[test]
    fn next_char_targets_shifted() {
        let c = Shakespeare::generate(1, 400, 10, 0.4, 5);
        let (train, _) = c.split(0.2);
        let mut rng = Rng::new(0);
        match train[0].sample_batch(2, &mut rng) {
            Batch::Tokens { x, y, n, seq } => {
                assert_eq!((n, seq), (2, 10));
                assert_eq!(x.len(), 20);
                // y is x shifted by one within the source stream: check via
                // re-deriving from tokens is overkill; check lengths + range
                assert!(y.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
            }
            _ => panic!("wrong batch kind"),
        }
    }

    #[test]
    fn split_is_temporal_and_disjoint() {
        let c = Shakespeare::generate(3, 1000, 20, 0.4, 6);
        let (train, test) = c.split(0.25);
        for ((tr, te), sp) in train.iter().zip(&test).zip(&c.speakers) {
            assert!(tr.tokens.len() > te.tokens.len());
            assert_eq!(tr.tokens.len() + te.tokens.len(), sp.tokens.len());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Shakespeare::generate(4, 500, 20, 0.4, 7);
        let b = Shakespeare::generate(4, 500, 20, 0.4, 7);
        for (x, y) in a.speakers.iter().zip(&b.speakers) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
