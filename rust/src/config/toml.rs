//! Minimal TOML-subset parser for experiment configs.
//!
//! Supported (all the framework needs): `[section]` tables, `key = value`
//! with string / integer / float / boolean / homogeneous arrays, `#`
//! comments, blank lines. Nested tables, dates and inline tables are out of
//! scope and rejected explicitly.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// section → key → value ("" = top-level keys before any section header)
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

#[derive(Debug, thiserror::Error)]
#[error("toml parse error at line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section header"))?;
            if name.contains('[') || name.contains('.') {
                return Err(err("nested/array tables not supported"));
            }
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
        } else if let Some(eq) = line.find('=') {
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            // entry, not get_mut().unwrap(): the section header always
            // pre-inserts the table today, but a parser refactor must not be
            // able to turn that invariant into a mid-CLI panic
            doc.entry(section.clone()).or_default().insert(key.to_string(), value);
        } else {
            return Err(err("expected `key = value` or `[section]`"));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: no # inside our string values
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut out = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                out.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(out));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

/// Typed accessor over a parsed doc.
pub fn get<'a>(doc: &'a TomlDoc, section: &str, key: &str) -> Option<&'a TomlValue> {
    doc.get(section).and_then(|t| t.get(key))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = parse(
            r#"
# experiment config
[run]
task = "cifar"          # the image task
rounds = 60
seed = 42

[compress]
rate = 0.1
exact_topk = true
emd_levels = [0.0, 0.48, 1.35]
"#,
        )
        .unwrap();
        assert_eq!(get(&doc, "run", "task").unwrap().as_str(), Some("cifar"));
        assert_eq!(get(&doc, "run", "rounds").unwrap().as_usize(), Some(60));
        assert_eq!(get(&doc, "compress", "rate").unwrap().as_f64(), Some(0.1));
        assert_eq!(get(&doc, "compress", "exact_topk").unwrap().as_bool(), Some(true));
        let arr = match get(&doc, "compress", "emd_levels").unwrap() {
            TomlValue::Array(a) => a,
            _ => panic!(),
        };
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_f64(), Some(1.35));
    }

    #[test]
    fn top_level_keys() {
        let doc = parse("name = \"x\"\n[a]\nb = 1\n").unwrap();
        assert_eq!(get(&doc, "", "name").unwrap().as_str(), Some("x"));
        assert_eq!(get(&doc, "a", "b").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("keyonly\n").is_err());
        assert!(parse("k = \n").is_err());
        assert!(parse("[a.b]\n").is_err());
        assert!(parse("k = zzz\n").is_err());
    }

    #[test]
    fn string_with_hash_and_escape() {
        let doc = parse("k = \"a # not comment \\\" q\"\n").unwrap();
        assert_eq!(get(&doc, "", "k").unwrap().as_str(), Some("a # not comment \" q"));
    }

    #[test]
    fn numbers_with_underscores_and_exponents() {
        let doc = parse("a = 1_000\nb = 2.5e3\nc = -7\n").unwrap();
        assert_eq!(get(&doc, "", "a").unwrap().as_i64(), Some(1000));
        assert_eq!(get(&doc, "", "b").unwrap().as_f64(), Some(2500.0));
        assert_eq!(get(&doc, "", "c").unwrap().as_i64(), Some(-7));
    }
}
