//! Experiment configuration: typed config + TOML loading + presets.
//!
//! A [`RunConfig`] fully describes one FL training run (task, model,
//! engine, technique, compression and schedule hyper-parameters, data
//! shape, scale). Experiment harnesses build them programmatically; the
//! CLI loads them from TOML files (see `configs/` at the repo root) with
//! `--set section.key=value` overrides.
//!
//! ## Threading
//!
//! `run.workers` (TOML) / [`RunConfig::workers`] controls the round loop's
//! client fan-out: `0` (the default) uses one worker per available core,
//! `1` forces sequential execution, any other value caps the thread pool.
//! Results are **bit-identical at every setting** — the parallel path only
//! reorders embarrassingly-parallel per-client work, never the reductions —
//! so the knob is purely a performance/affinity control (e.g.
//! `--set run.workers=1` to profile the sequential path, or a low value to
//! share a box between experiment sweeps). Engines that cannot provide
//! per-worker instances (the PJRT engine) always run sequentially.

pub mod toml;

use crate::compress::{
    CompressConfig, CompressorKind, RateControlConfig, RateControlMode, SparsityWarmup,
    TauSchedule,
};
use crate::coordinator::hierarchy::HierarchyConfig;
use crate::coordinator::round::{FlConfig, LrSchedule};
use crate::coordinator::sampler::Sampler;
use crate::coordinator::store::StoreMode;
use crate::coordinator::traffic::TrafficPolicy;
use crate::sim::scheduler::{ProfilePreset, SelectionPolicy, SimConfig, StalenessPolicy};
use crate::sparse::codec::{IndexCoding, ValueCoding, WireCodec};
use crate::sparse::KernelMode;
use crate::transport::fault::FaultPlan;
use crate::transport::TransportConfig;
use anyhow::{anyhow, Result};
use toml::{get, parse, TomlDoc};

/// Which workload a run trains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// synthetic Mod-CIFAR10 image classification (paper §4.2)
    Cifar,
    /// synthetic Shakespeare next-char prediction (paper §4.3)
    Shakespeare,
    /// Gaussian blobs on the native engine (tests / CI)
    Blobs,
}

impl Task {
    pub fn parse(s: &str) -> Option<Task> {
        match s.to_ascii_lowercase().as_str() {
            "cifar" | "cifar10" | "mod-cifar10" => Some(Task::Cifar),
            "shakespeare" | "shake" => Some(Task::Shakespeare),
            "blobs" => Some(Task::Blobs),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Task::Cifar => "cifar",
            Task::Shakespeare => "shakespeare",
            Task::Blobs => "blobs",
        }
    }
}

/// Which engine executes the model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT artifacts on the PJRT CPU client (production path)
    Pjrt,
    /// pure-Rust MLP (tests / artifact-free quick runs)
    Native,
}

/// Experiment scale: trades fidelity for wall-clock on this CPU testbed.
/// `Paper` reproduces the paper's round/client counts exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Default,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s.to_ascii_lowercase().as_str() {
            "quick" | "smoke" => Some(Scale::Quick),
            "default" | "small" => Some(Scale::Default),
            "paper" | "full" => Some(Scale::Paper),
            _ => None,
        }
    }
}

/// Complete description of one FL run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub task: Task,
    pub engine: EngineKind,
    /// manifest model name (pjrt engine)
    pub model: String,
    pub technique: CompressorKind,
    pub clients: usize,
    pub rounds: usize,
    pub rate: f64,
    pub emd: f64,
    pub alpha: f32,
    pub beta: f32,
    pub tau_end: f32,
    pub tau_steps: usize,
    pub clip_norm: f32,
    pub exact_topk: bool,
    pub warmup_rounds: usize,
    pub lr: f32,
    pub batch: usize,
    pub local_steps: usize,
    pub eval_every: usize,
    pub seed: u64,
    /// samples per client (cifar/blobs) or chars per speaker (shakespeare)
    pub samples_per_client: usize,
    pub test_size: usize,
    pub downlink_per_client: bool,
    pub client_fraction: f64,
    /// round-loop worker threads: 0 = one per core, 1 = sequential
    /// (bit-identical results at any setting; see module docs)
    pub workers: usize,
    /// record the exact O(clients²·nnz) mask-overlap diagnostic instead of
    /// the O(nnz) estimate (analysis runs; TOML `run.exact_mask_overlap`)
    pub exact_mask_overlap: bool,
    /// fold uploads into the server aggregate straight from their wire
    /// bytes via the codec-v2 pull-decoder (TOML `run.streamed_ingest`);
    /// bit-identical to the default materialized ingest
    pub streamed_ingest: bool,
    /// hot-path kernel dispatch (TOML `run.kernels`: `auto` | `scalar` |
    /// `simd`; see docs/perf.md) — every kernel is bit-identical across
    /// modes, so this is purely a performance / CI-matrix control. The
    /// `FEDGMF_KERNELS` env var overrides this knob.
    pub kernels: KernelMode,
    /// time-domain scheduler knobs (TOML `[sim]` — see `docs/config.md`);
    /// the default is inert and preserves schedulerless output bit-exactly
    pub sim: SimConfig,
    /// per-direction wire codec (TOML `[codec]` — see `docs/wire.md`); the
    /// default (raw u32 + f32) emits v1 bytes and trajectories bit-exactly
    pub codec: WireCodec,
    /// service-mode socket settings + chaos plan (TOML `[transport]` — see
    /// `docs/transport.md`); the fault plan also reaches the in-process
    /// simulator through [`FlConfig::fault`], everything else only matters
    /// to `fedgmf serve` / `fedgmf client`
    pub transport: TransportConfig,
    /// fleet-state residency (TOML `run.store` — see `docs/hierarchy.md`):
    /// `auto` virtualizes whenever a sampler leaves clients idle, `dense`
    /// forces one resident buffer set per client, `virtual` forces
    /// sparse-at-rest records with pooled cohort scratch
    pub store: StoreMode,
    /// fleet topology (TOML `[hierarchy]` — see `docs/hierarchy.md`); the
    /// default is the paper's flat hub-and-spoke and is bit-inert
    pub hierarchy: HierarchyConfig,
    /// per-client adaptive rate controller (TOML `[rate_control]` — see
    /// `docs/config.md`); the default (`off`) plans nothing and keeps the
    /// run bit-identical to a pre-controller build
    pub rate_control: RateControlConfig,
}

/// Read one `[codec]` key through the coding's parser (shared by the
/// index and value variants — they differ only in the parse fn).
fn read_codec_key<T>(
    doc: &TomlDoc,
    key: &str,
    parse: fn(&str) -> Option<T>,
) -> Result<Option<T>> {
    match get(doc, "codec", key) {
        None => Ok(None),
        Some(v) => {
            let s = v.as_str().ok_or_else(|| anyhow!("codec.{key}: string"))?;
            parse(s).map(Some).ok_or_else(|| anyhow!("unknown codec.{key} `{s}`"))
        }
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            task: Task::Cifar,
            engine: EngineKind::Pjrt,
            model: "resnet8".into(),
            technique: CompressorKind::Dgc,
            clients: 10,
            rounds: 30,
            rate: 0.1,
            emd: 0.0,
            alpha: 0.9,
            beta: 0.9,
            tau_end: 0.6,
            tau_steps: 10,
            clip_norm: 5.0,
            exact_topk: false,
            warmup_rounds: 4,
            lr: 0.1,
            batch: 32,
            local_steps: 1,
            eval_every: 10,
            seed: 42,
            samples_per_client: 100,
            test_size: 320,
            downlink_per_client: false,
            client_fraction: 1.0,
            workers: 0,
            exact_mask_overlap: false,
            streamed_ingest: false,
            kernels: KernelMode::Auto,
            sim: SimConfig::default(),
            codec: WireCodec::default(),
            transport: TransportConfig::default(),
            store: StoreMode::Auto,
            hierarchy: HierarchyConfig::default(),
            rate_control: RateControlConfig::default(),
        }
    }
}

impl RunConfig {
    /// Paper-default Shakespeare run shape (Table 1: 100 clients, 80 rounds).
    pub fn shakespeare() -> Self {
        RunConfig {
            task: Task::Shakespeare,
            model: "charlstm".into(),
            clients: 100,
            rounds: 30,
            lr: 1.0,
            batch: 16,
            samples_per_client: 2000,
            client_fraction: 0.1, // 10 of 100 speakers per round keeps CPU tractable
            ..Default::default()
        }
    }

    /// Apply a scale preset (round/client counts).
    pub fn with_scale(mut self, scale: Scale) -> Self {
        match (self.task, scale) {
            (Task::Cifar, Scale::Quick) => {
                self.clients = 4;
                self.rounds = 6;
                self.samples_per_client = 40;
                self.test_size = 64;
                self.eval_every = 3;
            }
            (Task::Cifar, Scale::Default) => {} // struct defaults
            (Task::Cifar, Scale::Paper) => {
                self.clients = 20;
                self.rounds = 220;
                self.samples_per_client = 2500;
                self.test_size = 1000;
            }
            (Task::Shakespeare, Scale::Quick) => {
                self.clients = 10;
                self.rounds = 6;
                self.samples_per_client = 600;
                self.test_size = 64;
                self.eval_every = 3;
                self.client_fraction = 1.0;
            }
            (Task::Shakespeare, Scale::Default) => {}
            (Task::Shakespeare, Scale::Paper) => {
                self.clients = 100;
                self.rounds = 80;
                self.samples_per_client = 4000;
                self.client_fraction = 1.0;
            }
            (Task::Blobs, _) => {}
        }
        self
    }

    /// Build the coordinator config.
    pub fn fl_config(&self) -> FlConfig {
        FlConfig {
            kind: self.technique,
            compress: CompressConfig {
                alpha: self.alpha,
                beta: self.beta,
                tau: TauSchedule::Stepped {
                    end: self.tau_end,
                    steps: self.tau_steps,
                    total_rounds: self.rounds,
                },
                clip_norm: self.clip_norm,
                exact_topk: self.exact_topk,
            },
            rounds: self.rounds,
            batch_size: self.batch,
            local_steps: self.local_steps,
            lr: LrSchedule::step_at_halves(self.lr, self.rounds),
            warmup: SparsityWarmup { rate: self.rate, warmup_rounds: self.warmup_rounds },
            sampler: if self.client_fraction >= 1.0 {
                Sampler::Full
            } else {
                Sampler::Fraction(self.client_fraction)
            },
            traffic: TrafficPolicy { downlink_per_client: self.downlink_per_client },
            eval_every: self.eval_every,
            seed: self.seed,
            workers: self.workers,
            exact_mask_overlap: self.exact_mask_overlap,
            streamed_ingest: self.streamed_ingest,
            sim: self.sim,
            codec: self.codec,
            fault: self.transport.fault,
            store: self.store,
            hierarchy: self.hierarchy.clone(),
            rate_control: self.rate_control,
        }
    }

    /// Load from a TOML file + `section.key=value` overrides.
    pub fn from_toml_str(text: &str, overrides: &[String]) -> Result<Self> {
        let mut doc = parse(text).map_err(|e| anyhow!("{e}"))?;
        for ov in overrides {
            let (path, value) = ov
                .split_once('=')
                .ok_or_else(|| anyhow!("override `{ov}` must be section.key=value"))?;
            let (section, key) = path.trim().split_once('.').unwrap_or(("", path.trim()));
            if key.is_empty() {
                return Err(anyhow!("override `{ov}`: empty key (expected section.key=value)"));
            }
            let parsed = toml::parse(&format!("k = {}", value.trim()))
                .map_err(|e| anyhow!("override `{ov}`: {e}"))?;
            // a value that parses but doesn't land as `k` in the root table
            // (e.g. one smuggling a `[section]` header or a newline) would
            // have panicked the old direct indexing — reject it with context
            let v = parsed
                .get("")
                .and_then(|root| root.get("k"))
                .ok_or_else(|| {
                    anyhow!(
                        "override `{ov}`: `{}` is not a plain TOML value for key `{key}`",
                        value.trim()
                    )
                })?
                .clone();
            doc.entry(section.to_string()).or_default().insert(key.to_string(), v);
        }
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = RunConfig::default();
        if let Some(v) = get(doc, "run", "task").and_then(|v| v.as_str()) {
            cfg.task = Task::parse(v).ok_or_else(|| anyhow!("unknown task `{v}`"))?;
            if cfg.task == Task::Shakespeare {
                cfg = RunConfig { task: cfg.task, ..RunConfig::shakespeare() };
            }
        }
        macro_rules! read {
            ($sec:literal, $key:literal, $field:ident, $conv:ident, $ty:ty) => {
                if let Some(v) = get(doc, $sec, $key) {
                    cfg.$field = v
                        .$conv()
                        .ok_or_else(|| anyhow!(concat!($sec, ".", $key, ": wrong type")))?
                        as $ty;
                }
            };
        }
        if let Some(v) = get(doc, "run", "engine").and_then(|v| v.as_str()) {
            cfg.engine = match v {
                "pjrt" => EngineKind::Pjrt,
                "native" => EngineKind::Native,
                other => return Err(anyhow!("unknown engine `{other}`")),
            };
        }
        if let Some(v) = get(doc, "run", "model").and_then(|v| v.as_str()) {
            cfg.model = v.to_string();
        }
        if let Some(v) = get(doc, "run", "technique").and_then(|v| v.as_str()) {
            cfg.technique =
                CompressorKind::parse(v).ok_or_else(|| anyhow!("unknown technique `{v}`"))?;
        }
        read!("run", "rounds", rounds, as_usize, usize);
        read!("run", "seed", seed, as_usize, u64);
        read!("run", "workers", workers, as_usize, usize);
        if let Some(v) = get(doc, "run", "exact_mask_overlap") {
            cfg.exact_mask_overlap =
                v.as_bool().ok_or_else(|| anyhow!("run.exact_mask_overlap: bool"))?;
        }
        if let Some(v) = get(doc, "run", "streamed_ingest") {
            cfg.streamed_ingest =
                v.as_bool().ok_or_else(|| anyhow!("run.streamed_ingest: bool"))?;
        }
        if let Some(v) = get(doc, "run", "kernels") {
            let s = v.as_str().ok_or_else(|| anyhow!("run.kernels: string"))?;
            cfg.kernels =
                KernelMode::parse(s).ok_or_else(|| anyhow!("unknown run.kernels `{s}`"))?;
        }
        if let Some(v) = get(doc, "run", "store") {
            let s = v.as_str().ok_or_else(|| anyhow!("run.store: string"))?;
            cfg.store = StoreMode::parse(s).ok_or_else(|| anyhow!("unknown run.store `{s}`"))?;
        }
        read!("data", "clients", clients, as_usize, usize);
        read!("data", "samples_per_client", samples_per_client, as_usize, usize);
        read!("data", "test_size", test_size, as_usize, usize);
        read!("data", "emd", emd, as_f64, f64);
        read!("compress", "rate", rate, as_f64, f64);
        read!("compress", "alpha", alpha, as_f64, f32);
        read!("compress", "beta", beta, as_f64, f32);
        read!("compress", "tau_end", tau_end, as_f64, f32);
        read!("compress", "tau_steps", tau_steps, as_usize, usize);
        read!("compress", "clip_norm", clip_norm, as_f64, f32);
        read!("compress", "warmup_rounds", warmup_rounds, as_usize, usize);
        if let Some(v) = get(doc, "compress", "exact_topk") {
            cfg.exact_topk = v.as_bool().ok_or_else(|| anyhow!("compress.exact_topk: bool"))?;
        }
        read!("train", "lr", lr, as_f64, f32);
        read!("train", "batch", batch, as_usize, usize);
        read!("train", "local_steps", local_steps, as_usize, usize);
        read!("train", "eval_every", eval_every, as_usize, usize);
        read!("train", "client_fraction", client_fraction, as_f64, f64);
        if let Some(v) = get(doc, "traffic", "downlink_per_client") {
            cfg.downlink_per_client =
                v.as_bool().ok_or_else(|| anyhow!("traffic.downlink_per_client: bool"))?;
        }
        // [sim] — time-domain scheduler. Profile shape knobs (slow_every /
        // slow_factor / sigma) only take effect through `sim.profile`.
        {
            let mut slow_every = 4usize;
            let mut slow_factor = 10.0f64;
            let mut sigma = 0.8f64;
            if let Some(v) = get(doc, "sim", "slow_every") {
                slow_every = v.as_usize().ok_or_else(|| anyhow!("sim.slow_every: wrong type"))?;
            }
            if let Some(v) = get(doc, "sim", "slow_factor") {
                slow_factor = v.as_f64().ok_or_else(|| anyhow!("sim.slow_factor: wrong type"))?;
            }
            if let Some(v) = get(doc, "sim", "sigma") {
                sigma = v.as_f64().ok_or_else(|| anyhow!("sim.sigma: wrong type"))?;
            }
            if let Some(v) = get(doc, "sim", "profile") {
                let name = v.as_str().ok_or_else(|| anyhow!("sim.profile: string"))?;
                cfg.sim.preset = match name.to_ascii_lowercase().as_str() {
                    "uniform" => ProfilePreset::Uniform,
                    "heterogeneous" | "hetero" => {
                        ProfilePreset::Heterogeneous { slow_every, slow_factor }
                    }
                    "longtail" | "long-tail" | "long_tail" => ProfilePreset::LongTail { sigma },
                    other => return Err(anyhow!("unknown sim.profile `{other}`")),
                };
            }
            if let Some(v) = get(doc, "sim", "deadline_s") {
                cfg.sim.deadline_s =
                    v.as_f64().ok_or_else(|| anyhow!("sim.deadline_s: wrong type"))?;
            }
            if let Some(v) = get(doc, "sim", "dropout") {
                cfg.sim.dropout = v.as_f64().ok_or_else(|| anyhow!("sim.dropout: wrong type"))?;
            }
            if let Some(v) = get(doc, "sim", "overselect") {
                cfg.sim.overselect =
                    v.as_f64().ok_or_else(|| anyhow!("sim.overselect: wrong type"))?;
            }
            if let Some(v) = get(doc, "sim", "compute_s") {
                cfg.sim.compute_s =
                    v.as_f64().ok_or_else(|| anyhow!("sim.compute_s: wrong type"))?;
            }
            // semi-synchronous aggregation: sim.staleness_alpha only takes
            // effect through `sim.staleness = "carry_discounted"` (like the
            // profile shape knobs above)
            let mut staleness_alpha = 0.5f64;
            if let Some(v) = get(doc, "sim", "staleness_alpha") {
                staleness_alpha =
                    v.as_f64().ok_or_else(|| anyhow!("sim.staleness_alpha: wrong type"))?;
            }
            if let Some(v) = get(doc, "sim", "staleness") {
                let name = v.as_str().ok_or_else(|| anyhow!("sim.staleness: string"))?;
                cfg.sim.staleness = match name.to_ascii_lowercase().as_str() {
                    "drop" => StalenessPolicy::Drop,
                    "carry" => StalenessPolicy::Carry,
                    "carry_discounted" | "carry-discounted" | "discounted" => {
                        StalenessPolicy::CarryDiscounted(staleness_alpha)
                    }
                    other => return Err(anyhow!("unknown sim.staleness `{other}`")),
                };
            }
            // scheduler-aware selection: sim.selection_beta only takes
            // effect through `sim.selection = "feasibility"`
            let mut selection_beta = 0.5f64;
            if let Some(v) = get(doc, "sim", "selection_beta") {
                selection_beta =
                    v.as_f64().ok_or_else(|| anyhow!("sim.selection_beta: wrong type"))?;
            }
            if let Some(v) = get(doc, "sim", "selection") {
                let name = v.as_str().ok_or_else(|| anyhow!("sim.selection: string"))?;
                cfg.sim.selection = match name.to_ascii_lowercase().as_str() {
                    "uniform" => SelectionPolicy::Uniform,
                    "feasibility" | "feasible" => {
                        SelectionPolicy::Feasibility { beta: selection_beta }
                    }
                    other => return Err(anyhow!("unknown sim.selection `{other}`")),
                };
            }
        }
        // [codec] — wire codec v2. `index`/`value` set both directions,
        // `uplink_*`/`downlink_*` override per direction.
        {
            if let Some(ix) = read_codec_key(doc, "index", IndexCoding::parse)? {
                cfg.codec.uplink.index = ix;
                cfg.codec.downlink.index = ix;
            }
            if let Some(val) = read_codec_key(doc, "value", ValueCoding::parse)? {
                cfg.codec.uplink.value = val;
                cfg.codec.downlink.value = val;
            }
            if let Some(ix) = read_codec_key(doc, "uplink_index", IndexCoding::parse)? {
                cfg.codec.uplink.index = ix;
            }
            if let Some(val) = read_codec_key(doc, "uplink_value", ValueCoding::parse)? {
                cfg.codec.uplink.value = val;
            }
            if let Some(ix) = read_codec_key(doc, "downlink_index", IndexCoding::parse)? {
                cfg.codec.downlink.index = ix;
            }
            if let Some(val) = read_codec_key(doc, "downlink_value", ValueCoding::parse)? {
                cfg.codec.downlink.value = val;
            }
        }
        // [hierarchy] — fleet topology (see docs/hierarchy.md). The default
        // (tiers = 1) is the paper's flat hub-and-spoke.
        {
            if let Some(v) = get(doc, "hierarchy", "tiers") {
                cfg.hierarchy.tiers =
                    v.as_usize().ok_or_else(|| anyhow!("hierarchy.tiers: wrong type"))?;
            }
            if let Some(v) = get(doc, "hierarchy", "cohorts_per_edge") {
                cfg.hierarchy.cohorts_per_edge = v
                    .as_usize()
                    .ok_or_else(|| anyhow!("hierarchy.cohorts_per_edge: wrong type"))?;
            }
            if let Some(v) = get(doc, "hierarchy", "edge_uplink_bps") {
                cfg.hierarchy.edge_uplink_bps =
                    v.as_f64().ok_or_else(|| anyhow!("hierarchy.edge_uplink_bps: wrong type"))?;
            }
        }
        // [rate_control] — per-client adaptive rate controller (see
        // docs/config.md). Like [sim], the shape knobs are read first and
        // only take effect through `rate_control.mode`.
        {
            if let Some(v) = get(doc, "rate_control", "min_rate_frac") {
                cfg.rate_control.min_rate_frac =
                    v.as_f64().ok_or_else(|| anyhow!("rate_control.min_rate_frac: wrong type"))?;
            }
            if let Some(v) = get(doc, "rate_control", "max_rate_boost") {
                cfg.rate_control.max_rate_boost =
                    v.as_f64().ok_or_else(|| anyhow!("rate_control.max_rate_boost: wrong type"))?;
            }
            if let Some(v) = get(doc, "rate_control", "deadline_margin") {
                cfg.rate_control.deadline_margin = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("rate_control.deadline_margin: wrong type"))?;
            }
            if let Some(v) = get(doc, "rate_control", "adapt_coding") {
                cfg.rate_control.adapt_coding =
                    v.as_bool().ok_or_else(|| anyhow!("rate_control.adapt_coding: bool"))?;
            }
            if let Some(v) = get(doc, "rate_control", "mode") {
                let s = v.as_str().ok_or_else(|| anyhow!("rate_control.mode: string"))?;
                cfg.rate_control.mode = RateControlMode::parse(s)
                    .ok_or_else(|| anyhow!("unknown rate_control.mode `{s}`"))?;
            }
        }
        // [transport] — service-mode sockets + chaos (see docs/transport.md).
        // `fault` defaults its seed to the run seed so every party that
        // agrees on run.seed agrees on the chaos plan.
        {
            if let Some(v) = get(doc, "transport", "addr") {
                cfg.transport.addr =
                    v.as_str().ok_or_else(|| anyhow!("transport.addr: string"))?.to_string();
            }
            let mut read_ms = |key: &str, field: &mut u64| -> Result<()> {
                if let Some(v) = get(doc, "transport", key) {
                    *field = v
                        .as_usize()
                        .ok_or_else(|| anyhow!("transport.{key}: wrong type"))?
                        as u64;
                }
                Ok(())
            };
            read_ms("read_timeout_ms", &mut cfg.transport.read_timeout_ms)?;
            read_ms("write_timeout_ms", &mut cfg.transport.write_timeout_ms)?;
            read_ms("round_deadline_ms", &mut cfg.transport.round_deadline_ms)?;
            read_ms("backoff_base_ms", &mut cfg.transport.backoff_base_ms)?;
            read_ms("backoff_max_ms", &mut cfg.transport.backoff_max_ms)?;
            if let Some(v) = get(doc, "transport", "max_retries") {
                cfg.transport.max_retries =
                    v.as_usize().ok_or_else(|| anyhow!("transport.max_retries: wrong type"))?
                        as u32;
            }
            if let Some(v) = get(doc, "transport", "fault") {
                let s = v.as_str().ok_or_else(|| anyhow!("transport.fault: string"))?;
                cfg.transport.fault =
                    Some(FaultPlan::parse(s, cfg.seed).map_err(|e| anyhow!(e))?);
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0 < self.rate && self.rate <= 1.0) {
            return Err(anyhow!("rate must be in (0, 1], got {}", self.rate));
        }
        if !(0.0..=1.0).contains(&(self.tau_end as f64)) {
            return Err(anyhow!("tau_end must be in [0, 1]"));
        }
        if self.clients == 0 || self.rounds == 0 || self.batch == 0 {
            return Err(anyhow!("clients, rounds and batch must be positive"));
        }
        if self.task == Task::Cifar && self.emd > 1.8 {
            return Err(anyhow!("cifar EMD max is 1.8 (10 classes), got {}", self.emd));
        }
        self.sim.validate().map_err(|e| anyhow!(e))?;
        self.hierarchy.validate()?;
        self.rate_control.validate().map_err(|e| anyhow!(e))?;
        Ok(())
    }

    pub fn describe(&self) -> String {
        let mut s = format!(
            "{} | {} | {} clients | {} rounds | rate {} | EMD {} | engine {:?}",
            self.task.name(),
            self.technique.name(),
            self.clients,
            self.rounds,
            self.rate,
            self.emd,
            self.engine
        );
        if self.sim.scheduling_active() {
            s.push_str(&format!(
                " | sim: {} deadline={}s dropout={} overselect={} compute={}s staleness={} selection={}",
                self.sim.preset.name(),
                self.sim.deadline_s,
                self.sim.dropout,
                self.sim.overselect,
                self.sim.compute_s,
                self.sim.staleness.name(),
                self.sim.selection.name()
            ));
        }
        if !self.codec.is_v1() {
            s.push_str(&format!(
                " | codec: up={} down={}",
                self.codec.uplink.describe(),
                self.codec.downlink.describe()
            ));
        }
        if self.store != StoreMode::Auto {
            s.push_str(&format!(" | store: {}", self.store.name()));
        }
        if self.hierarchy.enabled() {
            s.push_str(&format!(
                " | hierarchy: {} tiers, {} cohorts/edge",
                self.hierarchy.tiers, self.hierarchy.cohorts_per_edge
            ));
        }
        if self.rate_control.active() {
            s.push_str(&format!(" | rate_control: {}", self.rate_control.describe()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
        RunConfig::shakespeare().validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = RunConfig::from_toml_str(
            r#"
[run]
task = "cifar"
technique = "dgcwgmf"
rounds = 12
[data]
clients = 5
emd = 0.99
[compress]
rate = 0.3
"#,
            &[],
        )
        .unwrap();
        assert_eq!(cfg.technique, CompressorKind::DgcWgmf);
        assert_eq!(cfg.rounds, 12);
        assert_eq!(cfg.clients, 5);
        assert!((cfg.emd - 0.99).abs() < 1e-12);
        assert!((cfg.rate - 0.3).abs() < 1e-12);
    }

    #[test]
    fn overrides_win() {
        let cfg = RunConfig::from_toml_str(
            "[run]\ntask = \"cifar\"\nrounds = 10\n",
            &["run.rounds=99".to_string(), "compress.rate=0.5".to_string()],
        )
        .unwrap();
        assert_eq!(cfg.rounds, 99);
        assert!((cfg.rate - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_invalid() {
        assert!(RunConfig::from_toml_str("[compress]\nrate = 0.0\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[run]\ntask = \"nope\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[run]\ntechnique = \"nope\"\n", &[]).is_err());
    }

    #[test]
    fn scale_presets() {
        let q = RunConfig::default().with_scale(Scale::Quick);
        assert!(q.rounds < RunConfig::default().rounds);
        let p = RunConfig::default().with_scale(Scale::Paper);
        assert_eq!(p.rounds, 220);
        assert_eq!(p.clients, 20);
        let sp = RunConfig::shakespeare().with_scale(Scale::Paper);
        assert_eq!(sp.rounds, 80);
        assert_eq!(sp.clients, 100);
    }

    #[test]
    fn fl_config_reflects_fields() {
        let mut rc = RunConfig::default();
        rc.rate = 0.2;
        rc.technique = CompressorKind::DgcWgm;
        rc.workers = 3;
        let fc = rc.fl_config();
        assert_eq!(fc.kind, CompressorKind::DgcWgm);
        assert!((fc.warmup.rate - 0.2).abs() < 1e-12);
        assert_eq!(fc.rounds, rc.rounds);
        assert_eq!(fc.workers, 3);
    }

    #[test]
    fn workers_knob_from_toml() {
        assert_eq!(RunConfig::default().workers, 0, "default = one worker per core");
        let cfg =
            RunConfig::from_toml_str("[run]\ntask = \"cifar\"\nworkers = 1\n", &[]).unwrap();
        assert_eq!(cfg.workers, 1);
        let cfg = RunConfig::from_toml_str("", &["run.workers=4".to_string()]).unwrap();
        assert_eq!(cfg.workers, 4);
    }

    #[test]
    fn sim_section_from_toml() {
        let cfg = RunConfig::from_toml_str(
            r#"
[sim]
profile = "heterogeneous"
slow_every = 5
slow_factor = 8.0
deadline_s = 0.5
dropout = 0.02
overselect = 1.25
compute_s = 0.05
"#,
            &[],
        )
        .unwrap();
        assert_eq!(
            cfg.sim.preset,
            ProfilePreset::Heterogeneous { slow_every: 5, slow_factor: 8.0 }
        );
        assert!((cfg.sim.deadline_s - 0.5).abs() < 1e-12);
        assert!((cfg.sim.dropout - 0.02).abs() < 1e-12);
        assert!((cfg.sim.overselect - 1.25).abs() < 1e-12);
        assert!((cfg.sim.compute_s - 0.05).abs() < 1e-12);
        assert!(cfg.sim.scheduling_active());
        assert_eq!(cfg.fl_config().sim, cfg.sim);
        assert!(cfg.describe().contains("deadline=0.5"));
        // default stays inert
        let plain = RunConfig::from_toml_str("", &[]).unwrap();
        assert!(!plain.sim.scheduling_active());
        assert!(!plain.describe().contains("deadline"));
        // longtail + --set override path
        let lt = RunConfig::from_toml_str(
            "[sim]\nprofile = \"longtail\"\nsigma = 1.2\n",
            &["sim.dropout=0.1".to_string()],
        )
        .unwrap();
        assert_eq!(lt.sim.preset, ProfilePreset::LongTail { sigma: 1.2 });
        assert!((lt.sim.dropout - 0.1).abs() < 1e-12);
    }

    #[test]
    fn staleness_and_selection_from_toml() {
        let cfg = RunConfig::from_toml_str(
            r#"
[sim]
deadline_s = 0.25
staleness = "carry_discounted"
staleness_alpha = 0.3
selection = "feasibility"
selection_beta = 0.8
"#,
            &[],
        )
        .unwrap();
        assert_eq!(cfg.sim.staleness, StalenessPolicy::CarryDiscounted(0.3));
        assert_eq!(cfg.sim.selection, SelectionPolicy::Feasibility { beta: 0.8 });
        assert!(cfg.sim.scheduling_active());
        assert!(cfg.describe().contains("staleness=carry_discounted"));
        assert!(cfg.describe().contains("selection=feasibility"));
        // plain carry, alpha ignored
        let carry =
            RunConfig::from_toml_str("[sim]\nstaleness = \"carry\"\n", &[]).unwrap();
        assert_eq!(carry.sim.staleness, StalenessPolicy::Carry);
        // --set override path
        let ov = RunConfig::from_toml_str(
            "",
            &["sim.staleness=\"carry\"".to_string(), "sim.selection=\"uniform\"".to_string()],
        )
        .unwrap();
        assert_eq!(ov.sim.staleness, StalenessPolicy::Carry);
        assert_eq!(ov.sim.selection, SelectionPolicy::Uniform);
        // defaults stay inert
        let plain = RunConfig::from_toml_str("", &[]).unwrap();
        assert_eq!(plain.sim.staleness, StalenessPolicy::Drop);
        assert_eq!(plain.sim.selection, SelectionPolicy::Uniform);
    }

    #[test]
    fn staleness_and_selection_reject_bad_values() {
        assert!(RunConfig::from_toml_str("[sim]\nstaleness = \"nope\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[sim]\nselection = \"nope\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str(
            "[sim]\nstaleness = \"carry_discounted\"\nstaleness_alpha = 1.5\n",
            &[]
        )
        .is_err());
        assert!(RunConfig::from_toml_str(
            "[sim]\nselection = \"feasibility\"\nselection_beta = -0.1\n",
            &[]
        )
        .is_err());
        assert!(RunConfig::from_toml_str("[sim]\nstaleness = 3\n", &[]).is_err());
    }

    #[test]
    fn sim_section_rejects_bad_values() {
        assert!(RunConfig::from_toml_str("[sim]\ndropout = 1.5\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[sim]\noverselect = 0.2\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[sim]\ndeadline_s = -2.0\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[sim]\nprofile = \"nope\"\n", &[]).is_err());
        assert!(
            RunConfig::from_toml_str("[sim]\nprofile = \"heterogeneous\"\nslow_every = 0\n", &[])
                .is_err()
        );
    }

    #[test]
    fn codec_section_from_toml() {
        // default: inert (v1) in both directions
        let plain = RunConfig::from_toml_str("", &[]).unwrap();
        assert!(plain.codec.is_v1());
        assert!(!plain.describe().contains("codec"));
        // both directions via index/value
        let cfg = RunConfig::from_toml_str(
            "[codec]\nindex = \"varint\"\nvalue = \"f16\"\n",
            &[],
        )
        .unwrap();
        assert_eq!(cfg.codec.uplink.index, IndexCoding::Varint);
        assert_eq!(cfg.codec.uplink.value, ValueCoding::F16);
        assert_eq!(cfg.codec.downlink, cfg.codec.uplink);
        assert_eq!(cfg.fl_config().codec, cfg.codec);
        assert!(cfg.describe().contains("codec: up=varint+f16 down=varint+f16"));
        // per-direction overrides win over the shared keys
        let mixed = RunConfig::from_toml_str(
            r#"
[codec]
index = "varint"
value = "q8"
downlink_value = "f32"
uplink_index = "raw"
"#,
            &[],
        )
        .unwrap();
        assert_eq!(mixed.codec.uplink.index, IndexCoding::Raw);
        assert_eq!(mixed.codec.uplink.value, ValueCoding::Q8);
        assert_eq!(mixed.codec.downlink.index, IndexCoding::Varint);
        assert_eq!(mixed.codec.downlink.value, ValueCoding::F32);
        // --set override path
        let ov = RunConfig::from_toml_str("", &["codec.index=\"varint\"".to_string()]).unwrap();
        assert_eq!(ov.codec.uplink.index, IndexCoding::Varint);
        assert_eq!(ov.codec.uplink.value, ValueCoding::F32);
    }

    #[test]
    fn codec_section_rejects_bad_values() {
        assert!(RunConfig::from_toml_str("[codec]\nindex = \"nope\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[codec]\nvalue = \"f8\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[codec]\nuplink_value = 3\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[codec]\ndownlink_index = true\n", &[]).is_err());
    }

    #[test]
    fn transport_section_from_toml() {
        use crate::transport::fault::FaultKind;
        // default: loopback TCP, no chaos, inert for the simulator
        let plain = RunConfig::from_toml_str("", &[]).unwrap();
        assert_eq!(plain.transport, TransportConfig::default());
        assert_eq!(plain.fl_config().fault, None);
        let cfg = RunConfig::from_toml_str(
            r#"
[run]
seed = 9
[transport]
addr = "unix:/tmp/fedgmf.sock"
read_timeout_ms = 500
write_timeout_ms = 600
round_deadline_ms = 5000
max_retries = 3
backoff_base_ms = 10
backoff_max_ms = 80
fault = "drop:0.25"
"#,
            &[],
        )
        .unwrap();
        assert_eq!(cfg.transport.addr, "unix:/tmp/fedgmf.sock");
        assert_eq!(cfg.transport.read_timeout_ms, 500);
        assert_eq!(cfg.transport.write_timeout_ms, 600);
        assert_eq!(cfg.transport.round_deadline_ms, 5000);
        assert_eq!(cfg.transport.max_retries, 3);
        assert_eq!(cfg.transport.backoff_base_ms, 10);
        assert_eq!(cfg.transport.backoff_max_ms, 80);
        let plan = cfg.transport.fault.unwrap();
        assert_eq!(plan.kind, FaultKind::Drop);
        assert!((plan.rate - 0.25).abs() < 1e-12);
        assert_eq!(plan.seed, 9, "fault seed defaults to the run seed");
        // the chaos plan reaches the simulator through FlConfig
        assert_eq!(cfg.fl_config().fault, Some(plan));
        // explicit @seed wins over the run seed
        let pinned = RunConfig::from_toml_str(
            "[transport]\nfault = \"delay:0.5@77\"\n",
            &[],
        )
        .unwrap();
        assert_eq!(pinned.transport.fault.unwrap().seed, 77);
        // --set override path
        let ov = RunConfig::from_toml_str(
            "",
            &["transport.fault=\"dup:0.1\"".to_string()],
        )
        .unwrap();
        assert_eq!(ov.transport.fault.unwrap().kind, FaultKind::Duplicate);
    }

    #[test]
    fn transport_section_rejects_bad_values() {
        assert!(RunConfig::from_toml_str("[transport]\nfault = \"nope:0.5\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[transport]\nfault = \"drop\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[transport]\nfault = 3\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[transport]\naddr = 3\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[transport]\nmax_retries = \"x\"\n", &[]).is_err());
    }

    #[test]
    fn rate_control_section_from_toml() {
        // default: off, inert, absent from describe()
        let plain = RunConfig::from_toml_str("", &[]).unwrap();
        assert!(!plain.rate_control.active());
        assert_eq!(plain.rate_control, RateControlConfig::default());
        assert!(!plain.describe().contains("rate_control"));
        let cfg = RunConfig::from_toml_str(
            r#"
[rate_control]
mode = "adaptive"
min_rate_frac = 0.2
max_rate_boost = 1.5
deadline_margin = 0.75
adapt_coding = false
"#,
            &[],
        )
        .unwrap();
        assert_eq!(cfg.rate_control.mode, RateControlMode::Adaptive);
        assert!((cfg.rate_control.min_rate_frac - 0.2).abs() < 1e-12);
        assert!((cfg.rate_control.max_rate_boost - 1.5).abs() < 1e-12);
        assert!((cfg.rate_control.deadline_margin - 0.75).abs() < 1e-12);
        assert!(!cfg.rate_control.adapt_coding);
        assert!(cfg.rate_control.active());
        assert_eq!(cfg.fl_config().rate_control, cfg.rate_control);
        assert!(cfg.describe().contains("rate_control: adaptive"));
        // knobs without the mode selector stay inert (like [sim] shapes)
        let knobs_only =
            RunConfig::from_toml_str("[rate_control]\nmin_rate_frac = 0.5\n", &[]).unwrap();
        assert!(!knobs_only.rate_control.active());
        // --set override path
        let ov = RunConfig::from_toml_str(
            "",
            &["rate_control.mode=\"adaptive\"".to_string()],
        )
        .unwrap();
        assert!(ov.rate_control.active());
    }

    #[test]
    fn rate_control_section_rejects_bad_values() {
        assert!(RunConfig::from_toml_str("[rate_control]\nmode = \"nope\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[rate_control]\nmode = 3\n", &[]).is_err());
        assert!(RunConfig::from_toml_str(
            "[rate_control]\nmode = \"adaptive\"\nmin_rate_frac = 0.0\n",
            &[]
        )
        .is_err());
        assert!(RunConfig::from_toml_str(
            "[rate_control]\nmode = \"adaptive\"\nmax_rate_boost = 0.5\n",
            &[]
        )
        .is_err());
        assert!(RunConfig::from_toml_str(
            "[rate_control]\nmode = \"adaptive\"\ndeadline_margin = 2.0\n",
            &[]
        )
        .is_err());
        assert!(RunConfig::from_toml_str(
            "[rate_control]\nadapt_coding = \"yes\"\n",
            &[]
        )
        .is_err());
    }

    #[test]
    fn malformed_overrides_error_instead_of_panicking() {
        // every malformed --set shape must surface a contextual Err; none
        // of these may panic mid-CLI
        for bad in [
            "run.rounds",          // no '='
            "run.rounds=",         // empty value
            "run.=5",              // empty key
            "=5",                  // empty path
            "run.rounds=zzz",      // unparseable value
            "run.rounds=\"open",   // unterminated string
            "sim.deadline_s=[1,",  // unterminated array
        ] {
            let got = RunConfig::from_toml_str("", &[bad.to_string()]);
            assert!(got.is_err(), "override `{bad}` must error");
            let msg = format!("{:#}", got.unwrap_err());
            assert!(
                msg.contains(bad.split('=').next().unwrap().trim()) || msg.contains("override"),
                "error for `{bad}` lacks context: {msg}"
            );
        }
        // wrong-typed section values keep their key in the message
        let got = RunConfig::from_toml_str("[sim]\ndeadline_s = \"fast\"\n", &[]);
        let msg = format!("{:#}", got.unwrap_err());
        assert!(msg.contains("sim.deadline_s"), "missing key context: {msg}");
    }

    #[test]
    fn exact_mask_overlap_knob_from_toml() {
        assert!(!RunConfig::default().exact_mask_overlap);
        let cfg = RunConfig::from_toml_str("[run]\nexact_mask_overlap = true\n", &[]).unwrap();
        assert!(cfg.exact_mask_overlap);
        assert!(cfg.fl_config().exact_mask_overlap);
        assert!(RunConfig::from_toml_str("[run]\nexact_mask_overlap = 3\n", &[]).is_err());
    }

    #[test]
    fn store_and_hierarchy_from_toml() {
        // defaults: auto residency, flat topology, both inert
        let plain = RunConfig::from_toml_str("", &[]).unwrap();
        assert_eq!(plain.store, StoreMode::Auto);
        assert!(!plain.hierarchy.enabled());
        assert!(!plain.describe().contains("store"));
        assert!(!plain.describe().contains("hierarchy"));
        let cfg = RunConfig::from_toml_str(
            r#"
[run]
store = "virtual"
[hierarchy]
tiers = 2
cohorts_per_edge = 8
edge_uplink_bps = 5e7
"#,
            &[],
        )
        .unwrap();
        assert_eq!(cfg.store, StoreMode::Virtual);
        assert_eq!(cfg.hierarchy.tiers, 2);
        assert_eq!(cfg.hierarchy.cohorts_per_edge, 8);
        assert!((cfg.hierarchy.edge_uplink_bps - 5e7).abs() < 1e-3);
        assert!(cfg.hierarchy.enabled());
        let fc = cfg.fl_config();
        assert_eq!(fc.store, StoreMode::Virtual);
        assert_eq!(fc.hierarchy.tiers, 2);
        assert!(cfg.describe().contains("store: virtual"));
        assert!(cfg.describe().contains("hierarchy: 2 tiers, 8 cohorts/edge"));
        // --set override path
        let ov = RunConfig::from_toml_str(
            "",
            &["run.store=\"dense\"".to_string(), "hierarchy.tiers=2".to_string()],
        )
        .unwrap();
        assert_eq!(ov.store, StoreMode::Dense);
        assert_eq!(ov.hierarchy.tiers, 2);
        // bad values rejected
        assert!(RunConfig::from_toml_str("[run]\nstore = \"nope\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[hierarchy]\ntiers = 5\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[hierarchy]\ncohorts_per_edge = 0\n", &[]).is_err());
    }

    #[test]
    fn streamed_ingest_knob_from_toml() {
        assert!(!RunConfig::default().streamed_ingest, "materialized ingest is the default");
        let cfg = RunConfig::from_toml_str("[run]\nstreamed_ingest = true\n", &[]).unwrap();
        assert!(cfg.streamed_ingest);
        assert!(cfg.fl_config().streamed_ingest);
        let ov = RunConfig::from_toml_str("", &["run.streamed_ingest=true".to_string()]).unwrap();
        assert!(ov.streamed_ingest);
        assert!(RunConfig::from_toml_str("[run]\nstreamed_ingest = 3\n", &[]).is_err());
    }

    #[test]
    fn kernels_knob_from_toml() {
        assert_eq!(RunConfig::default().kernels, KernelMode::Auto, "auto dispatch is the default");
        let cfg = RunConfig::from_toml_str("[run]\nkernels = \"scalar\"\n", &[]).unwrap();
        assert_eq!(cfg.kernels, KernelMode::Scalar);
        let ov = RunConfig::from_toml_str("", &["run.kernels=simd".to_string()]).unwrap();
        assert_eq!(ov.kernels, KernelMode::Simd);
        assert!(RunConfig::from_toml_str("[run]\nkernels = \"turbo\"\n", &[]).is_err());
        assert!(RunConfig::from_toml_str("[run]\nkernels = 3\n", &[]).is_err());
    }
}
