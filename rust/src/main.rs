//! fedgmf — CLI launcher.
//!
//! ```text
//! fedgmf train --config configs/cifar_gmf.toml [--set compress.rate=0.3 ...]
//! fedgmf experiment --id table3 [--scale quick|default|paper] [--engine native]
//! fedgmf experiment --list
//! fedgmf verify --scale quick [--bless]     # scenario-matrix conformance
//! fedgmf serve --clients 4 --rounds 6       # coordinator over TCP/UDS
//! fedgmf client --id 0 --clients 4 ...      # one fleet member
//! fedgmf data --task cifar --emd 1.35       # inspect partition statistics
//! fedgmf artifacts-check                    # verify AOT artifacts load
//! ```
//!
//! (argument parsing is hand-rolled: the build environment is offline and
//! the vendored crate set has no clap)

use fedgmf::compress::CompressorKind;
use fedgmf::config::{EngineKind, RunConfig, Scale};
use fedgmf::experiments::{self, ExpArgs};
use fedgmf::runtime::manifest::Manifest;
use fedgmf::runtime::pjrt::PjrtContext;
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "experiment" | "exp" => cmd_experiment(rest),
        "verify" => cmd_verify(rest),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "data" => cmd_data(rest),
        "artifacts-check" => cmd_artifacts_check(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            Err(anyhow::anyhow!("unknown command `{other}`"))
        }
    }
}

fn print_usage() {
    println!(
        "fedgmf — federated learning with Global Momentum Fusion compression

USAGE:
  fedgmf train [--config FILE] [--set sec.key=val ...] [--out-dir DIR]
               [--technique dgc|gmc|dgcwgm|dgcwgmf] [--scale S]
               [--budget SIM_SECONDS]   # stop at a simulated-seconds budget
               # time-domain scheduler: --set sim.deadline_s=0.25 sim.dropout=0.02
               #   sim.overselect=1.25 sim.compute_s=0.05 sim.profile=\"heterogeneous\"
               # semi-sync aggregation: --set sim.staleness=\"carry\" (or carry_discounted
               #   + sim.staleness_alpha=0.5) and sim.selection=\"feasibility\"
  fedgmf experiment --id ID [--scale quick|default|paper] [--engine pjrt|native]
               [--techniques a,b] [--levels 0.1,0.5] [--out-dir DIR] [--seed N]
  fedgmf experiment --list
  fedgmf verify [--scale quick|default] [--bless] [--golden FILE] [--report FILE]
               [--kernels auto|scalar|simd]
               # run the full scenario-matrix conformance harness (see
               # docs/testing.md): technique x codec x staleness x selection x
               # preset x workers, with invariant ledgers and golden digests;
               # --bless regenerates the golden registry; --kernels forces the
               # hot-path dispatch (digests are identical across modes)
  fedgmf serve [--listen ADDR] --clients N --rounds R [--seed S]
               [--fault kind:rate[@seed]] [--deadline-ms MS] [--out-dir DIR]
               [--selfcheck]
               # fault-tolerant service mode: drive the round loop over
               # TCP (host:port) or a Unix socket (unix:/path); --selfcheck
               # replays the run in-process and compares trajectory digests
  fedgmf client --id I [--connect ADDR] --clients N --rounds R [--seed S]
               [--fault kind:rate[@seed]]
               # one fleet member; all parties must agree on
               # clients/rounds/seed/fault (the run derives from them alone)
  fedgmf data --task cifar|shakespeare [--emd X] [--clients N]
  fedgmf artifacts-check [--artifacts DIR]
"
    );
}

/// Tiny flag parser: `--key value` pairs + repeated `--set`.
struct Flags {
    vals: Vec<(String, String)>,
}

impl Flags {
    fn parse(args: &[String]) -> anyhow::Result<Flags> {
        let mut vals = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let k = &args[i];
            if let Some(name) = k.strip_prefix("--") {
                // value-less boolean flags
                if name == "list" || name == "bless" || name == "selfcheck" {
                    vals.push((name.to_string(), "true".into()));
                    i += 1;
                    continue;
                }
                let v = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow::anyhow!("flag --{name} needs a value"))?;
                vals.push((name.to_string(), v.clone()));
                i += 2;
            } else {
                return Err(anyhow::anyhow!("unexpected argument `{k}`"));
            }
        }
        Ok(Flags { vals })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.vals.iter().rev().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn all(&self, name: &str) -> Vec<String> {
        self.vals.iter().filter(|(k, _)| k == name).map(|(_, v)| v.clone()).collect()
    }

    fn has(&self, name: &str) -> bool {
        self.get(name).is_some()
    }
}

fn artifacts_dir(f: &Flags) -> PathBuf {
    f.get("artifacts").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let mut cfg = if let Some(path) = f.get("config") {
        let text = std::fs::read_to_string(path)?;
        RunConfig::from_toml_str(&text, &f.all("set"))?
    } else {
        RunConfig::from_toml_str("", &f.all("set"))?
    };
    if let Some(t) = f.get("technique") {
        cfg.technique = CompressorKind::parse(t)
            .ok_or_else(|| anyhow::anyhow!("unknown technique `{t}`"))?;
    }
    if let Some(s) = f.get("scale") {
        let scale = Scale::parse(s).ok_or_else(|| anyhow::anyhow!("unknown scale `{s}`"))?;
        cfg = cfg.with_scale(scale);
    }
    if let Some(e) = f.get("engine") {
        cfg.engine = match e {
            "pjrt" => EngineKind::Pjrt,
            "native" => EngineKind::Native,
            other => return Err(anyhow::anyhow!("unknown engine `{other}`")),
        };
    }
    let out_dir = PathBuf::from(f.get("out-dir").unwrap_or("results/train"));
    std::fs::create_dir_all(&out_dir)?;

    let budget = f.get("budget").map(|b| b.parse::<f64>()).transpose()?;
    // single-threaded startup: the global dispatch mode is set once, before
    // any kernel runs (FEDGMF_KERNELS still overrides — see docs/perf.md)
    fedgmf::sparse::simd::set_mode(cfg.kernels);
    println!("run: {} | kernels {}", cfg.describe(), fedgmf::sparse::simd::describe());
    let mut ctx = None;
    let (summary, emd) =
        experiments::runner::execute_with(&cfg, &artifacts_dir(&f), &mut ctx, budget)?;
    println!("achieved EMD: {emd:.4}");
    println!(
        "final acc {:.4} | best {:.4} | traffic {:.4} GB (up {:.4} / down {:.4}) | sim {:.1}s",
        summary.final_accuracy,
        summary.best_accuracy,
        summary.total_traffic_gb,
        summary.uplink_gb,
        summary.downlink_gb,
        summary.sim_seconds
    );
    if cfg.sim.scheduling_active() {
        println!(
            "scheduler: {} rounds | {} uploads dropped at the deadline | {} offline | {:.4} GB wasted uplink",
            summary.recorder.rounds.len(),
            summary.dropped_deadline,
            summary.dropped_offline,
            summary.wasted_uplink_gb
        );
        if summary.carried_total > 0 {
            println!(
                "semi-sync: {} late uploads carried into later rounds ({:.4} GB re-used)",
                summary.carried_total, summary.carried_gb
            );
        }
    }
    if !cfg.codec.is_v1() {
        println!(
            "codec: {:.4} GB on the wire vs {:.4} GB v1-equivalent ({:.2}x reduction)",
            summary.total_traffic_gb, summary.precodec_gb, summary.codec_ratio
        );
    }
    let curve = out_dir.join(format!("{}.csv", summary.technique));
    summary.recorder.write_csv(&curve)?;
    std::fs::write(out_dir.join("summary.json"), summary.recorder.summary_json().to_pretty())?;
    println!("curve: {}", curve.display());
    Ok(())
}

fn cmd_experiment(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    if f.has("list") {
        print!("{}", experiments::list());
        return Ok(());
    }
    let id = f.get("id").ok_or_else(|| anyhow::anyhow!("--id required (or --list)"))?;
    let mut ea = ExpArgs::new(
        artifacts_dir(&f),
        PathBuf::from(f.get("out-dir").unwrap_or("results")),
    );
    if let Some(s) = f.get("scale") {
        ea.scale = Scale::parse(s).ok_or_else(|| anyhow::anyhow!("unknown scale `{s}`"))?;
    }
    if let Some(e) = f.get("engine") {
        ea.engine = Some(match e {
            "pjrt" => EngineKind::Pjrt,
            "native" => EngineKind::Native,
            other => return Err(anyhow::anyhow!("unknown engine `{other}`")),
        });
    }
    if let Some(seed) = f.get("seed") {
        ea.seed = seed.parse()?;
    }
    if let Some(ts) = f.get("techniques") {
        for t in ts.split(',') {
            ea.techniques.push(
                CompressorKind::parse(t).ok_or_else(|| anyhow::anyhow!("unknown technique `{t}`"))?,
            );
        }
    }
    if let Some(ls) = f.get("levels") {
        for l in ls.split(',') {
            ea.levels.push(l.trim().parse()?);
        }
    }
    let report = experiments::run(id, &ea)?;
    println!("{report}");
    let report_path = ea.out_dir.join(id).join("report.txt");
    std::fs::write(&report_path, &report)?;
    println!("(report saved to {})", report_path.display());
    Ok(())
}

fn cmd_verify(args: &[String]) -> anyhow::Result<()> {
    use fedgmf::testkit::{self, VerifyOptions};
    let f = Flags::parse(args)?;
    let scale = match f.get("scale") {
        None => Scale::Quick,
        Some(s) => Scale::parse(s).ok_or_else(|| anyhow::anyhow!("unknown scale `{s}`"))?,
    };
    if let Some(k) = f.get("kernels") {
        let mode = fedgmf::sparse::KernelMode::parse(k)
            .ok_or_else(|| anyhow::anyhow!("unknown kernel mode `{k}`"))?;
        fedgmf::sparse::simd::set_mode(mode);
    }
    let opts = VerifyOptions {
        scale,
        bless: f.has("bless"),
        golden_path: f
            .get("golden")
            .map(PathBuf::from)
            .unwrap_or_else(testkit::default_golden_path),
        report_path: f.get("report").map(PathBuf::from),
    };
    let report = testkit::run_verify(&opts)?;
    print!("{}", report.render());
    if let Some(path) = &opts.report_path {
        println!("(conformance report saved to {})", path.display());
    }
    if !report.passed() {
        return Err(anyhow::anyhow!(
            "verify failed: {} invariant check(s) failed, {} digest mismatch(es)",
            report.invariant_failures(),
            report.digest_mismatches.len()
        ));
    }
    Ok(())
}

/// Shared `(clients, rounds, seed, fault)` parsing for the service pair —
/// every party must derive the identical run from these four values.
fn service_args(
    f: &Flags,
) -> anyhow::Result<(usize, usize, u64, Option<fedgmf::transport::fault::FaultPlan>)> {
    use fedgmf::transport::fault::FaultPlan;
    let clients: usize =
        f.get("clients").ok_or_else(|| anyhow::anyhow!("--clients required"))?.parse()?;
    let rounds: usize =
        f.get("rounds").ok_or_else(|| anyhow::anyhow!("--rounds required"))?.parse()?;
    let seed: u64 = f.get("seed").unwrap_or("42").parse()?;
    let fault = f
        .get("fault")
        .map(|s| FaultPlan::parse(s, seed).map_err(|e| anyhow::anyhow!(e)))
        .transpose()?;
    Ok((clients, rounds, seed, fault))
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    use fedgmf::coordinator::service::{build_service_run, ServiceRun};
    use fedgmf::testkit::digest;
    use fedgmf::transport::socket::SocketTransport;
    use fedgmf::transport::TransportConfig;

    let f = Flags::parse(args)?;
    let (clients, rounds, seed, fault) = service_args(&f)?;
    let mut tcfg = TransportConfig::default();
    if let Some(addr) = f.get("listen") {
        tcfg.addr = addr.to_string();
    }
    if let Some(ms) = f.get("deadline-ms") {
        tcfg.round_deadline_ms = ms.parse()?;
    }
    tcfg.fault = fault;
    let deadline_ms = tcfg.round_deadline_ms;

    let run = build_service_run(clients, rounds, seed, fault);
    let dim = run.params.len();
    let mut transport = SocketTransport::bind(tcfg, clients, dim, rounds)?;
    println!(
        "serve: {} | {clients} clients x {rounds} rounds | seed {seed}{}",
        transport.local_addr(),
        fault.map(|p| format!(" | fault {}", p.describe())).unwrap_or_default()
    );
    let mut service = ServiceRun::new(run, deadline_ms);
    let summary = service.run(&mut transport)?;
    let bits: Vec<u32> = service.run.params.iter().map(|p| p.to_bits()).collect();
    let d = digest::trajectory_digest(&bits, &service.run.recorder.rounds);
    println!(
        "done: final loss {:.6} | traffic {:.6} GB | digest {}",
        summary.final_loss,
        summary.total_traffic_gb,
        digest::hex(d)
    );
    let totals = service.run.recorder.rounds.iter().fold((0, 0, 0, 0), |a, r| {
        (a.0 + r.retries, a.1 + r.timeouts, a.2 + r.stale_frames, a.3 + r.dup_frames)
    });
    println!(
        "transport: {} retries | {} timeouts | {} stale frames | {} dup frames",
        totals.0, totals.1, totals.2, totals.3
    );
    if let Some(dir) = f.get("out-dir") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)?;
        service.run.recorder.write_csv(&dir.join("service.csv"))?;
        std::fs::write(
            dir.join("summary.json"),
            service.run.recorder.summary_json().to_pretty(),
        )?;
    }
    if f.has("selfcheck") {
        // replay the identical run through the in-process simulator: the
        // wire must be invisible to the trajectory
        let fx = fedgmf::experiments::workload::verify_fixture(clients, seed);
        let mut engine = fx.engine;
        let cfg = fedgmf::coordinator::service::service_config(clients, rounds, seed, fault);
        let mut sim = fedgmf::coordinator::FlRun::new(&engine, fx.shards, Vec::new(), fx.network, cfg);
        sim.run(&mut engine)?;
        let sim_bits: Vec<u32> = sim.params.iter().map(|p| p.to_bits()).collect();
        let d_sim = digest::trajectory_digest(&sim_bits, &sim.recorder.rounds);
        if d_sim == d {
            println!("selfcheck: simulator digest {} matches", digest::hex(d_sim));
        } else {
            return Err(anyhow::anyhow!(
                "selfcheck FAILED: service digest {} != simulator digest {}",
                digest::hex(d),
                digest::hex(d_sim)
            ));
        }
    }
    Ok(())
}

fn cmd_client(args: &[String]) -> anyhow::Result<()> {
    use fedgmf::coordinator::service::build_service_client;
    use fedgmf::transport::socket::run_client;
    use fedgmf::transport::TransportConfig;

    let f = Flags::parse(args)?;
    let (clients, rounds, seed, fault) = service_args(&f)?;
    let id: usize = f.get("id").ok_or_else(|| anyhow::anyhow!("--id required"))?.parse()?;
    if id >= clients {
        return Err(anyhow::anyhow!("--id {id} out of range for --clients {clients}"));
    }
    let mut tcfg = TransportConfig::default();
    if let Some(addr) = f.get("connect") {
        tcfg.addr = addr.to_string();
    }
    tcfg.fault = fault;
    let mut handler = build_service_client(clients, id, rounds, seed, fault);
    run_client(&tcfg, &mut handler)?;
    println!("client {id}: run complete");
    Ok(())
}

fn cmd_data(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let task = f.get("task").unwrap_or("cifar");
    let mut cfg = match task {
        "shakespeare" => RunConfig::shakespeare(),
        _ => RunConfig::default(),
    };
    if let Some(e) = f.get("emd") {
        cfg.emd = e.parse()?;
    }
    if let Some(c) = f.get("clients") {
        cfg.clients = c.parse()?;
    }
    let w = experiments::workload::build_workload(&cfg)?;
    println!("task {} | {} clients | achieved EMD {:.4}", task, w.shards.len(), w.achieved_emd);
    for (i, s) in w.shards.iter().enumerate().take(8) {
        let h = s.label_histogram();
        let nz: Vec<String> = h
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .take(12)
            .map(|(c, &n)| format!("{c}:{n}"))
            .collect();
        println!("  client {i:>3}: {} samples | {}", s.len(), nz.join(" "));
    }
    if w.shards.len() > 8 {
        println!("  ... ({} more clients)", w.shards.len() - 8);
    }
    Ok(())
}

fn cmd_artifacts_check(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let dir = artifacts_dir(&f);
    let man = Manifest::load(&dir)?;
    println!("manifest v{} | models: {:?}", man.version, man.names());
    let ctx = PjrtContext::cpu()?;
    println!("PJRT platform: {}", ctx.client.platform_name());
    for entry in &man.models {
        let t0 = std::time::Instant::now();
        let _exe = ctx.load(&entry.train_file)?;
        let _exe2 = ctx.load(&entry.eval_file)?;
        let _k = fedgmf::runtime::pjrt::KernelExecutor::new(&ctx, entry)?;
        println!(
            "  {:<10} P={:<8} batch={:<3} compiled train+eval+kernels in {:.2}s",
            entry.name,
            entry.param_count,
            entry.batch,
            t0.elapsed().as_secs_f64()
        );
    }
    println!("artifacts OK");
    Ok(())
}
