//! Trajectory digests — one u64 fingerprint per run, stable across
//! machines, worker counts and rebuilds.
//!
//! The digest folds the final model parameter bits and every per-round
//! record field the round loop promises to keep deterministic through an
//! FNV-1a hash. Two runs produce the same digest iff they are
//! bit-identical on every promised observable — which makes the digest
//! both the cross-worker-equality invariant (`fedgmf verify`) and the CI
//! determinism-matrix fingerprint (`tests/determinism.rs`), from one
//! implementation.
//!
//! The field order is part of the golden-registry format: appending a new
//! `RoundRecord` field here invalidates committed digests, which is
//! exactly the right failure mode (the registry must be re-blessed when
//! the observable surface grows) — but do it deliberately.

use crate::metrics::recorder::RoundRecord;

/// Incremental FNV-1a over little-endian u64 words.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;

    pub fn new() -> Self {
        Fnv64(Self::OFFSET)
    }

    /// Fold one word (byte-at-a-time, little-endian).
    pub fn eat(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    pub fn value(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Digest of one run's observable trajectory: final parameter bit patterns
/// plus every deterministic per-round record field, in a fixed order.
pub fn trajectory_digest(param_bits: &[u32], rounds: &[RoundRecord]) -> u64 {
    let mut h = Fnv64::new();
    for &p in param_bits {
        h.eat(p as u64);
    }
    for r in rounds {
        h.eat(r.round as u64);
        h.eat(r.train_loss.to_bits());
        h.eat(r.test_accuracy.to_bits());
        h.eat(r.uplink_bytes as u64);
        h.eat(r.downlink_bytes as u64);
        h.eat(r.aggregate_nnz as u64);
        h.eat(r.mask_overlap.to_bits());
        h.eat(r.sim_seconds.to_bits());
        h.eat(r.sim_clock.to_bits());
        h.eat(r.selected as u64);
        h.eat(r.dropped_deadline as u64);
        h.eat(r.dropped_offline as u64);
        h.eat(r.carried_in as u64);
        h.eat(r.carried_bytes as u64);
        h.eat(r.wasted_uplink_bytes as u64);
        h.eat(r.traffic_gini.to_bits());
        h.eat(r.precodec_bytes as u64);
        h.eat(r.codec_ratio.to_bits());
    }
    h.value()
}

/// Render a digest the way the golden registry stores it.
pub fn hex(d: u64) -> String {
    format!("{d:016x}")
}

/// Parse a registry digest string.
pub fn from_hex(s: &str) -> Option<u64> {
    if s.len() == 16 {
        u64::from_str_radix(s, 16).ok()
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vector() {
        // FNV-1a over the bytes of one zero word
        let mut h = Fnv64::new();
        h.eat(0);
        let mut want = Fnv64::OFFSET;
        for _ in 0..8 {
            want ^= 0;
            want = want.wrapping_mul(Fnv64::PRIME);
        }
        assert_eq!(h.value(), want);
    }

    #[test]
    fn digest_sensitive_to_every_promised_field() {
        let base = RoundRecord {
            round: 1,
            train_loss: 0.5,
            uplink_bytes: 100,
            codec_ratio: 1.0,
            ..Default::default()
        };
        let d0 = trajectory_digest(&[1, 2, 3], &[base.clone()]);
        assert_eq!(d0, trajectory_digest(&[1, 2, 3], &[base.clone()]), "digest is a pure fn");
        let mut param_change = trajectory_digest(&[1, 2, 4], &[base.clone()]);
        assert_ne!(d0, param_change);
        let mut r = base.clone();
        r.carried_in = 1;
        param_change = trajectory_digest(&[1, 2, 3], &[r]);
        assert_ne!(d0, param_change);
        let mut r = base.clone();
        r.traffic_gini = 0.25;
        assert_ne!(d0, trajectory_digest(&[1, 2, 3], &[r]));
        let mut r = base;
        r.precodec_bytes = 7;
        assert_ne!(d0, trajectory_digest(&[1, 2, 3], &[r]));
    }

    #[test]
    fn digest_blind_to_edge_tier_fields() {
        // the t1 ≡ t2 identity: tier-1 backhaul columns are diagnostics,
        // never digest inputs — a two-tier run must fingerprint identically
        // to its flat twin
        let flat = RoundRecord { round: 2, uplink_bytes: 64, ..Default::default() };
        let mut tiered = flat.clone();
        tiered.edge_count = 4;
        tiered.edge_uplink_bytes = 999;
        tiered.edge_downlink_bytes = 500;
        tiered.edge_backhaul_s = 1.25;
        assert_eq!(
            trajectory_digest(&[9, 9], &[flat]),
            trajectory_digest(&[9, 9], &[tiered]),
            "edge columns leaked into the digest"
        );
    }

    #[test]
    fn digest_blind_to_rate_control_fields() {
        // the rate_* columns are derivable diagnostics: a rate_control=off
        // run must fingerprint identically to a pre-controller build, so
        // the recorder's rate family never enters the digest (the
        // controller's *effects* — bytes, losses, params — of course do)
        let off = RoundRecord { round: 5, uplink_bytes: 48, ..Default::default() };
        let mut annotated = off.clone();
        annotated.rate_mean = 0.07;
        annotated.rate_min = 0.02;
        annotated.rate_max = 0.1;
        annotated.coding_downshifts = 3;
        assert_eq!(
            trajectory_digest(&[7], &[off]),
            trajectory_digest(&[7], &[annotated]),
            "rate-control columns leaked into the digest"
        );
    }

    #[test]
    fn hex_roundtrip() {
        for d in [0u64, 1, 0xdead_beef_cafe_f00d, u64::MAX] {
            assert_eq!(from_hex(&hex(d)), Some(d));
        }
        assert_eq!(from_hex("xyz"), None);
        assert_eq!(from_hex("123"), None);
    }
}
