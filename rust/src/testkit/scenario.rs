//! Scenario-matrix enumeration for `fedgmf verify`.
//!
//! One [`Scenario`] is a point in the cross-product of every behavioural
//! axis the system has grown: compressor technique × wire codec ×
//! staleness policy × selection policy × scheduler capability preset ×
//! chaos fault plan.
//! Worker count is a further axis handled by the runner (every scenario is
//! executed at each [`WORKERS`] entry and the trajectory digests must be
//! equal — the cross-worker invariant), so it never appears in a
//! scenario's registry key.
//!
//! **Adding an axis value is one edit**: push it onto the matching `AXIS_*`
//! slice (and its `name()`); [`Scenario::all`] is the cross-product over
//! those slices, so enumeration, invariant checking, digest comparison and
//! the golden-registry coverage check (missing *and* stale keys both fail)
//! all pick the new value up automatically. Adding a whole new axis means
//! extending [`Scenario`] and its `key()` — the registry key format is the
//! compatibility surface, so re-bless after either change.

use crate::compress::CompressorKind;
use crate::coordinator::round::{FlConfig, LrSchedule};
use crate::coordinator::sampler::Sampler;
use crate::sim::scheduler::{ProfilePreset, SelectionPolicy, SimConfig, StalenessPolicy};
use crate::sparse::codec::{CodecParams, IndexCoding, ValueCoding, WireCodec};
use crate::transport::fault::{FaultKind, FaultPlan};

/// Wire-codec axis values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecAxis {
    /// raw u32 + f32 — the v1-identical default
    V1,
    /// delta-varint indices + IEEE half values
    VarintF16,
    /// delta-varint indices + blockwise int8 values
    VarintQ8,
}

impl CodecAxis {
    pub fn name(&self) -> &'static str {
        match self {
            CodecAxis::V1 => "v1",
            CodecAxis::VarintF16 => "varint_f16",
            CodecAxis::VarintQ8 => "varint_q8",
        }
    }

    pub fn wire_codec(&self) -> WireCodec {
        let p = match self {
            CodecAxis::V1 => CodecParams::V1,
            CodecAxis::VarintF16 => {
                CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 }
            }
            CodecAxis::VarintQ8 => {
                CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 }
            }
        };
        WireCodec { uplink: p, downlink: p }
    }
}

/// Staleness-policy axis values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StalenessAxis {
    Drop,
    Carry,
    /// `carry_discounted` at the fixture α below.
    CarryDiscounted,
}

impl StalenessAxis {
    pub fn name(&self) -> &'static str {
        match self {
            StalenessAxis::Drop => "drop",
            StalenessAxis::Carry => "carry",
            StalenessAxis::CarryDiscounted => "carry_discounted",
        }
    }

    pub fn policy(&self) -> StalenessPolicy {
        match self {
            StalenessAxis::Drop => StalenessPolicy::Drop,
            StalenessAxis::Carry => StalenessPolicy::Carry,
            StalenessAxis::CarryDiscounted => StalenessPolicy::CarryDiscounted(FIXTURE_ALPHA),
        }
    }
}

/// Cohort-selection axis values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionAxis {
    Uniform,
    /// feasibility-weighted at the fixture β below.
    Feasibility,
}

impl SelectionAxis {
    pub fn name(&self) -> &'static str {
        match self {
            SelectionAxis::Uniform => "uniform",
            SelectionAxis::Feasibility => "feasibility",
        }
    }

    pub fn policy(&self) -> SelectionPolicy {
        match self {
            SelectionAxis::Uniform => SelectionPolicy::Uniform,
            SelectionAxis::Feasibility => SelectionPolicy::Feasibility { beta: FIXTURE_BETA },
        }
    }
}

/// Scheduler capability-preset axis values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PresetAxis {
    Uniform,
    LongTail,
}

impl PresetAxis {
    pub fn name(&self) -> &'static str {
        match self {
            PresetAxis::Uniform => "uniform",
            PresetAxis::LongTail => "longtail",
        }
    }

    pub fn preset(&self) -> ProfilePreset {
        match self {
            PresetAxis::Uniform => ProfilePreset::Uniform,
            PresetAxis::LongTail => ProfilePreset::LongTail { sigma: FIXTURE_SIGMA },
        }
    }
}

/// Chaos-plan axis values: the deterministic fault plans of
/// [`crate::transport::fault`], replayed by the simulator (`FlConfig::fault`)
/// exactly as the service transports inject them on the wire. Every value
/// must keep the mass and traffic ledgers clean — faults may change *which*
/// uploads land, never create or destroy gradient mass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosAxis {
    /// no plan — bit-identical to the pre-fault loop
    None,
    Drop,
    Delay,
    Duplicate,
    Reorder,
    Truncate,
    Disconnect,
}

impl ChaosAxis {
    pub fn name(&self) -> &'static str {
        match self {
            ChaosAxis::None => "none",
            ChaosAxis::Drop => "drop",
            ChaosAxis::Delay => "delay",
            ChaosAxis::Duplicate => "dup",
            ChaosAxis::Reorder => "reorder",
            ChaosAxis::Truncate => "truncate",
            ChaosAxis::Disconnect => "disconnect",
        }
    }

    pub fn plan(&self) -> Option<FaultPlan> {
        let kind = match self {
            ChaosAxis::None => return None,
            ChaosAxis::Drop => FaultKind::Drop,
            ChaosAxis::Delay => FaultKind::Delay,
            ChaosAxis::Duplicate => FaultKind::Duplicate,
            ChaosAxis::Reorder => FaultKind::Reorder,
            ChaosAxis::Truncate => FaultKind::Truncate,
            ChaosAxis::Disconnect => FaultKind::Disconnect,
        };
        Some(FaultPlan::new(kind, FIXTURE_FAULT_RATE, FIXTURE_SEED))
    }
}

// ------------------------------------------------------------- axis values

pub const AXIS_TECHNIQUES: &[CompressorKind] = &CompressorKind::ALL;
pub const AXIS_CODECS: &[CodecAxis] =
    &[CodecAxis::V1, CodecAxis::VarintF16, CodecAxis::VarintQ8];
pub const AXIS_STALENESS: &[StalenessAxis] =
    &[StalenessAxis::Drop, StalenessAxis::Carry, StalenessAxis::CarryDiscounted];
pub const AXIS_SELECTION: &[SelectionAxis] =
    &[SelectionAxis::Uniform, SelectionAxis::Feasibility];
pub const AXIS_PRESETS: &[PresetAxis] = &[PresetAxis::Uniform, PresetAxis::LongTail];
pub const AXIS_CHAOS: &[ChaosAxis] = &[
    ChaosAxis::None,
    ChaosAxis::Drop,
    ChaosAxis::Delay,
    ChaosAxis::Duplicate,
    ChaosAxis::Reorder,
    ChaosAxis::Truncate,
    ChaosAxis::Disconnect,
];

/// Worker-count runs per scenario: sequential reference and one-per-core.
/// Digests must be equal across all entries (the determinism contract).
pub const WORKERS: &[(&str, usize)] = &[("w1", 1), ("wpc", 0)];

/// Topology runs per scenario: the flat reference (already covered by the
/// worker matrix) plus a two-tier edge-aggregated run. Like the worker
/// axis, tier count must never move the trajectory digest — edges are
/// contiguous slices of the participant order and the hub's fold is
/// unchanged (see `coordinator::hierarchy`) — so the runner folds the
/// two-tier digest into the same golden-gated equality check.
pub const TIERS: &[(&str, usize)] = &[("t1", 1), ("t2", 2)];

/// Edge fan-in for the two-tier runs: 3 members per edge splits the
/// 6-client fixture cohort into two genuine edges.
pub const FIXTURE_COHORTS_PER_EDGE: usize = 3;

// ---------------------------------------------------------------- fixture

/// Staleness discount for the `carry_discounted` axis value.
pub const FIXTURE_ALPHA: f64 = 0.5;
/// Feasibility bias for the `feasibility` axis value.
pub const FIXTURE_BETA: f64 = 0.5;
/// Long-tail sigma for the `longtail` axis value.
pub const FIXTURE_SIGMA: f64 = 0.8;
/// Per-(client, round) fault rate for the non-`none` chaos axis values.
pub const FIXTURE_FAULT_RATE: f64 = 0.25;

/// Fixture shape: the slowest link tier misses the deadline under every
/// codec axis (see `experiments::workload::verify_fixture`), so the carry
/// and drop policies genuinely diverge in every scenario that can reach
/// them.
pub const FIXTURE_CLIENTS: usize = 10;
pub const FIXTURE_SEED: u64 = 42;
pub const FIXTURE_RATE: f64 = 0.25;
pub const FIXTURE_WARMUP_ROUNDS: usize = 2;
pub const FIXTURE_COHORT: usize = 6;
pub const FIXTURE_DEADLINE_S: f64 = 0.095;
pub const FIXTURE_DROPOUT: f64 = 0.1;
pub const FIXTURE_OVERSELECT: f64 = 1.25;
pub const FIXTURE_COMPUTE_S: f64 = 0.02;

/// One point of the scenario matrix (worker count excluded — see module
/// docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scenario {
    pub technique: CompressorKind,
    pub codec: CodecAxis,
    pub staleness: StalenessAxis,
    pub selection: SelectionAxis,
    pub preset: PresetAxis,
    pub chaos: ChaosAxis,
}

impl Scenario {
    /// Full cross-product over the `AXIS_*` slices, in a fixed
    /// lexicographic order (stable registry ordering).
    pub fn all() -> Vec<Scenario> {
        let mut out = Vec::new();
        for &technique in AXIS_TECHNIQUES {
            for &codec in AXIS_CODECS {
                for &staleness in AXIS_STALENESS {
                    for &selection in AXIS_SELECTION {
                        for &preset in AXIS_PRESETS {
                            for &chaos in AXIS_CHAOS {
                                out.push(Scenario {
                                    technique,
                                    codec,
                                    staleness,
                                    selection,
                                    preset,
                                    chaos,
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Registry key — the stable identity of this scenario.
    pub fn key(&self) -> String {
        format!(
            "{}/{}/{}/{}/{}/{}",
            self.technique.name(),
            self.codec.name(),
            self.staleness.name(),
            self.selection.name(),
            self.preset.name(),
            self.chaos.name()
        )
    }

    /// The scenario's `[sim]` knobs over the shared fixture regime.
    pub fn sim_config(&self) -> SimConfig {
        SimConfig {
            preset: self.preset.preset(),
            deadline_s: FIXTURE_DEADLINE_S,
            dropout: FIXTURE_DROPOUT,
            overselect: FIXTURE_OVERSELECT,
            compute_s: FIXTURE_COMPUTE_S,
            staleness: self.staleness.policy(),
            selection: self.selection.policy(),
        }
    }

    /// Full coordinator config for this scenario at `workers` threads.
    pub fn fl_config(&self, workers: usize, rounds: usize) -> FlConfig {
        let mut cfg = FlConfig::new(self.technique, FIXTURE_RATE, rounds);
        cfg.lr = LrSchedule::constant(0.3);
        cfg.warmup.warmup_rounds = FIXTURE_WARMUP_ROUNDS;
        cfg.sampler = Sampler::Count(FIXTURE_COHORT);
        cfg.eval_every = 0;
        cfg.seed = FIXTURE_SEED;
        cfg.workers = workers;
        cfg.sim = self.sim_config();
        cfg.codec = self.codec.wire_codec();
        cfg.fault = self.chaos.plan();
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn matrix_is_the_full_cross_product() {
        let all = Scenario::all();
        let want = AXIS_TECHNIQUES.len()
            * AXIS_CODECS.len()
            * AXIS_STALENESS.len()
            * AXIS_SELECTION.len()
            * AXIS_PRESETS.len()
            * AXIS_CHAOS.len();
        assert_eq!(all.len(), want);
        assert!(all.len() * WORKERS.len() >= 200, "the matrix must stay >= 200 runs");
        let keys: BTreeSet<String> = all.iter().map(|s| s.key()).collect();
        assert_eq!(keys.len(), all.len(), "scenario keys must be unique");
    }

    #[test]
    fn every_scenario_sim_config_validates() {
        for s in Scenario::all() {
            s.sim_config().validate().unwrap_or_else(|e| panic!("{}: {e}", s.key()));
            let cfg = s.fl_config(1, 4);
            assert_eq!(cfg.kind, s.technique);
            assert_eq!(cfg.codec, s.codec.wire_codec());
            assert!(cfg.sim.scheduling_active(), "{}: fixture regime must schedule", s.key());
        }
    }

    #[test]
    fn keys_are_stable_strings() {
        let mut s = Scenario {
            technique: CompressorKind::DgcWgmf,
            codec: CodecAxis::VarintQ8,
            staleness: StalenessAxis::CarryDiscounted,
            selection: SelectionAxis::Feasibility,
            preset: PresetAxis::LongTail,
            chaos: ChaosAxis::None,
        };
        assert_eq!(s.key(), "DGCwGMF/varint_q8/carry_discounted/feasibility/longtail/none");
        s.chaos = ChaosAxis::Disconnect;
        assert_eq!(s.key(), "DGCwGMF/varint_q8/carry_discounted/feasibility/longtail/disconnect");
    }

    #[test]
    fn chaos_axis_wires_the_fault_plan_into_fl_config() {
        for &chaos in AXIS_CHAOS {
            let s = Scenario {
                technique: CompressorKind::DgcWgmf,
                codec: CodecAxis::VarintQ8,
                staleness: StalenessAxis::CarryDiscounted,
                selection: SelectionAxis::Feasibility,
                preset: PresetAxis::LongTail,
                chaos,
            };
            let cfg = s.fl_config(1, 4);
            assert_eq!(cfg.fault, chaos.plan());
            match chaos {
                ChaosAxis::None => assert!(cfg.fault.is_none()),
                _ => {
                    let plan = cfg.fault.expect("non-none chaos carries a plan");
                    assert_eq!(plan.rate, FIXTURE_FAULT_RATE);
                    assert_eq!(plan.seed, FIXTURE_SEED);
                    assert_eq!(plan.describe(), format!("{}:0.25@{}", chaos.name(), FIXTURE_SEED));
                }
            }
        }
    }
}
