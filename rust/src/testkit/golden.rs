//! Golden trajectory-digest registry for `fedgmf verify`.
//!
//! The registry (`rust/tests/golden/verify_matrix.json`) maps every
//! scenario key to the trajectory digest a conforming build must
//! reproduce, per scale. `fedgmf verify --bless` regenerates it; because
//! digests are pure functions of the fixture and the file serialises
//! through the in-tree deterministic JSON writer (BTreeMap ordering,
//! stable number formatting), re-blessing an unchanged tree is
//! byte-identical.
//!
//! A freshly grown axis (or an intentional trajectory change) shows up as
//! a digest/coverage mismatch; the fix is to review the behaviour change
//! and re-bless. `blessed: false` marks a placeholder written in an
//! environment that could not execute the matrix — the digest gate then
//! self-arms on the first blessed commit, the same pattern as the bench
//! regression gate (see `docs/ci.md`).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Registry file schema version.
pub const GOLDEN_SCHEMA: u64 = 1;

/// In-memory registry: scale name → (scenario key → digest).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GoldenRegistry {
    pub blessed: bool,
    pub scales: BTreeMap<String, BTreeMap<String, u64>>,
}

impl GoldenRegistry {
    /// Load a registry. A missing file reads as an unblessed empty
    /// registry (the self-arming state); a present-but-malformed file is
    /// an error — silent fallback would disarm the gate.
    pub fn load(path: &Path) -> Result<GoldenRegistry> {
        if !path.exists() {
            return Ok(GoldenRegistry::default());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading golden registry {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("golden registry {}: {e}", path.display()))?;
        let schema = j.get("schema").and_then(|v| v.as_usize()).unwrap_or(0);
        if schema as u64 != GOLDEN_SCHEMA {
            return Err(anyhow!(
                "golden registry {}: schema {schema} != {GOLDEN_SCHEMA}",
                path.display()
            ));
        }
        let blessed = matches!(j.get("blessed"), Some(Json::Bool(true)));
        let mut scales = BTreeMap::new();
        if let Some(sc) = j.get("scales").and_then(|v| v.as_obj()) {
            for (scale, digests) in sc {
                let map = digests
                    .as_obj()
                    .ok_or_else(|| anyhow!("golden registry: scale {scale} is not an object"))?;
                let mut parsed = BTreeMap::new();
                for (key, dv) in map {
                    let hex = dv
                        .as_str()
                        .ok_or_else(|| anyhow!("golden registry: {scale}/{key}: not a string"))?;
                    let d = super::digest::from_hex(hex).ok_or_else(|| {
                        anyhow!("golden registry: {scale}/{key}: bad digest `{hex}`")
                    })?;
                    parsed.insert(key.clone(), d);
                }
                scales.insert(scale.clone(), parsed);
            }
        }
        Ok(GoldenRegistry { blessed, scales })
    }

    /// Committed digests for one scale (None when the scale was never
    /// blessed).
    pub fn digests(&self, scale: &str) -> Option<&BTreeMap<String, u64>> {
        self.scales.get(scale)
    }

    /// Replace one scale's digests and mark the registry blessed.
    pub fn bless(&mut self, scale: &str, digests: BTreeMap<String, u64>) {
        self.blessed = true;
        self.scales.insert(scale.to_string(), digests);
    }

    /// Deterministic serialisation (byte-identical for equal contents).
    pub fn to_json(&self) -> Json {
        let scales = Json::Obj(
            self.scales
                .iter()
                .map(|(scale, digests)| {
                    let map = Json::Obj(
                        digests
                            .iter()
                            .map(|(k, &d)| (k.clone(), Json::str(super::digest::hex(d))))
                            .collect(),
                    );
                    (scale.clone(), map)
                })
                .collect(),
        );
        Json::obj(vec![
            ("schema", Json::num(GOLDEN_SCHEMA as f64)),
            ("blessed", Json::Bool(self.blessed)),
            ("scales", scales),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing golden registry {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fedgmf-golden-{}-{name}.json", std::process::id()))
    }

    #[test]
    fn missing_file_reads_unblessed_empty() {
        let g = GoldenRegistry::load(Path::new("/nonexistent/registry.json")).unwrap();
        assert!(!g.blessed);
        assert!(g.scales.is_empty());
        assert!(g.digests("quick").is_none());
    }

    #[test]
    fn save_load_roundtrip_and_byte_identical_rewrite() {
        let mut g = GoldenRegistry::default();
        let mut d = BTreeMap::new();
        d.insert("DGC/v1/drop/uniform/uniform".to_string(), 0xdead_beef_u64);
        d.insert("GMC/varint_q8/carry/feasibility/longtail".to_string(), 7);
        g.bless("quick", d);
        let path = tmp("roundtrip");
        g.save(&path).unwrap();
        let first = std::fs::read(&path).unwrap();
        let back = GoldenRegistry::load(&path).unwrap();
        assert_eq!(back, g);
        assert!(back.blessed);
        assert_eq!(back.digests("quick").unwrap().len(), 2);
        assert_eq!(
            back.digests("quick").unwrap()["DGC/v1/drop/uniform/uniform"],
            0xdead_beef_u64
        );
        // re-saving the reloaded registry is byte-identical
        back.save(&path).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), first);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_registry_is_an_error_not_a_fallback() {
        let path = tmp("malformed");
        std::fs::write(&path, "{ not json").unwrap();
        assert!(GoldenRegistry::load(&path).is_err());
        std::fs::write(&path, r#"{"schema": 99, "blessed": true, "scales": {}}"#).unwrap();
        assert!(GoldenRegistry::load(&path).is_err(), "wrong schema must not disarm the gate");
        std::fs::write(
            &path,
            r#"{"schema": 1, "blessed": true, "scales": {"quick": {"k": "nothex"}}}"#,
        )
        .unwrap();
        assert!(GoldenRegistry::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
