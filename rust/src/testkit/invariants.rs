//! Invariant ledgers: the machine-checkable contracts `fedgmf verify`
//! asserts for every scenario in the matrix.
//!
//! Three families:
//!
//! * **Mass conservation** ([`MassLedger`], installed via
//!   `FlRun::ledger`): per coordinate in f64, every unit of transmitted
//!   gradient mass ends up in exactly one place — an aggregate (times its
//!   contributor count), the client residual (via a restore path), or the
//!   server's stale queue at the staleness discount. This generalises the
//!   carry-only ledger `tests/semi_sync.rs` introduced to **all** staleness
//!   policies, selection policies, codecs (the in-flight mass under a
//!   lossy coding is the echo — quantisation error is restored at compress
//!   time and cancels out of the balance) and techniques (the
//!   server-momentum broadcast is audited through the round aggregate
//!   Ĝ_t, never the momentum state).
//! * **Traffic consistency** ([`check_traffic`]): the per-round records
//!   are internally consistent ([`RoundRecord::consistency_violations`])
//!   and the meter's cumulative ledgers equal the per-round sums,
//!   including the per-client attribution and the pre-codec ledger.
//! * **q8 value coding** ([`check_q8_roundtrip`]): blockwise-int8
//!   round-trip error is bounded by half a quantisation step per
//!   coordinate and exact zeros survive exactly — the same check
//!   `tests/proptests.rs` drives with randomized vectors.

use crate::coordinator::traffic::TrafficMeter;
use crate::metrics::ledger::RoundLedger;
use crate::metrics::recorder::Recorder;
use crate::sim::scheduler::{ClientFate, StalenessPolicy};
use crate::sim::staleness::StaleQueue;
use crate::sparse::codec::{q8_block_scale, Q8_BLOCK};
use crate::sparse::vector::SparseVec;
use std::any::Any;

/// Relative tolerance for the f64 mass balance (f32 arithmetic underneath).
const MASS_REL_TOL: f64 = 1e-3;

/// Per-coordinate gradient-mass conservation ledger.
///
/// Balance, per coordinate `i`, at the end of a run:
///
/// ```text
///   uploaded[i] = delivered[i] + restored[i] + α · pending[i]
/// ```
///
/// where `uploaded` sums the echo of every upload that crossed the wire
/// (fates `Accepted` and `Straggler`; `Offline` clients never transmitted
/// and their full client-side restore cancels), `delivered` sums
/// `contributors × Ĝ_t` over all rounds, `restored` is the mass the
/// coordinator returned to client residuals (full echo for dropped
/// stragglers, the unapplied `1 − α` fraction for carried ones), and
/// `pending` is what the stale queue still holds when the run ends.
pub struct MassLedger {
    dim: usize,
    alpha: f64,
    carries: bool,
    uploaded: Vec<f64>,
    delivered: Vec<f64>,
    restored: Vec<f64>,
    /// transmitted uploads seen (diagnostic; a zero count would make the
    /// balance vacuously true)
    pub uploads_seen: usize,
    /// straggler fates seen (diagnostic for regime assertions)
    pub stragglers_seen: usize,
}

impl MassLedger {
    pub fn new(dim: usize, staleness: StalenessPolicy) -> Self {
        MassLedger {
            dim,
            alpha: staleness.alpha() as f64,
            carries: staleness.carries(),
            uploaded: vec![0.0; dim],
            delivered: vec![0.0; dim],
            restored: vec![0.0; dim],
            uploads_seen: 0,
            stragglers_seen: 0,
        }
    }

    /// Close the books: check the balance against what the stale queue
    /// still holds. Returns human-readable violations (empty = conserved).
    pub fn check(&self, stale: &StaleQueue) -> Vec<String> {
        let mut pending = vec![0.0f64; self.dim];
        for e in stale.pending_entries() {
            for (&i, &v) in e.grad.indices.iter().zip(&e.grad.values) {
                pending[i as usize] += v as f64;
            }
        }
        let mut out = Vec::new();
        if self.uploads_seen == 0 {
            out.push("mass: no transmitted upload observed (vacuous balance)".into());
        }
        for i in 0..self.dim {
            let want = self.uploaded[i];
            let got = self.delivered[i] + self.restored[i] + self.alpha * pending[i];
            let tol = MASS_REL_TOL * want.abs().max(1.0);
            if (got - want).abs() > tol {
                out.push(format!(
                    "mass: coord {i}: delivered {} + restored {} + {}*pending {} = {got} \
                     != uploaded {want}",
                    self.delivered[i], self.restored[i], self.alpha, pending[i]
                ));
                if out.len() >= 8 {
                    out.push("mass: (further coordinate violations elided)".into());
                    break;
                }
            }
        }
        out
    }
}

impl RoundLedger for MassLedger {
    fn on_upload(
        &mut self,
        _client: usize,
        fate: ClientFate,
        echo: &SparseVec,
        _wire_bytes: usize,
        _precodec_bytes: usize,
    ) {
        match fate {
            ClientFate::Accepted => {
                self.uploads_seen += 1;
                for (&i, &v) in echo.indices.iter().zip(&echo.values) {
                    self.uploaded[i as usize] += v as f64;
                }
            }
            ClientFate::Straggler => {
                self.uploads_seen += 1;
                self.stragglers_seen += 1;
                // the bytes crossed the wire; what the server will not
                // apply (everything under drop, 1 − α under carry) went
                // back into the client residual
                let back = if self.carries { 1.0 - self.alpha } else { 1.0 };
                for (&i, &v) in echo.indices.iter().zip(&echo.values) {
                    self.uploaded[i as usize] += v as f64;
                    self.restored[i as usize] += back * v as f64;
                }
            }
            // never transmitted: the full client-side restore cancels the
            // never-uploaded mass — nothing enters the balance
            ClientFate::Offline => {}
        }
    }

    fn on_aggregate(&mut self, aggregate: &SparseVec, contributors: usize) {
        let c = contributors as f64;
        for (&i, &v) in aggregate.indices.iter().zip(&aggregate.values) {
            self.delivered[i as usize] += c * v as f64;
        }
    }

    fn into_any(self: Box<Self>) -> Box<dyn Any> {
        self
    }
}

/// Traffic-meter ⇄ recorder consistency: per-round record sanity plus the
/// cumulative-equals-sum-of-rounds contract for every ledger the meter
/// keeps (actual bytes, wasted bytes, pre-codec bytes, per-client
/// attribution). `v1_codec` additionally pins the pre-codec ledger to the
/// actual bytes (the default codec ships v1 bytes exactly).
pub fn check_traffic(
    meter: &TrafficMeter,
    recorder: &Recorder,
    clients: usize,
    v1_codec: bool,
) -> Vec<String> {
    let mut out = Vec::new();
    for r in &recorder.rounds {
        out.extend(r.consistency_violations());
        if v1_codec {
            if r.precodec_bytes != r.uplink_bytes + r.downlink_bytes {
                out.push(format!(
                    "traffic: round {}: v1 codec precodec {} != actual {}",
                    r.round,
                    r.precodec_bytes,
                    r.uplink_bytes + r.downlink_bytes
                ));
            }
            if r.codec_ratio != 1.0 {
                out.push(format!(
                    "traffic: round {}: v1 codec ratio {} != 1",
                    r.round, r.codec_ratio
                ));
            }
        }
    }
    let sums = [
        ("uplink", recorder.total_uplink(), meter.total_uplink),
        ("downlink", recorder.total_downlink(), meter.total_downlink),
        (
            "wasted",
            recorder.rounds.iter().map(|r| r.wasted_uplink_bytes).sum::<usize>(),
            meter.total_wasted_uplink,
        ),
        ("precodec", recorder.total_precodec_bytes(), meter.total_precodec),
        ("edge uplink", recorder.total_edge_uplink(), meter.total_edge_uplink),
        ("edge downlink", recorder.total_edge_downlink(), meter.total_edge_downlink),
    ];
    for (name, rec, met) in sums {
        if rec != met {
            out.push(format!("traffic: {name}: recorder sum {rec} != meter total {met}"));
        }
    }
    let per_client: usize = meter.per_client_uplink.iter().sum();
    if per_client != meter.total_uplink {
        out.push(format!(
            "traffic: per-client attribution {per_client} != total uplink {}",
            meter.total_uplink
        ));
    }
    // the final recorded Gini must be recomputable from the meter state
    let mut scratch = Vec::new();
    let gini = meter.uplink_gini(clients, &mut scratch);
    if !gini.is_finite() || !(0.0..1.0).contains(&gini) {
        out.push(format!("traffic: final gini {gini} outside [0, 1)"));
    }
    if let Some(last) = recorder.rounds.last() {
        if last.traffic_gini.to_bits() != gini.to_bits() {
            out.push(format!(
                "traffic: final recorded gini {} != recomputed {gini}",
                last.traffic_gini
            ));
        }
    }
    out
}

/// q8 round-trip contract over the *value stream* (support order): the
/// decoded support equals the original (sparse/bitmap containers keep
/// explicit zero entries), exact zeros decode to exact zeros, and every
/// value's round-trip error is bounded by half the block's quantisation
/// step (`scale/2`, scale = block maxabs / 127) plus f32 rounding noise.
///
/// `original` is the pre-encode vector, `decoded` the post-decode one.
/// Callers must arrange a sparse or bitmap container (a dense container
/// drops zero entries; its error bound is asserted elsewhere).
pub fn check_q8_roundtrip(original: &SparseVec, decoded: &SparseVec) -> Vec<String> {
    let mut out = Vec::new();
    if decoded.indices != original.indices {
        out.push(format!(
            "q8: support changed: {} entries in, {} out",
            original.nnz(),
            decoded.nnz()
        ));
        return out;
    }
    for (block_no, (orig_block, dec_block)) in original
        .values
        .chunks(Q8_BLOCK)
        .zip(decoded.values.chunks(Q8_BLOCK))
        .enumerate()
    {
        let scale = q8_block_scale(orig_block);
        let maxabs = scale * 127.0;
        // half a step, plus the independent f32 roundings of the scale and
        // its reciprocal
        let tol = scale * 0.5 + maxabs * 1e-6 + 1e-7;
        for (j, (&a, &b)) in orig_block.iter().zip(dec_block).enumerate() {
            if a == 0.0 && b != 0.0 {
                out.push(format!("q8: block {block_no} slot {j}: exact zero became {b}"));
                continue;
            }
            let err = (a - b).abs();
            if err as f64 > tol as f64 {
                out.push(format!(
                    "q8: block {block_no} slot {j}: |{a} - {b}| = {err} > tol {tol} \
                     (scale {scale})"
                ));
            }
        }
        if out.len() >= 8 {
            out.push("q8: (further violations elided)".into());
            break;
        }
    }
    out
}

/// Kernel-dispatch self-check: every dispatched hot-path kernel
/// (`sparse::simd`, `sparse::topk`) must be bit-identical to its
/// always-compiled scalar twin on deterministic data covering the
/// adversarial shapes — denormals, ±0, f16 saturation points, all-zero q8
/// blocks, q8 round-half boundaries, multi-byte varint gaps. `fedgmf
/// verify` runs this on the machine it executes on, so every conformance
/// run proves the *active* dispatch (`sparse::simd::describe()`) against
/// the scalar reference, not just whatever CI happened to detect.
pub fn check_kernel_dispatch() -> Vec<String> {
    use crate::sparse::{simd, topk};
    use crate::util::rng::Rng;
    let mode = simd::describe();
    let mut out = Vec::new();
    let mut rng = Rng::new(0xD15);
    let mut vals: Vec<f32> = vec![
        0.0,
        -0.0,
        0.5,
        -0.5,
        f32::from_bits(0.5f32.to_bits() - 1), // q8 round-half boundary trap
        65504.0,
        65520.0, // f16 saturation edge
        1e9,
        -1e9,
        f32::MIN_POSITIVE,
        f32::from_bits(1), // subnormal
        126.5,
        127.49,
    ];
    for _ in 0..2048 {
        vals.push(rng.normal() * 10f32.powi(rng.below(13) as i32 - 6));
    }
    let bits = |xs: &[f32]| -> Vec<u32> { xs.iter().map(|v| v.to_bits()).collect() };

    // f16 encode/decode
    let (mut ea, mut eb) = (Vec::new(), Vec::new());
    simd::f16_encode(&vals, &mut ea);
    simd::f16_encode_scalar(&vals, &mut eb);
    if ea != eb {
        out.push(format!("kernels({mode}): f16 encode diverges from scalar"));
    }
    let (mut da, mut db) = (vec![0f32; vals.len()], vec![0f32; vals.len()]);
    simd::f16_decode(&eb, &mut da);
    simd::f16_decode_scalar(&eb, &mut db);
    if bits(&da) != bits(&db) {
        out.push(format!("kernels({mode}): f16 decode diverges from scalar"));
    }

    // q8 maxabs / quantize / dequantize, including an all-zero block
    let zero_block = [0.0f32; 64];
    for block in vals.chunks(Q8_BLOCK).chain(std::iter::once(&zero_block[..])) {
        let ma = simd::maxabs(block);
        let ms = simd::maxabs_scalar(block);
        if ma.to_bits() != ms.to_bits() {
            out.push(format!("kernels({mode}): maxabs {ma} != scalar {ms}"));
        }
        if ms > 0.0 {
            let (mut qa, mut qb) = (Vec::new(), Vec::new());
            simd::q8_quantize(block, ms, &mut qa);
            simd::q8_quantize_scalar(block, ms, &mut qb);
            if qa != qb {
                out.push(format!("kernels({mode}): q8 quantize diverges from scalar"));
            }
            let scale = ms / 127.0;
            let (mut ra, mut rb) = (vec![0f32; qb.len()], vec![0f32; qb.len()]);
            simd::q8_dequantize(&qb, scale, &mut ra);
            simd::q8_dequantize_scalar(&qb, scale, &mut rb);
            if bits(&ra) != bits(&rb) {
                out.push(format!("kernels({mode}): q8 dequantize diverges from scalar"));
            }
        }
    }

    // varint gap coding over mixed-width gaps
    let mut ids: Vec<u32> = Vec::new();
    let mut acc = 0u64;
    while acc < u32::MAX as u64 - (1 << 22) && ids.len() < 4000 {
        acc += 1 + rng.below(1 << (3 + rng.below(20))) as u64;
        ids.push(acc as u32);
    }
    let (mut va, mut vb) = (Vec::new(), Vec::new());
    simd::varint_encode_gaps(&ids, &mut va);
    simd::varint_encode_gaps_scalar(&ids, &mut vb);
    if va != vb {
        out.push(format!("kernels({mode}): varint encode diverges from scalar"));
    }
    if simd::varint_gaps_bytes(&ids) != simd::varint_gaps_bytes_scalar(&ids) {
        out.push(format!("kernels({mode}): varint size diverges from scalar"));
    }
    let (mut ga, mut gb) = (vec![0u32; ids.len()], vec![0u32; ids.len()]);
    let (mut pa, mut pb) = (0usize, 0usize);
    let ra = simd::varint_decode_gaps(&vb, &mut pa, &mut ga);
    let rb = simd::varint_decode_gaps_scalar(&vb, &mut pb, &mut gb);
    if ga != gb || pa != pb || ra.0 != rb.0 || format!("{:?}", ra.1) != format!("{:?}", rb.1) {
        out.push(format!("kernels({mode}): varint decode diverges from scalar"));
    }

    // bucketed top-k threshold vs full quickselect
    let scores: Vec<f32> = vals.iter().map(|v| v.abs()).collect();
    let mut scratch = Vec::new();
    for k in [1usize, 7, scores.len() / 3, scores.len()] {
        let b = topk::threshold_exact_bucketed(&scores, k, &mut scratch);
        let q = topk::threshold_exact_quickselect(&scores, k, &mut scratch);
        if b != q {
            out.push(format!("kernels({mode}): bucketed top-k k={k}: {b} != quickselect {q}"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::traffic::TrafficPolicy;
    use crate::metrics::recorder::RoundRecord;
    use crate::sparse::codec::{CodecParams, IndexCoding, ValueCoding};
    use crate::sparse::wire;

    #[test]
    fn mass_ledger_balances_a_hand_built_round() {
        // 3 clients: one accepted, one straggler (carried at α = 0.5), one
        // offline. Aggregate = (accepted + 0·stale)/1 this round; the
        // straggler's upload stays pending.
        let dim = 4;
        let mut l = MassLedger::new(dim, StalenessPolicy::CarryDiscounted(0.5));
        let acc = SparseVec::new(dim, vec![(0, 2.0), (2, -1.0)]);
        let late = SparseVec::new(dim, vec![(1, 4.0)]);
        let off = SparseVec::new(dim, vec![(3, 9.0)]);
        l.on_upload(0, ClientFate::Accepted, &acc, 10, 10);
        l.on_upload(1, ClientFate::Straggler, &late, 10, 10);
        l.on_upload(2, ClientFate::Offline, &off, 0, 0);
        l.on_aggregate(&acc, 1); // mean of one contributor = the upload
        let mut q = StaleQueue::new();
        q.begin_round();
        q.push(1, 0, 10, &late);
        let violations = l.check(&q);
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(l.uploads_seen, 2, "offline never transmitted");
        assert_eq!(l.stragglers_seen, 1);
    }

    #[test]
    fn mass_ledger_catches_lost_mass() {
        let dim = 2;
        let mut l = MassLedger::new(dim, StalenessPolicy::Drop);
        let up = SparseVec::new(dim, vec![(0, 1.0)]);
        l.on_upload(0, ClientFate::Accepted, &up, 10, 10);
        // the aggregate never arrives: delivered stays 0
        let q = StaleQueue::new();
        let violations = l.check(&q);
        assert!(violations.iter().any(|v| v.contains("coord 0")), "{violations:?}");
    }

    #[test]
    fn mass_ledger_flags_vacuous_runs() {
        let l = MassLedger::new(2, StalenessPolicy::Drop);
        let q = StaleQueue::new();
        assert!(l.check(&q).iter().any(|v| v.contains("vacuous")));
    }

    #[test]
    fn traffic_check_accepts_consistent_books() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(0, 100, 100);
        m.record_wasted_uplink(1, 40, 40);
        m.record_broadcast(60, 60, 2);
        let mut rec = Recorder::new();
        let mut scratch = Vec::new();
        rec.push(RoundRecord {
            round: 0,
            uplink_bytes: 140,
            downlink_bytes: 60,
            wasted_uplink_bytes: 40,
            precodec_bytes: 200,
            codec_ratio: 1.0,
            selected: 2,
            dropped_deadline: 1,
            traffic_gini: m.uplink_gini(2, &mut scratch),
            ..Default::default()
        });
        let violations = check_traffic(&m, &rec, 2, true);
        assert!(violations.is_empty(), "{violations:?}");
        // corrupt one book: the check must notice
        let mut bad = rec.clone();
        bad.rounds[0].precodec_bytes = 999;
        assert!(!check_traffic(&m, &bad, 2, true).is_empty());
        // edge books must reconcile too: meter-side backhaul with no
        // matching record column is a leak
        let mut m2 = m.clone();
        m2.record_edge_uplink(50, 50);
        assert!(check_traffic(&m2, &rec, 2, true)
            .iter()
            .any(|v| v.contains("edge uplink")));
        let mut tiered = rec.clone();
        tiered.rounds[0].edge_count = 1;
        tiered.rounds[0].edge_uplink_bytes = 50;
        assert!(check_traffic(&m2, &tiered, 2, true).is_empty());
    }

    #[test]
    fn q8_check_passes_real_roundtrips_and_catches_corruption() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let dim = 4000;
        let mut ids: Vec<u32> = (0..dim as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(300);
        ids.sort_unstable();
        let mut values: Vec<f32> = ids.iter().map(|_| rng.normal() * 2.0).collect();
        values[7] = 0.0; // exact zero must survive exactly
        let sv = SparseVec::from_sorted(dim, ids, values);
        let p = CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 };
        let mut buf = Vec::new();
        wire::encode_with(&sv, &mut buf, p);
        let back = wire::decode(&buf).unwrap();
        let violations = check_q8_roundtrip(&sv, &back);
        assert!(violations.is_empty(), "{violations:?}");
        // corrupting one decoded value beyond the step must be caught
        let mut bad = back.clone();
        let maxabs = sv.values.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        bad.values[0] += maxabs; // far outside scale/2
        assert!(!check_q8_roundtrip(&sv, &bad).is_empty());
        // support change must be caught
        let mut shifted = back.clone();
        shifted.indices[0] += 1;
        assert!(!check_q8_roundtrip(&sv, &shifted).is_empty());
    }
}
