//! Scenario-matrix conformance harness — the subsystem behind
//! `fedgmf verify`.
//!
//! The paper's claim (GMF keeps accuracy while cutting uplink bytes) rests
//! on invariants this repo has so far asserted piecemeal per-PR:
//! error-feedback mass conservation through every residual/restore path,
//! traffic-meter ledger consistency, and bit-identical trajectories at any
//! worker count. This module makes the full scenario space a first-class
//! artifact: [`scenario::Scenario::all`] enumerates the cross-product of
//! every behavioural axis (technique × codec × staleness × selection ×
//! capability preset × chaos plan), [`run_scenario`] executes each point on a tiny
//! deterministic fixture at every worker count with the invariant ledgers
//! installed, and the resulting trajectory digests are compared against a
//! committed golden registry (`rust/tests/golden/verify_matrix.json`,
//! regenerated with `--bless`).
//!
//! Gate semantics: invariant violations and cross-worker digest divergence
//! always fail. The golden-digest comparison arms itself once a blessed
//! registry is committed (`blessed: true`); until then verify reports the
//! would-be digests in its JSON report so the first toolchain-bearing run
//! can bless and commit them. See `docs/testing.md`.

pub mod digest;
pub mod golden;
pub mod invariants;
pub mod scenario;

use crate::config::Scale;
use crate::coordinator::round::FlRun;
use crate::experiments::workload::{verify_fixture, VerifyFixture};
use crate::runtime::TrainEngine;
use crate::sparse::codec::{CodecParams, IndexCoding, ValueCoding};
use crate::sparse::vector::SparseVec;
use crate::sparse::wire;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::Result;
use golden::GoldenRegistry;
use invariants::MassLedger;
use scenario::{CodecAxis, Scenario};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// How `fedgmf verify` runs.
#[derive(Clone, Debug)]
pub struct VerifyOptions {
    pub scale: Scale,
    /// regenerate the golden registry instead of gating on it
    pub bless: bool,
    pub golden_path: PathBuf,
    /// write the conformance report JSON here (CI artifact)
    pub report_path: Option<PathBuf>,
}

/// Outcome of one scenario (all worker counts folded in).
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub key: String,
    /// trajectory digest of the sequential (workers = 1) reference run
    pub digest: u64,
    /// invariant violations across all worker runs, plus any cross-worker
    /// digest divergence
    pub violations: Vec<String>,
}

/// Full conformance report.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub scale: &'static str,
    /// total runs executed (scenarios × worker counts, plus one
    /// streamed-ingest, one two-tier and one adaptive rate-control run per
    /// scenario)
    pub runs: usize,
    /// streamed-ingest runs folded into the cross-worker digest gate (one
    /// per scenario — proves streamed ≡ materialized across the matrix)
    pub streamed_runs: usize,
    /// two-tier topology runs folded into the same digest gate (one per
    /// scenario per non-flat [`scenario::TIERS`] entry — proves a two-tier
    /// edge fleet ≡ the flat hub-and-spoke, bit for bit)
    pub tiered_runs: usize,
    /// adaptive rate-control runs (one per scenario) held to every
    /// invariant ledger but excluded from the digest equality gate —
    /// per-client (k, coding) planning changes the trajectory by design
    pub rate_control_runs: usize,
    pub scenarios: Vec<ScenarioResult>,
    /// one-off codec self-check violations (q8 round-trip contract)
    pub codec_selfcheck: Vec<String>,
    /// kernel-dispatch self-check violations (dispatched hot-path kernels
    /// vs their scalar twins — see `invariants::check_kernel_dispatch`)
    pub kernel_selfcheck: Vec<String>,
    /// active kernel dispatch for this run (`sparse::simd::describe()`),
    /// recorded so a report proves *which* path produced its digests
    pub kernel_dispatch: String,
    /// whether the loaded registry file was blessed at all (it may still
    /// lack a section for this scale — see `digest_gate_armed`)
    pub registry_blessed: bool,
    /// whether a blessed golden registry section for THIS scale gated the
    /// digests
    pub digest_gate_armed: bool,
    pub digest_mismatches: Vec<String>,
    /// whether `--bless` was requested (a requested-but-refused bless is
    /// reported distinctly — see [`VerifyReport::render`])
    pub bless_requested: bool,
    /// whether this invocation (re)wrote the registry
    pub blessed_now: bool,
    pub golden_path: String,
}

impl VerifyReport {
    /// Failed invariant checks: scenarios with at least one violation,
    /// plus the standalone codec self-check when it failed.
    pub fn invariant_failures(&self) -> usize {
        self.scenarios.iter().filter(|s| !s.violations.is_empty()).count()
            + usize::from(!self.codec_selfcheck.is_empty())
            + usize::from(!self.kernel_selfcheck.is_empty())
    }

    pub fn passed(&self) -> bool {
        self.invariant_failures() == 0 && self.digest_mismatches.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let digests = Json::Obj(
            self.scenarios
                .iter()
                .map(|s| (s.key.clone(), Json::str(digest::hex(s.digest))))
                .collect(),
        );
        let violations = Json::Obj(
            self.scenarios
                .iter()
                .filter(|s| !s.violations.is_empty())
                .map(|s| {
                    let list =
                        Json::Arr(s.violations.iter().map(|v| Json::str(v.as_str())).collect());
                    (s.key.clone(), list)
                })
                .collect(),
        );
        let chaos_axis =
            Json::Arr(scenario::AXIS_CHAOS.iter().map(|c| Json::str(c.name())).collect());
        // runner-level axis (not part of the scenario key): every gated run
        // is `off`; one extra `adaptive` run per scenario rides the
        // invariant ledgers only
        let rate_control_axis = Json::Arr(vec![Json::str("off"), Json::str("adaptive")]);
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("scale", Json::str(self.scale)),
            ("runs", Json::num(self.runs as f64)),
            ("streamed_runs", Json::num(self.streamed_runs as f64)),
            ("tiered_runs", Json::num(self.tiered_runs as f64)),
            ("rate_control_runs", Json::num(self.rate_control_runs as f64)),
            ("scenarios", Json::num(self.scenarios.len() as f64)),
            ("chaos_axis", chaos_axis),
            ("rate_control_axis", rate_control_axis),
            ("invariant_failures", Json::num(self.invariant_failures() as f64)),
            (
                "codec_selfcheck",
                Json::Arr(self.codec_selfcheck.iter().map(|v| Json::str(v.as_str())).collect()),
            ),
            (
                "kernel_selfcheck",
                Json::Arr(self.kernel_selfcheck.iter().map(|v| Json::str(v.as_str())).collect()),
            ),
            ("kernel_dispatch", Json::str(self.kernel_dispatch.clone())),
            ("registry_blessed", Json::Bool(self.registry_blessed)),
            ("digest_gate_armed", Json::Bool(self.digest_gate_armed)),
            ("bless_requested", Json::Bool(self.bless_requested)),
            (
                "digest_mismatches",
                Json::Arr(self.digest_mismatches.iter().map(|v| Json::str(v.as_str())).collect()),
            ),
            ("blessed", Json::Bool(self.blessed_now)),
            ("golden_path", Json::str(self.golden_path.clone())),
            ("passed", Json::Bool(self.passed())),
            ("digests", digests),
            ("violations", violations),
        ])
    }

    /// Human summary for the CLI.
    pub fn render(&self) -> String {
        let mut out = format!(
            "verify[{}]: {} scenarios x {} worker counts (+{} streamed-ingest, \
             +{} two-tier, +{} adaptive-rate) = {} runs | kernels {}\n",
            self.scale,
            self.scenarios.len(),
            scenario::WORKERS.len(),
            self.streamed_runs,
            self.tiered_runs,
            self.rate_control_runs,
            self.runs,
            self.kernel_dispatch
        );
        let inv = self.invariant_failures();
        if inv == 0 {
            out.push_str("invariants: mass conservation, traffic ledgers, cross-worker \
                          digests — all clean\n");
        } else {
            // `inv` counts failed checks: failing scenarios plus (at most
            // one) codec self-check — both kinds are listed below
            out.push_str(&format!("invariants: {inv} check(s) FAILED:\n"));
            for s in self.scenarios.iter().filter(|s| !s.violations.is_empty()).take(10) {
                out.push_str(&format!("  {}:\n", s.key));
                for v in s.violations.iter().take(4) {
                    out.push_str(&format!("    {v}\n"));
                }
            }
            for v in self.codec_selfcheck.iter().take(4) {
                out.push_str(&format!("  codec self-check: {v}\n"));
            }
            for v in self.kernel_selfcheck.iter().take(4) {
                out.push_str(&format!("  kernel self-check: {v}\n"));
            }
        }
        if self.blessed_now {
            out.push_str(&format!("golden registry blessed: {}\n", self.golden_path));
        } else if self.bless_requested {
            // bless was refused (invariant failures above); no digest
            // comparison ran, so make no claim about the goldens
            out.push_str(
                "golden registry NOT blessed: fix the invariant failures above and \
                 re-run --bless\n",
            );
        } else if self.digest_gate_armed {
            if self.digest_mismatches.is_empty() {
                out.push_str(&format!(
                    "golden digests: all {} match {}\n",
                    self.scenarios.len(),
                    self.golden_path
                ));
            } else {
                out.push_str(&format!(
                    "golden digests: {} MISMATCH(ES) vs {}:\n",
                    self.digest_mismatches.len(),
                    self.golden_path
                ));
                for m in self.digest_mismatches.iter().take(10) {
                    out.push_str(&format!("  {m}\n"));
                }
            }
        } else if self.registry_blessed {
            // blessed file, but no digests for this scale: say so precisely
            // — "unblessed" here would send the operator to a file that
            // plainly reads `"blessed": true`
            out.push_str(&format!(
                "golden digests: registry has no {} section — digest gate skipped \
                 (run `fedgmf verify --scale {} --bless` and commit)\n",
                self.scale, self.scale
            ));
        } else {
            out.push_str(
                "golden digests: registry unblessed — digest gate skipped (run \
                 `fedgmf verify --bless` on a toolchain-bearing host and commit the \
                 registry to arm it)\n",
            );
        }
        out
    }
}

fn scale_name(scale: Scale) -> &'static str {
    match scale {
        Scale::Quick => "quick",
        Scale::Default => "default",
        Scale::Paper => "paper",
    }
}

fn rounds_for(scale: Scale) -> usize {
    match scale {
        Scale::Quick => 6,
        Scale::Default => 10,
        Scale::Paper => 12,
    }
}

/// Default registry location: the crate's `tests/golden/` (compile-time
/// manifest dir), falling back to cwd-relative paths for relocated
/// binaries.
pub fn default_golden_path() -> PathBuf {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/verify_matrix.json");
    if manifest.exists() {
        return manifest;
    }
    for rel in ["tests/golden/verify_matrix.json", "rust/tests/golden/verify_matrix.json"] {
        let p = PathBuf::from(rel);
        if p.exists() {
            return p;
        }
    }
    manifest
}

/// Execute one scenario at one worker count on a fresh fixture, with the
/// mass-conservation ledger installed; returns the trajectory digest and
/// every invariant violation observed.
pub fn run_scenario(s: &Scenario, workers: usize, rounds: usize) -> Result<(u64, Vec<String>)> {
    run_scenario_with(s, workers, rounds, false)
}

/// [`run_scenario`] with the server-side ingest path selectable: `streamed`
/// folds accepted uploads straight from their wire bytes through the
/// codec-v2 pull-decoder. A streamed run must reproduce the materialized
/// run's trajectory digest bit-for-bit — `run_verify` pits one streamed run
/// against the worker matrix per scenario to prove exactly that.
pub fn run_scenario_with(
    s: &Scenario,
    workers: usize,
    rounds: usize,
    streamed: bool,
) -> Result<(u64, Vec<String>)> {
    run_scenario_tiered(s, workers, rounds, streamed, 1)
}

/// [`run_scenario_with`] with the fleet topology selectable: `tiers = 2`
/// routes cohort uploads through edge aggregators (fixture fan-in
/// [`scenario::FIXTURE_COHORTS_PER_EDGE`]). A two-tier run must reproduce
/// the flat run's trajectory digest bit-for-bit — the tiers axis in
/// `run_verify` pits one such run against the worker matrix per scenario.
pub fn run_scenario_tiered(
    s: &Scenario,
    workers: usize,
    rounds: usize,
    streamed: bool,
    tiers: usize,
) -> Result<(u64, Vec<String>)> {
    run_scenario_inner(s, workers, rounds, streamed, tiers, false)
}

/// [`run_scenario`] with the adaptive per-client rate controller switched
/// on (`rate_control.mode = adaptive`, boost 2.0 so the history term can
/// genuinely move k). Every invariant — per-coordinate mass ledger,
/// traffic-meter consistency — must still hold; the digest is *not*
/// compared against the fixed-rate reference, because per-client (k,
/// coding) planning changes the trajectory by design. `rate_control = off`
/// needs no extra leg: every digest-gated run above is exactly that.
pub fn run_scenario_rate_controlled(
    s: &Scenario,
    workers: usize,
    rounds: usize,
) -> Result<(u64, Vec<String>)> {
    run_scenario_inner(s, workers, rounds, false, 1, true)
}

fn run_scenario_inner(
    s: &Scenario,
    workers: usize,
    rounds: usize,
    streamed: bool,
    tiers: usize,
    adaptive: bool,
) -> Result<(u64, Vec<String>)> {
    let VerifyFixture { shards, network, mut engine } =
        verify_fixture(scenario::FIXTURE_CLIENTS, scenario::FIXTURE_SEED);
    let mut cfg = s.fl_config(workers, rounds);
    cfg.streamed_ingest = streamed;
    cfg.hierarchy.tiers = tiers;
    cfg.hierarchy.cohorts_per_edge = scenario::FIXTURE_COHORTS_PER_EDGE;
    if adaptive {
        cfg.rate_control.mode = crate::compress::RateControlMode::Adaptive;
        cfg.rate_control.max_rate_boost = 2.0;
    }
    let staleness = cfg.sim.staleness;
    let dim = engine.param_count();
    let mut run = FlRun::new(&engine, shards, Vec::new(), network, cfg);
    run.ledger = Some(Box::new(MassLedger::new(dim, staleness)));
    let summary = run.run(&mut engine)?;
    let ledger = run
        .ledger
        .take()
        .expect("ledger installed above")
        .into_any()
        .downcast::<MassLedger>()
        .expect("mass ledger type");
    let mut violations = ledger.check(&run.stale_queue);
    violations.extend(invariants::check_traffic(
        &run.meter,
        &summary.recorder,
        run.store.fleet_len(),
        s.codec == CodecAxis::V1,
    ));
    let bits: Vec<u32> = run.params.iter().map(|p| p.to_bits()).collect();
    Ok((digest::trajectory_digest(&bits, &summary.recorder.rounds), violations))
}

/// One-off q8 value-coding self-check (the same invariant the proptests
/// drive with randomized vectors): encode/decode a deterministic sparse
/// top-k-shaped payload and audit the round-trip contract.
fn q8_selfcheck() -> Vec<String> {
    let mut rng = Rng::new(scenario::FIXTURE_SEED);
    let dim = 4096;
    let mut ids: Vec<u32> = (0..dim as u32).collect();
    rng.shuffle(&mut ids);
    ids.truncate(400);
    ids.sort_unstable();
    let mut values: Vec<f32> = ids.iter().map(|_| rng.normal() * 3.0).collect();
    values[0] = 0.0; // exact zeros must survive exactly
    let sv = SparseVec::from_sorted(dim, ids, values);
    let p = CodecParams { index: IndexCoding::Varint, value: ValueCoding::Q8 };
    let mut buf = Vec::new();
    wire::encode_with(&sv, &mut buf, p);
    match wire::decode(&buf) {
        Ok(back) => invariants::check_q8_roundtrip(&sv, &back),
        Err(e) => vec![format!("q8: self-check buffer failed to decode: {e}")],
    }
}

/// Run the full conformance matrix; see the module docs for gate
/// semantics. Always returns `Ok(report)` for harness errors short of an
/// engine failure — callers decide the exit code from
/// [`VerifyReport::passed`].
pub fn run_verify(opts: &VerifyOptions) -> Result<VerifyReport> {
    let rounds = rounds_for(opts.scale);
    let scale_key = scale_name(opts.scale);
    let registry = GoldenRegistry::load(&opts.golden_path)?;
    let mut results: Vec<ScenarioResult> = Vec::new();
    let mut fresh: BTreeMap<String, u64> = BTreeMap::new();
    let mut runs = 0usize;
    for s in Scenario::all() {
        let key = s.key();
        let mut violations = Vec::new();
        let mut worker_digests: Vec<(&str, u64)> = Vec::new();
        for &(wname, workers) in scenario::WORKERS {
            let (d, v) = run_scenario(&s, workers, rounds)?;
            runs += 1;
            worker_digests.push((wname, d));
            violations.extend(v.into_iter().map(|m| format!("[{wname}] {m}")));
        }
        // one streamed-ingest run per scenario rides the same cross-worker
        // digest gate: streamed and materialized ingest must agree
        // bit-for-bit on every point of the matrix
        {
            let (d, v) = run_scenario_with(&s, 1, rounds, true)?;
            runs += 1;
            worker_digests.push(("w1+streamed", d));
            violations.extend(v.into_iter().map(|m| format!("[w1+streamed] {m}")));
        }
        // the tiers axis: every non-flat topology entry runs once per
        // scenario and its digest joins the same equality gate — a two-tier
        // edge fleet must be bit-identical to the flat reference (which the
        // golden registry pins), per the hierarchy module's contract
        for &(tname, tiers) in scenario::TIERS.iter().filter(|&&(_, t)| t > 1) {
            let (d, v) = run_scenario_tiered(&s, 1, rounds, false, tiers)?;
            runs += 1;
            worker_digests.push((tname, d));
            violations.extend(v.into_iter().map(|m| format!("[{tname}] {m}")));
        }
        // the rate-control axis: one adaptive run per scenario, held to the
        // same invariant ledgers but NOT pushed into `worker_digests` — the
        // controller changes the trajectory by design, so only `off` (every
        // run above) is digest-gated
        {
            let (_, v) = run_scenario_rate_controlled(&s, 1, rounds)?;
            runs += 1;
            violations.extend(v.into_iter().map(|m| format!("[w1+adaptive] {m}")));
        }
        let reference = worker_digests[0].1;
        for &(wname, d) in &worker_digests[1..] {
            if d != reference {
                violations.push(format!(
                    "cross-worker digest mismatch: {wname} {} != {} {}",
                    digest::hex(d),
                    scenario::WORKERS[0].0,
                    digest::hex(reference)
                ));
            }
        }
        fresh.insert(key.clone(), reference);
        results.push(ScenarioResult { key, digest: reference, violations });
    }
    let codec_selfcheck = q8_selfcheck();
    let kernel_selfcheck = invariants::check_kernel_dispatch();

    let invariants_clean = results.iter().all(|r| r.violations.is_empty())
        && codec_selfcheck.is_empty()
        && kernel_selfcheck.is_empty();
    let mut digest_mismatches = Vec::new();
    let registry_blessed = registry.blessed;
    let digest_gate_armed = registry.blessed && registry.digests(scale_key).is_some();
    let mut blessed_now = false;
    if opts.bless {
        if invariants_clean {
            let mut reg = registry;
            reg.bless(scale_key, fresh);
            reg.save(&opts.golden_path)?;
            blessed_now = true;
        }
        // a failing tree is never blessed: the report carries the failures
    } else if digest_gate_armed {
        let committed = registry.digests(scale_key).expect("armed implies present");
        for r in &results {
            match committed.get(&r.key) {
                Some(&want) if want == r.digest => {}
                Some(&want) => digest_mismatches.push(format!(
                    "{}: digest {} != golden {}",
                    r.key,
                    digest::hex(r.digest),
                    digest::hex(want)
                )),
                None => digest_mismatches.push(format!(
                    "{}: not in golden registry (new scenario — review and re-bless)",
                    r.key
                )),
            }
        }
        for k in committed.keys() {
            if !fresh.contains_key(k) {
                digest_mismatches.push(format!(
                    "{k}: in golden registry but no longer enumerated (coverage shrank — \
                     review and re-bless)"
                ));
            }
        }
    }

    let report = VerifyReport {
        scale: scale_key,
        runs,
        streamed_runs: Scenario::all().len(),
        tiered_runs: Scenario::all().len()
            * scenario::TIERS.iter().filter(|&&(_, t)| t > 1).count(),
        rate_control_runs: Scenario::all().len(),
        scenarios: results,
        codec_selfcheck,
        kernel_selfcheck,
        kernel_dispatch: crate::sparse::simd::describe(),
        registry_blessed,
        digest_gate_armed,
        digest_mismatches,
        bless_requested: opts.bless,
        blessed_now,
        golden_path: opts.golden_path.display().to_string(),
    };
    if let Some(path) = &opts.report_path {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, report.to_json().to_pretty())?;
    }
    Ok(report)
}
