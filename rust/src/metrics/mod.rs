//! Metric recording and reporting.
pub mod ledger;
pub mod recorder;

pub use ledger::RoundLedger;
pub use recorder::{Recorder, RoundRecord};
