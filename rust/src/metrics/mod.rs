//! Metric recording and reporting.
pub mod recorder;

pub use recorder::{Recorder, RoundRecord};
