//! Per-round metric recording: loss/accuracy curves, traffic, mask overlap.
//!
//! One `RoundRecord` per communication round; the recorder serialises to CSV
//! (for the figure series) and JSON (for EXPERIMENTS.md evidence), both via
//! the in-tree writers (no external deps).

use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

/// Everything measured about one communication round.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    pub train_loss: f64,
    pub test_loss: f64,
    pub test_accuracy: f64,
    /// bytes uploaded by all participating clients this round
    pub uplink_bytes: usize,
    /// bytes of the server broadcast (counted once — hub multicast)
    pub downlink_bytes: usize,
    /// nnz of the aggregated gradient (union support size)
    pub aggregate_nnz: usize,
    /// mean pairwise Jaccard overlap of client masks
    pub mask_overlap: f64,
    /// simulated network seconds for the round
    pub sim_seconds: f64,
    /// wall-clock compute seconds for the round (this testbed)
    pub wall_seconds: f64,
}

/// Accumulates rounds; produces summaries and files.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    pub rounds: Vec<RoundRecord>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn total_uplink(&self) -> usize {
        self.rounds.iter().map(|r| r.uplink_bytes).sum()
    }

    pub fn total_downlink(&self) -> usize {
        self.rounds.iter().map(|r| r.downlink_bytes).sum()
    }

    /// Total communication overhead in bytes — the paper's headline column.
    pub fn total_traffic(&self) -> usize {
        self.total_uplink() + self.total_downlink()
    }

    pub fn total_traffic_gb(&self) -> f64 {
        self.total_traffic() as f64 / 1e9
    }

    pub fn total_sim_seconds(&self) -> f64 {
        self.rounds.iter().map(|r| r.sim_seconds).sum()
    }

    /// Final test accuracy (last evaluated round).
    pub fn final_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .find(|r| r.test_accuracy > 0.0)
            .map(|r| r.test_accuracy)
            .unwrap_or(0.0)
    }

    /// Best test accuracy over the run.
    pub fn best_accuracy(&self) -> f64 {
        self.rounds.iter().map(|r| r.test_accuracy).fold(0.0, f64::max)
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,train_loss,test_loss,test_accuracy,uplink_bytes,downlink_bytes,aggregate_nnz,mask_overlap,sim_seconds,wall_seconds\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{},{},{},{:.6},{:.6},{:.6}\n",
                r.round,
                r.train_loss,
                r.test_loss,
                r.test_accuracy,
                r.uplink_bytes,
                r.downlink_bytes,
                r.aggregate_nnz,
                r.mask_overlap,
                r.sim_seconds,
                r.wall_seconds
            ));
        }
        out
    }

    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("rounds", Json::num(self.rounds.len() as f64)),
            ("final_accuracy", Json::num(self.final_accuracy())),
            ("best_accuracy", Json::num(self.best_accuracy())),
            ("total_uplink_bytes", Json::num(self.total_uplink() as f64)),
            ("total_downlink_bytes", Json::num(self.total_downlink() as f64)),
            ("total_traffic_gb", Json::num(self.total_traffic_gb())),
            ("total_sim_seconds", Json::num(self.total_sim_seconds())),
        ])
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64, up: usize, down: usize) -> RoundRecord {
        RoundRecord {
            round,
            test_accuracy: acc,
            uplink_bytes: up,
            downlink_bytes: down,
            ..Default::default()
        }
    }

    #[test]
    fn totals_and_final() {
        let mut r = Recorder::new();
        r.push(rec(0, 0.1, 100, 50));
        r.push(rec(1, 0.5, 100, 60));
        r.push(rec(2, 0.4, 100, 70));
        assert_eq!(r.total_uplink(), 300);
        assert_eq!(r.total_downlink(), 180);
        assert_eq!(r.total_traffic(), 480);
        assert_eq!(r.final_accuracy(), 0.4);
        assert_eq!(r.best_accuracy(), 0.5);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut r = Recorder::new();
        r.push(rec(0, 0.3, 10, 5));
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[1].starts_with("0,"));
    }

    #[test]
    fn summary_json_fields() {
        let mut r = Recorder::new();
        r.push(rec(0, 0.3, 10, 5));
        let j = r.summary_json();
        assert_eq!(j.get("rounds").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("total_uplink_bytes").unwrap().as_usize(), Some(10));
    }

    #[test]
    fn empty_recorder_is_safe() {
        let r = Recorder::new();
        assert_eq!(r.final_accuracy(), 0.0);
        assert_eq!(r.total_traffic(), 0);
    }
}
