//! Per-round metric recording: loss/accuracy curves, traffic, mask overlap.
//!
//! One `RoundRecord` per communication round; the recorder serialises to CSV
//! (for the figure series) and JSON (for EXPERIMENTS.md evidence), both via
//! the in-tree writers (no external deps).

use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

/// Everything measured about one communication round.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    pub train_loss: f64,
    pub test_loss: f64,
    pub test_accuracy: f64,
    /// bytes uploaded by all participating clients this round
    pub uplink_bytes: usize,
    /// bytes of the server broadcast (counted once — hub multicast)
    pub downlink_bytes: usize,
    /// nnz of the aggregated gradient (union support size)
    pub aggregate_nnz: usize,
    /// mean pairwise Jaccard overlap of client masks
    pub mask_overlap: f64,
    /// simulated network seconds for the round
    pub sim_seconds: f64,
    /// wall-clock compute seconds for the round (this testbed)
    pub wall_seconds: f64,
    /// clients selected for the round (over-provisioned cohort size)
    pub selected: usize,
    /// selected clients whose upload missed the round deadline
    pub dropped_deadline: usize,
    /// selected clients that dropped out entirely (upload never sent)
    pub dropped_offline: usize,
    /// cumulative simulated seconds at the end of this round (round clock)
    pub sim_clock: f64,
    /// straggler bytes this round: uploaded but discarded at the deadline
    /// (included in `uplink_bytes`)
    pub wasted_uplink_bytes: usize,
    /// late uploads carried over from the previous round into this round's
    /// aggregate (semi-synchronous staleness policies; 0 under `drop`)
    pub carried_in: usize,
    /// wire bytes of the carried uploads (spent in the round they were
    /// produced; attributed here so carry-over cost is visible per round)
    pub carried_bytes: usize,
    /// Gini coefficient of cumulative per-client uplink bytes after this
    /// round — the selection-fairness statistic (0 = equal spend across the
    /// fleet, → 1 = one client pays for everyone)
    pub traffic_gini: f64,
    /// v1-equivalent (raw u32 index + f32 value) bytes of everything that
    /// crossed the wire this round — what the round would have cost before
    /// codec v2 (equals `uplink_bytes + downlink_bytes` under the default
    /// codec)
    pub precodec_bytes: usize,
    /// `precodec_bytes / (uplink_bytes + downlink_bytes)` — the wire
    /// codec's byte reduction factor for the round (1 under the default
    /// codec; 1 when nothing crossed the wire)
    pub codec_ratio: f64,
    /// transport-level reconnect/resend attempts this round (truncate and
    /// disconnect faults; wall-clock state, never part of the digest)
    pub retries: usize,
    /// expected uploads still missing when the round's wall-clock deadline
    /// closed it (service mode's graceful degradation)
    pub timeouts: usize,
    /// frames that arrived after their round had already closed
    pub stale_frames: usize,
    /// duplicate (client, round) frames rejected by the receive path
    pub dup_frames: usize,
    /// edge aggregators active this round (0 = flat hub-and-spoke). The
    /// edge_* columns describe the tier-1 backhaul only and are deliberately
    /// OUTSIDE the trajectory digest: a two-tier run is byte-identical to a
    /// flat run everywhere the digest looks.
    pub edge_count: usize,
    /// merged edge → hub backhaul bytes this round (support-union frames,
    /// uplink codec)
    pub edge_uplink_bytes: usize,
    /// hub → edge broadcast fan-out bytes (broadcast frame × edge_count)
    pub edge_downlink_bytes: usize,
    /// simulated backhaul seconds over the parallel edge links (diagnostic
    /// only — never added to `sim_seconds`, which is digested)
    pub edge_backhaul_s: f64,
    /// mean effective top-k rate (`k / dim`) across the round's cohort.
    /// Like the edge_* columns, the rate_* family is deliberately OUTSIDE
    /// the trajectory digest: a `rate_control = off` run must stay
    /// digest-identical to a pre-controller build, and under `off` these
    /// just echo the shared warmup rate.
    pub rate_mean: f64,
    /// smallest per-client effective rate the controller planned this round
    pub rate_min: f64,
    /// largest per-client effective rate the controller planned this round
    pub rate_max: f64,
    /// cohort members whose uplink value coding was stepped lossier than
    /// the configured base coding this round (0 when the controller is off)
    pub coding_downshifts: usize,
}

impl RoundRecord {
    /// Internal-consistency violations of this record — the per-round half
    /// of the traffic invariant ledger `fedgmf verify` runs over every
    /// scenario (see `crate::testkit::invariants`). Empty means the record
    /// is self-consistent: every derived statistic is finite and in range,
    /// and the codec-ratio/pre-codec relation holds to the bit contract
    /// the round loop promises.
    pub fn consistency_violations(&self) -> Vec<String> {
        let mut out = Vec::new();
        let r = self.round;
        if !self.codec_ratio.is_finite() || self.codec_ratio <= 0.0 {
            out.push(format!("round {r}: codec_ratio {} not finite/positive", self.codec_ratio));
        }
        if !self.traffic_gini.is_finite() || !(0.0..1.0).contains(&self.traffic_gini) {
            out.push(format!("round {r}: traffic_gini {} outside [0, 1)", self.traffic_gini));
        }
        if self.wasted_uplink_bytes > self.uplink_bytes {
            out.push(format!(
                "round {r}: wasted {} exceeds uplink {}",
                self.wasted_uplink_bytes, self.uplink_bytes
            ));
        }
        let actual = self.uplink_bytes + self.downlink_bytes;
        let want_ratio =
            if actual == 0 { 1.0 } else { self.precodec_bytes as f64 / actual as f64 };
        if (self.codec_ratio - want_ratio).abs() > 1e-12 {
            out.push(format!(
                "round {r}: codec_ratio {} != precodec/actual {}",
                self.codec_ratio, want_ratio
            ));
        }
        if self.dropped_deadline + self.dropped_offline > self.selected {
            out.push(format!(
                "round {r}: drops {}+{} exceed cohort {}",
                self.dropped_deadline, self.dropped_offline, self.selected
            ));
        }
        if !self.sim_seconds.is_finite() || self.sim_seconds < 0.0 {
            out.push(format!("round {r}: sim_seconds {} invalid", self.sim_seconds));
        }
        if !self.train_loss.is_finite() {
            out.push(format!("round {r}: train_loss {} not finite", self.train_loss));
        }
        if self.edge_count == 0
            && (self.edge_uplink_bytes != 0
                || self.edge_downlink_bytes != 0
                || self.edge_backhaul_s != 0.0)
        {
            out.push(format!(
                "round {r}: edge traffic ({}, {}, {}) recorded with no edges",
                self.edge_uplink_bytes, self.edge_downlink_bytes, self.edge_backhaul_s
            ));
        }
        if !self.edge_backhaul_s.is_finite() || self.edge_backhaul_s < 0.0 {
            out.push(format!("round {r}: edge_backhaul_s {} invalid", self.edge_backhaul_s));
        }
        if !(self.rate_mean.is_finite() && self.rate_min.is_finite() && self.rate_max.is_finite())
            || self.rate_min < 0.0
            || self.rate_max > 1.0
            || self.rate_min > self.rate_mean + 1e-12
            || self.rate_mean > self.rate_max + 1e-12
        {
            out.push(format!(
                "round {r}: rate columns ({}, {}, {}) violate 0 <= min <= mean <= max <= 1",
                self.rate_min, self.rate_mean, self.rate_max
            ));
        }
        out
    }
}

/// Accumulates rounds; produces summaries and files.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    pub rounds: Vec<RoundRecord>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn total_uplink(&self) -> usize {
        self.rounds.iter().map(|r| r.uplink_bytes).sum()
    }

    pub fn total_downlink(&self) -> usize {
        self.rounds.iter().map(|r| r.downlink_bytes).sum()
    }

    /// Total communication overhead in bytes — the paper's headline column.
    pub fn total_traffic(&self) -> usize {
        self.total_uplink() + self.total_downlink()
    }

    pub fn total_traffic_gb(&self) -> f64 {
        self.total_traffic() as f64 / 1e9
    }

    pub fn total_sim_seconds(&self) -> f64 {
        self.rounds.iter().map(|r| r.sim_seconds).sum()
    }

    pub fn total_dropped_deadline(&self) -> usize {
        self.rounds.iter().map(|r| r.dropped_deadline).sum()
    }

    pub fn total_dropped_offline(&self) -> usize {
        self.rounds.iter().map(|r| r.dropped_offline).sum()
    }

    /// Late uploads that were carried into a later round's aggregate.
    pub fn total_carried_in(&self) -> usize {
        self.rounds.iter().map(|r| r.carried_in).sum()
    }

    pub fn total_carried_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.carried_bytes).sum()
    }

    /// Whole-run v1-equivalent bytes (pre-codec ledger).
    pub fn total_precodec_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.precodec_bytes).sum()
    }

    /// Whole-run pre-codec over post-codec byte ratio (1 when no traffic).
    pub fn overall_codec_ratio(&self) -> f64 {
        let actual = self.total_traffic();
        if actual == 0 {
            1.0
        } else {
            self.total_precodec_bytes() as f64 / actual as f64
        }
    }

    /// Last evaluated accuracy at or before the simulated-seconds `budget`
    /// (by the round clock); 0 when nothing was evaluated in time.
    pub fn accuracy_at_sim_seconds(&self, budget: f64) -> f64 {
        let mut acc = 0.0;
        for r in &self.rounds {
            if r.sim_clock > budget {
                break;
            }
            if r.test_accuracy > 0.0 {
                acc = r.test_accuracy;
            }
        }
        acc
    }

    /// Final test accuracy (last evaluated round).
    pub fn final_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .find(|r| r.test_accuracy > 0.0)
            .map(|r| r.test_accuracy)
            .unwrap_or(0.0)
    }

    /// Best test accuracy over the run.
    pub fn best_accuracy(&self) -> f64 {
        self.rounds.iter().map(|r| r.test_accuracy).fold(0.0, f64::max)
    }

    /// Transport retry attempts over the run (0 for pure-simulator runs).
    pub fn total_retries(&self) -> usize {
        self.rounds.iter().map(|r| r.retries).sum()
    }

    /// Wall-deadline round closures that left expected uploads missing.
    pub fn total_timeouts(&self) -> usize {
        self.rounds.iter().map(|r| r.timeouts).sum()
    }

    /// Frames that arrived after their round closed.
    pub fn total_stale_frames(&self) -> usize {
        self.rounds.iter().map(|r| r.stale_frames).sum()
    }

    /// Duplicate (client, round) frames rejected.
    pub fn total_dup_frames(&self) -> usize {
        self.rounds.iter().map(|r| r.dup_frames).sum()
    }

    /// Whole-run tier-1 (edge → hub) backhaul bytes; 0 for flat fleets.
    pub fn total_edge_uplink(&self) -> usize {
        self.rounds.iter().map(|r| r.edge_uplink_bytes).sum()
    }

    /// Whole-run hub → edge broadcast fan-out bytes; 0 for flat fleets.
    pub fn total_edge_downlink(&self) -> usize {
        self.rounds.iter().map(|r| r.edge_downlink_bytes).sum()
    }

    /// Uplink codings stepped lossier by the rate controller (whole run).
    pub fn total_coding_downshifts(&self) -> usize {
        self.rounds.iter().map(|r| r.coding_downshifts).sum()
    }

    /// Mean of the per-round mean effective top-k rate (the shared warmup
    /// rate when the controller is off; 0 for an empty recorder).
    pub fn mean_effective_rate(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.rate_mean).sum::<f64>() / self.rounds.len() as f64
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,train_loss,test_loss,test_accuracy,uplink_bytes,downlink_bytes,\
             aggregate_nnz,mask_overlap,sim_seconds,wall_seconds,selected,dropped_deadline,\
             dropped_offline,sim_clock,wasted_uplink_bytes,carried_in,carried_bytes,\
             traffic_gini,precodec_bytes,codec_ratio,retries,timeouts,stale_frames,\
             dup_frames,edge_count,edge_uplink_bytes,edge_downlink_bytes,edge_backhaul_s,\
             rate_mean,rate_min,rate_max,coding_downshifts\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{},{},{},{:.6},{:.6},{:.6},{},{},{},{:.6},{},{},{},\
                 {:.6},{},{:.6},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.6},{}\n",
                r.round,
                r.train_loss,
                r.test_loss,
                r.test_accuracy,
                r.uplink_bytes,
                r.downlink_bytes,
                r.aggregate_nnz,
                r.mask_overlap,
                r.sim_seconds,
                r.wall_seconds,
                r.selected,
                r.dropped_deadline,
                r.dropped_offline,
                r.sim_clock,
                r.wasted_uplink_bytes,
                r.carried_in,
                r.carried_bytes,
                r.traffic_gini,
                r.precodec_bytes,
                r.codec_ratio,
                r.retries,
                r.timeouts,
                r.stale_frames,
                r.dup_frames,
                r.edge_count,
                r.edge_uplink_bytes,
                r.edge_downlink_bytes,
                r.edge_backhaul_s,
                r.rate_mean,
                r.rate_min,
                r.rate_max,
                r.coding_downshifts
            ));
        }
        out
    }

    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("rounds", Json::num(self.rounds.len() as f64)),
            ("final_accuracy", Json::num(self.final_accuracy())),
            ("best_accuracy", Json::num(self.best_accuracy())),
            ("total_uplink_bytes", Json::num(self.total_uplink() as f64)),
            ("total_downlink_bytes", Json::num(self.total_downlink() as f64)),
            ("total_traffic_gb", Json::num(self.total_traffic_gb())),
            ("total_sim_seconds", Json::num(self.total_sim_seconds())),
            ("total_dropped_deadline", Json::num(self.total_dropped_deadline() as f64)),
            ("total_dropped_offline", Json::num(self.total_dropped_offline() as f64)),
            ("total_carried_in", Json::num(self.total_carried_in() as f64)),
            ("total_carried_bytes", Json::num(self.total_carried_bytes() as f64)),
            (
                "final_traffic_gini",
                Json::num(self.rounds.last().map(|r| r.traffic_gini).unwrap_or(0.0)),
            ),
            ("total_precodec_bytes", Json::num(self.total_precodec_bytes() as f64)),
            ("overall_codec_ratio", Json::num(self.overall_codec_ratio())),
            ("total_retries", Json::num(self.total_retries() as f64)),
            ("total_timeouts", Json::num(self.total_timeouts() as f64)),
            ("total_stale_frames", Json::num(self.total_stale_frames() as f64)),
            ("total_dup_frames", Json::num(self.total_dup_frames() as f64)),
            ("total_edge_uplink_bytes", Json::num(self.total_edge_uplink() as f64)),
            ("total_edge_downlink_bytes", Json::num(self.total_edge_downlink() as f64)),
            ("total_coding_downshifts", Json::num(self.total_coding_downshifts() as f64)),
            ("mean_effective_rate", Json::num(self.mean_effective_rate())),
        ])
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64, up: usize, down: usize) -> RoundRecord {
        RoundRecord {
            round,
            test_accuracy: acc,
            uplink_bytes: up,
            downlink_bytes: down,
            ..Default::default()
        }
    }

    #[test]
    fn totals_and_final() {
        let mut r = Recorder::new();
        r.push(rec(0, 0.1, 100, 50));
        r.push(rec(1, 0.5, 100, 60));
        r.push(rec(2, 0.4, 100, 70));
        assert_eq!(r.total_uplink(), 300);
        assert_eq!(r.total_downlink(), 180);
        assert_eq!(r.total_traffic(), 480);
        assert_eq!(r.final_accuracy(), 0.4);
        assert_eq!(r.best_accuracy(), 0.5);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut r = Recorder::new();
        r.push(rec(0, 0.3, 10, 5));
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[1].starts_with("0,"));
    }

    #[test]
    fn summary_json_fields() {
        let mut r = Recorder::new();
        r.push(rec(0, 0.3, 10, 5));
        let j = r.summary_json();
        assert_eq!(j.get("rounds").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("total_uplink_bytes").unwrap().as_usize(), Some(10));
    }

    #[test]
    fn empty_recorder_is_safe() {
        let r = Recorder::new();
        assert_eq!(r.final_accuracy(), 0.0);
        assert_eq!(r.total_traffic(), 0);
        assert_eq!(r.accuracy_at_sim_seconds(100.0), 0.0);
    }

    #[test]
    fn drop_totals_and_budget_accuracy() {
        let mut r = Recorder::new();
        r.push(RoundRecord {
            round: 0,
            test_accuracy: 0.2,
            dropped_deadline: 2,
            dropped_offline: 1,
            sim_clock: 1.0,
            ..Default::default()
        });
        r.push(RoundRecord {
            round: 1,
            test_accuracy: 0.0, // not evaluated
            dropped_deadline: 1,
            sim_clock: 2.0,
            ..Default::default()
        });
        r.push(RoundRecord {
            round: 2,
            test_accuracy: 0.6,
            sim_clock: 3.0,
            ..Default::default()
        });
        assert_eq!(r.total_dropped_deadline(), 3);
        assert_eq!(r.total_dropped_offline(), 1);
        assert_eq!(r.accuracy_at_sim_seconds(0.5), 0.0);
        assert_eq!(r.accuracy_at_sim_seconds(1.0), 0.2);
        assert_eq!(r.accuracy_at_sim_seconds(2.5), 0.2, "round 1 had no eval");
        assert_eq!(r.accuracy_at_sim_seconds(10.0), 0.6);
        let csv = r.to_csv();
        assert!(csv.lines().next().unwrap().ends_with(
            "sim_clock,wasted_uplink_bytes,carried_in,carried_bytes,traffic_gini,\
             precodec_bytes,codec_ratio,retries,timeouts,stale_frames,dup_frames,\
             edge_count,edge_uplink_bytes,edge_downlink_bytes,edge_backhaul_s,\
             rate_mean,rate_min,rate_max,coding_downshifts"
        ));
    }

    #[test]
    fn transport_counter_totals() {
        let mut r = Recorder::new();
        r.push(RoundRecord { retries: 2, stale_frames: 1, ..Default::default() });
        r.push(RoundRecord { retries: 1, timeouts: 3, dup_frames: 4, ..Default::default() });
        assert_eq!(r.total_retries(), 3);
        assert_eq!(r.total_timeouts(), 3);
        assert_eq!(r.total_stale_frames(), 1);
        assert_eq!(r.total_dup_frames(), 4);
        let j = r.summary_json();
        assert_eq!(j.get("total_retries").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("total_dup_frames").unwrap().as_usize(), Some(4));
        let row = r.to_csv().lines().nth(1).unwrap().to_string();
        assert!(
            row.ends_with("2,0,1,0,0,0,0,0.000000,0.000000,0.000000,0.000000,0"),
            "row {row}"
        );
    }

    #[test]
    fn carry_totals_accumulate() {
        let mut r = Recorder::new();
        r.push(RoundRecord { carried_in: 2, carried_bytes: 300, ..Default::default() });
        r.push(RoundRecord {
            carried_in: 1,
            carried_bytes: 120,
            traffic_gini: 0.25,
            ..Default::default()
        });
        assert_eq!(r.total_carried_in(), 3);
        assert_eq!(r.total_carried_bytes(), 420);
        let j = r.summary_json();
        assert_eq!(j.get("total_carried_in").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("final_traffic_gini").unwrap().as_f64(), Some(0.25));
    }

    #[test]
    fn consistency_violations_flag_bad_records() {
        // a well-formed round reads clean
        let good = RoundRecord {
            round: 3,
            uplink_bytes: 100,
            downlink_bytes: 50,
            precodec_bytes: 300,
            codec_ratio: 2.0,
            selected: 4,
            dropped_deadline: 1,
            traffic_gini: 0.2,
            ..Default::default()
        };
        assert!(good.consistency_violations().is_empty(), "{:?}", good.consistency_violations());
        // an empty-wire round must read ratio 1, not 0/NaN
        let empty = RoundRecord { codec_ratio: 1.0, ..Default::default() };
        assert!(empty.consistency_violations().is_empty());
        // broken records are each caught
        let bad_ratio = RoundRecord { codec_ratio: f64::NAN, ..Default::default() };
        assert!(!bad_ratio.consistency_violations().is_empty());
        let bad_gini = RoundRecord { codec_ratio: 1.0, traffic_gini: 1.5, ..Default::default() };
        assert!(!bad_gini.consistency_violations().is_empty());
        let bad_waste = RoundRecord {
            codec_ratio: 1.0,
            uplink_bytes: 10,
            downlink_bytes: 0,
            precodec_bytes: 10,
            wasted_uplink_bytes: 20,
            ..Default::default()
        };
        assert!(!bad_waste.consistency_violations().is_empty());
        let bad_relation = RoundRecord {
            uplink_bytes: 100,
            downlink_bytes: 0,
            precodec_bytes: 100,
            codec_ratio: 2.0,
            ..Default::default()
        };
        assert!(!bad_relation.consistency_violations().is_empty());
        let bad_drops = RoundRecord {
            codec_ratio: 1.0,
            selected: 2,
            dropped_deadline: 2,
            dropped_offline: 1,
            ..Default::default()
        };
        assert!(!bad_drops.consistency_violations().is_empty());
    }

    #[test]
    fn edge_columns_total_and_validate() {
        let mut r = Recorder::new();
        r.push(RoundRecord {
            edge_count: 2,
            edge_uplink_bytes: 300,
            edge_downlink_bytes: 200,
            edge_backhaul_s: 0.5,
            codec_ratio: 1.0,
            ..Default::default()
        });
        r.push(RoundRecord { codec_ratio: 1.0, ..Default::default() });
        assert_eq!(r.total_edge_uplink(), 300);
        assert_eq!(r.total_edge_downlink(), 200);
        let j = r.summary_json();
        assert_eq!(j.get("total_edge_uplink_bytes").unwrap().as_usize(), Some(300));
        assert_eq!(j.get("total_edge_downlink_bytes").unwrap().as_usize(), Some(200));
        let row = r.to_csv().lines().nth(1).unwrap().to_string();
        assert!(
            row.ends_with("2,300,200,0.500000,0.000000,0.000000,0.000000,0"),
            "row {row}"
        );
        // flat rounds must keep the edge columns zero
        assert!(r.rounds[1].consistency_violations().is_empty());
        let phantom = RoundRecord {
            codec_ratio: 1.0,
            edge_uplink_bytes: 10,
            ..Default::default()
        };
        assert!(
            !phantom.consistency_violations().is_empty(),
            "edge bytes with edge_count 0 must be flagged"
        );
        let bad_backhaul = RoundRecord {
            codec_ratio: 1.0,
            edge_count: 1,
            edge_backhaul_s: f64::NAN,
            ..Default::default()
        };
        assert!(!bad_backhaul.consistency_violations().is_empty());
    }

    #[test]
    fn rate_columns_total_and_validate() {
        let mut r = Recorder::new();
        r.push(RoundRecord {
            codec_ratio: 1.0,
            rate_mean: 0.08,
            rate_min: 0.05,
            rate_max: 0.1,
            coding_downshifts: 3,
            ..Default::default()
        });
        r.push(RoundRecord {
            codec_ratio: 1.0,
            rate_mean: 0.1,
            rate_min: 0.1,
            rate_max: 0.1,
            coding_downshifts: 1,
            ..Default::default()
        });
        assert_eq!(r.total_coding_downshifts(), 4);
        assert!((r.mean_effective_rate() - 0.09).abs() < 1e-12);
        let j = r.summary_json();
        assert_eq!(j.get("total_coding_downshifts").unwrap().as_usize(), Some(4));
        assert!((j.get("mean_effective_rate").unwrap().as_f64().unwrap() - 0.09).abs() < 1e-12);
        assert!(r.rounds[0].consistency_violations().is_empty());
        // a min above the mean (or a rate outside [0, 1]) is flagged
        let bad = RoundRecord {
            codec_ratio: 1.0,
            rate_mean: 0.05,
            rate_min: 0.2,
            rate_max: 0.3,
            ..Default::default()
        };
        assert!(!bad.consistency_violations().is_empty());
        let oob = RoundRecord {
            codec_ratio: 1.0,
            rate_mean: 1.2,
            rate_min: 1.1,
            rate_max: 1.3,
            ..Default::default()
        };
        assert!(!oob.consistency_violations().is_empty());
    }

    #[test]
    fn precodec_totals_and_ratio() {
        let mut r = Recorder::new();
        assert_eq!(r.overall_codec_ratio(), 1.0, "no traffic → ratio 1");
        r.push(RoundRecord {
            uplink_bytes: 60,
            downlink_bytes: 40,
            precodec_bytes: 250,
            codec_ratio: 2.5,
            ..Default::default()
        });
        r.push(RoundRecord {
            uplink_bytes: 100,
            precodec_bytes: 100,
            codec_ratio: 1.0,
            ..Default::default()
        });
        assert_eq!(r.total_precodec_bytes(), 350);
        assert!((r.overall_codec_ratio() - 1.75).abs() < 1e-12);
        let j = r.summary_json();
        assert_eq!(j.get("total_precodec_bytes").unwrap().as_usize(), Some(350));
        assert_eq!(j.get("overall_codec_ratio").unwrap().as_f64(), Some(1.75));
    }
}
