//! Round-event ledger hooks — the observation surface behind `fedgmf
//! verify`'s invariant checks.
//!
//! The conformance harness (`crate::testkit`) needs to see, per round, what
//! the coordinator's reductions actually did: which decoded upload met
//! which fate, and what aggregate the server produced from how many
//! contributors. Threading that state out of `FlRun` piecemeal would either
//! expose private scratch buffers or force every caller to re-derive fates
//! from the recorder. Instead the round loop carries an optional
//! [`RoundLedger`]: when installed (`FlRun::ledger`), the loop calls the
//! hooks at the deterministic reduction points; when absent (the default,
//! and every production path) the only cost is a branch on a `None` — no
//! allocation, no virtual call, no observable behaviour change.
//!
//! Hooks fire on the coordinator thread only, in deterministic participant
//! order, so a ledger sees the same event stream at every worker count —
//! which is exactly what lets the testkit assert cross-worker digest
//! equality and per-coordinate mass conservation from one implementation.

use crate::sim::scheduler::ClientFate;
use crate::sparse::vector::SparseVec;
use std::any::Any;

/// Observer of one FL run's per-round reduction events.
///
/// All hooks default to no-ops so a ledger implements only what it audits.
/// `into_any` is the retrieval path: after the run, the owner takes the
/// boxed ledger back out of `FlRun::ledger` and downcasts it to read the
/// accumulated state.
pub trait RoundLedger: Any {
    /// A communication round opened (after the stale-queue rotation,
    /// before any upload event of that round).
    fn begin_round(&mut self, _round: usize) {}

    /// One selected participant's fate was decided. `echo` is the decoded
    /// upload exactly as the server would aggregate it (post wire
    /// round-trip — under a lossy codec this is the in-flight mass, not
    /// the pre-quantisation upload). `Offline` clients never transmitted;
    /// their `echo` is reported for completeness but no byte of it crossed
    /// the wire.
    fn on_upload(
        &mut self,
        _client: usize,
        _fate: ClientFate,
        _echo: &SparseVec,
        _wire_bytes: usize,
        _precodec_bytes: usize,
    ) {
    }

    /// The server closed the round: `aggregate` is the round aggregate
    /// Ĝ_t *before* the downlink codec (under the server-momentum
    /// broadcast policy this is Ĝ_t, not the momentum payload), and
    /// `contributors` is the mean's denominator — fresh accepted uploads
    /// plus carried-in stale uploads.
    fn on_aggregate(&mut self, _aggregate: &SparseVec, _contributors: usize) {}

    /// Recover the concrete ledger after the run.
    fn into_any(self: Box<Self>) -> Box<dyn Any>;
}
