//! # fedgmf
//!
//! A federated-learning framework with **Global Momentum Fusion** gradient
//! compression — a full reproduction of Kuo, Kuo & Lin, *"Improving
//! Federated Learning Communication Efficiency with Global Momentum Fusion
//! for Gradient Compression Schemes"* (2022).
//!
//! Three layers (see DESIGN.md):
//! * L3 (this crate): FL coordinator, compression policies, sparse
//!   transport, network simulation, experiment harness.
//! * L2: JAX models AOT-lowered to HLO artifacts (`python/compile/`).
//! * L1: Pallas kernels specifying the compression hot path.

// Index-based loops mirror the L1 kernel specifications one-to-one and are
// kept for auditability against the Pallas sources; default-then-override is
// the config layer's idiom for schedule rebinding.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod testkit;
pub mod transport;
pub mod util;
