//! The FL coordinator: hub-and-spoke round protocol (paper Fig. 1 + Alg. 1).
//!
//! * [`client::FlClient`] — local trainer + compressor state.
//! * [`store::ClientStore`] — fleet state at rest: dense per-client
//!   buffers, or sparse records materialized into pooled scratch for the
//!   sampled cohort only (million-client fleets in bounded memory).
//! * [`server::FlServer`] — sparse aggregation + broadcast policy (plain
//!   aggregate vs server-side global momentum, the DGCwGM half).
//! * [`hierarchy`] — optional two-tier topology: edge aggregators pre-merge
//!   cohort uploads before the hub (backhaul traffic accounting).
//! * [`traffic::TrafficMeter`] — byte-exact accounting of both overhead
//!   terms of §2.1 (client uploads, server broadcast).
//! * [`round::FlRun`] — the synchronous round loop tying it all together.
//! * [`sampler`] — client participation policies.
//! * [`service`] — the same round loop replayed over a
//!   [`crate::transport::Transport`] (in-process or socket fleet).

pub mod client;
pub mod hierarchy;
pub mod round;
pub mod sampler;
pub mod server;
pub mod service;
pub mod store;
pub mod traffic;

pub use round::{FlConfig, FlRun, RunSummary};
pub use server::BroadcastPolicy;
pub use store::{ClientStore, StoreMode};

use crate::sparse::vector::SparseVec;
use crate::sparse::wire;

/// Decode a broadcast frame into `out`, mapping wire errors into the one
/// shared diagnostic both round loops (simulator and service) report. A
/// corrupt broadcast is a protocol bug, never a recoverable condition, so
/// the two call sites must fail identically.
pub(crate) fn decode_broadcast(buf: &[u8], out: &mut SparseVec) -> anyhow::Result<()> {
    wire::decode_into(buf, out).map_err(|e| anyhow::anyhow!("broadcast decode failed: {e:?}"))
}
