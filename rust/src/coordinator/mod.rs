//! The FL coordinator: hub-and-spoke round protocol (paper Fig. 1 + Alg. 1).
//!
//! * [`client::FlClient`] — local trainer + compressor state.
//! * [`server::FlServer`] — sparse aggregation + broadcast policy (plain
//!   aggregate vs server-side global momentum, the DGCwGM half).
//! * [`traffic::TrafficMeter`] — byte-exact accounting of both overhead
//!   terms of §2.1 (client uploads, server broadcast).
//! * [`round::FlRun`] — the synchronous round loop tying it all together.
//! * [`sampler`] — client participation policies.
//! * [`service`] — the same round loop replayed over a
//!   [`crate::transport::Transport`] (in-process or socket fleet).

pub mod client;
pub mod round;
pub mod sampler;
pub mod server;
pub mod service;
pub mod traffic;

pub use round::{FlConfig, FlRun, RunSummary};
pub use server::BroadcastPolicy;
