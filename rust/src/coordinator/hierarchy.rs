//! Two-tier edge aggregation: the topology escape hatch from the paper's
//! hub-and-spoke bottleneck.
//!
//! RingFed-style pre-aggregation (PAPERS.md): the round's accepted cohort is
//! partitioned into contiguous groups, each served by an **edge aggregator**
//! that merges its members' uploads with the existing [`Aggregator`]
//! machinery and forwards ONE merged frame to the hub over the backhaul.
//!
//! ## Bit-identity contract (`tiers = 1` ≡ `tiers = 2`, byte for byte)
//!
//! The hub's numerics never change: it still folds the individual member
//! uploads in accepted-participant order, exactly as the flat fleet does —
//! edges are *contiguous slices of that same order*, so re-associating the
//! fold at the edge boundary would be the only way to change the result,
//! and we deliberately don't. The edge merge is computed for what the wire
//! actually carries (tier-1 backhaul bytes, support union), not for what
//! the hub adds up. Consequence: trajectory digests are identical across
//! tier counts, and the `tiers` axis in `fedgmf verify` cross-checks that
//! every run.
//!
//! ## What tier 2 buys
//!
//! The hub's ingress drops from `cohort` frames to `edges` frames, and the
//! backhaul frame's support is the *union* of member supports — overlapping
//! coordinates are carried once instead of once per member. GMF's raised
//! mask overlap (the paper's whole point) therefore compounds here: the
//! more the member masks agree, the smaller the union and the cheaper the
//! backhaul. `edge_uplink_bytes / Σ member_bytes` in the round records
//! measures exactly that.

use std::ops::Range;

use crate::sparse::codec::CodecParams;
use crate::sparse::merge::Aggregator;
use crate::sparse::vector::SparseVec;
use crate::sparse::wire;

/// `[hierarchy]` config: fleet topology between clients and the hub.
#[derive(Clone, Debug)]
pub struct HierarchyConfig {
    /// Aggregation tiers. `1` = the paper's flat hub-and-spoke (default);
    /// `2` = edge aggregators pre-merge cohort uploads before the hub.
    pub tiers: usize,
    /// How many cohort members each edge aggregator serves (tier 2 only).
    /// The accepted cohort is split into contiguous groups of this size in
    /// participant order; the last edge takes the remainder.
    pub cohorts_per_edge: usize,
    /// Edge → hub backhaul bandwidth (bits/s), for the non-digested
    /// backhaul-time diagnostic.
    pub edge_uplink_bps: f64,
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        HierarchyConfig { tiers: 1, cohorts_per_edge: 32, edge_uplink_bps: 1e8 }
    }
}

impl HierarchyConfig {
    /// Whether an edge tier sits between clients and the hub.
    pub fn enabled(&self) -> bool {
        self.tiers >= 2
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if !(1..=2).contains(&self.tiers) {
            anyhow::bail!("hierarchy.tiers must be 1 (flat) or 2 (edge tier), got {}", self.tiers);
        }
        if self.cohorts_per_edge == 0 {
            anyhow::bail!("hierarchy.cohorts_per_edge must be >= 1");
        }
        if !(self.edge_uplink_bps > 0.0) {
            anyhow::bail!("hierarchy.edge_uplink_bps must be > 0");
        }
        Ok(())
    }
}

/// Partition `accepted` cohort members (already in participant order) into
/// contiguous per-edge ranges of at most `per_edge` members. Contiguity is
/// the bit-identity guarantee: concatenating the ranges reproduces the flat
/// fold order exactly.
pub fn plan_edges(accepted: usize, per_edge: usize) -> Vec<Range<usize>> {
    assert!(per_edge >= 1, "per_edge must be >= 1");
    let mut edges = Vec::with_capacity(accepted.div_ceil(per_edge));
    let mut lo = 0;
    while lo < accepted {
        let hi = (lo + per_edge).min(accepted);
        edges.push(lo..hi);
        lo = hi;
    }
    edges
}

/// One round's tier-1 (edge → hub) traffic, summed over all edges.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EdgeRoundStats {
    /// Edge aggregators active this round (0 when the cohort is empty).
    pub edges: usize,
    /// Backhaul bytes actually on the wire (merged frames, uplink codec).
    pub uplink_bytes: usize,
    /// The same frames costed at the v1 baseline codec (compression-ratio
    /// denominator, mirroring the per-client `precodec_bytes`).
    pub precodec_bytes: usize,
}

/// Reusable edge-merge scratch: one [`Aggregator`] + frame + wire buffer,
/// shared by every edge in a round (edges run sequentially in the
/// simulator; only their *traffic* is modelled as parallel hardware).
pub struct EdgeMerger {
    agg: Aggregator,
    frame: SparseVec,
    wire_buf: Vec<u8>,
}

impl EdgeMerger {
    pub fn new(dim: usize) -> Self {
        EdgeMerger { agg: Aggregator::new(dim), frame: SparseVec::empty(dim), wire_buf: Vec::new() }
    }

    /// Merge one edge's member uploads (a contiguous slice of the accepted
    /// cohort, in participant order) into a single backhaul frame and
    /// return its wire cost under `codec`. The merged frame is the SUM of
    /// member uploads over their support union — the hub re-folds the
    /// members itself for numerics, so this frame only prices the wire.
    pub fn merge(&mut self, members: &[&SparseVec], codec: CodecParams) -> EdgeRoundStats {
        if members.is_empty() {
            return EdgeRoundStats::default();
        }
        self.agg.add(members, 1.0, 1);
        // count = 1: emit the raw sum, not the mean — the backhaul carries
        // un-normalised mass and the hub normalises once, globally
        self.agg.finish_into(1, &mut self.frame, 1);
        wire::encode_with(&self.frame, &mut self.wire_buf, codec);
        EdgeRoundStats {
            edges: 1,
            uplink_bytes: self.wire_buf.len(),
            precodec_bytes: wire::encoded_bytes(&self.frame),
        }
    }

    /// The last merged frame (support union of the edge's members).
    pub fn frame(&self) -> &SparseVec {
        &self.frame
    }
}

impl EdgeRoundStats {
    /// Accumulate another edge's stats into this round total.
    pub fn absorb(&mut self, other: EdgeRoundStats) {
        self.edges += other.edges;
        self.uplink_bytes += other.uplink_bytes;
        self.precodec_bytes += other.precodec_bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_flat_and_valid() {
        let h = HierarchyConfig::default();
        assert!(!h.enabled());
        h.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        assert!(HierarchyConfig { tiers: 0, ..Default::default() }.validate().is_err());
        assert!(HierarchyConfig { tiers: 3, ..Default::default() }.validate().is_err());
        assert!(
            HierarchyConfig { cohorts_per_edge: 0, ..Default::default() }.validate().is_err()
        );
        assert!(
            HierarchyConfig { edge_uplink_bps: 0.0, ..Default::default() }.validate().is_err()
        );
        HierarchyConfig { tiers: 2, ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn edges_partition_the_cohort_contiguously() {
        let edges = plan_edges(10, 4);
        assert_eq!(edges, vec![0..4, 4..8, 8..10]);
        // concatenation reproduces the flat participant order exactly
        let flat: Vec<usize> = edges.iter().flat_map(|r| r.clone()).collect();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
        assert!(plan_edges(0, 4).is_empty());
        assert_eq!(plan_edges(3, 8), vec![0..3]);
    }

    #[test]
    fn merged_frame_is_support_union_sum() {
        let dim = 8;
        let a = SparseVec::new(dim, vec![(1, 2.0), (3, 1.0)]);
        let b = SparseVec::new(dim, vec![(3, 1.0), (6, -4.0)]);
        let mut m = EdgeMerger::new(dim);
        let stats = m.merge(&[&a, &b], CodecParams::default());
        assert_eq!(m.frame().indices, vec![1, 3, 6]);
        assert_eq!(m.frame().values, vec![2.0, 2.0, -4.0], "sum, not mean");
        assert_eq!(stats.edges, 1);
        assert!(stats.uplink_bytes > 0);
    }

    #[test]
    fn union_support_makes_backhaul_cheaper_than_member_frames() {
        // perfectly overlapping masks: two member frames cost ~2x the
        // single merged frame — the GMF-compounding effect in miniature
        let dim = 64;
        let a = SparseVec::new(dim, (0..16).map(|i| (i, 1.0)).collect());
        let b = SparseVec::new(dim, (0..16).map(|i| (i, 2.0)).collect());
        let member_bytes = wire::encode(&a).len() + wire::encode(&b).len();
        let mut m = EdgeMerger::new(dim);
        let stats = m.merge(&[&a, &b], CodecParams::default());
        assert!(
            stats.uplink_bytes < member_bytes,
            "backhaul {} must undercut member total {member_bytes}",
            stats.uplink_bytes
        );
    }

    #[test]
    fn merger_resets_between_edges() {
        let dim = 8;
        let mut m = EdgeMerger::new(dim);
        let _ = m.merge(&[&SparseVec::new(dim, vec![(0, 5.0)])], CodecParams::default());
        let _ = m.merge(&[&SparseVec::new(dim, vec![(7, 1.0)])], CodecParams::default());
        assert_eq!(m.frame().indices, vec![7], "previous edge's mass must not leak");
        assert_eq!(m.frame().values, vec![1.0]);
    }

    #[test]
    fn round_stats_absorb_sums_fields() {
        let mut total = EdgeRoundStats::default();
        total.absorb(EdgeRoundStats { edges: 1, uplink_bytes: 100, precodec_bytes: 120 });
        total.absorb(EdgeRoundStats { edges: 1, uplink_bytes: 50, precodec_bytes: 60 });
        assert_eq!(
            total,
            EdgeRoundStats { edges: 2, uplink_bytes: 150, precodec_bytes: 180 }
        );
    }
}
