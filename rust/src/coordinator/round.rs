//! The synchronous FL round loop (paper Algorithm 1, all four schemes).
//!
//! One `FlRun` owns the global model, the clients, the server, the traffic
//! meter and the network simulator, and drives `rounds` communication
//! rounds, recording everything the experiment harness needs.

use super::client::FlClient;
use super::sampler::Sampler;
use super::server::{BroadcastPolicy, FlServer};
use super::traffic::{TrafficMeter, TrafficPolicy};
use crate::compress::{self, CompressConfig, CompressorKind, SparsityWarmup};
use crate::data::dataset::{Batch, Dataset};
use crate::metrics::recorder::{Recorder, RoundRecord};
use crate::runtime::{evaluate, TrainEngine};
use crate::sim::network::Network;
use crate::sparse::merge::mean_pairwise_jaccard;
use crate::sparse::vector::SparseVec;
use crate::sparse::wire;
use crate::util::rng::Rng;
use std::time::Instant;

/// Learning-rate schedule: base lr with multiplicative milestones.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub base: f32,
    /// (round, factor): from `round` on, lr *= factor (applied cumulatively)
    pub milestones: Vec<(usize, f32)>,
}

impl LrSchedule {
    pub fn constant(base: f32) -> Self {
        LrSchedule { base, milestones: Vec::new() }
    }

    /// Paper-style: decay at 50% and 75% of training by 10×.
    pub fn step_at_halves(base: f32, total_rounds: usize) -> Self {
        LrSchedule {
            base,
            milestones: vec![(total_rounds / 2, 0.1), (total_rounds * 3 / 4, 0.1)],
        }
    }

    pub fn at(&self, round: usize) -> f32 {
        let mut lr = self.base;
        for &(r, f) in &self.milestones {
            if round >= r {
                lr *= f;
            }
        }
        lr
    }
}

/// Full configuration of one FL training run.
#[derive(Clone, Debug)]
pub struct FlConfig {
    pub kind: CompressorKind,
    pub compress: CompressConfig,
    pub rounds: usize,
    pub batch_size: usize,
    /// minibatches averaged into the local gradient each round
    pub local_steps: usize,
    pub lr: LrSchedule,
    pub warmup: SparsityWarmup,
    pub sampler: Sampler,
    pub traffic: TrafficPolicy,
    /// evaluate every N rounds (and always on the last round); 0 = last only
    pub eval_every: usize,
    pub seed: u64,
}

impl FlConfig {
    /// Sensible defaults for a given technique / compression rate / length.
    pub fn new(kind: CompressorKind, rate: f64, rounds: usize) -> Self {
        let mut compress = CompressConfig::default();
        compress.tau = crate::compress::TauSchedule::paper(rounds);
        FlConfig {
            kind,
            compress,
            rounds,
            batch_size: 32,
            local_steps: 1,
            lr: LrSchedule::step_at_halves(0.1, rounds),
            warmup: SparsityWarmup { rate, warmup_rounds: (rounds / 20).min(8) },
            sampler: Sampler::Full,
            traffic: TrafficPolicy::default(),
            eval_every: 10,
            seed: 42,
        }
    }
}

/// Outcome of a run: the recorder plus headline numbers.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub technique: &'static str,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub final_loss: f64,
    pub total_traffic_gb: f64,
    pub uplink_gb: f64,
    pub downlink_gb: f64,
    pub sim_seconds: f64,
    pub mean_mask_overlap: f64,
    pub recorder: Recorder,
}

/// One federated training run.
pub struct FlRun {
    pub cfg: FlConfig,
    pub params: Vec<f32>,
    pub clients: Vec<FlClient>,
    pub server: FlServer,
    pub meter: TrafficMeter,
    pub network: Network,
    pub recorder: Recorder,
    test_batches: Vec<Batch>,
    last_payload: SparseVec,
}

impl FlRun {
    /// Build a run: one shard per client. The engine is passed per-call so
    /// several runs can share one compiled artifact set.
    pub fn new(
        engine: &dyn TrainEngine,
        shards: Vec<Box<dyn Dataset + Send>>,
        test_batches: Vec<Batch>,
        network: Network,
        cfg: FlConfig,
    ) -> Self {
        let dim = engine.param_count();
        let root = Rng::new(cfg.seed);
        let clients: Vec<FlClient> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                FlClient::new(id, compress::build(cfg.kind, &cfg.compress, dim), shard, &root)
            })
            .collect();
        let policy = if cfg.kind.server_momentum() {
            BroadcastPolicy::ServerMomentum { beta: cfg.compress.beta }
        } else {
            BroadcastPolicy::Aggregate
        };
        FlRun {
            params: engine.initial_params(),
            server: FlServer::new(dim, policy),
            meter: TrafficMeter::new(cfg.traffic),
            network,
            recorder: Recorder::new(),
            clients,
            test_batches,
            last_payload: SparseVec::empty(dim),
            cfg,
        }
    }

    /// Execute one communication round; returns the round record.
    pub fn step_round(
        &mut self,
        engine: &mut dyn TrainEngine,
        round: usize,
    ) -> anyhow::Result<RoundRecord> {
        let wall = Instant::now();
        self.meter.begin_round();
        let root = Rng::new(self.cfg.seed);
        let participants = self.cfg.sampler.sample(self.clients.len(), round, &root);
        let dim = self.params.len();
        let k = self.cfg.warmup.k_at(dim, round);

        // 1. broadcast of the previous round reaches everyone (Alg.1 l.14+8)
        if round > 0 {
            for c in self.clients.iter_mut() {
                c.observe_broadcast(&self.last_payload);
            }
        }

        // 2. local training + compression + upload
        let mut train_loss = 0.0;
        let mut grads: Vec<SparseVec> = Vec::with_capacity(participants.len());
        for &cid in &participants {
            let client = &mut self.clients[cid];
            let (compressed, loss, _corr, _seen) = client.local_round(
                engine,
                &self.params,
                self.cfg.batch_size,
                self.cfg.local_steps,
                k,
                round,
            )?;
            train_loss += loss;
            // the gradient actually crosses the wire
            let buf = wire::encode(&compressed.gradient);
            self.meter.record_uplink(cid, buf.len());
            let decoded = wire::decode(&buf).expect("self-encoded gradient must decode");
            self.server.receive(&decoded);
            grads.push(decoded);
        }
        train_loss /= participants.len().max(1) as f64;

        // 3. aggregate + broadcast
        let (payload, _ghat) = self.server.finish_round(participants.len());
        let bcast_buf = wire::encode(&payload);
        self.meter.record_broadcast(bcast_buf.len(), participants.len());
        let payload = wire::decode(&bcast_buf).expect("broadcast must decode");

        // 4. synchronized model update (Alg. 1 line 15)
        let lr = self.cfg.lr.at(round);
        payload.add_into(&mut self.params, -lr);
        self.last_payload = payload;

        // 5. diagnostics + eval
        let refs: Vec<&SparseVec> = grads.iter().collect();
        let overlap = mean_pairwise_jaccard(&refs);
        let sim_s = self.network.uplink_time(&self.meter.round_uplinks)
            + self.network.broadcast_time(bcast_buf.len(), &participants);

        let is_last = round + 1 == self.cfg.rounds;
        let do_eval = is_last
            || (self.cfg.eval_every > 0 && round % self.cfg.eval_every == self.cfg.eval_every - 1);
        let (test_loss, test_acc) = if do_eval && !self.test_batches.is_empty() {
            evaluate(engine, &self.params, &self.test_batches)?
        } else {
            (0.0, 0.0)
        };

        let rec = RoundRecord {
            round,
            train_loss,
            test_loss,
            test_accuracy: test_acc,
            uplink_bytes: self.meter.round_uplink,
            downlink_bytes: self.meter.round_downlink,
            aggregate_nnz: self.last_payload.nnz(),
            mask_overlap: overlap,
            sim_seconds: sim_s,
            wall_seconds: wall.elapsed().as_secs_f64(),
        };
        self.recorder.push(rec.clone());
        Ok(rec)
    }

    /// Drive the full configured number of rounds.
    pub fn run(&mut self, engine: &mut dyn TrainEngine) -> anyhow::Result<RunSummary> {
        for round in 0..self.cfg.rounds {
            self.step_round(engine, round)?;
        }
        Ok(self.summary())
    }

    pub fn summary(&self) -> RunSummary {
        let overlaps: Vec<f64> = self.recorder.rounds.iter().map(|r| r.mask_overlap).collect();
        RunSummary {
            technique: self.cfg.kind.name(),
            final_accuracy: self.recorder.final_accuracy(),
            best_accuracy: self.recorder.best_accuracy(),
            final_loss: self
                .recorder
                .rounds
                .last()
                .map(|r| if r.test_loss > 0.0 { r.test_loss } else { r.train_loss })
                .unwrap_or(0.0),
            total_traffic_gb: self.meter.total_gb(),
            uplink_gb: self.meter.total_uplink as f64 / 1e9,
            downlink_gb: self.meter.total_downlink as f64 / 1e9,
            sim_seconds: self.recorder.total_sim_seconds(),
            mean_mask_overlap: crate::util::math::mean(&overlaps),
            recorder: self.recorder.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::{BlobDataset, NativeEngine};

    fn blob_shards(
        clients: usize,
        per_client: usize,
        dim: usize,
        classes: usize,
        seed: u64,
    ) -> (Vec<Box<dyn Dataset + Send>>, Vec<Batch>) {
        let mut shards: Vec<Box<dyn Dataset + Send>> = Vec::new();
        for c in 0..clients {
            // shared centers (same task), disjoint noise per client shard
            shards.push(Box::new(BlobDataset::generate_split(
                per_client, dim, classes, 0.4, seed, seed + 1 + c as u64,
            )));
        }
        let test = BlobDataset::generate_split(128, dim, classes, 0.4, seed, seed ^ 0x7E57);
        let batches = test.eval_batches(32);
        (shards, batches)
    }

    fn quick_cfg(kind: CompressorKind) -> FlConfig {
        let mut cfg = FlConfig::new(kind, 0.1, 30);
        cfg.lr = LrSchedule::constant(0.5);
        cfg.eval_every = 5;
        cfg
    }

    #[test]
    fn dgc_run_converges_on_blobs() {
        let mut engine = NativeEngine::new(8, 12, 4, 1);
        let (shards, test) = blob_shards(4, 80, 8, 4, 10);
        let net = Network::uniform(4, Default::default());
        let mut run = FlRun::new(&engine, shards, test, net, quick_cfg(CompressorKind::Dgc));
        let summary = run.run(&mut engine).unwrap();
        assert!(summary.final_accuracy > 0.8, "acc {}", summary.final_accuracy);
        assert!(summary.total_traffic_gb > 0.0);
    }

    #[test]
    fn all_four_schemes_run_and_report() {
        for kind in CompressorKind::ALL {
            let mut engine = NativeEngine::new(8, 10, 3, 2);
            let (shards, test) = blob_shards(3, 60, 8, 3, 20);
            let net = Network::uniform(3, Default::default());
            let mut run = FlRun::new(&engine, shards, test, net, quick_cfg(kind));
            let summary = run.run(&mut engine).unwrap();
            assert_eq!(summary.technique, kind.name());
            assert!(summary.final_accuracy > 0.5, "{}: acc {}", kind.name(), summary.final_accuracy);
        }
    }

    #[test]
    fn dgcwgm_downlink_exceeds_dgc() {
        // paper §2.1: server momentum accumulates support → larger downlink
        let run_kind = |kind: CompressorKind| -> (f64, f64) {
            let mut engine = NativeEngine::new(8, 10, 3, 3);
            let (shards, test) = blob_shards(4, 60, 8, 3, 30);
            let net = Network::uniform(4, Default::default());
            let mut run = FlRun::new(&engine, shards, test, net, quick_cfg(kind));
            let s = run.run(&mut engine).unwrap();
            (s.downlink_gb, s.uplink_gb)
        };
        let (down_dgc, up_dgc) = run_kind(CompressorKind::Dgc);
        let (down_gm, up_gm) = run_kind(CompressorKind::DgcWgm);
        assert!(down_gm > down_dgc, "GM downlink {down_gm} vs DGC {down_dgc}");
        assert!((up_gm - up_dgc).abs() / up_dgc < 0.05, "uplinks comparable");
    }

    #[test]
    fn lr_schedule_milestones() {
        let lr = LrSchedule::step_at_halves(0.1, 100);
        assert_eq!(lr.at(0), 0.1);
        assert!((lr.at(50) - 0.01).abs() < 1e-7);
        assert!((lr.at(75) - 0.001).abs() < 1e-8);
    }

    #[test]
    fn traffic_recorded_every_round() {
        let mut engine = NativeEngine::new(6, 8, 3, 4);
        let (shards, test) = blob_shards(3, 40, 6, 3, 40);
        let net = Network::uniform(3, Default::default());
        let mut cfg = quick_cfg(CompressorKind::DgcWgmf);
        cfg.rounds = 5;
        let mut run = FlRun::new(&engine, shards, test, net, cfg);
        let summary = run.run(&mut engine).unwrap();
        assert_eq!(summary.recorder.rounds.len(), 5);
        for r in &summary.recorder.rounds {
            assert!(r.uplink_bytes > 0);
            assert!(r.downlink_bytes > 0);
            assert!(r.sim_seconds > 0.0);
        }
    }
}
