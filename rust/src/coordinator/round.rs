//! The synchronous FL round loop (paper Algorithm 1, all four schemes).
//!
//! One `FlRun` owns the global model, the clients, the server, the traffic
//! meter and the network simulator, and drives `rounds` communication
//! rounds, recording everything the experiment harness needs.
//!
//! ## Parallel execution
//!
//! Client work — broadcast observation, local training, compression, wire
//! encode/decode — is embarrassingly parallel: every piece of mutable state
//! it touches is per-client. `step_round` therefore fans it out over up to
//! [`FlConfig::workers`] threads (`std::thread::scope`, one
//! [`TrainEngine::spawn_worker`] instance per extra thread), while every
//! order-sensitive reduction — the f64 loss sum, traffic metering, the f32
//! server merge — runs in deterministic participant order. Results are
//! **bit-identical** at any worker count (asserted by
//! `tests/determinism.rs`).
//!
//! ## Steady-state allocation
//!
//! All round-sized buffers (client gradient accumulators, compression
//! outputs, wire encode/decode buffers, the server aggregate and broadcast)
//! are persistent and reused round over round: once warm, the round loop
//! performs no heap allocation on those paths.
//!
//! ## Time-domain scheduling
//!
//! When the [`SimConfig`] knobs are active the round runs under a simulated
//! clock: the sampler over-provisions the cohort, every selected client's
//! finish time is `compute_time + uplink_time` from its
//! [`crate::sim::scheduler::ClientProfile`], uploads past `sim.deadline_s`
//! are discarded (the client's residual is restored so error feedback
//! survives — see [`crate::compress::Compressor::restore_upload`]), and
//! hard dropouts are injected per round from the run RNG. With the default
//! (inert) `SimConfig` every step below reduces bit-exactly to the PR 1
//! behaviour; `tests/determinism.rs` pins both directions.
//!
//! ## Semi-synchronous aggregation
//!
//! `sim.staleness` decides what a deadline miss costs. Under `drop`
//! (default) the late upload is discarded and the client residual restored
//! — bit-identical to the scheduler-only behaviour. Under
//! `carry`/`carry_discounted(α)` the late upload is buffered in the
//! server-side [`StaleQueue`] and folded into the *next* round's aggregate
//! with weight α (fresh uploads first, then stale, in deterministic
//! order), while the client residual gets the unapplied `1 − α` back — so
//! no transmitted byte is wasted and no gradient mass is lost.
//! `sim.selection = feasibility(β)` additionally biases the cohort draw
//! toward clients whose delivery history and uplink spend make them good
//! picks, under a `1 − β` fairness floor; the per-round `traffic_gini`
//! column tracks how evenly the uplink bill stays spread. Both knobs keep
//! the run bit-identical across worker counts.
//!
//! ## Wire codec
//!
//! Every buffer that crosses a link goes through [`FlConfig::codec`]
//! (TOML `[codec]`): uploads through the uplink params inside
//! `FlClient::local_round`, the broadcast through the downlink params
//! here. The default (raw u32 + f32) emits v1 bytes exactly; varint/f16/q8
//! codings shrink the wire, with lossy quantisation error folded into the
//! client residual so error feedback absorbs it. The meter keeps a
//! pre-codec (v1-equivalent) ledger alongside the actual bytes, surfacing
//! per-round `precodec_bytes` and `codec_ratio` columns.

use super::client::FlClient;
use super::hierarchy::{plan_edges, EdgeMerger, EdgeRoundStats, HierarchyConfig};
use super::sampler::{feasibility_weights, Sampler, SelectionHistory};
use super::server::{BroadcastPolicy, FlServer, IngestOpts, UploadSource};
use super::store::{ClientStore, DenseStore, StoreMode, VirtualStore};
use super::traffic::{TrafficMeter, TrafficPolicy};
use crate::compress::{
    self, CompressConfig, CompressorKind, HistorySignals, LinkSignals, RateControlConfig,
    RateDecision, SparsityWarmup,
};
use crate::data::dataset::{Batch, Dataset};
use crate::metrics::ledger::RoundLedger;
use crate::metrics::recorder::{Recorder, RoundRecord};
use crate::runtime::{evaluate_with_pool, TrainEngine};
use crate::sim::network::Network;
use crate::sim::scheduler::{uplink_close, ClientFate, Scheduler, SelectionPolicy, SimConfig};
use crate::sim::staleness::StaleQueue;
use crate::transport::fault::{FaultKind, FaultPlan, DELAY_S};
use crate::sparse::codec::WireCodec;
use crate::sparse::merge::{mean_jaccard_estimate, mean_pairwise_jaccard};
use crate::sparse::stream::Runs;
use crate::sparse::vector::SparseVec;
use crate::sparse::wire;
use crate::util::rng::Rng;
use std::time::Instant;

/// Resolve a configured worker count: 0 = one per available core.
pub(crate) fn resolve_pool(workers: usize) -> usize {
    if workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        workers
    }
}

/// Learning-rate schedule: base lr with multiplicative milestones.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub base: f32,
    /// (round, factor): from `round` on, lr *= factor (applied cumulatively)
    pub milestones: Vec<(usize, f32)>,
}

impl LrSchedule {
    pub fn constant(base: f32) -> Self {
        LrSchedule { base, milestones: Vec::new() }
    }

    /// Paper-style: decay at 50% and 75% of training by 10×.
    pub fn step_at_halves(base: f32, total_rounds: usize) -> Self {
        LrSchedule {
            base,
            milestones: vec![(total_rounds / 2, 0.1), (total_rounds * 3 / 4, 0.1)],
        }
    }

    pub fn at(&self, round: usize) -> f32 {
        let mut lr = self.base;
        for &(r, f) in &self.milestones {
            if round >= r {
                lr *= f;
            }
        }
        lr
    }
}

/// Full configuration of one FL training run.
#[derive(Clone, Debug)]
pub struct FlConfig {
    pub kind: CompressorKind,
    pub compress: CompressConfig,
    pub rounds: usize,
    pub batch_size: usize,
    /// minibatches averaged into the local gradient each round
    pub local_steps: usize,
    pub lr: LrSchedule,
    pub warmup: SparsityWarmup,
    pub sampler: Sampler,
    pub traffic: TrafficPolicy,
    /// evaluate every N rounds (and always on the last round); 0 = last only
    pub eval_every: usize,
    pub seed: u64,
    /// worker threads for the per-client fan-out: 0 = one per available
    /// core, 1 = sequential. Any setting produces bit-identical results.
    pub workers: usize,
    /// compute the exact O(clients²·nnz) pairwise mask-overlap diagnostic
    /// instead of the O(total-nnz) count-based estimate (analysis runs only
    /// — the exact statistic dominates round cost at large cohorts)
    pub exact_mask_overlap: bool,
    /// fold accepted uploads into the server aggregate straight from their
    /// wire bytes (the codec-v2 pull-decoder) instead of batching decoded
    /// [`SparseVec`]s — server-side ingest scratch becomes independent of
    /// the model dimension. Bit-identical to the materialized path (the
    /// decoder emits the exact pairs `decode_into` would, in the same
    /// order); `false` (the default) keeps the batch merge.
    pub streamed_ingest: bool,
    /// time-domain scheduler knobs (TOML `[sim]`); the default is inert and
    /// keeps the run bit-identical to the schedulerless round loop
    pub sim: SimConfig,
    /// per-direction wire codec (TOML `[codec]`); the default (raw u32 +
    /// f32) produces byte-identical v1 buffers and bit-identical model
    /// trajectories, lossy value codings feed their quantisation error
    /// into client-side error feedback (see `coordinator::client`)
    pub codec: WireCodec,
    /// deterministic chaos plan (`kind:rate[@seed]`, see
    /// `transport::fault`): the simulator applies the same per-(client,
    /// round) fault decisions the service transports inject on the wire, so
    /// a faulted service run stays digest-comparable with the in-process
    /// run. `None` (the default) is bit-identical to the pre-fault loop.
    pub fault: Option<FaultPlan>,
    /// how per-client state is kept (TOML top-level `store`): `Auto` (the
    /// default) picks `Dense` for full-participation samplers and
    /// `Virtual` — sparse at rest, only the cohort materialized — for
    /// sampled fleets. Either choice is bit-identical (see
    /// `coordinator::store`); the knob only trades memory for
    /// checkout/checkin work.
    pub store: StoreMode,
    /// fleet topology between clients and the hub (TOML `[hierarchy]`):
    /// `tiers = 2` inserts edge aggregators that pre-merge cohort uploads.
    /// Trajectory digests are bit-identical across tier counts — the edge
    /// tier only changes what the wire carries (see `coordinator::hierarchy`).
    pub hierarchy: HierarchyConfig,
    /// per-client adaptive rate controller (TOML `[rate_control]`): plans
    /// each participant's effective top-k and uplink value coding per round
    /// from its own capability profile, deadline-hit history and cumulative
    /// uplink spend — inputs a service client mirrors locally, so service
    /// fleets reproduce simulator plans without protocol changes (see
    /// `compress::rate_control`). The default (`off`) never plans and is
    /// bit-identical to the pre-controller loop.
    pub rate_control: RateControlConfig,
}

impl FlConfig {
    /// Sensible defaults for a given technique / compression rate / length.
    pub fn new(kind: CompressorKind, rate: f64, rounds: usize) -> Self {
        let mut compress = CompressConfig::default();
        compress.tau = crate::compress::TauSchedule::paper(rounds);
        FlConfig {
            kind,
            compress,
            rounds,
            batch_size: 32,
            local_steps: 1,
            lr: LrSchedule::step_at_halves(0.1, rounds),
            warmup: SparsityWarmup { rate, warmup_rounds: (rounds / 20).min(8) },
            sampler: Sampler::Full,
            traffic: TrafficPolicy::default(),
            eval_every: 10,
            seed: 42,
            workers: 0,
            exact_mask_overlap: false,
            streamed_ingest: false,
            sim: SimConfig::default(),
            codec: WireCodec::default(),
            fault: None,
            store: StoreMode::Auto,
            hierarchy: HierarchyConfig::default(),
            rate_control: RateControlConfig::default(),
        }
    }
}

/// Outcome of a run: the recorder plus headline numbers.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub technique: &'static str,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub final_loss: f64,
    pub total_traffic_gb: f64,
    pub uplink_gb: f64,
    pub downlink_gb: f64,
    pub sim_seconds: f64,
    pub mean_mask_overlap: f64,
    /// uploads discarded for missing the round deadline (whole run)
    pub dropped_deadline: usize,
    /// uploads lost to hard dropouts (whole run)
    pub dropped_offline: usize,
    /// straggler bytes that crossed the wire but were discarded
    pub wasted_uplink_gb: f64,
    /// late uploads folded into a later round's aggregate (whole run)
    pub carried_total: usize,
    /// wire bytes of those carried uploads
    pub carried_gb: f64,
    /// v1-equivalent bytes of all traffic (pre-codec ledger)
    pub precodec_gb: f64,
    /// pre-codec over post-codec byte ratio (1 under the default codec)
    pub codec_ratio: f64,
    pub recorder: Recorder,
}

/// One federated training run.
pub struct FlRun {
    pub cfg: FlConfig,
    pub params: Vec<f32>,
    /// per-client state keeper: permanently dense, or sparse at rest with a
    /// pooled cohort (see [`StoreMode`] / `coordinator::store`)
    pub store: Box<dyn ClientStore>,
    pub server: FlServer,
    pub meter: TrafficMeter,
    /// per-client capability profiles (built from the constructor's network
    /// + `sim.profile` preset) and the run's simulated round clock
    pub scheduler: Scheduler,
    pub recorder: Recorder,
    test_batches: Vec<Batch>,
    /// broadcast payload before its wire round-trip (reused across rounds)
    payload_scratch: SparseVec,
    /// broadcast wire bytes (reused across rounds)
    bcast_buf: Vec<u8>,
    /// per-participant training losses, reduced in participant order
    loss_scratch: Vec<f64>,
    /// index buffer for the mask-overlap estimator
    overlap_scratch: Vec<u32>,
    /// per-participant wire payload sizes for the scheduler (reused)
    bytes_scratch: Vec<usize>,
    /// per-participant fates for the round (reused)
    fate_scratch: Vec<ClientFate>,
    /// per-participant simulated finish times (reused)
    finish_scratch: Vec<f64>,
    /// accepted participant ids for broadcast timing (reused)
    accepted_scratch: Vec<usize>,
    /// server-side buffer of deadline-missed uploads awaiting the next
    /// round's aggregate (semi-synchronous staleness policies)
    pub stale_queue: StaleQueue,
    /// per-client delivery history feeding feasibility-aware selection
    pub history: SelectionHistory,
    /// feasibility selection weights (reused)
    weight_scratch: Vec<f64>,
    /// per-participant effective top-k for the round (reused; holds the
    /// shared warmup k when the rate controller is off)
    k_scratch: Vec<usize>,
    /// per-participant rate-controller plans (reused; empty when off)
    decision_scratch: Vec<RateDecision>,
    /// Gini sort buffer for the fairness statistic (reused)
    gini_scratch: Vec<f64>,
    /// broadcast payload after its wire round-trip — the exact update every
    /// client applies (public for round-level conservation tests)
    pub last_payload: SparseVec,
    /// worker engine pool, spawned once and reused every round
    worker_engines: Vec<Box<dyn TrainEngine>>,
    /// edge-merge scratch for the two-tier topology (None when flat)
    edge_merger: Option<EdgeMerger>,
    /// optional round-event observer (conformance invariant ledgers — see
    /// `metrics::ledger`); `None` (the default) costs one branch per hook
    /// site and nothing else
    pub ledger: Option<Box<dyn RoundLedger>>,
}

impl FlRun {
    /// Build a run: one shard per client. The engine is passed per-call so
    /// several runs can share one compiled artifact set.
    pub fn new(
        engine: &dyn TrainEngine,
        shards: Vec<Box<dyn Dataset + Send>>,
        test_batches: Vec<Batch>,
        network: Network,
        cfg: FlConfig,
    ) -> Self {
        let dim = engine.param_count();
        let root = Rng::new(cfg.seed);
        let uplink_codec = cfg.codec.uplink;
        let fleet = shards.len();
        // Auto: full participation re-materializes everyone every round, so
        // permanent density is strictly cheaper; sampled fleets virtualize
        let mode = match cfg.store {
            StoreMode::Auto => {
                if matches!(cfg.sampler, Sampler::Full) {
                    StoreMode::Dense
                } else {
                    StoreMode::Virtual
                }
            }
            m => m,
        };
        let store: Box<dyn ClientStore> = match mode {
            StoreMode::Virtual => Box::new(VirtualStore::new(
                shards,
                &root,
                dim,
                cfg.kind,
                &cfg.compress,
                uplink_codec,
            )),
            _ => Box::new(DenseStore::new(
                shards,
                &root,
                dim,
                cfg.kind,
                &cfg.compress,
                uplink_codec,
            )),
        };
        let policy = if cfg.kind.server_momentum() {
            BroadcastPolicy::ServerMomentum { beta: cfg.compress.beta }
        } else {
            BroadcastPolicy::Aggregate
        };
        let scheduler = Scheduler::new(&network, cfg.sim.preset, cfg.seed);
        let history = SelectionHistory::new(fleet);
        FlRun {
            params: engine.initial_params(),
            server: FlServer::new(dim, policy),
            meter: TrafficMeter::new(cfg.traffic),
            scheduler,
            recorder: Recorder::new(),
            store,
            test_batches,
            last_payload: SparseVec::empty(dim),
            payload_scratch: SparseVec::empty(dim),
            bcast_buf: Vec::new(),
            loss_scratch: Vec::new(),
            overlap_scratch: Vec::new(),
            bytes_scratch: Vec::new(),
            fate_scratch: Vec::new(),
            finish_scratch: Vec::new(),
            accepted_scratch: Vec::new(),
            stale_queue: StaleQueue::new(),
            history,
            weight_scratch: Vec::new(),
            k_scratch: Vec::new(),
            decision_scratch: Vec::new(),
            gini_scratch: Vec::new(),
            worker_engines: Vec::new(),
            edge_merger: None,
            ledger: None,
            cfg,
        }
    }

    /// Execute one communication round; returns the round record.
    ///
    /// Bit-identical at every `cfg.workers` setting: client work is
    /// exclusively per-client, and every order-sensitive reduction (loss
    /// sum, metering, server merge) runs in deterministic participant order.
    pub fn step_round(
        &mut self,
        engine: &mut dyn TrainEngine,
        round: usize,
    ) -> anyhow::Result<RoundRecord> {
        let wall = Instant::now();
        self.meter.begin_round();
        // rotate the stale queue: last round's late arrivals become this
        // round's carried-in contributions (empty under the drop policy)
        self.stale_queue.begin_round();
        if let Some(l) = self.ledger.as_deref_mut() {
            l.begin_round(round);
        }
        let root = Rng::new(self.cfg.seed);
        // over-provision the cohort when the scheduler is active (a superset
        // of the base sample; `overselect = 1` is exactly `sample`); the
        // feasibility policy swaps the uniform shuffle for a weighted draw
        // fed by delivery history + per-client uplink spend
        let fleet = self.store.fleet_len();
        let participants = match self.cfg.sim.selection {
            SelectionPolicy::Uniform => self.cfg.sampler.sample_overselected(
                fleet,
                round,
                &root,
                self.cfg.sim.overselect,
            ),
            SelectionPolicy::Feasibility { beta } => {
                feasibility_weights(
                    &self.history,
                    &self.meter.per_client_uplink,
                    fleet,
                    beta,
                    &mut self.weight_scratch,
                );
                self.cfg.sampler.sample_weighted(
                    fleet,
                    round,
                    &root,
                    self.cfg.sim.overselect,
                    &self.weight_scratch,
                )
            }
        };
        let dim = self.params.len();
        let k = self.cfg.warmup.k_at(dim, round);
        let pool = resolve_pool(self.cfg.workers);

        // per-client rate control: plan every participant's effective top-k
        // and uplink value coding before fan-out, in participant order.
        // Every input is something the client itself can mirror in service
        // mode (own profile, own Laplace hit history, own metered spend —
        // the meter charges Accepted and Straggler fates, never Offline),
        // so a service fleet reproduces these plans bit-for-bit without any
        // protocol change. Off (the default) skips planning entirely and
        // fills the shared warmup k.
        self.k_scratch.clear();
        self.decision_scratch.clear();
        if self.cfg.rate_control.active() {
            for &cid in &participants {
                let p = self.scheduler.profile(cid);
                let d = self.cfg.rate_control.plan(
                    k,
                    dim,
                    self.cfg.codec.uplink.index,
                    self.cfg.codec.uplink.value,
                    LinkSignals {
                        up_bps: p.link.up_bps,
                        latency_s: p.link.latency_s,
                        compute_mult: p.compute_mult,
                    },
                    HistorySignals {
                        hit_rate: self.history.hit_rate(cid),
                        times_selected: self.history.times_selected(cid) as u64,
                        spent_bytes: self.meter.client_uplink(cid) as u64,
                    },
                    self.cfg.sim.deadline_s,
                    self.cfg.sim.compute_s,
                    self.cfg.local_steps,
                );
                self.k_scratch.push(d.k);
                self.decision_scratch.push(d);
            }
        } else {
            self.k_scratch.resize(participants.len(), k);
        }

        // 1. broadcast of the previous round reaches everyone (Alg.1 l.14+8)
        //    — per-client momentum fold-in, skipped wholesale for schemes
        //    whose observe is a no-op (plain DGC). The dense store fans it
        //    out over the pool eagerly; the virtual store logs the payload
        //    and replays it lazily at the client's next checkout — both
        //    produce bit-identical planes (see `coordinator::store`).
        if round > 0 && self.store.observes_broadcast() {
            self.store.observe_broadcast(&self.last_payload, pool);
        }

        // 2. local training + compression + wire round-trip, fanned out over
        //    worker threads; each client writes only its own persistent
        //    buffers (upload / wire_buf / echo)
        let n = participants.len();
        self.loss_scratch.clear();
        self.loss_scratch.resize(n, 0.0);
        let overlap;
        let mut uplink_phase;
        let carried_in: usize;
        let carried_bytes: usize;
        // frame-level chaos the simulator books but a real transport would
        // have absorbed (retried resends, deduplicated frames)
        let mut chaos_retries = 0usize;
        let mut chaos_dups = 0usize;
        let mut edge_stats = EdgeRoundStats::default();
        self.store.checkout(&participants);
        {
            let mut parts: Vec<&mut FlClient> = self.store.cohort_mut();
            // retarget each checked-out client's uplink value coding to this
            // round's plan, before any compress (the same round's restores
            // must see the codec the payload was encoded with)
            if self.cfg.rate_control.active() {
                for (c, d) in parts.iter_mut().zip(&self.decision_scratch) {
                    c.set_uplink_value(d.value);
                }
            }
            let (batch_size, local_steps) = (self.cfg.batch_size, self.cfg.local_steps);
            let params = &self.params;
            let losses = &mut self.loss_scratch[..];
            let ks = &self.k_scratch[..];
            // top up the persistent worker pool (first rounds only; engines
            // are reused every round thereafter)
            let want = if pool > 1 && n > 1 { pool.min(n) - 1 } else { 0 };
            while self.worker_engines.len() < want {
                match engine.spawn_worker() {
                    Some(e) => self.worker_engines.push(e),
                    // engine cannot be replicated: run sequentially
                    None => break,
                }
            }
            let extra = &mut self.worker_engines[..self.worker_engines.len().min(want)];
            if extra.is_empty() {
                for ((c, l), &ck) in parts.iter_mut().zip(losses.iter_mut()).zip(ks) {
                    let (loss, _, _) =
                        c.local_round(engine, params, batch_size, local_steps, ck, round)?;
                    *l = loss;
                }
            } else {
                let threads = extra.len() + 1;
                let chunk = n.div_ceil(threads);
                let mut first_err: anyhow::Result<()> = Ok(());
                std::thread::scope(|s| {
                    let mut part_chunks = parts.chunks_mut(chunk);
                    let mut loss_chunks = losses.chunks_mut(chunk);
                    let mut k_chunks = ks.chunks(chunk);
                    let head_parts = part_chunks.next();
                    let head_losses = loss_chunks.next();
                    let head_ks = k_chunks.next();
                    let mut handles = Vec::with_capacity(threads - 1);
                    for (((pc, lc), kc), eng) in
                        part_chunks.zip(loss_chunks).zip(k_chunks).zip(extra.iter_mut())
                    {
                        handles.push(s.spawn(move || -> anyhow::Result<()> {
                            for ((c, l), &ck) in pc.iter_mut().zip(lc.iter_mut()).zip(kc) {
                                let (loss, _, _) = c.local_round(
                                    eng.as_mut(),
                                    params,
                                    batch_size,
                                    local_steps,
                                    ck,
                                    round,
                                )?;
                                *l = loss;
                            }
                            Ok(())
                        }));
                    }
                    // the caller's engine drives the first chunk on this thread
                    if let (Some(pc), Some(lc), Some(kc)) = (head_parts, head_losses, head_ks) {
                        for ((c, l), &ck) in pc.iter_mut().zip(lc.iter_mut()).zip(kc) {
                            match c.local_round(engine, params, batch_size, local_steps, ck, round)
                            {
                                Ok((loss, _, _)) => *l = loss,
                                Err(e) => {
                                    first_err = Err(e);
                                    break;
                                }
                            }
                        }
                    }
                    for h in handles {
                        let r = h.join().expect("fl worker thread panicked");
                        if first_err.is_ok() {
                            first_err = r;
                        }
                    }
                });
                first_err?;
            }

            // 3. time-domain schedule: per-client finish times, deadline
            //    cut, dropout injection. Dropout draws come from a per-round
            //    RNG derived from the run seed, in participant order — the
            //    plan is independent of the worker count. With the inert
            //    default SimConfig every fate is Accepted and the uplink
            //    phase equals the PR 1 passive estimate bit-exactly.
            self.bytes_scratch.clear();
            self.bytes_scratch.extend(parts.iter().map(|c| c.wire_buf.len()));
            let mut drop_rng = root.derive(0xD30F ^ round as u64);
            uplink_phase = self.scheduler.plan_round(
                &self.cfg.sim,
                &participants,
                &self.bytes_scratch,
                self.cfg.local_steps,
                &mut drop_rng,
                &mut self.fate_scratch,
                &mut self.finish_scratch,
            );

            // 3b. chaos overrides: replay the fault plan's per-(client,
            //     round) decisions on the planned fates, exactly the way the
            //     service backends experience them. `drop` silences the
            //     upload (offline), `delay` lands it DELAY_S later (which
            //     can flip an accepted upload into a straggler when a
            //     deadline is armed); duplicate/reorder/truncate/disconnect
            //     are frame-level mischief a transport absorbs — the
            //     simulator only books the counters. The dropout RNG above
            //     is consumed for every participant regardless, so a
            //     faulted run stays aligned with the service fleet.
            if let Some(plan) = self.cfg.fault {
                let deadline = self.cfg.sim.deadline_s;
                for ((&cid, fate), finish) in participants
                    .iter()
                    .zip(self.fate_scratch.iter_mut())
                    .zip(self.finish_scratch.iter_mut())
                {
                    if !plan.hits(cid, round) {
                        continue;
                    }
                    match plan.kind {
                        FaultKind::Drop => *fate = ClientFate::Offline,
                        FaultKind::Delay => {
                            *finish += DELAY_S;
                            if *fate == ClientFate::Accepted
                                && deadline > 0.0
                                && *finish > deadline
                            {
                                *fate = ClientFate::Straggler;
                            }
                        }
                        FaultKind::Duplicate => chaos_dups += 1,
                        FaultKind::Truncate | FaultKind::Disconnect => chaos_retries += 1,
                        FaultKind::Reorder => {}
                    }
                }
                uplink_phase =
                    uplink_close(&self.cfg.sim, &self.fate_scratch, &self.finish_scratch);
            }

            // 4. deterministic reductions, in participant order: accepted
            //    uploads are metered and aggregated. What a deadline miss
            //    costs depends on the staleness policy: under `drop` the
            //    bytes are wasted and the full upload returns to the client
            //    residual; under the carry policies the upload is buffered
            //    server-side for the next round and only the unapplied
            //    1 − α fraction returns to the residual. Offline clients
            //    never transmitted, so they always restore in full.
            let alpha = self.cfg.sim.staleness.alpha();
            let carries = self.cfg.sim.staleness.carries();
            for ((c, &cid), &fate) in
                parts.iter_mut().zip(&participants).zip(&self.fate_scratch)
            {
                if let Some(l) = self.ledger.as_deref_mut() {
                    l.on_upload(cid, fate, &c.echo, c.wire_buf.len(), c.precodec_bytes);
                }
                match fate {
                    ClientFate::Accepted => {
                        self.meter.record_uplink(cid, c.wire_buf.len(), c.precodec_bytes);
                        self.history.record(cid, true);
                    }
                    ClientFate::Straggler => {
                        self.history.record(cid, false);
                        if carries {
                            // late but not lost: the bytes were spent and
                            // the server will use them next round
                            self.meter.record_carried_uplink(
                                cid,
                                c.wire_buf.len(),
                                c.precodec_bytes,
                            );
                            // push is (client, round)-idempotent: exactly
                            // one restore may pair with one queued entry,
                            // or carried mass would be double-counted
                            if self.stale_queue.push(cid, round, c.wire_buf.len(), &c.echo)
                                && alpha < 1.0
                            {
                                c.restore_dropped_upload_scaled(1.0 - alpha);
                            }
                        } else {
                            self.meter.record_wasted_uplink(
                                cid,
                                c.wire_buf.len(),
                                c.precodec_bytes,
                            );
                            c.restore_dropped_upload();
                        }
                    }
                    ClientFate::Offline => {
                        self.history.record(cid, false);
                        c.restore_dropped_upload();
                    }
                }
            }
            let mut echoes: Vec<&SparseVec> = Vec::with_capacity(n);
            for (c, &fate) in parts.iter().zip(&self.fate_scratch) {
                if fate == ClientFate::Accepted {
                    echoes.push(&c.echo);
                }
            }
            overlap = if self.cfg.exact_mask_overlap {
                mean_pairwise_jaccard(&echoes)
            } else {
                mean_jaccard_estimate(&echoes, &mut self.overlap_scratch)
            };
            // two-tier topology: edges pre-merge contiguous slices of the
            // accepted cohort and forward one frame each over the backhaul.
            // This prices the tier-1 wire only — the hub below still folds
            // the individual member uploads in the SAME participant order
            // the flat fleet uses, so the aggregate (and the whole
            // trajectory) is bit-identical across tier counts.
            if self.cfg.hierarchy.enabled() && !echoes.is_empty() {
                let merger = self.edge_merger.get_or_insert_with(|| EdgeMerger::new(dim));
                for range in plan_edges(echoes.len(), self.cfg.hierarchy.cohorts_per_edge) {
                    edge_stats.absorb(merger.merge(&echoes[range], self.cfg.codec.uplink));
                }
                self.meter
                    .record_edge_uplink(edge_stats.uplink_bytes, edge_stats.precodec_bytes);
            }
            // fresh uploads first, then last round's carried-over stale
            // uploads at the staleness discount — a fixed order per
            // coordinate, so worker counts never change the f32 sums
            if self.cfg.streamed_ingest {
                // fold straight from the wire bytes, in the same participant
                // order the batch merge would use — the pull-decoder emits
                // the exact pairs `decode_into` produces, so the aggregate
                // is bit-identical to the materialized path
                for (c, &fate) in parts.iter().zip(&self.fate_scratch) {
                    if fate == ClientFate::Accepted {
                        let runs = Runs::validate(&c.wire_buf).map_err(|e| {
                            anyhow::anyhow!("upload from client {}: {e:?}", c.id)
                        })?;
                        self.server.ingest(UploadSource::Wire(&runs), IngestOpts::new());
                    }
                }
            } else {
                self.server
                    .ingest(UploadSource::Batch(&echoes), IngestOpts::new().sharded(pool));
            }
            let stale = self.stale_queue.ready();
            carried_in = stale.len();
            carried_bytes = stale.iter().map(|e| e.bytes).sum();
            if carried_in > 0 {
                let stale_refs: Vec<&SparseVec> = stale.iter().map(|e| &e.grad).collect();
                self.server.ingest(
                    UploadSource::Batch(&stale_refs),
                    IngestOpts::new().scaled(alpha).sharded(pool),
                );
            }
        }
        // the cohort's planes fold back to rest (virtual stores gather +
        // evict; dense stores just clear the checkout bookkeeping)
        self.store.checkin();
        let mut train_loss = 0.0;
        let mut n_accepted = 0usize;
        let mut dropped_deadline = 0usize;
        let mut dropped_offline = 0usize;
        for (&l, &fate) in self.loss_scratch.iter().zip(&self.fate_scratch) {
            match fate {
                ClientFate::Accepted => {
                    train_loss += l;
                    n_accepted += 1;
                }
                ClientFate::Straggler => dropped_deadline += 1,
                ClientFate::Offline => dropped_offline += 1,
            }
        }
        train_loss /= n_accepted.max(1) as f64;

        // 5. aggregate + broadcast (through the persistent wire buffers).
        //    Carried-in stale uploads are genuine contributors: they enter
        //    the mean's denominator at full count (their *values* carry the
        //    α discount), so stale clients can never dominate a round.
        self.server.finish_round_into(n_accepted + carried_in, &mut self.payload_scratch, pool);
        if let Some(l) = self.ledger.as_deref_mut() {
            let aggregate = self.server.round_aggregate(&self.payload_scratch);
            l.on_aggregate(aggregate, n_accepted + carried_in);
        }
        self.stale_queue.recycle_ready();
        wire::encode_with(&self.payload_scratch, &mut self.bcast_buf, self.cfg.codec.downlink);
        let bcast_precodec = wire::encoded_bytes(&self.payload_scratch);
        self.meter.record_broadcast(self.bcast_buf.len(), bcast_precodec, n);
        // tier-1 downlink: the hub ships the broadcast once per edge; the
        // edges fan it out to their members (whose tier-0 bytes the meter
        // already booked above)
        if edge_stats.edges > 0 {
            self.meter.record_edge_broadcast(self.bcast_buf.len(), edge_stats.edges);
        }
        super::decode_broadcast(&self.bcast_buf, &mut self.last_payload)?;

        // 6. synchronized model update (Alg. 1 line 15)
        let lr = self.cfg.lr.at(round);
        self.last_payload.add_into(&mut self.params, -lr);

        // 7. round clock + diagnostics + eval
        self.accepted_scratch.clear();
        for (&cid, &fate) in participants.iter().zip(&self.fate_scratch) {
            if fate == ClientFate::Accepted {
                self.accepted_scratch.push(cid);
            }
        }
        let sim_s = uplink_phase
            + self.scheduler.broadcast_time(self.bcast_buf.len(), &self.accepted_scratch);
        let sim_clock = self.scheduler.advance(sim_s);

        let is_last = round + 1 == self.cfg.rounds;
        let do_eval = is_last
            || (self.cfg.eval_every > 0 && round % self.cfg.eval_every == self.cfg.eval_every - 1);
        let (test_loss, test_acc) = if do_eval && !self.test_batches.is_empty() {
            evaluate_with_pool(
                engine,
                &mut self.worker_engines,
                &self.params,
                &self.test_batches,
            )?
        } else {
            (0.0, 0.0)
        };

        let traffic_gini = self.meter.uplink_gini(fleet, &mut self.gini_scratch);
        // backhaul clock: how long the slowest edge spends forwarding its
        // merged frame. A diagnostic only — NOT added to sim_seconds, which
        // is digested and must stay identical across tier counts.
        let edge_backhaul_s = crate::sim::scheduler::backhaul_time(
            edge_stats.uplink_bytes,
            edge_stats.edges,
            self.cfg.hierarchy.edge_uplink_bps,
        );
        // per-client rate-control diagnostics. Like the edge_* columns these
        // are NOT digested: a rate_control=off run must stay digest-identical
        // to a pre-controller build, and the columns are derivable
        // diagnostics, not trajectory state.
        let shared_rate = if dim > 0 { k as f64 / dim as f64 } else { 0.0 };
        let (rate_mean, rate_min, rate_max, coding_downshifts) =
            if self.decision_scratch.is_empty() {
                (shared_rate, shared_rate, shared_rate, 0)
            } else {
                let mut sum = 0.0f64;
                let mut lo = f64::INFINITY;
                let mut hi = 0.0f64;
                let mut shifts = 0usize;
                for d in &self.decision_scratch {
                    sum += d.rate;
                    lo = lo.min(d.rate);
                    hi = hi.max(d.rate);
                    shifts += d.downshifted as usize;
                }
                (sum / self.decision_scratch.len() as f64, lo, hi, shifts)
            };
        let rec = RoundRecord {
            round,
            train_loss,
            test_loss,
            test_accuracy: test_acc,
            uplink_bytes: self.meter.round_uplink,
            downlink_bytes: self.meter.round_downlink,
            aggregate_nnz: self.last_payload.nnz(),
            mask_overlap: overlap,
            sim_seconds: sim_s,
            wall_seconds: wall.elapsed().as_secs_f64(),
            selected: n,
            dropped_deadline,
            dropped_offline,
            sim_clock,
            wasted_uplink_bytes: self.meter.round_wasted_uplink,
            carried_in,
            carried_bytes,
            traffic_gini,
            precodec_bytes: self.meter.round_precodec,
            codec_ratio: self.meter.round_codec_ratio(),
            retries: chaos_retries,
            timeouts: 0,
            stale_frames: 0,
            dup_frames: chaos_dups,
            edge_count: edge_stats.edges,
            edge_uplink_bytes: edge_stats.uplink_bytes,
            edge_downlink_bytes: if edge_stats.edges > 0 {
                self.bcast_buf.len() * edge_stats.edges
            } else {
                0
            },
            edge_backhaul_s,
            rate_mean,
            rate_min,
            rate_max,
            coding_downshifts,
        };
        self.recorder.push(rec.clone());
        Ok(rec)
    }

    /// Drive the full configured number of rounds.
    pub fn run(&mut self, engine: &mut dyn TrainEngine) -> anyhow::Result<RunSummary> {
        for round in 0..self.cfg.rounds {
            self.step_round(engine, round)?;
        }
        Ok(self.summary())
    }

    /// Drive rounds until the simulated round clock reaches `budget_s`
    /// seconds, capped at the configured round count — the time-to-accuracy
    /// regime: schemes with cheaper rounds fit more of them into the budget.
    pub fn run_for_budget(
        &mut self,
        engine: &mut dyn TrainEngine,
        budget_s: f64,
    ) -> anyhow::Result<RunSummary> {
        for round in 0..self.cfg.rounds {
            if self.scheduler.clock() >= budget_s {
                break;
            }
            self.step_round(engine, round)?;
        }
        Ok(self.summary())
    }

    pub fn summary(&self) -> RunSummary {
        let overlaps: Vec<f64> = self.recorder.rounds.iter().map(|r| r.mask_overlap).collect();
        RunSummary {
            technique: self.cfg.kind.name(),
            final_accuracy: self.recorder.final_accuracy(),
            best_accuracy: self.recorder.best_accuracy(),
            final_loss: self
                .recorder
                .rounds
                .last()
                .map(|r| if r.test_loss > 0.0 { r.test_loss } else { r.train_loss })
                .unwrap_or(0.0),
            total_traffic_gb: self.meter.total_gb(),
            uplink_gb: self.meter.total_uplink as f64 / 1e9,
            downlink_gb: self.meter.total_downlink as f64 / 1e9,
            sim_seconds: self.recorder.total_sim_seconds(),
            mean_mask_overlap: crate::util::math::mean(&overlaps),
            dropped_deadline: self.recorder.total_dropped_deadline(),
            dropped_offline: self.recorder.total_dropped_offline(),
            wasted_uplink_gb: self.meter.total_wasted_uplink as f64 / 1e9,
            carried_total: self.recorder.total_carried_in(),
            carried_gb: self.recorder.total_carried_bytes() as f64 / 1e9,
            precodec_gb: self.meter.total_precodec as f64 / 1e9,
            codec_ratio: self.meter.total_codec_ratio(),
            recorder: self.recorder.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Compressor as _;
    use crate::runtime::native::{BlobDataset, NativeEngine};

    fn blob_shards(
        clients: usize,
        per_client: usize,
        dim: usize,
        classes: usize,
        seed: u64,
    ) -> (Vec<Box<dyn Dataset + Send>>, Vec<Batch>) {
        let mut shards: Vec<Box<dyn Dataset + Send>> = Vec::new();
        for c in 0..clients {
            // shared centers (same task), disjoint noise per client shard
            shards.push(Box::new(BlobDataset::generate_split(
                per_client, dim, classes, 0.4, seed, seed + 1 + c as u64,
            )));
        }
        let test = BlobDataset::generate_split(128, dim, classes, 0.4, seed, seed ^ 0x7E57);
        let batches = test.eval_batches(32);
        (shards, batches)
    }

    fn quick_cfg(kind: CompressorKind) -> FlConfig {
        let mut cfg = FlConfig::new(kind, 0.1, 30);
        cfg.lr = LrSchedule::constant(0.5);
        cfg.eval_every = 5;
        cfg
    }

    #[test]
    fn dgc_run_converges_on_blobs() {
        let mut engine = NativeEngine::new(8, 12, 4, 1);
        let (shards, test) = blob_shards(4, 80, 8, 4, 10);
        let net = Network::uniform(4, Default::default());
        let mut run = FlRun::new(&engine, shards, test, net, quick_cfg(CompressorKind::Dgc));
        let summary = run.run(&mut engine).unwrap();
        assert!(summary.final_accuracy > 0.8, "acc {}", summary.final_accuracy);
        assert!(summary.total_traffic_gb > 0.0);
    }

    #[test]
    fn all_four_schemes_run_and_report() {
        for kind in CompressorKind::ALL {
            let mut engine = NativeEngine::new(8, 10, 3, 2);
            let (shards, test) = blob_shards(3, 60, 8, 3, 20);
            let net = Network::uniform(3, Default::default());
            let mut run = FlRun::new(&engine, shards, test, net, quick_cfg(kind));
            let summary = run.run(&mut engine).unwrap();
            assert_eq!(summary.technique, kind.name());
            let acc = summary.final_accuracy;
            assert!(acc > 0.5, "{}: acc {acc}", kind.name());
        }
    }

    #[test]
    fn dgcwgm_downlink_exceeds_dgc() {
        // paper §2.1: server momentum accumulates support → larger downlink
        let run_kind = |kind: CompressorKind| -> (f64, f64) {
            let mut engine = NativeEngine::new(8, 10, 3, 3);
            let (shards, test) = blob_shards(4, 60, 8, 3, 30);
            let net = Network::uniform(4, Default::default());
            let mut run = FlRun::new(&engine, shards, test, net, quick_cfg(kind));
            let s = run.run(&mut engine).unwrap();
            (s.downlink_gb, s.uplink_gb)
        };
        let (down_dgc, up_dgc) = run_kind(CompressorKind::Dgc);
        let (down_gm, up_gm) = run_kind(CompressorKind::DgcWgm);
        assert!(down_gm > down_dgc, "GM downlink {down_gm} vs DGC {down_dgc}");
        assert!((up_gm - up_dgc).abs() / up_dgc < 0.05, "uplinks comparable");
    }

    #[test]
    fn steady_state_round_reuses_client_buffers() {
        // after the warmup rounds grow the buffers, further rounds must not
        // reallocate any client-side hot-path buffer (upload, wire, echo)
        let mut engine = NativeEngine::new(8, 12, 4, 1);
        let (shards, test) = blob_shards(4, 80, 8, 4, 10);
        let net = Network::uniform(4, Default::default());
        let mut cfg = quick_cfg(CompressorKind::DgcWgmf);
        cfg.rounds = 12;
        let mut run = FlRun::new(&engine, shards, test, net, cfg);
        for round in 0..3 {
            run.step_round(&mut engine, round).unwrap();
        }
        // quick_cfg keeps Sampler::Full, so Auto resolves to the dense store
        let snapshot: Vec<(*const u32, *const f32, *const u8, *const u32)> = run
            .store
            .dense_clients()
            .expect("full participation uses the dense store")
            .iter()
            .map(|c| {
                (
                    c.upload.indices.as_ptr(),
                    c.upload.values.as_ptr(),
                    c.wire_buf.as_ptr(),
                    c.echo.indices.as_ptr(),
                )
            })
            .collect();
        for round in 3..12 {
            run.step_round(&mut engine, round).unwrap();
        }
        for (c, snap) in run.store.dense_clients().unwrap().iter().zip(&snapshot) {
            assert_eq!(c.upload.indices.as_ptr(), snap.0, "upload indices reallocated");
            assert_eq!(c.upload.values.as_ptr(), snap.1, "upload values reallocated");
            assert_eq!(c.wire_buf.as_ptr(), snap.2, "wire buffer reallocated");
            assert_eq!(c.echo.indices.as_ptr(), snap.3, "echo reallocated");
        }
    }

    #[test]
    fn explicit_worker_counts_run() {
        // smoke over several worker settings, including more workers than
        // clients; numerical equality is covered by tests/determinism.rs
        for workers in [1usize, 2, 7] {
            let mut engine = NativeEngine::new(8, 10, 3, 2);
            let (shards, test) = blob_shards(3, 60, 8, 3, 20);
            let net = Network::uniform(3, Default::default());
            let mut cfg = quick_cfg(CompressorKind::Dgc);
            cfg.rounds = 5;
            cfg.workers = workers;
            let mut run = FlRun::new(&engine, shards, test, net, cfg);
            let summary = run.run(&mut engine).unwrap();
            assert_eq!(summary.recorder.rounds.len(), 5, "workers={workers}");
        }
    }

    #[test]
    fn deadline_drops_freeze_model_and_residuals_reenter() {
        let mut engine = NativeEngine::new(8, 12, 4, 1);
        let (shards, test) = blob_shards(4, 80, 8, 4, 10);
        let net = Network::uniform(4, Default::default());
        let mut cfg = quick_cfg(CompressorKind::DgcWgmf);
        cfg.rounds = 8;
        cfg.sim.deadline_s = 1e-9; // link latency alone exceeds this: all miss
        let mut run = FlRun::new(&engine, shards, test, net, cfg);
        let init = run.params.clone();
        for round in 0..3 {
            let rec = run.step_round(&mut engine, round).unwrap();
            assert_eq!(rec.selected, 4);
            assert_eq!(rec.dropped_deadline, 4, "round {round}: everyone misses");
            assert_eq!(rec.aggregate_nnz, 0, "nothing aggregated");
            assert!(rec.uplink_bytes > 0, "straggler bytes still crossed the wire");
        }
        assert_eq!(run.params, init, "no accepted upload → model frozen");
        assert_eq!(run.meter.total_wasted_uplink, run.meter.total_uplink);
        for id in 0..4 {
            assert!(
                run.store.residual_norm(id) > 0.0,
                "dropped mass retained client-side"
            );
        }
        // relax the deadline mid-run: the held-back mass must re-enter
        run.cfg.sim.deadline_s = 1e9;
        let rec = run.step_round(&mut engine, 3).unwrap();
        assert_eq!(rec.dropped_deadline, 0);
        assert!(rec.aggregate_nnz > 0, "held-back residuals re-enter the aggregate");
        assert_ne!(run.params, init, "training resumes");
    }

    #[test]
    fn overselect_and_dropout_round_accounting() {
        let mut engine = NativeEngine::new(8, 12, 4, 1);
        let (shards, test) = blob_shards(6, 80, 8, 4, 10);
        let net = Network::uniform(6, Default::default());
        let mut cfg = quick_cfg(CompressorKind::Dgc);
        cfg.rounds = 6;
        cfg.sampler = Sampler::Count(3);
        cfg.sim.overselect = 1.5; // ceil(1.5 · 3) = 5 selected per round
        cfg.sim.dropout = 0.4;
        let mut run = FlRun::new(&engine, shards, test, net, cfg);
        let summary = run.run(&mut engine).unwrap();
        for r in &summary.recorder.rounds {
            assert_eq!(r.selected, 5, "round {}", r.round);
            assert!(r.dropped_offline <= 5);
            assert!(r.sim_clock > 0.0);
        }
        // P(zero dropouts over 6 rounds × 5 clients at 0.4) ≈ 2e-7
        assert!(summary.dropped_offline > 0, "dropouts must be injected");
        assert_eq!(
            summary.dropped_offline,
            summary.recorder.total_dropped_offline()
        );
        // round clock is the cumulative sum of round times
        let mut acc = 0.0;
        for r in &summary.recorder.rounds {
            acc += r.sim_seconds;
            assert!((r.sim_clock - acc).abs() < 1e-12, "round {}", r.round);
        }
    }

    #[test]
    fn carry_applies_late_uploads_next_round_without_waste() {
        use crate::sim::scheduler::StalenessPolicy;
        let mut engine = NativeEngine::new(8, 12, 4, 1);
        let (shards, test) = blob_shards(4, 80, 8, 4, 10);
        let net = Network::uniform(4, Default::default());
        let mut cfg = quick_cfg(CompressorKind::DgcWgmf);
        cfg.rounds = 6;
        cfg.sim.deadline_s = 1e-9; // link latency alone exceeds this: all miss
        cfg.sim.staleness = StalenessPolicy::Carry;
        let mut run = FlRun::new(&engine, shards, test, net, cfg);
        let init = run.params.clone();
        let r0 = run.step_round(&mut engine, 0).unwrap();
        assert_eq!(r0.dropped_deadline, 4, "everyone misses");
        assert_eq!(r0.carried_in, 0, "nothing was buffered before round 0");
        assert_eq!(r0.aggregate_nnz, 0);
        assert!(r0.uplink_bytes > 0, "late bytes still crossed the wire");
        assert_eq!(run.params, init, "no contribution reached round 0");
        assert_eq!(run.stale_queue.pending(), 4);
        let r1 = run.step_round(&mut engine, 1).unwrap();
        assert_eq!(r1.carried_in, 4, "round 0's late uploads enter round 1's aggregate");
        assert!(r1.carried_bytes > 0);
        assert!(r1.aggregate_nnz > 0);
        assert_ne!(run.params, init, "carried mass moves the model");
        for round in 2..6 {
            run.step_round(&mut engine, round).unwrap();
        }
        let summary = run.summary();
        assert_eq!(summary.carried_total, 4 * 5, "every round after the first carries 4");
        assert_eq!(summary.dropped_deadline, 4 * 6);
        assert_eq!(run.meter.total_wasted_uplink, 0, "carry never wastes straggler bytes");
        assert_eq!(summary.wasted_uplink_gb, 0.0);
        assert_eq!(run.stale_queue.pending(), 4, "the last round's stragglers end buffered");
    }

    #[test]
    fn feasibility_selection_keeps_cohort_shape_and_records_fairness() {
        use crate::sim::scheduler::SelectionPolicy;
        let mut engine = NativeEngine::new(8, 12, 4, 1);
        let (shards, test) = blob_shards(6, 80, 8, 4, 10);
        let net = Network::uniform(6, Default::default());
        let mut cfg = quick_cfg(CompressorKind::Dgc);
        cfg.rounds = 6;
        cfg.sampler = Sampler::Count(3);
        cfg.sim.selection = SelectionPolicy::Feasibility { beta: 0.6 };
        cfg.sim.deadline_s = 0.5;
        cfg.sim.compute_s = 0.01;
        let mut run = FlRun::new(&engine, shards, test, net, cfg);
        let summary = run.run(&mut engine).unwrap();
        let mut total_selected = 0;
        for r in &summary.recorder.rounds {
            assert_eq!(r.selected, 3, "round {}", r.round);
            assert!((0.0..1.0).contains(&r.traffic_gini), "round {}", r.round);
            total_selected += r.selected;
        }
        let recorded: usize =
            (0..6).map(|c| run.history.times_selected(c)).sum();
        assert_eq!(recorded, total_selected, "history must see every selection outcome");
    }

    #[test]
    fn default_codec_reads_ratio_one() {
        let mut engine = NativeEngine::new(8, 12, 4, 1);
        let (shards, test) = blob_shards(4, 80, 8, 4, 10);
        let net = Network::uniform(4, Default::default());
        let mut cfg = quick_cfg(CompressorKind::Dgc);
        cfg.rounds = 4;
        let mut run = FlRun::new(&engine, shards, test, net, cfg);
        let summary = run.run(&mut engine).unwrap();
        for r in &summary.recorder.rounds {
            assert_eq!(
                r.precodec_bytes,
                r.uplink_bytes + r.downlink_bytes,
                "round {}: default codec ships v1 bytes",
                r.round
            );
            assert_eq!(r.codec_ratio, 1.0, "round {}", r.round);
        }
        assert_eq!(summary.codec_ratio, 1.0);
    }

    #[test]
    fn varint_f16_codec_shrinks_wire_and_keeps_buffers_warm() {
        use crate::sparse::codec::{CodecParams, IndexCoding, ValueCoding, WireCodec};
        // dim large enough that the sparse container wins the uplink with a
        // comfortable margin (no container flapping near the crossover)
        let mut engine = NativeEngine::new(30, 40, 8, 1);
        let shards: Vec<Box<dyn Dataset + Send>> = (0..4)
            .map(|c| {
                Box::new(BlobDataset::generate_split(80, 30, 8, 0.4, 10, 11 + c as u64))
                    as Box<dyn Dataset + Send>
            })
            .collect();
        let net = Network::uniform(4, Default::default());
        let mut cfg = quick_cfg(CompressorKind::DgcWgmf);
        cfg.rounds = 12;
        let v2 = CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 };
        cfg.codec = WireCodec { uplink: v2, downlink: v2 };
        let mut run = FlRun::new(&engine, shards, Vec::new(), net, cfg);
        for round in 0..3 {
            run.step_round(&mut engine, round).unwrap();
        }
        let snapshot: Vec<(*const u32, *const f32, *const u8, *const u32)> = run
            .store
            .dense_clients()
            .expect("full participation uses the dense store")
            .iter()
            .map(|c| {
                (
                    c.upload.indices.as_ptr(),
                    c.upload.values.as_ptr(),
                    c.wire_buf.as_ptr(),
                    c.echo.indices.as_ptr(),
                )
            })
            .collect();
        for round in 3..12 {
            let rec = run.step_round(&mut engine, round).unwrap();
            // the acceptance bar: varint (+f16) coding buys ≥ 1.5× fewer
            // bytes per round than v1 would have spent on the same payloads
            assert!(
                rec.codec_ratio >= 1.5,
                "round {}: codec ratio {} below 1.5x",
                round,
                rec.codec_ratio
            );
            assert!(rec.precodec_bytes > rec.uplink_bytes + rec.downlink_bytes);
        }
        for (c, snap) in run.store.dense_clients().unwrap().iter().zip(&snapshot) {
            assert_eq!(c.upload.indices.as_ptr(), snap.0, "upload indices reallocated");
            assert_eq!(c.upload.values.as_ptr(), snap.1, "upload values reallocated");
            assert_eq!(c.wire_buf.as_ptr(), snap.2, "wire buffer reallocated");
            assert_eq!(c.echo.indices.as_ptr(), snap.3, "echo reallocated");
        }
        let summary = run.summary();
        assert!(summary.codec_ratio >= 1.5, "run ratio {}", summary.codec_ratio);
        assert!(summary.precodec_gb > summary.total_traffic_gb);
        // error feedback still converges the residual machinery: the model
        // moved and nothing blew up under quantisation
        assert!(summary.recorder.rounds.iter().all(|r| r.train_loss.is_finite()));
    }

    #[test]
    fn lossy_codec_drop_restores_echo_mass() {
        use crate::sparse::codec::{CodecParams, IndexCoding, ValueCoding, WireCodec};
        // every upload misses the deadline under f16 coding: the in-flight
        // echo mass must re-enter the residual (not the pre-quantisation
        // upload), so a later relaxed round still trains
        let mut engine = NativeEngine::new(30, 40, 8, 1);
        let shards: Vec<Box<dyn Dataset + Send>> = (0..4)
            .map(|c| {
                Box::new(BlobDataset::generate_split(80, 30, 8, 0.4, 10, 11 + c as u64))
                    as Box<dyn Dataset + Send>
            })
            .collect();
        let net = Network::uniform(4, Default::default());
        let mut cfg = quick_cfg(CompressorKind::DgcWgmf);
        cfg.rounds = 6;
        cfg.sim.deadline_s = 1e-9;
        let v2 = CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 };
        cfg.codec = WireCodec { uplink: v2, downlink: v2 };
        let mut run = FlRun::new(&engine, shards, Vec::new(), net, cfg);
        let init = run.params.clone();
        for round in 0..3 {
            let rec = run.step_round(&mut engine, round).unwrap();
            assert_eq!(rec.dropped_deadline, 4, "round {round}");
        }
        assert_eq!(run.params, init, "no accepted upload → model frozen");
        run.cfg.sim.deadline_s = 1e9;
        let rec = run.step_round(&mut engine, 3).unwrap();
        assert_eq!(rec.dropped_deadline, 0);
        assert!(rec.aggregate_nnz > 0, "held-back echo mass re-enters the aggregate");
        assert_ne!(run.params, init, "training resumes");
    }

    #[test]
    fn streamed_ingest_matches_materialized_bit_for_bit() {
        use crate::sparse::codec::{CodecParams, IndexCoding, ValueCoding, WireCodec};
        let codecs = [
            WireCodec::default(),
            WireCodec {
                uplink: CodecParams { index: IndexCoding::Varint, value: ValueCoding::F16 },
                downlink: CodecParams { index: IndexCoding::Raw, value: ValueCoding::F32 },
            },
        ];
        for codec in codecs {
            let run_with = |streamed: bool| -> (Vec<u32>, Vec<u64>) {
                let mut engine = NativeEngine::new(8, 12, 4, 1);
                let (shards, test) = blob_shards(4, 80, 8, 4, 10);
                let net = Network::uniform(4, Default::default());
                let mut cfg = quick_cfg(CompressorKind::DgcWgmf);
                cfg.rounds = 8;
                cfg.codec = codec.clone();
                cfg.streamed_ingest = streamed;
                let mut run = FlRun::new(&engine, shards, test, net, cfg);
                let summary = run.run(&mut engine).unwrap();
                let losses =
                    summary.recorder.rounds.iter().map(|r| r.train_loss.to_bits()).collect();
                (run.params.iter().map(|v| v.to_bits()).collect(), losses)
            };
            let (pm, lm) = run_with(false);
            let (ps, ls) = run_with(true);
            assert_eq!(pm, ps, "streamed ingest must reproduce the materialized trajectory");
            assert_eq!(lm, ls, "per-round losses must match bit-for-bit");
        }
    }

    #[test]
    fn virtual_store_matches_dense_trajectory_bit_for_bit() {
        // the tentpole contract: virtualized state must not move a single
        // bit of the trajectory, including broadcast replay (DGCwGMF
        // accumulates observed payloads, GMC replaces its momentum)
        for kind in [CompressorKind::DgcWgmf, CompressorKind::Gmc] {
            let run_with = |mode: StoreMode| -> (Vec<u32>, Vec<u64>) {
                let mut engine = NativeEngine::new(8, 12, 4, 1);
                let (shards, test) = blob_shards(5, 80, 8, 4, 10);
                let net = Network::uniform(5, Default::default());
                let mut cfg = quick_cfg(kind);
                cfg.rounds = 8;
                cfg.sampler = Sampler::Count(2); // rotating cohorts: replay gaps
                cfg.store = mode;
                let mut run = FlRun::new(&engine, shards, test, net, cfg);
                let summary = run.run(&mut engine).unwrap();
                let losses =
                    summary.recorder.rounds.iter().map(|r| r.train_loss.to_bits()).collect();
                (run.params.iter().map(|v| v.to_bits()).collect(), losses)
            };
            let (pd, ld) = run_with(StoreMode::Dense);
            let (pv, lv) = run_with(StoreMode::Virtual);
            assert_eq!(pd, pv, "{}: virtual store must reproduce the dense params", kind.name());
            assert_eq!(ld, lv, "{}: per-round losses must match bit-for-bit", kind.name());
        }
    }

    #[test]
    fn auto_store_picks_density_by_sampler() {
        let build = |sampler: Sampler| {
            let engine = NativeEngine::new(8, 12, 4, 1);
            let (shards, test) = blob_shards(4, 40, 8, 4, 10);
            let net = Network::uniform(4, Default::default());
            let mut cfg = quick_cfg(CompressorKind::Dgc);
            cfg.sampler = sampler;
            FlRun::new(&engine, shards, test, net, cfg)
        };
        assert!(build(Sampler::Full).store.dense_clients().is_some());
        assert!(build(Sampler::Count(2)).store.dense_clients().is_none());
    }

    #[test]
    fn two_tier_run_is_bit_identical_to_flat_and_meters_backhaul() {
        let run_with = |tiers: usize| {
            let mut engine = NativeEngine::new(8, 12, 4, 1);
            let (shards, test) = blob_shards(6, 80, 8, 4, 10);
            let net = Network::uniform(6, Default::default());
            let mut cfg = quick_cfg(CompressorKind::DgcWgmf);
            cfg.rounds = 6;
            cfg.sampler = Sampler::Count(4);
            cfg.hierarchy.tiers = tiers;
            cfg.hierarchy.cohorts_per_edge = 3; // 4 accepted → 2 edges
            let mut run = FlRun::new(&engine, shards, test, net, cfg);
            let summary = run.run(&mut engine).unwrap();
            (run.params.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(), summary)
        };
        let (p1, flat) = run_with(1);
        let (p2, tiered) = run_with(2);
        assert_eq!(p1, p2, "edge aggregation must not move the trajectory");
        for (a, b) in flat.recorder.rounds.iter().zip(&tiered.recorder.rounds) {
            // every digested column agrees; only the edge diagnostics differ
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {}", a.round);
            assert_eq!(a.uplink_bytes, b.uplink_bytes);
            assert_eq!(a.downlink_bytes, b.downlink_bytes);
            assert_eq!(a.aggregate_nnz, b.aggregate_nnz);
            assert_eq!(a.sim_seconds.to_bits(), b.sim_seconds.to_bits());
            assert_eq!(a.edge_count, 0, "flat run has no edges");
            assert_eq!(a.edge_uplink_bytes, 0);
            assert_eq!(a.edge_downlink_bytes, 0);
            assert_eq!(b.edge_count, 2, "round {}: 4 accepted / 3 per edge", b.round);
            assert!(b.edge_uplink_bytes > 0, "backhaul bytes metered");
            assert!(
                b.edge_uplink_bytes <= a.uplink_bytes,
                "round {}: union-support backhaul {} must not exceed member total {}",
                b.round,
                b.edge_uplink_bytes,
                a.uplink_bytes
            );
            assert_eq!(b.edge_downlink_bytes % b.edge_count, 0, "one broadcast per edge");
            assert!(b.edge_backhaul_s > 0.0);
        }
    }

    #[test]
    fn lr_schedule_milestones() {
        let lr = LrSchedule::step_at_halves(0.1, 100);
        assert_eq!(lr.at(0), 0.1);
        assert!((lr.at(50) - 0.01).abs() < 1e-7);
        assert!((lr.at(75) - 0.001).abs() < 1e-8);
    }

    #[test]
    fn traffic_recorded_every_round() {
        let mut engine = NativeEngine::new(6, 8, 3, 4);
        let (shards, test) = blob_shards(3, 40, 6, 3, 40);
        let net = Network::uniform(3, Default::default());
        let mut cfg = quick_cfg(CompressorKind::DgcWgmf);
        cfg.rounds = 5;
        let mut run = FlRun::new(&engine, shards, test, net, cfg);
        let summary = run.run(&mut engine).unwrap();
        assert_eq!(summary.recorder.rounds.len(), 5);
        for r in &summary.recorder.rounds {
            assert!(r.uplink_bytes > 0);
            assert!(r.downlink_bytes > 0);
            assert!(r.sim_seconds > 0.0);
        }
    }
}
