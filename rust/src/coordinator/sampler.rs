//! Client participation policies.
//!
//! The paper trains with full participation (20 / 100 clients every round);
//! partial participation is a first-class knob for the ablation benches.
//! The time-domain scheduler adds two layers on top: cohort
//! over-provisioning (`sim.overselect`, so stragglers can be dropped
//! without starving the aggregate) and scheduler-aware *weighted* selection
//! (`sim.selection = feasibility(β)`), which biases the draw toward clients
//! whose deadline-hit history and cumulative uplink spend make them good
//! picks — under a fairness floor that keeps every client selectable.

use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};

/// One-shot warning when over-selection is clamped by the population size:
/// the request silently degrades toward full participation, which is
/// usually a misconfiguration (`overselect · cohort > clients`).
static OVERSELECT_CLAMP_WARNED: AtomicBool = AtomicBool::new(false);

/// Scale a base cohort size by the over-selection factor, clamped to the
/// population. Warns (once per process) when the clamp actually bites.
fn boosted_count(count: usize, overselect: f64, clients: usize) -> usize {
    if overselect <= 1.0 {
        return count;
    }
    let want = (count as f64 * overselect).ceil() as usize;
    if want > clients && !OVERSELECT_CLAMP_WARNED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "warning: sim.overselect requests {want} of {clients} clients; clamping to the \
             full population (shown once — shrink overselect or the base cohort)"
        );
    }
    want.clamp(1, clients)
}

#[derive(Clone, Copy, Debug)]
pub enum Sampler {
    /// Every client participates every round (paper default).
    Full,
    /// A uniform random fraction (at least one client).
    Fraction(f64),
    /// A fixed number per round.
    Count(usize),
}

impl Sampler {
    /// Participant ids for `round`, deterministic given `rng` seed.
    pub fn sample(&self, clients: usize, round: usize, rng: &Rng) -> Vec<usize> {
        self.sample_overselected(clients, round, rng, 1.0)
    }

    /// Base cohort size for this policy over a population of `clients`.
    fn base_count(&self, clients: usize) -> usize {
        match *self {
            Sampler::Full => clients,
            Sampler::Fraction(f) => ((clients as f64 * f).round() as usize).clamp(1, clients),
            Sampler::Count(c) => c.clamp(1, clients),
        }
    }

    /// Like [`Sampler::sample`], over-provisioned by `overselect` (≥ 1): the
    /// deadline scheduler selects `ceil(overselect · clients_per_round)` so
    /// stragglers and dropouts can be discarded without starving the
    /// aggregate. `overselect <= 1` reproduces `sample` exactly, and the
    /// over-selected cohort is always a superset of the base cohort (both
    /// are prefixes of the same per-round shuffle). Requests beyond the
    /// population are clamped, with a one-shot warning.
    pub fn sample_overselected(
        &self,
        clients: usize,
        round: usize,
        rng: &Rng,
        overselect: f64,
    ) -> Vec<usize> {
        if matches!(self, Sampler::Full) {
            return (0..clients).collect();
        }
        let count = boosted_count(self.base_count(clients), overselect, clients);
        Self::choose(clients, count, round, rng)
    }

    /// Weighted variant of [`Sampler::sample_overselected`] for the
    /// feasibility selection policy: cohort sizes are identical, but *which*
    /// clients fill the cohort follows `weights` (one strictly positive
    /// weight per client) via the Efraimidis–Spirakis key scheme
    /// (`key_i = u_i^(1/w_i)`, take the largest keys). The over-selected
    /// cohort is still a superset of the base cohort (both are prefixes of
    /// the same key ranking) and the draw is a pure function of
    /// (seed, round, weights) — worker counts never touch it.
    pub fn sample_weighted(
        &self,
        clients: usize,
        round: usize,
        rng: &Rng,
        overselect: f64,
        weights: &[f64],
    ) -> Vec<usize> {
        debug_assert_eq!(weights.len(), clients);
        if matches!(self, Sampler::Full) {
            return (0..clients).collect();
        }
        let count = boosted_count(self.base_count(clients), overselect, clients);
        let mut r = rng.derive(0xFEA5 ^ round as u64);
        let mut keyed: Vec<(f64, usize)> = (0..clients)
            .map(|i| {
                let u = r.f64();
                let w = weights[i].max(1e-12);
                (u.powf(1.0 / w), i)
            })
            .collect();
        keyed.sort_unstable_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        let mut ids: Vec<usize> = keyed.into_iter().take(count).map(|(_, i)| i).collect();
        ids.sort_unstable();
        ids
    }

    fn choose(clients: usize, count: usize, round: usize, rng: &Rng) -> Vec<usize> {
        let mut ids: Vec<usize> = (0..clients).collect();
        let mut r = rng.derive(0x5A3F ^ round as u64);
        r.shuffle(&mut ids);
        ids.truncate(count);
        ids.sort_unstable();
        ids
    }
}

/// Per-client participation-outcome history, recorded by the round loop and
/// consumed by the feasibility selection policy. Only server-observable
/// facts enter it: how often a client was selected, and how often its
/// upload actually arrived by the deadline (hard dropouts count as misses —
/// from the server's side an unreliable client and a slow one look alike).
#[derive(Clone, Debug, Default)]
pub struct SelectionHistory {
    selected: Vec<u32>,
    delivered: Vec<u32>,
}

impl SelectionHistory {
    pub fn new(clients: usize) -> Self {
        SelectionHistory { selected: vec![0; clients], delivered: vec![0; clients] }
    }

    fn ensure(&mut self, client: usize) {
        if client >= self.selected.len() {
            self.selected.resize(client + 1, 0);
            self.delivered.resize(client + 1, 0);
        }
    }

    /// Record one selection outcome for `client`.
    pub fn record(&mut self, client: usize, delivered: bool) {
        self.ensure(client);
        self.selected[client] += 1;
        if delivered {
            self.delivered[client] += 1;
        }
    }

    pub fn times_selected(&self, client: usize) -> usize {
        self.selected.get(client).copied().unwrap_or(0) as usize
    }

    pub fn times_delivered(&self, client: usize) -> usize {
        self.delivered.get(client).copied().unwrap_or(0) as usize
    }

    /// Laplace-smoothed delivery rate in (0, 1):
    /// `(delivered + 1) / (selected + 2)`. A never-selected client reads
    /// 0.5 — a neutral prior, so fresh clients are neither favoured nor
    /// penalised.
    pub fn hit_rate(&self, client: usize) -> f64 {
        let sel = self.times_selected(client) as f64;
        let del = self.times_delivered(client) as f64;
        (del + 1.0) / (sel + 2.0)
    }
}

/// Selection weights for [`Sampler::sample_weighted`] under
/// `sim.selection = feasibility(β)`:
///
/// ```text
///   w_i = (1 − β) + β · hit_i · parity_i
/// ```
///
/// where `hit_i` is the client's smoothed deadline-hit rate and
/// `parity_i = mean_uplink / (uplink_i + mean_uplink)` de-prioritises
/// clients that already spent more uplink bytes than the fleet average
/// (0.5 at parity, → 1 for clients that paid nothing, → 0 for heavy
/// spenders). The `1 − β` term is the fairness floor: every client keeps a
/// strictly positive weight, so nobody is starved out of selection
/// entirely. `β = 0` weights everyone equally.
///
/// `per_client_uplink` is the traffic meter's cumulative per-client byte
/// list (it may be shorter than `clients`; missing entries count as 0).
/// `out` is a reusable buffer — no allocation once warm.
pub fn feasibility_weights(
    history: &SelectionHistory,
    per_client_uplink: &[usize],
    clients: usize,
    beta: f64,
    out: &mut Vec<f64>,
) {
    out.clear();
    out.reserve(clients);
    let total: usize = per_client_uplink.iter().take(clients).sum();
    let mean = total as f64 / clients.max(1) as f64;
    for i in 0..clients {
        let spent = per_client_uplink.get(i).copied().unwrap_or(0) as f64;
        let parity = if total == 0 { 1.0 } else { mean / (spent + mean) };
        out.push((1.0 - beta) + beta * history.hit_rate(i) * parity);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_is_everyone() {
        let rng = Rng::new(1);
        assert_eq!(Sampler::Full.sample(5, 0, &rng), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fraction_counts() {
        let rng = Rng::new(2);
        assert_eq!(Sampler::Fraction(0.5).sample(10, 0, &rng).len(), 5);
        assert_eq!(Sampler::Fraction(0.0).sample(10, 0, &rng).len(), 1); // floor 1
        assert_eq!(Sampler::Fraction(1.0).sample(10, 3, &rng).len(), 10);
    }

    #[test]
    fn deterministic_per_round_but_varies_across_rounds() {
        let rng = Rng::new(3);
        let a = Sampler::Count(3).sample(10, 7, &rng);
        let b = Sampler::Count(3).sample(10, 7, &rng);
        assert_eq!(a, b);
        let c = Sampler::Count(3).sample(10, 8, &rng);
        assert_ne!(a, c);
    }

    #[test]
    fn overselect_scales_count_and_keeps_superset() {
        let rng = Rng::new(9);
        let base = Sampler::Count(4).sample(20, 5, &rng);
        let over = Sampler::Count(4).sample_overselected(20, 5, &rng, 1.5);
        assert_eq!(over.len(), 6, "ceil(1.5 * 4)");
        assert!(base.iter().all(|id| over.contains(id)), "over-selection must be a superset");
        // factor 1.0 is exactly `sample`
        let same = Sampler::Count(4).sample_overselected(20, 5, &rng, 1.0);
        assert_eq!(base, same);
        // clamped to the population
        let all = Sampler::Fraction(0.9).sample_overselected(10, 0, &rng, 4.0);
        assert_eq!(all.len(), 10);
        // Full cannot over-provision beyond the population
        assert_eq!(Sampler::Full.sample_overselected(5, 0, &rng, 2.0).len(), 5);
    }

    #[test]
    fn overselect_beyond_population_clamps_never_duplicates() {
        let rng = Rng::new(21);
        // ceil(8 · 10) = 80 of 8: must clamp to the full population, not
        // sample with anything replacement-adjacent
        let ids = Sampler::Count(8).sample_overselected(8, 2, &rng, 10.0);
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        let ids = Sampler::Fraction(0.75).sample_overselected(4, 0, &rng, 100.0);
        assert_eq!(ids.len(), 4);
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "no duplicate ids");
    }

    #[test]
    fn ids_sorted_unique_in_range() {
        let rng = Rng::new(4);
        let ids = Sampler::Count(6).sample(20, 11, &rng);
        assert_eq!(ids.len(), 6);
        assert!(ids.windows(2).all(|w| w[0] < w[1]));
        assert!(ids.iter().all(|&i| i < 20));
    }

    #[test]
    fn weighted_sampling_matches_cohort_shape() {
        let rng = Rng::new(30);
        let weights = vec![1.0; 20];
        let ids = Sampler::Count(4).sample_weighted(20, 3, &rng, 1.0, &weights);
        assert_eq!(ids.len(), 4);
        assert!(ids.windows(2).all(|w| w[0] < w[1]));
        assert!(ids.iter().all(|&i| i < 20));
        // deterministic in (seed, round)
        let again = Sampler::Count(4).sample_weighted(20, 3, &rng, 1.0, &weights);
        assert_eq!(ids, again);
        let other_round = Sampler::Count(4).sample_weighted(20, 4, &rng, 1.0, &weights);
        assert_ne!(ids, other_round);
        // over-selection is a superset of the base draw
        let over = Sampler::Count(4).sample_weighted(20, 3, &rng, 1.5, &weights);
        assert_eq!(over.len(), 6);
        assert!(ids.iter().all(|id| over.contains(id)));
        // Full ignores weights
        assert_eq!(
            Sampler::Full.sample_weighted(5, 0, &rng, 1.0, &[1.0; 5]),
            vec![0, 1, 2, 3, 4]
        );
    }

    #[test]
    fn weighted_sampling_prefers_heavy_clients() {
        let rng = Rng::new(31);
        // client 7 has overwhelming weight: it must appear in (essentially)
        // every cohort; clients with ~0 weight essentially never beat it
        let mut weights = vec![1e-9; 10];
        weights[7] = 1.0;
        for round in 0..50 {
            let ids = Sampler::Count(2).sample_weighted(10, round, &rng, 1.0, &weights);
            assert!(ids.contains(&7), "round {round}: heavy client missing from {ids:?}");
        }
    }

    #[test]
    fn history_hit_rate_smoothing() {
        let mut h = SelectionHistory::new(3);
        assert_eq!(h.hit_rate(0), 0.5, "fresh client reads the neutral prior");
        h.record(0, true);
        h.record(0, true);
        h.record(1, false);
        assert_eq!(h.times_selected(0), 2);
        assert_eq!(h.times_delivered(0), 2);
        assert_eq!(h.hit_rate(0), 3.0 / 4.0);
        assert_eq!(h.hit_rate(1), 1.0 / 3.0);
        assert_eq!(h.hit_rate(2), 0.5);
        // out-of-range reads are safe; records grow the table
        assert_eq!(h.hit_rate(9), 0.5);
        h.record(9, true);
        assert_eq!(h.times_selected(9), 1);
    }

    #[test]
    fn feasibility_weights_floor_and_bias() {
        let mut h = SelectionHistory::new(3);
        for _ in 0..8 {
            h.record(0, true); // always delivers
            h.record(1, false); // always misses
        }
        let uplink = vec![900usize, 0, 0];
        let mut w = Vec::new();
        feasibility_weights(&h, &uplink, 3, 0.6, &mut w);
        assert_eq!(w.len(), 3);
        // fairness floor: even the always-missing client keeps ≥ 1 − β
        for &x in &w {
            assert!(x >= 0.4, "weight {x} fell through the fairness floor");
        }
        // client 2 (fresh, no spend) must outrank client 1 (always misses)
        assert!(w[2] > w[1]);
        // heavy spender 0 is discounted by traffic parity despite hitting:
        // hit₀ = 9/10 · parity₀ = 300/1200 vs hit₂ = 0.5 · parity₂ = 300/300
        assert!(w[2] > w[0]);
        // β = 0 is uniform
        feasibility_weights(&h, &uplink, 3, 0.0, &mut w);
        assert!(w.iter().all(|&x| x == 1.0));
        // no traffic recorded at all → parity neutral, no NaNs
        feasibility_weights(&h, &[], 3, 1.0, &mut w);
        assert!(w.iter().all(|&x| x.is_finite() && x > 0.0));
    }
}
