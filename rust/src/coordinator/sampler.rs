//! Client participation policies.
//!
//! The paper trains with full participation (20 / 100 clients every round);
//! partial participation is a first-class knob for the ablation benches.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub enum Sampler {
    /// Every client participates every round (paper default).
    Full,
    /// A uniform random fraction (at least one client).
    Fraction(f64),
    /// A fixed number per round.
    Count(usize),
}

impl Sampler {
    /// Participant ids for `round`, deterministic given `rng` seed.
    pub fn sample(&self, clients: usize, round: usize, rng: &Rng) -> Vec<usize> {
        self.sample_overselected(clients, round, rng, 1.0)
    }

    /// Like [`Sampler::sample`], over-provisioned by `overselect` (≥ 1): the
    /// deadline scheduler selects `ceil(overselect · clients_per_round)` so
    /// stragglers and dropouts can be discarded without starving the
    /// aggregate. `overselect <= 1` reproduces `sample` exactly, and the
    /// over-selected cohort is always a superset of the base cohort (both
    /// are prefixes of the same per-round shuffle).
    pub fn sample_overselected(
        &self,
        clients: usize,
        round: usize,
        rng: &Rng,
        overselect: f64,
    ) -> Vec<usize> {
        let boost = |count: usize| -> usize {
            if overselect > 1.0 {
                ((count as f64 * overselect).ceil() as usize).clamp(1, clients)
            } else {
                count
            }
        };
        match *self {
            Sampler::Full => (0..clients).collect(),
            Sampler::Fraction(f) => {
                let count = ((clients as f64 * f).round() as usize).clamp(1, clients);
                Self::choose(clients, boost(count), round, rng)
            }
            Sampler::Count(c) => Self::choose(clients, boost(c.clamp(1, clients)), round, rng),
        }
    }

    fn choose(clients: usize, count: usize, round: usize, rng: &Rng) -> Vec<usize> {
        let mut ids: Vec<usize> = (0..clients).collect();
        let mut r = rng.derive(0x5A3F ^ round as u64);
        r.shuffle(&mut ids);
        ids.truncate(count);
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_is_everyone() {
        let rng = Rng::new(1);
        assert_eq!(Sampler::Full.sample(5, 0, &rng), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fraction_counts() {
        let rng = Rng::new(2);
        assert_eq!(Sampler::Fraction(0.5).sample(10, 0, &rng).len(), 5);
        assert_eq!(Sampler::Fraction(0.0).sample(10, 0, &rng).len(), 1); // floor 1
        assert_eq!(Sampler::Fraction(1.0).sample(10, 3, &rng).len(), 10);
    }

    #[test]
    fn deterministic_per_round_but_varies_across_rounds() {
        let rng = Rng::new(3);
        let a = Sampler::Count(3).sample(10, 7, &rng);
        let b = Sampler::Count(3).sample(10, 7, &rng);
        assert_eq!(a, b);
        let c = Sampler::Count(3).sample(10, 8, &rng);
        assert_ne!(a, c);
    }

    #[test]
    fn overselect_scales_count_and_keeps_superset() {
        let rng = Rng::new(9);
        let base = Sampler::Count(4).sample(20, 5, &rng);
        let over = Sampler::Count(4).sample_overselected(20, 5, &rng, 1.5);
        assert_eq!(over.len(), 6, "ceil(1.5 * 4)");
        assert!(base.iter().all(|id| over.contains(id)), "over-selection must be a superset");
        // factor 1.0 is exactly `sample`
        let same = Sampler::Count(4).sample_overselected(20, 5, &rng, 1.0);
        assert_eq!(base, same);
        // clamped to the population
        let all = Sampler::Fraction(0.9).sample_overselected(10, 0, &rng, 4.0);
        assert_eq!(all.len(), 10);
        // Full cannot over-provision beyond the population
        assert_eq!(Sampler::Full.sample_overselected(5, 0, &rng, 2.0).len(), 5);
    }

    #[test]
    fn ids_sorted_unique_in_range() {
        let rng = Rng::new(4);
        let ids = Sampler::Count(6).sample(20, 11, &rng);
        assert_eq!(ids.len(), 6);
        assert!(ids.windows(2).all(|w| w[0] < w[1]));
        assert!(ids.iter().all(|&i| i < 20));
    }
}
