//! FL server: sparse aggregation + broadcast policy.
//!
//! The broadcast policy is where DGCwGM differs from every other scheme:
//! instead of broadcasting the round's aggregate `Ĝ_t`, the server keeps a
//! global momentum `M_t = β·M_{t-1} + Ĝ_t` and broadcasts **M_t**, whose
//! sparse support accumulates round over round ("making aggregated gradient
//! nearly full size in the future rounds" — paper §2.1/Fig. 1). The wire
//! layer's dense fallback then kicks in and the downlink grows — the +15.4%
//! overhead row of Table 3.

use crate::sparse::merge::Aggregator;
use crate::sparse::stream::Runs;
use crate::sparse::vector::SparseVec;

/// What the server sends back to clients each round.
#[derive(Clone, Debug)]
pub enum BroadcastPolicy {
    /// Broadcast the plain aggregate Ĝ_t (DGC, GMC, DGCwGMF).
    Aggregate,
    /// Broadcast the server-side global momentum (DGCwGM, paper §2.1).
    ServerMomentum { beta: f32 },
}

/// Where an upload's values come from — the one axis the consolidated
/// [`FlServer::ingest`] entry point dispatches on. All three forms feed the
/// identical per-coordinate `acc += scale · v` fold, so choosing a source is
/// a transport decision, never a numerics decision.
pub enum UploadSource<'a> {
    /// A single already-decoded client gradient.
    Sparse(&'a SparseVec),
    /// A single client gradient read straight from a validated wire buffer
    /// (no intermediate `SparseVec`; see docs/wire.md for the pull decoder).
    Wire(&'a Runs<'a>),
    /// A whole pre-deduplicated batch folded in slice order (the simulator's
    /// cohort path; may shard the coordinate space over workers).
    Batch(&'a [&'a SparseVec]),
}

/// Policy knobs for [`FlServer::ingest`]. Start from [`IngestOpts::new`]
/// (scale 1.0, no dedup guard, sequential) and layer on what the call site
/// needs.
#[derive(Clone, Copy, Debug)]
pub struct IngestOpts {
    /// `Some(id)`: idempotent receive — reject if `id` already contributed
    /// since the last [`FlServer::begin_round`].
    pub client: Option<usize>,
    /// Staleness discount applied to every value (`acc += scale · v`).
    pub scale: f32,
    /// Worker-thread cap for batch merges (ignored for single uploads).
    pub workers: usize,
}

impl Default for IngestOpts {
    fn default() -> Self {
        IngestOpts { client: None, scale: 1.0, workers: 1 }
    }
}

impl IngestOpts {
    pub fn new() -> Self {
        Self::default()
    }

    /// Guard against duplicated transport frames from `client` this round.
    pub fn from_client(mut self, client: usize) -> Self {
        self.client = Some(client);
        self
    }

    /// Discount every value by `scale` (the carried-upload staleness path).
    pub fn scaled(mut self, scale: f32) -> Self {
        self.scale = scale;
        self
    }

    /// Allow batch merges to shard the coordinate space over `workers`.
    pub fn sharded(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }
}

/// What [`FlServer::ingest`] did with an upload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ingested {
    /// Whether the upload entered the aggregate (false only when the
    /// per-client dedup guard rejected a duplicated frame).
    pub applied: bool,
    /// Nonzeros folded into the accumulator.
    pub nnz: usize,
}

pub struct FlServer {
    dim: usize,
    agg: Aggregator,
    policy: BroadcastPolicy,
    /// server momentum state (ServerMomentum only)
    momentum: Vec<f32>,
    /// entries of |momentum| below this are dropped from the broadcast
    /// support (exact 0.0 keeps every touched coordinate forever)
    momentum_prune_eps: f32,
    /// per-round aggregate Ĝ_t scratch, reused across rounds
    ghat_scratch: SparseVec,
    /// clients whose upload already entered this round's aggregate — the
    /// idempotent-receive guard for [`FlServer::receive_upload`] (sorted)
    round_seen: Vec<usize>,
}

impl FlServer {
    pub fn new(dim: usize, policy: BroadcastPolicy) -> Self {
        let momentum = match policy {
            BroadcastPolicy::ServerMomentum { .. } => vec![0.0; dim],
            BroadcastPolicy::Aggregate => Vec::new(),
        };
        FlServer {
            dim,
            agg: Aggregator::new(dim),
            policy,
            momentum,
            momentum_prune_eps: 0.0,
            ghat_scratch: SparseVec::empty(dim),
            round_seen: Vec::new(),
        }
    }

    /// Open a round: reset the idempotent-receive guard. Callers feeding
    /// uploads through [`FlServer::receive_upload`] (the service round
    /// loop) must call this once per round; the batch paths
    /// ([`FlServer::receive_all`]) are unaffected.
    pub fn begin_round(&mut self) {
        self.round_seen.clear();
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Receive client uploads through the one consolidated entry point.
    ///
    /// Every ingest is the same per-coordinate `acc += scale · v` fold; the
    /// [`UploadSource`] only chooses how the values arrive (decoded vector,
    /// validated wire buffer, or a whole batch) and [`IngestOpts`] chooses
    /// the policy knobs:
    ///
    /// * `scale` — staleness discount applied to every value (default 1.0;
    ///   IEEE-754 guarantees `1.0 · v == v`, so the default is bit-identical
    ///   to an unscaled fold).
    /// * `from_client(id)` — idempotent receive: the upload is rejected if
    ///   `id` already contributed since the last [`FlServer::begin_round`]
    ///   (a duplicated transport frame must never enter the mean twice).
    ///   Only meaningful for single-upload sources; batch sources are
    ///   trusted pre-deduplicated cohorts.
    /// * `sharded(workers)` — batch merges may shard the coordinate space
    ///   over up to `workers` threads, bit-identical to the sequential fold
    ///   in `grads` order at any worker count.
    ///
    /// Streamed ingest is bit-identical to decoding the buffer first: the
    /// pull-decoder emits the exact (index, value) pairs `decode_into`
    /// would produce, in the same order. Returns what happened: whether the
    /// upload entered the aggregate and how many nonzeros were folded.
    pub fn ingest(&mut self, source: UploadSource<'_>, opts: IngestOpts) -> Ingested {
        if let Some(client) = opts.client {
            debug_assert!(
                !matches!(source, UploadSource::Batch(_)),
                "per-client dedup applies to single uploads, not batches"
            );
            match self.round_seen.binary_search(&client) {
                Ok(_) => return Ingested { applied: false, nnz: 0 },
                Err(at) => self.round_seen.insert(at, client),
            }
        }
        let nnz = match source {
            UploadSource::Sparse(g) => {
                self.agg.add(&[g], opts.scale, 1);
                g.nnz()
            }
            UploadSource::Wire(runs) => self.agg.fold_stream(runs, opts.scale),
            UploadSource::Batch(grads) => {
                self.agg.add(grads, opts.scale, opts.workers);
                grads.iter().map(|g| g.nnz()).sum()
            }
        };
        Ingested { applied: true, nnz }
    }

    /// Receive one (already-decoded) client gradient.
    #[deprecated(note = "use `FlServer::ingest(UploadSource::Sparse(g), IngestOpts::new())`")]
    pub fn receive(&mut self, g: &SparseVec) {
        self.ingest(UploadSource::Sparse(g), IngestOpts::new());
    }

    /// Idempotent per-client receive; returns whether the gradient applied.
    #[deprecated(note = "use `FlServer::ingest` with `IngestOpts::new().from_client(client)`")]
    pub fn receive_upload(&mut self, client: usize, g: &SparseVec) -> bool {
        self.ingest(UploadSource::Sparse(g), IngestOpts::new().from_client(client)).applied
    }

    /// Streamed receive from a validated wire buffer; returns runs folded.
    #[deprecated(note = "use `FlServer::ingest(UploadSource::Wire(runs), IngestOpts::new())`")]
    pub fn receive_stream(&mut self, runs: &Runs<'_>) -> usize {
        self.ingest(UploadSource::Wire(runs), IngestOpts::new()).nnz
    }

    /// Idempotent streamed receive; returns whether the upload was folded.
    #[deprecated(note = "use `FlServer::ingest` with `IngestOpts::new().from_client(client)`")]
    pub fn receive_upload_streamed(&mut self, client: usize, runs: &Runs<'_>) -> bool {
        self.ingest(UploadSource::Wire(runs), IngestOpts::new().from_client(client)).applied
    }

    /// Batch receive of a whole round of decoded gradients.
    #[deprecated(note = "use `FlServer::ingest(UploadSource::Batch(grads), ...)`")]
    pub fn receive_all(&mut self, grads: &[&SparseVec], workers: usize) {
        self.ingest(UploadSource::Batch(grads), IngestOpts::new().sharded(workers));
    }

    /// Batch receive of carried-over stale gradients, discounted by `scale`.
    #[deprecated(note = "use `FlServer::ingest` with `IngestOpts::new().scaled(scale)`")]
    pub fn receive_all_scaled(&mut self, grads: &[&SparseVec], scale: f32, workers: usize) {
        self.ingest(UploadSource::Batch(grads), IngestOpts::new().scaled(scale).sharded(workers));
    }

    /// Allocation-free `finish_round`: writes the broadcast payload into a
    /// caller-owned reusable vector (cleared, capacity kept) and resets the
    /// aggregator for the next round. Under `ServerMomentum` the round
    /// aggregate Ĝ_t is retained internally (`ghat_scratch`) for the
    /// momentum update. The aggregate emit may shard over up to `workers`
    /// threads; results are bit-identical at any setting.
    pub fn finish_round_into(
        &mut self,
        participants: usize,
        payload: &mut SparseVec,
        workers: usize,
    ) {
        match self.policy {
            BroadcastPolicy::Aggregate => {
                // payload is Ĝ_t itself
                self.agg.finish_into(participants, payload, workers);
            }
            BroadcastPolicy::ServerMomentum { beta } => {
                self.agg.finish_into(participants, &mut self.ghat_scratch, workers);
                for m in self.momentum.iter_mut() {
                    *m *= beta;
                }
                self.ghat_scratch.add_into(&mut self.momentum, 1.0);
                payload.dim = self.dim;
                payload.indices.clear();
                payload.values.clear();
                let eps = self.momentum_prune_eps;
                for (i, &m) in self.momentum.iter().enumerate() {
                    // eps == 0.0 (default) keeps every nonzero coordinate —
                    // the support-only-accumulates behaviour the paper measures
                    let keep = if eps > 0.0 { m.abs() > eps } else { m != 0.0 };
                    if keep {
                        payload.indices.push(i as u32);
                        payload.values.push(m);
                    }
                }
            }
        }
    }

    /// The round aggregate Ĝ_t behind the last
    /// [`FlServer::finish_round_into`] call: the payload itself under the
    /// `Aggregate` policy, the retained `ghat_scratch` under
    /// `ServerMomentum` (whose payload is the momentum M_t, not Ĝ_t).
    /// The conformance ledger uses this so mass-conservation checks audit
    /// the aggregate, never the momentum state.
    pub fn round_aggregate<'a>(&'a self, payload: &'a SparseVec) -> &'a SparseVec {
        match self.policy {
            BroadcastPolicy::Aggregate => payload,
            BroadcastPolicy::ServerMomentum { .. } => &self.ghat_scratch,
        }
    }

    /// Close the round: aggregate the received gradients and produce
    /// (broadcast payload, aggregate Ĝ_t).
    ///
    /// The aggregate is what clients use for their model update bookkeeping
    /// in all schemes; under `ServerMomentum` the *payload* is M_t and the
    /// model update uses M_t as well (momentum SGD applied at the server).
    /// Allocating convenience wrapper over [`FlServer::finish_round_into`].
    pub fn finish_round(&mut self, participants: usize) -> (SparseVec, SparseVec) {
        let mut payload = SparseVec::empty(self.dim);
        self.finish_round_into(participants, &mut payload, 1);
        let ghat = match self.policy {
            BroadcastPolicy::Aggregate => payload.clone(),
            BroadcastPolicy::ServerMomentum { .. } => self.ghat_scratch.clone(),
        };
        (payload, ghat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shorthand: fold one decoded gradient with default options.
    fn recv(s: &mut FlServer, g: &SparseVec) {
        s.ingest(UploadSource::Sparse(g), IngestOpts::new());
    }

    #[test]
    fn aggregate_policy_broadcasts_mean() {
        let mut s = FlServer::new(6, BroadcastPolicy::Aggregate);
        recv(&mut s, &SparseVec::new(6, vec![(1, 2.0)]));
        recv(&mut s, &SparseVec::new(6, vec![(1, 4.0), (3, 2.0)]));
        let (payload, ghat) = s.finish_round(2);
        assert_eq!(payload, ghat);
        assert_eq!(ghat.indices, vec![1, 3]);
        assert_eq!(ghat.values, vec![3.0, 1.0]);
    }

    #[test]
    fn scaled_ingest_discounts_stale_gradients() {
        let mut s = FlServer::new(6, BroadcastPolicy::Aggregate);
        recv(&mut s, &SparseVec::new(6, vec![(1, 2.0)]));
        let stale = SparseVec::new(6, vec![(1, 2.0), (4, 4.0)]);
        let got = s.ingest(UploadSource::Batch(&[&stale]), IngestOpts::new().scaled(0.5));
        assert_eq!(got, Ingested { applied: true, nnz: 2 });
        let (payload, _) = s.finish_round(2);
        assert_eq!(payload.indices, vec![1, 4]);
        assert_eq!(payload.values, vec![1.5, 1.0]); // (2 + 1)/2, (0 + 2)/2
    }

    #[test]
    fn server_momentum_support_grows() {
        let mut s = FlServer::new(100, BroadcastPolicy::ServerMomentum { beta: 0.9 });
        // round 1: coords 0..10
        for i in 0..10u32 {
            recv(&mut s, &SparseVec::new(100, vec![(i, 1.0)]));
        }
        let (p1, _) = s.finish_round(10);
        assert_eq!(p1.nnz(), 10);
        // round 2: different coords 50..60 — payload keeps the old support
        for i in 50..60u32 {
            recv(&mut s, &SparseVec::new(100, vec![(i, 1.0)]));
        }
        let (p2, g2) = s.finish_round(10);
        assert_eq!(g2.nnz(), 10, "aggregate itself is sparse");
        assert_eq!(p2.nnz(), 20, "momentum payload accumulates support");
    }

    #[test]
    fn server_momentum_decays_values() {
        let mut s = FlServer::new(10, BroadcastPolicy::ServerMomentum { beta: 0.5 });
        recv(&mut s, &SparseVec::new(10, vec![(2, 8.0)]));
        let (p1, _) = s.finish_round(1);
        assert_eq!(p1.values, vec![8.0]);
        let (p2, _) = s.finish_round(1); // no contributions: pure decay
        assert_eq!(p2.values, vec![4.0]);
    }

    #[test]
    fn round_aggregate_is_ghat_under_both_policies() {
        // Aggregate policy: the payload IS Ĝ_t
        let mut s = FlServer::new(6, BroadcastPolicy::Aggregate);
        recv(&mut s, &SparseVec::new(6, vec![(1, 2.0)]));
        let (payload, ghat) = s.finish_round(1);
        assert_eq!(s.round_aggregate(&payload), &ghat);
        // ServerMomentum: the payload is M_t, the aggregate is Ĝ_t
        let mut m = FlServer::new(6, BroadcastPolicy::ServerMomentum { beta: 0.5 });
        recv(&mut m, &SparseVec::new(6, vec![(2, 4.0)]));
        let (_, _) = m.finish_round(1);
        recv(&mut m, &SparseVec::new(6, vec![(3, 2.0)]));
        let (p2, g2) = m.finish_round(1);
        assert_eq!(p2.nnz(), 2, "momentum payload keeps old support");
        assert_eq!(m.round_aggregate(&p2), &g2, "aggregate is the fresh Ĝ_t");
        assert_eq!(g2.indices, vec![3]);
    }

    #[test]
    fn duplicate_upload_is_rejected_and_mass_ledger_stays_balanced() {
        use crate::metrics::ledger::RoundLedger;
        use crate::sim::scheduler::{ClientFate, StalenessPolicy};
        use crate::sim::staleness::StaleQueue;
        use crate::testkit::invariants::MassLedger;
        let dim = 6;
        let mut s = FlServer::new(dim, BroadcastPolicy::Aggregate);
        let mut ledger = MassLedger::new(dim, StalenessPolicy::Drop);
        let g = SparseVec::new(dim, vec![(1, 2.0), (4, -3.0)]);
        s.begin_round();
        // the client uploaded once; the wire delivered the frame twice
        ledger.on_upload(0, ClientFate::Accepted, &g, 24, 24);
        let from0 = IngestOpts::new().from_client(0);
        assert!(
            s.ingest(UploadSource::Sparse(&g), from0).applied,
            "first frame enters the aggregate"
        );
        assert_eq!(
            s.ingest(UploadSource::Sparse(&g), from0),
            Ingested { applied: false, nnz: 0 },
            "duplicated frame must be rejected"
        );
        let (payload, ghat) = s.finish_round(1);
        ledger.on_aggregate(&ghat, 1);
        assert_eq!(payload.values, vec![2.0, -3.0], "mean over ONE contributor");
        let violations = ledger.check(&StaleQueue::new());
        assert!(violations.is_empty(), "{violations:?}");
        // a new round admits the same client again
        s.begin_round();
        assert!(s.ingest(UploadSource::Sparse(&g), from0).applied);
    }

    #[test]
    fn streamed_ingest_is_bit_identical_to_decoded_ingest() {
        use crate::sparse::wire;
        let dim = 64;
        let grads = [
            SparseVec::new(dim, vec![(1, 0.125), (7, -3.5), (40, 1e-30)]),
            SparseVec::new(dim, vec![(0, 2.0), (7, 0.7), (63, -0.1)]),
        ];
        let mut a = FlServer::new(dim, BroadcastPolicy::Aggregate);
        let mut b = FlServer::new(dim, BroadcastPolicy::Aggregate);
        for g in &grads {
            recv(&mut a, g);
            let buf = wire::encode(g);
            let runs = Runs::validate(&buf).expect("encoded buffer validates");
            assert_eq!(b.ingest(UploadSource::Wire(&runs), IngestOpts::new()).nnz, g.nnz());
        }
        let (pa, _) = a.finish_round(grads.len());
        let (pb, _) = b.finish_round(grads.len());
        assert_eq!(pa.indices, pb.indices);
        assert_eq!(
            pa.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            pb.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn streamed_upload_guard_rejects_duplicates() {
        use crate::sparse::wire;
        let dim = 8;
        let mut s = FlServer::new(dim, BroadcastPolicy::Aggregate);
        let g = SparseVec::new(dim, vec![(2, 4.0)]);
        let buf = wire::encode(&g);
        let runs = Runs::validate(&buf).unwrap();
        s.begin_round();
        let from0 = IngestOpts::new().from_client(0);
        assert!(s.ingest(UploadSource::Wire(&runs), from0).applied);
        assert!(
            !s.ingest(UploadSource::Wire(&runs), from0).applied,
            "duplicate frame rejected"
        );
        let (p, _) = s.finish_round(1);
        assert_eq!(p.values, vec![4.0], "folded exactly once");
    }

    #[test]
    fn aggregate_resets_each_round() {
        let mut s = FlServer::new(4, BroadcastPolicy::Aggregate);
        recv(&mut s, &SparseVec::new(4, vec![(0, 4.0)]));
        let _ = s.finish_round(1);
        let (p, _) = s.finish_round(1);
        assert_eq!(p.nnz(), 0);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_receive_forwarders_match_ingest() {
        // the pre-consolidation API must stay callable and bit-identical
        use crate::sparse::wire;
        let dim = 16;
        let g1 = SparseVec::new(dim, vec![(1, 2.0), (9, -0.5)]);
        let g2 = SparseVec::new(dim, vec![(3, 4.0)]);
        let buf = wire::encode(&g2);
        let runs = Runs::validate(&buf).unwrap();

        let mut old = FlServer::new(dim, BroadcastPolicy::Aggregate);
        old.begin_round();
        old.receive(&g1);
        assert!(old.receive_upload(7, &g1));
        assert!(!old.receive_upload(7, &g1));
        assert_eq!(old.receive_stream(&runs), 1);
        assert!(old.receive_upload_streamed(8, &runs));
        old.receive_all(&[&g2], 1);
        old.receive_all_scaled(&[&g1], 0.5, 1);
        let (po, _) = old.finish_round(6);

        let mut new = FlServer::new(dim, BroadcastPolicy::Aggregate);
        new.begin_round();
        new.ingest(UploadSource::Sparse(&g1), IngestOpts::new());
        assert!(new.ingest(UploadSource::Sparse(&g1), IngestOpts::new().from_client(7)).applied);
        assert!(!new.ingest(UploadSource::Sparse(&g1), IngestOpts::new().from_client(7)).applied);
        assert_eq!(new.ingest(UploadSource::Wire(&runs), IngestOpts::new()).nnz, 1);
        assert!(new.ingest(UploadSource::Wire(&runs), IngestOpts::new().from_client(8)).applied);
        new.ingest(UploadSource::Batch(&[&g2]), IngestOpts::new().sharded(1));
        new.ingest(UploadSource::Batch(&[&g1]), IngestOpts::new().scaled(0.5));
        let (pn, _) = new.finish_round(6);

        assert_eq!(po.indices, pn.indices);
        assert_eq!(
            po.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            pn.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
