//! FL server: sparse aggregation + broadcast policy.
//!
//! The broadcast policy is where DGCwGM differs from every other scheme:
//! instead of broadcasting the round's aggregate `Ĝ_t`, the server keeps a
//! global momentum `M_t = β·M_{t-1} + Ĝ_t` and broadcasts **M_t**, whose
//! sparse support accumulates round over round ("making aggregated gradient
//! nearly full size in the future rounds" — paper §2.1/Fig. 1). The wire
//! layer's dense fallback then kicks in and the downlink grows — the +15.4%
//! overhead row of Table 3.

use crate::sparse::merge::Aggregator;
use crate::sparse::stream::Runs;
use crate::sparse::vector::SparseVec;

/// What the server sends back to clients each round.
#[derive(Clone, Debug)]
pub enum BroadcastPolicy {
    /// Broadcast the plain aggregate Ĝ_t (DGC, GMC, DGCwGMF).
    Aggregate,
    /// Broadcast the server-side global momentum (DGCwGM, paper §2.1).
    ServerMomentum { beta: f32 },
}

pub struct FlServer {
    dim: usize,
    agg: Aggregator,
    policy: BroadcastPolicy,
    /// server momentum state (ServerMomentum only)
    momentum: Vec<f32>,
    /// entries of |momentum| below this are dropped from the broadcast
    /// support (exact 0.0 keeps every touched coordinate forever)
    momentum_prune_eps: f32,
    /// per-round aggregate Ĝ_t scratch, reused across rounds
    ghat_scratch: SparseVec,
    /// clients whose upload already entered this round's aggregate — the
    /// idempotent-receive guard for [`FlServer::receive_upload`] (sorted)
    round_seen: Vec<usize>,
}

impl FlServer {
    pub fn new(dim: usize, policy: BroadcastPolicy) -> Self {
        let momentum = match policy {
            BroadcastPolicy::ServerMomentum { .. } => vec![0.0; dim],
            BroadcastPolicy::Aggregate => Vec::new(),
        };
        FlServer {
            dim,
            agg: Aggregator::new(dim),
            policy,
            momentum,
            momentum_prune_eps: 0.0,
            ghat_scratch: SparseVec::empty(dim),
            round_seen: Vec::new(),
        }
    }

    /// Open a round: reset the idempotent-receive guard. Callers feeding
    /// uploads through [`FlServer::receive_upload`] (the service round
    /// loop) must call this once per round; the batch paths
    /// ([`FlServer::receive_all`]) are unaffected.
    pub fn begin_round(&mut self) {
        self.round_seen.clear();
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Receive one (already-decoded) client gradient.
    pub fn receive(&mut self, g: &SparseVec) {
        self.agg.add(g);
    }

    /// Idempotent per-client receive: folds `g` into the aggregate unless
    /// `client` already contributed since the last [`FlServer::begin_round`]
    /// — a duplicated transport frame must never enter the mean twice.
    /// Returns whether the gradient was applied. Bit-identical to
    /// [`FlServer::receive`] calls in the same order when no duplicates
    /// occur.
    pub fn receive_upload(&mut self, client: usize, g: &SparseVec) -> bool {
        match self.round_seen.binary_search(&client) {
            Ok(_) => false,
            Err(at) => {
                self.round_seen.insert(at, client);
                self.agg.add(g);
                true
            }
        }
    }

    /// Receive one client gradient straight from a validated wire buffer,
    /// without materializing a [`SparseVec`]. Bit-identical to decoding the
    /// buffer and calling [`FlServer::receive`]: the pull-decoder emits the
    /// exact (index, value) pairs `decode_into` would produce, in the same
    /// order, and the fold applies the same `acc += 1.0 * v` expression the
    /// batch merge uses. Returns the number of runs folded.
    pub fn receive_stream(&mut self, runs: &Runs<'_>) -> usize {
        self.agg.fold_stream(runs, 1.0)
    }

    /// Idempotent streamed receive: [`FlServer::receive_upload`] over a
    /// validated wire buffer instead of a decoded gradient. Duplicated
    /// transport frames are rejected by the same per-round guard. Returns
    /// whether the upload was folded.
    pub fn receive_upload_streamed(&mut self, client: usize, runs: &Runs<'_>) -> bool {
        match self.round_seen.binary_search(&client) {
            Ok(_) => false,
            Err(at) => {
                self.round_seen.insert(at, client);
                self.agg.fold_stream(runs, 1.0);
                true
            }
        }
    }

    /// Receive a whole round of decoded client gradients at once. The merge
    /// may shard the coordinate space over up to `workers` threads and is
    /// bit-identical to sequential [`FlServer::receive`] calls in `grads`
    /// order.
    pub fn receive_all(&mut self, grads: &[&SparseVec], workers: usize) {
        self.agg.add_all(grads, workers);
    }

    /// Receive a batch of *carried-over* stale gradients (last round's
    /// deadline-missers), each scaled by the staleness discount `scale`
    /// before entering the aggregate. Same sharding and determinism
    /// contract as [`FlServer::receive_all`]; call it after the round's
    /// fresh gradients so the per-coordinate addition order is
    /// fresh-then-stale at every worker count.
    pub fn receive_all_scaled(&mut self, grads: &[&SparseVec], scale: f32, workers: usize) {
        self.agg.add_all_scaled(grads, scale, workers);
    }

    /// Allocation-free `finish_round`: writes the broadcast payload into a
    /// caller-owned reusable vector (cleared, capacity kept) and resets the
    /// aggregator for the next round. Under `ServerMomentum` the round
    /// aggregate Ĝ_t is retained internally (`ghat_scratch`) for the
    /// momentum update. The aggregate emit may shard over up to `workers`
    /// threads; results are bit-identical at any setting.
    pub fn finish_round_into(
        &mut self,
        participants: usize,
        payload: &mut SparseVec,
        workers: usize,
    ) {
        match self.policy {
            BroadcastPolicy::Aggregate => {
                // payload is Ĝ_t itself
                self.agg.finish_mean_into_with(participants, payload, workers);
            }
            BroadcastPolicy::ServerMomentum { beta } => {
                self.agg.finish_mean_into_with(participants, &mut self.ghat_scratch, workers);
                for m in self.momentum.iter_mut() {
                    *m *= beta;
                }
                self.ghat_scratch.add_into(&mut self.momentum, 1.0);
                payload.dim = self.dim;
                payload.indices.clear();
                payload.values.clear();
                let eps = self.momentum_prune_eps;
                for (i, &m) in self.momentum.iter().enumerate() {
                    // eps == 0.0 (default) keeps every nonzero coordinate —
                    // the support-only-accumulates behaviour the paper measures
                    let keep = if eps > 0.0 { m.abs() > eps } else { m != 0.0 };
                    if keep {
                        payload.indices.push(i as u32);
                        payload.values.push(m);
                    }
                }
            }
        }
    }

    /// The round aggregate Ĝ_t behind the last
    /// [`FlServer::finish_round_into`] call: the payload itself under the
    /// `Aggregate` policy, the retained `ghat_scratch` under
    /// `ServerMomentum` (whose payload is the momentum M_t, not Ĝ_t).
    /// The conformance ledger uses this so mass-conservation checks audit
    /// the aggregate, never the momentum state.
    pub fn round_aggregate<'a>(&'a self, payload: &'a SparseVec) -> &'a SparseVec {
        match self.policy {
            BroadcastPolicy::Aggregate => payload,
            BroadcastPolicy::ServerMomentum { .. } => &self.ghat_scratch,
        }
    }

    /// Close the round: aggregate the received gradients and produce
    /// (broadcast payload, aggregate Ĝ_t).
    ///
    /// The aggregate is what clients use for their model update bookkeeping
    /// in all schemes; under `ServerMomentum` the *payload* is M_t and the
    /// model update uses M_t as well (momentum SGD applied at the server).
    /// Allocating convenience wrapper over [`FlServer::finish_round_into`].
    pub fn finish_round(&mut self, participants: usize) -> (SparseVec, SparseVec) {
        let mut payload = SparseVec::empty(self.dim);
        self.finish_round_into(participants, &mut payload, 1);
        let ghat = match self.policy {
            BroadcastPolicy::Aggregate => payload.clone(),
            BroadcastPolicy::ServerMomentum { .. } => self.ghat_scratch.clone(),
        };
        (payload, ghat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_policy_broadcasts_mean() {
        let mut s = FlServer::new(6, BroadcastPolicy::Aggregate);
        s.receive(&SparseVec::new(6, vec![(1, 2.0)]));
        s.receive(&SparseVec::new(6, vec![(1, 4.0), (3, 2.0)]));
        let (payload, ghat) = s.finish_round(2);
        assert_eq!(payload, ghat);
        assert_eq!(ghat.indices, vec![1, 3]);
        assert_eq!(ghat.values, vec![3.0, 1.0]);
    }

    #[test]
    fn scaled_receive_discounts_stale_gradients() {
        let mut s = FlServer::new(6, BroadcastPolicy::Aggregate);
        s.receive(&SparseVec::new(6, vec![(1, 2.0)]));
        s.receive_all_scaled(&[&SparseVec::new(6, vec![(1, 2.0), (4, 4.0)])], 0.5, 1);
        let (payload, _) = s.finish_round(2);
        assert_eq!(payload.indices, vec![1, 4]);
        assert_eq!(payload.values, vec![1.5, 1.0]); // (2 + 1)/2, (0 + 2)/2
    }

    #[test]
    fn server_momentum_support_grows() {
        let mut s = FlServer::new(100, BroadcastPolicy::ServerMomentum { beta: 0.9 });
        // round 1: coords 0..10
        for i in 0..10u32 {
            s.receive(&SparseVec::new(100, vec![(i, 1.0)]));
        }
        let (p1, _) = s.finish_round(10);
        assert_eq!(p1.nnz(), 10);
        // round 2: different coords 50..60 — payload keeps the old support
        for i in 50..60u32 {
            s.receive(&SparseVec::new(100, vec![(i, 1.0)]));
        }
        let (p2, g2) = s.finish_round(10);
        assert_eq!(g2.nnz(), 10, "aggregate itself is sparse");
        assert_eq!(p2.nnz(), 20, "momentum payload accumulates support");
    }

    #[test]
    fn server_momentum_decays_values() {
        let mut s = FlServer::new(10, BroadcastPolicy::ServerMomentum { beta: 0.5 });
        s.receive(&SparseVec::new(10, vec![(2, 8.0)]));
        let (p1, _) = s.finish_round(1);
        assert_eq!(p1.values, vec![8.0]);
        let (p2, _) = s.finish_round(1); // no contributions: pure decay
        assert_eq!(p2.values, vec![4.0]);
    }

    #[test]
    fn round_aggregate_is_ghat_under_both_policies() {
        // Aggregate policy: the payload IS Ĝ_t
        let mut s = FlServer::new(6, BroadcastPolicy::Aggregate);
        s.receive(&SparseVec::new(6, vec![(1, 2.0)]));
        let (payload, ghat) = s.finish_round(1);
        assert_eq!(s.round_aggregate(&payload), &ghat);
        // ServerMomentum: the payload is M_t, the aggregate is Ĝ_t
        let mut m = FlServer::new(6, BroadcastPolicy::ServerMomentum { beta: 0.5 });
        m.receive(&SparseVec::new(6, vec![(2, 4.0)]));
        let (_, _) = m.finish_round(1);
        m.receive(&SparseVec::new(6, vec![(3, 2.0)]));
        let (p2, g2) = m.finish_round(1);
        assert_eq!(p2.nnz(), 2, "momentum payload keeps old support");
        assert_eq!(m.round_aggregate(&p2), &g2, "aggregate is the fresh Ĝ_t");
        assert_eq!(g2.indices, vec![3]);
    }

    #[test]
    fn duplicate_upload_is_rejected_and_mass_ledger_stays_balanced() {
        use crate::metrics::ledger::RoundLedger;
        use crate::sim::scheduler::{ClientFate, StalenessPolicy};
        use crate::sim::staleness::StaleQueue;
        use crate::testkit::invariants::MassLedger;
        let dim = 6;
        let mut s = FlServer::new(dim, BroadcastPolicy::Aggregate);
        let mut ledger = MassLedger::new(dim, StalenessPolicy::Drop);
        let g = SparseVec::new(dim, vec![(1, 2.0), (4, -3.0)]);
        s.begin_round();
        // the client uploaded once; the wire delivered the frame twice
        ledger.on_upload(0, ClientFate::Accepted, &g, 24, 24);
        assert!(s.receive_upload(0, &g), "first frame enters the aggregate");
        assert!(!s.receive_upload(0, &g), "duplicated frame must be rejected");
        let (payload, ghat) = s.finish_round(1);
        ledger.on_aggregate(&ghat, 1);
        assert_eq!(payload.values, vec![2.0, -3.0], "mean over ONE contributor");
        let violations = ledger.check(&StaleQueue::new());
        assert!(violations.is_empty(), "{violations:?}");
        // a new round admits the same client again
        s.begin_round();
        assert!(s.receive_upload(0, &g));
    }

    #[test]
    fn streamed_receive_is_bit_identical_to_decoded_receive() {
        use crate::sparse::wire;
        let dim = 64;
        let grads = [
            SparseVec::new(dim, vec![(1, 0.125), (7, -3.5), (40, 1e-30)]),
            SparseVec::new(dim, vec![(0, 2.0), (7, 0.7), (63, -0.1)]),
        ];
        let mut a = FlServer::new(dim, BroadcastPolicy::Aggregate);
        let mut b = FlServer::new(dim, BroadcastPolicy::Aggregate);
        for g in &grads {
            a.receive(g);
            let buf = wire::encode(g);
            let runs = Runs::validate(&buf).expect("encoded buffer validates");
            assert_eq!(b.receive_stream(&runs), g.nnz());
        }
        let (pa, _) = a.finish_round(grads.len());
        let (pb, _) = b.finish_round(grads.len());
        assert_eq!(pa.indices, pb.indices);
        assert_eq!(
            pa.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            pb.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn streamed_upload_guard_rejects_duplicates() {
        use crate::sparse::wire;
        let dim = 8;
        let mut s = FlServer::new(dim, BroadcastPolicy::Aggregate);
        let g = SparseVec::new(dim, vec![(2, 4.0)]);
        let buf = wire::encode(&g);
        let runs = Runs::validate(&buf).unwrap();
        s.begin_round();
        assert!(s.receive_upload_streamed(0, &runs));
        assert!(!s.receive_upload_streamed(0, &runs), "duplicate frame rejected");
        let (p, _) = s.finish_round(1);
        assert_eq!(p.values, vec![4.0], "folded exactly once");
    }

    #[test]
    fn aggregate_resets_each_round() {
        let mut s = FlServer::new(4, BroadcastPolicy::Aggregate);
        s.receive(&SparseVec::new(4, vec![(0, 4.0)]));
        let _ = s.finish_round(1);
        let (p, _) = s.finish_round(1);
        assert_eq!(p.nnz(), 0);
    }
}
