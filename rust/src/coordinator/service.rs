//! Service-mode round loop: `FlRun`'s coordinator logic replayed over a
//! [`Transport`] instead of direct method calls on in-process clients.
//!
//! The split mirrors a real deployment. [`ServiceRun`] owns everything
//! server-side — selection, fates, the stale queue, aggregation, the
//! broadcast codec, metering — and talks to clients exclusively through
//! `Transport::broadcast` / `Transport::collect`. [`ServiceClient`] owns
//! everything client-side — shard, engine, compressor, its own mirror of
//! the global parameters — and reacts to frames through the
//! [`ClientHandler`] trait, so the same client state machine runs behind
//! the in-process transport and behind a socket in another process.
//!
//! ## Digest identity with the simulator
//!
//! A service run over the loopback (or in-process) transport is required to
//! reproduce the in-process simulator's `trajectory_digest` **bit-exactly**
//! under [`service_config`]: same selection draws (both sides derive every
//! RNG from the run seed), same per-client training (the engine's
//! `train_step` is a pure function of `(params, batch)` and every client
//! re-derives `FlRun`'s per-client RNG), same scheduler arithmetic (the
//! simulated finish times are recomputed server-side from the arrived wire
//! bytes through the shared [`uplink_close`]), and same reduction order
//! (arrivals are re-walked in participant order, never arrival order).
//! Wall-clock effects — retries, timeouts, frame duplicates — land only in
//! the non-digested transport counter columns.
//!
//! ## Fates on the wire
//!
//! The simulator restores a dropped upload into the client residual in the
//! same iteration that decides its fate. Over a wire the client cannot know
//! its fate until the server tells it: the fate byte for round `r` rides on
//! the client's next ROUND frame (round `r + 1`) or the final DONE frame,
//! and [`ServiceClient::apply_fate`] performs exactly the restore the
//! simulator would have. The only exception is a plan-`drop` fault: the
//! client knows it never sent, restores immediately, and ignores the
//! (offline) fate echo.
//!
//! ## Late frames and mass conservation
//!
//! A frame for a closed round reaches the round loop through
//! [`RoundArrivals::late`]. It is folded into the stale queue **only** when
//! the staleness policy carries *and* the server fated that exact
//! `(client, round)` upload a straggler — i.e. the frame is a retransmit of
//! an upload the round already charged as carried. Any other late frame
//! (typically an offline-fated client whose upload limped in after the
//! deadline) is discarded: the fate byte told that client to restore its
//! residual in full, so aggregating the late copy would mint gradient mass
//! that exists nowhere else — exactly the double-count the
//! `MassLedger` invariant rejects.

use super::client::FlClient;
use super::round::{resolve_pool, FlConfig, FlRun, LrSchedule, RunSummary};
use super::sampler::{feasibility_weights, Sampler};
use super::server::{IngestOpts, UploadSource};
use crate::compress::{self, CompressorKind, HistorySignals, LinkSignals, RateDecision};
use crate::data::dataset::Dataset;
use crate::experiments::workload::verify_fixture;
use crate::metrics::recorder::RoundRecord;
use crate::runtime::TrainEngine;
use crate::sim::scheduler::{uplink_close, ClientFate, Scheduler, SelectionPolicy};
use crate::sparse::stream::Runs;
use crate::sparse::vector::SparseVec;
use crate::sparse::wire;
use crate::transport::fault::{FaultKind, FaultPlan, DELAY_S};
use crate::transport::framing::{FATE_ACCEPTED, FATE_NONE, FATE_OFFLINE, FATE_STRAGGLER};
use crate::transport::{ClientHandler, Transport, TransportStats, Upload};
use std::time::Instant;

/// Wire byte for a simulator fate.
pub fn fate_byte(fate: ClientFate) -> u8 {
    match fate {
        ClientFate::Accepted => FATE_ACCEPTED,
        ClientFate::Straggler => FATE_STRAGGLER,
        ClientFate::Offline => FATE_OFFLINE,
    }
}

/// The client half of a service run: `FlClient`'s compression state machine
/// plus everything `FlRun` used to do *for* the client — parameter mirror,
/// broadcast application, fate-driven residual restores — reacting to
/// transport frames.
pub struct ServiceClient {
    inner: FlClient,
    engine: Box<dyn TrainEngine>,
    cfg: FlConfig,
    /// this client's mirror of the synchronized global parameters
    params: Vec<f32>,
    /// last broadcast decoded (observed by GM/GMF compressors)
    last_payload: SparseVec,
    /// round whose upload is in flight, fate not yet known
    awaiting: Option<usize>,
    /// round whose residual was already restored client-side (plan-`drop`
    /// faults: the client knows it never sent) — the fate echo is ignored
    self_restored: Option<usize>,
    /// this client's own capability signals — measured locally in a real
    /// deployment, extracted from the shared network fixture here
    link: LinkSignals,
    /// mirrors of the server's `SelectionHistory` / `TrafficMeter` rows for
    /// this client, rebuilt from fate bytes alone: every settled fate is one
    /// selection, ACCEPTED is one delivery, and any non-offline fate charges
    /// the sent wire bytes (exactly the meter's bump rule — Offline uploads
    /// are never billed). These feed the rate controller the same inputs
    /// the simulator's planner reads server-side, so plans agree bit-exactly
    /// without any new protocol frames.
    sel_mirror: u64,
    del_mirror: u64,
    spent_mirror: u64,
    /// wire bytes of the in-flight upload, charged when its fate lands
    pending_bytes: usize,
}

impl ServiceClient {
    pub fn new(
        id: usize,
        cfg: FlConfig,
        shard: Box<dyn Dataset + Send>,
        engine: Box<dyn TrainEngine>,
        link: LinkSignals,
    ) -> Self {
        let dim = engine.param_count();
        let root = crate::util::rng::Rng::new(cfg.seed);
        let comp = compress::build(cfg.kind, &cfg.compress, dim);
        let inner = FlClient::new(id, comp, shard, &root, dim, cfg.codec.uplink);
        let params = engine.initial_params();
        ServiceClient {
            inner,
            engine,
            params,
            last_payload: SparseVec::empty(dim),
            awaiting: None,
            self_restored: None,
            link,
            sel_mirror: 0,
            del_mirror: 0,
            spent_mirror: 0,
            pending_bytes: 0,
            cfg,
        }
    }

    /// Laplace-smoothed delivery rate from the fate-byte mirror — the same
    /// `(delivered + 1) / (selected + 2)` the server's `SelectionHistory`
    /// computes, so both planners read identical history.
    fn mirror_hit_rate(&self) -> f64 {
        (self.del_mirror as f64 + 1.0) / (self.sel_mirror as f64 + 2.0)
    }

    /// Apply the server's verdict on the in-flight upload — the same
    /// residual restore `FlRun::step_round` performs, deferred until the
    /// fate byte reaches this side of the wire.
    fn apply_fate(&mut self, fate: u8) {
        let Some(round) = self.awaiting.take() else { return };
        // every settled fate is one selection event, mirroring the server's
        // `history.record(cid, ..)` for all participants (including plan-drop
        // clients, whom the server fates offline without an arrival)
        self.sel_mirror += 1;
        let sent = std::mem::take(&mut self.pending_bytes);
        if self.self_restored.take() == Some(round) {
            return; // plan-drop: restored at send time, fate echo is stale
        }
        match fate {
            FATE_ACCEPTED => {
                self.del_mirror += 1;
                self.spent_mirror += sent as u64;
            }
            // stragglers crossed the wire — carried or wasted, the meter
            // bills them either way; only offline uploads go unbilled
            FATE_STRAGGLER => self.spent_mirror += sent as u64,
            _ => {}
        }
        match fate {
            FATE_STRAGGLER => {
                let alpha = self.cfg.sim.staleness.alpha();
                if self.cfg.sim.staleness.carries() {
                    // the server buffered the upload and will apply α of it;
                    // only the unapplied fraction returns to the residual
                    if alpha < 1.0 {
                        self.inner.restore_dropped_upload_scaled(1.0 - alpha);
                    }
                } else {
                    self.inner.restore_dropped_upload();
                }
            }
            FATE_OFFLINE => self.inner.restore_dropped_upload(),
            _ => {} // accepted (or none): nothing to restore
        }
    }
}

impl ClientHandler for ServiceClient {
    fn id(&self) -> usize {
        self.inner.id
    }

    fn handle_round(
        &mut self,
        round: usize,
        payload: &[u8],
        participate: bool,
        fate: u8,
    ) -> anyhow::Result<Option<Upload>> {
        // 1. settle the previous round's upload (fate piggybacks here)
        self.apply_fate(fate);

        // 2. apply the broadcast: decode, fold into the parameter mirror at
        //    the *previous* round's learning rate (the payload is round
        //    r-1's aggregate), and let momentum-observing schemes see it
        if round > 0 && !payload.is_empty() {
            wire::decode_into(payload, &mut self.last_payload)
                .map_err(|e| anyhow::anyhow!("client {}: broadcast decode: {e:?}", self.inner.id))?;
            let lr = self.cfg.lr.at(round - 1);
            self.last_payload.add_into(&mut self.params, -lr);
            if self.inner.compressor.observes_broadcast() {
                self.inner.observe_broadcast(&self.last_payload);
            }
        }

        if !participate {
            return Ok(None);
        }

        // 3. local training + compression + wire encode, exactly the
        //    simulator's client fan-out body. With the rate controller on,
        //    the client plans its own effective k / value coding from the
        //    fate-byte mirror — identical inputs to the server-side planner,
        //    hence identical plans. The codec retarget happens here, strictly
        //    after step 1's `apply_fate`: a restore of the previous round's
        //    upload must still see the coding that upload was encoded with.
        let base_k = self.cfg.warmup.k_at(self.params.len(), round);
        let k = if self.cfg.rate_control.active() {
            let d = self.cfg.rate_control.plan(
                base_k,
                self.params.len(),
                self.cfg.codec.uplink.index,
                self.cfg.codec.uplink.value,
                self.link,
                HistorySignals {
                    hit_rate: self.mirror_hit_rate(),
                    times_selected: self.sel_mirror,
                    spent_bytes: self.spent_mirror,
                },
                self.cfg.sim.deadline_s,
                self.cfg.sim.compute_s,
                self.cfg.local_steps,
            );
            self.inner.set_uplink_value(d.value);
            d.k
        } else {
            base_k
        };
        let (loss, _, _) = self.inner.local_round(
            self.engine.as_mut(),
            &self.params,
            self.cfg.batch_size,
            self.cfg.local_steps,
            k,
            round,
        )?;
        self.awaiting = Some(round);
        self.pending_bytes = self.inner.wire_buf.len();

        // 4. a plan-`drop` fault silences the upload at the source; the
        //    client restores immediately (it knows nothing was sent)
        if matches!(self.cfg.fault, Some(p) if p.kind == FaultKind::Drop && p.hits(self.inner.id, round))
        {
            self.inner.restore_dropped_upload();
            self.self_restored = Some(round);
            return Ok(None);
        }

        Ok(Some(Upload {
            client: self.inner.id,
            round,
            loss,
            precodec_bytes: self.inner.precodec_bytes,
            bytes: self.inner.wire_buf.clone(),
        }))
    }

    fn handle_done(&mut self, fate: u8) -> anyhow::Result<()> {
        self.apply_fate(fate);
        Ok(())
    }
}

/// The server half of a service run: `FlRun`'s round loop with the client
/// fan-out replaced by transport frames. Wraps an `FlRun` for its state
/// (server, meter, scheduler, stale queue, history, recorder) — the wrapped
/// run's `clients` are never trained; clients live behind the transport.
pub struct ServiceRun {
    pub run: FlRun,
    /// wall-clock budget `Transport::collect` waits per round before closing
    /// the round with whoever arrived
    pub round_deadline_ms: u64,
    /// per-client fate byte of each client's *last* participation — rides
    /// on the next ROUND frame (clients ignore fates they already settled)
    wire_fates: Vec<u8>,
    /// last `(round, fate)` per client — gates late-frame admission
    last_fate: Vec<(usize, u8)>,
    fates: Vec<ClientFate>,
    finishes: Vec<f64>,
    weight_scratch: Vec<f64>,
    overlap_scratch: Vec<u32>,
    gini_scratch: Vec<f64>,
    /// decoded current-round arrivals, index-aligned with `uploads`
    /// (materialized ingest only; streamed ingest leaves this untouched)
    echo_scratch: Vec<SparseVec>,
    /// single reused decode target for the streamed path's on-demand
    /// materializations (ledger hooks, carried stragglers) — the only
    /// dimension-sized ingest scratch that path ever holds
    carry_scratch: SparseVec,
    payload_scratch: SparseVec,
    /// broadcast wire bytes of the previous round (what `broadcast` ships)
    bcast_buf: Vec<u8>,
    accepted_scratch: Vec<usize>,
    /// per-participant rate-controller plans recomputed server-side for the
    /// recorder's rate columns (reused; empty when the controller is off)
    decision_scratch: Vec<RateDecision>,
    prev_stats: TransportStats,
}

impl ServiceRun {
    pub fn new(run: FlRun, round_deadline_ms: u64) -> Self {
        let n = run.store.fleet_len();
        ServiceRun {
            wire_fates: vec![FATE_NONE; n],
            last_fate: vec![(usize::MAX, FATE_NONE); n],
            fates: Vec::new(),
            finishes: Vec::new(),
            weight_scratch: Vec::new(),
            overlap_scratch: Vec::new(),
            gini_scratch: Vec::new(),
            echo_scratch: Vec::new(),
            carry_scratch: SparseVec::empty(run.params.len()),
            payload_scratch: SparseVec::empty(run.params.len()),
            bcast_buf: Vec::new(),
            accepted_scratch: Vec::new(),
            decision_scratch: Vec::new(),
            prev_stats: TransportStats::default(),
            round_deadline_ms,
            run,
        }
    }

    /// One communication round over the transport. Mirrors
    /// `FlRun::step_round` stage for stage; every divergence is a comment.
    pub fn step_round(
        &mut self,
        transport: &mut dyn Transport,
        round: usize,
    ) -> anyhow::Result<RoundRecord> {
        let wall = Instant::now();
        let r = &mut self.run;
        r.meter.begin_round();
        r.stale_queue.begin_round();
        r.server.begin_round();
        if let Some(l) = r.ledger.as_deref_mut() {
            l.begin_round(round);
        }
        let root = crate::util::rng::Rng::new(r.cfg.seed);
        let participants = match r.cfg.sim.selection {
            SelectionPolicy::Uniform => r.cfg.sampler.sample_overselected(
                r.store.fleet_len(),
                round,
                &root,
                r.cfg.sim.overselect,
            ),
            SelectionPolicy::Feasibility { beta } => {
                feasibility_weights(
                    &r.history,
                    &r.meter.per_client_uplink,
                    r.store.fleet_len(),
                    beta,
                    &mut self.weight_scratch,
                );
                r.cfg.sampler.sample_weighted(
                    r.store.fleet_len(),
                    round,
                    &root,
                    r.cfg.sim.overselect,
                    &self.weight_scratch,
                )
            }
        };
        let n = participants.len();
        let pool = resolve_pool(r.cfg.workers);

        // recompute each participant's rate-controller plan from the
        // server-side history/meter — the same pure function the client
        // evaluates over its fate-byte mirror, so these are the plans the
        // arriving uploads were actually shaped by. Server-side they feed
        // only the recorder's (non-digested) rate columns.
        let dim = r.params.len();
        let base_k = r.cfg.warmup.k_at(dim, round);
        self.decision_scratch.clear();
        if r.cfg.rate_control.active() {
            for &cid in &participants {
                let p = r.scheduler.profile(cid);
                let d = r.cfg.rate_control.plan(
                    base_k,
                    dim,
                    r.cfg.codec.uplink.index,
                    r.cfg.codec.uplink.value,
                    LinkSignals {
                        up_bps: p.link.up_bps,
                        latency_s: p.link.latency_s,
                        compute_mult: p.compute_mult,
                    },
                    HistorySignals {
                        hit_rate: r.history.hit_rate(cid),
                        times_selected: r.history.times_selected(cid) as u64,
                        spent_bytes: r.meter.client_uplink(cid) as u64,
                    },
                    r.cfg.sim.deadline_s,
                    r.cfg.sim.compute_s,
                    r.cfg.local_steps,
                );
                self.decision_scratch.push(d);
            }
        }

        // open the round on the wire: the previous round's broadcast bytes
        // (empty on round 0) plus each client's pending fate byte
        transport.broadcast(round, &self.bcast_buf, &participants, &self.wire_fates)?;

        // a plan-`drop` client never sends — both sides derive that from the
        // shared plan, so the server must not wait out the deadline for it
        let fault = r.cfg.fault;
        let dropped_by_plan =
            |cid: usize| matches!(fault, Some(p) if p.kind == FaultKind::Drop && p.hits(cid, round));
        let expected: Vec<usize> =
            participants.iter().copied().filter(|&c| !dropped_by_plan(c)).collect();
        let arrivals = transport.collect(round, &expected, self.round_deadline_ms)?;

        // fates, in participant order: the simulator's schedule arithmetic
        // recomputed from the arrived wire bytes. The dropout RNG is drawn
        // per participant exactly as `plan_round` draws it.
        let mut drop_rng = root.derive(0xD30F ^ round as u64);
        self.fates.clear();
        self.finishes.clear();
        let deadline = r.cfg.sim.deadline_s;
        for &cid in &participants {
            let offline_draw = r.cfg.sim.dropout > 0.0 && drop_rng.f64() < r.cfg.sim.dropout;
            let arrived = arrivals.uploads.binary_search_by_key(&cid, |u| u.client).ok();
            let (fate, finish) = match arrived {
                // no frame: plan-drop, or a genuinely lost/timed-out client
                None => (ClientFate::Offline, 0.0),
                Some(_) if offline_draw => (ClientFate::Offline, 0.0),
                Some(i) => {
                    let up = &arrivals.uploads[i];
                    let mut finish = r
                        .scheduler
                        .compute_time(&r.cfg.sim, cid, r.cfg.local_steps)
                        + r.scheduler.uplink_time(cid, up.bytes.len());
                    if matches!(fault, Some(p) if p.kind == FaultKind::Delay && p.hits(cid, round))
                    {
                        finish += DELAY_S;
                    }
                    if deadline > 0.0 && finish > deadline {
                        (ClientFate::Straggler, finish)
                    } else {
                        (ClientFate::Accepted, finish)
                    }
                }
            };
            self.fates.push(fate);
            self.finishes.push(finish);
        }
        let uplink_phase = uplink_close(&r.cfg.sim, &self.fates, &self.finishes);

        // decode every current-round arrival once, index-aligned — unless
        // streamed ingest is on, which only *validates* each buffer here
        // (same errors, in the same arrival-walk order) and folds accepted
        // uploads straight from the bytes below. Exact mask overlap needs
        // every echo at once, so it keeps the materialized path.
        let materialize = !r.cfg.streamed_ingest || r.cfg.exact_mask_overlap;
        if materialize {
            if self.echo_scratch.len() < arrivals.uploads.len() {
                let dim = r.params.len();
                self.echo_scratch.resize_with(arrivals.uploads.len(), || SparseVec::empty(dim));
            }
            for (up, echo) in arrivals.uploads.iter().zip(self.echo_scratch.iter_mut()) {
                wire::decode_into(&up.bytes, echo)
                    .map_err(|e| anyhow::anyhow!("upload from client {}: {e:?}", up.client))?;
            }
        } else {
            for up in &arrivals.uploads {
                Runs::validate(&up.bytes)
                    .map_err(|e| anyhow::anyhow!("upload from client {}: {e:?}", up.client))?;
            }
        }

        // deterministic reductions, in participant order — never arrival
        // order. The client-side residual restores the simulator performs
        // here happen remotely when the fate byte lands (`apply_fate`).
        let alpha = r.cfg.sim.staleness.alpha();
        let carries = r.cfg.sim.staleness.carries();
        let empty_echo = SparseVec::empty(r.params.len());
        let mut train_loss = 0.0f64;
        let mut n_accepted = 0usize;
        let mut dropped_deadline = 0usize;
        let mut dropped_offline = 0usize;
        for (i, &cid) in participants.iter().enumerate() {
            let fate = self.fates[i];
            let at = arrivals.uploads.binary_search_by_key(&cid, |u| u.client).ok();
            let (bytes, precodec, loss) = match at {
                Some(j) => (
                    arrivals.uploads[j].bytes.len(),
                    arrivals.uploads[j].precodec_bytes,
                    arrivals.uploads[j].loss,
                ),
                None => (0, 0, 0.0),
            };
            // only the ledger hook and a carried straggler consume the
            // decoded gradient; the streamed path materializes it on demand
            // into one reused scratch instead of holding every arrival
            let echo: &SparseVec = match at {
                Some(j) if materialize => &self.echo_scratch[j],
                Some(j)
                    if r.ledger.is_some()
                        || (carries && fate == ClientFate::Straggler) =>
                {
                    wire::decode_into(&arrivals.uploads[j].bytes, &mut self.carry_scratch)
                        .map_err(|e| {
                            anyhow::anyhow!(
                                "upload from client {}: {e:?}",
                                arrivals.uploads[j].client
                            )
                        })?;
                    &self.carry_scratch
                }
                _ => &empty_echo,
            };
            if let Some(l) = r.ledger.as_deref_mut() {
                l.on_upload(cid, fate, echo, bytes, precodec);
            }
            match fate {
                ClientFate::Accepted => {
                    r.meter.record_uplink(cid, bytes, precodec);
                    r.history.record(cid, true);
                    train_loss += loss;
                    n_accepted += 1;
                }
                ClientFate::Straggler => {
                    r.history.record(cid, false);
                    dropped_deadline += 1;
                    if carries {
                        r.meter.record_carried_uplink(cid, bytes, precodec);
                        r.stale_queue.push(cid, round, bytes, echo);
                    } else {
                        r.meter.record_wasted_uplink(cid, bytes, precodec);
                    }
                }
                ClientFate::Offline => {
                    r.history.record(cid, false);
                    dropped_offline += 1;
                }
            }
            let fb = fate_byte(fate);
            self.wire_fates[cid] = fb;
            self.last_fate[cid] = (round, fb);
        }

        // accepted uploads in participant order: overlap diagnostic + merge
        self.accepted_scratch.clear();
        let overlap;
        if materialize {
            let mut accepted_echoes: Vec<&SparseVec> = Vec::with_capacity(n);
            for (i, &cid) in participants.iter().enumerate() {
                if self.fates[i] == ClientFate::Accepted {
                    if let Ok(j) = arrivals.uploads.binary_search_by_key(&cid, |u| u.client) {
                        accepted_echoes.push(&self.echo_scratch[j]);
                        self.accepted_scratch.push(cid);
                    }
                }
            }
            overlap = if r.cfg.exact_mask_overlap {
                crate::sparse::merge::mean_pairwise_jaccard(&accepted_echoes)
            } else {
                crate::sparse::merge::mean_jaccard_estimate(
                    &accepted_echoes,
                    &mut self.overlap_scratch,
                )
            };
            // idempotent per-(client, round) ingest — the transports already
            // deduplicate frames, this is the server-side backstop. Sequential
            // adds in participant order are bit-identical to the batch path.
            for (&cid, &echo) in self.accepted_scratch.iter().zip(accepted_echoes.iter()) {
                r.server.ingest(UploadSource::Sparse(echo), IngestOpts::new().from_client(cid));
            }
        } else {
            // streamed ingest: fold every accepted upload straight from its
            // (already validated) wire bytes, collecting its mask indices
            // for the overlap estimate along the way. Fold order is the
            // participant order, value expressions are the decoder's own —
            // the aggregate is bit-identical to the materialized merge.
            let scratch = &mut self.overlap_scratch;
            scratch.clear();
            for (i, &cid) in participants.iter().enumerate() {
                if self.fates[i] != ClientFate::Accepted {
                    continue;
                }
                let Ok(j) = arrivals.uploads.binary_search_by_key(&cid, |u| u.client) else {
                    continue;
                };
                let runs = Runs::validate(&arrivals.uploads[j].bytes).map_err(|e| {
                    anyhow::anyhow!("upload from client {}: {e:?}", arrivals.uploads[j].client)
                })?;
                runs.for_each(|idx, _| scratch.push(idx));
                r.server.ingest(UploadSource::Wire(&runs), IngestOpts::new().from_client(cid));
                self.accepted_scratch.push(cid);
            }
            overlap =
                crate::sparse::merge::jaccard_estimate_finish(self.accepted_scratch.len(), scratch);
        }
        let stale = r.stale_queue.ready();
        let carried_in = stale.len();
        let carried_bytes: usize = stale.iter().map(|e| e.bytes).sum();
        if carried_in > 0 {
            let stale_refs: Vec<&SparseVec> = stale.iter().map(|e| &e.grad).collect();
            r.server.ingest(
                UploadSource::Batch(&stale_refs),
                IngestOpts::new().scaled(alpha).sharded(pool),
            );
        }

        // late frames: admissible only as retransmits of carried stragglers
        // (see module docs — anything else would double-count mass). The
        // queue's (client, round) idempotence rejects true duplicates.
        if carries {
            for up in &arrivals.late {
                if self.last_fate.get(up.client).copied() != Some((up.round, FATE_STRAGGLER)) {
                    continue;
                }
                let mut g = SparseVec::empty(0);
                if wire::decode_into(&up.bytes, &mut g).is_ok() {
                    r.stale_queue.push(up.client, up.round, up.bytes.len(), &g);
                }
            }
        }

        train_loss /= n_accepted.max(1) as f64;

        // aggregate + broadcast through the persistent wire buffers
        r.server.finish_round_into(n_accepted + carried_in, &mut self.payload_scratch, pool);
        if let Some(l) = r.ledger.as_deref_mut() {
            let aggregate = r.server.round_aggregate(&self.payload_scratch);
            l.on_aggregate(aggregate, n_accepted + carried_in);
        }
        r.stale_queue.recycle_ready();
        wire::encode_with(&self.payload_scratch, &mut self.bcast_buf, r.cfg.codec.downlink);
        let bcast_precodec = wire::encoded_bytes(&self.payload_scratch);
        r.meter.record_broadcast(self.bcast_buf.len(), bcast_precodec, n);
        // a malformed broadcast is a transport-grade failure, not a panic:
        // surface it through the round result like every other decode site
        super::decode_broadcast(&self.bcast_buf, &mut r.last_payload)?;

        // the server's own parameter mirror (clients apply the identical
        // update when the broadcast frame reaches them next round)
        let lr = r.cfg.lr.at(round);
        r.last_payload.add_into(&mut r.params, -lr);

        let sim_s = uplink_phase
            + r.scheduler.broadcast_time(self.bcast_buf.len(), &self.accepted_scratch);
        let sim_clock = r.scheduler.advance(sim_s);

        // transport counters: per-round deltas of the backend's totals
        let stats = transport.stats();
        let d = stats.delta(&self.prev_stats);
        self.prev_stats = stats;

        let traffic_gini = r.meter.uplink_gini(r.store.fleet_len(), &mut self.gini_scratch);
        // rate-control diagnostics, mirroring `FlRun::step_round` (and like
        // it, never digested)
        let shared_rate = if dim > 0 { base_k as f64 / dim as f64 } else { 0.0 };
        let (rate_mean, rate_min, rate_max, coding_downshifts) =
            if self.decision_scratch.is_empty() {
                (shared_rate, shared_rate, shared_rate, 0)
            } else {
                let mut sum = 0.0f64;
                let mut lo = f64::INFINITY;
                let mut hi = 0.0f64;
                let mut shifts = 0usize;
                for d in &self.decision_scratch {
                    sum += d.rate;
                    lo = lo.min(d.rate);
                    hi = hi.max(d.rate);
                    shifts += d.downshifted as usize;
                }
                (sum / self.decision_scratch.len() as f64, lo, hi, shifts)
            };
        let rec = RoundRecord {
            round,
            train_loss,
            test_loss: 0.0,
            test_accuracy: 0.0,
            uplink_bytes: r.meter.round_uplink,
            downlink_bytes: r.meter.round_downlink,
            aggregate_nnz: r.last_payload.nnz(),
            mask_overlap: overlap,
            sim_seconds: sim_s,
            wall_seconds: wall.elapsed().as_secs_f64(),
            selected: n,
            dropped_deadline,
            dropped_offline,
            sim_clock,
            wasted_uplink_bytes: r.meter.round_wasted_uplink,
            carried_in,
            carried_bytes,
            traffic_gini,
            precodec_bytes: r.meter.round_precodec,
            codec_ratio: r.meter.round_codec_ratio(),
            retries: d.retries,
            timeouts: d.timeouts,
            stale_frames: d.stale_frames,
            dup_frames: d.dup_frames,
            // the edge tier is a simulator topology model; service fleets
            // talk to the hub directly, so the tier-1 columns stay zero
            edge_count: 0,
            edge_uplink_bytes: 0,
            edge_downlink_bytes: 0,
            edge_backhaul_s: 0.0,
            rate_mean,
            rate_min,
            rate_max,
            coding_downshifts,
        };
        r.recorder.push(rec.clone());
        Ok(rec)
    }

    /// Drive the configured number of rounds, then release the fleet with
    /// their final fates.
    pub fn run(&mut self, transport: &mut dyn Transport) -> anyhow::Result<RunSummary> {
        for round in 0..self.run.cfg.rounds {
            self.step_round(transport, round)?;
        }
        transport.shutdown(&self.wire_fates)?;
        Ok(self.run.summary())
    }
}

/// The canonical service-mode `FlConfig`: deterministic regardless of
/// wall-clock (no sim deadline, no dropout), DGC+GMF at rate 0.25, a fixed
/// 3/5 cohort — shared by `fedgmf serve`, `fedgmf client` and the
/// digest-identity tests so every party derives the identical run from
/// `(clients, rounds, seed, fault)` alone.
pub fn service_config(
    clients: usize,
    rounds: usize,
    seed: u64,
    fault: Option<FaultPlan>,
) -> FlConfig {
    let mut cfg = FlConfig::new(CompressorKind::DgcWgmf, 0.25, rounds);
    cfg.lr = LrSchedule::constant(0.3);
    cfg.warmup.warmup_rounds = 2;
    cfg.sampler = Sampler::Count((clients * 3 / 5).max(1));
    cfg.eval_every = 0;
    cfg.seed = seed;
    cfg.workers = 1;
    cfg.fault = fault;
    cfg
}

/// Server-side state for a service run: the shared fixture's engine seeds
/// the parameter mirror; the fixture's shards ride along untrained (clients
/// live behind the transport).
pub fn build_service_run(
    clients: usize,
    rounds: usize,
    seed: u64,
    fault: Option<FaultPlan>,
) -> FlRun {
    let fx = verify_fixture(clients, seed);
    let cfg = service_config(clients, rounds, seed, fault);
    FlRun::new(&fx.engine, fx.shards, Vec::new(), fx.network, cfg)
}

/// One client's half of the same run: shard `id` of the shared fixture plus
/// its own engine instance (identically seeded, hence identical initial
/// parameters).
pub fn build_service_client(
    clients: usize,
    id: usize,
    rounds: usize,
    seed: u64,
    fault: Option<FaultPlan>,
) -> ServiceClient {
    assert!(id < clients, "client id {id} out of range for {clients} clients");
    let mut fx = verify_fixture(clients, seed);
    let cfg = service_config(clients, rounds, seed, fault);
    let shard = fx.shards.remove(id);
    // the client's own capability profile: in a real fleet the device
    // measures this; here both sides derive it from the shared fixture
    // network through the same deterministic scheduler construction, so the
    // client's rate-controller inputs equal the server's
    let sched = Scheduler::new(&fx.network, cfg.sim.preset, cfg.seed);
    let p = sched.profile(id);
    let link = LinkSignals {
        up_bps: p.link.up_bps,
        latency_s: p.link.latency_s,
        compute_mult: p.compute_mult,
    };
    ServiceClient::new(id, cfg, shard, Box::new(fx.engine), link)
}

/// The full fleet as in-process handlers (for `InProcTransport` and tests).
pub fn build_service_handlers(
    clients: usize,
    rounds: usize,
    seed: u64,
    fault: Option<FaultPlan>,
) -> Vec<Box<dyn ClientHandler>> {
    (0..clients)
        .map(|id| {
            Box::new(build_service_client(clients, id, rounds, seed, fault))
                as Box<dyn ClientHandler>
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::RateControlMode;
    use crate::sim::scheduler::{ProfilePreset, StalenessPolicy};
    use crate::testkit::digest::trajectory_digest;
    use crate::transport::inproc::InProcTransport;
    use crate::transport::TransportConfig;

    fn param_bits(params: &[f32]) -> Vec<u32> {
        params.iter().map(|p| p.to_bits()).collect()
    }

    fn sim_digest(clients: usize, rounds: usize, seed: u64, fault: Option<FaultPlan>) -> u64 {
        let fx = verify_fixture(clients, seed);
        let mut engine = fx.engine;
        let cfg = service_config(clients, rounds, seed, fault);
        let mut run = FlRun::new(&engine, fx.shards, Vec::new(), fx.network, cfg);
        run.run(&mut engine).unwrap();
        trajectory_digest(&param_bits(&run.params), &run.recorder.rounds)
    }

    fn service_digest_with(
        clients: usize,
        rounds: usize,
        seed: u64,
        fault: Option<FaultPlan>,
        streamed: bool,
    ) -> u64 {
        let mut cfg = TransportConfig::default();
        cfg.fault = fault;
        let handlers = build_service_handlers(clients, rounds, seed, fault);
        let mut transport = InProcTransport::new(handlers, cfg);
        let mut run = build_service_run(clients, rounds, seed, fault);
        run.cfg.streamed_ingest = streamed;
        let mut service = ServiceRun::new(run, 1000);
        service.run(&mut transport).unwrap();
        trajectory_digest(&param_bits(&service.run.params), &service.run.recorder.rounds)
    }

    fn service_digest(clients: usize, rounds: usize, seed: u64, fault: Option<FaultPlan>) -> u64 {
        service_digest_with(clients, rounds, seed, fault, false)
    }

    #[test]
    fn service_run_matches_simulator_digest() {
        assert_eq!(
            sim_digest(6, 4, 42, None),
            service_digest(6, 4, 42, None),
            "fault-free service run must be digest-identical to the simulator"
        );
    }

    #[test]
    fn service_run_matches_simulator_digest_under_drop_plan() {
        let plan = Some(FaultPlan::new(FaultKind::Drop, 0.35, 7));
        assert_eq!(
            sim_digest(6, 5, 42, plan),
            service_digest(6, 5, 42, plan),
            "drop-faulted service run must be digest-identical to the simulator"
        );
    }

    #[test]
    fn streamed_service_ingest_matches_materialized_digest() {
        assert_eq!(
            service_digest_with(6, 4, 42, None, false),
            service_digest_with(6, 4, 42, None, true),
            "streamed ingest must not move the service digest"
        );
        let plan = Some(FaultPlan::new(FaultKind::Duplicate, 0.5, 3));
        assert_eq!(
            service_digest_with(6, 4, 42, plan, false),
            service_digest_with(6, 4, 42, plan, true),
            "streamed ingest must absorb duplicated frames identically"
        );
    }

    /// `service_config` with the rate controller on over a straggler-prone
    /// heterogeneous fleet — the config under which client and server must
    /// re-derive identical per-client plans from fate bytes alone.
    fn adaptive_cfg(clients: usize, rounds: usize, seed: u64) -> FlConfig {
        let mut cfg = service_config(clients, rounds, seed, None);
        cfg.rate_control.mode = RateControlMode::Adaptive;
        cfg.sim.preset = ProfilePreset::Heterogeneous { slow_every: 2, slow_factor: 8.0 };
        cfg.sim.deadline_s = 0.05;
        cfg.sim.compute_s = 0.01;
        cfg.sim.staleness = StalenessPolicy::CarryDiscounted(0.5);
        cfg
    }

    fn sim_digest_adaptive(clients: usize, rounds: usize, seed: u64) -> u64 {
        let fx = verify_fixture(clients, seed);
        let mut engine = fx.engine;
        let cfg = adaptive_cfg(clients, rounds, seed);
        let mut run = FlRun::new(&engine, fx.shards, Vec::new(), fx.network, cfg);
        run.run(&mut engine).unwrap();
        trajectory_digest(&param_bits(&run.params), &run.recorder.rounds)
    }

    fn service_digest_adaptive(clients: usize, rounds: usize, seed: u64) -> u64 {
        let handlers: Vec<Box<dyn ClientHandler>> = (0..clients)
            .map(|id| {
                let mut fx = verify_fixture(clients, seed);
                let cfg = adaptive_cfg(clients, rounds, seed);
                let shard = fx.shards.remove(id);
                let sched = Scheduler::new(&fx.network, cfg.sim.preset, cfg.seed);
                let p = sched.profile(id);
                let link = LinkSignals {
                    up_bps: p.link.up_bps,
                    latency_s: p.link.latency_s,
                    compute_mult: p.compute_mult,
                };
                Box::new(ServiceClient::new(id, cfg, shard, Box::new(fx.engine), link))
                    as Box<dyn ClientHandler>
            })
            .collect();
        let mut transport = InProcTransport::new(handlers, TransportConfig::default());
        let fx = verify_fixture(clients, seed);
        let run = FlRun::new(
            &fx.engine,
            fx.shards,
            Vec::new(),
            fx.network,
            adaptive_cfg(clients, rounds, seed),
        );
        let mut service = ServiceRun::new(run, 1000);
        service.run(&mut transport).unwrap();
        trajectory_digest(&param_bits(&service.run.params), &service.run.recorder.rounds)
    }

    #[test]
    fn adaptive_service_run_matches_simulator_digest() {
        // the closed loop's headline guarantee: with per-client k and value
        // coding re-planned every round, the fate-byte mirror gives the
        // client planner bit-identical inputs to the server's, so the whole
        // trajectory — straggler fates, scaled carry restores, per-client
        // codec switches included — survives the move onto the wire
        assert_eq!(
            sim_digest_adaptive(6, 6, 42),
            service_digest_adaptive(6, 6, 42),
            "adaptive service run must be digest-identical to the simulator"
        );
    }

    #[test]
    fn adaptive_service_rounds_actually_diverge_rates() {
        // guard against the identity above passing vacuously: the
        // heterogeneous fleet must produce a genuine per-client rate spread
        let fx = verify_fixture(6, 42);
        let mut engine = fx.engine;
        let cfg = adaptive_cfg(6, 6, 42);
        let mut run = FlRun::new(&engine, fx.shards, Vec::new(), fx.network, cfg);
        run.run(&mut engine).unwrap();
        let spread = run
            .recorder
            .rounds
            .iter()
            .any(|r| r.rate_max - r.rate_min > 1e-9);
        assert!(spread, "adaptive plans never diverged across a bimodal fleet");
    }

    #[test]
    fn service_run_books_transport_counters_outside_the_digest() {
        let plan = Some(FaultPlan::new(FaultKind::Duplicate, 0.5, 3));
        let d_sim = sim_digest(6, 4, 42, plan);
        let d_svc = service_digest(6, 4, 42, plan);
        assert_eq!(d_sim, d_svc, "duplicated frames are absorbed before the digest");

        let mut cfg = TransportConfig::default();
        cfg.fault = plan;
        let handlers = build_service_handlers(6, 4, 42, plan);
        let mut transport = InProcTransport::new(handlers, cfg);
        let mut service = ServiceRun::new(build_service_run(6, 4, 42, plan), 1000);
        service.run(&mut transport).unwrap();
        let dups: usize = service.run.recorder.rounds.iter().map(|r| r.dup_frames).sum();
        assert!(dups > 0, "duplicate plan at rate 0.5 must book dup frames");
    }
}
