//! FL client: local data shard + compression state + persistent round
//! buffers.
//!
//! The model itself stays synchronized across clients (every client applies
//! the same broadcast update, Alg. 1 line 15), so the run keeps a single
//! parameter vector and each client owns only its *divergent* state: the
//! compressor memory (U, V, M), its data shard, and the reusable buffers the
//! round hot path writes into (`grad_acc`, `upload`, `wire_buf`, `echo`) —
//! after the first round a client round performs no heap allocation for
//! gradient accumulation, compression output or wire encode/decode.
//!
//! ## Wire codec + quantisation error feedback
//!
//! The upload is serialised through the run's uplink [`CodecParams`]. Under
//! a lossy value coding (f16/q8) the bytes on the wire carry `Q(upload)`,
//! not `upload` — so immediately after the encode/decode round-trip the
//! client folds the quantisation error `upload − echo` back into the
//! compressor residual V ([`Compressor::restore_upload`]). From that point
//! on the *in-flight* mass is exactly `echo` (what the server will see):
//! a deadline miss or dropout restores `echo`, not `upload`, and the
//! DGC/GMC/GMF error-feedback invariant — nothing the client computed is
//! ever lost — holds bit-for-bit at every codec setting. Under the default
//! f32 coding the round-trip is exact (`echo == upload`), no error is
//! restored, and behaviour is byte- and bit-identical to codec v1.
//!
//! All per-round state is exclusively per-client, which is what lets the
//! coordinator fan `local_round` calls out over worker threads with results
//! bit-identical to sequential execution.

use crate::compress::Compressor;
use crate::data::dataset::{Batch, Dataset};
use crate::runtime::TrainEngine;
use crate::sparse::codec::CodecParams;
use crate::sparse::vector::SparseVec;
use crate::sparse::wire;
use crate::util::rng::Rng;

pub struct FlClient {
    pub id: usize,
    pub compressor: Box<dyn Compressor>,
    pub shard: Box<dyn Dataset + Send>,
    pub rng: Rng,
    /// uplink wire codec for this run
    codec: CodecParams,
    /// local-gradient accumulator, zeroed and refilled each round
    grad_acc: Vec<f32>,
    /// compressed upload, reused round over round (capacity kept)
    pub upload: SparseVec,
    /// serialised upload — the bytes that actually cross the wire
    pub wire_buf: Vec<u8>,
    /// the upload decoded back, i.e. the gradient as the server sees it
    pub echo: SparseVec,
    /// v1-equivalent (raw u32 + f32) bytes of the last upload — the
    /// pre-codec size the traffic meter reports byte reduction against
    pub precodec_bytes: usize,
    /// quantisation error (`upload − echo`) scratch, reused across rounds
    quant_err: SparseVec,
}

impl FlClient {
    pub fn new(
        id: usize,
        compressor: Box<dyn Compressor>,
        shard: Box<dyn Dataset + Send>,
        root_rng: &Rng,
        dim: usize,
        codec: CodecParams,
    ) -> Self {
        FlClient {
            id,
            compressor,
            shard,
            rng: root_rng.derive(0xC11E ^ id as u64),
            codec,
            grad_acc: vec![0.0; dim],
            upload: SparseVec::empty(dim),
            wire_buf: Vec::new(),
            echo: SparseVec::empty(dim),
            precodec_bytes: 0,
            quant_err: SparseVec::empty(dim),
        }
    }

    /// Receive the round broadcast (Alg. 1 line 14 → line 8 of the next
    /// round's momentum accumulate).
    pub fn observe_broadcast(&mut self, payload: &SparseVec) {
        self.compressor.observe_broadcast(payload);
    }

    /// Retarget the uplink value coding for the *next* `local_round` (the
    /// per-client rate controller may coarsen f32 → f16 → q8 round over
    /// round). Must not be called between a round's compress and its
    /// restore: `restore_dropped_upload*` picks `echo` vs `upload` from
    /// the codec the payload was encoded with, so the round loop and the
    /// service client both set this before fan-out / after fates settle.
    pub fn set_uplink_value(&mut self, value: crate::sparse::codec::ValueCoding) {
        self.codec.value = value;
    }

    /// The uplink codec currently in effect (test/diagnostic accessor).
    pub fn uplink_codec(&self) -> CodecParams {
        self.codec
    }

    /// The server never saw this round's upload (deadline miss or hard
    /// dropout): fold the in-flight values back into the compressor's
    /// residual so the mass re-enters a later round's top-k selection.
    /// Under a lossy value coding the in-flight mass is `echo` (the
    /// quantisation error `upload − echo` was already restored at compress
    /// time); under exact f32 coding it is `upload`, byte-for-byte the
    /// pre-codec behaviour.
    pub fn restore_dropped_upload(&mut self) {
        if self.codec.lossy() {
            self.compressor.restore_upload(&self.echo);
        } else {
            self.compressor.restore_upload(&self.upload);
        }
    }

    /// Carry-discount restore: the server buffered this round's late upload
    /// and will apply `α` of it next round, so only the unapplied
    /// `scale = 1 − α` fraction of the in-flight mass returns to the
    /// residual — together the two halves conserve the upload's gradient
    /// mass exactly (the server aggregates `echo`, so the in-flight mass is
    /// `echo` under lossy codings, `upload` under exact f32).
    pub fn restore_dropped_upload_scaled(&mut self, scale: f32) {
        if self.codec.lossy() {
            self.compressor.restore_upload_scaled(&self.echo, scale);
        } else {
            self.compressor.restore_upload_scaled(&self.upload, scale);
        }
    }

    /// One local round, entirely into the persistent buffers: compute the
    /// local gradient at the current global parameters (averaged over
    /// `local_steps` minibatches), compress it into `upload`, serialise
    /// through the uplink codec into `wire_buf`, decode into `echo`, and —
    /// under a lossy value coding — restore the quantisation error into
    /// the compressor residual.
    ///
    /// Returns (mean training loss, #correct, #seen).
    pub fn local_round(
        &mut self,
        engine: &mut dyn TrainEngine,
        params: &[f32],
        batch_size: usize,
        local_steps: usize,
        k: usize,
        round: usize,
    ) -> anyhow::Result<(f64, usize, usize)> {
        debug_assert_eq!(self.grad_acc.len(), params.len());
        self.grad_acc.iter_mut().for_each(|a| *a = 0.0);
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        let mut seen = 0usize;
        for _ in 0..local_steps.max(1) {
            let batch: Batch = self.shard.sample_batch(batch_size, &mut self.rng);
            let out = engine.train_step(params, &batch)?;
            for (a, g) in self.grad_acc.iter_mut().zip(&out.grads) {
                *a += g;
            }
            loss_sum += out.loss;
            correct += out.ncorrect;
            seen += batch.prediction_count();
        }
        let steps = local_steps.max(1) as f32;
        if steps > 1.0 {
            for a in self.grad_acc.iter_mut() {
                *a /= steps;
            }
        }
        let _threshold = self.compressor.compress_into(&self.grad_acc, k, round, &mut self.upload);
        self.precodec_bytes = wire::encoded_bytes(&self.upload);
        wire::encode_with(&self.upload, &mut self.wire_buf, self.codec);
        wire::decode_into(&self.wire_buf, &mut self.echo)
            .expect("self-encoded gradient must decode");
        if self.codec.lossy() {
            // error feedback absorbs the wire's quantisation error: what the
            // encoder rounded away re-enters a later round's top-k selection
            self.upload.diff_into(&self.echo, &mut self.quant_err);
            self.compressor.restore_upload(&self.quant_err);
        }
        Ok((loss_sum / steps as f64, correct, seen))
    }
}
