//! FL client: local data shard + compression state + persistent round
//! buffers.
//!
//! The model itself stays synchronized across clients (every client applies
//! the same broadcast update, Alg. 1 line 15), so the run keeps a single
//! parameter vector and each client owns only its *divergent* state: the
//! compressor memory (U, V, M), its data shard, and the reusable buffers the
//! round hot path writes into (`grad_acc`, `upload`, `wire_buf`, `echo`) —
//! after the first round a client round performs no heap allocation for
//! gradient accumulation, compression output or wire encode/decode.
//!
//! All per-round state is exclusively per-client, which is what lets the
//! coordinator fan `local_round` calls out over worker threads with results
//! bit-identical to sequential execution.

use crate::compress::Compressor;
use crate::data::dataset::{Batch, Dataset};
use crate::runtime::TrainEngine;
use crate::sparse::vector::SparseVec;
use crate::sparse::wire;
use crate::util::rng::Rng;

pub struct FlClient {
    pub id: usize,
    pub compressor: Box<dyn Compressor>,
    pub shard: Box<dyn Dataset + Send>,
    pub rng: Rng,
    /// local-gradient accumulator, zeroed and refilled each round
    grad_acc: Vec<f32>,
    /// compressed upload, reused round over round (capacity kept)
    pub upload: SparseVec,
    /// serialised upload — the bytes that actually cross the wire
    pub wire_buf: Vec<u8>,
    /// the upload decoded back, i.e. the gradient as the server sees it
    pub echo: SparseVec,
}

impl FlClient {
    pub fn new(
        id: usize,
        compressor: Box<dyn Compressor>,
        shard: Box<dyn Dataset + Send>,
        root_rng: &Rng,
        dim: usize,
    ) -> Self {
        FlClient {
            id,
            compressor,
            shard,
            rng: root_rng.derive(0xC11E ^ id as u64),
            grad_acc: vec![0.0; dim],
            upload: SparseVec::empty(dim),
            wire_buf: Vec::new(),
            echo: SparseVec::empty(dim),
        }
    }

    /// Receive the round broadcast (Alg. 1 line 14 → line 8 of the next
    /// round's momentum accumulate).
    pub fn observe_broadcast(&mut self, payload: &SparseVec) {
        self.compressor.observe_broadcast(payload);
    }

    /// The server never saw this round's upload (deadline miss or hard
    /// dropout): fold the extracted values back into the compressor's
    /// residual so the mass re-enters a later round's top-k selection.
    pub fn restore_dropped_upload(&mut self) {
        self.compressor.restore_upload(&self.upload);
    }

    /// Carry-discount restore: the server buffered this round's late upload
    /// and will apply `α` of it next round, so only the unapplied
    /// `scale = 1 − α` fraction returns to the residual — together the two
    /// halves conserve the upload's gradient mass exactly.
    pub fn restore_dropped_upload_scaled(&mut self, scale: f32) {
        self.compressor.restore_upload_scaled(&self.upload, scale);
    }

    /// One local round, entirely into the persistent buffers: compute the
    /// local gradient at the current global parameters (averaged over
    /// `local_steps` minibatches), compress it into `upload`, serialise into
    /// `wire_buf` and decode into `echo`.
    ///
    /// Returns (mean training loss, #correct, #seen).
    pub fn local_round(
        &mut self,
        engine: &mut dyn TrainEngine,
        params: &[f32],
        batch_size: usize,
        local_steps: usize,
        k: usize,
        round: usize,
    ) -> anyhow::Result<(f64, usize, usize)> {
        debug_assert_eq!(self.grad_acc.len(), params.len());
        self.grad_acc.iter_mut().for_each(|a| *a = 0.0);
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        let mut seen = 0usize;
        for _ in 0..local_steps.max(1) {
            let batch: Batch = self.shard.sample_batch(batch_size, &mut self.rng);
            let out = engine.train_step(params, &batch)?;
            for (a, g) in self.grad_acc.iter_mut().zip(&out.grads) {
                *a += g;
            }
            loss_sum += out.loss;
            correct += out.ncorrect;
            seen += batch.prediction_count();
        }
        let steps = local_steps.max(1) as f32;
        if steps > 1.0 {
            for a in self.grad_acc.iter_mut() {
                *a /= steps;
            }
        }
        let _threshold = self.compressor.compress_into(&self.grad_acc, k, round, &mut self.upload);
        wire::encode_into(&self.upload, &mut self.wire_buf);
        wire::decode_into(&self.wire_buf, &mut self.echo)
            .expect("self-encoded gradient must decode");
        Ok((loss_sum / steps as f64, correct, seen))
    }
}
