//! FL client: local data shard + compression state.
//!
//! The model itself stays synchronized across clients (every client applies
//! the same broadcast update, Alg. 1 line 15), so the run keeps a single
//! parameter vector and each client owns only its *divergent* state: the
//! compressor memory (U, V, M) and its data shard.

use crate::compress::{Compressed, Compressor};
use crate::data::dataset::{Batch, Dataset};
use crate::runtime::TrainEngine;
use crate::sparse::vector::SparseVec;
use crate::util::rng::Rng;

pub struct FlClient {
    pub id: usize,
    pub compressor: Box<dyn Compressor>,
    pub shard: Box<dyn Dataset + Send>,
    pub rng: Rng,
}

impl FlClient {
    pub fn new(
        id: usize,
        compressor: Box<dyn Compressor>,
        shard: Box<dyn Dataset + Send>,
        root_rng: &Rng,
    ) -> Self {
        FlClient { id, compressor, shard, rng: root_rng.derive(0xC11E ^ id as u64) }
    }

    /// Receive the round broadcast (Alg. 1 line 14 → line 8 of the next
    /// round's momentum accumulate).
    pub fn observe_broadcast(&mut self, payload: &SparseVec) {
        self.compressor.observe_broadcast(payload);
    }

    /// One local round: compute the local gradient at the current global
    /// parameters (averaged over `local_steps` minibatches) and compress it.
    ///
    /// Returns (compressed upload, mean training loss, #correct, #seen).
    pub fn local_round(
        &mut self,
        engine: &mut dyn TrainEngine,
        params: &[f32],
        batch_size: usize,
        local_steps: usize,
        k: usize,
        round: usize,
    ) -> anyhow::Result<(Compressed, f64, usize, usize)> {
        let mut grad_acc: Vec<f32> = vec![0.0; params.len()];
        let mut loss_sum = 0.0;
        let mut correct = 0usize;
        let mut seen = 0usize;
        for _ in 0..local_steps.max(1) {
            let batch: Batch = self.shard.sample_batch(batch_size, &mut self.rng);
            let out = engine.train_step(params, &batch)?;
            for (a, g) in grad_acc.iter_mut().zip(&out.grads) {
                *a += g;
            }
            loss_sum += out.loss;
            correct += out.ncorrect;
            seen += batch.prediction_count();
        }
        let steps = local_steps.max(1) as f32;
        if steps > 1.0 {
            for a in grad_acc.iter_mut() {
                *a /= steps;
            }
        }
        let compressed = self.compressor.compress(&grad_acc, k, round);
        Ok((compressed, loss_sum / steps as f64, correct, seen))
    }
}
