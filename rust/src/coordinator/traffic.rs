//! Byte-exact communication accounting (paper §2.1's two overhead terms).
//!
//! Every gradient that crosses a link is serialised through `sparse::wire`,
//! and the byte counts recorded here are the lengths of those real buffers —
//! the "Communication Overheads" columns of Tables 3/4 are sums of these.

/// Accounting policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct TrafficPolicy {
    /// Count the broadcast once per round (hub multicast, default — matches
    /// the scale of the paper's totals) or once per participating client.
    pub downlink_per_client: bool,
}

impl Default for TrafficPolicy {
    fn default() -> Self {
        TrafficPolicy { downlink_per_client: false }
    }
}

/// Per-round and cumulative traffic totals.
#[derive(Clone, Debug, Default)]
pub struct TrafficMeter {
    pub policy: TrafficPolicy,
    pub round_uplink: usize,
    pub round_downlink: usize,
    pub total_uplink: usize,
    pub total_downlink: usize,
    /// per-client uplink bytes this round (for the network simulator)
    pub round_uplinks: Vec<(usize, usize)>,
}

impl TrafficMeter {
    pub fn new(policy: TrafficPolicy) -> Self {
        TrafficMeter { policy, ..Default::default() }
    }

    pub fn begin_round(&mut self) {
        self.round_uplink = 0;
        self.round_downlink = 0;
        self.round_uplinks.clear();
    }

    pub fn record_uplink(&mut self, client: usize, bytes: usize) {
        self.round_uplink += bytes;
        self.total_uplink += bytes;
        self.round_uplinks.push((client, bytes));
    }

    pub fn record_broadcast(&mut self, bytes: usize, participants: usize) {
        let effective = if self.policy.downlink_per_client { bytes * participants } else { bytes };
        self.round_downlink += effective;
        self.total_downlink += effective;
    }

    pub fn total(&self) -> usize {
        self.total_uplink + self.total_downlink
    }

    pub fn total_gb(&self) -> f64 {
        self.total() as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_rounds() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(0, 100);
        m.record_uplink(1, 150);
        m.record_broadcast(80, 2);
        assert_eq!(m.round_uplink, 250);
        assert_eq!(m.round_downlink, 80);
        m.begin_round();
        m.record_uplink(0, 10);
        assert_eq!(m.round_uplink, 10);
        assert_eq!(m.total_uplink, 260);
        assert_eq!(m.total(), 340);
    }

    #[test]
    fn per_client_downlink_multiplies() {
        let mut m = TrafficMeter::new(TrafficPolicy { downlink_per_client: true });
        m.begin_round();
        m.record_broadcast(100, 5);
        assert_eq!(m.round_downlink, 500);
    }

    #[test]
    fn uplinks_listed_for_simulator() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(3, 42);
        assert_eq!(m.round_uplinks, vec![(3, 42)]);
    }
}
