//! Byte-exact communication accounting (paper §2.1's two overhead terms).
//!
//! Every gradient that crosses a link is serialised through `sparse::wire`,
//! and the byte counts recorded here are the lengths of those real buffers —
//! the "Communication Overheads" columns of Tables 3/4 are sums of these.
//!
//! The time-domain scheduler adds two refinements: per-client cumulative
//! uplink totals (who actually pays for over-provisioning) and a *wasted*
//! uplink category — bytes a deadline-missed straggler transmitted that the
//! server then discarded. Wasted bytes still count toward the uplink totals
//! (they crossed the wire); offline dropouts transmit nothing and are not
//! recorded at all. Under the semi-synchronous carry policies a late upload
//! is *carried* instead of wasted: its bytes count toward every uplink
//! total but join `round_uplinks` in no round — the update enters the next
//! round's aggregate from the server's stale queue, not this one's.
//!
//! Codec v2 adds a *pre-codec* ledger: every record call takes both the
//! actual buffer length and the v1-equivalent (raw u32 + f32) size of the
//! same payload (`wire::encoded_bytes`), so per-round and cumulative byte
//! reduction ratios are exact. Under the default codec the two ledgers are
//! equal and the ratio is 1.

/// Accounting policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct TrafficPolicy {
    /// Count the broadcast once per round (hub multicast, default — matches
    /// the scale of the paper's totals) or once per participating client.
    pub downlink_per_client: bool,
}

impl Default for TrafficPolicy {
    fn default() -> Self {
        TrafficPolicy { downlink_per_client: false }
    }
}

/// Per-round and cumulative traffic totals.
#[derive(Clone, Debug, Default)]
pub struct TrafficMeter {
    pub policy: TrafficPolicy,
    pub round_uplink: usize,
    pub round_downlink: usize,
    pub total_uplink: usize,
    pub total_downlink: usize,
    /// accepted per-client uplinks this round, in participant order —
    /// diagnostic view of who actually reached the aggregate (discarded
    /// straggler uploads are deliberately absent)
    pub round_uplinks: Vec<(usize, usize)>,
    /// straggler bytes discarded by the deadline this round / overall
    pub round_wasted_uplink: usize,
    pub total_wasted_uplink: usize,
    /// v1-equivalent bytes of everything that crossed a link this round /
    /// overall (uplink incl. wasted and carried, plus the broadcast)
    pub round_precodec: usize,
    pub total_precodec: usize,
    /// cumulative uplink bytes per client id (grown on first use)
    pub per_client_uplink: Vec<usize>,
    /// tier-1 backhaul (edge → hub) bytes this round / overall. A separate
    /// ledger on purpose: tier-0 totals (and the codec ratios above) are
    /// digested, and a flat run must stay byte-identical to a two-tier run
    /// — edge traffic never leaks into the tier-0 columns.
    pub round_edge_uplink: usize,
    pub total_edge_uplink: usize,
    /// v1-equivalent bytes of the merged backhaul frames (tier-1 codec
    /// ratio denominator, mirroring `round_precodec` for tier 0)
    pub round_edge_precodec: usize,
    pub total_edge_precodec: usize,
    /// hub → edge broadcast fan-out bytes this round / overall (the hub
    /// ships the broadcast once per edge; edges re-multicast locally)
    pub round_edge_downlink: usize,
    pub total_edge_downlink: usize,
}

impl TrafficMeter {
    pub fn new(policy: TrafficPolicy) -> Self {
        TrafficMeter { policy, ..Default::default() }
    }

    pub fn begin_round(&mut self) {
        self.round_uplink = 0;
        self.round_downlink = 0;
        self.round_wasted_uplink = 0;
        self.round_precodec = 0;
        self.round_uplinks.clear();
        self.round_edge_uplink = 0;
        self.round_edge_precodec = 0;
        self.round_edge_downlink = 0;
    }

    fn bump_client(&mut self, client: usize, bytes: usize) {
        if client >= self.per_client_uplink.len() {
            self.per_client_uplink.resize(client + 1, 0);
        }
        self.per_client_uplink[client] += bytes;
    }

    fn bump_precodec(&mut self, precodec_bytes: usize) {
        self.round_precodec += precodec_bytes;
        self.total_precodec += precodec_bytes;
    }

    /// An upload the server accepted into the aggregate. `bytes` is the
    /// wire buffer length, `precodec_bytes` its v1-equivalent size.
    pub fn record_uplink(&mut self, client: usize, bytes: usize, precodec_bytes: usize) {
        self.round_uplink += bytes;
        self.total_uplink += bytes;
        self.round_uplinks.push((client, bytes));
        self.bump_client(client, bytes);
        self.bump_precodec(precodec_bytes);
    }

    /// An upload that crossed the wire after the deadline and was buffered
    /// for the *next* round's aggregate (semi-synchronous carry): the bytes
    /// count toward all uplink totals — they were spent and will be used —
    /// but not toward `round_uplinks`, which lists only uploads that entered
    /// this round's aggregate, and not toward the wasted counters.
    pub fn record_carried_uplink(&mut self, client: usize, bytes: usize, precodec_bytes: usize) {
        self.round_uplink += bytes;
        self.total_uplink += bytes;
        self.bump_client(client, bytes);
        self.bump_precodec(precodec_bytes);
    }

    /// An upload that crossed the wire but missed the round deadline: it
    /// counts toward the uplink totals (the bytes were spent) and toward the
    /// wasted counters (the server discarded them), but not toward
    /// `round_uplinks` — it never reached the aggregate.
    pub fn record_wasted_uplink(&mut self, client: usize, bytes: usize, precodec_bytes: usize) {
        self.round_uplink += bytes;
        self.total_uplink += bytes;
        self.round_wasted_uplink += bytes;
        self.total_wasted_uplink += bytes;
        self.bump_client(client, bytes);
        self.bump_precodec(precodec_bytes);
    }

    /// One round's merged edge → hub backhaul frames (summed over edges).
    /// `bytes` is the wire length under the uplink codec, `precodec_bytes`
    /// the v1-equivalent cost of the same frames.
    pub fn record_edge_uplink(&mut self, bytes: usize, precodec_bytes: usize) {
        self.round_edge_uplink += bytes;
        self.total_edge_uplink += bytes;
        self.round_edge_precodec += precodec_bytes;
        self.total_edge_precodec += precodec_bytes;
    }

    /// The hub → edge leg of the broadcast: the hub ships the frame once
    /// per edge aggregator, which then re-multicasts to its cohort (the
    /// tier-0 downlink ledger already prices that second leg).
    pub fn record_edge_broadcast(&mut self, bcast_bytes: usize, edges: usize) {
        self.round_edge_downlink += bcast_bytes * edges;
        self.total_edge_downlink += bcast_bytes * edges;
    }

    pub fn record_broadcast(&mut self, bytes: usize, precodec_bytes: usize, participants: usize) {
        let mult = if self.policy.downlink_per_client { participants } else { 1 };
        self.round_downlink += bytes * mult;
        self.total_downlink += bytes * mult;
        self.bump_precodec(precodec_bytes * mult);
    }

    /// Pre-codec over post-codec bytes for the round — the codec's byte
    /// reduction factor (1 under the default codec, > 1 when v2 coding
    /// shrinks the wire). A zero-byte round reads 1 — the neutral "no
    /// reduction observed" element — never NaN or an infinity, so empty
    /// rounds (whole cohort offline before any broadcast) stay plottable.
    pub fn round_codec_ratio(&self) -> f64 {
        Self::ratio_of(self.round_precodec, self.round_uplink + self.round_downlink)
    }

    /// Whole-run pre-codec over post-codec byte ratio (same zero-byte
    /// guarantee as [`TrafficMeter::round_codec_ratio`]).
    pub fn total_codec_ratio(&self) -> f64 {
        Self::ratio_of(self.total_precodec, self.total())
    }

    fn ratio_of(precodec: usize, actual: usize) -> f64 {
        if actual == 0 {
            1.0
        } else {
            precodec as f64 / actual as f64
        }
    }

    /// Cumulative uplink bytes attributed to `client`.
    pub fn client_uplink(&self, client: usize) -> usize {
        self.per_client_uplink.get(client).copied().unwrap_or(0)
    }

    /// Gini coefficient of cumulative per-client uplink bytes over a fleet
    /// of `clients` (clients beyond the recorded list count as 0 — they
    /// have paid nothing yet). 0 = everyone paid the same; → 1 = one client
    /// paid for everyone. This is the selection-fairness statistic the
    /// recorder surfaces per round: feasibility-biased selection must not
    /// silently concentrate the uplink bill on the fast clients.
    ///
    /// `scratch` is a reusable sort buffer (no allocation when warm).
    ///
    /// Guaranteed to return a finite value in `[0, (n-1)/n]` for every
    /// input: an empty fleet or a fleet with zero recorded bytes reads
    /// 0.0 (perfect equality), never NaN or an infinity — the statistic
    /// feeds the per-round recorder and must stay plottable through
    /// empty/degenerate rounds (asserted by the testkit traffic ledger).
    pub fn uplink_gini(&self, clients: usize, scratch: &mut Vec<f64>) -> f64 {
        if clients == 0 {
            return 0.0;
        }
        scratch.clear();
        scratch.reserve(clients);
        for i in 0..clients {
            scratch.push(self.per_client_uplink.get(i).copied().unwrap_or(0) as f64);
        }
        // total_cmp: byte counts come from usize so NaN cannot occur, but a
        // panicking comparator inside a metrics read is never worth it
        scratch.sort_unstable_by(|a, b| a.total_cmp(b));
        let total: f64 = scratch.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return 0.0;
        }
        let n = clients as f64;
        let mut weighted = 0.0;
        for (i, &x) in scratch.iter().enumerate() {
            weighted += (i as f64 + 1.0) * x;
        }
        (2.0 * weighted / (n * total) - (n + 1.0) / n).clamp(0.0, (n - 1.0) / n)
    }

    pub fn total(&self) -> usize {
        self.total_uplink + self.total_downlink
    }

    pub fn total_gb(&self) -> f64 {
        self.total() as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_rounds() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(0, 100, 100);
        m.record_uplink(1, 150, 150);
        m.record_broadcast(80, 80, 2);
        assert_eq!(m.round_uplink, 250);
        assert_eq!(m.round_downlink, 80);
        m.begin_round();
        m.record_uplink(0, 10, 10);
        assert_eq!(m.round_uplink, 10);
        assert_eq!(m.total_uplink, 260);
        assert_eq!(m.total(), 340);
    }

    #[test]
    fn per_client_downlink_multiplies() {
        let mut m = TrafficMeter::new(TrafficPolicy { downlink_per_client: true });
        m.begin_round();
        m.record_broadcast(100, 130, 5);
        assert_eq!(m.round_downlink, 500);
        assert_eq!(m.round_precodec, 650, "precodec multiplies like the actual bytes");
    }

    #[test]
    fn uplinks_listed_for_simulator() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(3, 42, 42);
        assert_eq!(m.round_uplinks, vec![(3, 42)]);
    }

    #[test]
    fn wasted_uplink_counts_toward_totals_but_not_aggregate_list() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(0, 100, 100);
        m.record_wasted_uplink(1, 70, 70);
        assert_eq!(m.round_uplink, 170, "wasted bytes crossed the wire");
        assert_eq!(m.round_wasted_uplink, 70);
        assert_eq!(m.round_uplinks, vec![(0, 100)], "discarded upload never aggregated");
        m.begin_round();
        assert_eq!(m.round_wasted_uplink, 0);
        assert_eq!(m.total_wasted_uplink, 70);
        assert_eq!(m.total_uplink, 170);
    }

    #[test]
    fn carried_uplink_counts_toward_totals_but_not_round_list_or_waste() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(0, 100, 100);
        m.record_carried_uplink(1, 70, 70);
        assert_eq!(m.round_uplink, 170, "carried bytes crossed the wire");
        assert_eq!(m.round_wasted_uplink, 0, "carried bytes are not wasted");
        assert_eq!(m.round_uplinks, vec![(0, 100)], "carried upload enters a later aggregate");
        assert_eq!(m.client_uplink(1), 70, "the client still paid for them");
        assert_eq!(m.total_uplink, 170);
    }

    #[test]
    fn precodec_ledger_and_ratio() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        assert_eq!(m.round_codec_ratio(), 1.0, "no traffic reads as ratio 1");
        assert_eq!(m.total_codec_ratio(), 1.0);
        m.begin_round();
        // 3 uploads shrunk 2× by the codec (incl. a wasted and a carried
        // one — every transmitted byte counts), broadcast shrunk 1.5×
        m.record_uplink(0, 50, 100);
        m.record_wasted_uplink(1, 50, 100);
        m.record_carried_uplink(2, 50, 100);
        m.record_broadcast(100, 150, 3);
        assert_eq!(m.round_precodec, 450);
        let want = 450.0 / 250.0;
        assert!((m.round_codec_ratio() - want).abs() < 1e-12);
        m.begin_round();
        assert_eq!(m.round_precodec, 0, "round ledger resets");
        assert_eq!(m.total_precodec, 450, "run ledger accumulates");
        m.record_uplink(0, 25, 25); // default-codec round: ratio contribution 1
        assert_eq!(m.round_codec_ratio(), 1.0);
        let total_want = 475.0 / 275.0;
        assert!((m.total_codec_ratio() - total_want).abs() < 1e-12);
    }

    #[test]
    fn uplink_gini_bounds_and_ordering() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        let mut scratch = Vec::new();
        assert_eq!(m.uplink_gini(4, &mut scratch), 0.0, "no traffic → perfectly equal");
        m.begin_round();
        m.record_uplink(0, 100, 100);
        m.record_uplink(1, 100, 100);
        m.record_uplink(2, 100, 100);
        m.record_uplink(3, 100, 100);
        assert!(m.uplink_gini(4, &mut scratch).abs() < 1e-12, "equal spend → 0");
        // one client pays for everyone → close to the n-client maximum
        let mut skew = TrafficMeter::new(TrafficPolicy::default());
        skew.begin_round();
        skew.record_uplink(0, 1000, 1000);
        let g = skew.uplink_gini(4, &mut scratch);
        assert!((g - 0.75).abs() < 1e-12, "max Gini for n=4 is (n-1)/n, got {g}");
        // unseen clients count as zero spend
        assert!(skew.uplink_gini(8, &mut scratch) > g);
        assert_eq!(skew.uplink_gini(0, &mut scratch), 0.0);
    }

    #[test]
    fn gini_and_ratio_survive_empty_fleet_and_zero_byte_rounds() {
        // the degenerate corners the recorder can hit: nothing selected,
        // nothing transmitted, or a fleet of size zero — every statistic
        // must come back finite and in range, never NaN/inf
        let m = TrafficMeter::new(TrafficPolicy::default());
        let mut scratch = Vec::new();
        for clients in [0usize, 1, 4, 1000] {
            let g = m.uplink_gini(clients, &mut scratch);
            assert_eq!(g, 0.0, "untouched meter, {clients} clients");
        }
        assert_eq!(m.round_codec_ratio(), 1.0);
        assert_eq!(m.total_codec_ratio(), 1.0);
        // a round that opened but saw no traffic at all
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        assert_eq!(m.round_codec_ratio(), 1.0, "zero-byte round is neutral, not NaN");
        assert!(m.round_codec_ratio().is_finite());
        assert_eq!(m.uplink_gini(8, &mut scratch), 0.0);
        // traffic in an earlier round, then an empty round: round-scoped
        // stats reset to the neutral values, run-scoped ones persist
        m.record_uplink(0, 100, 200);
        m.begin_round();
        assert_eq!(m.round_codec_ratio(), 1.0);
        assert!((m.total_codec_ratio() - 2.0).abs() < 1e-12);
        let g = m.uplink_gini(4, &mut scratch);
        assert!(g.is_finite() && (0.0..1.0).contains(&g));
        // single-client fleet: Gini is 0 by definition ((n-1)/n = 0)
        assert_eq!(m.uplink_gini(1, &mut scratch), 0.0);
    }

    #[test]
    fn gini_upper_bound_is_clamped_to_n_minus_one_over_n() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(0, usize::MAX / 4, usize::MAX / 4);
        let mut scratch = Vec::new();
        for n in [2usize, 3, 16] {
            let g = m.uplink_gini(n, &mut scratch);
            let max = (n as f64 - 1.0) / n as f64;
            assert!(g.is_finite());
            assert!(g <= max + 1e-15, "n={n}: {g} > {max}");
            assert!((g - max).abs() < 1e-9, "one payer ~= the n-client maximum");
        }
    }

    #[test]
    fn edge_ledger_is_isolated_from_tier0_totals() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(0, 100, 100);
        m.record_edge_uplink(60, 90);
        m.record_edge_broadcast(40, 3);
        assert_eq!(m.round_edge_uplink, 60);
        assert_eq!(m.round_edge_precodec, 90);
        assert_eq!(m.round_edge_downlink, 120, "broadcast once per edge");
        // digested tier-0 columns must not move
        assert_eq!(m.round_uplink, 100);
        assert_eq!(m.round_downlink, 0);
        assert_eq!(m.round_precodec, 100);
        assert_eq!(m.round_codec_ratio(), 1.0, "edge bytes stay out of the codec ratio");
        m.begin_round();
        assert_eq!(m.round_edge_uplink, 0, "round edge ledger resets");
        assert_eq!(m.round_edge_downlink, 0);
        assert_eq!(m.round_edge_precodec, 0);
        assert_eq!(m.total_edge_uplink, 60, "run edge ledger accumulates");
        assert_eq!(m.total_edge_precodec, 90);
        assert_eq!(m.total_edge_downlink, 120);
    }

    #[test]
    fn per_client_totals_accumulate() {
        let mut m = TrafficMeter::new(TrafficPolicy::default());
        m.begin_round();
        m.record_uplink(2, 40, 40);
        m.record_wasted_uplink(5, 9, 9);
        m.begin_round();
        m.record_uplink(2, 60, 60);
        assert_eq!(m.client_uplink(2), 100);
        assert_eq!(m.client_uplink(5), 9);
        assert_eq!(m.client_uplink(7), 0, "never-seen client reads zero");
        assert_eq!(m.per_client_uplink.len(), 6);
    }
}
