//! Virtualized per-client state: the [`ClientStore`] trait and its two
//! implementations.
//!
//! The flat fleet keeps every client's dense compressor planes (U/V/M) and
//! round scratch resident for the whole run — O(fleet × dim) memory, which
//! caps simulated fleets at a few thousand clients. But between the rounds
//! a client is sampled into, its state is *cold*: the planes only change on
//! `compress` (sampled rounds) and `observe_broadcast` (every round, for
//! momentum-observing schemes), and the planes are sparse in practice —
//! top-k extraction clears what it ships and error feedback refills slowly.
//!
//! [`VirtualStore`] exploits both facts:
//!
//! * **At rest** each client is a [`ClientRecord`]: its RNG checkpoint, its
//!   shard, and its state planes gathered to sparse [`SparseVec`]s — memory
//!   O(nnz), not O(dim).
//! * **Broadcasts are logged, not fanned out.** Instead of folding every
//!   broadcast into every client's momentum eagerly, the store appends the
//!   payload to a replay log. When a client is next materialized, the store
//!   replays exactly the broadcasts it missed, in order, through the
//!   compressor's own `observe_broadcast` — the per-coordinate operation
//!   sequence is identical to the eager fan-out, so the resulting planes
//!   are bit-identical (asserted by `tests/proptests.rs`).
//! * **Only the cohort is dense.** `checkout` scatters the sampled clients'
//!   sparse planes into pooled dense slots (reused round over round);
//!   `checkin` gathers them back and evicts. Resident memory is
//!   O(cohort × dim + fleet at-rest nnz + log nnz) — a 1M-client fleet with
//!   a 1k cohort fits where the dense fleet needed ~dim × 1M floats.
//!
//! Gather keeps every value whose f32 *bits* are nonzero (so a stored
//! `-0.0` survives the round-trip) and scatter writes into a zeroed plane,
//! which makes gather→scatter the exact identity on the dense planes:
//! virtualization never moves a single bit of the trajectory.
//!
//! [`DenseStore`] is the old behaviour behind the same trait — every client
//! permanently materialized — and remains the right choice for full-
//! participation runs, where checkout/checkin would churn every client
//! every round.

use super::client::FlClient;
use crate::compress::{self, CompressConfig, CompressorKind};
use crate::data::dataset::{Batch, Dataset};
use crate::sparse::codec::CodecParams;
use crate::sparse::vector::SparseVec;
use crate::util::rng::Rng;

/// Below this much total broadcast-observation work (dense momentum coords ×
/// clients) the per-round thread spawns cost more than they parallelise.
const PARALLEL_OBSERVE_MIN_WORK: usize = 1 << 15;

/// How `FlRun` keeps per-client state (TOML top-level `store` key).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum StoreMode {
    /// `Dense` for full-participation samplers, `Virtual` otherwise.
    #[default]
    Auto,
    /// Every client permanently materialized (the pre-store behaviour).
    Dense,
    /// Sparse-at-rest records + pooled dense cohort slots.
    Virtual,
}

impl StoreMode {
    pub fn parse(s: &str) -> Option<StoreMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(StoreMode::Auto),
            "dense" => Some(StoreMode::Dense),
            "virtual" => Some(StoreMode::Virtual),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StoreMode::Auto => "auto",
            StoreMode::Dense => "dense",
            StoreMode::Virtual => "virtual",
        }
    }
}

/// Per-client state keeper for the round loop. Both implementations are
/// bit-identical in effect: the trajectory of a run must not depend on
/// which store backs it (pinned by the store proptests and the verify
/// matrix, which runs the fixture through `VirtualStore`).
pub trait ClientStore: Send {
    /// Total number of clients in the fleet (resident or not).
    fn fleet_len(&self) -> usize;

    /// Whether this fleet's scheme observes broadcasts at all (plain DGC
    /// does not, letting the round loop skip the call entirely).
    fn observes_broadcast(&self) -> bool;

    /// Deliver a round broadcast fleet-wide. Dense stores fold it into
    /// every client eagerly (fanned out over `workers` threads when the
    /// work amortizes the spawns); virtual stores append it to the replay
    /// log and fold it lazily at the next checkout.
    fn observe_broadcast(&mut self, payload: &SparseVec, workers: usize);

    /// Materialize the round cohort. `cohort` must be sorted, unique and
    /// in range (every `Sampler` variant guarantees this). Panics if a
    /// cohort is already checked out.
    fn checkout(&mut self, cohort: &[usize]);

    /// The materialized cohort, in `cohort` order. Valid between
    /// `checkout` and `checkin`.
    fn cohort_mut(&mut self) -> Vec<&mut FlClient>;

    /// Fold the cohort's state back to rest and evict it from the slots.
    fn checkin(&mut self);

    /// Bytes of client state this store currently keeps resident: at-rest
    /// records, the broadcast replay log, and the dense slot pool (planes +
    /// round scratch). Deliberately excludes shard payloads — data residency
    /// is the dataset layer's problem, not the state store's.
    fn resident_state_bytes(&mut self) -> usize;

    /// Residual (V-plane) L2 norm of one client at rest — diagnostics.
    fn residual_norm(&mut self, id: usize) -> f32;

    /// The permanently-dense fleet, when this store keeps one
    /// (`DenseStore`); `None` for virtualized stores. Test access only.
    fn dense_clients(&self) -> Option<&[FlClient]>;
}

/// Zero-sized placeholder shard a pooled slot holds while unbound.
struct NullShard;

impl Dataset for NullShard {
    fn len(&self) -> usize {
        0
    }
    fn label_histogram(&self) -> Vec<usize> {
        Vec::new()
    }
    fn sample_batch(&self, _batch: usize, _rng: &mut Rng) -> Batch {
        unreachable!("pooled slot trained without a bound shard")
    }
    fn eval_batches(&self, _batch: usize) -> Vec<Batch> {
        Vec::new()
    }
}

/// Dense planes + round scratch one materialized client costs (excluding
/// the shard, see [`ClientStore::resident_state_bytes`]).
fn slot_bytes(c: &mut FlClient) -> usize {
    let planes: usize = c.compressor.state_planes_mut().iter().map(|(_, p)| p.len() * 4).sum();
    let sv = |v: &SparseVec| (v.indices.capacity() + v.values.capacity()) * 4;
    planes + sv(&c.upload) + sv(&c.echo) + c.wire_buf.capacity() + c.upload.dim * 4
}

/// The pre-store behaviour: every client permanently materialized.
pub struct DenseStore {
    clients: Vec<FlClient>,
    cohort: Vec<usize>,
    observes: bool,
    dim: usize,
}

impl DenseStore {
    pub fn new(
        shards: Vec<Box<dyn Dataset + Send>>,
        root: &Rng,
        dim: usize,
        kind: CompressorKind,
        cfg: &CompressConfig,
        codec: CodecParams,
    ) -> Self {
        let clients: Vec<FlClient> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                let comp = compress::build(kind, cfg, dim);
                FlClient::new(id, comp, shard, root, dim, codec)
            })
            .collect();
        let observes = clients.first().is_some_and(|c| c.compressor.observes_broadcast());
        DenseStore { clients, cohort: Vec::new(), observes, dim }
    }
}

impl ClientStore for DenseStore {
    fn fleet_len(&self) -> usize {
        self.clients.len()
    }

    fn observes_broadcast(&self) -> bool {
        self.observes
    }

    fn observe_broadcast(&mut self, payload: &SparseVec, workers: usize) {
        let clients = &mut self.clients;
        let observe_work = self.dim * clients.len();
        if workers > 1 && clients.len() > 1 && observe_work >= PARALLEL_OBSERVE_MIN_WORK {
            let chunk = clients.len().div_ceil(workers);
            std::thread::scope(|s| {
                for ch in clients.chunks_mut(chunk) {
                    s.spawn(move || {
                        for c in ch {
                            c.observe_broadcast(payload);
                        }
                    });
                }
            });
        } else {
            for c in clients.iter_mut() {
                c.observe_broadcast(payload);
            }
        }
    }

    fn checkout(&mut self, cohort: &[usize]) {
        assert!(self.cohort.is_empty(), "cohort already checked out");
        self.cohort.extend_from_slice(cohort);
    }

    fn cohort_mut(&mut self) -> Vec<&mut FlClient> {
        let mut parts: Vec<&mut FlClient> = Vec::with_capacity(self.cohort.len());
        let mut client_iter = self.clients.iter_mut().enumerate();
        for &cid in &self.cohort {
            for (i, c) in client_iter.by_ref() {
                if i == cid {
                    parts.push(c);
                    break;
                }
            }
        }
        // the single-pass match above requires ascending participant ids
        // (every Sampler variant sorts); a miss here would silently skip
        // clients and misalign the round's reductions
        assert_eq!(
            parts.len(),
            self.cohort.len(),
            "sampler must return sorted unique in-range client ids"
        );
        parts
    }

    fn checkin(&mut self) {
        self.cohort.clear();
    }

    fn resident_state_bytes(&mut self) -> usize {
        self.clients.iter_mut().map(slot_bytes).sum()
    }

    fn residual_norm(&mut self, id: usize) -> f32 {
        self.clients[id].compressor.residual_norm()
    }

    fn dense_clients(&self) -> Option<&[FlClient]> {
        Some(&self.clients)
    }
}

/// One client at rest: everything that carries information across rounds,
/// in sparse/compact form.
struct ClientRecord {
    /// RNG checkpoint — advanced only while materialized (training draws)
    rng: Rng,
    /// the client's shard, lent to a slot while materialized
    shard: Option<Box<dyn Dataset + Send>>,
    /// state planes gathered to sparse, aligned with the scheme's
    /// `state_planes_mut` order; empty until first eviction
    planes: Vec<SparseVec>,
    /// broadcasts already folded into the planes (replay-log cursor)
    observed: usize,
}

/// Sparse-at-rest fleet with a pooled dense cohort.
pub struct VirtualStore {
    records: Vec<ClientRecord>,
    /// pooled dense slots, grown to the largest cohort seen
    slots: Vec<FlClient>,
    /// record ids currently materialized, aligned with the slot prefix
    out: Vec<usize>,
    /// broadcast replay log (empty for schemes that never observe)
    log: Vec<SparseVec>,
    root: Rng,
    kind: CompressorKind,
    compress: CompressConfig,
    codec: CodecParams,
    dim: usize,
    observes: bool,
}

impl VirtualStore {
    pub fn new(
        shards: Vec<Box<dyn Dataset + Send>>,
        root: &Rng,
        dim: usize,
        kind: CompressorKind,
        cfg: &CompressConfig,
        codec: CodecParams,
    ) -> Self {
        let records: Vec<ClientRecord> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| ClientRecord {
                // the exact stream `FlClient::new` derives, so a virtual
                // client trains on the same draws as its dense twin
                rng: root.derive(0xC11E ^ id as u64),
                shard: Some(shard),
                planes: Vec::new(),
                observed: 0,
            })
            .collect();
        let observes = compress::build(kind, cfg, 0).observes_broadcast();
        VirtualStore {
            records,
            slots: Vec::new(),
            out: Vec::new(),
            log: Vec::new(),
            root: root.clone(),
            kind,
            compress: cfg.clone(),
            codec,
            dim,
            observes,
        }
    }

    /// Materialize one record into one slot: rebind identity, scatter the
    /// sparse planes into zeroed dense ones, replay missed broadcasts.
    fn materialize(record: &mut ClientRecord, slot: &mut FlClient, id: usize, log: &[SparseVec]) {
        slot.id = id;
        slot.rng = record.rng.clone();
        let shard = record.shard.take().expect("client materialized twice");
        let _null = std::mem::replace(&mut slot.shard, shard);
        for (i, (_, dense)) in slot.compressor.state_planes_mut().into_iter().enumerate() {
            dense.fill(0.0);
            if let Some(sparse) = record.planes.get(i) {
                for (&ix, &v) in sparse.indices.iter().zip(&sparse.values) {
                    dense[ix as usize] = v;
                }
            }
        }
        // replay the broadcasts this client slept through, in order — the
        // same per-coordinate operation sequence the eager fan-out runs
        for payload in &log[record.observed..] {
            slot.compressor.observe_broadcast(payload);
        }
        record.observed = log.len();
    }

    /// Evict one slot back into its record: gather planes (keeping every
    /// value whose bits are nonzero, so `-0.0` survives), zero the slot's
    /// planes for the next tenant, checkpoint the RNG, return the shard.
    fn evict(record: &mut ClientRecord, slot: &mut FlClient, dim: usize) {
        record.rng = slot.rng.clone();
        record.shard = Some(std::mem::replace(&mut slot.shard, Box::new(NullShard)));
        let planes = slot.compressor.state_planes_mut();
        if record.planes.len() < planes.len() {
            record.planes.resize_with(planes.len(), || SparseVec::empty(dim));
        }
        for ((_, dense), sparse) in planes.into_iter().zip(record.planes.iter_mut()) {
            sparse.indices.clear();
            sparse.values.clear();
            for (ix, v) in dense.iter_mut().enumerate() {
                if v.to_bits() != 0 {
                    sparse.indices.push(ix as u32);
                    sparse.values.push(*v);
                }
                *v = 0.0;
            }
        }
    }
}

impl ClientStore for VirtualStore {
    fn fleet_len(&self) -> usize {
        self.records.len()
    }

    fn observes_broadcast(&self) -> bool {
        self.observes
    }

    fn observe_broadcast(&mut self, payload: &SparseVec, _workers: usize) {
        if self.observes {
            self.log.push(payload.clone());
        }
    }

    fn checkout(&mut self, cohort: &[usize]) {
        assert!(self.out.is_empty(), "cohort already checked out");
        assert!(
            cohort.windows(2).all(|w| w[0] < w[1])
                && cohort.last().map_or(true, |&c| c < self.records.len()),
            "sampler must return sorted unique in-range client ids"
        );
        while self.slots.len() < cohort.len() {
            let comp = compress::build(self.kind, &self.compress, self.dim);
            self.slots.push(FlClient::new(
                usize::MAX,
                comp,
                Box::new(NullShard),
                &self.root,
                self.dim,
                self.codec,
            ));
        }
        for (slot, &id) in self.slots.iter_mut().zip(cohort) {
            Self::materialize(&mut self.records[id], slot, id, &self.log);
        }
        self.out.extend_from_slice(cohort);
    }

    fn cohort_mut(&mut self) -> Vec<&mut FlClient> {
        self.slots[..self.out.len()].iter_mut().collect()
    }

    fn checkin(&mut self) {
        for (slot, &id) in self.slots.iter_mut().zip(&self.out) {
            Self::evict(&mut self.records[id], slot, self.dim);
        }
        self.out.clear();
    }

    fn resident_state_bytes(&mut self) -> usize {
        let sv = |v: &SparseVec| (v.indices.capacity() + v.values.capacity()) * 4;
        let records: usize = self
            .records
            .iter()
            .map(|r| {
                std::mem::size_of::<ClientRecord>() + r.planes.iter().map(sv).sum::<usize>()
            })
            .sum();
        let log: usize = self.log.iter().map(sv).sum();
        let slots: usize = self.slots.iter_mut().map(slot_bytes).sum();
        records + log + slots
    }

    fn residual_norm(&mut self, id: usize) -> f32 {
        if let Some(pos) = self.out.iter().position(|&c| c == id) {
            return self.slots[pos].compressor.residual_norm();
        }
        // at rest, V is one of the gathered planes; its index depends on the
        // scheme, so look it up by name through a slot-shaped probe
        let names: Vec<&'static str> = if let Some(slot) = self.slots.first_mut() {
            slot.compressor.state_planes_mut().iter().map(|(n, _)| *n).collect()
        } else {
            compress::build(self.kind, &self.compress, 0)
                .state_planes_mut()
                .iter()
                .map(|(n, _)| *n)
                .collect()
        };
        let Some(vi) = names.iter().position(|&n| n == "v") else { return 0.0 };
        // the planes at rest may still be behind on replay, but replayed
        // broadcasts only touch M — V is exact at rest
        self.records[id].planes.get(vi).map(|p| p.l2_norm()).unwrap_or(0.0)
    }

    fn dense_clients(&self) -> Option<&[FlClient]> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::BlobDataset;

    fn shards(n: usize, dim: usize) -> Vec<Box<dyn Dataset + Send>> {
        (0..n)
            .map(|c| {
                Box::new(BlobDataset::generate_split(20, dim, 3, 0.4, 7, 8 + c as u64))
                    as Box<dyn Dataset + Send>
            })
            .collect()
    }

    fn stores(n: usize, dim: usize, kind: CompressorKind) -> (DenseStore, VirtualStore) {
        let root = Rng::new(42);
        let cfg = CompressConfig::default();
        let codec = CodecParams::default();
        (
            DenseStore::new(shards(n, dim), &root, dim, kind, &cfg, codec),
            VirtualStore::new(shards(n, dim), &root, dim, kind, &cfg, codec),
        )
    }

    /// Drive both stores through the same observe/mutate schedule and
    /// assert the dense planes agree bit-for-bit at every materialization.
    #[test]
    fn virtual_planes_match_dense_across_schemes() {
        let dim = 12;
        for kind in CompressorKind::ALL {
            let (mut dense, mut virt) = stores(5, dim, kind);
            let mut rng = Rng::new(99);
            for round in 0..6 {
                if round > 0 && dense.observes_broadcast() {
                    let payload = SparseVec::new(
                        dim,
                        vec![(round as u32 % dim as u32, 0.5 - round as f32 * 0.1)],
                    );
                    dense.observe_broadcast(&payload, 1);
                    virt.observe_broadcast(&payload, 1);
                }
                // a rotating 2-client cohort exercises replay gaps
                let a = rng.below(4);
                let cohort = [a, a + 1];
                dense.checkout(&cohort);
                virt.checkout(&cohort);
                let mut d = dense.cohort_mut();
                let mut v = virt.cohort_mut();
                for (dc, vc) in d.iter_mut().zip(v.iter_mut()) {
                    assert_eq!(dc.id, vc.id);
                    assert_eq!(
                        dc.rng.next_u64(),
                        vc.rng.next_u64(),
                        "{}: rng checkpoint diverged",
                        kind.name()
                    );
                    // perturb the planes through the compressor so eviction
                    // has real state to gather (including a negative zero)
                    let grad: Vec<f32> = (0..dim)
                        .map(|i| if i % 3 == 0 { 0.0 } else { (i as f32 - 4.0) * 0.25 })
                        .collect();
                    dc.compressor.compress_into(&grad, 3, round, &mut dc.upload);
                    vc.compressor.compress_into(&grad, 3, round, &mut vc.upload);
                    let dp = dc.compressor.state_planes_mut();
                    let vp = vc.compressor.state_planes_mut();
                    assert_eq!(dp.len(), vp.len());
                    for ((dn, dpl), (vn, vpl)) in dp.iter().zip(vp.iter()) {
                        assert_eq!(dn, vn);
                        let db: Vec<u32> = dpl.iter().map(|x| x.to_bits()).collect();
                        let vb: Vec<u32> = vpl.iter().map(|x| x.to_bits()).collect();
                        assert_eq!(db, vb, "{}: plane {dn} diverged round {round}", kind.name());
                    }
                }
                drop(d);
                drop(v);
                dense.checkin();
                virt.checkin();
            }
        }
    }

    #[test]
    fn gather_preserves_negative_zero() {
        let dim = 4;
        let root = Rng::new(1);
        let cfg = CompressConfig::default();
        let mut virt = VirtualStore::new(
            shards(1, dim),
            &root,
            dim,
            CompressorKind::Dgc,
            &cfg,
            CodecParams::default(),
        );
        virt.checkout(&[0]);
        {
            let mut cohort = virt.cohort_mut();
            let planes = cohort[0].compressor.state_planes_mut();
            let (_, v) = &planes[1];
            assert!(v.iter().all(|&x| x == 0.0));
        }
        {
            let mut cohort = virt.cohort_mut();
            let mut planes = cohort[0].compressor.state_planes_mut();
            planes[1].1[2] = -0.0;
            planes[1].1[3] = 1.5;
        }
        virt.checkin();
        virt.checkout(&[0]);
        let mut cohort = virt.cohort_mut();
        let planes = cohort[0].compressor.state_planes_mut();
        let v = &planes[1].1;
        assert_eq!(v[2].to_bits(), (-0.0f32).to_bits(), "-0.0 must survive eviction");
        assert_eq!(v[3], 1.5);
    }

    #[test]
    fn resident_bytes_scale_with_cohort_not_fleet() {
        let dim = 64;
        let root = Rng::new(5);
        let cfg = CompressConfig::default();
        let build = |n: usize| {
            VirtualStore::new(
                shards(n, dim),
                &root,
                dim,
                CompressorKind::DgcWgmf,
                &cfg,
                CodecParams::default(),
            )
        };
        let mut small = build(8);
        let mut large = build(64);
        small.checkout(&[0, 1]);
        large.checkout(&[0, 1]);
        small.checkin();
        large.checkin();
        let per_rec = std::mem::size_of::<ClientRecord>();
        let (s, l) = (small.resident_state_bytes(), large.resident_state_bytes());
        // growing the fleet 8× costs only the extra at-rest records, not
        // 8× the dense slot pool
        assert!(
            l - s <= 56 * per_rec + 56 * 2 * dim * 4 / 8,
            "fleet growth leaked dense state: {s} -> {l}"
        );
        let mut dense = DenseStore::new(
            shards(64, dim),
            &root,
            dim,
            CompressorKind::DgcWgmf,
            &cfg,
            CodecParams::default(),
        );
        assert!(
            dense.resident_state_bytes() > l,
            "a dense 64-client fleet must out-weigh the virtual one"
        );
    }

    #[test]
    fn residual_norm_readable_at_rest() {
        let dim = 8;
        let root = Rng::new(3);
        let cfg = CompressConfig::default();
        let mut virt = VirtualStore::new(
            shards(2, dim),
            &root,
            dim,
            CompressorKind::Dgc,
            &cfg,
            CodecParams::default(),
        );
        assert_eq!(virt.residual_norm(0), 0.0);
        virt.checkout(&[0]);
        {
            let mut cohort = virt.cohort_mut();
            let grad: Vec<f32> = (0..dim).map(|i| i as f32 * 0.3 + 0.1).collect();
            let mut out = SparseVec::empty(dim);
            cohort[0].compressor.compress_into(&grad, 2, 0, &mut out);
        }
        let norm_out = virt.residual_norm(0);
        virt.checkin();
        let norm_rest = virt.residual_norm(0);
        assert!(norm_rest > 0.0, "residual must be visible at rest");
        assert_eq!(norm_out.to_bits(), norm_rest.to_bits());
    }
}
