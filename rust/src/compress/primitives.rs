//! Hot-path primitives shared by all compression schemes.
//!
//! Each function mirrors one L1 Pallas kernel (see
//! `python/compile/kernels/gmf.py`); the integration test
//! `rust/tests/pjrt_roundtrip.rs` checks this module against the AOT
//! artifacts built from those kernels, making the Pallas kernels the
//! specification and this module the optimised engine.

use crate::sparse::topk;
use crate::sparse::vector::SparseVec;
use crate::util::math::l2_norm;

/// Epsilon guarding the normalisation (matches the jax kernels).
pub const NORM_EPS: f32 = 1e-12;

/// Momentum correction (Alg. 1 lines 6-7, kernel `dgc_update`):
/// `U ← α·U + g ; V ← V + U` — in place, single fused pass.
pub fn dgc_update(u: &mut [f32], v: &mut [f32], grad: &[f32], alpha: f32) {
    debug_assert_eq!(u.len(), v.len());
    debug_assert_eq!(u.len(), grad.len());
    for i in 0..u.len() {
        let un = alpha * u[i] + grad[i];
        u[i] = un;
        v[i] += un;
    }
}

/// Global momentum accumulate (Alg. 1 line 8): `M ← β·M + Ĝ_{t-1}`,
/// with the sparse broadcast applied on top of the decayed dense state.
pub fn momentum_accumulate(m: &mut [f32], beta: f32, ghat: &SparseVec) {
    debug_assert_eq!(m.len(), ghat.dim);
    for x in m.iter_mut() {
        *x *= beta;
    }
    ghat.add_into(m, 1.0);
}

/// GMF selection score (Alg. 1 line 9, kernels `sumsq` + `gmf_fuse`):
/// `Z = |(1−τ)·N(V) + τ·N(M)|` written into `z`.
pub fn gmf_score(z: &mut [f32], v: &[f32], m: &[f32], tau: f32) {
    debug_assert_eq!(z.len(), v.len());
    debug_assert_eq!(z.len(), m.len());
    let inv_nv = 1.0 / (l2_norm(v) + NORM_EPS);
    let inv_nm = 1.0 / (l2_norm(m) + NORM_EPS);
    let a = (1.0 - tau) * inv_nv;
    let b = tau * inv_nm;
    for i in 0..z.len() {
        z[i] = (a * v[i] + b * m[i]).abs();
    }
}

/// |V| selection score (DGC / GMC).
pub fn abs_score(z: &mut [f32], v: &[f32]) {
    debug_assert_eq!(z.len(), v.len());
    for i in 0..z.len() {
        z[i] = v[i].abs();
    }
}

/// Masked extraction + memory update (Alg. 1 lines 10-12, kernel
/// `mask_apply`): pulls the top-k coordinates of `v` (by `scores`) out into
/// `out` (cleared and refilled, capacity kept) and zeroes them in `u` and
/// `v`. Both `scratch` and `out` are reused across rounds — no allocation
/// when warm. Returns the selection threshold.
///
/// The threshold kernels dispatch internally (`sparse::simd`): under the
/// accelerated mode `threshold_exact`/`threshold_sampled` run the bucketed
/// histogram selection, under the scalar mode the full quickselect — both
/// return the same threshold value, so the extracted support and every
/// value this function emits are identical either way.
#[allow(clippy::too_many_arguments)]
pub fn extract_and_clear_into(
    u: &mut [f32],
    v: &mut [f32],
    scores: &[f32],
    k: usize,
    exact: bool,
    seed: u64,
    scratch: &mut Vec<f32>,
    out: &mut SparseVec,
) -> f32 {
    let threshold = if exact {
        topk::threshold_exact(scores, k, scratch)
    } else {
        topk::threshold_sampled(scores, k, seed, scratch)
    };
    out.dim = v.len();
    topk::select_at_threshold_into(scores, threshold, k, &mut out.indices);
    out.values.clear();
    out.values.reserve(out.indices.len());
    for &i in &out.indices {
        let iu = i as usize;
        out.values.push(v[iu]);
        v[iu] = 0.0;
        u[iu] = 0.0;
    }
    out.debug_check();
    threshold
}

/// Allocating convenience wrapper over [`extract_and_clear_into`].
pub fn extract_and_clear(
    u: &mut [f32],
    v: &mut [f32],
    scores: &[f32],
    k: usize,
    exact: bool,
    seed: u64,
    scratch: &mut Vec<f32>,
) -> (SparseVec, f32) {
    let mut out = SparseVec::empty(v.len());
    let threshold = extract_and_clear_into(u, v, scores, k, exact, seed, scratch, &mut out);
    (out, threshold)
}

/// Gradient L2 clipping (DGC detail): scales `grad` in place if its norm
/// exceeds `clip`; no-op when `clip <= 0`.
pub fn clip_gradient(grad: &mut [f32], clip: f32) {
    if clip <= 0.0 {
        return;
    }
    let norm = l2_norm(grad);
    if norm > clip {
        let s = clip / norm;
        for g in grad.iter_mut() {
            *g *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn dgc_update_matches_formula() {
        let mut u = vec![1.0, -2.0];
        let mut v = vec![0.5, 0.5];
        dgc_update(&mut u, &mut v, &[0.1, 0.2], 0.9);
        assert!((u[0] - 1.0f32).abs() < 1e-6); // 0.9*1 + 0.1
        assert!((u[1] - (-1.6f32)).abs() < 1e-6);
        assert!((v[0] - 1.5).abs() < 1e-6);
        assert!((v[1] - (-1.1)).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulate_decays_and_adds() {
        let mut m = vec![1.0, 2.0, 0.0];
        let ghat = SparseVec::new(3, vec![(2, 5.0)]);
        momentum_accumulate(&mut m, 0.5, &ghat);
        assert_eq!(m, vec![0.5, 1.0, 5.0]);
    }

    #[test]
    fn gmf_score_tau_zero_is_scaled_abs_v() {
        let v = randvec(100, 1);
        let m = randvec(100, 2);
        let mut z = vec![0.0; 100];
        gmf_score(&mut z, &v, &m, 0.0);
        let nv = l2_norm(&v);
        for i in 0..100 {
            assert!((z[i] - (v[i] / nv).abs()).abs() < 1e-6);
        }
    }

    #[test]
    fn gmf_score_scale_invariant() {
        let v = randvec(200, 3);
        let m = randvec(200, 4);
        let v2: Vec<f32> = v.iter().map(|x| x * 100.0).collect();
        let mut z1 = vec![0.0; 200];
        let mut z2 = vec![0.0; 200];
        gmf_score(&mut z1, &v, &m, 0.4);
        gmf_score(&mut z2, &v2, &m, 0.4);
        for (a, b) in z1.iter().zip(&z2) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gmf_score_zero_momentum_finite() {
        let v = randvec(64, 5);
        let m = vec![0.0; 64];
        let mut z = vec![0.0; 64];
        gmf_score(&mut z, &v, &m, 0.6);
        assert!(z.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn extract_clears_selected_keeps_rest() {
        let mut u = vec![1.0; 6];
        let mut v = vec![0.1, 5.0, 0.2, 4.0, 0.3, 0.05];
        let scores: Vec<f32> = v.iter().map(|x: &f32| x.abs()).collect();
        let mut scratch = Vec::new();
        let (g, thr) = extract_and_clear(&mut u, &mut v, &scores, 2, true, 0, &mut scratch);
        assert_eq!(g.indices, vec![1, 3]);
        assert_eq!(g.values, vec![5.0, 4.0]);
        assert_eq!(v[1], 0.0);
        assert_eq!(v[3], 0.0);
        assert_eq!(u[1], 0.0);
        assert_eq!(u[3], 0.0);
        assert_eq!(v[0], 0.1); // untouched residual
        assert_eq!(u[0], 1.0);
        assert!(thr <= 4.0 && thr > 0.3);
    }

    #[test]
    fn extract_partitions_v() {
        // transmitted + residual == original V (paper's orthogonality, Fig 2)
        let mut u = randvec(500, 6);
        let mut v = randvec(500, 7);
        let orig_v = v.clone();
        let scores: Vec<f32> = v.iter().map(|x| x.abs()).collect();
        let mut scratch = Vec::new();
        let (g, _) = extract_and_clear(&mut u, &mut v, &scores, 50, true, 0, &mut scratch);
        let mut reassembled = v.clone();
        g.add_into(&mut reassembled, 1.0);
        for (a, b) in reassembled.iter().zip(&orig_v) {
            assert_eq!(a, b);
        }
        // orthogonality: residual and transmitted have disjoint support
        let dot: f64 = g.indices.iter().map(|&i| v[i as usize] as f64).sum();
        assert_eq!(dot, 0.0);
    }

    #[test]
    fn clip_caps_norm() {
        let mut g = vec![3.0, 4.0]; // norm 5
        clip_gradient(&mut g, 1.0);
        assert!((l2_norm(&g) - 1.0).abs() < 1e-6);
        let mut g2 = vec![0.3, 0.4];
        clip_gradient(&mut g2, 1.0);
        assert_eq!(g2, vec![0.3, 0.4]); // under the cap: untouched
        let mut g3 = vec![3.0, 4.0];
        clip_gradient(&mut g3, 0.0); // disabled
        assert_eq!(g3, vec![3.0, 4.0]);
    }
}
