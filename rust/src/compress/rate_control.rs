//! Per-client adaptive rate control: closes the scheduler ⇄ codec loop.
//!
//! Historically every client uploaded at one shared top-k rate and value
//! coding, so slow links missed round deadlines while fast links wasted
//! headroom — the waste the scheduler's `wasted_uplink_bytes` and
//! `traffic_gini` columns measure but nothing acted on. The controller
//! plans, per client and per round, an effective top-k and value coding
//! from three signals:
//!
//! 1. the client's own capability profile (uplink bandwidth, latency,
//!    compute multiplier),
//! 2. its own deadline-hit history (Laplace-smoothed, the same
//!    `(delivered + 1) / (selected + 2)` estimate `SelectionHistory`
//!    keeps),
//! 3. its own cumulative uplink spend versus what the base rate would
//!    have cost it over the same selections.
//!
//! Every input is **client-mirrorable**: a service-mode client learns its
//! own selection/delivery outcomes from the fate bytes it already
//! receives and knows its own profile and payload sizes, so it can
//! reproduce the server's plan without any protocol change. Decisions
//! are pure functions of those inputs — no fleet-global state, no RNG —
//! so the simulator, the service server and every service client compute
//! identical plans. `mode = "off"` (the default) never constructs a plan
//! and is bit-identical to the pre-controller trajectory.
//!
//! Error feedback absorbs the extra lossiness: a coordinate shaved by a
//! smaller k or coarsened by a q8 downshift lands in the residual and is
//! re-emitted later, so the per-coordinate mass ledger stays clean across
//! rate switches (see `testkit::invariants::MassLedger`).

use crate::sparse::codec::{IndexCoding, ValueCoding};

/// Controller mode. `Off` is the default and leaves every trajectory
/// bit-identical to a build without the controller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RateControlMode {
    Off,
    /// shave (and optionally coarsen) per client from profile + history
    Adaptive,
}

impl RateControlMode {
    pub fn parse(s: &str) -> Option<RateControlMode> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" | "fixed" => Some(RateControlMode::Off),
            "adaptive" | "on" | "auto" => Some(RateControlMode::Adaptive),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            RateControlMode::Off => "off",
            RateControlMode::Adaptive => "adaptive",
        }
    }
}

/// `[rate_control]` knobs (see `docs/config.md`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RateControlConfig {
    pub mode: RateControlMode,
    /// floor on the per-client rate, as a fraction of the shared base k
    /// (a struggling client never uploads fewer than
    /// `ceil(base_k * min_rate_frac)` coordinates)
    pub min_rate_frac: f64,
    /// ceiling multiplier on the shared base k (1.0 = shave-only; the
    /// controller never uploads more than `base_k * max_rate_boost`)
    pub max_rate_boost: f64,
    /// fraction of the round deadline budgeted for latency + compute +
    /// upload when capping k to link capacity
    pub deadline_margin: f64,
    /// allow stepping the value coding *lossier* (f32 → f16 → q8) when
    /// the shaped k still misses the deadline budget; never steps toward
    /// lossless
    pub adapt_coding: bool,
}

impl Default for RateControlConfig {
    fn default() -> Self {
        RateControlConfig {
            mode: RateControlMode::Off,
            min_rate_frac: 0.25,
            max_rate_boost: 1.0,
            deadline_margin: 0.8,
            adapt_coding: true,
        }
    }
}

/// A client's own link/compute capability, as the scheduler models it.
/// Plain floats (not `ClientProfile`) so this module stays independent
/// of the sim layer and service clients can fill it from their own copy
/// of the network description.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSignals {
    /// effective uplink rate in the scheduler's `bytes / up_bps` units
    pub up_bps: f64,
    pub latency_s: f64,
    /// multiplier on the fleet-wide per-step compute cost
    pub compute_mult: f64,
}

/// A client's own selection history and spend ledger.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistorySignals {
    /// Laplace-smoothed deadline-hit rate `(delivered + 1) / (selected + 2)`;
    /// 0.5 before any observations
    pub hit_rate: f64,
    /// rounds this client was selected so far (before the current round)
    pub times_selected: u64,
    /// cumulative uplink bytes the meter charged this client (offline
    /// fates charge nothing, matching `TrafficMeter`)
    pub spent_bytes: u64,
}

impl HistorySignals {
    /// Neutral history: unobserved client, no spend.
    pub fn fresh() -> Self {
        HistorySignals { hit_rate: 0.5, times_selected: 0, spent_bytes: 0 }
    }
}

/// One planned upload: the per-client effective top-k and value coding.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RateDecision {
    pub k: usize,
    /// `k / dim` (0 when `dim == 0`)
    pub rate: f64,
    pub value: ValueCoding,
    /// true when `value` is lossier than the configured base coding
    pub downshifted: bool,
}

/// Fixed header allowance in the payload-size model (wire frame + codec
/// preamble). A planning estimate, not the exact encoder output.
const EST_HEADER_BYTES: f64 = 16.0;

/// Planning estimate of encoded bytes per coordinate for one coding
/// choice. Varint gaps and q8 blocks are data-dependent; these are the
/// steady-state averages the controller budgets with. Exactness is not
/// required — the deadline margin absorbs the model error — but the
/// estimate must be a pure function so all parties agree on it.
fn est_bytes_per_coord(index: IndexCoding, value: ValueCoding) -> f64 {
    let ix = match index {
        IndexCoding::Raw => 4.0,
        IndexCoding::Varint => 2.5,
    };
    let val = match value {
        ValueCoding::F32 => 4.0,
        ValueCoding::F16 => 2.0,
        ValueCoding::Q8 => 1.25, // 1 byte + blockwise scale amortized
    };
    ix + val
}

/// Planning estimate of one upload's total encoded bytes.
pub fn est_upload_bytes(k: usize, index: IndexCoding, value: ValueCoding) -> f64 {
    EST_HEADER_BYTES + k as f64 * est_bytes_per_coord(index, value)
}

fn step_lossier(v: ValueCoding) -> ValueCoding {
    match v {
        ValueCoding::F32 => ValueCoding::F16,
        ValueCoding::F16 | ValueCoding::Q8 => ValueCoding::Q8,
    }
}

impl RateControlConfig {
    pub fn off() -> Self {
        RateControlConfig::default()
    }

    pub fn active(&self) -> bool {
        self.mode == RateControlMode::Adaptive
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.min_rate_frac > 0.0 && self.min_rate_frac <= 1.0) {
            return Err(format!(
                "rate_control.min_rate_frac must be in (0, 1], got {}",
                self.min_rate_frac
            ));
        }
        if !(self.max_rate_boost >= 1.0 && self.max_rate_boost <= 8.0) {
            return Err(format!(
                "rate_control.max_rate_boost must be in [1, 8], got {}",
                self.max_rate_boost
            ));
        }
        if !(self.deadline_margin > 0.0 && self.deadline_margin <= 1.0) {
            return Err(format!(
                "rate_control.deadline_margin must be in (0, 1], got {}",
                self.deadline_margin
            ));
        }
        Ok(())
    }

    pub fn describe(&self) -> String {
        format!(
            "{} min_frac={} max_boost={} margin={} adapt_coding={}",
            self.mode.name(),
            self.min_rate_frac,
            self.max_rate_boost,
            self.deadline_margin,
            self.adapt_coding
        )
    }

    /// Plan one client's upload for one round.
    ///
    /// `base_k` is the shared warmup schedule's k for this round
    /// (`SparsityWarmup::k_at`), `base_value` the configured uplink value
    /// coding. `deadline_s <= 0` (scheduling inactive) disables the
    /// capacity cap and leaves only history/spend shaping. The result is
    /// always within `1..=dim` (and `k == 0` only when `dim == 0`),
    /// and `value` is never less lossy than `base_value`.
    pub fn plan(
        &self,
        base_k: usize,
        dim: usize,
        index: IndexCoding,
        base_value: ValueCoding,
        link: LinkSignals,
        hist: HistorySignals,
        deadline_s: f64,
        compute_s: f64,
        local_steps: usize,
    ) -> RateDecision {
        debug_assert!(self.active(), "plan() is only called when the controller is on");
        if dim == 0 || base_k == 0 {
            return RateDecision { k: 0, rate: 0.0, value: base_value, downshifted: false };
        }
        let clamp_k = |k: f64| -> usize { (k.max(1.0) as usize).clamp(1, dim) };
        let k_floor = clamp_k((base_k as f64 * self.min_rate_frac).ceil());

        // 1. history + spend shaping. A client that keeps missing the
        // deadline shaves; one that has spent less than its own base-rate
        // bill (because it was shaved or dropped) earns headroom back.
        let w_hist = 0.5 + hist.hit_rate.clamp(0.0, 1.0);
        let w_spend = if hist.times_selected == 0 {
            1.0
        } else {
            let expected =
                hist.times_selected as f64 * est_upload_bytes(base_k, index, base_value);
            let actual = (hist.spent_bytes as f64).max(1.0);
            (expected / actual).clamp(0.5, 2.0)
        };
        let w = (w_hist * w_spend).clamp(self.min_rate_frac, self.max_rate_boost);
        let mut k = clamp_k((base_k as f64 * w).round()).max(k_floor);
        let mut value = base_value;

        // 2. deadline-capacity cap: fit the payload into the share of the
        // deadline left after latency + local compute, stepping the value
        // coding lossier (never lossless-ward) before shaving below the
        // shaped k. Uses the scheduler's own time model
        // (`latency_s + bytes / up_bps` + `compute_mult * compute_s * steps`).
        if deadline_s > 0.0 && deadline_s.is_finite() && link.up_bps > 0.0 {
            let compute = link.compute_mult * compute_s * local_steps as f64;
            let budget_s = deadline_s * self.deadline_margin - link.latency_s - compute;
            let capacity = budget_s * link.up_bps - EST_HEADER_BYTES;
            if capacity <= 0.0 {
                // hopeless link for this deadline: send the floor as
                // cheaply as allowed rather than going silent.
                k = k_floor;
                if self.adapt_coding {
                    value = ValueCoding::Q8;
                }
            } else {
                let mut k_cap = (capacity / est_bytes_per_coord(index, value)).floor();
                while self.adapt_coding
                    && (k_cap as usize) < k
                    && step_lossier(value) != value
                {
                    value = step_lossier(value);
                    k_cap = (capacity / est_bytes_per_coord(index, value)).floor();
                }
                if (k_cap as usize) < k {
                    k = clamp_k(k_cap).max(k_floor);
                }
            }
        }

        let k = k.clamp(1, dim);
        RateDecision {
            k,
            rate: k as f64 / dim as f64,
            value,
            downshifted: value != base_value,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adaptive() -> RateControlConfig {
        RateControlConfig { mode: RateControlMode::Adaptive, ..RateControlConfig::default() }
    }

    fn fast_link() -> LinkSignals {
        LinkSignals { up_bps: 1_000_000.0, latency_s: 0.0, compute_mult: 1.0 }
    }

    #[test]
    fn default_is_off_and_validates() {
        let cfg = RateControlConfig::default();
        assert_eq!(cfg.mode, RateControlMode::Off);
        assert!(!cfg.active());
        cfg.validate().unwrap();
        adaptive().validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let bad = RateControlConfig { min_rate_frac: 0.0, ..adaptive() };
        assert!(bad.validate().is_err());
        let bad = RateControlConfig { max_rate_boost: 0.5, ..adaptive() };
        assert!(bad.validate().is_err());
        let bad = RateControlConfig { deadline_margin: 1.5, ..adaptive() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn mode_parses() {
        assert_eq!(RateControlMode::parse("off"), Some(RateControlMode::Off));
        assert_eq!(RateControlMode::parse("Adaptive"), Some(RateControlMode::Adaptive));
        assert_eq!(RateControlMode::parse("nope"), None);
        assert_eq!(RateControlMode::Adaptive.name(), "adaptive");
    }

    #[test]
    fn neutral_signals_keep_base_rate() {
        // fresh history, no deadline: shave-only default leaves k at base.
        let d = adaptive().plan(
            100,
            1000,
            IndexCoding::Raw,
            ValueCoding::F32,
            fast_link(),
            HistorySignals::fresh(),
            0.0,
            0.0,
            1,
        );
        assert_eq!(d.k, 100);
        assert_eq!(d.value, ValueCoding::F32);
        assert!(!d.downshifted);
        assert!((d.rate - 0.1).abs() < 1e-12);
    }

    #[test]
    fn slow_link_gets_smaller_k_than_fast_link() {
        let cfg = adaptive();
        let slow = LinkSignals { up_bps: 2_000.0, ..fast_link() };
        let plan = |link| {
            cfg.plan(
                200,
                1000,
                IndexCoding::Raw,
                ValueCoding::F32,
                link,
                HistorySignals::fresh(),
                0.1,
                0.0,
                1,
            )
        };
        let df = plan(fast_link());
        let ds = plan(slow);
        assert_eq!(df.k, 200, "fast link keeps the base k");
        assert!(ds.k < df.k, "slow link is capped: {} !< {}", ds.k, df.k);
        assert!(ds.k >= 1);
    }

    #[test]
    fn missing_deadlines_shaves_and_underspending_earns_back() {
        let cfg = adaptive();
        let plan = |hist| {
            cfg.plan(
                100,
                1000,
                IndexCoding::Raw,
                ValueCoding::F32,
                fast_link(),
                hist,
                0.0,
                0.0,
                1,
            )
        };
        let struggler = plan(HistorySignals {
            hit_rate: 0.1,
            times_selected: 10,
            spent_bytes: est_upload_bytes(100, IndexCoding::Raw, ValueCoding::F32) as u64 * 10,
        });
        assert!(struggler.k < 100, "low hit rate shaves: {}", struggler.k);
        // spent half its base-rate bill: spend weight 2.0 offsets the
        // hit-rate shave up to the boost ceiling (1.0 by default).
        let frugal = plan(HistorySignals {
            hit_rate: 0.5,
            times_selected: 10,
            spent_bytes: est_upload_bytes(100, IndexCoding::Raw, ValueCoding::F32) as u64 * 5,
        });
        assert_eq!(frugal.k, 100, "underspend earns back to the ceiling");
    }

    #[test]
    fn coding_only_steps_lossier() {
        let cfg = adaptive();
        // a link too slow for f32 at the shaped k downshifts before shaving
        let tight = LinkSignals { up_bps: 40_000.0, latency_s: 0.0, compute_mult: 1.0 };
        let d = cfg.plan(
            400,
            1000,
            IndexCoding::Raw,
            ValueCoding::F32,
            tight,
            HistorySignals::fresh(),
            0.05,
            0.0,
            1,
        );
        assert!(d.downshifted, "tight budget downshifts the coding");
        assert_ne!(d.value, ValueCoding::F32);
        // base q8 never climbs back toward lossless
        let d = cfg.plan(
            400,
            1000,
            IndexCoding::Raw,
            ValueCoding::Q8,
            fast_link(),
            HistorySignals::fresh(),
            10.0,
            0.0,
            1,
        );
        assert_eq!(d.value, ValueCoding::Q8);
        assert!(!d.downshifted, "base coding is not a downshift");
        // adapt_coding = false shaves k instead of touching the coding
        let fixed = RateControlConfig { adapt_coding: false, ..cfg };
        let d = fixed.plan(
            400,
            1000,
            IndexCoding::Raw,
            ValueCoding::F32,
            tight,
            HistorySignals::fresh(),
            0.05,
            0.0,
            1,
        );
        assert_eq!(d.value, ValueCoding::F32);
        assert!(d.k < 400);
    }

    #[test]
    fn hopeless_link_sends_the_floor() {
        let cfg = adaptive();
        let dead = LinkSignals { up_bps: 1e-3, latency_s: 10.0, compute_mult: 1.0 };
        let d = cfg.plan(
            100,
            1000,
            IndexCoding::Raw,
            ValueCoding::F32,
            dead,
            HistorySignals::fresh(),
            0.1,
            0.02,
            1,
        );
        assert_eq!(d.k, 25, "floor = ceil(base_k * min_rate_frac)");
        assert_eq!(d.value, ValueCoding::Q8, "cheapest allowed coding");
        assert!(d.k >= 1);
    }

    #[test]
    fn bounds_hold_on_degenerate_shapes() {
        let cfg = adaptive();
        for (base_k, dim) in [(1usize, 1usize), (5, 3), (1, 1000), (1000, 1000)] {
            let d = cfg.plan(
                base_k,
                dim,
                IndexCoding::Varint,
                ValueCoding::F16,
                LinkSignals { up_bps: 10.0, latency_s: 0.05, compute_mult: 4.0 },
                HistorySignals { hit_rate: 0.0, times_selected: 3, spent_bytes: 1 << 30 },
                0.06,
                0.01,
                2,
            );
            assert!(d.k >= 1 && d.k <= dim, "k {} out of 1..={dim}", d.k);
            assert!(d.rate > 0.0 && d.rate <= 1.0);
        }
        let d = cfg.plan(
            0,
            0,
            IndexCoding::Raw,
            ValueCoding::F32,
            fast_link(),
            HistorySignals::fresh(),
            0.1,
            0.0,
            1,
        );
        assert_eq!(d.k, 0, "dim 0 stays empty");
    }

    #[test]
    fn plan_is_deterministic() {
        let cfg = adaptive();
        let go = || {
            cfg.plan(
                123,
                997,
                IndexCoding::Varint,
                ValueCoding::F32,
                LinkSignals { up_bps: 9_600.0, latency_s: 0.004, compute_mult: 2.5 },
                HistorySignals { hit_rate: 0.375, times_selected: 7, spent_bytes: 31_287 },
                0.095,
                0.02,
                1,
            )
        };
        assert_eq!(go(), go());
    }
}
