//! Compressor trait + configuration shared by all schemes.

use super::Compressed;
use crate::sparse::vector::SparseVec;

/// Which compression technique a run uses (paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CompressorKind {
    /// Deep Gradient Compression (Lin et al. 2018) — the baseline.
    Dgc,
    /// Global Momentum Compression (Zhao et al. 2019).
    Gmc,
    /// DGC clients + server-side global momentum broadcast (paper §2.1).
    DgcWgm,
    /// DGC + the paper's Global Momentum Fusion (Algorithm 1).
    DgcWgmf,
}

impl CompressorKind {
    pub const ALL: [CompressorKind; 4] =
        [CompressorKind::Dgc, CompressorKind::Gmc, CompressorKind::DgcWgm, CompressorKind::DgcWgmf];

    pub fn name(&self) -> &'static str {
        match self {
            CompressorKind::Dgc => "DGC",
            CompressorKind::Gmc => "GMC",
            CompressorKind::DgcWgm => "DGCwGM",
            CompressorKind::DgcWgmf => "DGCwGMF",
        }
    }

    pub fn parse(s: &str) -> Option<CompressorKind> {
        match s.to_ascii_lowercase().as_str() {
            "dgc" => Some(CompressorKind::Dgc),
            "gmc" => Some(CompressorKind::Gmc),
            "dgcwgm" | "dgc_gm" | "dgc+gm" => Some(CompressorKind::DgcWgm),
            "dgcwgmf" | "dgc_gmf" | "dgc+gmf" | "gmf" => Some(CompressorKind::DgcWgmf),
            _ => None,
        }
    }

    /// Whether the server runs momentum on the aggregate (DGCwGM only).
    pub fn server_momentum(&self) -> bool {
        matches!(self, CompressorKind::DgcWgm)
    }

    /// Paper Table 2 row for this technique.
    pub fn technique_row(&self) -> TechniqueRow {
        match self {
            CompressorKind::Dgc => {
                TechniqueRow { momentum_correction: true, client_gm: None, server_gm: false }
            }
            CompressorKind::Gmc => TechniqueRow {
                momentum_correction: false,
                client_gm: Some("compensation"),
                server_gm: false,
            },
            CompressorKind::DgcWgm => {
                TechniqueRow { momentum_correction: true, client_gm: None, server_gm: true }
            }
            CompressorKind::DgcWgmf => TechniqueRow {
                momentum_correction: true,
                client_gm: Some("compression"),
                server_gm: false,
            },
        }
    }
}

/// Table 2 introspection record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TechniqueRow {
    pub momentum_correction: bool,
    /// None, or where the client-side global momentum participates.
    pub client_gm: Option<&'static str>,
    pub server_gm: bool,
}

/// Hyper-parameters shared across schemes.
#[derive(Clone, Debug)]
pub struct CompressConfig {
    /// local momentum factor α (momentum correction)
    pub alpha: f32,
    /// global momentum factor β
    pub beta: f32,
    /// fusion ratio schedule τ(round) — GMF only
    pub tau: super::schedule::TauSchedule,
    /// gradient L2 clipping before accumulation; <= 0 disables
    pub clip_norm: f32,
    /// exact top-k (true) vs DGC sampled-threshold estimation (false)
    pub exact_topk: bool,
}

impl Default for CompressConfig {
    fn default() -> Self {
        CompressConfig {
            alpha: 0.9,
            beta: 0.9,
            tau: super::schedule::TauSchedule::paper_default(),
            clip_norm: 0.0,
            exact_topk: false,
        }
    }
}

/// Client-side compression state machine.
///
/// Round protocol (matches Algorithm 1's loop body):
///   1. `observe_broadcast(Ĝ_{t-1})` — at the end of round t-1 every client
///      receives the aggregate; schemes tracking global momentum fold it in.
///   2. `compress(∇_{k,t}, k, t)` — compress the fresh local gradient.
pub trait Compressor: Send {
    fn name(&self) -> &'static str;
    fn observe_broadcast(&mut self, ghat: &SparseVec);

    /// Whether [`Compressor::observe_broadcast`] does any work. Schemes with
    /// no client-side global state (plain DGC) return `false`, letting the
    /// round loop skip the broadcast fan-out entirely.
    fn observes_broadcast(&self) -> bool {
        true
    }

    /// Hot path: compress the local gradient into a caller-owned reusable
    /// output vector (`out` is cleared and refilled, keeping its capacity —
    /// no steady-state allocation). Returns the selection threshold used.
    fn compress_into(&mut self, grad: &[f32], k: usize, round: usize, out: &mut SparseVec)
        -> f32;

    /// Allocating convenience wrapper over [`Compressor::compress_into`]
    /// (tests / cold paths).
    fn compress(&mut self, grad: &[f32], k: usize, round: usize) -> Compressed {
        let mut out = SparseVec::empty(grad.len());
        let threshold = self.compress_into(grad, k, round, &mut out);
        Compressed { gradient: out, threshold }
    }

    /// Re-inject a transmitted-but-lost upload into the residual V.
    ///
    /// The time-domain scheduler calls this when a client's upload misses
    /// the round deadline or the client drops out: the extracted mass goes
    /// back into the compensation buffer, so nothing the client computed is
    /// lost — the coordinates re-enter a later round's top-k selection
    /// (error feedback survives the drop). Exactly inverts the `V ⊙= (1−mask)`
    /// clear of [`Compressor::compress_into`] for the transmitted values.
    fn restore_upload(&mut self, upload: &SparseVec) {
        self.restore_upload_scaled(upload, 1.0);
    }

    /// Partial restore: fold `scale · upload` back into the residual V.
    ///
    /// The semi-synchronous carry-discount path restores exactly the
    /// `1 − α` fraction the server will *not* apply of a deadline-missed
    /// upload, so gradient mass is conserved: `α` enters the next round's
    /// aggregate via the stale queue, `1 − α` re-enters a later round's
    /// top-k selection through error feedback. `scale = 1` is the full
    /// restore of [`Compressor::restore_upload`].
    fn restore_upload_scaled(&mut self, upload: &SparseVec, scale: f32);

    /// Residual (V) L2 norm — over-fitting diagnostic used by Fig. 4 analysis.
    fn residual_norm(&self) -> f32;

    /// The scheme's persistent dense state planes, labelled with the paper's
    /// names ("u", "v", "m"). These are exactly the buffers that carry
    /// information across rounds — everything a state store must gather to
    /// sparse form when a client leaves the round cohort and scatter back on
    /// its next materialization. Scratch buffers (scores, sort scratch,
    /// gradient copies) are deliberately excluded: they are overwritten
    /// before every read, so pooled reuse across clients is safe.
    fn state_planes_mut(&mut self) -> Vec<(&'static str, &mut [f32])>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(CompressorKind::parse("dgc"), Some(CompressorKind::Dgc));
        assert_eq!(CompressorKind::parse("DGCwGMF"), Some(CompressorKind::DgcWgmf));
        assert_eq!(CompressorKind::parse("dgcwgm"), Some(CompressorKind::DgcWgm));
        assert_eq!(CompressorKind::parse("nope"), None);
    }

    #[test]
    fn table2_rows() {
        let dgc = CompressorKind::Dgc.technique_row();
        assert!(dgc.momentum_correction && dgc.client_gm.is_none() && !dgc.server_gm);
        let gmf = CompressorKind::DgcWgmf.technique_row();
        assert_eq!(gmf.client_gm, Some("compression"));
        assert!(!gmf.server_gm);
        let gm = CompressorKind::DgcWgm.technique_row();
        assert!(gm.server_gm);
        assert!(CompressorKind::DgcWgm.server_momentum());
        assert!(!CompressorKind::DgcWgmf.server_momentum());
    }
}
