//! DGCwGMF — the paper's contribution (Algorithm 1).
//!
//! DGC's momentum correction for compensation, plus the **Global Momentum
//! Fusion** layer in the compression policy: the selection score fuses the
//! normalised local residual with the normalised client-tracked global
//! momentum,
//!
//! ```text
//!   M ← β·M + Ĝ_{t-1}                        (line 8)
//!   U ← α·U + ∇ ; V ← V + U                  (lines 6-7)
//!   Z = |(1−τ)·N(V) + τ·N(M)|                (line 9, GMF)
//!   mask = top-k(Z) ; transmit V⊙mask        (line 10)
//!   U,V ⊙= (1−mask)                          (lines 11-12)
//! ```
//!
//! τ=0 degenerates to DGC (tested). τ>0 correlates client masks through the
//! shared M, shrinking the union support of the server aggregate — the
//! downlink saving measured in Tables 3/4.

use super::policy::{CompressConfig, Compressor};
use super::primitives;
use super::schedule::TauSchedule;
use crate::sparse::vector::SparseVec;
use crate::util::math::l2_norm;

pub struct DgcGmf {
    alpha: f32,
    beta: f32,
    tau: TauSchedule,
    clip_norm: f32,
    exact_topk: bool,
    u: Vec<f32>,
    v: Vec<f32>,
    m: Vec<f32>,
    scores: Vec<f32>,
    scratch: Vec<f32>,
    grad_buf: Vec<f32>,
}

impl DgcGmf {
    pub fn new(cfg: &CompressConfig, dim: usize) -> Self {
        DgcGmf {
            alpha: cfg.alpha,
            beta: cfg.beta,
            tau: cfg.tau.clone(),
            clip_norm: cfg.clip_norm,
            exact_topk: cfg.exact_topk,
            u: vec![0.0; dim],
            v: vec![0.0; dim],
            m: vec![0.0; dim],
            scores: vec![0.0; dim],
            scratch: Vec::new(),
            grad_buf: vec![0.0; dim],
        }
    }

    pub fn momentum_norm(&self) -> f32 {
        l2_norm(&self.m)
    }

    /// Current fusion ratio (diagnostics).
    pub fn tau_at(&self, round: usize) -> f32 {
        self.tau.at(round)
    }
}

impl Compressor for DgcGmf {
    fn name(&self) -> &'static str {
        "DGCwGMF"
    }

    fn observe_broadcast(&mut self, ghat: &SparseVec) {
        primitives::momentum_accumulate(&mut self.m, self.beta, ghat); // line 8
    }

    fn compress_into(&mut self, grad: &[f32], k: usize, round: usize, out: &mut SparseVec) -> f32 {
        debug_assert_eq!(grad.len(), self.u.len());
        self.grad_buf.copy_from_slice(grad);
        primitives::clip_gradient(&mut self.grad_buf, self.clip_norm);
        primitives::dgc_update(&mut self.u, &mut self.v, &self.grad_buf, self.alpha); // 6-7
        let tau = self.tau.at(round);
        primitives::gmf_score(&mut self.scores, &self.v, &self.m, tau); // 9
        primitives::extract_and_clear_into(
            &mut self.u,
            &mut self.v,
            &self.scores,
            k,
            self.exact_topk,
            round as u64,
            &mut self.scratch,
            out,
        ) // 10-12
    }

    fn restore_upload_scaled(&mut self, upload: &SparseVec, scale: f32) {
        upload.add_into(&mut self.v, scale);
    }

    fn residual_norm(&self) -> f32 {
        l2_norm(&self.v)
    }

    fn state_planes_mut(&mut self) -> Vec<(&'static str, &mut [f32])> {
        vec![("u", &mut self.u[..]), ("v", &mut self.v[..]), ("m", &mut self.m[..])]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::dgc::Dgc;
    use crate::sparse::merge::mean_pairwise_jaccard;
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    fn cfg_tau(tau: f32) -> CompressConfig {
        CompressConfig { tau: TauSchedule::Constant(tau), ..Default::default() }
    }

    #[test]
    fn tau_zero_equals_dgc_exactly() {
        let dim = 300;
        let mut gmf = DgcGmf::new(&cfg_tau(0.0), dim);
        let mut dgc = Dgc::new(&CompressConfig::default(), dim);
        let ghat = SparseVec::new(dim, vec![(5, 3.0), (9, -1.0)]);
        for round in 0..8 {
            gmf.observe_broadcast(&ghat);
            dgc.observe_broadcast(&ghat);
            let grad = randvec(dim, 50 + round);
            let a = gmf.compress(&grad, 30, round as usize);
            let b = dgc.compress(&grad, 30, round as usize);
            assert_eq!(a.gradient.indices, b.gradient.indices, "round {round}");
            assert_eq!(a.gradient.values, b.gradient.values);
        }
    }

    #[test]
    fn tau_biases_selection_toward_momentum() {
        let dim = 100;
        let mut gmf = DgcGmf::new(&cfg_tau(0.9), dim);
        // global momentum strongly favours coordinates 0..5
        let ghat = SparseVec::new(dim, (0..5).map(|i| (i, 100.0)).collect());
        gmf.observe_broadcast(&ghat);
        let grad = randvec(dim, 7);
        let out = gmf.compress(&grad, 10, 0);
        for i in 0..5u32 {
            assert!(out.gradient.indices.contains(&i), "coord {i} not selected");
        }
    }

    #[test]
    fn transmitted_values_are_residual_not_momentum() {
        // GMF only changes *which* coordinates are picked; the transmitted
        // values are still V's (compensated local information)
        let dim = 50;
        let mut gmf = DgcGmf::new(&cfg_tau(0.8), dim);
        let ghat = SparseVec::new(dim, vec![(2, 10.0)]);
        gmf.observe_broadcast(&ghat);
        let grad = randvec(dim, 9);
        let out = gmf.compress(&grad, 5, 0);
        for (&i, &val) in out.gradient.indices.iter().zip(&out.gradient.values) {
            assert!((val - grad[i as usize]).abs() < 1e-6); // first round: V == grad
        }
    }

    #[test]
    fn gmf_raises_mask_overlap_across_heterogeneous_clients() {
        // the mechanism behind the paper's downlink saving: with a shared
        // global momentum, client masks overlap more than DGC's
        let dim = 2000;
        let clients = 8;
        let k = 100;
        let rounds = 15;

        let run = |tau: f32| -> f64 {
            let mut comps: Vec<DgcGmf> =
                (0..clients).map(|_| DgcGmf::new(&cfg_tau(tau), dim)).collect();
            // a common drift direction + per-client noise (non-IID-ish)
            let common = randvec(dim, 1000);
            let mut last_overlap = 0.0;
            let mut ghat = SparseVec::empty(dim);
            for round in 0..rounds {
                let mut grads: Vec<SparseVec> = Vec::new();
                for (c, comp) in comps.iter_mut().enumerate() {
                    comp.observe_broadcast(&ghat);
                    let noise = randvec(dim, (round * 100 + c) as u64);
                    let grad: Vec<f32> = common
                        .iter()
                        .zip(&noise)
                        .map(|(cm, nz)| 0.3 * cm + nz)
                        .collect();
                    grads.push(comp.compress(&grad, k, round).gradient);
                }
                let refs: Vec<&SparseVec> = grads.iter().collect();
                last_overlap = mean_pairwise_jaccard(&refs);
                // aggregate
                let mut agg = crate::sparse::merge::Aggregator::new(dim);
                agg.add(&refs, 1.0, 1);
                let mut mean = SparseVec::empty(0);
                agg.finish_into(clients, &mut mean, 1);
                ghat = mean;
            }
            last_overlap
        };

        let overlap_dgc = run(0.0);
        let overlap_gmf = run(0.6);
        assert!(
            overlap_gmf > overlap_dgc,
            "GMF overlap {overlap_gmf} must exceed DGC overlap {overlap_dgc}"
        );
    }

    #[test]
    fn stepped_schedule_applies_over_rounds() {
        let cfg = CompressConfig {
            tau: TauSchedule::Stepped { end: 0.6, steps: 10, total_rounds: 20 },
            ..Default::default()
        };
        let gmf = DgcGmf::new(&cfg, 10);
        assert_eq!(gmf.tau_at(0), 0.0);
        assert!(gmf.tau_at(19) > 0.5);
    }
}
