//! Gradient compression schemes (the paper's Table 2):
//!
//! | Technique | Momentum correction | Client-side GM | Server-side GM |
//! |-----------|--------------------|----------------|----------------|
//! | DGC       | yes                | —              | —              |
//! | GMC       | —                  | compensation   | —              |
//! | DGCwGM    | yes                | —              | yes (server)   |
//! | DGCwGMF   | yes                | compression    | —              |
//!
//! Client-side state machines live here; the *server*-side half of DGCwGM
//! (momentum on the aggregate) lives in `coordinator::server` as a
//! [`BroadcastPolicy`]. All schemes share the same hot-path primitives
//! (`primitives.rs`), which mirror the L1 Pallas kernels one-to-one and are
//! equivalence-tested against the AOT artifacts.

pub mod gmc;
pub mod policy;
pub mod primitives;
pub mod rate_control;
pub mod schedule;

pub mod dgc;
pub mod dgc_gmf;

pub use dgc::Dgc;
pub use dgc_gmf::DgcGmf;
pub use gmc::Gmc;
pub use policy::{Compressor, CompressorKind, CompressConfig, TechniqueRow};
pub use rate_control::{
    HistorySignals, LinkSignals, RateControlConfig, RateControlMode, RateDecision,
};
pub use schedule::{SparsityWarmup, TauSchedule};

use crate::sparse::vector::SparseVec;

/// Build a client compressor of the given kind.
///
/// `DGCwGM` uses a plain DGC client (its global momentum is server-side);
/// the distinction is carried by the coordinator's broadcast policy.
pub fn build(kind: CompressorKind, cfg: &CompressConfig, dim: usize) -> Box<dyn Compressor> {
    match kind {
        CompressorKind::Dgc | CompressorKind::DgcWgm => Box::new(Dgc::new(cfg, dim)),
        CompressorKind::Gmc => Box::new(Gmc::new(cfg, dim)),
        CompressorKind::DgcWgmf => Box::new(DgcGmf::new(cfg, dim)),
    }
}

/// Output of one client compression call.
#[derive(Clone, Debug)]
pub struct Compressed {
    pub gradient: SparseVec,
    /// selection threshold actually used (diagnostics)
    pub threshold: f32,
}
