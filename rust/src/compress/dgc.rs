//! DGC — Deep Gradient Compression (Lin et al., ICLR 2018), the baseline.
//!
//! Client keeps momentum-corrected residuals:
//! ```text
//!   U ← α·U + ∇         (momentum correction)
//!   V ← V + U           (residual accumulation)
//!   mask = top-k(|V|) ; transmit V⊙mask ; U,V ⊙= (1−mask)
//! ```
//! Also used verbatim as the client half of DGCwGM (the server adds its
//! global momentum on the aggregate).

use super::policy::{CompressConfig, Compressor};
use super::primitives;
use crate::sparse::vector::SparseVec;
use crate::util::math::l2_norm;

pub struct Dgc {
    alpha: f32,
    clip_norm: f32,
    exact_topk: bool,
    u: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    scratch: Vec<f32>,
    grad_buf: Vec<f32>,
}

impl Dgc {
    pub fn new(cfg: &CompressConfig, dim: usize) -> Self {
        Dgc {
            alpha: cfg.alpha,
            clip_norm: cfg.clip_norm,
            exact_topk: cfg.exact_topk,
            u: vec![0.0; dim],
            v: vec![0.0; dim],
            scores: vec![0.0; dim],
            scratch: Vec::new(),
            grad_buf: vec![0.0; dim],
        }
    }
}

impl Compressor for Dgc {
    fn name(&self) -> &'static str {
        "DGC"
    }

    fn observe_broadcast(&mut self, _ghat: &SparseVec) {
        // DGC tracks no global state on the client.
    }

    fn observes_broadcast(&self) -> bool {
        false
    }

    fn compress_into(&mut self, grad: &[f32], k: usize, round: usize, out: &mut SparseVec) -> f32 {
        debug_assert_eq!(grad.len(), self.u.len());
        self.grad_buf.copy_from_slice(grad);
        primitives::clip_gradient(&mut self.grad_buf, self.clip_norm);
        primitives::dgc_update(&mut self.u, &mut self.v, &self.grad_buf, self.alpha);
        primitives::abs_score(&mut self.scores, &self.v);
        primitives::extract_and_clear_into(
            &mut self.u,
            &mut self.v,
            &self.scores,
            k,
            self.exact_topk,
            round as u64,
            &mut self.scratch,
            out,
        )
    }

    fn restore_upload_scaled(&mut self, upload: &SparseVec, scale: f32) {
        upload.add_into(&mut self.v, scale);
    }

    fn residual_norm(&self) -> f32 {
        l2_norm(&self.v)
    }

    fn state_planes_mut(&mut self) -> Vec<(&'static str, &mut [f32])> {
        vec![("u", &mut self.u[..]), ("v", &mut self.v[..])]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> CompressConfig {
        CompressConfig { alpha: 0.9, ..Default::default() }
    }

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn first_round_transmits_topk_of_gradient() {
        let mut dgc = Dgc::new(&cfg(), 100);
        let grad = randvec(100, 1);
        let out = dgc.compress(&grad, 10, 0);
        assert_eq!(out.gradient.nnz(), 10);
        // with U=V=0, V after update == grad, so values are gradient values
        for (&i, &val) in out.gradient.indices.iter().zip(&out.gradient.values) {
            assert!((val - grad[i as usize]).abs() < 1e-6);
        }
    }

    #[test]
    fn residual_accumulates_unselected_mass() {
        let mut dgc = Dgc::new(&cfg(), 50);
        let grad = randvec(50, 2);
        let norm_before = l2_norm(&grad);
        let out = dgc.compress(&grad, 5, 0);
        let res = dgc.residual_norm();
        assert!(res > 0.0 && res < norm_before);
        // transmitted + residual energy ≈ total (disjoint support)
        let sent = out.gradient.l2_norm();
        let energy_gap = (sent * sent + res * res - norm_before * norm_before).abs();
        assert!(energy_gap / (norm_before * norm_before) < 1e-4);
    }

    #[test]
    fn no_residual_nothing_lost_over_rounds() {
        // sum of everything ever transmitted + final residual == sum of all
        // momentum-corrected gradients (error-feedback invariant)
        let dim = 200;
        let mut dgc = Dgc::new(&CompressConfig { alpha: 0.0, ..cfg() }, dim);
        let mut transmitted = vec![0.0f32; dim];
        let mut total = vec![0.0f32; dim];
        for round in 0..20 {
            let grad = randvec(dim, 100 + round);
            for i in 0..dim {
                total[i] += grad[i];
            }
            let out = dgc.compress(&grad, 20, round as usize);
            out.gradient.add_into(&mut transmitted, 1.0);
        }
        for i in 0..dim {
            let residual = total[i] - transmitted[i];
            assert!((residual - dgc.v[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn momentum_correction_differs_from_plain_momentum() {
        // alpha > 0 must change the transmitted values vs alpha = 0
        let grad = randvec(64, 5);
        let mut a = Dgc::new(&CompressConfig { alpha: 0.9, ..cfg() }, 64);
        let mut b = Dgc::new(&CompressConfig { alpha: 0.0, ..cfg() }, 64);
        let _ = a.compress(&grad, 6, 0);
        let _ = b.compress(&grad, 6, 0);
        let ga = a.compress(&grad, 6, 1);
        let gb = b.compress(&grad, 6, 1);
        assert_ne!(ga.gradient.values, gb.gradient.values);
    }

    #[test]
    fn only_global_momentum_schemes_observe_broadcasts() {
        assert!(!Dgc::new(&cfg(), 8).observes_broadcast());
        assert!(crate::compress::Gmc::new(&CompressConfig::default(), 8).observes_broadcast());
        assert!(crate::compress::DgcGmf::new(&CompressConfig::default(), 8).observes_broadcast());
    }

    #[test]
    fn restored_upload_is_retransmitted_verbatim() {
        // a dropped upload, restored into V, must come back out of the next
        // compression unchanged when nothing new competes with it (α = 0 so
        // a zero gradient leaves U — and therefore V — untouched)
        for kind in crate::compress::CompressorKind::ALL {
            let dim = 120;
            let cfg = CompressConfig {
                alpha: 0.0,
                exact_topk: true,
                tau: crate::compress::TauSchedule::Constant(0.0),
                ..CompressConfig::default()
            };
            let mut comp = crate::compress::build(kind, &cfg, dim);
            let grad = randvec(dim, 77);
            let first = comp.compress(&grad, 12, 0);
            assert_eq!(first.gradient.nnz(), 12);
            // the server never saw `first`: put it back
            comp.restore_upload(&first.gradient);
            let zeros = vec![0.0f32; dim];
            let second = comp.compress(&zeros, 12, 1);
            assert_eq!(
                second.gradient, first.gradient,
                "{}: restored residual must re-enter the next upload verbatim",
                kind.name()
            );
        }
    }

    #[test]
    fn partial_restore_returns_exactly_the_scaled_fraction() {
        // the carry-discount path restores (1 − α)·upload; with a zero
        // follow-up gradient and α_momentum = 0 the next upload must be the
        // scaled fraction verbatim (0.25 is a power of two: exact in f32)
        for kind in crate::compress::CompressorKind::ALL {
            let dim = 120;
            let cfg = CompressConfig {
                alpha: 0.0,
                exact_topk: true,
                tau: crate::compress::TauSchedule::Constant(0.0),
                ..CompressConfig::default()
            };
            let mut comp = crate::compress::build(kind, &cfg, dim);
            // exactly k nonzeros: after round 0 the residual is empty, so
            // the restored fraction alone defines round 1's top-k
            let mut grad = vec![0.0f32; dim];
            let mut r = Rng::new(78);
            for i in 0..12 {
                grad[i * 9] = r.normal() + if r.f32() < 0.5 { 1.5 } else { -1.5 };
            }
            let first = comp.compress(&grad, 12, 0);
            assert_eq!(first.gradient.nnz(), 12, "{}", kind.name());
            comp.restore_upload_scaled(&first.gradient, 0.25);
            let zeros = vec![0.0f32; dim];
            let second = comp.compress(&zeros, 12, 1);
            assert_eq!(second.gradient.indices, first.gradient.indices, "{}", kind.name());
            for (a, b) in second.gradient.values.iter().zip(&first.gradient.values) {
                assert_eq!(a.to_bits(), (0.25 * b).to_bits(), "{}", kind.name());
            }
        }
    }

    #[test]
    fn clipping_bounds_update_energy() {
        let mut dgc = Dgc::new(&CompressConfig { clip_norm: 0.1, alpha: 0.0, ..cfg() }, 32);
        let grad: Vec<f32> = (0..32).map(|i| (i as f32) * 10.0).collect();
        let out = dgc.compress(&grad, 32, 0);
        assert!(out.gradient.l2_norm() <= 0.1 + 1e-5);
    }
}
