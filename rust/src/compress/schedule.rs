//! Schedules: the paper's stepped fusion-ratio ramp and DGC's sparsity
//! warmup.

/// Fusion ratio τ over training (paper §4.1: "start from 0 and step
/// increase to 0.6 in 10 steps").
#[derive(Clone, Debug)]
pub enum TauSchedule {
    /// Constant τ.
    Constant(f32),
    /// `steps` equal increments from 0 up to `end`, spread over
    /// `total_rounds` rounds: τ(t) = end · floor(t·steps/total) / steps.
    Stepped { end: f32, steps: usize, total_rounds: usize },
}

impl TauSchedule {
    /// The paper's setting for a run of `total_rounds`.
    pub fn paper(total_rounds: usize) -> TauSchedule {
        TauSchedule::Stepped { end: 0.6, steps: 10, total_rounds }
    }

    /// Placeholder default (rebound to the run length by the config layer).
    pub fn paper_default() -> TauSchedule {
        TauSchedule::paper(220)
    }

    pub fn at(&self, round: usize) -> f32 {
        match *self {
            TauSchedule::Constant(tau) => tau,
            TauSchedule::Stepped { end, steps, total_rounds } => {
                if total_rounds == 0 || steps == 0 {
                    return end;
                }
                let step = (round * steps) / total_rounds;
                end * (step.min(steps) as f32) / steps as f32
            }
        }
    }
}

/// DGC's sparsity warmup: keep-rate starts high (transmit almost
/// everything) and decays exponentially to the target over the first
/// `warmup_rounds`, avoiding early-training divergence at aggressive
/// compression.
#[derive(Clone, Copy, Debug)]
pub struct SparsityWarmup {
    /// final keep rate (paper's "compression rate", e.g. 0.1)
    pub rate: f64,
    /// rounds of warmup; 0 disables
    pub warmup_rounds: usize,
}

impl SparsityWarmup {
    pub fn none(rate: f64) -> Self {
        SparsityWarmup { rate, warmup_rounds: 0 }
    }

    /// Effective keep-rate for `round`.
    pub fn at(&self, round: usize) -> f64 {
        if round >= self.warmup_rounds || self.warmup_rounds == 0 {
            return self.rate;
        }
        // geometric interpolation 1.0 → rate over warmup_rounds
        let frac = (round + 1) as f64 / self.warmup_rounds as f64;
        let keep = self.rate.powf(frac);
        keep.max(self.rate)
    }

    /// k for a parameter vector of length `dim` at `round` (at least 1).
    pub fn k_at(&self, dim: usize, round: usize) -> usize {
        ((self.at(round) * dim as f64).ceil() as usize).clamp(1, dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stepped_tau_ramp() {
        let s = TauSchedule::paper(100);
        assert_eq!(s.at(0), 0.0);
        // step width = 10 rounds; after the first step τ = 0.06
        assert!((s.at(10) - 0.06).abs() < 1e-6);
        assert!((s.at(55) - 0.3).abs() < 1e-6);
        assert!((s.at(99) - 0.54).abs() < 1e-6);
        assert!((s.at(1000) - 0.6).abs() < 1e-6); // clamped after the ramp
    }

    #[test]
    fn constant_tau() {
        let s = TauSchedule::Constant(0.25);
        assert_eq!(s.at(0), 0.25);
        assert_eq!(s.at(999), 0.25);
    }

    #[test]
    fn tau_monotone_nondecreasing() {
        let s = TauSchedule::paper(220);
        let mut last = -1.0f32;
        for t in 0..220 {
            let tau = s.at(t);
            assert!(tau >= last);
            assert!((0.0..=0.6).contains(&tau));
            last = tau;
        }
    }

    #[test]
    fn warmup_decays_to_rate() {
        let w = SparsityWarmup { rate: 0.1, warmup_rounds: 4 };
        let keeps: Vec<f64> = (0..6).map(|t| w.at(t)).collect();
        // strictly decreasing during warmup, then flat at the target
        assert!(keeps[0] > keeps[1] && keeps[1] > keeps[2] && keeps[2] > keeps[3]);
        assert!((keeps[3] - 0.1).abs() < 1e-12);
        assert_eq!(keeps[4], 0.1);
        assert_eq!(keeps[5], 0.1);
    }

    #[test]
    fn warmup_none_is_flat() {
        let w = SparsityWarmup::none(0.3);
        assert_eq!(w.at(0), 0.3);
        assert_eq!(w.at(100), 0.3);
    }

    #[test]
    fn k_at_bounds() {
        let w = SparsityWarmup::none(0.1);
        assert_eq!(w.k_at(1000, 0), 100);
        assert_eq!(w.k_at(3, 0), 1);
        let tiny = SparsityWarmup::none(1e-9);
        assert_eq!(tiny.k_at(1000, 0), 1); // never zero
        let full = SparsityWarmup::none(1.0);
        assert_eq!(full.k_at(1000, 0), 1000);
    }
}
