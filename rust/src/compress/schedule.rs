//! Schedules: the paper's stepped fusion-ratio ramp and DGC's sparsity
//! warmup.

/// Fusion ratio τ over training (paper §4.1: "start from 0 and step
/// increase to 0.6 in 10 steps").
#[derive(Clone, Debug)]
pub enum TauSchedule {
    /// Constant τ.
    Constant(f32),
    /// `steps` equal increments from 0 up to `end`, spread over
    /// `total_rounds` rounds: τ(t) = end · floor(t·steps/total) / steps.
    Stepped { end: f32, steps: usize, total_rounds: usize },
}

impl TauSchedule {
    /// The paper's setting for a run of `total_rounds`.
    pub fn paper(total_rounds: usize) -> TauSchedule {
        TauSchedule::Stepped { end: 0.6, steps: 10, total_rounds }
    }

    /// Placeholder default (rebound to the run length by the config layer).
    pub fn paper_default() -> TauSchedule {
        TauSchedule::paper(220)
    }

    pub fn at(&self, round: usize) -> f32 {
        match *self {
            TauSchedule::Constant(tau) => tau,
            TauSchedule::Stepped { end, steps, total_rounds } => {
                if total_rounds == 0 || steps == 0 {
                    return end;
                }
                // saturating: a round count near usize::MAX must clamp to
                // the ramp's end, not overflow the multiply
                let step = round.saturating_mul(steps) / total_rounds;
                end * (step.min(steps) as f32) / steps as f32
            }
        }
    }
}

/// DGC's sparsity warmup: keep-rate starts high (transmit almost
/// everything) and decays exponentially to the target over the first
/// `warmup_rounds`, avoiding early-training divergence at aggressive
/// compression.
#[derive(Clone, Copy, Debug)]
pub struct SparsityWarmup {
    /// final keep rate (paper's "compression rate", e.g. 0.1)
    pub rate: f64,
    /// rounds of warmup; 0 disables
    pub warmup_rounds: usize,
}

impl SparsityWarmup {
    pub fn none(rate: f64) -> Self {
        SparsityWarmup { rate, warmup_rounds: 0 }
    }

    /// Effective keep-rate for `round`.
    pub fn at(&self, round: usize) -> f64 {
        if round >= self.warmup_rounds || self.warmup_rounds == 0 {
            return self.rate;
        }
        // geometric interpolation 1.0 → rate over warmup_rounds
        let frac = (round + 1) as f64 / self.warmup_rounds as f64;
        let keep = self.rate.powf(frac);
        keep.max(self.rate)
    }

    /// k for a parameter vector of length `dim` at `round`: at least 1 and
    /// at most `dim` for any nonempty vector — a keep-rate of 1e-9 still
    /// transmits one coordinate, a rate of 1.0 never overruns the vector.
    /// `dim = 0` returns 0 (there is nothing to select; `clamp(1, 0)`
    /// would panic).
    pub fn k_at(&self, dim: usize, round: usize) -> usize {
        if dim == 0 {
            return 0;
        }
        ((self.at(round) * dim as f64).ceil() as usize).clamp(1, dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stepped_tau_ramp() {
        let s = TauSchedule::paper(100);
        assert_eq!(s.at(0), 0.0);
        // step width = 10 rounds; after the first step τ = 0.06
        assert!((s.at(10) - 0.06).abs() < 1e-6);
        assert!((s.at(55) - 0.3).abs() < 1e-6);
        assert!((s.at(99) - 0.54).abs() < 1e-6);
        assert!((s.at(1000) - 0.6).abs() < 1e-6); // clamped after the ramp
    }

    #[test]
    fn constant_tau() {
        let s = TauSchedule::Constant(0.25);
        assert_eq!(s.at(0), 0.25);
        assert_eq!(s.at(999), 0.25);
    }

    #[test]
    fn tau_monotone_nondecreasing() {
        let s = TauSchedule::paper(220);
        let mut last = -1.0f32;
        for t in 0..220 {
            let tau = s.at(t);
            assert!(tau >= last);
            assert!((0.0..=0.6).contains(&tau));
            last = tau;
        }
    }

    #[test]
    fn warmup_decays_to_rate() {
        let w = SparsityWarmup { rate: 0.1, warmup_rounds: 4 };
        let keeps: Vec<f64> = (0..6).map(|t| w.at(t)).collect();
        // strictly decreasing during warmup, then flat at the target
        assert!(keeps[0] > keeps[1] && keeps[1] > keeps[2] && keeps[2] > keeps[3]);
        assert!((keeps[3] - 0.1).abs() < 1e-12);
        assert_eq!(keeps[4], 0.1);
        assert_eq!(keeps[5], 0.1);
    }

    #[test]
    fn warmup_none_is_flat() {
        let w = SparsityWarmup::none(0.3);
        assert_eq!(w.at(0), 0.3);
        assert_eq!(w.at(100), 0.3);
    }

    #[test]
    fn k_at_bounds() {
        let w = SparsityWarmup::none(0.1);
        assert_eq!(w.k_at(1000, 0), 100);
        assert_eq!(w.k_at(3, 0), 1);
        let tiny = SparsityWarmup::none(1e-9);
        assert_eq!(tiny.k_at(1000, 0), 1); // never zero
        let full = SparsityWarmup::none(1.0);
        assert_eq!(full.k_at(1000, 0), 1000);
    }

    #[test]
    fn k_at_degenerate_dims_never_panic_or_overrun() {
        // dim = 0: nothing to select — 0, not a clamp(1, 0) panic
        for rate in [1e-12, 0.1, 1.0] {
            let w = SparsityWarmup { rate, warmup_rounds: 3 };
            for round in [0usize, 1, 3, 1000] {
                assert_eq!(w.k_at(0, round), 0, "rate {rate} round {round}");
                let k1 = w.k_at(1, round);
                assert_eq!(k1, 1, "dim 1 always transmits its one coordinate");
                let k = w.k_at(7, round);
                assert!((1..=7).contains(&k), "rate {rate} round {round}: k {k}");
            }
        }
        // warmup inflates k toward dim but never past it
        let w = SparsityWarmup { rate: 0.5, warmup_rounds: 4 };
        for round in 0..8 {
            assert!(w.k_at(10, round) <= 10);
            assert!(w.k_at(10, round) >= w.k_at(10, round + 1), "warmup k non-increasing");
        }
    }

    #[test]
    fn tau_round_boundaries() {
        // round 0 and round >= total_rounds under the stepped schedule
        let s = TauSchedule::Stepped { end: 0.6, steps: 10, total_rounds: 100 };
        assert_eq!(s.at(0), 0.0, "ramp starts at zero");
        assert!((s.at(99) - 0.54).abs() < 1e-6, "last in-range round");
        assert!((s.at(100) - 0.6).abs() < 1e-6, "round == total clamps to end");
        assert!((s.at(usize::MAX) - 0.6).abs() < 1e-6, "far past the end stays clamped");
        // degenerate schedules: zero rounds / zero steps read the end value
        assert_eq!(TauSchedule::Stepped { end: 0.3, steps: 10, total_rounds: 0 }.at(0), 0.3);
        assert_eq!(TauSchedule::Stepped { end: 0.3, steps: 0, total_rounds: 50 }.at(25), 0.3);
        // constants ignore the round entirely
        assert_eq!(TauSchedule::Constant(0.4).at(usize::MAX), 0.4);
    }
}
