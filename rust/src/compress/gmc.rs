//! GMC — Global Momentum Compression (Zhao et al., 2019).
//!
//! Client-side global momentum **in the compensation process** (paper
//! Table 2): instead of DGC's local momentum correction, the client folds
//! the *previous global update* into the residual each round — Zhao et
//! al.'s `u_t = (w_{t-1} − w_t)/η` is exactly the broadcast aggregate, so
//! the recursion `v = g + β·u` realises momentum SGD globally:
//!
//! ```text
//!   U ← Ĝ_{t-1}                 (observe_broadcast; the last global update)
//!   V ← V + ∇ + β·U             (compensation with global momentum pull)
//!   mask = top-k(|V|) ; transmit V⊙mask ; V ⊙= (1−mask)
//! ```
//!
//! (Ĝ recursively contains β·its own predecessor, so no client-side
//! geometric accumulation is needed — accumulating here would compound the
//! momentum twice and diverge.)
//!
//! The paper's §2.2 critique — which our Table 3/Fig 4 reproduction
//! measures — is that GMC ignores the variance between the local gradient
//! and the global momentum: under high-EMD data the compensation keeps
//! pulling V toward a global direction that poorly matches the local
//! distribution, the residual grows, and late in training the transmitted
//! values over-fit local data, degrading the global model.

use super::policy::{CompressConfig, Compressor};
use super::primitives;
use crate::sparse::vector::SparseVec;
use crate::util::math::l2_norm;

pub struct Gmc {
    beta: f32,
    clip_norm: f32,
    exact_topk: bool,
    v: Vec<f32>,
    m: Vec<f32>,
    u_dummy: Vec<f32>, // extract_and_clear clears U too; GMC has no U
    scores: Vec<f32>,
    scratch: Vec<f32>,
    grad_buf: Vec<f32>,
}

impl Gmc {
    pub fn new(cfg: &CompressConfig, dim: usize) -> Self {
        Gmc {
            beta: cfg.beta,
            clip_norm: cfg.clip_norm,
            exact_topk: cfg.exact_topk,
            v: vec![0.0; dim],
            m: vec![0.0; dim],
            u_dummy: vec![0.0; dim],
            scores: vec![0.0; dim],
            scratch: Vec::new(),
            grad_buf: vec![0.0; dim],
        }
    }

    pub fn momentum_norm(&self) -> f32 {
        l2_norm(&self.m)
    }
}

impl Compressor for Gmc {
    fn name(&self) -> &'static str {
        "GMC"
    }

    fn observe_broadcast(&mut self, ghat: &SparseVec) {
        // store the last global update (not an accumulation — Ĝ already
        // carries the momentum recursion)
        self.m.iter_mut().for_each(|x| *x = 0.0);
        ghat.add_into(&mut self.m, 1.0);
    }

    fn compress_into(&mut self, grad: &[f32], k: usize, round: usize, out: &mut SparseVec) -> f32 {
        debug_assert_eq!(grad.len(), self.v.len());
        self.grad_buf.copy_from_slice(grad);
        primitives::clip_gradient(&mut self.grad_buf, self.clip_norm);
        // V ← V + ∇ + β·M  (no local momentum correction)
        for i in 0..self.v.len() {
            self.v[i] += self.grad_buf[i] + self.beta * self.m[i];
        }
        primitives::abs_score(&mut self.scores, &self.v);
        primitives::extract_and_clear_into(
            &mut self.u_dummy,
            &mut self.v,
            &self.scores,
            k,
            self.exact_topk,
            round as u64,
            &mut self.scratch,
            out,
        )
    }

    fn restore_upload_scaled(&mut self, upload: &SparseVec, scale: f32) {
        upload.add_into(&mut self.v, scale);
    }

    fn residual_norm(&self) -> f32 {
        l2_norm(&self.v)
    }

    fn state_planes_mut(&mut self) -> Vec<(&'static str, &mut [f32])> {
        // `u_dummy` stays all-zero by construction (extract only ever clears
        // it), so only V and the replaced-per-broadcast M persist
        vec![("v", &mut self.v[..]), ("m", &mut self.m[..])]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn without_broadcast_behaves_like_plain_topk_with_residual() {
        let mut gmc = Gmc::new(&CompressConfig::default(), 80);
        let grad = randvec(80, 1);
        let out = gmc.compress(&grad, 8, 0);
        assert_eq!(out.gradient.nnz(), 8);
        for (&i, &val) in out.gradient.indices.iter().zip(&out.gradient.values) {
            assert!((val - grad[i as usize]).abs() < 1e-6);
        }
    }

    #[test]
    fn broadcast_biases_compensation() {
        let dim = 60;
        let mut a = Gmc::new(&CompressConfig::default(), dim);
        let mut b = Gmc::new(&CompressConfig::default(), dim);
        let ghat = SparseVec::new(dim, vec![(0, 10.0), (1, 10.0)]);
        b.observe_broadcast(&ghat);
        let grad = randvec(dim, 2);
        let ga = a.compress(&grad, 6, 0);
        let gb = b.compress(&grad, 6, 0);
        assert_ne!(ga.gradient.indices, gb.gradient.indices);
        // the boosted coordinates should now be selected
        assert!(gb.gradient.indices.contains(&0));
        assert!(gb.gradient.indices.contains(&1));
    }

    #[test]
    fn stores_last_broadcast_without_accumulating() {
        // Ĝ already carries the momentum recursion; GMC must not compound it
        let dim = 10;
        let mut gmc = Gmc::new(&CompressConfig { beta: 0.5, ..Default::default() }, dim);
        gmc.observe_broadcast(&SparseVec::new(dim, vec![(3, 8.0)]));
        assert_eq!(gmc.m[3], 8.0);
        gmc.observe_broadcast(&SparseVec::new(dim, vec![(4, 2.0)]));
        assert_eq!(gmc.m[3], 0.0, "previous broadcast replaced, not decayed");
        assert_eq!(gmc.m[4], 2.0);
    }

    #[test]
    fn residual_grows_when_momentum_diverges_from_gradient() {
        // the §2.2 failure mode in miniature: when the global update points
        // in a direction unrelated to the local gradient (high variance,
        // i.e. non-IID), the compensation keeps injecting that foreign mass
        // into V and the residual runs above the momentum-free case
        let dim = 100;
        let mut with_m = Gmc::new(&CompressConfig { beta: 0.9, ..Default::default() }, dim);
        let mut no_m = Gmc::new(&CompressConfig { beta: 0.9, ..Default::default() }, dim);
        let grad = randvec(dim, 3);
        let foreign = SparseVec::from_dense(&randvec(dim, 99)); // uncorrelated
        for round in 0..10 {
            with_m.observe_broadcast(&foreign);
            no_m.observe_broadcast(&SparseVec::empty(dim));
            let _ = with_m.compress(&grad, 10, round);
            let _ = no_m.compress(&grad, 10, round);
        }
        assert!(with_m.residual_norm() > no_m.residual_norm());
    }
}
